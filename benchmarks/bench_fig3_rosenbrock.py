"""Fig. 3 reproduction: relaxed 100-D Rosenbrock — GP-H / GP-X (Alg. 1,
RBF kernel, history 2, shared line search) vs BFGS.

Paper claim: "All algorithms shared the same line search routine and show
similar performance."  (scipy is unavailable offline; the BFGS baseline is
our own implementation using the SAME strong-Wolfe search.)
"""
import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_gp import ROSEN
from repro.optim import gp_optimize
from repro.optim.classic import bfgs_optimize


def _fg():
    def f(x):
        return jnp.sum(x[:-1] ** 2 + 2.0 * (x[1:] - x[:-1] ** 2) ** 2)

    g = jax.grad(f)
    return lambda x: (float(f(x)), g(x))


def run() -> dict:
    cfg = ROSEN
    fg = _fg()
    x0 = jnp.asarray(np.random.RandomState(cfg.seed + 3).randn(cfg.d)) * 0.5
    out = {}
    for name, kw in [
        ("gp_h", dict(mode="gph", lam=cfg.lam_gph)),
        ("gp_x", dict(mode="gpx", lam=cfg.lam_gpx)),
    ]:
        tr = gp_optimize(fg, x0, kernel="rbf", history=cfg.history,
                         max_iters=cfg.max_iters, tol_grad=cfg.tol_grad,
                         noise=1e-10, **kw)
        out[name] = {"iters": len(tr.gnorms) - 1,
                     "final_f": float(tr.fvals[-1]),
                     "final_gnorm": float(tr.gnorms[-1]),
                     "grad_evals": tr.n_grad_evals}
    trb = bfgs_optimize(fg, x0, max_iters=cfg.max_iters,
                        tol_grad=cfg.tol_grad)
    out["bfgs"] = {"iters": len(trb.gnorms) - 1,
                   "final_f": float(trb.fvals[-1]),
                   "final_gnorm": float(trb.gnorms[-1]),
                   "grad_evals": trb.n_grad_evals}
    out["paper_claim"] = "GP-H / GP-X / BFGS show similar performance"
    # "similar" per the paper's own Fig. 3: all three reach the optimum;
    # GP-X is visibly the slowest there too. Criterion: every method
    # converges (f < 1e-6) within an order of magnitude of the fastest.
    ok = all(out[k]["final_f"] < 1e-6 for k in ("gp_h", "gp_x", "bfgs"))
    spread = max(out[k]["iters"] for k in ("gp_h", "gp_x", "bfgs")) / \
        max(1, min(out[k]["iters"] for k in ("gp_h", "gp_x", "bfgs")))
    out["iter_spread"] = spread
    out["claim_holds"] = bool(ok and spread < 10.0)
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=2))

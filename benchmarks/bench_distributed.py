"""D-sharded state machine: measured collective bytes vs the O(N^2) model.

Compiled on 8 fake host devices (subprocess, same pattern as the optimizer
collectives bench), every phase program of ``core/dist_state.py`` is
lowered at TWO input dimensions and its all-reduce bytes are read off the
optimized HLO.  The claim under test is the headline of DESIGN.md sec. 14:
per-phase collective volume follows the analytic ``psum_bytes`` model —
O(N) for extend, O(N^2) for resolve/rebuild, O(QN) for queries — and is
EXACTLY independent of D (the (N, D) strips never cross the wire).
"""
import json
import os
import subprocess
import sys

_SRC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
from repro.core import ShardedGPGState
from repro.core.dist_state import PHASE_PSUMS, psum_bytes
from repro.utils.hlo import collective_bytes, count_psums

D_SMALL, D_LARGE = 256, 2048
CAP, Q = 8, 4
out = {"devices": jax.device_count(), "cap": CAP, "q": Q,
       "d_values": [D_SMALL, D_LARGE], "phases": {}}

def phase_programs(d):
    st = ShardedGPGState("rbf", d, capacity=CAP, lam=0.5, noise=1e-6)
    x = jnp.zeros((st.d_pad,))
    rhs = jnp.zeros((CAP, st.d_pad))
    xq = jnp.zeros((Q, st.d_pad))
    nz = jnp.asarray(1e-6)
    lam = jnp.asarray(0.5, st.data.base.X.dtype)
    itemsize = jnp.dtype(st.data.base.X.dtype).itemsize

    def fn(name):
        f = st._phase(name)
        return getattr(f, "fn", f)

    progs = {
        "extend": (fn("extend"), (st.data, x, x, nz)),
        "evict": (fn("evict"), (st.data, nz)),
        "refactor": (fn("refactor"), (st.data, lam, nz)),
        "resolve": (fn("resolve"), (st.data, rhs, nz)),
        "rebuild": (fn("rebuild"), (st.data, nz)),
        "query": (st._query_raw(Q), (st.data, xq)),
    }
    return progs, itemsize

rows = {}
for d in (D_SMALL, D_LARGE):
    progs, itemsize = phase_programs(d)
    for name, (f, args) in progs.items():
        jx = jax.make_jaxpr(f)(*args)
        hlo = jax.jit(f).lower(*args).compile().as_text()
        row = rows.setdefault(name, {
            "model_bytes": psum_bytes(name, cap=CAP, q=Q, itemsize=itemsize),
            "psums": count_psums(jx),
            "psum_budget": PHASE_PSUMS[name],
            "measured": {}})
        row["measured"][str(d)] = collective_bytes(hlo)

for name, row in rows.items():
    vals = set(row["measured"].values())
    row["d_independent"] = len(vals) == 1
    m = row["measured"][str(D_SMALL)]
    row["model_err"] = abs(m - row["model_bytes"]) / max(row["model_bytes"], 1)
    row["psum_budget_ok"] = row["psums"] <= row["psum_budget"]
out["phases"] = rows

# per-solve total on the wire: one extend (border psum) IS the solve path
out["solve_bytes"] = rows["extend"]["measured"][str(D_SMALL)]
out["query_bytes"] = rows["query"]["measured"][str(D_SMALL)]
out["rebuild_bytes"] = rows["rebuild"]["measured"][str(D_SMALL)]
out["claim_holds"] = all(
    r["d_independent"] and r["model_err"] == 0.0 and r["psum_budget_ok"]
    for r in rows.values())
print("RESULT" + json.dumps(out))
"""


def run() -> dict:
    r = subprocess.run(
        [sys.executable, "-c", _SRC], capture_output=True, text=True,
        timeout=900,
        env={"PYTHONPATH": "src", "PATH": os.environ.get("PATH", ""),
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")})
    for line in r.stdout.splitlines():
        if line.startswith("RESULT"):
            out = json.loads(line[len("RESULT"):])
            out["paper_claim"] = (
                "D-sharded incremental inference moves O(N^2) bytes per "
                "collective — never O(N D): extend psums 4N border floats, "
                "resolve/rebuild N^2 strips, queries 2QN + Q + 2N — all "
                "exactly matching the analytic model and invariant in D")
            return out
    return {"error": r.stdout[-500:] + r.stderr[-2000:], "claim_holds": False}


if __name__ == "__main__":
    print(json.dumps(run(), indent=2))

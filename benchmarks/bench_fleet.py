"""Multi-tenant fleet bench: vmapped state batches vs the per-tenant loop.

Claims gated here (DESIGN.md sec. 15):

  1. CORRECTNESS — a fleet churn trajectory (join / extend past the
     window / evict / refit / query on heterogeneous tenants) matches the
     same ops driven per tenant through the plain single-state primitives
     to <= 1e-5 relative (``fleet_vs_loop_err``).
  2. LAUNCH EFFICIENCY — the continuous-batching server packs every
     round of pending tenant ops into ONE vmapped launch per op type:
     device launches per tenant-op (``ratio_launches_per_op``) stays at
     ~1/B instead of 1, and the whole churn compiles each op exactly once
     per signature (``one_compile_per_signature``).
  3. THROUGHPUT — steady-state extend+query tenant throughput of the
     batched fleet vs the same jitted ops looped per tenant
     (``tenants_per_second`` / ``fleet_speedup_x``; machine-dependent,
     NOT regression-gated).

Emits ``BENCH_fleet.json`` at the repo root (standalone or via
``benchmarks.run``) so successive PRs can diff the trajectory.
"""
import json
import os
import time

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np

from repro.core import get_kernel
from repro.core.fleet import GPFleet, fleet_lane
from repro.core.state import gpg_evict, gpg_extend, gpg_init
from repro.obs import compile_watch
from repro.obs import trace as obs
from repro.train.serve import GPFleetServer

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

D = 8
WINDOW = 4
B = 8
CHURN_STEPS = 10


def _churn_err() -> float:
    """Max relative lane error of a full churn trajectory vs the loop."""
    spec = get_kernel("rbf")
    r = np.random.RandomState(0)
    lams = np.exp(r.uniform(-0.5, 0.5, B))
    noises = 10.0 ** r.uniform(-7.0, -5.0, B)
    fl = GPFleet(spec, d=D, window=WINDOW, batch=B)
    singles = {}
    for b in range(B):
        t = f"t{b}"
        fl.join(t, lam=lams[b], noise=noises[b])
        singles[t] = gpg_init(spec, D, WINDOW, lam=lams[b])
    ext = jax.jit(lambda d_, x, g, nz: gpg_extend(spec, d_, x, g, noise=nz))
    ev = jax.jit(lambda d_, nz: gpg_evict(spec, d_, noise=nz, solve=False))
    for step in range(CHURN_STEPS):
        sel = [t for i, t in enumerate(singles) if (step + i) % 3 != 0]
        xs = {t: (r.randn(D), r.randn(D)) for t in sel}
        fl.extend(xs)
        for t, (x, g) in xs.items():
            nz = jnp.asarray(noises[int(t[1:])])
            if int(singles[t].count) >= WINDOW:
                singles[t] = ev(singles[t], nz)
            singles[t] = ext(singles[t], jnp.asarray(x), jnp.asarray(g), nz)
    err = 0.0
    for t, s in singles.items():
        lane = fleet_lane(fl.fleet, fl.slot_of(t))
        sc = max(1.0, float(jnp.max(jnp.abs(s.Z))))
        err = max(err, float(jnp.max(jnp.abs(lane.Z - s.Z))) / sc)
        assert int(lane.count) == int(s.count)
    return err


def _launches_per_op() -> dict:
    """Serve a request storm through the continuous-batching loop and
    count device launches + compiles per tenant-op."""
    r = np.random.RandomState(1)
    with obs.use_obs(True):
        before = obs.snapshot()
        marks = {w.name for w in compile_watch.all_watches()}
        srv = GPFleetServer(kernel="rbf", d=D)
        for b in range(B):
            srv.connect(f"t{b}", lam=0.5 + 0.1 * b, noise=1e-6)
        n_ops = 0
        for step in range(CHURN_STEPS):
            for b in range(B):
                t = f"t{b}"
                srv.submit(t, "extend", (r.randn(D), r.randn(D)))
                n_ops += 1
                if step % 2 == 0:
                    srv.submit(t, "query", r.randn(4, D))
                    n_ops += 1
        srv.submit("t0", "refit")
        n_ops += 1
        srv.drain()
        launches = obs.REGISTRY.delta(before)["counters"].get(
            "fleet.launches", 0.0)
        watches = [w for w in compile_watch.all_watches()
                   if w.name not in marks]
        stable = all(not w.violations() for w in watches)
        compiles = int(sum(w.n_compiles() for w in watches))
        sigs = int(sum(w.n_signatures() for w in watches))
    return {
        "tenant_ops": n_ops,
        "launches": int(launches),
        "ratio_launches_per_op": round(launches / n_ops, 4),
        "compiles": compiles,
        "signatures": sigs,
        "one_compile_per_signature": bool(stable and compiles == sigs),
    }


def _throughput() -> dict:
    """Steady-state extend throughput: one vmapped launch for B tenants
    vs the same jitted single-tenant op looped B times."""
    spec = get_kernel("rbf")
    r = np.random.RandomState(2)
    fl = GPFleet(spec, d=D, window=WINDOW, batch=B)
    for b in range(B):
        fl.join(f"t{b}", lam=1.0, noise=1e-6)
    obs_batch = {f"t{b}": (r.randn(D), r.randn(D)) for b in range(B)}
    fl.extend(obs_batch)                      # warm: compile + window fill
    for _ in range(WINDOW):
        fl.extend(obs_batch)
    reps = 20
    t0 = time.perf_counter()
    for _ in range(reps):
        fl.extend(obs_batch)
    jax.block_until_ready(fl.fleet.data.Z)
    dt_fleet = (time.perf_counter() - t0) / reps

    single = gpg_init(spec, D, WINDOW, lam=1.0)
    ext = jax.jit(lambda d_, x, g: gpg_extend(spec, d_, x, g, noise=1e-6))
    ev = jax.jit(lambda d_: gpg_evict(spec, d_, noise=1e-6, solve=False))
    for _ in range(WINDOW + 1):               # warm + fill
        single = ext(ev(single) if int(single.count) >= WINDOW else single,
                     jnp.zeros(D), jnp.ones(D))
    t0 = time.perf_counter()
    for _ in range(reps):
        for b in range(B):                    # B sequential launches
            single = ext(ev(single), jnp.zeros(D), jnp.ones(D))
    jax.block_until_ready(single.Z)
    dt_loop = (time.perf_counter() - t0) / reps
    return {
        "tenants_per_second": round(B / dt_fleet, 1),
        "loop_tenants_per_second": round(B / dt_loop, 1),
        "fleet_speedup_x": round(dt_loop / dt_fleet, 2),
        "fleet_step_ms": round(dt_fleet * 1e3, 3),
    }


def run() -> dict:
    out = {"d": D, "window": WINDOW, "tenants": B}
    out["fleet_vs_loop_err"] = _churn_err()
    out.update(_launches_per_op())
    out.update(_throughput())
    out["claim_holds"] = bool(
        out["fleet_vs_loop_err"] <= 1e-5
        and out["one_compile_per_signature"]
        and out["ratio_launches_per_op"] < 1.0)
    return out


if __name__ == "__main__":
    res = run()
    print(json.dumps(res, indent=1))
    with open(os.path.join(_ROOT, "BENCH_fleet.json"), "w") as f:
        json.dump(res, f, indent=1)

"""Regime-aware large-N solver bench (DESIGN.md sec. 16).

The paper's exact decomposition is an N < D story; past the crossover the
(N^2, N^2) determinant-lemma inner matrix dominates.  This bench gates
the ``repro.regime`` escape hatch:

  * iterative (matrix-free Krylov) posterior at N=96, D=32 agrees with
    the dense (ND, ND) oracle to <= 1e-4 (measured ~1e-10);
  * SLQ evidence agrees with the slogdet oracle to <= 1% relative;
  * the analytic cost-model crossover N*(D) is reported, and the live
    ``regime.switch`` telemetry fires at exactly that N;
  * the modeled HBM bytes of one iterative solve (a deterministic
    traffic polynomial, regression-gated via ``run.py --check``);
  * the structural jaxpr proof: the iterative path never materializes
    an (ND, ND) object or a dense N^2-axis intermediate;
  * crossing the regime boundary causes ZERO recompiles of a compiled
    serve step (regime decisions are host-side ints, not shapes).
"""
import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np

from repro.core import build_factors, get_kernel
from repro.core.gram import dense_gram
from repro.core.state import GPGState
from repro.hyper import HyperParams, mll_dense
from repro.obs import compile_watch
from repro.obs import trace as obs
from repro.regime import (RegimePolicy, assert_streaming_structure,
                          posterior_solve, slq_mll)
from repro.train.serve import build_gp_serve_step


def _regime_switch_recompiles() -> dict:
    """Stream a windowed state across the regime crossover under the
    recompile sentinel: the compiled serve step must keep ONE signature.

    d=6 puts the modeled crossover at n=7 (inside a 12-extend stream);
    capacity is pre-sized past the stream so no growth doubling fires —
    capacity is the ONLY shape key; the regime switch itself must be
    shape-free.
    """
    prev_enabled = obs.enabled()
    obs.set_enabled(True)
    watches_before = list(compile_watch.all_watches())
    try:
        rng = np.random.RandomState(2)
        d = 6
        st = GPGState("rbf", d=d, capacity=16, lam=0.5,
                      noise=1e-8, policy="iterate")
        pol = st.policy
        bundle = build_gp_serve_step(st, microbatch=4)
        Xq = jnp.asarray(rng.randn(4, d))
        switched_at = None
        for i in range(12):
            st.extend(rng.randn(d), rng.randn(d))
            if switched_at is None and st.regime == "iterative":
                switched_at = st.n
            bundle.query(Xq)
        watch = next(w for w in compile_watch.all_watches()
                     if w not in watches_before and
                     w.name == "gp_serve_step")
        recompiles = sum(c - 1 for c in watch.compiles.values() if c > 1)
        return {
            "crossover_n": pol.crossover_n(d),
            "switched_at": switched_at,
            "switch_on_model": switched_at == pol.crossover_n(d),
            "serve_signatures": len(watch.compiles),
            "recompiles_across_switch": recompiles,
        }
    finally:
        obs.set_enabled(True if prev_enabled else None)


def run() -> dict:
    spec = get_kernel("rbf")
    rng = np.random.RandomState(0)
    n, d = 96, 32
    X = jnp.asarray(rng.randn(n, d))
    G = jnp.asarray(rng.randn(n, d))
    lam = 1.0 / d
    signal, noise = 1.2, 1e-4
    noise_eff = noise / signal
    f = build_factors(spec, X, lam=lam, noise=noise_eff)

    # 1) matrix-free Krylov posterior vs the dense (ND, ND) oracle
    res = posterior_solve(spec, f, G, tol=1e-10)
    K = dense_gram(spec, X, lam=lam, noise=noise_eff)
    Zo = jnp.linalg.solve(K, G.reshape(-1)).reshape(n, d)
    solve_rel_err = float(jnp.linalg.norm(res.Z - Zo)
                          / jnp.linalg.norm(Zo))

    # 2) SLQ evidence vs the slogdet oracle
    h = HyperParams.create(lengthscale2=1.0 / lam, signal=signal,
                           noise=noise)
    m_slq = float(slq_mll(spec, X, G, h))
    m_oracle = float(mll_dense(spec, X, G, h))
    slq_mll_rel = abs(m_slq - m_oracle) / abs(m_oracle)

    # 3) the analytic crossover + the modeled iterative HBM traffic
    pol = RegimePolicy()
    iters = int(res.iters)
    hbm = {
        "iters": iters,
        "iterative_hbm_bytes": pol.cost.iterative_hbm_bytes(n, d, iters),
        "exact_flops": pol.cost.exact_flops(n, d),
        "iterative_flops": pol.cost.iterative_flops(
            n, d, pol.planned_iters),
    }

    # 4) structural proof: no (ND, ND) object, no dense N^2-sized axis
    try:
        max_axis, max_size = assert_streaming_structure(
            lambda g: posterior_solve(spec, f, g, tol=1e-10).Z, G,
            n=n, d=d)
        structure = {"ok": True, "max_axis": int(max_axis),
                     "max_size": int(max_size), "nd": n * d}
    except Exception as e:  # noqa: BLE001
        structure = {"ok": False, "error": str(e)}

    # 5) regime switch under the recompile sentinel
    switch = _regime_switch_recompiles()

    return {
        "n": n, "d": d,
        "solve_rel_err": solve_rel_err,
        "slq_mll_rel": slq_mll_rel,
        "mll_slq": m_slq, "mll_oracle": m_oracle,
        "crossover_n_d32": pol.crossover_n(d),
        "hbm_model": hbm,
        "structure": structure,
        "regime_switch": switch,
        "paper_claim": "matrix-free Krylov + SLQ extend exact GPG "
                       "inference past the N<D ceiling at O(iters N^2 D) "
                       "without (ND,ND) intermediates or recompiles",
        "claim_holds": bool(
            solve_rel_err <= 1e-4
            and slq_mll_rel <= 0.01
            and structure["ok"]
            and switch["switch_on_model"]
            and switch["recompiles_across_switch"] == 0
            and switch["serve_signatures"] == 1),
    }


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=2))

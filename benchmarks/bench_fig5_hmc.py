"""Fig. 5 / Sec. 5.3 reproduction: GPG-HMC vs HMC on the 100-D banana.

Paper claims (qualitative): with a budget of N = floor(sqrt(D)) true
gradient observations collected in the early phase, GPG-HMC samples with
acceptance comparable to HMC, while the per-sample gradient cost drops
from T leapfrog evaluations of the true gradient to ZERO (the acceptance
test still queries the true energy, so samples remain valid).
Also runs one random-rotated instance (App. F.3).
"""
import math

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp

from repro.configs.paper_gp import HMC as CFG
from repro.hyper import HyperParams
from repro.sampling import (banana_energy, banana_energy_rotated, gpg_hmc,
                            hmc, random_rotation)


def run(n_samples: int = 400) -> dict:
    d = CFG.d
    fourth = math.ceil(d ** 0.25)
    eps = CFG.eps_base / fourth
    steps = CFG.t_base * fourth
    budget = int(CFG.budget_factor * math.floor(math.sqrt(d)))
    refit = CFG.hyper_mode == "mll"
    key = jax.random.PRNGKey(CFG.seed)
    x0 = jax.random.normal(key, (d,))

    res_hmc = hmc(banana_energy, x0, key, n_samples=n_samples, eps=eps,
                  steps=steps, mass=CFG.mass)
    hp = HyperParams.create(lengthscale2=CFG.lengthscale2_factor * d,
                            noise=1e-8)
    res_gpg = gpg_hmc(banana_energy, x0, jax.random.PRNGKey(CFG.seed + 1),
                      n_samples=n_samples, eps=eps, steps=steps,
                      hypers=hp, refit_surrogate=refit,
                      budget=budget, mass=CFG.mass, max_train_iters=600)

    # rotated instance (conservative lengthscale + half step, App. F.3)
    R = random_rotation(d, seed=11)
    e_rot = banana_energy_rotated(R)
    res_rot = gpg_hmc(e_rot, x0, jax.random.PRNGKey(CFG.seed + 2),
                      n_samples=n_samples // 2, eps=eps / 2, steps=steps,
                      hypers=HyperParams.create(lengthscale2=0.25 * d,
                                                noise=1e-8),
                      refit_surrogate=refit,
                      budget=budget, mass=CFG.mass, max_train_iters=600)

    grad_calls_hmc = n_samples * (steps + 1)
    out = {
        "d": d, "eps": eps, "steps": steps, "budget": budget,
        "hmc_accept": float(res_hmc.accept_rate),
        "gpg_accept": res_gpg.accept_rate,
        "gpg_true_grad_calls": res_gpg.n_true_grad_calls,
        "gpg_train_iters": res_gpg.n_train_iters,
        "hmc_grad_calls_for_same_samples": grad_calls_hmc,
        "gradient_call_reduction": grad_calls_hmc /
        max(res_gpg.n_true_grad_calls, 1),
        "rotated_gpg_accept": res_rot.accept_rate,
        "paper_claim": "HMC 0.46+-0.02 vs GPG 0.50+-0.02 with N=10 "
                       "gradient observations (rotated ensemble)",
        "claim_holds": bool(res_gpg.accept_rate > 0.3
                            and res_gpg.n_true_grad_calls <= 3 * budget),
    }
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=2))

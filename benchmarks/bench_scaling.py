"""Complexity-scaling benchmark (paper Sec. 2.3 claims).

Measures wall time of the three solve paths as D grows at fixed N:
  * dense O((ND)^3) reference (small D only),
  * Woodbury exact O(N^2 D + N^6)  — should be ~linear in D,
  * poly2 fast path O(N^2 D + N^3).
Also verifies the memory claim: factor storage grows linearly in D.
Linearity is asserted by fitting the log-log slope of time vs D.
"""
import time

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np

from repro.core import (build_factors, dense_solve, get_kernel,
                        poly2_quadratic_solve, woodbury_solve)


def _time(fn, reps=3):
    fn()                                  # compile/warm
    ts = []
    for _ in range(reps):
        t0 = time.time()
        fn()
        ts.append(time.time() - t0)
    return min(ts)


def run() -> dict:
    n = 8
    spec = get_kernel("rbf")
    rng = np.random.RandomState(0)
    out = {"n": n, "woodbury": [], "poly2_fast": [], "dense": []}

    dims = [256, 1024, 4096, 16384, 65536]
    for d in dims:
        X = jnp.asarray(rng.randn(n, d))
        G = jnp.asarray(rng.randn(n, d))
        f = build_factors(spec, X, lam=1.0 / d)
        solve = jax.jit(lambda X_, G_: woodbury_solve(
            spec, build_factors(spec, X_, lam=1.0 / d), G_))
        t = _time(lambda: jax.block_until_ready(solve(X, G)))
        out["woodbury"].append({"d": d, "seconds": t})

        spec2 = get_kernel("poly2")
        c = jnp.zeros((d,))
        f2 = build_factors(spec2, X, lam=1.0 / d, c=c)
        fast = jax.jit(lambda X_, G_: poly2_quadratic_solve(
            build_factors(spec2, X_, lam=1.0 / d, c=c), G_))
        t2 = _time(lambda: jax.block_until_ready(fast(X, G)))
        out["poly2_fast"].append({"d": d, "seconds": t2})

    for d in [32, 64, 128]:
        X = jnp.asarray(rng.randn(n, d))
        G = jnp.asarray(rng.randn(n, d))
        t = _time(lambda: jax.block_until_ready(
            dense_solve(spec, X, G, lam=1.0 / d)), reps=1)
        out["dense"].append({"d": d, "seconds": t})

    # slope of woodbury time vs D over the top decade (expect ~<= 1.2)
    big = [r for r in out["woodbury"] if r["d"] >= 4096]
    slope = np.polyfit([np.log(r["d"]) for r in big],
                       [np.log(r["seconds"]) for r in big], 1)[0]
    out["woodbury_loglog_slope_vs_d"] = float(slope)
    out["paper_claim"] = "exact inference cost linear in D for N < D"
    out["claim_holds"] = bool(slope < 1.4)
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=2))

"""Pallas kernel microbenchmarks (interpret-mode correctness + jnp-path
throughput on CPU; the BlockSpec geometry is the TPU deliverable).

For each kernel: max abs error vs the ref.py oracle across a shape sweep,
plus CPU wall time of the jnp reference path (the number that matters on
this container; TPU timing requires hardware).

The fused_gram_mvm section additionally scores the single-launch Alg.-2
megakernel against the unfused three-launch sequence on the metric that
governs TPU wall clock for these memory-bound ops: **HBM bytes per CG
iteration**, via the analytic transfer model of DESIGN.md §4.3, converted
to roofline seconds for a TPU v5e. The fused path must come in at <= ~60%
of the unfused bytes (claim gate below); results land in
BENCH_kernels.json at the repo root for cross-PR tracking.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import (fused_gram_mvm, fused_gram_mvm_multi,
                           fused_gram_mvm_ref, fused_gram_norms,
                           fused_gram_norms_ref, gram_update, gram_update_ref,
                           skinny_gram, skinny_gram_ref)
from repro.utils.roofline import TPUv5e


from repro.utils.hlo import count_primitive


def _count_pallas_calls(jaxpr) -> int:
    """One launch with one (N, D) output pins the fused path's HBM transfer
    count to the DESIGN.md 4.3 model — a refactor that splits the MVM into
    multiple launches (re-materializing intermediates) flips the gate."""
    return count_primitive(jaxpr, "pallas_call")


def _time(fn, reps=5):
    fn()
    ts = []
    for _ in range(reps):
        t0 = time.time()
        jax.block_until_ready(fn())
        ts.append(time.time() - t0)
    return min(ts)


# ---------------------------------------------------------------------------
# Analytic HBM transfer model for one Gram MVM (DESIGN.md §4.3).
# Counts (N, D)-sized transfers in units of bytes; (N, N) traffic included
# for honesty but negligible at the benchmarked shapes.
# ---------------------------------------------------------------------------

def mvm_hbm_bytes(n: int, d: int, *, r: int = 1, itemsize: int = 4) -> dict:
    nd = n * d * itemsize
    nn = n * n * itemsize
    # Unfused XLA sequence per RHS (each launch materializes its output):
    #   skinny_gram:      read Xt + V,          write M            2nd + nn
    #   small algebra:    read M + K2e,         write small        3nn
    #   K1e @ V:          read K1e + V,         write t1           2nd + nn
    #   small @ Xt:       read small + Xt,      write t2           2nd + nn
    #   epilogue (*lam, +, +noise*V): read t1 + t2 + V, write W    4nd
    unfused = r * (10 * nd + 6 * nn)
    # Fused megakernel: phase 0 reads Xt+V, phase 1 reads Xt+V and writes W;
    # K1e/K2e read once. Multi-RHS streams Xt once per phase for all R.
    fused = (2 + 3 * r) * nd + 2 * nn
    return {
        "unfused_bytes": int(unfused),
        "fused_bytes": int(fused),
        "ratio": fused / unfused,
        "unfused_roofline_s": unfused / TPUv5e.hbm_bw,
        "fused_roofline_s": fused / TPUv5e.hbm_bw,
    }


def run() -> dict:
    rng = jax.random.PRNGKey(0)
    out = {}
    shapes = [(8, 8, 4096), (16, 16, 65536), (8, 8, 262144)]
    rows = []
    for na, nb, d in shapes:
        A = jax.random.normal(jax.random.fold_in(rng, 1), (na, d))
        B = jax.random.normal(jax.random.fold_in(rng, 2), (nb, d))
        got = skinny_gram(A, B, 0.5, interpret=True)
        want = skinny_gram_ref(A, B, 0.5)
        # relative error (f32 accumulation noise grows ~sqrt(D))
        err = float(jnp.max(jnp.abs(got - want)) /
                    jnp.max(jnp.abs(want)))
        ref = jax.jit(lambda a, b: skinny_gram_ref(a, b, 0.5))
        t = _time(lambda: ref(A, B))
        gbps = (A.size + B.size) * 4 / t / 1e9
        rows.append({"shape": [na, nb, d], "interp_err": err,
                     "jnp_seconds": t, "jnp_gb_per_s": gbps})
    out["skinny_gram"] = rows

    n, d = 8, 65536
    K1 = jax.random.normal(jax.random.fold_in(rng, 3), (n, n))
    M = jax.random.normal(jax.random.fold_in(rng, 4), (n, n))
    V = jax.random.normal(jax.random.fold_in(rng, 5), (n, d))
    X = jax.random.normal(jax.random.fold_in(rng, 6), (n, d))
    err = float(jnp.max(jnp.abs(
        gram_update(K1, M, V, X, 0.5, interpret=True) -
        gram_update_ref(K1, M, V, X, 0.5))))
    ref2 = jax.jit(lambda: gram_update_ref(K1, M, V, X, 0.5))
    out["gram_update"] = {"shape": [n, d], "interp_err": err,
                          "jnp_seconds": _time(lambda: ref2())}

    A = jax.random.normal(jax.random.fold_in(rng, 7), (8, 65536))
    P, na_, nb_ = fused_gram_norms(A, A, 0.3, interpret=True)
    Pr, nar, nbr = fused_gram_norms_ref(A, A, 0.3)
    out["fused_gram_norms"] = {
        "interp_err": float(max(jnp.max(jnp.abs(P - Pr)),
                                jnp.max(jnp.abs(na_ - nar[:, 0])))),
    }

    # --- fused Alg.-2 megakernel: parity + HBM-bytes-per-iteration model ---
    n, d = 16, 65536
    K1e = jax.random.normal(jax.random.fold_in(rng, 8), (n, n))
    K2e = jax.random.normal(jax.random.fold_in(rng, 9), (n, n)) * 0.1
    Xt = jax.random.normal(jax.random.fold_in(rng, 10), (n, d))
    Vv = jax.random.normal(jax.random.fold_in(rng, 11), (n, d))
    fused_rows = []
    for stationary in (False, True):
        got = fused_gram_mvm(K1e, K2e, Xt, Vv, 0.5, stationary=stationary,
                             noise=1e-2, interpret=True)
        want = fused_gram_mvm_ref(K1e, K2e, Xt, Vv, 0.5,
                                  stationary=stationary, noise=1e-2)
        err = float(jnp.max(jnp.abs(got - want)) / jnp.max(jnp.abs(want)))
        # CPU wall clock of the *unfused* jnp sequence this kernel replaces
        # (the fused kernel itself only runs for real on TPU).
        def unfused():
            m = (Xt * 0.5) @ Vv.T
            if stationary:
                mt = K2e * (m - jnp.diagonal(m)[None, :])
                small = jnp.diag(jnp.sum(mt, axis=1)) - mt
            else:
                small = K2e * m
            return (K1e @ Vv + small @ Xt) * 0.5 + 1e-2 * Vv
        t = _time(jax.jit(unfused))
        fused_rows.append({
            "stationary": stationary, "shape": [n, d], "interp_err": err,
            "jnp_unfused_seconds": t,
            "hbm_model": mvm_hbm_bytes(n, d),
        })
    # multi-RHS amortization sweep
    multi = []
    for r in (1, 2, 4, 8):
        model = mvm_hbm_bytes(n, d, r=r)
        model["r"] = r
        model["per_rhs_fused_bytes"] = model["fused_bytes"] / r
        multi.append(model)
    Vs = jax.random.normal(jax.random.fold_in(rng, 12), (2, n, 4096))
    Xs = Xt[:, :4096]
    got_m = fused_gram_mvm_multi(K1e, K2e, Xs, Vs, 0.5, stationary=True,
                                 interpret=True)
    want_m = fused_gram_mvm_ref(K1e, K2e, Xs, Vs, 0.5, stationary=True)
    # structural check backing the analytic byte model (see _count_pallas_calls)
    launches = _count_pallas_calls(jax.make_jaxpr(
        lambda v: fused_gram_mvm(K1e, K2e, Xt, v, 0.5, stationary=True,
                                 interpret=True))(Vv).jaxpr)
    launches_multi = _count_pallas_calls(jax.make_jaxpr(
        lambda v: fused_gram_mvm_multi(K1e, K2e, Xs, v, 0.5, stationary=True,
                                       interpret=True))(Vs).jaxpr)
    out["fused_gram_mvm"] = {
        "rows": fused_rows,
        "multi_rhs_model": multi,
        "multi_rhs_interp_err": float(jnp.max(jnp.abs(got_m - want_m)) /
                                      jnp.max(jnp.abs(want_m))),
        "pallas_calls_per_mvm": launches,
        "pallas_calls_per_multi_mvm": launches_multi,
        "paper_claim": "single-launch fused MVM cuts HBM bytes/iter vs the "
                       "unfused sequence (DESIGN.md 4.3)",
    }

    byte_ratio_ok = all(r["hbm_model"]["ratio"] <= 0.6 for r in fused_rows)
    out["claim_holds"] = bool(
        all(r["interp_err"] < 1e-5 for r in rows)
        and out["gram_update"]["interp_err"] < 1e-4
        and all(r["interp_err"] < 1e-4 for r in fused_rows)
        and out["fused_gram_mvm"]["multi_rhs_interp_err"] < 1e-4
        and launches == 1 and launches_multi == 1
        and byte_ratio_ok)
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=2))

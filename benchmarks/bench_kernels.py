"""Pallas kernel microbenchmarks (interpret-mode correctness + jnp-path
throughput on CPU; the BlockSpec geometry is the TPU deliverable).

For each kernel: max abs error vs the ref.py oracle across a shape sweep,
plus CPU wall time of the jnp reference path (the number that matters on
this container; TPU timing requires hardware).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import (fused_gram_norms, fused_gram_norms_ref,
                           gram_update, gram_update_ref, skinny_gram,
                           skinny_gram_ref)


def _time(fn, reps=5):
    fn()
    ts = []
    for _ in range(reps):
        t0 = time.time()
        jax.block_until_ready(fn())
        ts.append(time.time() - t0)
    return min(ts)


def run() -> dict:
    rng = jax.random.PRNGKey(0)
    out = {}
    shapes = [(8, 8, 4096), (16, 16, 65536), (8, 8, 262144)]
    rows = []
    for na, nb, d in shapes:
        A = jax.random.normal(jax.random.fold_in(rng, 1), (na, d))
        B = jax.random.normal(jax.random.fold_in(rng, 2), (nb, d))
        got = skinny_gram(A, B, 0.5, interpret=True)
        want = skinny_gram_ref(A, B, 0.5)
        # relative error (f32 accumulation noise grows ~sqrt(D))
        err = float(jnp.max(jnp.abs(got - want)) /
                    jnp.max(jnp.abs(want)))
        ref = jax.jit(lambda a, b: skinny_gram_ref(a, b, 0.5))
        t = _time(lambda: ref(A, B))
        gbps = (A.size + B.size) * 4 / t / 1e9
        rows.append({"shape": [na, nb, d], "interp_err": err,
                     "jnp_seconds": t, "jnp_gb_per_s": gbps})
    out["skinny_gram"] = rows

    n, d = 8, 65536
    K1 = jax.random.normal(jax.random.fold_in(rng, 3), (n, n))
    M = jax.random.normal(jax.random.fold_in(rng, 4), (n, n))
    V = jax.random.normal(jax.random.fold_in(rng, 5), (n, d))
    X = jax.random.normal(jax.random.fold_in(rng, 6), (n, d))
    err = float(jnp.max(jnp.abs(
        gram_update(K1, M, V, X, 0.5, interpret=True) -
        gram_update_ref(K1, M, V, X, 0.5))))
    ref2 = jax.jit(lambda: gram_update_ref(K1, M, V, X, 0.5))
    out["gram_update"] = {"shape": [n, d], "interp_err": err,
                          "jnp_seconds": _time(lambda: ref2())}

    A = jax.random.normal(jax.random.fold_in(rng, 7), (8, 65536))
    P, na_, nb_ = fused_gram_norms(A, A, 0.3, interpret=True)
    Pr, nar, nbr = fused_gram_norms_ref(A, A, 0.3)
    out["fused_gram_norms"] = {
        "interp_err": float(max(jnp.max(jnp.abs(P - Pr)),
                                jnp.max(jnp.abs(na_ - nar[:, 0])))),
    }
    out["claim_holds"] = all(
        r["interp_err"] < 1e-5 for r in rows) and \
        out["gram_update"]["interp_err"] < 1e-4
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=2))

"""Pallas kernel microbenchmarks (interpret-mode correctness + jnp-path
throughput on CPU; the BlockSpec geometry is the TPU deliverable).

For each kernel: normwise relative error vs the ref.py oracle across a
shape sweep, plus CPU wall time of the jnp reference path.  The top-level
``mode`` field records how the Pallas bodies executed — ``"interpret"``
(CPU: Python interpreter, correctness only) or ``"compiled"`` (TPU: real
Mosaic kernels, and ``pallas_seconds`` columns appear next to the oracle
timings) — so the perf trajectory across PRs is honest about which
numbers are wall clock and which are models.

Three claim gates:
  * fused_gram_mvm: single-launch Alg.-2 megakernel HBM bytes <= 60% of
    the unfused sequence (analytic model, DESIGN.md §4.3);
  * fused_factor_build: the single-sweep factor bundle's modeled HBM
    bytes <= 40% of the pre-fusion multi-pass factor build (DESIGN.md
    §12), and the lowered exact solve / query microbatch consume exactly
    ONE reduction stream of X (jaxpr-counted);
  * precision: bf16-in/f32-accum results track the f32 oracle on the same
    stored values to <= 1e-3 normwise on every gated kernel.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import (fused_factor_build, fused_factor_build_ref,
                           fused_gram_mvm, fused_gram_mvm_multi,
                           fused_gram_mvm_ref, fused_gram_norms,
                           fused_gram_norms_ref, gram_update, gram_update_ref,
                           skinny_gram, skinny_gram_ref, small_matmul)
from repro.utils.hlo import count_data_streams, count_primitive
from repro.utils.roofline import TPUv5e


def _mode() -> str:
    return "compiled" if jax.default_backend() == "tpu" else "interpret"


def _count_pallas_calls(jaxpr) -> int:
    """One launch with one (N, D) output pins the fused path's HBM transfer
    count to the DESIGN.md 4.3 model — a refactor that splits the MVM into
    multiple launches (re-materializing intermediates) flips the gate."""
    return count_primitive(jaxpr, "pallas_call")


def _time(fn, reps=5):
    fn()
    ts = []
    for _ in range(reps):
        t0 = time.time()
        jax.block_until_ready(fn())
        ts.append(time.time() - t0)
    return min(ts)


def _pallas_time(fn, reps=5):
    """Compiled-Pallas wall time — only meaningful on real hardware.

    In interpret mode the kernel body runs in Python, so timing it would
    poison the cross-PR trajectory; the column stays None on CPU."""
    if _mode() != "compiled":
        return None
    return _time(fn, reps)


def _nrel(got, want):
    got = jnp.asarray(got, jnp.float64).reshape(-1)
    want = jnp.asarray(want, jnp.float64).reshape(-1)
    return float(jnp.linalg.norm(got - want) /
                 (jnp.linalg.norm(want) + 1e-30))


# ---------------------------------------------------------------------------
# Analytic HBM transfer model for one Gram MVM (DESIGN.md §4.3).
# Counts (N, D)-sized transfers in units of bytes; (N, N) traffic included
# for honesty but negligible at the benchmarked shapes.
# ---------------------------------------------------------------------------

def mvm_hbm_bytes(n: int, d: int, *, r: int = 1, itemsize: int = 4) -> dict:
    nd = n * d * itemsize
    nn = n * n * itemsize
    # Unfused XLA sequence per RHS (each launch materializes its output):
    #   skinny_gram:      read Xt + V,          write M            2nd + nn
    #   small algebra:    read M + K2e,         write small        3nn
    #   K1e @ V:          read K1e + V,         write t1           2nd + nn
    #   small @ Xt:       read small + Xt,      write t2           2nd + nn
    #   epilogue (*lam, +, +noise*V): read t1 + t2 + V, write W    4nd
    unfused = r * (10 * nd + 6 * nn)
    # Fused megakernel: phase 0 reads Xt+V, phase 1 reads Xt+V and writes W;
    # K1e/K2e read once. Multi-RHS streams Xt once per phase for all R.
    fused = (2 + 3 * r) * nd + 2 * nn
    return {
        "unfused_bytes": int(unfused),
        "fused_bytes": int(fused),
        "ratio": fused / unfused,
        "unfused_roofline_s": unfused / TPUv5e.hbm_bw,
        "fused_roofline_s": fused / TPUv5e.hbm_bw,
    }


# ---------------------------------------------------------------------------
# Analytic HBM model for the single-sweep factor build (DESIGN.md §12).
# ---------------------------------------------------------------------------

def factor_build_hbm_bytes(n: int, d: int, *, itemsize: int = 4) -> dict:
    """Bytes to build ALL exact-solve factors from (X, G), per solve.

    Baseline = the pre-fusion sequence this PR replaced (each launch
    streams its operands; (N, N) outputs negligible and omitted):
      pairwise-r gram+norms (one fused_gram_norms): read X, X     2 nd
      S = (Xt L) Xt^T       (skinny_gram):          read Xt, Xt   2 nd
      W0 = K1i @ G          (kron_precond):         read G, write W0  2 nd
      T0 = W0 @ Xt^T        (skinny_gram):          read W0, Xt   2 nd
    Fused = ONE fused_factor_build launch: read A(=Xt), B(=Xt), V(=G)
    once each — T0 = K1i @ (G Xt^T) needs no stream (associativity), and
    the (N, D) intermediate W0 no longer exists.
    """
    nd = n * d * itemsize
    unfused = 8 * nd
    fused = 3 * nd
    return {
        "unfused_bytes": int(unfused),
        "fused_bytes": int(fused),
        "ratio": fused / unfused,
        "fused_bytes_bf16": int(fused) // 2,   # bf16 storage halves inputs
        "ratio_bf16_vs_f32_baseline": (fused // 2) / unfused,
        "unfused_roofline_s": unfused / TPUv5e.hbm_bw,
        "fused_roofline_s": fused / TPUv5e.hbm_bw,
    }


def query_chunk_hbm_bytes(q: int, n: int, d: int, *,
                          itemsize: int = 4) -> dict:
    """Informational (ungated): value+grad posterior means per microbatch.

    Unfused sequence (cross_value_matvec + cross_grad_matvec, stationary):
    2x pairwise_r (qd+nd each), 2x scaled_gram(Xq, Z) (qd+nd), 2x
    row_dots(Xt, Z) (2nd), gram_update (2nd, write qd), epilogue (read
    W+Xq, write grad: 3qd).  Fused: one factor sweep (qd+2nd), one
    gram_update (2nd + write qd), epilogue (3qd).
    """
    qd, nd = q * d * itemsize, n * d * itemsize
    unfused = 5 * qd + 10 * nd + 3 * qd
    fused = 2 * qd + 4 * nd + 3 * qd
    return {"unfused_bytes": int(unfused), "fused_bytes": int(fused),
            "ratio": fused / unfused}


# ---------------------------------------------------------------------------
# Structural single-sweep gate: jaxpr stream counts of the solve/query path
# ---------------------------------------------------------------------------

def x_stream_counts() -> dict:
    from repro.core import build_factors, get_kernel, use_backend
    from repro.core import woodbury_solve
    from repro.core.query import _query_chunk

    n, q, d = 5, 4, 384
    rng = jax.random.PRNGKey(3)
    out = {}
    for name in ("rbf", "expdot"):
        spec = get_kernel(name)
        c = None if spec.is_stationary else jnp.full((d,), 0.01, jnp.float32)
        X = jax.random.normal(jax.random.fold_in(rng, 1), (n, d), jnp.float32)
        G = jax.random.normal(jax.random.fold_in(rng, 2), (n, d), jnp.float32)
        Xq = jax.random.normal(jax.random.fold_in(rng, 3), (q, d),
                               jnp.float32)
        with use_backend("pallas"):
            f = build_factors(spec, X, lam=0.5, c=c, noise=1e-3)
            solve_j = jax.make_jaxpr(
                lambda Xt, g: woodbury_solve(spec, f._replace(Xt=Xt),
                                             g))(f.Xt, G)
            query_j = jax.make_jaxpr(
                lambda Xt, z, xq: _query_chunk(spec, xq, f._replace(Xt=Xt),
                                               z, None))(f.Xt, G, Xq)
        out[name] = {
            "woodbury_solve": count_data_streams(solve_j, 0, d),
            "query_chunk": count_data_streams(query_j, 0, d),
        }
    return out


def run() -> dict:
    rng = jax.random.PRNGKey(0)
    out = {"mode": _mode()}
    shapes = [(8, 8, 4096), (16, 16, 65536), (8, 8, 262144)]
    rows = []
    for na, nb, d in shapes:
        A = jax.random.normal(jax.random.fold_in(rng, 1), (na, d))
        B = jax.random.normal(jax.random.fold_in(rng, 2), (nb, d))
        got = skinny_gram(A, B, 0.5, interpret=True)
        want = skinny_gram_ref(A, B, 0.5)
        # relative error (f32 accumulation noise grows ~sqrt(D))
        err = float(jnp.max(jnp.abs(got - want)) /
                    jnp.max(jnp.abs(want)))
        ref = jax.jit(lambda a, b: skinny_gram_ref(a, b, 0.5))
        t = _time(lambda: ref(A, B))
        gbps = (A.size + B.size) * 4 / t / 1e9
        rows.append({"shape": [na, nb, d], "interp_err": err,
                     "jnp_seconds": t, "jnp_gb_per_s": gbps,
                     "pallas_seconds": _pallas_time(
                         lambda: skinny_gram(A, B, 0.5))})
    out["skinny_gram"] = rows

    n, d = 8, 65536
    K1 = jax.random.normal(jax.random.fold_in(rng, 3), (n, n))
    M = jax.random.normal(jax.random.fold_in(rng, 4), (n, n))
    V = jax.random.normal(jax.random.fold_in(rng, 5), (n, d))
    X = jax.random.normal(jax.random.fold_in(rng, 6), (n, d))
    err = float(jnp.max(jnp.abs(
        gram_update(K1, M, V, X, 0.5, interpret=True) -
        gram_update_ref(K1, M, V, X, 0.5))))
    ref2 = jax.jit(lambda: gram_update_ref(K1, M, V, X, 0.5))
    out["gram_update"] = {"shape": [n, d], "interp_err": err,
                          "jnp_seconds": _time(lambda: ref2()),
                          "pallas_seconds": _pallas_time(
                              lambda: gram_update(K1, M, V, X, 0.5))}

    # fused_gram_norms: the norm outputs have magnitude ~lam*D (all-positive
    # sums), so an ABSOLUTE error metric reads ~1e-3 at D=65536 while the
    # per-output RELATIVE error sits at f32-accumulation level like every
    # sibling kernel (the PR-5 "5.9e-3 interp_err" was exactly this metric
    # artifact, not an accumulation-order bug).  Gate the relative metric.
    A = jax.random.normal(jax.random.fold_in(rng, 7), (8, 65536))
    P, na_, nb_ = fused_gram_norms(A, A, 0.3, interpret=True)
    Pr, nar, nbr = fused_gram_norms_ref(A, A, 0.3)
    out["fused_gram_norms"] = {
        "interp_rel_err": float(max(_nrel(P, Pr), _nrel(na_, nar[:, 0]),
                                    _nrel(nb_, nbr[:, 0]))),
        "interp_abs_err_norms": float(jnp.max(jnp.abs(na_ - nar[:, 0]))),
        "norm_magnitude": float(jnp.max(jnp.abs(nar))),
        "note": "norms are O(lam*D) positive sums; abs err ~1e-3 here IS "
                "rel err ~3e-7 — the claim gate uses the relative metric",
        "pallas_seconds": _pallas_time(
            lambda: fused_gram_norms(A, A, 0.3)),
    }

    # --- fused Alg.-2 megakernel: parity + HBM-bytes-per-iteration model ---
    n, d = 16, 65536
    K1e = jax.random.normal(jax.random.fold_in(rng, 8), (n, n))
    K2e = jax.random.normal(jax.random.fold_in(rng, 9), (n, n)) * 0.1
    Xt = jax.random.normal(jax.random.fold_in(rng, 10), (n, d))
    Vv = jax.random.normal(jax.random.fold_in(rng, 11), (n, d))
    fused_rows = []
    for stationary in (False, True):
        got = fused_gram_mvm(K1e, K2e, Xt, Vv, 0.5, stationary=stationary,
                             noise=1e-2, interpret=True)
        want = fused_gram_mvm_ref(K1e, K2e, Xt, Vv, 0.5,
                                  stationary=stationary, noise=1e-2)
        err = float(jnp.max(jnp.abs(got - want)) / jnp.max(jnp.abs(want)))
        # CPU wall clock of the *unfused* jnp sequence this kernel replaces
        # (the fused kernel itself only runs for real on TPU).
        def unfused():
            m = (Xt * 0.5) @ Vv.T
            if stationary:
                mt = K2e * (m - jnp.diagonal(m)[None, :])
                small = jnp.diag(jnp.sum(mt, axis=1)) - mt
            else:
                small = K2e * m
            return (K1e @ Vv + small @ Xt) * 0.5 + 1e-2 * Vv
        t = _time(jax.jit(unfused))
        fused_rows.append({
            "stationary": stationary, "shape": [n, d], "interp_err": err,
            "jnp_unfused_seconds": t,
            "pallas_seconds": _pallas_time(
                lambda s=stationary: fused_gram_mvm(
                    K1e, K2e, Xt, Vv, 0.5, stationary=s, noise=1e-2)),
            "hbm_model": mvm_hbm_bytes(n, d),
        })
    # multi-RHS amortization sweep
    multi = []
    for r in (1, 2, 4, 8):
        model = mvm_hbm_bytes(n, d, r=r)
        model["r"] = r
        model["per_rhs_fused_bytes"] = model["fused_bytes"] / r
        multi.append(model)
    Vs = jax.random.normal(jax.random.fold_in(rng, 12), (2, n, 4096))
    Xs = Xt[:, :4096]
    got_m = fused_gram_mvm_multi(K1e, K2e, Xs, Vs, 0.5, stationary=True,
                                 interpret=True)
    want_m = fused_gram_mvm_ref(K1e, K2e, Xs, Vs, 0.5, stationary=True)
    # structural check backing the analytic byte model (see _count_pallas_calls)
    launches = _count_pallas_calls(jax.make_jaxpr(
        lambda v: fused_gram_mvm(K1e, K2e, Xt, v, 0.5, stationary=True,
                                 interpret=True))(Vv).jaxpr)
    launches_multi = _count_pallas_calls(jax.make_jaxpr(
        lambda v: fused_gram_mvm_multi(K1e, K2e, Xs, v, 0.5, stationary=True,
                                       interpret=True))(Vs).jaxpr)
    out["fused_gram_mvm"] = {
        "rows": fused_rows,
        "multi_rhs_model": multi,
        "multi_rhs_interp_err": float(jnp.max(jnp.abs(got_m - want_m)) /
                                      jnp.max(jnp.abs(want_m))),
        "pallas_calls_per_mvm": launches,
        "pallas_calls_per_multi_mvm": launches_multi,
        "paper_claim": "single-launch fused MVM cuts HBM bytes/iter vs the "
                       "unfused sequence (DESIGN.md 4.3)",
    }

    # --- single-sweep fused factor build (DESIGN.md §12) -------------------
    n, d = 16, 65536
    G = jax.random.normal(jax.random.fold_in(rng, 13), (n, d))
    ffb_rows = []
    for na, nb, dd in [(8, 8, 4096), (16, 16, 65536)]:
        Af = Xt[:na, :dd]
        Bf = Xt[:nb, :dd]
        Vf = G[:nb, :dd]
        got = fused_factor_build(Af, Bf, Vf, 0.5, interpret=True)
        want = fused_factor_build_ref(Af, Bf, Vf, 0.5)
        err = max(_nrel(g, w) for g, w in zip(got, want))
        ffb_rows.append({
            "shape": [na, nb, dd], "interp_err": err,
            "jnp_seconds": _time(jax.jit(
                lambda a=Af, b=Bf, v=Vf: fused_factor_build_ref(a, b, v,
                                                                0.5))),
            "pallas_seconds": _pallas_time(
                lambda a=Af, b=Bf, v=Vf: fused_factor_build(a, b, v, 0.5)),
            "hbm_model": factor_build_hbm_bytes(na, dd),
        })
    ffb_launches = _count_pallas_calls(jax.make_jaxpr(
        lambda a, v: fused_factor_build(Xt, a, v, 0.5, interpret=True))(
            Xt, G).jaxpr)
    out["fused_factor_build"] = {
        "rows": ffb_rows,
        "pallas_calls_per_bundle": ffb_launches,
        "query_chunk_model": query_chunk_hbm_bytes(16, 16, 65536),
        "x_streams": x_stream_counts(),
        "paper_claim": "ONE sweep of (X, G) builds every exact-solve factor "
                       "(gram, norms, S, G Xt^T); the lowered solve/query "
                       "reads X in exactly one reduction stream "
                       "(DESIGN.md 12)",
    }

    # --- precision policy: bf16-in / f32-accum vs the f32 oracle -----------
    n, d = 8, 65536
    X16 = Xt[:n, :d].astype(jnp.bfloat16)
    V16 = Vv[:n, :d].astype(jnp.bfloat16)
    X32, V32 = X16.astype(jnp.float32), V16.astype(jnp.float32)
    Kb = K1e[:n, :n]
    K2b = K2e[:n, :n]
    bf16 = {}
    bf16["skinny_gram"] = _nrel(skinny_gram(X16, V16, 0.5, interpret=True),
                                skinny_gram_ref(X32, V32, 0.5))
    bf16["gram_update"] = _nrel(
        gram_update(Kb, K2b, V16, X16, 0.5, noise=0.1, interpret=True),
        gram_update_ref(Kb, K2b, V32, X32, 0.5, noise=0.1))
    bf16["small_matmul"] = _nrel(small_matmul(Kb, V16, 0.5, interpret=True),
                                 (Kb @ V32) * 0.5)
    P16 = fused_gram_norms(X16, V16, 0.5, interpret=True)
    P32 = fused_gram_norms_ref(X32, V32, 0.5)
    bf16["fused_gram_norms"] = max(
        _nrel(g, w) for g, w in zip(P16, (P32[0], P32[1][:, 0],
                                          P32[2][:, 0])))
    bf16["fused_gram_mvm"] = _nrel(
        fused_gram_mvm(Kb, K2b, X16, V16, 0.5, stationary=True, noise=0.1,
                       interpret=True),
        fused_gram_mvm_ref(Kb, K2b, X32, V32, 0.5, stationary=True,
                           noise=0.1))
    F16 = fused_factor_build(X16, X16, V16, 0.5, interpret=True)
    F32 = fused_factor_build_ref(X32, X32, V32, 0.5)
    bf16["fused_factor_build"] = max(
        _nrel(g, w) for g, w in zip(F16, F32))
    out["bf16_vs_f32_oracle_rel"] = {
        **{k: float(v) for k, v in bf16.items()},
        "note": "kernel(bf16 storage) vs f32 oracle on the same stored "
                "values, normwise — isolates what the pipeline adds "
                "(accumulation order) from storage quantization; gate "
                "<= 1e-3 (DESIGN.md 12 precision table)",
    }

    streams_ok = all(
        v["woodbury_solve"]["reduction"] == 1
        and v["query_chunk"]["reduction"] == 1
        for v in out["fused_factor_build"]["x_streams"].values())
    byte_ratio_ok = all(r["hbm_model"]["ratio"] <= 0.6 for r in fused_rows)
    ffb_ratio_ok = all(r["hbm_model"]["ratio"] <= 0.4 for r in ffb_rows)
    bf16_ok = all(v <= 1e-3 for v in bf16.values())
    out["claim_holds"] = bool(
        all(r["interp_err"] < 1e-5 for r in rows)
        and out["gram_update"]["interp_err"] < 1e-4
        and out["fused_gram_norms"]["interp_rel_err"] < 1e-5
        and all(r["interp_err"] < 1e-4 for r in fused_rows)
        and out["fused_gram_mvm"]["multi_rhs_interp_err"] < 1e-4
        and launches == 1 and launches_multi == 1
        and byte_ratio_ok
        and all(r["interp_err"] < 1e-5 for r in ffb_rows)
        and ffb_launches == 1
        and ffb_ratio_ok
        and streams_ok
        and bf16_ok)
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=2))

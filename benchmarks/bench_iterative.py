"""Preconditioning benchmark (paper Sec. 2.3: preconditioning "drastically
reduces the required number of iterations" for the matrix-free CG path).

The Kronecker term B = K' x Lambda gives a FREE preconditioner — B^{-1} is
an N x N inverse; this bench measures CG iterations with and without it
across lengthscales (conditioning regimes).
"""
import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np

from repro.core import build_factors, get_kernel, gram_cg_solve


def run() -> dict:
    spec = get_kernel("rbf")
    rng = np.random.RandomState(0)
    n, d = 24, 64
    X = jnp.asarray(rng.randn(n, d)) * 2.0
    G = jnp.asarray(rng.randn(n, d))
    rows = []
    for lam in [0.005, 0.02, 0.1]:
        f = build_factors(spec, X, lam=lam, noise=1e-9)
        it_p = int(gram_cg_solve(spec, f, G, tol=1e-8,
                                 precondition=True).iters)
        it_n = int(gram_cg_solve(spec, f, G, tol=1e-8,
                                 precondition=False).iters)
        rows.append({"lam": lam, "iters_precond": it_p,
                     "iters_plain": it_n,
                     "speedup": it_n / max(it_p, 1)})
    return {
        "rows": rows,
        "paper_claim": "Kronecker-term preconditioning reduces CG iters",
        # preconditioning wins in the ill-conditioned (small-lam) regime it
        # is meant for, and must never hurt badly elsewhere
        "claim_holds": bool(
            any(r["speedup"] > 1.3 for r in rows)
            and all(r["iters_precond"] <= r["iters_plain"] + 2
                    for r in rows)),
    }


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=2))

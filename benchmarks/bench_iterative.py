"""Preconditioning benchmark (paper Sec. 2.3: preconditioning "drastically
reduces the required number of iterations" for the matrix-free CG path).

The Kronecker term B = K' x Lambda gives a FREE preconditioner — B^{-1} is
an N x N inverse; this bench measures CG iterations with and without it
across lengthscales (conditioning regimes).
"""
import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_kernels import mvm_hbm_bytes
from repro.core import (build_factors, get_kernel, gram_cg_solve,
                        gram_cg_solve_multi, gram_matvec_multi)


def run() -> dict:
    spec = get_kernel("rbf")
    rng = np.random.RandomState(0)
    n, d = 24, 64
    X = jnp.asarray(rng.randn(n, d)) * 2.0
    G = jnp.asarray(rng.randn(n, d))
    rows = []
    for lam in [0.005, 0.02, 0.1]:
        f = build_factors(spec, X, lam=lam, noise=1e-9)
        it_p = int(gram_cg_solve(spec, f, G, tol=1e-8,
                                 precondition=True).iters)
        it_n = int(gram_cg_solve(spec, f, G, tol=1e-8,
                                 precondition=False).iters)
        rows.append({"lam": lam, "iters_precond": it_p,
                     "iters_plain": it_n,
                     "speedup": it_n / max(it_p, 1)})

    # stacked-RHS CG: one multi-RHS fused MVM per iteration for all RHS
    f = build_factors(spec, X, lam=0.02, noise=1e-9)
    Gs = jnp.stack([G, jnp.asarray(rng.randn(n, d))])
    rm = gram_cg_solve_multi(spec, f, Gs, tol=1e-8)
    res_m = float(jnp.linalg.norm(
        gram_matvec_multi(f, rm.x, stationary=spec.is_stationary) - Gs) /
        jnp.linalg.norm(Gs))
    multi_rhs = {"r": 2, "iters": int(rm.iters), "relres": res_m}

    # HBM bytes per CG iteration at a production shape (DESIGN.md 4.3):
    # the per-iteration cost is exactly one Gram MVM + the O(ND) CG axpys.
    hbm = {}
    for r in (1, 4):
        m = mvm_hbm_bytes(32, 1_000_000, r=r)
        m["r"] = r
        hbm[f"r{r}"] = m
    fused_wins = all(v["fused_bytes"] < 0.6 * v["unfused_bytes"]
                     for v in hbm.values())

    return {
        "rows": rows,
        "multi_rhs_cg": multi_rhs,
        "hbm_bytes_per_iteration": hbm,
        "paper_claim": "Kronecker-term preconditioning reduces CG iters; "
                       "fused MVM cuts HBM bytes per iteration",
        # preconditioning wins in the ill-conditioned (small-lam) regime it
        # is meant for, and must never hurt badly elsewhere
        "claim_holds": bool(
            any(r["speedup"] > 1.3 for r in rows)
            and all(r["iters_precond"] <= r["iters_plain"] + 2
                    for r in rows)
            and res_m < 1e-6
            and fused_wins),
    }


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=2))

"""Memory-footprint table (paper Sec. 2.3 "General Improvements" +
Sec. 5.2 numbers): dense Gram storage vs factor storage across (N, D),
including the paper's flagship N=1000, D=100 cell (74 GB vs 25 MB).
"""
import numpy as np


def factor_bytes(n: int, d: int, dtype_bytes: int = 8) -> int:
    # K', K'' (N^2 each), X (ND), plus CG workspace 2*ND (paper: 3ND+3N^2)
    return (3 * n * d + 3 * n * n) * dtype_bytes


def dense_bytes(n: int, d: int, dtype_bytes: int = 8) -> int:
    return (n * d) ** 2 * dtype_bytes


def run() -> dict:
    cells = [(10, 100), (100, 100), (1000, 100), (8, 1_000_000),
             (64, 1_000_000_000)]
    rows = []
    for n, d in cells:
        db = dense_bytes(n, d)
        fb = factor_bytes(n, d)
        rows.append({
            "n": n, "d": d,
            "dense_gb": db / 1e9,
            "factors_mb": fb / 1e6,
            "ratio": db / fb,
        })
    flagship = rows[2]
    return {
        "rows": rows,
        "paper_flagship": flagship,
        "paper_claim": ">74 GB dense vs 25 MB factors at N=1000, D=100",
        # paper rounds 3ND+3N^2 doubles (26.4 MB) down to "25 MB"
        "claim_holds": bool(flagship["dense_gb"] > 74.0
                            and flagship["factors_mb"] < 30.0),
    }


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=2))

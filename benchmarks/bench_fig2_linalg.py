"""Fig. 2 reproduction: 100-D quadratic, CG vs GP-X (solution-based) vs
GP-H (Hessian-based, fixed c=0).

Paper claims: "The new solution-based inference shows performance similar
to CG. The presented Hessian-based algorithm uses a fixed c=0 which
compromises the performance."
"""
import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_gp import LINALG
from repro.linalg import (cg_solve, hessian_probabilistic_solver,
                          make_test_matrix, solution_probabilistic_solver)


def run() -> dict:
    cfg = LINALG
    A = make_test_matrix(cfg.d, lam_min=cfg.lam_min, lam_max=cfg.lam_max,
                         rho=cfg.rho, seed=cfg.seed)
    rng = np.random.RandomState(cfg.seed)
    x0 = jnp.asarray(rng.randn(cfg.d) * 5.0)                 # N(0, 5^2)
    xstar = jnp.asarray(rng.randn(cfg.d) - 2.0)              # N(-2, 1)
    b = A @ xstar

    out = {}
    for name, fn in [("cg", cg_solve),
                     ("gp_solution", solution_probabilistic_solver),
                     ("gp_hessian", hessian_probabilistic_solver)]:
        tr = fn(A, b, x0, tol=cfg.tol, max_iters=cfg.max_iters)
        out[name] = {
            "iters": int(tr.iters),
            "relres": float(tr.relres[-1]),
            "relres_curve_head": [float(v) for v in tr.relres[:12]],
            "x_err": float(jnp.max(jnp.abs(tr.x - xstar))),
        }
    out["paper_claim"] = ("GP-X ~ CG iterations; GP-H (c=0) much slower")
    out["claim_holds"] = bool(
        out["gp_solution"]["iters"] <= out["cg"]["iters"] * 2 + 3
        and out["gp_hessian"]["relres"] > out["cg"]["relres"])
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=2))

"""Benchmark orchestrator: one module per paper table/figure + systems
benches. ``PYTHONPATH=src python -m benchmarks.run [--only a,b]``.

Each bench returns a dict with a ``claim_holds`` verdict tying the
measurement back to the paper's statement; the summary table at the end is
the reproduction scorecard.

``--check`` turns the committed ``BENCH_*.json`` baselines into a
regression gate: fresh results are diffed against them and any claim
metric that regresses by more than ``CHECK_TOLERANCE`` (20%) — byte
ratios/totals growing, error metrics growing past a floating-point jitter
floor, a ``claim_holds`` flipping to false — fails the run.  Wall-clock
and GB/s columns are excluded (machine-dependent noise); the gated
metrics are the deterministic models and accuracy numbers that define the
perf story.
"""
import argparse
import json
import time
import traceback

CHECK_TOLERANCE = 0.20      # fail on > 20% regression of a claim metric
_ERR_FLOOR = 1e-5           # abs floor under which error metrics are noise


def _is_claim_metric(key: str) -> bool:
    # "unfused_*" is the baseline side of a model, not a deliverable
    return (key == "claim_holds" or key == "ratio" or key.endswith("_err")
            or (key.endswith("_bytes") and not key.startswith("unfused"))
            or key.endswith("_rel") or key.startswith("ratio_"))


def _walk_regressions(base, fresh, path, failures):
    """Recursively diff claim metrics; append (path, old, new) regressions.

    Higher is worse for every gated numeric metric (byte counts/ratios and
    error magnitudes); ``claim_holds`` must not flip true -> false.
    Structure drift (new/removed keys) is NOT a failure — baselines are
    refreshed by committing the new JSON.
    """
    if isinstance(base, dict) and isinstance(fresh, dict):
        for k in base:
            if k == "telemetry":
                # observability sections are machine/run-dependent (and
                # full of *_bytes gauge names) — never regression-gated
                continue
            if k in fresh:
                _walk_regressions(base[k], fresh[k], path + (str(k),),
                                  failures)
        return
    if isinstance(base, list) and isinstance(fresh, list):
        for i, (b, f) in enumerate(zip(base, fresh)):
            _walk_regressions(b, f, path + (str(i),), failures)
        return
    key = path[-1] if path else ""
    # a metric is gated by its own key, or by sitting inside a gated
    # container (e.g. the per-kernel entries of bf16_vs_f32_oracle_rel)
    if not (_is_claim_metric(key)
            or any(_is_claim_metric(p) for p in path[:-1])):
        return
    if not _is_claim_metric(key):
        key = next(p for p in path if _is_claim_metric(p))
    # null/absent metrics are "not measured here", never a regression:
    # interpret-mode baselines carry e.g. ``pallas_seconds: null`` and a
    # compiled column must not trip against them (nor vice versa)
    if base is None or fresh is None:
        return
    if isinstance(base, bool) or isinstance(fresh, bool):
        if base is True and fresh is not True:
            failures.append((".".join(path), base, fresh))
        return
    if isinstance(base, (int, float)) and isinstance(fresh, (int, float)):
        limit = base * (1.0 + CHECK_TOLERANCE)
        if key.endswith("_err") or key.endswith("_rel"):
            limit = max(limit, _ERR_FLOOR)
        if fresh > limit:
            failures.append((".".join(path), base, fresh))


def check_against_baselines(results: dict, root: str) -> list:
    """Diff fresh results vs the committed BENCH_*.json; list regressions."""
    import os

    failures = []
    for key in PERF_TRACKED:
        if key not in results:
            continue
        base_path = os.path.join(root, f"BENCH_{key}.json")
        if not os.path.exists(base_path):
            continue    # first run for this bench: nothing to regress from
        with open(base_path) as f:
            base = json.load(f)
        _walk_regressions(base, results[key], (key,), failures)
    return failures

BENCHES = [
    ("fig2_linalg", "benchmarks.bench_fig2_linalg",
     "Fig. 2: CG vs GP-X vs GP-H on 100-D quadratic"),
    ("fig3_rosenbrock", "benchmarks.bench_fig3_rosenbrock",
     "Fig. 3: Alg. 1 vs BFGS on relaxed 100-D Rosenbrock"),
    ("fig4_surface", "benchmarks.bench_fig4_surface",
     "Fig. 4/Sec 5.2: N>D matrix-free CG + surface recovery"),
    ("fig5_hmc", "benchmarks.bench_fig5_hmc",
     "Fig. 5/Sec 5.3: GPG-HMC vs HMC acceptance + budget"),
    ("scaling", "benchmarks.bench_scaling",
     "Sec. 2.3: O(D)-linear exact inference"),
    ("memory", "benchmarks.bench_memory",
     "Sec. 2.3/5.2: storage 74GB -> 25MB"),
    ("iterative", "benchmarks.bench_iterative",
     "Sec. 2.3: free Kronecker preconditioner"),
    ("kernels", "benchmarks.bench_kernels",
     "Pallas kernels vs oracles + throughput"),
    ("gp_collectives", "benchmarks.bench_gp_optimizer_collectives",
     "DESIGN 2: GP optimizer collective footprint"),
    ("hyper", "benchmarks.bench_hyper",
     "DESIGN 11: structured exact MLL + hyperparameter fit"),
    ("distributed", "benchmarks.bench_distributed",
     "DESIGN 14: D-sharded state machine O(N^2)-byte collectives"),
    ("fleet", "benchmarks.bench_fleet",
     "DESIGN 15: multi-tenant vmapped fleet + continuous batching"),
    ("regime", "benchmarks.bench_regime",
     "DESIGN 16: regime crossover, Krylov posterior + SLQ past N<D"),
    ("resilience", "benchmarks.bench_resilience",
     "DESIGN 17: bitwise snapshot/journal recovery + zero-cost guardrails"),
]

# Benches whose JSON lands at the repo root for cross-PR tracking; also
# the set --check regresses against.
PERF_TRACKED = ("kernels", "iterative", "hyper", "distributed", "fleet",
                "regime", "resilience")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--out", default="results/bench.json")
    ap.add_argument("--strict", action="store_true",
                    help="exit nonzero if any executed claim gate fails "
                         "(used by CI to enforce the perf/repro gates)")
    ap.add_argument("--check", action="store_true",
                    help="regression gate: diff fresh results against the "
                         "committed BENCH_*.json baselines and exit nonzero "
                         "on a >20%% regression of any claim metric")
    args = ap.parse_args()

    # with REPRO_OBS=on each bench row grows a ``telemetry`` section (the
    # registry delta across the bench: CG iterations, fallbacks, spans);
    # --check skips the subtree, so telemetry never gates perf
    try:
        from repro.obs import trace as obs
        obs_on = obs.enabled()
    except ImportError:     # benches runnable without src on the path
        obs, obs_on = None, False

    results = {}
    for key, module, desc in BENCHES:
        if args.only and key not in args.only.split(","):
            continue
        t0 = time.time()
        print(f"=== {key}: {desc}", flush=True)
        snap = obs.snapshot() if obs_on else None
        try:
            mod = __import__(module, fromlist=["run"])
            r = mod.run()
            r["_seconds"] = round(time.time() - t0, 1)
            if obs_on:
                r["telemetry"] = obs.REGISTRY.delta(snap)
            results[key] = r
            print(json.dumps(r, indent=1, default=str), flush=True)
        except Exception as e:  # noqa: BLE001
            results[key] = {"error": str(e), "claim_holds": False,
                            "_trace": traceback.format_exc()[-1500:]}
            print(f"ERROR {e}", flush=True)
    if obs_on:
        obs.flush()     # final registry snapshot into the JSONL sink

    print("\n===== reproduction scorecard =====")
    for key, module, desc in BENCHES:
        if key in results:
            v = results[key].get("claim_holds")
            print(f"  {key:18s} {'PASS' if v else 'FAIL':4s}  {desc}")
    import os

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1, default=str)
    # Regression gate BEFORE the baselines are overwritten below.
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    regressions = check_against_baselines(results, root) if args.check else []
    if regressions:
        print(f"\n===== --check: {len(regressions)} claim-metric "
              f"regression(s) vs committed baselines =====")
        for path, old, new in regressions:
            print(f"  REGRESSED {path}: {old} -> {new}")
    elif args.check:
        print("\n--check: no claim-metric regressions vs committed baselines")
    # Per-PR perf trajectory: the roofline-scored benches land at the repo
    # root so successive PRs can diff them (CI uploads them as artifacts).
    # NEVER overwrite the baselines with results that just failed the
    # regression gate — a rerun would then compare regressed-vs-regressed
    # and pass, masking the regression.
    if regressions:
        print("(baselines left untouched — fix the regression or commit "
              "new baselines deliberately with a run without --check)")
    else:
        for key in PERF_TRACKED:
            if key in results:
                with open(os.path.join(root, f"BENCH_{key}.json"), "w") as f:
                    json.dump(results[key], f, indent=1, default=str)
    n_fail = sum(1 for r in results.values() if not r.get("claim_holds"))
    print(f"\n{len(results) - n_fail}/{len(results)} claims hold")
    if (args.strict and n_fail) or regressions:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

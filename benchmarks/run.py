"""Benchmark orchestrator: one module per paper table/figure + systems
benches. ``PYTHONPATH=src python -m benchmarks.run [--only a,b]``.

Each bench returns a dict with a ``claim_holds`` verdict tying the
measurement back to the paper's statement; the summary table at the end is
the reproduction scorecard.
"""
import argparse
import json
import time
import traceback

BENCHES = [
    ("fig2_linalg", "benchmarks.bench_fig2_linalg",
     "Fig. 2: CG vs GP-X vs GP-H on 100-D quadratic"),
    ("fig3_rosenbrock", "benchmarks.bench_fig3_rosenbrock",
     "Fig. 3: Alg. 1 vs BFGS on relaxed 100-D Rosenbrock"),
    ("fig4_surface", "benchmarks.bench_fig4_surface",
     "Fig. 4/Sec 5.2: N>D matrix-free CG + surface recovery"),
    ("fig5_hmc", "benchmarks.bench_fig5_hmc",
     "Fig. 5/Sec 5.3: GPG-HMC vs HMC acceptance + budget"),
    ("scaling", "benchmarks.bench_scaling",
     "Sec. 2.3: O(D)-linear exact inference"),
    ("memory", "benchmarks.bench_memory",
     "Sec. 2.3/5.2: storage 74GB -> 25MB"),
    ("iterative", "benchmarks.bench_iterative",
     "Sec. 2.3: free Kronecker preconditioner"),
    ("kernels", "benchmarks.bench_kernels",
     "Pallas kernels vs oracles + throughput"),
    ("gp_collectives", "benchmarks.bench_gp_optimizer_collectives",
     "DESIGN 2: GP optimizer collective footprint"),
    ("hyper", "benchmarks.bench_hyper",
     "DESIGN 11: structured exact MLL + hyperparameter fit"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--out", default="results/bench.json")
    ap.add_argument("--strict", action="store_true",
                    help="exit nonzero if any executed claim gate fails "
                         "(used by CI to enforce the perf/repro gates)")
    args = ap.parse_args()

    results = {}
    for key, module, desc in BENCHES:
        if args.only and key not in args.only.split(","):
            continue
        t0 = time.time()
        print(f"=== {key}: {desc}", flush=True)
        try:
            mod = __import__(module, fromlist=["run"])
            r = mod.run()
            r["_seconds"] = round(time.time() - t0, 1)
            results[key] = r
            print(json.dumps(r, indent=1, default=str), flush=True)
        except Exception as e:  # noqa: BLE001
            results[key] = {"error": str(e), "claim_holds": False,
                            "_trace": traceback.format_exc()[-1500:]}
            print(f"ERROR {e}", flush=True)

    print("\n===== reproduction scorecard =====")
    for key, module, desc in BENCHES:
        if key in results:
            v = results[key].get("claim_holds")
            print(f"  {key:18s} {'PASS' if v else 'FAIL':4s}  {desc}")
    import os

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1, default=str)
    # Per-PR perf trajectory: the roofline-scored benches land at the repo
    # root so successive PRs can diff them (CI uploads them as artifacts).
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for key in ("kernels", "iterative", "hyper"):
        if key in results:
            with open(os.path.join(root, f"BENCH_{key}.json"), "w") as f:
                json.dump(results[key], f, indent=1, default=str)
    n_fail = sum(1 for r in results.values() if not r.get("claim_holds"))
    print(f"\n{len(results) - n_fail}/{len(results)} claims hold")
    if args.strict and n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""Fig. 4 / Sec. 5.2 reproduction: infer the function surface of the 100-D
relaxed Rosenbrock from N=1000 gradient observations with the matrix-free
MVM + preconditioned CG (N > D regime — the Gram matrix would need > 74 GB;
the factor set needs ~25 MB).

Reported: iterations to tolerance, peak factor storage, error of the
inferred function values along the (x1, x2) plane vs ground truth, and the
memory ratio vs the dense Gram matrix.
"""
import time

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np

from repro.core import (build_factors, cross_value_matvec, get_kernel,
                        gram_cg_solve, posterior_grad)


def run(n: int = 400, d: int = 100, tol: float = 1e-6) -> dict:
    """Default N reduced to 400 for CI speed (paper: 1000; same regime
    N*D >> 0, identical code path — pass n=1000 to reproduce exactly)."""
    spec = get_kernel("rbf")
    lam = 1.0 / (10.0 * d)                       # paper: ell^2 = 10*D

    def f(x):
        return jnp.sum(x[:-1] ** 2 + 2.0 * (x[1:] - x[:-1] ** 2) ** 2)

    grad = jax.vmap(jax.grad(f))
    rng = np.random.RandomState(0)
    X = jnp.asarray(rng.uniform(-2, 2, size=(n, d)))
    G = grad(X)

    f_fac = build_factors(spec, X, lam=lam, noise=1e-8)
    t0 = time.time()
    res = gram_cg_solve(spec, f_fac, G, tol=tol, maxiter=2000)
    dt = time.time() - t0

    # memory accounting (paper Sec. 5.2 table-in-text)
    dense_bytes = (n * d) ** 2 * 8
    factor_bytes = (3 * n * d + 3 * n * n) * 8   # paper's own accounting

    # surface check along the (x1, x2) plane
    g1, g2 = jnp.meshgrid(jnp.linspace(-2, 2, 9), jnp.linspace(-2, 2, 9))
    Xq = jnp.zeros((81, d)).at[:, 0].set(g1.ravel()).at[:, 1].set(g2.ravel())
    vals = cross_value_matvec(spec, Xq, f_fac, res.x)
    truth = jax.vmap(f)(Xq)
    # posterior value is defined up to a constant: compare centered
    vc = vals - vals.mean()
    tc = truth - truth.mean()
    corr = float(jnp.sum(vc * tc) /
                 jnp.sqrt(jnp.sum(vc ** 2) * jnp.sum(tc ** 2)))
    pg = posterior_grad(spec, X[:8], f_fac, res.x)
    interp_err = float(jnp.max(jnp.abs(pg - G[:8])) / jnp.max(jnp.abs(G[:8])))

    return {
        "n": n, "d": d,
        "cg_iters": int(res.iters),
        "cg_relres": float(res.resnorm / jnp.linalg.norm(G)),
        "seconds": round(dt, 2),
        "dense_gram_gb": dense_bytes / 1e9,
        "factor_mb": factor_bytes / 1e6,
        "memory_ratio": dense_bytes / factor_bytes,
        "surface_correlation": corr,
        "train_grad_interp_relerr": interp_err,
        "paper_claim": "74 GB dense vs 25 MB factors at N=1000; surface "
                       "recovers minimum + elongation",
        "claim_holds": bool(corr > 0.9 and interp_err < 1e-3),
    }


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=2))

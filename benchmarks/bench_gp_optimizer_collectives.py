"""The systems thesis bench: the GP-H optimizer's collective footprint on
the production mesh vs the gradient all-reduce it rides on.

Lowered on 8 host devices (subprocess-free: this bench re-execs itself
with the device-count flag if needed), the train step is compiled twice —
momentum vs gp — and the per-step collective bytes are compared. The
paper's structure guarantees the GP addition is O(history^2) bytes,
independent of D; the gradient all-reduce is O(D).
"""
import json
import os
import subprocess
import sys

_SRC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.launch.mesh import make_test_mesh
from repro.optim import get_optimizer
from repro.train import build_train_step
from repro.utils.hlo_cost import analyze_hlo

mesh = make_test_mesh((2, 4), ("data", "model"))
cfg = get_config("gemma3-1b", smoke=True)
out = {}
for name in ["momentum", "gp", "gp_tree"]:
    if name == "momentum":
        opt = get_optimizer(name, lr=1e-3)
    elif name == "gp":
        opt = get_optimizer("gp", lr=1.0, history=6, pad_to=8)
    else:
        opt = get_optimizer("gp_tree", lr=1.0, history=6)
    b = build_train_step(cfg, opt, mesh, shape="smoke_train", donate=False)
    hlo = b.step.lower(b.abstract_params, b.abstract_opt_state,
                       b.abstract_batch).compile().as_text()
    c = analyze_hlo(hlo)
    out[name] = {"collective_bytes": c.coll_bytes,
                 "by_kind": {k: v for k, v in c.coll_by_kind.items()}}
d = sum(x.size for x in jax.tree_util.tree_leaves(
    jax.eval_shape(lambda r: None, 0) or []) ) if False else 0
out["gp_overhead_fraction"] = (out["gp"]["collective_bytes"] -
    out["momentum"]["collective_bytes"]) / \
    max(out["momentum"]["collective_bytes"], 1)
out["gp_tree_overhead_fraction"] = (out["gp_tree"]["collective_bytes"] -
    out["momentum"]["collective_bytes"]) / \
    max(out["momentum"]["collective_bytes"], 1)
print("RESULT" + json.dumps(out))
"""


def run() -> dict:
    r = subprocess.run(
        [sys.executable, "-c", _SRC], capture_output=True, text=True,
        timeout=900,
        env={"PYTHONPATH": "src", "PATH": os.environ.get("PATH", "")})
    for line in r.stdout.splitlines():
        if line.startswith("RESULT"):
            out = json.loads(line[len("RESULT"):])
            out["paper_claim"] = (
                "pytree-native GP-H adds ~O(m^2) collective bytes on top "
                "of the grad all-reduce; the flat-vector variant pays an "
                "extra O(D) reshard (kept as the measured baseline)")
            out["claim_holds"] = bool(
                out["gp_tree_overhead_fraction"] <
                0.5 * max(out["gp_overhead_fraction"], 0.1))
            return out
    return {"error": r.stdout[-500:] + r.stderr[-2000:], "claim_holds": False}


if __name__ == "__main__":
    print(json.dumps(run(), indent=2))

"""Model-selection bench: structured exact MLL vs the dense oracle.

Claims gated here (DESIGN.md sec. 11):

  1. ACCURACY    — `hyper.mll` matches the dense `slogdet` + solve oracle
                   to <= 1e-5 relative for BOTH kernel families, and its
                   hyper-gradient matches central finite differences.
  2. STRUCTURE   — the jaxpr of `mll` (and of `jax.grad(mll)`) contains NO
                   intermediate with an axis >= N*D: the (ND, ND) Gram is
                   structurally absent, not just avoided on average.
  3. SCALING     — structured MLL wall-clock at D far beyond what the
                   dense oracle can touch (its (ND, ND) matrix would be
                   GBs), plus a measured small-size speedup ratio.
  4. FIT         — `hyper.fit` on the Fig.-3 relaxed-Rosenbrock gradient
                   surrogate improves the evidence over the
                   `auto_lengthscale` median-distance heuristic init.

Emits ``BENCH_hyper.json`` at the repo root (standalone or via
``benchmarks.run``) so successive PRs can diff the trajectory.
"""
import json
import os
import time

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp

from repro.core import get_kernel
from repro.hyper import (HyperParams, assert_no_dense_gram, fit, mll,
                         mll_dense)
from repro.optim.gp_directions import auto_lengthscale

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _rel(a, b):
    return float(abs(a - b) / max(1.0, abs(b)))


def _time(fn, *args, reps: int = 5) -> float:
    fn(*args)                      # compile / warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def _rosenbrock_surrogate_data(d: int = 100, n: int = 8, seed: int = 0):
    """(X, G) along a descent path of the relaxed Rosenbrock (Fig. 3)."""
    def f(x):
        return jnp.sum(x[:-1] ** 2 + 2.0 * (x[1:] - x[:-1] ** 2) ** 2)

    g = jax.grad(f)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(seed), (d,))
    X, G = [], []
    for _ in range(n):
        gx = g(x)
        X.append(x)
        G.append(gx)
        x = x - 0.02 * gx / (1.0 + jnp.linalg.norm(gx) / jnp.sqrt(d))
    return jnp.stack(X), jnp.stack(G)


def run() -> dict:
    key = jax.random.PRNGKey(0)
    out = {}

    # -- 1. accuracy vs the dense oracle (both families) + gradients ------
    acc = {}
    grads_ok = True
    for name, c in [("rbf", None), ("rq", None), ("expdot", 0.2),
                    ("poly3", 0.1)]:
        spec = get_kernel(name)
        n, d = 5, 8
        X = jax.random.normal(jax.random.fold_in(key, 1), (n, d))
        G = jax.random.normal(jax.random.fold_in(key, 2), (n, d))
        cc = None if c is None else c * jnp.ones(d)
        h = HyperParams.create(lengthscale2=2.0, signal=1.2, noise=1e-4)
        a = mll(spec, X, G, h, c=cc)
        b = mll_dense(spec, X, G, h, c=cc)
        acc[name] = _rel(float(a), float(b))
        g = jax.grad(lambda hp: mll(spec, X, G, hp, c=cc))(h)
        eps = 1e-5
        for i, fld in enumerate(h._fields):
            hp = h._replace(**{fld: getattr(h, fld) + eps})
            hm = h._replace(**{fld: getattr(h, fld) - eps})
            fd = float(mll(spec, X, G, hp, c=cc)
                       - mll(spec, X, G, hm, c=cc)) / (2 * eps)
            rel = abs(float(g[i]) - fd) / max(1.0, abs(fd))
            grads_ok &= rel < 1e-4
    out["mll_vs_dense_rel_err"] = acc
    out["acc_ok"] = bool(max(acc.values()) <= 1e-5)
    out["grads_match_fd"] = bool(grads_ok)

    # -- 2. structural gate: no (ND, ND) axis in the jaxpr -----------------
    n, d = 6, 64
    X = jax.random.normal(jax.random.fold_in(key, 3), (n, d))
    G = jax.random.normal(jax.random.fold_in(key, 4), (n, d))
    h = HyperParams.create(lengthscale2=float(d), noise=1e-6)
    worst = worst_g = None
    structural_ok = True
    for name in ("rbf", "expdot"):
        spec = get_kernel(name)
        try:
            worst = assert_no_dense_gram(spec, X, G, h)
            worst_g = assert_no_dense_gram(spec, X, G, h, grad=True)
        except AssertionError:
            structural_ok = False
    out["structural_ok"] = structural_ok
    out["jaxpr_max_axis"] = {"mll": worst, "grad": worst_g, "nd": n * d,
                             "n2": n * n}

    # -- 3. wall-clock: structured at dense-impossible D, + small ratio ----
    spec = get_kernel("rbf")
    f_struct = jax.jit(lambda X, G, h: mll(spec, X, G, h))
    times = {}
    for dd in (256, 2048, 8192):
        Xb = jax.random.normal(jax.random.fold_in(key, dd), (8, dd))
        Gb = jax.random.normal(jax.random.fold_in(key, dd + 1), (8, dd))
        hb = HyperParams.create(lengthscale2=float(dd), noise=1e-6)
        times[f"structured_n8_d{dd}_ms"] = 1e3 * _time(f_struct, Xb, Gb, hb)
    Xs = jax.random.normal(jax.random.fold_in(key, 7), (6, 64))
    Gs = jax.random.normal(jax.random.fold_in(key, 8), (6, 64))
    hs = HyperParams.create(lengthscale2=64.0, noise=1e-6)
    t_s = _time(jax.jit(lambda: mll(spec, Xs, Gs, hs)))
    t_d = _time(jax.jit(lambda: mll_dense(spec, Xs, Gs, hs)))
    times["small_n6_d64_structured_ms"] = 1e3 * t_s
    times["small_n6_d64_dense_ms"] = 1e3 * t_d
    times["small_speedup_x"] = t_d / max(t_s, 1e-12)
    out["timings"] = {k: round(v, 3) for k, v in times.items()}
    # the dense (ND=65536)^2 Gram would be 32 GiB in f64; structured runs it
    out["dense_gram_bytes_at_d8192"] = int((8 * 8192) ** 2 * 8)

    # -- 4. fit on the Fig.-3 Rosenbrock surrogate beats the heuristic -----
    X, G = _rosenbrock_surrogate_data()
    lam0 = auto_lengthscale(X)
    init = HyperParams.from_lam(lam0, signal=1.0, noise=1e-8)
    res = fit("rbf", X, G, init=init, steps=150)
    out["rosenbrock_fit"] = {
        "mll_heuristic_init": float(res.mll0),
        "mll_fitted": float(res.mll),
        "improvement": res.improvement,
        "n_steps": res.n_steps,
        "converged": bool(res.converged),
        "hypers": res.hypers.natural(),
        "heuristic_lengthscale2": float(1.0 / lam0),
    }
    fit_ok = res.improvement > 0.0

    out["claim"] = ("exact structured MLL == dense oracle (<=1e-5), exact "
                    "hyper-gradients, no (ND, ND) intermediate in the "
                    "jaxpr, and MLL fit beats the median-distance "
                    "heuristic on the Fig.-3 surrogate")
    out["claim_holds"] = bool(out["acc_ok"] and grads_ok and structural_ok
                              and fit_ok)
    return out


def main() -> None:
    r = run()
    print(json.dumps(r, indent=1, default=str))
    with open(os.path.join(_ROOT, "BENCH_hyper.json"), "w") as fh:
        json.dump(r, fh, indent=1, default=str)


if __name__ == "__main__":
    main()

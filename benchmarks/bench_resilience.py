"""Resilience bench: snapshot/restore overhead + journal replay economics.

Claims gated here (DESIGN.md sec. 17):

  1. EXACT RECOVERY — a snapshot/restore roundtrip of a live ``GPGState``
     reproduces every factor leaf BITWISE (``restore_max_err`` == 0.0);
     the recovered server is the uninterrupted server, not an
     approximation of it.
  2. JOURNAL ECONOMICS — recovering via snapshot + journal-tail replay
     re-executes only the ops after the last snapshot marker:
     ``ratio_replay_ops`` (tail ops / full-stream ops) stays at the
     snapshot cadence (1/3 here), and the measured tail-replay wall time
     is commensurately below a from-scratch stream replay (wall seconds
     reported, NOT regression-gated).
  3. ZERO-COST GUARDRAILS — the admission / watchdog / trip-wire layer is
     entirely host-side: the jaxprs of the extend and query programs are
     byte-identical with guardrails on and off
     (``guardrails_zero_cost``).

Emits ``BENCH_resilience.json`` at the repo root (standalone or via
``benchmarks.run``) so successive PRs can diff the trajectory.
"""
import json
import os
import time

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np

from repro.core import get_kernel
from repro.core.query import make_query_fn
from repro.core.state import GPGState, gpg_extend, gpg_init
from repro.resilience import (Journal, guardrails, replay_single, restore,
                              take_snapshot)

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

D = 16
WINDOW = 8
N_OPS = 30
SNAP_EVERY = 10          # journal cadence: tail is at most 1/3 of the tape


def _mk_state(seed=0):
    st = GPGState("rbf", D, window=WINDOW, noise=1e-6)
    r = np.random.RandomState(seed)
    for _ in range(WINDOW):
        st.extend(r.randn(D), r.randn(D))
    return st


def _snapshot_restore(tmp) -> dict:
    """Wall cost of one snapshot / one restore + bitwise restore check."""
    st = _mk_state()
    root = os.path.join(tmp, "snap")
    take_snapshot(st, root, step=0)               # warm the path once
    reps = 5
    t0 = time.perf_counter()
    for k in range(1, reps + 1):
        take_snapshot(st, root, step=k, keep=2)
    dt_snap = (time.perf_counter() - t0) / reps
    t0 = time.perf_counter()
    for _ in range(reps):
        back = restore(root)
    dt_rest = (time.perf_counter() - t0) / reps
    err = 0.0
    for f in ("X", "G", "Xt", "K1e", "K2e", "L", "Z", "lam", "count"):
        a = np.asarray(getattr(st.data, f), np.float64)
        b = np.asarray(getattr(back.data, f), np.float64)
        err = max(err, float(np.max(np.abs(a - b))) if a.size else 0.0)
    # overhead yardstick: one streaming extend on the same state
    r = np.random.RandomState(1)
    x, g = r.randn(D), r.randn(D)
    st.extend(x, g)                                # warm the evict+extend pair
    t0 = time.perf_counter()
    st.extend(r.randn(D), r.randn(D))
    dt_ext = time.perf_counter() - t0
    return {
        "restore_max_err": err,
        "snapshot_seconds": round(dt_snap, 4),
        "restore_seconds": round(dt_rest, 4),
        "snapshot_per_extend_x": round(dt_snap / max(dt_ext, 1e-9), 1),
    }


def _journal_vs_stream(tmp) -> dict:
    """Crash at the end of an N_OPS tape journaled at SNAP_EVERY cadence:
    journal-tail replay vs replaying the whole op stream from scratch."""
    root = os.path.join(tmp, "jrnl")
    jpath = os.path.join(root, "ops.jsonl")
    os.makedirs(root, exist_ok=True)
    st = _mk_state(seed=2)
    j = Journal(jpath)
    take_snapshot(st, root, step=0, journal=j)
    r = np.random.RandomState(3)
    tape = [(r.randn(D), r.randn(D)) for _ in range(N_OPS)]
    for k, (x, g) in enumerate(tape, 1):
        st.extend(x, g)
        j.record("extend", payload={"x": x, "g": g})
        if k % SNAP_EVERY == 0 and k < N_OPS:
            take_snapshot(st, root, step=k, journal=j)
    # -- recovery path A: latest snapshot + journal tail
    tail = Journal.since_snapshot(Journal.read(jpath))
    t0 = time.perf_counter()
    back = restore(root)
    replay_single(back, tail)
    dt_journal = time.perf_counter() - t0
    # -- recovery path B: re-stream the full tape through a fresh state
    t0 = time.perf_counter()
    scratch = _mk_state(seed=2)
    for x, g in tape:
        scratch.extend(x, g)
    dt_stream = time.perf_counter() - t0
    err = float(np.max(np.abs(np.asarray(st.data.Z) - np.asarray(back.data.Z))))
    tail_ops = sum(1 for e in tail if e.get("op") != "snapshot")
    return {
        "tape_ops": N_OPS,
        "replay_tail_ops": tail_ops,
        "ratio_replay_ops": round(tail_ops / N_OPS, 4),
        "journal_recovery_seconds": round(dt_journal, 4),
        "stream_replay_seconds": round(dt_stream, 4),
        "journal_replay_max_err": err,
    }


def _zero_cost() -> dict:
    """Guardrails on/off must trace byte-identical extend/query jaxprs."""
    spec = get_kernel("rbf")
    data = gpg_init(spec, D, WINDOW)
    st = _mk_state(seed=4)
    f, Z = st.padded_factors, st.data.Z
    x = jnp.ones(D)
    Xq = jnp.ones((4, D))
    pairs = []
    for make, args in (
            (lambda: (lambda d_, x_, g_: gpg_extend(spec, d_, x_, g_,
                                                    noise=1e-8)),
             (data, x, x)),
            (lambda: make_query_fn(spec), (f, Z, Xq))):
        with guardrails.use_guardrails(False):
            off = str(jax.make_jaxpr(make())(*args))
        with guardrails.use_guardrails(True):
            on = str(jax.make_jaxpr(make())(*args))
        pairs.append(off == on)
    return {"guardrails_zero_cost": bool(all(pairs))}


def run() -> dict:
    import tempfile

    out = {"d": D, "window": WINDOW, "tape_len": N_OPS,
           "snapshot_every": SNAP_EVERY}
    with tempfile.TemporaryDirectory() as tmp:
        out.update(_snapshot_restore(tmp))
        out.update(_journal_vs_stream(tmp))
    out.update(_zero_cost())
    out["claim_holds"] = bool(
        out["restore_max_err"] == 0.0
        and out["journal_replay_max_err"] == 0.0
        and out["ratio_replay_ops"] < 1.0
        and out["guardrails_zero_cost"])
    return out


if __name__ == "__main__":
    res = run()
    print(json.dumps(res, indent=1))
    with open(os.path.join(_ROOT, "BENCH_resilience.json"), "w") as f:
        json.dump(res, f, indent=1)

"""Chaos drill: inject every serve fault class, prove every one recovers.

CI's ``chaos`` job runs this under full telemetry and gates the log:

    REPRO_OBS=on REPRO_OBS_JSONL=/tmp/chaos.jsonl \
        PYTHONPATH=src python tools/chaos_drill.py
    python tools/check_telemetry.py /tmp/chaos.jsonl --expect-recovery

The drill exercises, in one process (one telemetry log):

  happy path          a ``GPServeBundle`` extend/query workload — the
                      required core counters/spans (``state.extend``,
                      ``serve.query``, ``cost.*``) come from here, so the
                      gate proves chaos rode on a REAL serving stack;
  nan_payload         corrupted observations rejected at admission with a
                      typed error (server path);
  kill_step           a killed serve step absorbed by bounded retry;
  straggler           a parked request expired by the deadline sweep;
  degenerate_factor   a poisoned Cholesky healed by the jitter ladder
                      inside ``extend``'s post-mutation watchdog;
  cg_divergence       a poisoned warm start caught by the CG watchdog,
                      answered by the exact solver;
  crash               the live state destroyed mid-trajectory, restored
                      bit-identically from snapshot + journal tail.

Accounting contract (asserted by ``--expect-recovery``): every injection
bumps ``resilience.faults_injected`` exactly once, every handler bumps
``resilience.faults_recovered`` exactly once, and recovery triggers ZERO
recompiles of the serving executables.
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

import jax

jax.config.update("jax_enable_x64", True)
import numpy as np

from repro.core import get_kernel
from repro.core.state import GPGState
from repro.obs import trace as obs
from repro.resilience import (ChaosInjector, Journal, errors, guardrails,
                              replay_single, restore, take_snapshot)
from repro.train.serve import GPFleetServer, build_gp_serve_step

D = 6
WINDOW = 4


def happy_path() -> None:
    """An uninjected serve workload: the telemetry gate's core counters."""
    st = GPGState("rbf", D, window=WINDOW, noise=1e-6)
    bundle = build_gp_serve_step(st, microbatch=4, return_std=True)
    r = np.random.RandomState(0)
    for _ in range(WINDOW + 2):
        st.extend(r.randn(D), r.randn(D))
    for _ in range(3):
        out = bundle.query(r.randn(3, D))
        assert np.all(np.isfinite(np.asarray(out.value)))


def drill_nan_payload() -> None:
    srv = GPFleetServer(kernel="rbf", d=D,
                        injector=ChaosInjector(
                            seed=1, rates={"nan_payload": 1.0}, max_faults=2))
    srv.connect("t0")
    r = np.random.RandomState(1)
    for _ in range(2):
        q = srv.submit("t0", "extend", (r.randn(D), r.randn(D)))
        assert isinstance(q.result, errors.NonFiniteObservationError)
    srv.injector = None                 # clean op proves the tenant lives
    srv.submit("t0", "extend", (r.randn(D), r.randn(D)))
    srv.drain()
    assert srv.fleet.n("t0") == 1


def drill_kill_step() -> None:
    srv = GPFleetServer(kernel="rbf", d=D,
                        injector=ChaosInjector(
                            seed=2, rates={"kill_step": 1.0}, max_faults=2))
    srv.connect("t0")
    r = np.random.RandomState(2)
    req = srv.submit("t0", "extend", (r.randn(D), r.randn(D)))
    srv.drain()
    assert req.done and req.result is None      # retries absorbed both kills
    assert srv.fleet.n("t0") == 1


def drill_straggler() -> None:
    from repro.configs.paper_gp import GPFleetConfig

    srv = GPFleetServer(kernel="rbf", d=D,
                        config=GPFleetConfig(deadline_steps=2),
                        injector=ChaosInjector(
                            seed=3, rates={"straggler": 1.0}, max_faults=1))
    srv.connect("t0")
    req = srv.submit("t0", "query", np.zeros((1, D)))
    for _ in range(4):
        srv.step()
    assert isinstance(req.result, errors.DeadlineExceededError)


def drill_degenerate_factor() -> None:
    st = GPGState("rbf", D, window=WINDOW, noise=1e-6)
    r = np.random.RandomState(4)
    for _ in range(3):
        st.extend(r.randn(D), r.randn(D))
    inj = ChaosInjector(seed=4, rates={"degenerate_factor": 1.0})
    assert inj.poison_factor(st)
    st.extend(r.randn(D), r.randn(D))   # the watchdog heals inside here
    assert guardrails.factor_ok(st)


def drill_cg_divergence() -> None:
    from repro.core import build_factors
    from repro.regime import solve

    spec = get_kernel("rbf")
    r = np.random.RandomState(5)
    n = 9                               # n > d: the iterative regime
    X, G = r.randn(n, D), r.randn(n, D)
    f = build_factors(spec, X, lam=0.7, noise=1e-6)
    inj = ChaosInjector(seed=5)
    z0 = inj.poison_warm_start((n, D))
    Z, info = solve(spec, f, G, policy="iterative", z0=z0, maxiter=4)
    assert info["fallback"] is True
    assert np.all(np.isfinite(np.asarray(Z)))


def drill_crash(root: str) -> None:
    jpath = os.path.join(root, "ops.jsonl")
    st = GPGState("rbf", D, window=WINDOW, noise=1e-6)
    j = Journal(jpath)
    r = np.random.RandomState(6)
    for _ in range(2):
        x, g = r.randn(D), r.randn(D)
        st.extend(x, g)
        j.record("extend", payload={"x": x, "g": g})
    take_snapshot(st, root, step=2, journal=j)
    for _ in range(2):                  # the journal tail past the snapshot
        x, g = r.randn(D), r.randn(D)
        st.extend(x, g)
        j.record("extend", payload={"x": x, "g": g})
    want_Z = np.asarray(st.data.Z).copy()
    inj = ChaosInjector(seed=6)
    inj.record("crash", step=4)
    del st                              # the process state is gone
    back = restore(root)
    replay_single(back, Journal.since_snapshot(Journal.read(jpath)))
    assert np.array_equal(np.asarray(back.data.Z), want_Z), \
        "crash recovery was not bit-identical"
    guardrails.record_recovery("crash", restored_step=2)


def main() -> int:
    if not obs.enabled():
        print("chaos_drill: run with REPRO_OBS=on REPRO_OBS_JSONL=<log> "
              "(the drill exists to produce a gateable telemetry log)",
              file=sys.stderr)
        return 2
    happy_path()
    drill_nan_payload()
    drill_kill_step()
    drill_straggler()
    drill_degenerate_factor()
    drill_cg_divergence()
    with tempfile.TemporaryDirectory() as td:
        drill_crash(td)
    snap = obs.snapshot()["counters"]
    inj = int(snap.get("resilience.faults_injected", 0))
    rec = int(snap.get("resilience.faults_recovered", 0))
    print(f"chaos drill: {inj} faults injected, {rec} recovered")
    obs.flush()
    return 0 if inj == rec and inj > 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())

"""Execute every ```python code block in the given markdown files.

The CI docs job runs this over README.md so documented snippets cannot
rot: each fenced python block is executed in its own namespace, in order,
and any exception fails the build with the block's source and location.

Usage:  PYTHONPATH=src python tools/check_docs.py README.md [more.md ...]
"""
from __future__ import annotations

import re
import sys
import time

FENCE = re.compile(r"^```python\s*$(.*?)^```\s*$", re.M | re.S)


def blocks_of(path: str) -> list[tuple[int, str]]:
    text = open(path, encoding="utf-8").read()
    out = []
    for m in FENCE.finditer(text):
        line = text[: m.start()].count("\n") + 2   # first line of the code
        out.append((line, m.group(1)))
    return out


def main(paths: list[str]) -> int:
    failures = 0
    for path in paths:
        blocks = blocks_of(path)
        if not blocks:
            print(f"{path}: no python blocks")
            continue
        for line, src in blocks:
            t0 = time.time()
            try:
                exec(compile(src, f"{path}:{line}", "exec"), {"__name__": "__docs__"})
                print(f"{path}:{line}: ok ({time.time()-t0:.1f}s)")
            except Exception as e:  # noqa: BLE001 — report and keep going
                failures += 1
                print(f"{path}:{line}: FAILED — {type(e).__name__}: {e}")
                print("----\n" + src.strip() + "\n----")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:] or ["README.md"]))

"""Telemetry smoke gate: validate one run's REPRO_OBS JSONL log.

CI runs an instrumented workload (``examples/streaming_bo.py --smoke``
with ``REPRO_OBS=on REPRO_OBS_JSONL=<log>``), then gates on this script:

    python tools/check_telemetry.py <log.jsonl> [--allow-recompile]
                                    [--require-span NAME ...]
                                    [--expect-regime-switch-at N]
                                    [--expect-recovery]

Checks (each failure is one line on stderr; exit 1 on any):

  * every line parses as a JSON object with a ``type``;
  * required spans occurred (default: ``state.extend``, ``serve.query``)
    and no span has a negative duration;
  * the recompile sentinel stayed clean: no ``compile`` event with
    ``nth > 1`` (``--allow-recompile`` downgrades this for workloads
    that legitimately re-trace, e.g. after ``jax.clear_caches()``);
  * a final ``snapshot`` event exists and carries the core counters
    (extend calls, pivot fallbacks, serve requests, solver-cache misses,
    serve-step compiles) plus at least one ``cost.*`` modeled gauge;
  * the snapshot counters are self-consistent with the event stream
    (``state.extend_calls`` == number of ``state.extend`` span events;
    ``serve.requests`` == number of ``serve.query`` span events);
  * with ``--expect-regime-switch-at N``: the run's FIRST
    ``{"type": "regime", "event": "switch", ..., "to": "iterative"}``
    event fired at exactly n == N — the analytic crossover of the
    regime cost model (``repro.regime.policy``) agreeing with the live
    stream is what makes the flop model auditable, not advisory.

The log must come from ONE process run (the sink appends; point each run
at a fresh file, as CI does).
"""
from __future__ import annotations

import argparse
import json
import sys

DEFAULT_REQUIRED_SPANS = ("state.extend", "serve.query")

REQUIRED_COUNTERS = (
    "state.extend_calls",
    "state.refactor_fallback",
    "serve.requests",
    "serve.solver_cache.misses",
    "compile.gp_serve_step.compiles",
)


def check(path: str, *, required_spans=DEFAULT_REQUIRED_SPANS,
          allow_recompile: bool = False,
          expect_regime_switch_at: int | None = None,
          expect_recovery: bool = False) -> list[str]:
    """Validate one telemetry log; return a list of failure strings."""
    failures: list[str] = []
    events: list[dict] = []
    try:
        with open(path, encoding="utf-8") as fh:
            for lineno, line in enumerate(fh, 1):
                line = line.strip()
                if not line:
                    continue
                try:
                    ev = json.loads(line)
                except json.JSONDecodeError as e:
                    failures.append(f"line {lineno}: malformed JSON ({e})")
                    continue
                if not isinstance(ev, dict) or "type" not in ev:
                    failures.append(f"line {lineno}: event without a 'type'")
                    continue
                events.append(ev)
    except OSError as e:
        return [f"cannot read {path}: {e}"]
    if not events:
        return [f"{path}: no telemetry events"]

    spans = [e for e in events if e.get("type") == "span"]
    span_names = [e.get("name") for e in spans]
    for name in required_spans:
        if name not in span_names:
            failures.append(f"required span never recorded: {name}")
    for e in spans:
        if not isinstance(e.get("dur_s"), (int, float)) or e["dur_s"] < 0:
            failures.append(f"span {e.get('path')}: bad duration "
                            f"{e.get('dur_s')!r}")

    recompiles = [e for e in events
                  if e.get("type") == "compile" and e.get("nth", 1) > 1]
    if recompiles and not allow_recompile:
        for e in recompiles:
            failures.append(
                f"recompile-sentinel violation: watch={e.get('watch')} "
                f"traced a seen signature again (nth={e.get('nth')})")

    if expect_regime_switch_at is not None:
        switches = [e for e in events
                    if e.get("type") == "regime"
                    and e.get("event") == "switch"
                    and e.get("to") == "iterative"]
        if not switches:
            failures.append(
                "no regime switch to 'iterative' recorded (expected one "
                f"at n={expect_regime_switch_at})")
        else:
            first = switches[0]
            if int(first.get("n", -1)) != int(expect_regime_switch_at):
                failures.append(
                    "regime switch fired off-model: first exact->iterative "
                    f"at n={first.get('n')} but the cost model says "
                    f"n={expect_regime_switch_at} "
                    f"(crossover_n={first.get('crossover_n')})")

    snaps = [e for e in events if e.get("type") == "snapshot"]
    if not snaps:
        failures.append("no final registry snapshot (trace.flush() missing)")
        return failures
    snap = snaps[-1]
    counters = snap.get("counters", {})
    gauges = snap.get("gauges", {})
    for name in REQUIRED_COUNTERS:
        if name not in counters:
            failures.append(f"snapshot missing required counter: {name}")
    if not any(k.startswith("cost.") for k in gauges):
        failures.append("snapshot has no cost.* modeled gauges")

    if expect_recovery:
        # the chaos-drill gate: every injected fault was recovered (the
        # totals MATCH — a drill that injected nothing proves nothing),
        # and recovery never triggered a recompile (checked above; this
        # flag refuses --allow-recompile as a matter of policy)
        inj = int(counters.get("resilience.faults_injected", 0))
        rec = int(counters.get("resilience.faults_recovered", 0))
        if "resilience.faults_injected" not in counters:
            failures.append("--expect-recovery: no "
                            "resilience.faults_injected counter (the "
                            "chaos injector never ran)")
        elif inj == 0:
            failures.append("--expect-recovery: zero faults injected — "
                            "the drill proved nothing")
        elif inj != rec:
            failures.append(
                f"--expect-recovery: injected {inj} != recovered {rec} "
                "(an unhandled fault class, or double-counted recovery)")
        if allow_recompile:
            failures.append("--expect-recovery is incompatible with "
                            "--allow-recompile: zero-recompile recovery "
                            "IS the claim under test")

    # self-consistency: the registry's call counters must agree with the
    # number of span events the same call sites emitted
    for counter, span_name in (("state.extend_calls", "state.extend"),
                               ("serve.requests", "serve.query")):
        if counter in counters:
            n_events = span_names.count(span_name)
            if int(counters[counter]) != n_events:
                failures.append(
                    f"counter/span mismatch: {counter}={counters[counter]} "
                    f"but {n_events} '{span_name}' span events")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("log", help="path to the REPRO_OBS_JSONL file")
    ap.add_argument("--allow-recompile", action="store_true",
                    help="do not fail on compile events with nth > 1")
    ap.add_argument("--require-span", action="append", default=None,
                    metavar="NAME",
                    help="span name that must appear (repeatable; default: "
                         + ", ".join(DEFAULT_REQUIRED_SPANS) + ")")
    ap.add_argument("--expect-regime-switch-at", type=int, default=None,
                    metavar="N",
                    help="assert the first exact->iterative regime switch "
                         "event fired at exactly this n (the modeled "
                         "crossover)")
    ap.add_argument("--expect-recovery", action="store_true",
                    help="assert resilience.faults_injected == "
                         "resilience.faults_recovered > 0 and zero "
                         "recompiles (the chaos-drill gate)")
    args = ap.parse_args(argv)
    required = tuple(args.require_span) if args.require_span \
        else DEFAULT_REQUIRED_SPANS
    failures = check(args.log, required_spans=required,
                     allow_recompile=args.allow_recompile,
                     expect_regime_switch_at=args.expect_regime_switch_at,
                     expect_recovery=args.expect_recovery)
    if failures:
        for f in failures:
            print(f"TELEMETRY FAIL: {f}", file=sys.stderr)
        return 1
    print(f"telemetry OK: {args.log}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

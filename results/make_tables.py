"""Render EXPERIMENTS.md tables from the dry-run JSONs."""
import json
import sys


def load(path):
    try:
        with open(path) as f:
            return {(r["arch"], r["shape"]): r for r in json.load(f)}
    except FileNotFoundError:
        return {}


def fmt_s(x):
    if x >= 1.0:
        return f"{x:8.2f}s"
    return f"{x*1e3:7.1f}ms"


def table(rows, title):
    print(f"\n### {title}\n")
    print("| arch | shape | fits | peak GB | compute | memory[opt] | "
          "collective | dominant | useful | MFU bound |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for (arch, shape), r in sorted(rows.items()):
        if r["status"] == "skipped":
            print(f"| {arch} | {shape} | — | — | — | — | — | SKIP | — | — |")
            continue
        if r["status"] != "ok":
            print(f"| {arch} | {shape} | ERR | | | | | | | |")
            continue
        print(f"| {arch} | {shape} | {'Y' if r['fits_hbm'] else 'N'} "
              f"| {r['peak_bytes']/1e9:.2f} | {fmt_s(r['compute_s'])} "
              f"| {fmt_s(r['memory_s'])} [{fmt_s(r.get('memory_s_opt', 0))}] "
              f"| {fmt_s(r['collective_s'])} | {r['dominant'][:4]} "
              f"| {r['useful_ratio']:.2f} | {r['mfu_bound']:.3f} |")


if __name__ == "__main__":
    single = load("results/dryrun_single_opt.json")
    multi = load("results/dryrun_multi_opt.json")
    base = load("results/dryrun_baseline.json")
    table(base, "Baseline (paper-standard formulations), single-pod 16x16")
    table(single, "Optimized, single-pod 16x16")
    table(multi, "Optimized, multi-pod 2x16x16")

"""Optimizer behaviour tests: classic pytree optimizers, the GP-precond
training optimizer, and the paper's Alg. 1 drivers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import get_optimizer, gp_optimize
from repro.optim.classic import bfgs_optimize, strong_wolfe


def quad_problem(d=12, seed=0):
    rng = np.random.RandomState(seed)
    A = rng.randn(d, d)
    A = jnp.asarray(A @ A.T + 0.5 * np.eye(d))
    xstar = jnp.asarray(rng.randn(d))

    def fg(x):
        g = A @ (x - xstar)
        return 0.5 * float((x - xstar) @ g), g

    return fg, xstar, A


@pytest.mark.parametrize("name", ["sgd", "momentum", "adamw", "adamw8bit",
                                  "adafactor"])
def test_pytree_optimizers_reduce_quadratic(name):
    fg, xstar, A = quad_problem()
    params = {"x": jnp.zeros(12, jnp.float32), "y": jnp.ones((3, 4)) * 0.0}
    # first-order methods need lr < 2/lambda_max (~0.04 here)
    first_order = name in ("sgd", "momentum")
    opt = get_optimizer(name, lr=8e-3 if first_order else 0.1)
    state = opt.init(params)

    def loss(p):
        x = p["x"] + p["y"].reshape(-1)
        g = A @ (x - xstar)
        return 0.5 * (x - xstar) @ g

    l0 = float(loss(params))
    for _ in range(150 if first_order else 60):
        grads = jax.grad(loss)(params)
        params, state = opt.update(grads, state, params)
    l1 = float(loss(params))
    assert l1 < (0.5 if first_order else 0.2) * l0, (name, l0, l1)


def test_gp_precond_optimizer_runs_and_descends():
    fg, xstar, A = quad_problem(d=20, seed=1)
    params = {"x": jnp.zeros(20, jnp.float64)}
    opt = get_optimizer("gp", lr=1.0, history=4, fallback_lr=5e-2,
                        max_step_rms=1.0)
    state = opt.init(params)

    def loss(p):
        g = A @ (p["x"] - xstar)
        return 0.5 * (p["x"] - xstar) @ g

    l0 = float(loss(params))
    for _ in range(30):
        grads = jax.grad(loss)(params)
        params, state = opt.update(grads, state, params)
        assert bool(jnp.all(jnp.isfinite(params["x"])))
    assert float(loss(params)) < l0
    assert int(state["count"]) == 4          # ring buffer saturates


def test_strong_wolfe_satisfies_conditions():
    fg, xstar, A = quad_problem(d=8, seed=2)
    f = lambda x: fg(x)[0]
    x = jnp.zeros(8, jnp.float64)
    f0, g0 = fg(x)
    d = -g0
    alpha, _ = strong_wolfe(f, fg, x, d, f0, g0)
    assert alpha > 0
    f1, g1 = fg(x + alpha * d)
    dg0 = float(g0 @ d)
    assert f1 <= f0 + 1e-4 * alpha * dg0            # Armijo
    assert abs(float(g1 @ d)) <= 0.9 * abs(dg0)     # curvature


def test_gp_optimize_rosenbrock_matches_paper_setting():
    """Fig. 3 sanity at D=20 (fast): GP-H and GP-X both reach tol."""
    D = 20

    def f_np(x):
        return jnp.sum(x[:-1] ** 2 + 2.0 * (x[1:] - x[:-1] ** 2) ** 2)

    grad = jax.grad(f_np)

    def fg(x):
        return float(f_np(x)), grad(x)

    x0 = jnp.asarray(np.random.RandomState(3).randn(D)) * 0.5
    for mode, lam in [("gph", 9.0), ("gpx", 0.05)]:
        tr = gp_optimize(fg, x0, mode=mode, kernel="rbf", lam=lam, history=2,
                         max_iters=150, tol_grad=1e-5, noise=1e-10)
        assert tr.gnorms[-1] <= 1e-5 * tr.gnorms[0] * 10, (mode, tr.gnorms[-1])

    trb = bfgs_optimize(fg, x0, max_iters=150, tol_grad=1e-5)
    assert trb.gnorms[-1] <= 1e-4 * trb.gnorms[0]

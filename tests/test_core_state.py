"""Incremental posterior state (core/state.py) + batched query serving
(core/query.py, train/serve.py).

The two contract tests the serving layer stands on:

  * extend() k times == from-scratch factorization on the union of the
    observations (values, gradients, Hessian matvecs), and it is genuinely
    incremental — no refactorization events, and structurally no
    intermediate with an N^2-sized axis (the O((N^2)^3) dense inner solve
    of the Woodbury path can never have happened).
  * posterior_batch serves any number of queries off ONE inner solve
    (factor reuse asserted against the state's n_solve counter).
"""
from functools import partial

import jax
import jax.numpy as jnp
import pytest

from repro.core import (GPGState, build_factors, dense_solve, get_kernel,
                        posterior_batch, posterior_grad, posterior_hessian,
                        posterior_value)
from repro.core.state import gpg_extend, gpg_init

KERNELS = ["rbf", "rq", "expdot"]
D = 7
LAM = 0.7
NOISE = 1e-8


def _data(rng, n, d=D, fold=0):
    X = jax.random.normal(jax.random.fold_in(rng, 2 * fold + 1), (n, d))
    G = jax.random.normal(jax.random.fold_in(rng, 2 * fold + 2), (n, d))
    return X, G


def _scratch(name, X, G, noise=NOISE):
    spec = get_kernel(name)
    Z = dense_solve(spec, X, G, lam=LAM, noise=noise)
    f = build_factors(spec, X, lam=LAM, noise=noise)
    return spec, f, Z


def _rel(a, b):
    return float(jnp.linalg.norm(a - b) / jnp.maximum(jnp.linalg.norm(b),
                                                      1e-30))


# ---------------------------------------------------------------------------
# extend() == from-scratch (the acceptance property)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", KERNELS)
def test_extend_k_times_matches_scratch(name, rng):
    """k extends == one from-scratch solve: values, grads, Hessian mv."""
    k = 6
    X, G = _data(rng, k)
    st = GPGState(name, D, capacity=8, lam=LAM, noise=NOISE)
    for i in range(k):
        st.extend(X[i], G[i])
    spec, f, Zref = _scratch(name, X, G)
    assert _rel(st.Z, Zref) < 1e-6

    Xq = X[:3] + 0.1 * jax.random.normal(jax.random.fold_in(rng, 7), (3, D))
    probe = jax.random.normal(jax.random.fold_in(rng, 8), (D,))
    pb = st.posterior(Xq, probe=probe)
    assert _rel(pb.value, posterior_value(spec, Xq, f, Zref)) < 1e-5
    assert _rel(pb.grad, posterior_grad(spec, Xq, f, Zref)) < 1e-5
    href = jnp.stack([posterior_hessian(spec, xq, f, Zref).matvec(probe)
                      for xq in Xq])
    assert _rel(pb.hess_v, href) < 1e-5
    # and it really was incremental: no fallback refactorization fired
    assert st.stats["n_refactor"] == 0


def test_extend_matches_scratch_dot_kernel_with_center(rng):
    """Dot-family path (centered Xt) through the same extend machinery."""
    k = 5
    X, G = _data(rng, k)
    c = 0.3 * jax.random.normal(jax.random.fold_in(rng, 9), (D,))
    st = GPGState("expdot", D, capacity=8, lam=LAM, noise=NOISE, c=c)
    for i in range(k):
        st.extend(X[i], G[i])
    spec = get_kernel("expdot")
    Zref = dense_solve(spec, X, G, lam=LAM, c=c, noise=NOISE)
    assert _rel(st.Z, Zref) < 1e-6


def test_extend_property_sweep(rng):
    """Property sweep over (n, d, kernel, seed): extends match scratch."""
    cases = [(n, d, k, s) for n in (2, 5) for d in (3, 9)
             for k in KERNELS for s in (0, 1)]
    for n, d, name, seed in cases:
        key = jax.random.fold_in(rng, hash((n, d, name, seed)) % (2**31))
        X, G = _data(key, n, d)
        st = GPGState(name, d, capacity=n, lam=LAM, noise=NOISE)
        for i in range(n):
            st.extend(X[i], G[i])
        spec = get_kernel(name)
        Zref = dense_solve(spec, X, G, lam=LAM, noise=NOISE)
        assert _rel(st.Z, Zref) < 1e-5, (n, d, name, seed)


# ---------------------------------------------------------------------------
# structurally incremental: no N^2-sized axis anywhere in extend()
# ---------------------------------------------------------------------------


def _jaxpr_dims(jaxpr):
    dims = []
    for eqn in jaxpr.eqns:
        for v in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(v, "aval", None)
            shape = getattr(aval, "shape", ())
            dims.extend(int(s) for s in shape if isinstance(s, int))
        for val in eqn.params.values():
            for sub in (val if isinstance(val, (tuple, list)) else (val,)):
                inner = getattr(sub, "jaxpr", sub)   # ClosedJaxpr -> Jaxpr
                if hasattr(inner, "eqns"):
                    dims.extend(_jaxpr_dims(inner))
    return dims


def test_extend_never_materializes_dense_inner_system(rng):
    """The (N^2 x N^2) refactorization is structurally impossible in
    extend(): no traced intermediate has any axis >= N^2.  The dense inner
    operator of ``woodbury_solve`` would show up as axes of cap^2 = 36 and
    cap^4 = 1296; the largest legitimate object is the flattened (N*D,) CG
    inner product, and cap*d = 30 < 36 by construction here."""
    cap, d = 6, 5
    spec = get_kernel("rbf")
    data = gpg_init(spec, d, cap, lam=LAM)
    X, G = _data(rng, cap, d)
    for i in range(3):     # pre-fill a few rows so the border is nontrivial
        data = gpg_extend(spec, data, X[i], G[i], noise=NOISE)
    closed = jax.make_jaxpr(
        partial(gpg_extend, spec, noise=NOISE))(data, X[3], G[3])
    dims = _jaxpr_dims(closed.jaxpr)
    assert dims and max(dims) < cap * cap, max(dims)


# ---------------------------------------------------------------------------
# sliding window eviction
# ---------------------------------------------------------------------------


def test_eviction_window_invariant(rng):
    """Streaming k > m observations through window=m is equivalent to
    conditioning from scratch on the LAST m observations only."""
    m, total = 4, 11
    X, G = _data(rng, total)
    st = GPGState("rbf", D, window=m, lam=LAM, noise=NOISE)
    for i in range(total):
        st.extend(X[i], G[i])
        assert st.n == min(i + 1, m)          # bounded-N invariant
        assert st.data.capacity == m          # storage never grows
    assert jnp.allclose(st.X, X[total - m:])
    assert jnp.allclose(st.G, G[total - m:])
    spec, f, Zref = _scratch("rbf", X[total - m:], G[total - m:])
    assert _rel(st.Z, Zref) < 1e-6
    Xq = X[-2:] + 0.05
    assert _rel(st.posterior(Xq).grad, posterior_grad(spec, Xq, f, Zref)) < 1e-5


def test_explicit_evict_matches_scratch_on_suffix(rng):
    X, G = _data(rng, 7)
    st = GPGState.from_data("rbf", X, G, lam=LAM, noise=NOISE)
    st.evict(3)
    spec, f, Zref = _scratch("rbf", X[3:], G[3:])
    assert st.n == 4
    assert _rel(st.Z, Zref) < 1e-6


def test_degraded_pivot_falls_back_to_refactor(rng):
    """A near-duplicate observation degenerates the bordered pivot; the
    state must fall back to a full (N^3, never N^6) refactorization and
    stay finite."""
    X, G = _data(rng, 4)
    st = GPGState("rbf", D, capacity=6, lam=LAM, noise=NOISE,
                  deg_thresh=1e-4)
    for i in range(4):
        st.extend(X[i], G[i])
    assert st.stats["n_refactor"] == 0
    st.extend(X[0] + 1e-9, G[0])              # kernel-space collinear
    assert st.stats["n_refactor"] == 1        # fallback fired
    assert bool(jnp.all(jnp.isfinite(st.Z)))


def test_degraded_pivot_fallback_counted_by_obs(rng):
    """The observability counter for the fallback path: healthy extends
    leave ``state.refactor_fallback`` at 0, the degenerate one increments
    it EXACTLY once (and the in-jit degenerate tap agrees)."""
    from repro.obs import trace as obs

    obs.reset()
    with obs.use_obs(True):
        X, G = _data(rng, 4)
        st = GPGState("rbf", D, capacity=6, lam=LAM, noise=NOISE,
                      deg_thresh=1e-4)
        for i in range(4):
            st.extend(X[i], G[i])
        assert obs.counter_value("state.refactor_fallback") == 0
        assert obs.counter_value("state.extend_calls") == 4
        st.extend(X[0] + 1e-9, G[0])
        assert obs.counter_value("state.refactor_fallback") == 1
        assert obs.counter_value("state.extend_calls") == 5
        # the traced-side tap (inside the lax.cond predicate) agrees with
        # the host-side ground truth
        assert obs.counter_value("state.degenerate_fallback") == 1
    obs.reset()


# ---------------------------------------------------------------------------
# batched query serving: factor reuse, zero re-solves
# ---------------------------------------------------------------------------


def test_posterior_batch_q64_single_inner_solve(rng):
    """Bulk conditioning does EXACTLY ONE inner solve; serving Q=64
    queries (micro-batched) performs zero additional ones."""
    X, G = _data(rng, 8)
    st = GPGState.from_data("rbf", X, G, lam=LAM, noise=NOISE)
    assert st.stats["n_solve"] == 1
    Xq = jax.random.normal(jax.random.fold_in(rng, 3), (64, D))
    probe = jnp.ones((D,))
    pb = st.posterior(Xq, probe=probe, microbatch=16)
    assert pb.value.shape == (64,) and pb.grad.shape == (64, D)
    assert pb.hess_v.shape == (64, D)
    assert st.stats["n_solve"] == 1           # factor reuse: no re-solve
    assert st.stats["n_refactor"] == 1        # only the bulk conditioning

    # microbatching is exact (same contractions, chunked)
    pb1 = st.posterior(Xq, probe=probe)
    assert jnp.allclose(pb.value, pb1.value)
    assert jnp.allclose(pb.grad, pb1.grad)
    assert jnp.allclose(pb.hess_v, pb1.hess_v)


@pytest.mark.parametrize("q,microbatch", [(7, 3), (5, 4), (1, 4), (9, 2)])
def test_posterior_batch_ragged_microbatch(q, microbatch, rng):
    """Q not divisible by the microbatch: the trailing partial chunk must
    be served exactly — same values/grads/stds as the unchunked call, and
    output shapes trimmed to Q."""
    X, G = _data(rng, 6)
    st = GPGState.from_data("rbf", X, G, lam=LAM, noise=NOISE)
    Xq = jax.random.normal(jax.random.fold_in(rng, 11), (q, D))
    probe = jnp.ones((D,))
    pb = st.posterior(Xq, probe=probe, microbatch=microbatch,
                      return_std=True)
    ref = st.posterior(Xq, probe=probe, return_std=True)
    assert pb.value.shape == (q,) and pb.grad.shape == (q, D)
    assert pb.std.shape == (q,) and pb.hess_v.shape == (q, D)
    assert jnp.allclose(pb.value, ref.value)
    assert jnp.allclose(pb.grad, ref.grad)
    assert jnp.allclose(pb.std, ref.std)
    assert jnp.allclose(pb.hess_v, ref.hess_v)


@pytest.mark.parametrize("q", [1, 5, 11])
def test_serve_bundle_ragged_request(q, rng):
    """Serve-side padding path for requests not divisible by microbatch
    (including a single query and q > 2*microbatch)."""
    from repro.train.serve import build_gp_serve_step

    X, G = _data(rng, 5)
    st = GPGState.from_data("rbf", X, G, lam=LAM, noise=NOISE)
    srv = build_gp_serve_step(st, microbatch=4)
    Xq = jax.random.normal(jax.random.fold_in(rng, 12), (q, D))
    pb = srv.query(Xq)
    ref = st.posterior(Xq)
    assert pb.value.shape == (q,) and pb.grad.shape == (q, D)
    assert jnp.allclose(pb.grad, ref.grad)
    assert jnp.allclose(pb.value, ref.value)


def test_posterior_batch_matches_pointwise_inference(rng):
    X, G = _data(rng, 6)
    st = GPGState.from_data("rq", X, G, lam=LAM, noise=NOISE)
    spec, f, Zref = _scratch("rq", X, G)
    Xq = jax.random.normal(jax.random.fold_in(rng, 4), (5, D))
    pb = posterior_batch(st.spec, Xq, st.factors, st.Z, microbatch=2)
    assert _rel(pb.grad, posterior_grad(spec, Xq, f, Zref)) < 1e-5
    assert _rel(pb.value, posterior_value(spec, Xq, f, Zref)) < 1e-5


def test_gp_serve_bundle_pads_and_reuses_compilation(rng):
    from repro.train.serve import build_gp_serve_step

    X, G = _data(rng, 5)
    st = GPGState.from_data("rbf", X, G, lam=LAM, noise=NOISE, capacity=8)
    srv = build_gp_serve_step(st, microbatch=8)
    Xq = jax.random.normal(jax.random.fold_in(rng, 5), (13, D))  # != 0 mod 8
    pb = srv.query(Xq)
    ref = st.posterior(Xq)
    assert pb.grad.shape == (13, D)
    assert jnp.allclose(pb.grad, ref.grad)
    assert jnp.allclose(pb.value, ref.value)
    # extend between requests changes count (5 -> 6) but NOT the padded
    # shapes: the SAME executable must serve the new state revision
    assert srv.step._cache_size() == 1
    st.extend(Xq[0], G[0] * 0.5)
    pb2 = srv.query(Xq[:3])
    ref2 = st.posterior(Xq[:3])
    assert jnp.allclose(pb2.grad, ref2.grad)
    assert srv.step._cache_size() == 1       # no recompilation happened


# ---------------------------------------------------------------------------
# factor-reuse re-solves (GP-X) and state-vs-stateless directions
# ---------------------------------------------------------------------------


def test_resolve_new_rhs_reuses_factors(rng):
    X, G = _data(rng, 6)
    st = GPGState.from_data("rbf", X, G, lam=LAM, noise=NOISE)
    refactors = st.stats["n_refactor"]
    rhs = jax.random.normal(jax.random.fold_in(rng, 6), (6, D))
    Z = st.resolve(rhs)
    Zref = dense_solve(get_kernel("rbf"), X, rhs, lam=LAM, noise=NOISE)
    assert _rel(Z, Zref) < 1e-6
    assert st.stats["n_refactor"] == refactors   # zero refactorization


def test_state_directions_match_stateless(rng):
    from repro.optim import (gph_direction, gph_direction_state,
                             gpx_direction, gpx_direction_state)

    X, G = _data(rng, 5)
    x_t, g_t = X[-1], G[-1]
    st = GPGState.from_data("rbf", X, G, lam=LAM, noise=NOISE)
    d_state = gph_direction_state(st, x_t, g_t)
    d_ref = gph_direction(X, G, x_t, g_t, kernel="rbf", lam=LAM, noise=NOISE)
    assert _rel(d_state, d_ref) < 1e-5

    stg = GPGState.from_data("rbf", G, X, lam=LAM, noise=NOISE)  # flipped
    d_state = gpx_direction_state(stg, x_t)
    d_ref = gpx_direction(X, G, x_t, kernel="rbf", lam=LAM, noise=NOISE)
    assert _rel(d_state, d_ref) < 1e-5


def test_unbounded_growth_is_exact(rng):
    """window=None doubles capacity by zero-padding; padding is inert."""
    X, G = _data(rng, 9)
    st = GPGState("rbf", D, capacity=2, lam=LAM, noise=NOISE)
    for i in range(9):
        st.extend(X[i], G[i])
    assert st.n == 9 and st.data.capacity >= 9
    spec, f, Zref = _scratch("rbf", X, G)
    assert _rel(st.Z, Zref) < 1e-6

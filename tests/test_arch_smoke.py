"""Per-architecture smoke tests (assignment contract): instantiate the
REDUCED config of each family, run one forward + one train step on CPU,
assert output shapes and no NaNs. Serving (prefill+decode) consistency is
asserted against the full forward for every family that supports it.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.launch.mesh import make_test_mesh
from repro.models import SHAPES, build_model, make_concrete_batch
from repro.optim import get_optimizer
from repro.train import build_train_step


@pytest.fixture(scope="module")
def mesh():
    n = len(jax.devices())
    return make_test_mesh((1, n), ("data", "model"))


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, rng):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(rng)
    batch = make_concrete_batch(cfg, "smoke_train")
    logits, aux = model.logits(params, batch)
    ss = SHAPES["smoke_train"]
    assert logits.shape == (ss.global_batch, ss.seq_len, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_decreases_loss(arch, mesh):
    cfg = get_config(arch, smoke=True)
    opt = get_optimizer("adamw", lr=2e-3)
    bundle = build_train_step(cfg, opt, mesh, shape="smoke_train",
                              donate=False)
    params = bundle.model.init(jax.random.PRNGKey(0))
    opt_state = bundle.opt.init(params)
    batch = make_concrete_batch(cfg, "smoke_train")
    losses = []
    for _ in range(4):
        params, opt_state, metrics = bundle.step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        assert jnp.isfinite(metrics["loss"])
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch, rng):
    """Greedy serving path agrees with the training forward at the decode
    position (MoE: capacity-free regime so routing is identical)."""
    cfg = get_config(arch, smoke=True)
    if cfg.n_experts:
        cfg = type(cfg)(**{**cfg.__dict__, "capacity_factor": 8.0})
    model = build_model(cfg)
    params = model.init(rng)
    batch = make_concrete_batch(cfg, "smoke_train")
    full_logits, _ = model.logits(params, batch)
    s = 63
    pre = {}
    for k, v in batch.items():
        if k == "tokens":
            pre[k] = v[:, :s]
        elif k == "positions":
            pre[k] = v[..., :s]
        else:
            pre[k] = v
    _, cache = model.prefill(params, pre, 96)
    dl, _ = model.decode(params, cache, batch["tokens"][:, s],
                         jnp.full((2,), s, jnp.int32))
    err = float(jnp.max(jnp.abs(dl - full_logits[:, s])))
    assert err < 1e-3, f"{arch}: decode/forward mismatch {err}"


@pytest.mark.parametrize("arch", ["gemma3-4b", "zamba2-7b", "mamba2-130m"])
def test_long_context_decode_state_is_bounded(arch, rng):
    """Sub-quadratic archs: cache memory must NOT scale with full seq_len
    for the window/SSM components."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)

    def cache_bytes(max_len):
        cache = jax.eval_shape(lambda: model.init_cache(1, max_len))
        return sum(
            int(jnp.prod(jnp.array(l.shape))) * l.dtype.itemsize
            for l in jax.tree_util.tree_leaves(cache))

    b1, b2 = cache_bytes(1024), cache_bytes(4096)
    if cfg.family == "ssm":
        assert b1 == b2                     # O(1) state
    else:
        # only global-attention caches may grow (window/SSM parts fixed)
        assert b2 < 4096 / 1024 * b1

"""Woodbury / CG / poly2-fast-path solves (paper Sec. 2.3, 4.2, App. C).

Solution checks are RESIDUAL-based: poly2's Gram matrix is rank-deficient
once N*D > D(D+1)/2, so Z is not unique — but Gram @ Z == G must hold for
any valid solver output, and posterior predictions agree across solvers.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (build_factors, dense_solve, get_kernel, gram_cg_solve,
                        gram_matvec, poly2_quadratic_solve, woodbury_solve)

N, D = 5, 7
LAM = 0.7
KERNELS = ["rbf", "matern52", "rq", "poly2", "poly3", "expdot"]


def setup(name, rng, consistent_poly2=True):
    spec = get_kernel(name)
    c = None
    if not spec.is_stationary:
        c = jax.random.normal(jax.random.fold_in(rng, 99), (D,)) * 0.1
    X = jax.random.normal(jax.random.fold_in(rng, 1), (N, D))
    if name == "poly2" and consistent_poly2:
        # keep the RHS in the Gram's range: gradients of a true quadratic
        A0 = jax.random.normal(jax.random.fold_in(rng, 11), (D, D))
        A0 = A0 @ A0.T
        G = (X - c) @ A0.T
    else:
        G = jax.random.normal(jax.random.fold_in(rng, 2), (N, D))
    return spec, X, G, c


def relres(spec, f, Z, G):
    r = gram_matvec(f, Z, stationary=spec.is_stationary) - G
    return float(jnp.linalg.norm(r) / jnp.linalg.norm(G))


@pytest.mark.parametrize("name", KERNELS)
def test_woodbury_residual(name, rng):
    spec, X, G, c = setup(name, rng)
    f = build_factors(spec, X, lam=LAM, c=c)
    Z = woodbury_solve(spec, f, G)
    assert relres(spec, f, Z, G) < 1e-7


@pytest.mark.parametrize("name", ["rbf", "rq", "expdot"])
def test_woodbury_matches_dense_solve(name, rng):
    spec, X, G, c = setup(name, rng)
    f = build_factors(spec, X, lam=LAM, c=c)
    Z = woodbury_solve(spec, f, G)
    Zd = dense_solve(spec, X, G, lam=LAM, c=c)
    assert jnp.max(jnp.abs(Z - Zd)) / jnp.max(jnp.abs(Zd)) < 1e-6


@pytest.mark.parametrize("name", ["rbf", "poly2", "expdot"])
def test_cg_residual(name, rng):
    spec, X, G, c = setup(name, rng)
    f = build_factors(spec, X, lam=LAM, c=c)
    res = gram_cg_solve(spec, f, G, tol=1e-10)
    assert relres(spec, f, res.x, G) < 1e-8


def test_cg_preconditioning_helps(rng):
    spec = get_kernel("rbf")
    X = jax.random.normal(rng, (8, 40)) * 3.0
    G = jax.random.normal(jax.random.fold_in(rng, 2), (8, 40))
    f = build_factors(spec, X, lam=0.05, noise=1e-8)
    it_pc = int(gram_cg_solve(spec, f, G, tol=1e-8, precondition=True).iters)
    it_np = int(gram_cg_solve(spec, f, G, tol=1e-8, precondition=False).iters)
    assert it_pc <= it_np, (it_pc, it_np)


def test_woodbury_with_noise(rng):
    spec = get_kernel("rbf")
    X = jax.random.normal(rng, (N, D))
    G = jax.random.normal(jax.random.fold_in(rng, 2), (N, D))
    f = build_factors(spec, X, lam=LAM, noise=0.1)
    Z = woodbury_solve(spec, f, G)
    Zd = dense_solve(spec, X, G, lam=LAM, noise=0.1)
    assert jnp.max(jnp.abs(Z - Zd)) / jnp.max(jnp.abs(Zd)) < 1e-8


def test_poly2_fast_path_is_valid_solution(rng):
    """Sec. 4.2 closed form: O(N^3) path solves the same system."""
    spec = get_kernel("poly2")
    A = np.random.RandomState(0).randn(D, D)
    A = jnp.asarray(A @ A.T + 0.5 * np.eye(D))
    xstar = jax.random.normal(jax.random.fold_in(rng, 7), (D,))
    c = jnp.zeros((D,))
    X = jax.random.normal(jax.random.fold_in(rng, 8), (N, D))
    G = (X - xstar) @ A.T
    g_c = A @ (c - xstar)
    f = build_factors(spec, X, lam=LAM, c=c)
    Zf = poly2_quadratic_solve(f, G, g_c=g_c)
    assert relres(spec, f, Zf, G - g_c) < 1e-8


def test_complexity_structure_never_materializes_gram(rng):
    """O(N^2 + ND) storage claim: factors hold only small matrices."""
    spec = get_kernel("rbf")
    X = jax.random.normal(rng, (4, 512))
    f = build_factors(spec, X, lam=0.01)
    sizes = {k: np.prod(np.asarray(v).shape) for k, v in f._asdict().items()
             if hasattr(v, "shape") and v is not None}
    assert max(sizes.values()) <= 4 * 512     # nothing (ND)^2-sized

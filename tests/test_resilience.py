"""Resilience subsystem tests (DESIGN.md sec. 17).

Deterministic coverage of the fault-tolerance stack:

  * typed error taxonomy + admission guardrails (single / fleet paths);
  * the jitter-escalation ladder healing a poisoned Cholesky;
  * the CG-divergence watchdog falling back to the exact solver;
  * the bf16-drift trip-wire re-casting from the f32 masters;
  * snapshot/restore roundtrips for all three state flavors (fleet
    elastic repack included; sharded same-mesh in a subprocess);
  * the op journal (torn tail vs torn interior, digest verification);
  * serve-loop hardening: shedding, deadlines, bounded retry, quarantine,
    degraded queries;
  * the zero-cost contract: guardrails on/off leave the serve jaxprs
    byte-identical.

The randomized crash/restore trajectories live in
tests/test_property_invariants.py (hypothesis over fuzz_machine's
``check_recovery_*``); this file is the always-on pinned suite.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fleet import GPFleet
from repro.core.state import GPGState
from repro.resilience import (ChaosInjector, Journal, errors, guardrails,
                              replay_single, restore, take_snapshot)
from repro.runtime.recovery import SimulatedFailure
from repro.train.serve import GPFleetServer, build_gp_serve_step


def _mk_state(d=4, window=4, **kw):
    kw.setdefault("noise", 1e-6)
    st = GPGState("rbf", d, window=window, **kw)
    r = np.random.RandomState(0)
    for _ in range(3):
        st.extend(r.randn(d), r.randn(d))
    return st


# ---------------------------------------------------------------------------
# Error taxonomy
# ---------------------------------------------------------------------------


def test_error_taxonomy_types():
    """Every typed failure is a ResilienceError; the two compatibility
    bridges (ValueError / NotImplementedError) hold for legacy callers."""
    assert issubclass(errors.NonFiniteObservationError, errors.ResilienceError)
    assert issubclass(errors.NonFiniteObservationError, ValueError)
    assert issubclass(errors.UnsupportedQueryError, NotImplementedError)
    for name in ("DeadlineExceededError", "QueueOverloadError",
                 "RetryExhaustedError", "TenantQuarantinedError",
                 "JournalCorruptionError"):
        assert issubclass(getattr(errors, name), errors.ResilienceError)
    shed = errors.ShedResponse(reason="queue_full", queue_depth=9)
    assert shed.queue_depth == 9 and not isinstance(shed, Exception)


# ---------------------------------------------------------------------------
# Admission guardrails
# ---------------------------------------------------------------------------


def test_single_state_rejects_nonfinite_admission():
    st = _mk_state()
    before = np.asarray(st.data.L).copy()
    x = np.ones(4)
    x[2] = np.nan
    with pytest.raises(errors.NonFiniteObservationError):
        st.extend(x, np.ones(4))
    # the poison never touched a factor
    assert np.array_equal(np.asarray(st.data.L), before)
    assert st.n == 3


def test_fleet_rejects_nonfinite_admission():
    fl = GPFleet("rbf", d=3, batch=2, window=4)
    fl.join("a")
    fl.join("b")
    fl.extend({"a": (np.ones(3), np.ones(3))})
    bad = np.array([1.0, np.inf, 0.0])
    with pytest.raises(errors.NonFiniteObservationError):
        fl.extend({"a": (np.ones(3), np.ones(3)), "b": (bad, np.ones(3))})
    assert fl.n("a") == 1 and fl.n("b") == 0   # whole group rejected


def test_guardrails_disabled_admits_anything():
    with guardrails.use_guardrails(False):
        st = _mk_state()
        x = np.ones(4)
        x[0] = np.nan
        st.extend(x, np.ones(4))        # no admission check: NaN goes in
        assert st.n == 4


# ---------------------------------------------------------------------------
# Jitter ladder / factor healing
# ---------------------------------------------------------------------------


def test_heal_ladder_recovers_poisoned_factor():
    st = _mk_state()
    want_Z = np.asarray(st.Z).copy()
    st.data = st.data._replace(L=jnp.full_like(st.data.L, jnp.nan),
                               resnorm=jnp.asarray(jnp.nan, st.data.resnorm.dtype))
    assert not guardrails.factor_ok(st)
    rung = guardrails.heal_factorization(st)
    assert rung == 0                    # masters were fine: plain refactor
    assert guardrails.factor_ok(st)
    np.testing.assert_allclose(np.asarray(st.Z), want_Z, rtol=1e-8)


def test_extend_self_heals_after_poison():
    """The post-mutation watchdog inside extend() heals a factor poisoned
    BETWEEN ops — the stream keeps going with correct answers."""
    st = _mk_state()
    inj = ChaosInjector(seed=3, rates={"degenerate_factor": 1.0})
    assert inj.poison_factor(st)
    r = np.random.RandomState(7)
    st.extend(r.randn(4), r.randn(4))   # watchdog fires in here
    assert guardrails.factor_ok(st)
    # the healed trajectory matches a clean rebuild of the same window
    clean = GPGState.from_data("rbf", st.X, st.G, noise=st.noise)
    np.testing.assert_allclose(np.asarray(st.Z), np.asarray(clean.Z),
                               rtol=1e-6, atol=1e-8)


def test_heal_gives_up_and_restores_jitter():
    """A state whose MASTERS are poisoned cannot be healed by jitter —
    the ladder gives up, restores the base jitter, and does not raise."""
    st = _mk_state()
    st.data = st.data._replace(X=jnp.full_like(st.data.X, jnp.nan))
    base = st.jitter
    assert guardrails.heal_factorization(st, max_rungs=2) == -1
    assert st.jitter == base


# ---------------------------------------------------------------------------
# CG-divergence watchdog
# ---------------------------------------------------------------------------


def test_cg_divergence_predicate():
    assert guardrails.cg_diverged(np.nan, 1.0)
    assert guardrails.cg_diverged(np.inf, 1.0)
    assert guardrails.cg_diverged(100.0, 1.0)
    assert not guardrails.cg_diverged(1e-9, 1.0)
    assert not guardrails.cg_diverged(5.0, 1.0)   # large-but-sane: no trip


def test_regime_solve_falls_back_on_poisoned_warm_start():
    from repro.core import build_factors, dense_solve, get_kernel
    from repro.regime import solve

    spec = get_kernel("rbf")
    r = np.random.RandomState(1)
    n, d = 9, 4                         # n > d: the iterative regime
    X, G = r.randn(n, d), r.randn(n, d)
    f = build_factors(spec, X, lam=0.7, noise=1e-6)
    inj = ChaosInjector(seed=0)
    z0 = inj.poison_warm_start((n, d))
    Z, info = solve(spec, f, G, policy="iterative", z0=z0, maxiter=4)
    assert info["fallback"] is True and info["regime"] == "exact"
    want = dense_solve(spec, X, G, lam=0.7, noise=1e-6, jitter=0.0)
    np.testing.assert_allclose(np.asarray(Z), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_regime_solve_no_fallback_on_healthy_solve():
    from repro.core import build_factors, get_kernel
    from repro.regime import solve

    spec = get_kernel("rbf")
    r = np.random.RandomState(2)
    X, G = r.randn(9, 4), r.randn(9, 4)
    f = build_factors(spec, X, lam=0.7, noise=1e-6)
    _, info = solve(spec, f, G, policy="iterative")
    assert info["regime"] == "iterative" and info["fallback"] is False


# ---------------------------------------------------------------------------
# bf16 trip-wire
# ---------------------------------------------------------------------------


def test_bf16_tripwire_recaches_poisoned_stream():
    st = _mk_state(precision="bf16")
    _ = st.stream_factors               # materialize the bf16 cache
    rev, f = st._stream_cache[0], st._stream_cache[1]
    st._stream_cache = (rev, f._replace(
        Xt=jnp.full_like(f.Xt, jnp.nan)),) + tuple(st._stream_cache[2:])
    assert guardrails.bf16_tripwire(st)
    assert st._stream_cache is None     # next query re-casts from masters
    f2, _ = st.stream_factors
    assert bool(jnp.all(jnp.isfinite(f2.Xt.astype(jnp.float32))))


def test_bf16_tripwire_quiet_on_healthy_stream():
    st = _mk_state(precision="bf16")
    _ = st.stream_factors
    assert not guardrails.bf16_tripwire(st)
    assert st._stream_cache is not None


# ---------------------------------------------------------------------------
# Snapshot / restore roundtrips
# ---------------------------------------------------------------------------


def _assert_same_leaves(a, b, fields=("X", "G", "Xt", "K1e", "K2e", "L",
                                      "Z", "lam", "count")):
    for f in fields:
        assert np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f))), f


def test_snapshot_restore_single_bitwise(tmp_path):
    st = _mk_state()
    take_snapshot(st, str(tmp_path), step=1)
    back = restore(str(tmp_path))
    _assert_same_leaves(st.data, back.data)
    assert (back.noise, back.window, back.revision, back.factor_revision) \
        == (st.noise, st.window, st.revision, st.factor_revision)
    # the restored state keeps streaming correctly
    r = np.random.RandomState(5)
    x, g = r.randn(4), r.randn(4)
    st.extend(x, g)
    back.extend(x, g)
    _assert_same_leaves(st.data, back.data)


def test_snapshot_restore_compressed_state(tmp_path):
    """A compressed state persists its reduction frame + raw copies and
    keeps answering queries (and degrading grad_std) after restore."""
    d, window = 6, 3
    st = GPGState("rbf", d, window=window, noise=1e-6, policy="compress")
    r = np.random.RandomState(3)
    base = r.randn(d)
    for _ in range(window + 2):         # overflow the window -> compress
        t = r.randn(2)
        x = base + t[0] * np.eye(d)[0] + t[1] * np.eye(d)[1]
        g = r.randn(d)
        st.extend(x, g)
    assert st._reduction is not None
    take_snapshot(st, str(tmp_path), step=7)
    back = restore(str(tmp_path))
    assert back._reduction is not None
    Xq = np.stack([base + 0.1 * np.eye(d)[0], base + 0.2 * np.eye(d)[1]])
    a, b = st.posterior(Xq), back.posterior(Xq)
    assert np.array_equal(np.asarray(a.value), np.asarray(b.value))
    assert np.array_equal(np.asarray(a.grad), np.asarray(b.grad))
    with pytest.raises(errors.UnsupportedQueryError):
        back.posterior(Xq, return_std=True, return_grad_std=True)


def test_snapshot_restore_fleet_elastic(tmp_path):
    fl = GPFleet("rbf", d=3, batch=2, window=3)
    r = np.random.RandomState(11)
    for t in ("x", "y"):
        fl.join(t)
    for _ in range(2):
        fl.extend({t: (r.randn(3), r.randn(3)) for t in ("x", "y")})
    take_snapshot(fl, str(tmp_path), step=2)
    for target in (2, 4):               # same packing, then elastic
        back = restore(str(tmp_path), batch=target)
        assert back.batch == target
        for t in ("x", "y"):
            _assert_same_leaves(fl.state_view(t), back.state_view(t))
        assert back.hypers_of("x") == fl.hypers_of("x")
    with pytest.raises(ValueError):
        restore(str(tmp_path), batch=1)  # 2 tenants cannot pack into 1


def test_restore_skips_corrupt_snapshot(tmp_path):
    from repro.checkpoint import manifest_index

    st = _mk_state()
    take_snapshot(st, str(tmp_path), step=1)
    st.extend(np.ones(4), np.ones(4))
    take_snapshot(st, str(tmp_path), step=2)
    idx = manifest_index(str(tmp_path), 2)
    leaf = tmp_path / "step_000000002" / idx["L"]["file"]
    leaf.write_bytes(leaf.read_bytes()[:-32])     # torn write
    back = restore(str(tmp_path))       # falls back to step 1
    assert back.n == 3


def test_sharded_snapshot_restore_subprocess(tmp_path):
    """Sharded flavor: snapshot on a 4-device mesh, restore on the SAME
    mesh shape bitwise, and on a 2-device mesh to exact values (the
    D-leaves are stored trimmed and re-padded per mesh)."""
    import subprocess
    import sys

    src = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
import numpy as np
from repro.core.dist_state import ShardedGPGState
from repro.resilience import restore, take_snapshot
r = np.random.RandomState(0)
d, cap = 6, 3
if %d == 4:
    st = ShardedGPGState("rbf", d, capacity=cap, noise=1e-6)
    for _ in range(3):
        st.extend(r.randn(d), r.randn(d))
    take_snapshot(st, {str(str(tmp_path))!r}, step=1)
    np.save({str(str(tmp_path))!r} + "/want.npy", st.snapshot_arrays()["Z"])
else:
    back = restore({str(str(tmp_path))!r})
    want = np.load({str(str(tmp_path))!r} + "/want.npy")
    got = back.snapshot_arrays()["Z"]   # mesh-independent logical leaves
    assert np.array_equal(got, want), np.max(np.abs(got - want))
    xq = np.random.RandomState(1).randn(2, d)
    back.posterior(xq)                 # restored state still serves
print("OK")
"""
    for n in (4, 4, 2):
        code = src % (n, n)
        r = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True,
            timeout=600,
            env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                 "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")})
        assert "OK" in r.stdout, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# Journal
# ---------------------------------------------------------------------------


def test_journal_roundtrip_and_replay(tmp_path):
    st = _mk_state()
    jpath = str(tmp_path / "ops.jsonl")
    take_snapshot(st, str(tmp_path), step=0, journal=Journal(jpath))
    j = Journal(jpath)
    r = np.random.RandomState(9)
    x, g = r.randn(4), r.randn(4)
    st.extend(x, g)
    j.record("extend", payload={"x": x, "g": g})
    st.evict()
    j.record("evict", args={"k": 1})
    back = restore(str(tmp_path))
    replay_single(back, Journal.since_snapshot(Journal.read(jpath)))
    _assert_same_leaves(st.data, back.data)


def test_journal_torn_tail_dropped_torn_interior_raises(tmp_path):
    p = str(tmp_path / "j.jsonl")
    j = Journal(p)
    j.record("extend", payload={"x": np.ones(2), "g": np.ones(2)})
    j.record("evict", args={"k": 1})
    with open(p, "a") as f:
        f.write('{"op": "ext')         # crash mid-append
    entries = Journal.read(p)           # torn TAIL: safely dropped
    assert [e["op"] for e in entries] == ["extend", "evict"]
    lines = open(p).read().splitlines()
    lines[0] = lines[0][:-5]            # torn INTERIOR: corruption
    open(p, "w").write("\n".join(lines) + "\n")
    with pytest.raises(errors.JournalCorruptionError):
        Journal.read(p)


def test_journal_digest_catches_tamper(tmp_path):
    import json

    p = str(tmp_path / "j.jsonl")
    Journal(p).record("extend", payload={"x": np.ones(3), "g": np.ones(3)})
    e = json.loads(open(p).read())
    e["payload"]["x"][0] = 2.0          # silent bit-flip
    from repro.resilience.journal import decode_payload

    with pytest.raises(errors.JournalCorruptionError):
        decode_payload(e)


# ---------------------------------------------------------------------------
# Serve-loop hardening
# ---------------------------------------------------------------------------


def _server(**kw):
    srv = GPFleetServer(kernel="rbf", d=3, **kw)
    srv.connect("t0")
    return srv


def test_server_load_shedding():
    from repro.configs.paper_gp import GPFleetConfig

    srv = _server(config=GPFleetConfig(max_queue=2))
    r = np.random.RandomState(0)
    reqs = [srv.submit("t0", "extend", (r.randn(3), r.randn(3)))
            for _ in range(4)]
    shed = [q for q in reqs if isinstance(q.result, errors.ShedResponse)]
    assert len(shed) == 2 and all(q.done for q in shed)
    assert shed[0].result.reason == "queue_full"
    srv.drain()
    assert all(q.done for q in reqs)


def test_server_deadline_expiry():
    from repro.configs.paper_gp import GPFleetConfig

    srv = _server(config=GPFleetConfig(deadline_steps=2))
    req = srv.submit("t0", "query", np.zeros((1, 3)))
    req.not_before = 10**9              # park it (a stuck dependency)
    for _ in range(4):
        srv.step()
    assert req.done
    assert isinstance(req.result, errors.DeadlineExceededError)


def test_server_retry_then_exhaustion():
    from repro.configs.paper_gp import GPFleetConfig

    r = np.random.RandomState(1)
    # one injected kill: absorbed by a retry
    srv = _server(injector=ChaosInjector(seed=0, rates={"kill_step": 1.0},
                                         max_faults=1))
    req = srv.submit("t0", "extend", (r.randn(3), r.randn(3)))
    srv.drain()
    assert req.done and req.result is None and req.attempts == 1
    assert srv.fleet.n("t0") == 1
    # unbounded kills: the retry budget runs out, typed failure
    srv2 = _server(config=GPFleetConfig(max_retries=1),
                   injector=ChaosInjector(seed=0,
                                          rates={"kill_step": 1.0}))
    req2 = srv2.submit("t0", "extend", (r.randn(3), r.randn(3)))
    srv2.drain(max_steps=64)
    assert req2.done
    assert isinstance(req2.result, errors.RetryExhaustedError)
    assert srv2.fleet.n("t0") == 0      # the op never half-applied


def test_server_quarantines_poison_tenant():
    srv = _server(injector=ChaosInjector(seed=0,
                                         rates={"nan_payload": 1.0}))
    srv.connect("ok")
    r = np.random.RandomState(2)
    for _ in range(3):                  # quarantine_threshold defaults to 3
        q = srv.submit("t0", "extend", (r.randn(3), r.randn(3)))
        assert isinstance(q.result, errors.NonFiniteObservationError)
    assert "t0" not in srv.tenants and "ok" in srv.tenants
    with pytest.raises(errors.TenantQuarantinedError):
        srv.submit("t0", "query", np.zeros((1, 3)))
    with pytest.raises(errors.TenantQuarantinedError):
        srv.connect("t0")
    # the healthy tenant is untouched
    inj = srv.injector
    srv.injector = None
    ok = srv.submit("ok", "extend", (r.randn(3), r.randn(3)))
    srv.drain()
    assert ok.done and srv.fleet.n("ok") == 1
    assert inj.injected["nan_payload"] == 3


def test_server_straggler_expires_via_deadline():
    from repro.configs.paper_gp import GPFleetConfig

    srv = _server(config=GPFleetConfig(deadline_steps=3),
                  injector=ChaosInjector(seed=0,
                                         rates={"straggler": 1.0}))
    req = srv.submit("t0", "query", np.zeros((1, 3)))
    assert req.chaos_kind == "straggler"
    for _ in range(6):
        srv.step()
    assert isinstance(req.result, errors.DeadlineExceededError)


def test_degraded_grad_std_query_on_compressed_state():
    """Satellite 1: a grad_std serve bundle over a state that compressed
    mid-stream degrades to grad_std=None instead of dying."""
    d, window = 6, 3
    st = GPGState("rbf", d, window=window, noise=1e-6, policy="compress")
    bundle = build_gp_serve_step(st, microbatch=2, return_std=True,
                                 return_grad_std=True)
    r = np.random.RandomState(4)
    base = r.randn(d)
    for _ in range(window + 2):
        t = r.randn(2)
        st.extend(base + t[0] * np.eye(d)[0] + t[1] * np.eye(d)[1],
                  r.randn(d))
    assert st._reduction is not None
    out = bundle.query(np.stack([base, base + 0.1 * np.eye(d)[0]]))
    assert out.value.shape == (2,)
    assert out.grad_std is None         # degraded, typed + counted
    assert out.std is not None


# ---------------------------------------------------------------------------
# Zero-cost contract
# ---------------------------------------------------------------------------


def test_guardrails_zero_cost_jaxpr_identity():
    """The compiled serve/extend programs are byte-identical with
    guardrails on or off — every guardrail runs on the host."""
    from repro.core import get_kernel
    from repro.core.query import make_query_fn
    from repro.core.state import gpg_extend, gpg_init

    spec = get_kernel("rbf")
    data = gpg_init(spec, 4, 4)
    x = jnp.ones(4)
    st = _mk_state()
    f, Z = st.padded_factors, st.data.Z
    Xq = jnp.ones((2, 4))

    def trace_pair(make):
        with guardrails.use_guardrails(False):
            off = str(jax.make_jaxpr(make())(*args))
        with guardrails.use_guardrails(True):
            on = str(jax.make_jaxpr(make())(*args))
        return off, on

    args = (data, x, x)
    off, on = trace_pair(
        lambda: (lambda d_, x_, g_: gpg_extend(spec, d_, x_, g_,
                                               noise=1e-8)))
    assert off == on
    args = (f, Z, Xq)
    off, on = trace_pair(lambda: make_query_fn(spec))
    assert off == on


def test_guardrails_idle_no_counters():
    """A healthy trajectory with guardrails on trips NOTHING — no heals,
    no escalations, no recoveries (the watchdog is non-finite-only)."""
    from repro.obs import trace as obs_trace

    reg = obs_trace.REGISTRY
    before = {k: reg.snapshot()["counters"].get(k, 0)
              for k in ("resilience.factor_faults",
                        "resilience.jitter_escalations",
                        "resilience.faults_recovered")}
    st = _mk_state(d=3, window=5)
    r = np.random.RandomState(8)
    for _ in range(6):
        st.extend(r.randn(3), r.randn(3))
    st.posterior(r.randn(2, 3))
    after = reg.snapshot()["counters"]
    for k, v in before.items():
        assert after.get(k, 0) == v, k

"""Per-Pallas-kernel validation: shape/dtype sweeps vs the ref.py oracles.

Kernels run in interpret mode on CPU (the body executes in Python), which
validates the BlockSpec indexing, accumulation, and padding contracts that
the TPU build relies on.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import (fused_gram_mvm, fused_gram_mvm_multi,
                           fused_gram_mvm_ref, fused_gram_norms,
                           fused_gram_norms_ref, gram_update, gram_update_ref,
                           skinny_gram, skinny_gram_ref)
from repro.kernels.ops import _LANE, _pick_block_d, _round_up

SHAPES = [(3, 5, 64), (8, 8, 128), (5, 12, 1000), (16, 4, 4096), (1, 1, 257)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _rand(rng, shape, dtype):
    return jax.random.normal(rng, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("na,nb,d", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("lam_kind", ["scalar", "diag"])
def test_skinny_gram(na, nb, d, dtype, lam_kind, rng):
    A = _rand(jax.random.fold_in(rng, 1), (na, d), dtype)
    B = _rand(jax.random.fold_in(rng, 2), (nb, d), dtype)
    lam = 0.3 if lam_kind == "scalar" else \
        jnp.abs(jax.random.normal(jax.random.fold_in(rng, 3), (d,))) + 0.1
    got = skinny_gram(A, B, lam, interpret=True)
    want = skinny_gram_ref(A, B, lam)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    assert jnp.allclose(got, want, rtol=tol, atol=tol * 10), \
        float(jnp.max(jnp.abs(got - want)))


@pytest.mark.parametrize("n,d", [(4, 128), (8, 1000), (12, 4096)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_gram_update(n, d, dtype, rng):
    K1 = _rand(jax.random.fold_in(rng, 1), (n, n), jnp.float32)
    M = _rand(jax.random.fold_in(rng, 2), (n, n), jnp.float32)
    V = _rand(jax.random.fold_in(rng, 3), (n, d), dtype)
    X = _rand(jax.random.fold_in(rng, 4), (n, d), dtype)
    got = gram_update(K1, M, V, X, 0.5, interpret=True)
    want = gram_update_ref(K1, M, V, X, 0.5)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    assert jnp.allclose(got.astype(jnp.float32), want.astype(jnp.float32),
                        rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("na,nb,d", [(3, 5, 64), (8, 8, 2048), (2, 9, 333)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_gram_norms(na, nb, d, dtype, rng):
    A = _rand(jax.random.fold_in(rng, 1), (na, d), dtype)
    B = _rand(jax.random.fold_in(rng, 2), (nb, d), dtype)
    lam = 0.7
    P, na_o, nb_o = fused_gram_norms(A, B, lam, interpret=True)
    Pr, nar, nbr = fused_gram_norms_ref(A, B, lam)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    assert jnp.allclose(P, Pr, rtol=tol, atol=tol * 10)
    assert jnp.allclose(na_o, nar[:, 0], rtol=tol, atol=tol * 10)
    assert jnp.allclose(nb_o, nbr[:, 0], rtol=tol, atol=tol * 10)


def test_skinny_gram_padding_exact(rng):
    """Zero-padded lam must kill padded columns EXACTLY (not approximately):
    a D=1000 input equals the same data embedded in D=1024 with garbage in
    the pad lanes but lam = 0 there — bit-identical through the kernel."""
    A = jax.random.normal(jax.random.fold_in(rng, 1), (4, 1000))
    B = jax.random.normal(jax.random.fold_in(rng, 2), (6, 1000))
    got = skinny_gram(A, B, 1.0, interpret=True)
    junk = 1e6 * jax.random.normal(jax.random.fold_in(rng, 3), (4 + 6, 24))
    A2 = jnp.concatenate([A, junk[:4]], axis=1)
    B2 = jnp.concatenate([B, junk[4:]], axis=1)
    lam2 = jnp.concatenate([jnp.ones(1000), jnp.zeros(24)])
    embedded = skinny_gram(A2, B2, lam2, interpret=True)
    assert jnp.array_equal(got, embedded)
    # and the f32-accumulated kernel tracks the oracle at f32 tolerance
    assert jnp.allclose(got, skinny_gram_ref(A, B, 1.0), rtol=1e-5, atol=1e-4)


def test_kernels_used_by_core_path(rng):
    """The kernels compute the same contraction core/gram.scaled_gram uses."""
    from repro.core import scaled_gram

    A = jax.random.normal(jax.random.fold_in(rng, 1), (5, 300))
    lam = 0.3
    got = skinny_gram(A, A, lam, interpret=True)
    want = scaled_gram(A, A, lam)
    assert jnp.allclose(got, want.astype(jnp.float32), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Fused single-pass Alg.-2 megakernel
# ---------------------------------------------------------------------------

MVM_SHAPES = [(3, 257), (8, 128), (5, 1000), (12, 1025)]


@pytest.mark.parametrize("n,d", MVM_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("stationary", [False, True])
@pytest.mark.parametrize("lam_kind", ["scalar", "diag"])
def test_fused_gram_mvm(n, d, dtype, stationary, lam_kind, rng):
    K1e = _rand(jax.random.fold_in(rng, 1), (n, n), jnp.float32)
    K2e = _rand(jax.random.fold_in(rng, 2), (n, n), jnp.float32)
    Xt = _rand(jax.random.fold_in(rng, 3), (n, d), dtype)
    V = _rand(jax.random.fold_in(rng, 4), (n, d), dtype)
    lam = 0.4 if lam_kind == "scalar" else \
        jnp.abs(jax.random.normal(jax.random.fold_in(rng, 5), (d,))) + 0.1
    noise = 0.25
    got = fused_gram_mvm(K1e, K2e, Xt, V, lam, stationary=stationary,
                         noise=noise, interpret=True)
    want = fused_gram_mvm_ref(K1e, K2e, Xt, V, lam, stationary=stationary,
                              noise=noise)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    err = jnp.max(jnp.abs(got.astype(jnp.float32) - want.astype(jnp.float32)))
    scale = jnp.max(jnp.abs(want.astype(jnp.float32))) + 1e-6
    assert err / scale < tol, float(err / scale)


@pytest.mark.parametrize("stationary", [False, True])
@pytest.mark.parametrize("r", [1, 3])
def test_fused_gram_mvm_multi(stationary, r, rng):
    n, d = 5, 333
    K1e = _rand(jax.random.fold_in(rng, 1), (n, n), jnp.float32)
    K2e = _rand(jax.random.fold_in(rng, 2), (n, n), jnp.float32)
    Xt = _rand(jax.random.fold_in(rng, 3), (n, d), jnp.float32)
    Vs = _rand(jax.random.fold_in(rng, 4), (r, n, d), jnp.float32)
    got = fused_gram_mvm_multi(K1e, K2e, Xt, Vs, 0.6, stationary=stationary,
                               noise=0.1, interpret=True)
    # stacked kernel == per-RHS single kernel == per-RHS oracle
    for i in range(r):
        single = fused_gram_mvm(K1e, K2e, Xt, Vs[i], 0.6,
                                stationary=stationary, noise=0.1,
                                interpret=True)
        want = fused_gram_mvm_ref(K1e, K2e, Xt, Vs[i], 0.6,
                                  stationary=stationary, noise=0.1)
        assert jnp.allclose(got[i], single, rtol=1e-5, atol=1e-4)
        assert jnp.allclose(got[i], want, rtol=1e-4, atol=1e-3)


def test_gram_update_v_scale_noise(rng):
    """The v_scale/noise extension used by Woodbury's fused assembly."""
    n, d = 6, 300
    K1 = _rand(jax.random.fold_in(rng, 1), (n, n), jnp.float32)
    M = _rand(jax.random.fold_in(rng, 2), (n, n), jnp.float32)
    V = _rand(jax.random.fold_in(rng, 3), (n, d), jnp.float32)
    X = _rand(jax.random.fold_in(rng, 4), (n, d), jnp.float32)
    vs = jnp.abs(jax.random.normal(jax.random.fold_in(rng, 5), (d,))) + 0.2
    got = gram_update(K1, M, V, X, 0.9, v_scale=vs, noise=0.3, interpret=True)
    want = gram_update_ref(K1, M, V, X, 0.9, v_scale=vs, noise=0.3)
    assert jnp.allclose(got, want, rtol=1e-5, atol=1e-4)


def test_small_matmul(rng):
    """Kronecker-preconditioner stream: W = (K @ V) * scale."""
    from repro.kernels import small_matmul

    n, d = 6, 1000
    K = _rand(jax.random.fold_in(rng, 1), (n, n), jnp.float32)
    V = _rand(jax.random.fold_in(rng, 2), (n, d), jnp.float32)
    scale = jnp.abs(jax.random.normal(jax.random.fold_in(rng, 3), (d,))) + 0.1
    got = small_matmul(K, V, scale, interpret=True)
    want = (K @ V) * scale
    assert jnp.allclose(got, want, rtol=1e-5, atol=1e-4)


def test_gram_update_rectangular(rng):
    """Cross-covariance query path: K1/M are (Nq, N), W is (Nq, D)."""
    nq, n, d = 3, 6, 260
    K1 = _rand(jax.random.fold_in(rng, 1), (nq, n), jnp.float32)
    M = _rand(jax.random.fold_in(rng, 2), (nq, n), jnp.float32)
    V = _rand(jax.random.fold_in(rng, 3), (n, d), jnp.float32)
    X = _rand(jax.random.fold_in(rng, 4), (n, d), jnp.float32)
    got = gram_update(K1, M, V, X, 0.5, interpret=True)
    want = gram_update_ref(K1, M, V, X, 0.5)
    assert got.shape == (nq, d)
    assert jnp.allclose(got, want, rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# Precision policy: bf16-in / f32-accum tracks the f32 oracle <= 1e-3 rel
# on every fused entry point (same stored data: the comparison isolates
# what the PIPELINE adds — accumulation order, fusion — from the
# unavoidable bf16 storage quantization, which belongs to the data)
# ---------------------------------------------------------------------------

BF16_TOL = 1e-3


def _norm_rel(got, want):
    got = jnp.asarray(got, jnp.float64)
    want = jnp.asarray(want, jnp.float64)
    return float(jnp.linalg.norm(got - want) / (jnp.linalg.norm(want) + 1e-30))


def _bf16_case(rng, n, d, nq=None):
    mk = lambda k, shape: jax.random.normal(
        jax.random.fold_in(rng, k), shape, jnp.float32).astype(jnp.bfloat16)
    K = jax.random.normal(jax.random.fold_in(rng, 9), (nq or n, n),
                          jnp.float32)
    return mk(1, (n, d)), mk(2, (n, d)), K


@pytest.mark.parametrize("entry", [
    "skinny_gram", "gram_update", "small_matmul", "fused_gram_norms",
    "fused_gram_mvm", "fused_gram_mvm_multi", "fused_factor_build",
])
def test_bf16_in_f32_accum_tracks_f32_oracle(entry, rng):
    """kernel(bf16 storage) vs f32 oracle on the SAME stored values."""
    from repro.kernels import fused_factor_build, fused_factor_build_ref
    from repro.kernels import small_matmul

    n, d = 8, 4096
    X16, V16, K = _bf16_case(rng, n, d)
    X32, V32 = X16.astype(jnp.float32), V16.astype(jnp.float32)
    lam = 0.5
    if entry == "skinny_gram":
        got = [skinny_gram(X16, V16, lam, interpret=True)]
        want = [skinny_gram_ref(X32, V32, lam)]
    elif entry == "gram_update":
        M = jax.random.normal(jax.random.fold_in(rng, 8), (n, n), jnp.float32)
        got = [gram_update(K, M, V16, X16, lam, noise=0.1, interpret=True)]
        want = [gram_update_ref(K, M, V32, X32, lam, noise=0.1)]
    elif entry == "small_matmul":
        got = [small_matmul(K, V16, lam, interpret=True)]
        want = [(K @ V32) * lam]
    elif entry == "fused_gram_norms":
        got = list(fused_gram_norms(X16, V16, lam, interpret=True))
        want = [w.reshape(g.shape) for g, w in zip(
            got, fused_gram_norms_ref(X32, V32, lam))]
    elif entry in ("fused_gram_mvm", "fused_gram_mvm_multi"):
        K2 = 0.1 * jax.random.normal(jax.random.fold_in(rng, 7), (n, n),
                                     jnp.float32)
        if entry == "fused_gram_mvm":
            got = [fused_gram_mvm(K, K2, X16, V16, lam, stationary=True,
                                  noise=0.1, interpret=True)]
            want = [fused_gram_mvm_ref(K, K2, X32, V32, lam, stationary=True,
                                       noise=0.1)]
        else:
            Vs16 = jnp.stack([V16, X16])
            got = [fused_gram_mvm_multi(K, K2, X16, Vs16, lam,
                                        stationary=True, interpret=True)]
            want = [fused_gram_mvm_ref(K, K2, X32, Vs16.astype(jnp.float32),
                                       lam, stationary=True)]
    else:
        got = list(fused_factor_build(X16, X16, V16, lam, interpret=True))
        want = [w.reshape(g.shape) for g, w in zip(
            got, fused_factor_build_ref(X32, X32, V32, lam))]
    for g, w in zip(got, want):
        assert g.dtype == jnp.float32      # f32 outputs, never bf16 rounded
        assert _norm_rel(g, w) < BF16_TOL, (entry, _norm_rel(g, w))


# ---------------------------------------------------------------------------
# Fused single-sweep factor-build megakernel (see also test_fused_factor.py)
# ---------------------------------------------------------------------------

def test_fused_factor_build_single_launch(rng):
    """The whole factor bundle == exactly ONE pallas_call in the jaxpr."""
    from repro.kernels import fused_factor_build
    from repro.utils.hlo import count_primitive

    A = jax.random.normal(jax.random.fold_in(rng, 1), (5, 300), jnp.float32)
    B = jax.random.normal(jax.random.fold_in(rng, 2), (7, 300), jnp.float32)
    closed = jax.make_jaxpr(
        lambda a, b: fused_factor_build(a, b, None, 0.5, interpret=True))(A, B)
    assert count_primitive(closed.jaxpr, "pallas_call") == 1


# ---------------------------------------------------------------------------
# block_d selection: pad-waste bound + VMEM budget
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d", [130, 257, 1000, 1024, 1025, 4097, 65537,
                               1_000_001])
def test_pick_block_d_waste_bounded(d):
    """For D just above a block boundary the pad waste must stay bounded:
    either the lane-minimal padding is used, or waste < 12.5%."""
    block = _pick_block_d(d)
    assert block % _LANE == 0
    padded = _round_up(d, block)
    minimal = _round_up(d, _LANE)
    assert padded == minimal or (padded - d) / d <= 0.125, \
        (d, block, padded, (padded - d) / d)


def test_pick_block_d_vmem_budget():
    """Streamed double-buffered footprint must respect the VMEM budget."""
    budget = 1 << 20  # 1 MiB
    rows = 64
    block = _pick_block_d(1 << 20, 4096, stream_rows=rows,
                          vmem_budget_bytes=budget)
    assert 8 * rows * block <= budget
    # and with a roomy budget the block is not needlessly shrunk
    assert _pick_block_d(1 << 20, 1024, stream_rows=8) == 1024
    # resident operands alone blowing the budget is a clear error, not an
    # opaque Mosaic VMEM failure later
    with pytest.raises(ValueError, match="VMEM budget"):
        _pick_block_d(1 << 20, 1024, stream_rows=8,
                      resident_bytes=2 * budget, vmem_budget_bytes=budget)

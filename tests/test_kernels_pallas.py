"""Per-Pallas-kernel validation: shape/dtype sweeps vs the ref.py oracles.

Kernels run in interpret mode on CPU (the body executes in Python), which
validates the BlockSpec indexing, accumulation, and padding contracts that
the TPU build relies on.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import (fused_gram_norms, fused_gram_norms_ref,
                           gram_update, gram_update_ref, skinny_gram,
                           skinny_gram_ref)

SHAPES = [(3, 5, 64), (8, 8, 128), (5, 12, 1000), (16, 4, 4096), (1, 1, 257)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _rand(rng, shape, dtype):
    return jax.random.normal(rng, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("na,nb,d", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("lam_kind", ["scalar", "diag"])
def test_skinny_gram(na, nb, d, dtype, lam_kind, rng):
    A = _rand(jax.random.fold_in(rng, 1), (na, d), dtype)
    B = _rand(jax.random.fold_in(rng, 2), (nb, d), dtype)
    lam = 0.3 if lam_kind == "scalar" else \
        jnp.abs(jax.random.normal(jax.random.fold_in(rng, 3), (d,))) + 0.1
    got = skinny_gram(A, B, lam, interpret=True)
    want = skinny_gram_ref(A, B, lam)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    assert jnp.allclose(got, want, rtol=tol, atol=tol * 10), \
        float(jnp.max(jnp.abs(got - want)))


@pytest.mark.parametrize("n,d", [(4, 128), (8, 1000), (12, 4096)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_gram_update(n, d, dtype, rng):
    K1 = _rand(jax.random.fold_in(rng, 1), (n, n), jnp.float32)
    M = _rand(jax.random.fold_in(rng, 2), (n, n), jnp.float32)
    V = _rand(jax.random.fold_in(rng, 3), (n, d), dtype)
    X = _rand(jax.random.fold_in(rng, 4), (n, d), dtype)
    got = gram_update(K1, M, V, X, 0.5, interpret=True)
    want = gram_update_ref(K1, M, V, X, 0.5)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    assert jnp.allclose(got.astype(jnp.float32), want.astype(jnp.float32),
                        rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("na,nb,d", [(3, 5, 64), (8, 8, 2048), (2, 9, 333)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_gram_norms(na, nb, d, dtype, rng):
    A = _rand(jax.random.fold_in(rng, 1), (na, d), dtype)
    B = _rand(jax.random.fold_in(rng, 2), (nb, d), dtype)
    lam = 0.7
    P, na_o, nb_o = fused_gram_norms(A, B, lam, interpret=True)
    Pr, nar, nbr = fused_gram_norms_ref(A, B, lam)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    assert jnp.allclose(P, Pr, rtol=tol, atol=tol * 10)
    assert jnp.allclose(na_o, nar[:, 0], rtol=tol, atol=tol * 10)
    assert jnp.allclose(nb_o, nbr[:, 0], rtol=tol, atol=tol * 10)


def test_skinny_gram_padding_exact(rng):
    """Zero-padded lam must kill padded columns EXACTLY (not approximately):
    compare a D=1000 input against the same data embedded in D=1024."""
    A = jax.random.normal(jax.random.fold_in(rng, 1), (4, 1000))
    B = jax.random.normal(jax.random.fold_in(rng, 2), (6, 1000))
    got = skinny_gram(A, B, 1.0, interpret=True)
    want = skinny_gram_ref(A, B, 1.0)
    assert jnp.allclose(got, want, rtol=1e-6, atol=1e-6)


def test_kernels_used_by_core_path(rng):
    """The kernels compute the same contraction core/gram.scaled_gram uses."""
    from repro.core import scaled_gram

    A = jax.random.normal(jax.random.fold_in(rng, 1), (5, 300))
    lam = 0.3
    got = skinny_gram(A, A, lam, interpret=True)
    want = scaled_gram(A, A, lam)
    assert jnp.allclose(got, want.astype(jnp.float32), rtol=1e-5, atol=1e-5)

"""Model selection & uncertainty subsystem (repro/hyper) contract tests.

The acceptance properties of the subsystem:

  * ``hyper.mll`` equals the dense ``jnp.linalg.slogdet`` + solve oracle
    to <= 1e-5 for small N*D, for BOTH kernel families (dot incl. a
    nonzero center, stationary), across noise/signal settings.
  * ``jax.grad(mll)`` w.r.t. log-lengthscale/log-signal/log-noise matches
    central finite differences.
  * structurally no (ND, ND) array in the mll (or grad-mll) jaxpr.
  * posterior variance is non-negative, ~0 for gradient components at
    training inputs as noise -> 0, and matches the dense posterior
    covariance diagonal to <= 1e-4 (value and gradient queries, both
    through ``posterior_batch(return_std=...)`` and the raw variance API).
  * ``fit()`` on the Fig.-3 Rosenbrock surrogate improves the MLL over
    the ``auto_lengthscale`` heuristic init.
  * the serving integrations hold: ``GPGState.mll/refit``, the HyperParams
    plumbing of gpg_hmc, and the compile-stability of the std serve step
    across extend() AND refit() (hypers are dynamic arguments).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import GPGState, build_factors, dense_gram, get_kernel
from repro.core.query import posterior_batch
from repro.hyper import (HyperParams, StructureError, assert_no_dense_gram,
                         fit, fit_scan, grad_var, make_solver, mll,
                         mll_dense, value_var)

# (name, center): both families, dot with and without centering
CASES = [("rbf", None), ("rq", None), ("matern52", None),
         ("expdot", None), ("expdot", 0.3), ("poly3", 0.1)]


def _data(rng, n, d, fold=0):
    X = jax.random.normal(jax.random.fold_in(rng, 2 * fold + 1), (n, d))
    G = jax.random.normal(jax.random.fold_in(rng, 2 * fold + 2), (n, d))
    return X, G


def _case(name, c, d):
    return get_kernel(name), (None if c is None else c * jnp.ones(d))


# ---------------------------------------------------------------------------
# MLL == dense oracle; exact hyper-gradients
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,c", CASES)
def test_mll_matches_dense_oracle(name, c, rng):
    n, d = 5, 6
    spec, cc = _case(name, c, d)
    X, G = _data(rng, n, d)
    for ls2, s2, sn2 in [(1.0, 1.0, 1e-8), (2.5, 1.7, 1e-3),
                         (0.7, 0.4, 1e-2)]:
        h = HyperParams.create(lengthscale2=ls2, signal=s2, noise=sn2)
        a = float(mll(spec, X, G, h, c=cc))
        b = float(mll_dense(spec, X, G, h, c=cc))
        assert abs(a - b) <= 1e-5 * max(1.0, abs(b)), (name, ls2, a, b)


@pytest.mark.parametrize("name,c", [("rbf", None), ("expdot", 0.2)])
def test_mll_gradient_matches_finite_differences(name, c, rng):
    n, d = 4, 7
    spec, cc = _case(name, c, d)
    X, G = _data(rng, n, d, fold=1)
    h = HyperParams.create(lengthscale2=1.8, signal=1.3, noise=1e-3)
    g = jax.grad(lambda hp: mll(spec, X, G, hp, c=cc))(h)
    eps = 1e-5
    for i, fld in enumerate(h._fields):
        hp = h._replace(**{fld: getattr(h, fld) + eps})
        hm = h._replace(**{fld: getattr(h, fld) - eps})
        fd = float(mll(spec, X, G, hp, c=cc) - mll(spec, X, G, hm, c=cc))
        fd /= 2 * eps
        assert abs(float(g[i]) - fd) <= 1e-4 * max(1.0, abs(fd)), (fld, g[i],
                                                                   fd)


def test_mll_pins_jnp_backend_under_pallas(rng):
    """The evidence path must stay reverse-mode differentiable even when
    the session backend is pallas (mll scopes the jnp oracle forms)."""
    from repro.core.backend import use_backend

    X, G = _data(rng, 4, 6, fold=42)
    h = HyperParams.create(lengthscale2=1.0, noise=1e-6)
    ref = mll("rbf", X, G, h)
    with use_backend("pallas"):
        a = mll("rbf", X, G, h)
        g = jax.grad(lambda hp: mll("rbf", X, G, hp))(h)
    assert float(a) == pytest.approx(float(ref))
    assert all(bool(jnp.isfinite(v)) for v in g)


def test_mll_is_jittable_and_scan_traceable(rng):
    X, G = _data(rng, 4, 6, fold=2)
    h = HyperParams.create(lengthscale2=1.0, noise=1e-6)
    a = jax.jit(lambda hp: mll("rbf", X, G, hp))(h)
    assert jnp.isfinite(a)
    h2, v2 = jax.jit(lambda: fit_scan("rbf", X, G, h, steps=25, lr=0.1))()
    assert jnp.isfinite(v2)
    assert bool(jnp.all(jnp.isfinite(jnp.asarray(tuple(h2)))))
    # 25 steps of guarded Adam from a sane init should not LOSE evidence
    assert float(v2) >= float(a) - 0.5


# ---------------------------------------------------------------------------
# Structural: the (ND, ND) Gram is absent from the jaxpr
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["rbf", "expdot"])
def test_mll_never_materializes_dense_gram(name, rng):
    """N=4, D=16: the forbidden Gram would appear as a 64-sized axis; the
    largest legitimate axes are N^2=16 (inner matrix) and D=16."""
    n, d = 4, 16
    X, G = _data(rng, n, d, fold=3)
    h = HyperParams.create(lengthscale2=float(d), noise=1e-6)
    worst = assert_no_dense_gram(name, X, G, h)
    assert worst < n * d
    worst_g = assert_no_dense_gram(name, X, G, h, grad=True)
    assert worst_g < n * d


def test_structural_check_catches_a_dense_computation(rng):
    """The checker is not vacuous: tracing the dense oracle through the
    same assertion machinery must trip StructureError."""
    from repro.hyper.mll import _jaxpr_axis_sizes

    n, d = 4, 16
    X, G = _data(rng, n, d, fold=4)
    h = HyperParams.create(lengthscale2=float(d), noise=1e-6)
    closed = jax.make_jaxpr(lambda hp: mll_dense("rbf", X, G, hp))(h)
    assert max(_jaxpr_axis_sizes(closed.jaxpr)) >= n * d
    with pytest.raises(ValueError):
        # vacuous geometry (N^2 >= ND) must be refused, not silently passed
        assert_no_dense_gram("rbf", X[:, :3], G[:, :3], h)


# ---------------------------------------------------------------------------
# Posterior variance: PSD, zero at training inputs, matches dense diagonal
# ---------------------------------------------------------------------------


def _dense_var(spec, Xq, X, lam, noise, signal, c=None):
    """Dense-oracle posterior variances via autodiff of the kernel."""
    n, d = X.shape
    K = (signal * dense_gram(spec, X, lam=lam, c=c)
         + noise * jnp.eye(n * d, dtype=X.dtype))
    Ki = jnp.linalg.inv(K)

    def kfun(xa, xb):
        if spec.is_stationary:
            dd = xa - xb
            r = jnp.sum(dd * lam * dd)
        else:
            xat = xa if c is None else xa - c
            xbt = xb if c is None else xb - c
            r = jnp.sum(xat * lam * xbt)
        return signal * spec.k0(r)

    vvals, vgrads = [], []
    for xq in Xq:
        cvec = jnp.stack([jax.grad(kfun, argnums=1)(xq, X[b])
                          for b in range(n)]).reshape(-1)
        vvals.append(kfun(xq, xq) - cvec @ Ki @ cvec)
        blocks = jnp.stack([jax.jacfwd(jax.grad(kfun, argnums=1),
                                       argnums=0)(xq, X[b])
                            for b in range(n)])        # (n, j, i)
        C = blocks.transpose(2, 0, 1).reshape(d, n * d)
        prior = jax.jacfwd(jax.grad(kfun, argnums=1), argnums=0)(xq, xq)
        vgrads.append(jnp.diag(prior)
                      - jnp.einsum("ik,kl,il->i", C, Ki, C))
    return jnp.stack(vvals), jnp.stack(vgrads)


@pytest.mark.parametrize("name,c", [("rbf", None), ("rq", None),
                                    ("expdot", 0.2), ("poly3", 0.1)])
def test_variance_matches_dense_posterior_covariance_diagonal(name, c, rng):
    n, d = 4, 5
    spec, cc = _case(name, c, d)
    X, _ = _data(rng, n, d, fold=5)
    Xq = jax.random.normal(jax.random.fold_in(rng, 77), (3, d))
    lam, noise, signal = 0.6, 1e-3, 1.4
    f = build_factors(spec, X, lam=lam, c=cc)
    sol = make_solver(spec, f, noise=noise, signal=signal)
    vv = value_var(spec, Xq, f, sol)
    vg = grad_var(spec, Xq, f, sol)
    rv, rg = _dense_var(spec, Xq, X, lam, noise, signal, c=cc)
    assert jnp.all(vv >= 0.0) and jnp.all(vg >= 0.0)
    assert float(jnp.max(jnp.abs(vv - rv))) <= 1e-4 * max(
        1.0, float(jnp.max(jnp.abs(rv))))
    assert float(jnp.max(jnp.abs(vg - rg))) <= 1e-4 * max(
        1.0, float(jnp.max(jnp.abs(rg))))


def test_grad_variance_vanishes_at_training_inputs_as_noise_to_zero(rng):
    """Gradients ARE the observations: their posterior variance at the
    training inputs must go to zero with the noise (value variance need
    not — values are never observed)."""
    n, d = 5, 6
    X, _ = _data(rng, n, d, fold=6)
    spec = get_kernel("rbf")
    f = build_factors(spec, X, lam=0.8)
    for noise in (1e-6, 1e-10):
        sol = make_solver(spec, f, noise=noise)
        vg = grad_var(spec, X, f, sol)
        assert float(jnp.max(vg)) <= 10.0 * noise + 1e-12, noise
        assert jnp.all(vg >= 0.0)


def test_posterior_batch_return_std_matches_dense(rng):
    n, d = 5, 4
    X, G = _data(rng, n, d, fold=7)
    lam, noise = 0.7, 1e-4
    st = GPGState.from_data("rbf", X, G, lam=lam, noise=noise)
    Xq = jax.random.normal(jax.random.fold_in(rng, 9), (6, d))
    pb = st.posterior(Xq, return_std=True, return_grad_std=True,
                      microbatch=4)
    spec = get_kernel("rbf")
    rv, rg = _dense_var(spec, Xq, X, lam, noise, 1.0)
    assert pb.std.shape == (6,) and pb.grad_std.shape == (6, d)
    assert float(jnp.max(jnp.abs(pb.std ** 2 - rv))) <= 1e-4
    assert float(jnp.max(jnp.abs(pb.grad_std ** 2 - rg))) <= 1e-4
    # the plain-mean path is untouched and std stays None
    pb0 = st.posterior(Xq)
    assert pb0.std is None and pb0.grad_std is None
    assert jnp.allclose(pb0.value, pb.value)


# ---------------------------------------------------------------------------
# Fitting: the evidence beats the heuristic on the Fig.-3 surrogate
# ---------------------------------------------------------------------------


def _rosenbrock_data(d=24, n=6, seed=0):
    def f(x):
        return jnp.sum(x[:-1] ** 2 + 2.0 * (x[1:] - x[:-1] ** 2) ** 2)

    g = jax.grad(f)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(seed), (d,))
    X, G = [], []
    for _ in range(n):
        gx = g(x)
        X.append(x)
        G.append(gx)
        x = x - 0.02 * gx / (1.0 + jnp.linalg.norm(gx) / jnp.sqrt(d))
    return jnp.stack(X), jnp.stack(G)


def test_fit_improves_over_auto_lengthscale_on_rosenbrock():
    from repro.optim.gp_directions import auto_lengthscale

    X, G = _rosenbrock_data()
    init = HyperParams.from_lam(auto_lengthscale(X), signal=1.0, noise=1e-8)
    res = fit("rbf", X, G, init=init, steps=120)
    assert res.improvement > 0.0, (float(res.mll0), float(res.mll))
    assert jnp.isfinite(res.mll)
    # and the fitted hypers respect the bound guards
    from repro.hyper import BOUNDS
    for v, (lo, hi) in zip(res.hypers, BOUNDS):
        assert lo - 1e-9 <= float(v) <= hi + 1e-9


def test_fit_scores_the_last_iterate(rng):
    """fit(steps=1) must perform (and evaluate) one real Adam step — the
    final iterate may not be silently discarded."""
    X, G = _data(rng, 5, 8, fold=14)
    init = HyperParams.create(lengthscale2=100.0, signal=1.0, noise=1e-6)
    res = fit("rbf", X, G, init=init, steps=1)
    assert res.n_steps == 1
    assert float(res.hypers.log_lengthscale2) != pytest.approx(
        float(init.log_lengthscale2))


def test_fit_mask_freezes_fields(rng):
    from repro.hyper import LENGTHSCALE_ONLY

    X, G = _data(rng, 5, 8, fold=8)
    init = HyperParams.create(lengthscale2=1.0, signal=1.0, noise=1e-6)
    res = fit("rbf", X, G, init=init, steps=30, mask=LENGTHSCALE_ONLY)
    assert float(res.hypers.log_signal) == pytest.approx(
        float(init.log_signal))
    assert float(res.hypers.log_noise) == pytest.approx(
        float(init.log_noise))
    assert float(res.hypers.log_lengthscale2) != pytest.approx(
        float(init.log_lengthscale2))


# ---------------------------------------------------------------------------
# Integrations: state, sampling, serving
# ---------------------------------------------------------------------------


def test_state_mll_and_refit(rng):
    X, G = _data(rng, 6, 7, fold=9)
    st = GPGState.from_data("rbf", X, G, lam=0.7, noise=1e-6)
    m0 = float(st.mll())
    assert m0 == pytest.approx(
        float(mll_dense("rbf", X, G, st.hypers)), rel=1e-6)
    res = st.refit(steps=60)
    assert res.improvement >= -1e-9
    assert float(st.mll()) >= m0 - 1e-6
    # the refit refactored the state coherently: hypers round-trip
    assert float(st.data.lam) == pytest.approx(float(res.hypers.lam))
    assert st.noise == pytest.approx(float(res.hypers.noise))
    assert st.signal == pytest.approx(float(res.hypers.signal))


def test_signal_variance_leaves_posterior_mean_invariant(rng):
    """Means only see sigma^2/s^2; doubling (signal, noise) together must
    leave Z and the served means unchanged while scaling the variance."""
    X, G = _data(rng, 5, 6, fold=10)
    Xq = X[:2] + 0.1
    a = GPGState.from_data("rbf", X, G, lam=0.7, noise=1e-4, signal=1.0)
    b = GPGState.from_data("rbf", X, G, lam=0.7, noise=2e-4, signal=2.0)
    assert jnp.allclose(a.Z, b.Z, atol=1e-10)
    pa = a.posterior(Xq, return_std=True)
    pb = b.posterior(Xq, return_std=True)
    assert jnp.allclose(pa.value, pb.value, atol=1e-10)
    assert jnp.allclose(2.0 * pa.std ** 2, pb.std ** 2, rtol=1e-8)


def test_posterior_batch_default_solver_signal_convention(rng):
    """Direct posterior_batch(return_std=True) on factors carrying the
    EFFECTIVE noise (the core GramFactors convention) must match the dense
    oracle for signal != 1 — the default-built solver may not divide the
    noise by the signal a second time."""
    n, d = 4, 5
    X, G = _data(rng, n, d, fold=12)
    spec = get_kernel("rbf")
    lam, noise, signal = 0.7, 4e-4, 4.0
    st = GPGState.from_data("rbf", X, G, lam=lam, noise=noise, signal=signal)
    pb = posterior_batch(spec, X[:2] + 0.1, st.factors, st.Z,
                         return_std=True, signal=signal)
    rv, _ = _dense_var(spec, X[:2] + 0.1, X, lam, noise, signal)
    assert float(jnp.max(jnp.abs(pb.std ** 2 - rv))) <= 1e-6 * max(
        1.0, float(jnp.max(jnp.abs(rv))))
    # and it agrees with the state's own pre-built-solver path
    ref = st.posterior(X[:2] + 0.1, return_std=True)
    assert jnp.allclose(pb.std, ref.std, rtol=1e-8)


def test_serve_bundle_caches_solver_per_revision(rng):
    from repro.train.serve import build_gp_serve_step

    X, G = _data(rng, 4, 5, fold=13)
    st = GPGState.from_data("rbf", X, G, lam=0.7, noise=1e-6, capacity=6)
    srv = build_gp_serve_step(st, microbatch=4, return_std=True)
    s1 = srv.refresh_solver()
    s2 = srv.refresh_solver()
    assert s1 is s2                       # same revision: LU reused
    st.extend(X[0] + 0.5, G[0])
    s3 = srv.refresh_solver()
    assert s3 is not s1                   # extend invalidates
    st.refit(steps=5)
    assert srv.refresh_solver() is not s3  # refit invalidates too


def test_gpg_hmc_hyperparams_plumbing():
    """HyperParams and the legacy lengthscale2 float must drive the SAME
    surrogate; condition_surrogate exposes the shared container."""
    from repro.sampling import condition_surrogate
    from repro.sampling.gpg_hmc import _as_hypers

    hp = _as_hypers(None, 12.5)
    assert float(hp.lengthscale2) == pytest.approx(12.5)
    assert float(hp.noise) == pytest.approx(1e-8)
    hp2 = _as_hypers(HyperParams.create(lengthscale2=3.0, noise=1e-6), 99.0)
    assert float(hp2.lengthscale2) == pytest.approx(3.0)
    with pytest.raises(TypeError):
        _as_hypers(None, None)
    with pytest.raises(TypeError):
        _as_hypers(2.0, None)          # bare float must use lengthscale2=
    with pytest.raises(TypeError):
        condition_surrogate(jnp.zeros((2, 3)), jnp.zeros((2, 3)))  # no hypers

    key = jax.random.PRNGKey(0)
    X = jax.random.normal(key, (4, 8))
    G = jax.random.normal(jax.random.fold_in(key, 1), (4, 8))
    s1 = condition_surrogate(X, G, 1.0 / 12.5)          # legacy lam float
    s2 = condition_surrogate(X, G, hp)                  # shared container
    assert jnp.allclose(s1.Z, s2.Z, atol=1e-12)
    assert float(s2.hypers.lengthscale2) == pytest.approx(12.5)


def test_serve_step_with_std_is_compile_stable_across_extend_and_refit(rng):
    from repro.train.serve import build_gp_serve_step

    X, G = _data(rng, 5, 6, fold=11)
    st = GPGState.from_data("rbf", X, G, lam=0.7, noise=1e-6, capacity=8)
    srv = build_gp_serve_step(st, microbatch=8, return_std=True)
    Xq = jax.random.normal(jax.random.fold_in(rng, 13), (11, 6))
    pb = srv.query(Xq)
    ref = st.posterior(Xq, return_std=True)
    assert pb.std.shape == (11,)
    assert jnp.allclose(pb.value, ref.value)
    assert jnp.allclose(pb.std, ref.std, rtol=1e-8, atol=1e-10)
    assert srv.step._cache_size() == 1
    # extend changes count, refit changes EVERY hyper — same executable
    st.extend(Xq[0], G[0] * 0.5)
    st.refit(steps=10)
    pb2 = srv.query(Xq[:3])
    ref2 = st.posterior(Xq[:3], return_std=True)
    assert jnp.allclose(pb2.std, ref2.std, rtol=1e-8, atol=1e-10)
    assert jnp.allclose(pb2.value, ref2.value)
    assert srv.step._cache_size() == 1


def test_gp_precond_mll_refresh_mode_runs(rng):
    """The in-jit MLL refresh branch traces and steps without NaNs."""
    from repro.optim.gp_precond import gp_precond

    opt = gp_precond(lr=0.1, history=3, refresh_every=2,
                     refresh_mode="mll", mll_steps=3, noise=1e-6,
                     fallback_lr=1e-2, kernel="rbf")
    params = {"w": jax.random.normal(rng, (12,), jnp.float32)}

    def loss(p):
        return jnp.sum(p["w"] ** 2 * jnp.arange(1, 13))

    state = opt.init(params)
    step = jax.jit(opt.update)
    for _ in range(6):
        grads = jax.grad(loss)(params)
        params, state = step(grads, state, params)
    assert bool(jnp.all(jnp.isfinite(params["w"])))
    # the MLL refresh refactored with a finite, in-bounds lengthscale
    assert bool(jnp.isfinite(state["gpg"].lam)) and float(
        state["gpg"].lam) > 0.0
    with pytest.raises(ValueError):
        gp_precond(refresh_mode="bogus")

"""Posterior inference (paper Sec. 4 / App. D/E): gradient, Hessian,
optimum — validated against autodiff of the posterior mean field.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import (build_factors, cross_grad_matvec, dense_cross_gram,
                        dense_solve, get_kernel, infer_optimum,
                        posterior_grad, posterior_hessian, woodbury_solve)

N, D = 5, 7
LAM = 0.7
KERNELS = ["rbf", "rq", "poly2", "poly3", "expdot"]


def setup(name, rng):
    spec = get_kernel(name)
    c = None
    if not spec.is_stationary:
        c = jax.random.normal(jax.random.fold_in(rng, 99), (D,)) * 0.1
    X = jax.random.normal(jax.random.fold_in(rng, 1), (N, D))
    if name == "poly2":
        # poly2's Gram is singular for N*D > D(D+1)/2: keep G in its range
        # so the dense solve stays well-scaled (cf. test_core_solvers)
        A0 = jax.random.normal(jax.random.fold_in(rng, 11), (D, D))
        A0 = A0 @ A0.T
        G = (X - c) @ A0.T
    else:
        G = jax.random.normal(jax.random.fold_in(rng, 2), (N, D))
    Z = dense_solve(spec, X, G, lam=LAM, c=c)
    return spec, X, G, Z, c


@pytest.mark.parametrize("name", KERNELS)
def test_posterior_grad_matches_dense_cross(name, rng):
    spec, X, G, Z, c = setup(name, rng)
    f = build_factors(spec, X, lam=LAM, c=c)
    Xq = jax.random.normal(jax.random.fold_in(rng, 4), (3, D))
    pg = posterior_grad(spec, Xq, f, Z)
    cross = dense_cross_gram(spec, Xq, X, lam=LAM, c=c)
    pg_d = (cross @ Z.reshape(-1)).reshape(3, D)
    assert jnp.allclose(pg, pg_d, rtol=1e-8, atol=1e-10)


@pytest.mark.parametrize("name", KERNELS)
def test_posterior_grad_interpolates(name, rng):
    """At training inputs the posterior mean reproduces observations."""
    spec, X, G, Z, c = setup(name, rng)
    f = build_factors(spec, X, lam=LAM, c=c)
    pg = posterior_grad(spec, X, f, Z)
    assert jnp.max(jnp.abs(pg - G)) / jnp.max(jnp.abs(G)) < 1e-6


@pytest.mark.parametrize("name", KERNELS)
def test_posterior_hessian_matches_autodiff(name, rng):
    spec, X, G, Z, c = setup(name, rng)
    f = build_factors(spec, X, lam=LAM, c=c)
    xq = jax.random.normal(jax.random.fold_in(rng, 4), (D,))

    def mean_grad(x):
        return cross_grad_matvec(spec, x[None], f, Z)[0]

    H_ad = jax.jacfwd(mean_grad)(xq)
    H_op = posterior_hessian(spec, xq, f, Z)
    assert jnp.max(jnp.abs(H_op.dense() - H_ad)) / \
        (jnp.max(jnp.abs(H_ad)) + 1e-30) < 1e-8


def test_hessian_operator_solve_consistent(rng):
    spec, X, G, Z, c = setup("rbf", rng)
    f = build_factors(spec, X, lam=LAM)
    xq = jax.random.normal(jax.random.fold_in(rng, 4), (D,))
    H = posterior_hessian(spec, xq, f, Z)
    rhs = jax.random.normal(jax.random.fold_in(rng, 5), (D,))
    sol = H.solve(rhs)
    assert jnp.allclose(H.matvec(sol), rhs, rtol=1e-4, atol=1e-5)


def test_infer_optimum_recovers_quadratic_minimum(rng):
    """GP-X on exact quadratic data with poly2: x(g=0) == x* exactly.

    Paper App. E.2 setup: kernel center c = g_t and prior mean x_t. The
    flipped field x(g) - x_t = A^{-1}(g - g_t) is then exactly the linear
    map a zero-mean poly2 gradient-GP represents, so with
    N >= (D+1)/2 observations the posterior at g = 0 IS x*.
    """
    import numpy as np

    spec = get_kernel("poly2")
    A = np.random.RandomState(0).randn(D, D)
    A = jnp.asarray(A @ A.T + 0.5 * np.eye(D))
    xstar = jax.random.normal(jax.random.fold_in(rng, 7), (D,))
    X = jax.random.normal(jax.random.fold_in(rng, 8), (N + 3, D))
    G = (X - xstar) @ A.T
    x_t, g_t = X[-1], G[-1]
    f_g = build_factors(spec, G, lam=1.0, c=g_t)
    Z = woodbury_solve(spec, f_g, X - x_t, jitter=1e-12)
    x_opt = infer_optimum(spec, f_g, Z, x_t)
    assert jnp.max(jnp.abs(x_opt - xstar)) < 1e-5

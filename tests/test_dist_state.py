"""D-sharded incremental state machine (core/dist_state.py): parity with
the single-device GPGState, psum-count jaxpr gates, per-shard single-X-
stream gates, and the sharded gp_precond optimizer step.

Host-process tests run on the 1-device contract (a 1-device mesh exercises
the identical shard_map programs); real 8-fake-device parity — including
uneven shards (D % devices != 0), ring/pipelined queries and the
collective-bytes model — runs in a subprocess with
``xla_force_host_platform_device_count=8`` (same pattern as
tests/test_distributed.py).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.core import GPGState, ShardedGPGState, get_kernel
from repro.core.dist_state import PHASE_PSUMS, psum_bytes
from repro.hyper import HyperParams, mll, mll_from_strips, strips_for_mll
from repro.utils.hlo import count_data_streams, count_psums

KERNELS = ["rbf", "expdot"]


def _mk(rng, n, d, seed=0):
    X = jax.random.normal(jax.random.fold_in(rng, seed + 1), (n, d))
    G = jax.random.normal(jax.random.fold_in(rng, seed + 2), (n, d))
    return X, G


# ---------------------------------------------------------------------------
# Strips-based MLL (hyper/mll.py) — replicated-evidence parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["rbf", "expdot", "poly2"])
def test_mll_from_strips_matches_mll(name, rng):
    n, d = 6, 24
    spec = get_kernel(name)
    X, G = _mk(rng, n, d)
    lam = 0.5 if spec.is_stationary else 0.5 / d
    h = HyperParams.from_lam(jnp.asarray(lam), signal=1.3, noise=1e-4)
    ref = mll(spec, X, G, h)
    S0, C, GG = strips_for_mll(X, G)
    got = mll_from_strips(spec, S0, C, GG, d, h)
    assert jnp.abs(got - ref) / (jnp.abs(ref) + 1.0) < 1e-8

    # value AND gradient parity (the refit path differentiates this)
    def f_ref(lam_):
        return mll(spec, X, G, HyperParams.from_lam(lam_, signal=1.3,
                                                    noise=1e-4))

    def f_strips(lam_):
        return mll_from_strips(spec, S0, C, GG, d,
                               HyperParams.from_lam(lam_, signal=1.3,
                                                    noise=1e-4))

    g_ref = jax.grad(f_ref)(jnp.asarray(lam))
    g_got = jax.grad(f_strips)(jnp.asarray(lam))
    assert jnp.abs(g_got - g_ref) / (jnp.abs(g_ref) + 1.0) < 1e-6


@pytest.mark.parametrize("name", KERNELS)
def test_mll_from_strips_padded_count(name, rng):
    """Padded strip rows (count < cap) are exactly inert."""
    n, cap, d = 4, 7, 16
    spec = get_kernel(name)
    X, G = _mk(rng, n, d, seed=3)
    lam = 0.4 if spec.is_stationary else 0.4 / d
    h = HyperParams.from_lam(jnp.asarray(lam), signal=1.0, noise=1e-5)
    S0, C, GG = strips_for_mll(X, G)
    pad = ((0, cap - n), (0, cap - n))
    got = mll_from_strips(spec, jnp.pad(S0, pad), jnp.pad(C, pad),
                          jnp.pad(GG, pad), d, h, count=n)
    ref = mll_from_strips(spec, S0, C, GG, d, h)
    assert jnp.abs(got - ref) < 1e-10 * (1.0 + jnp.abs(ref))


# ---------------------------------------------------------------------------
# Kernel-launch geometry: _pick_block_d sizes against the LOCAL shard
# ---------------------------------------------------------------------------


def test_pick_block_d_shard_aware():
    from repro.kernels.ops import _pick_block_d, use_data_shards

    d = 4096
    whole = _pick_block_d(d)
    sharded = _pick_block_d(d, shards=8)
    # one grid step over the 512-wide local shard, not the global D
    assert sharded == _pick_block_d(512)
    assert sharded <= whole
    with use_data_shards(8):
        assert _pick_block_d(d) == sharded
    assert _pick_block_d(d) == whole          # context restored


# ---------------------------------------------------------------------------
# 1-device-mesh parity: the same shard_map programs, exact expectations
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", KERNELS)
def test_sharded_state_matches_unsharded_1dev(name, rng):
    from repro.launch.mesh import make_d_mesh

    d, window, steps = 12, 4, 7
    spec = get_kernel(name)
    lam = 0.6 if spec.is_stationary else 0.6 / d
    kw = dict(window=window, lam=lam, noise=1e-6)
    st = ShardedGPGState(name, d, mesh=make_d_mesh(), **kw)
    ref = GPGState(name, d, tol=1e-12, **kw)
    X, G = _mk(rng, steps, d, seed=11)
    Xq, _ = _mk(rng, 3, d, seed=17)
    for i in range(steps):
        st.extend(X[i], G[i])
        ref.extend(X[i], G[i])
        assert jnp.max(jnp.abs(st.Z - ref.Z)) < 1e-6
    pb, pr = st.posterior(Xq), ref.posterior(Xq)
    assert jnp.max(jnp.abs(pb.value - pr.value)) < 1e-6
    assert jnp.max(jnp.abs(pb.grad - pr.grad)) < 1e-6
    # evict + resolve parity
    st.evict(); ref.evict()
    rhs = jax.random.normal(jax.random.fold_in(rng, 23), (st.n, d))
    Zs = st.resolve(rhs)
    Zr = ref.resolve(rhs)
    assert jnp.max(jnp.abs(Zs - Zr[: st.n])) < 1e-6


def test_sharded_refit_matches_unsharded(rng):
    d, n = 10, 6
    X, G = _mk(rng, n, d, seed=31)
    st = ShardedGPGState.from_data("rbf", X, G, lam=0.5, noise=1e-4)
    ref = GPGState.from_data("rbf", X, G, lam=0.5, noise=1e-4, tol=1e-12)
    m0 = st.mll()
    assert jnp.abs(m0 - ref.mll()) / (jnp.abs(m0) + 1.0) < 1e-6
    rs = st.refit(steps=40)
    rr = ref.refit(steps=40)
    assert jnp.abs(rs.hypers.lam - rr.hypers.lam) / rr.hypers.lam < 1e-4
    assert jnp.abs(st.mll() - ref.mll()) / (jnp.abs(m0) + 1.0) < 1e-5


# ---------------------------------------------------------------------------
# The jaxpr gates: at most ONE psum per phase, one local X stream per solve
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", KERNELS)
def test_phase_psum_counts(name, rng):
    """Every compiled phase program issues EXACTLY the collective count of
    the PHASE_PSUMS contract (the fused-psum invariant, jaxpr-level)."""
    from repro.launch.mesh import make_d_mesh

    d = 12
    st = ShardedGPGState(name, d, window=4, mesh=make_d_mesh(),
                         lam=0.5, noise=1e-6)
    x = jnp.zeros((st.d_pad,))
    g = jnp.zeros((st.d_pad,))
    rhs = jnp.zeros((st.data.capacity, st.d_pad))
    nz = jnp.asarray(1e-6)
    lam = jnp.asarray(0.5)
    cases = {
        "extend": ((st.data, x, g, nz), PHASE_PSUMS["extend"]),
        "evict": ((st.data, nz), PHASE_PSUMS["evict"]),
        "refactor": ((st.data, lam, nz), PHASE_PSUMS["refactor"]),
        "resolve": ((st.data, rhs, nz), PHASE_PSUMS["resolve"]),
        "rebuild": ((st.data, nz), PHASE_PSUMS["rebuild"]),
    }
    for phase, (args, want) in cases.items():
        st._phase(phase)  # build (and cache) the program
        raw = st._fns[phase]
        fn = getattr(raw, "fn", raw)      # unwrap CompileWatch if obs on
        jx = jax.make_jaxpr(fn)(*args)
        assert count_psums(jx) == want, (phase, count_psums(jx), want)
    jq = jax.make_jaxpr(st._query_raw(3))(st.data, jnp.zeros((3, st.d_pad)))
    assert count_psums(jq) == PHASE_PSUMS["query"]


def test_solve_single_local_x_stream(rng):
    """Per shard, one solve = ONE reduction stream of the local Xt shard
    (the extend border) + the ONE output-assembly expansion stream (the
    taint-walk teeth of DESIGN.md sec. 12, applied per-shard)."""
    from jax.sharding import PartitionSpec as P

    from repro.core.dist_state import sgpg_extend
    from repro.core.distributed import _shard_map
    from repro.launch.mesh import make_d_mesh

    n, d = 5, 256                 # (cap, cap) psum outputs stay < d_loc
    spec = get_kernel("rbf")
    mesh = make_d_mesh()
    names = tuple(mesh.axis_names)
    st = ShardedGPGState("rbf", d, window=n, mesh=mesh, lam=0.3, noise=1e-6)
    X, G = _mk(rng, n - 1, d, seed=41)
    for i in range(n - 1):
        st.extend(X[i], G[i])
    data = st.data
    x, g = _mk(rng, 1, d, seed=47)

    def fn(Xt, x, g):
        d2 = data._replace(base=data.base._replace(Xt=Xt))
        out, _ = sgpg_extend(spec, d2, x, g, axis_names=names, noise=1e-6,
                             solve=True)
        return out.base.Z

    sm = _shard_map(fn, mesh=mesh,
                    in_specs=(P(None, names), P(names), P(names)),
                    out_specs=P(None, names), check_rep=False)
    closed = jax.make_jaxpr(sm)(data.base.Xt, x[0], g[0])
    d_loc = d // mesh.size
    streams = count_data_streams(closed, 0, d_loc)
    assert streams == {"reduction": 1, "expansion": 1}, streams


def test_gp_precond_sharded_psum_budget():
    """The whole sharded training step is <= 3 fused psums in every mode
    (extend border, direction reductions, trust-region scalars)."""
    from repro.launch.mesh import make_d_mesh
    from repro.optim.gp_precond import gp_precond

    mesh = make_d_mesh()
    params = {"w": jnp.zeros((13,), jnp.float32)}
    grads = {"w": jnp.ones((13,), jnp.float32)}
    for mode in ("gph", "gpx"):
        for kern in KERNELS:
            for rmode in ("heuristic", "mll"):
                opt = gp_precond(mode=mode, kernel=kern, refresh_mode=rmode,
                                 history=4, mesh=mesh)
                st = opt.init(params)
                jx = jax.make_jaxpr(opt.update)(grads, st, params)
                got = count_psums(jx)
                assert got <= 3, (mode, kern, rmode, got)


def test_gp_precond_sharded_matches_unsharded_1dev(rng):
    """Short-trajectory parity of the sharded optimizer against the classic
    one (well-conditioned configs; the exact strips solve replaces CG, so
    the tolerance is solver-level, not bitwise)."""
    from repro.launch.mesh import make_d_mesh
    from repro.optim.gp_precond import gp_precond

    d = 11
    A = jax.random.normal(jax.random.fold_in(rng, 51), (d, d)) * 0.3 \
        + jnp.eye(d)
    H = A @ A.T

    def loss(p):
        return 0.5 * p["w"] @ H @ p["w"]

    mesh = make_d_mesh()
    for mode, kern in [("gph", "rbf"), ("gpx", "rbf"), ("gpx", "expdot")]:
        kw = dict(mode=mode, kernel=kern, history=4, refresh_every=3,
                  noise=1e-5, fallback_lr=0.05, max_step_rms=0.05)
        o0 = gp_precond(**kw, cg_tol=1e-12)
        o1 = gp_precond(**kw, mesh=mesh)
        p0 = {"w": jax.random.normal(jax.random.fold_in(rng, 53), (d,))}
        p1 = {"w": p0["w"]}
        s0, s1 = o0.init(p0), o1.init(p1)
        u0, u1 = jax.jit(o0.update), jax.jit(o1.update)
        for _ in range(7):
            g0 = jax.grad(loss)(p0)
            g1 = jax.grad(loss)(p1)
            p0, s0 = u0(g0, s0, p0)
            p1, s1 = u1(g1, s1, p1)
        dw = float(jnp.max(jnp.abs(p0["w"] - p1["w"])))
        assert dw < 5e-3, (mode, kern, dw)


def test_sharded_phase_compile_stability():
    """extend / evict / refactor never retrace: count and noise are traced
    arguments, so a refit or a shrinking window reuses the executable."""
    from repro.launch.mesh import make_d_mesh
    from repro.obs import trace as _obs

    _obs.set_enabled(True)
    try:
        st = ShardedGPGState("rbf", 8, window=3, mesh=make_d_mesh(),
                             lam=0.5, noise=1e-6)
        key = jax.random.PRNGKey(7)
        for i in range(6):      # wraps the window -> evict + extend mix
            x = jax.random.normal(jax.random.fold_in(key, 2 * i), (8,))
            g = jax.random.normal(jax.random.fold_in(key, 2 * i + 1), (8,))
            st.extend(x, g)
        st.refit(steps=5)       # changes lam AND noise
        x = jax.random.normal(jax.random.fold_in(key, 99), (8,))
        st.extend(x, x)
        for name, fn in st._fns.items():
            fn.assert_stable()
            assert fn.n_compiles() == 1, (name, fn.n_compiles())
    finally:
        _obs.set_enabled(None)


def test_psum_bytes_model_sanity():
    assert psum_bytes("extend", cap=6) == 4 * 2 * 2 * 6
    assert psum_bytes("extend", cap=6, with_rhs=True) == 4 * (24 + 36)
    assert psum_bytes("resolve", cap=6) == 4 * 36
    assert psum_bytes("rebuild", cap=6) == 3 * 4 * 36
    assert psum_bytes("query", cap=6, q=4) == 4 * (2 * 4 * 6 + 4 + 2 * 6)
    for ph in ("evict", "refactor", "solve", "refit"):
        assert psum_bytes(ph, cap=6) == 0
    # the claim itself: NEVER a function of D (no d parameter exists)


# ---------------------------------------------------------------------------
# Real 8-fake-device parity (subprocess; uneven shards included)
# ---------------------------------------------------------------------------

_SUBPROCESS_SRC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core import GPGState, ShardedGPGState, get_kernel
from repro.core.dist_state import PHASE_PSUMS, psum_bytes
from repro.core.distributed import _shard_map, ring_psum
from repro.launch.mesh import make_d_mesh
from repro.utils.hlo import collective_bytes, count_psums

mesh = make_d_mesh()
assert mesh.size == 8, mesh
failures = []
key = jax.random.PRNGKey(0)

def mk(n, d, seed):
    return (jax.random.normal(jax.random.fold_in(key, seed), (n, d)),
            jax.random.normal(jax.random.fold_in(key, seed + 1), (n, d)))

# full trajectory parity: extend -> evict -> posterior -> refit -> resolve,
# even (D=64) and UNEVEN (D=61, 61 % 8 != 0) shards, both kernel families
for kern in ("rbf", "expdot"):
    for d in (64, 61):
        spec = get_kernel(kern)
        lam = 0.6 if spec.is_stationary else 0.6 / d
        window, steps = 4, 6
        st = ShardedGPGState(kern, d, window=window, mesh=mesh, lam=lam,
                             noise=1e-6)
        ref = GPGState(kern, d, window=window, lam=lam, noise=1e-6,
                       tol=1e-12)
        X, G = mk(steps, d, 100 + d)
        for i in range(steps):
            st.extend(X[i], G[i]); ref.extend(X[i], G[i])
            e = float(jnp.max(jnp.abs(st.Z - ref.Z)))
            if e > 1e-5: failures.append((kern, d, "extend", i, e))
        Xq, _ = mk(3, d, 200 + d)
        pb, pr = st.posterior(Xq), ref.posterior(Xq)
        ev = float(jnp.max(jnp.abs(pb.value - pr.value)))
        eg = float(jnp.max(jnp.abs(pb.grad - pr.grad)))
        if max(ev, eg) > 1e-5: failures.append((kern, d, "posterior", ev, eg))
        rs = st.refit(steps=30); rr = ref.refit(steps=30)
        el = abs(float(rs.hypers.lam - rr.hypers.lam)) / float(rr.hypers.lam)
        if el > 1e-4: failures.append((kern, d, "refit", el))
        e = float(jnp.max(jnp.abs(st.Z - ref.Z)))
        if e > 1e-5: failures.append((kern, d, "refit-Z", e))
        st.evict(); ref.evict()
        rhs, _ = mk(st.n, d, 300 + d)
        Zs = st.resolve(rhs)
        Zr = ref.resolve(rhs)
        e = float(jnp.max(jnp.abs(Zs - Zr[: st.n])))
        if e > 1e-5: failures.append((kern, d, "resolve", e))

# ring_psum == psum (ppermute ring reduction): each device holds a (3,)
# shard; the ring all-reduce must equal the cross-device sum, replicated
x = jnp.arange(8.0 * 3)
names = tuple(mesh.axis_names)
ring = _shard_map(lambda v: ring_psum(v, names[0], 8),
                  mesh=mesh, in_specs=(P(names),), out_specs=P(),
                  check_rep=False)(x)
if float(jnp.max(jnp.abs(ring - x.reshape(8, 3).sum(0)))) > 1e-12:
    failures.append(("ring_psum", ring))

# pipelined (ppermute-overlapped) query == plain fused-psum query
st = ShardedGPGState("rbf", 64, window=4, mesh=mesh, lam=0.6, noise=1e-6)
X, G = mk(4, 64, 400)
for i in range(4):
    st.extend(X[i], G[i])
Xq, _ = mk(6, 64, 500)
p0 = st.posterior(Xq)
p1 = st.posterior(Xq, chunks=3)
if float(jnp.max(jnp.abs(p0.value - p1.value))) > 1e-10 or \
   float(jnp.max(jnp.abs(p0.grad - p1.grad))) > 1e-10:
    failures.append(("pipelined-query",))

# jaxpr psum gates on the REAL 8-device mesh + measured collective bytes
# vs the O(N^2) analytic model at two D values (D-independence)
vols = {}
for d in (64, 128):
    st = ShardedGPGState("rbf", d, window=4, mesh=mesh, lam=0.6, noise=1e-6)
    cap = st.data.capacity
    x = jnp.zeros((st.d_pad,)); nz = jnp.asarray(1e-6)
    st._phase("extend")
    fn = getattr(st._fns["extend"], "fn", st._fns["extend"])
    jx = jax.make_jaxpr(fn)(st.data, x, x, nz)
    if count_psums(jx) != PHASE_PSUMS["extend"]:
        failures.append(("gate-extend", count_psums(jx)))
    hlo = jax.jit(fn).lower(st.data, x, x, nz).compile().as_text()
    vols[d] = collective_bytes(hlo)
    itemsize = jnp.dtype(st.data.base.X.dtype).itemsize
    want = psum_bytes("extend", cap=cap, itemsize=itemsize)
    if vols[d] != want:
        failures.append(("bytes-extend", d, vols[d], want))
if vols[64] != vols[128]:
    failures.append(("bytes-D-dependent", vols))

# sharded gp_precond on the real mesh vs the classic optimizer
from repro.optim.gp_precond import gp_precond
d = 24
A = jax.random.normal(jax.random.fold_in(key, 900), (d, d)) * 0.3 + jnp.eye(d)
H = A @ A.T
loss = lambda p: 0.5 * p["w"] @ H @ p["w"]
kw = dict(mode="gpx", kernel="rbf", history=4, refresh_every=3, noise=1e-5,
          fallback_lr=0.05, max_step_rms=0.05)
o0 = gp_precond(**kw, cg_tol=1e-12)
o1 = gp_precond(**kw, mesh=mesh)
p0 = {"w": jax.random.normal(jax.random.fold_in(key, 901), (d,))}
p1 = {"w": p0["w"]}
s0, s1 = o0.init(p0), o1.init(p1)
u0, u1 = jax.jit(o0.update), jax.jit(o1.update)
for _ in range(7):
    g0 = jax.grad(loss)(p0); g1 = jax.grad(loss)(p1)
    p0, s0 = u0(g0, s0, p0)
    p1, s1 = u1(g1, s1, p1)
dw = float(jnp.max(jnp.abs(p0["w"] - p1["w"])))
if dw > 5e-3:
    failures.append(("gp_precond", dw))

assert not failures, failures
print("SUBPROCESS_OK")
"""


def test_sharded_state_parity_8dev():
    r = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_SRC],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")})
    assert "SUBPROCESS_OK" in r.stdout, r.stdout + r.stderr

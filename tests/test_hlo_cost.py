"""Tests for the trip-count-aware HLO cost model (utils/hlo_cost.py) —
the dry-run roofline's measurement instrument must itself be validated."""
import jax
import jax.numpy as jnp
import pytest

from repro.utils.hlo_cost import analyze_hlo


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scanned_matmul_flops_exact():
    hlo = _compile(
        lambda x: jax.lax.scan(lambda c, _: (c @ c, None), x, None,
                               length=10)[0],
        jax.ShapeDtypeStruct((512, 512), jnp.float32))
    r = analyze_hlo(hlo)
    assert abs(r.flops - 10 * 2 * 512 ** 3) / (10 * 2 * 512 ** 3) < 1e-6


def test_unrolled_equals_scanned():
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def unrolled(x):
        for _ in range(6):
            x = x @ x
        return x

    def scanned(x):
        return jax.lax.scan(lambda c, _: (c @ c, None), x, None, length=6)[0]

    fu = analyze_hlo(_compile(unrolled, x)).flops
    fs = analyze_hlo(_compile(scanned, x)).flops
    assert abs(fu - fs) / fu < 1e-6


def test_nested_scan_multiplies():
    def inner(c, _):
        return c @ c, None

    def outer(c, _):
        c, _ = jax.lax.scan(inner, c, None, length=4)
        return c, None

    hlo = _compile(
        lambda x: jax.lax.scan(outer, x, None, length=3)[0],
        jax.ShapeDtypeStruct((128, 128), jnp.float32))
    r = analyze_hlo(hlo)
    want = 3 * 4 * 2 * 128 ** 3
    assert abs(r.flops - want) / want < 1e-6


def test_rectangular_dot_flops():
    hlo = _compile(lambda a, b: a @ b,
                   jax.ShapeDtypeStruct((64, 1000), jnp.float32),
                   jax.ShapeDtypeStruct((1000, 32), jnp.float32))
    r = analyze_hlo(hlo)
    want = 2 * 64 * 32 * 1000
    assert abs(r.flops - want) / want < 1e-6


def test_bytes_scale_with_trip_count():
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def make(n):
        return _compile(
            lambda x: jax.lax.scan(lambda c, _: (jnp.sin(c), None), x, None,
                                   length=n)[0], x)

    b2 = analyze_hlo(make(2)).bytes_hbm
    b8 = analyze_hlo(make(8)).bytes_hbm
    assert 2.0 < b8 / b2 < 5.0              # ~4x (plus constant entry cost)


def test_optimistic_bytes_leq_pessimistic():
    hlo = _compile(lambda x: jnp.tanh(x @ x) + 1.0,
                   jax.ShapeDtypeStruct((128, 128), jnp.float32))
    r = analyze_hlo(hlo)
    assert 0 < r.bytes_out <= r.bytes_hbm

"""Seed-driven state-machine fuzz core (NOT a test module).

One integer seed deterministically generates a random op interleaving
(extend / evict / resolve / refit / query) and drives it through the
incremental machinery, checking after EVERY op against an oracle:

  * :func:`check_single_trajectory` — the single-tenant state
    (``core/state.py``) against a dense from-scratch solve of the full
    (ND, ND) system (``core/woodbury.dense_solve``) and a from-scratch
    factor rebuild for the posterior query (<= 1e-5).
  * :func:`check_fleet_vs_loop` — the vmapped fleet trajectory
    (``core/fleet.py``) against the same ops driven per tenant through
    the plain (un-vmapped) functional primitives (<= 1e-5; in practice
    ~1e-12 under x64 — vmap lowers to the same scalar programs).

Shared by the always-on deterministic tests (tests/test_fleet.py, a few
pinned seeds) and the hypothesis fuzz front end
(tests/test_property_invariants.py, hundreds of drawn seeds in CI's
``fleet-ci`` profile).  Any failure message carries the generating seed,
so ``REPRO_TEST_SEED=<seed>`` (or the printed hypothesis blob) replays
it exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (build_factors, dense_solve, get_kernel, make_query_fn,
                        woodbury_solve)
from repro.core.fleet import (fleet_evict, fleet_extend, fleet_init,
                              fleet_lane, fleet_posterior, fleet_refit)
from repro.core.gram import GramFactors
from repro.core.state import (gpg_evict, gpg_extend, gpg_init, gpg_refactor,
                              gpg_resolve)
from repro.hyper import HyperParams
from repro.hyper.fit import fit_scan_fn
from repro.hyper.mll import make_mll_strips_fn, strips_for_mll

FUZZ_KERNELS = ["rbf", "rq", "poly2", "expdot"]
TOL = 1e-5


def _factors_of(data, noise=0.0):
    return GramFactors(K1e=data.K1e, K2e=data.K2e, Xt=data.Xt, lam=data.lam,
                       noise=float(noise), c=None)


# Jitted op caches: hypothesis runs hundreds of examples, so every op goes
# through jax.jit (XLA's cache makes repeat signatures ~free — and jit IS
# the deployment path).  Noise rides as a TRACED scalar in the mirror loop,
# same as in the fleet lanes; the dense-oracle trajectory keeps host-float
# noise to also cover the static-noise branch of core/state.py.


@functools.lru_cache(maxsize=None)
def _fleet_jit(kname: str, window: int, refit_steps: int):
    spec = get_kernel(kname)
    return {
        "extend": jax.jit(lambda fl, X, G, op: fleet_extend(
            spec, fl, X, G, op, window=window)),
        "evict": jax.jit(lambda fl, op: fleet_evict(spec, fl, op)),
        "refit": jax.jit(lambda fl, op: fleet_refit(
            spec, fl, op, steps=refit_steps)),
        "query": jax.jit(lambda fl, Xq: fleet_posterior(spec, fl, Xq)),
    }


@functools.lru_cache(maxsize=None)
def _single_jit(kname: str, refit_steps: int):
    spec = get_kernel(kname)

    def refit(data, nz, sg, lr):
        S0, C, GG = strips_for_mll(data.X, data.G)
        fn = make_mll_strips_fn(spec, S0, C, GG, data.X.shape[1],
                                count=data.count)
        h0 = HyperParams(log_lengthscale2=-jnp.log(data.lam),
                         log_signal=jnp.log(sg),
                         log_noise=jnp.log(jnp.maximum(nz, 1e-30)))
        h, _ = fit_scan_fn(fn, h0, steps=refit_steps, lr=lr)
        return (gpg_refactor(spec, data, h.lam, noise=h.noise_eff),
                h.noise, h.signal)

    return {
        "extend": jax.jit(lambda d_, x, g, nz: gpg_extend(
            spec, d_, x, g, noise=nz)),
        "evict_nosolve": jax.jit(lambda d_, nz: gpg_evict(
            spec, d_, noise=nz, solve=False)),
        "evict": jax.jit(lambda d_, nz: gpg_evict(spec, d_, noise=nz)),
        "refit": jax.jit(refit),
    }


# ---------------------------------------------------------------------------
# Single-tenant state machine vs dense from-scratch oracle
# ---------------------------------------------------------------------------


def gen_single_ops(seed: int, n_ops: int, cap: int) -> list:
    """The seed IS the trajectory: a reproducible op list with payload
    sub-seeds (no ambient RNG anywhere)."""
    rnd = np.random.RandomState(seed)
    ops, count = [], 0
    for i in range(n_ops):
        cands = ["extend"] if count == 0 else (
            (["extend"] if count < cap else []) +
            ["evict", "resolve", "query", "query"])
        op = cands[rnd.randint(len(cands))]
        ops.append((op, int(rnd.randint(2**31 - 1))))
        count += {"extend": 1, "evict": -1}.get(op, 0)
    return ops


def check_single_trajectory(kname: str, d: int, cap: int, seed: int,
                            n_ops: int = 8, noise: float = 1e-6,
                            lam: float = 0.7) -> None:
    """Drive one random interleaving; dense-oracle-check after EVERY op."""
    spec = get_kernel(kname)
    data = gpg_init(spec, d, cap, lam=lam)
    qfn = make_query_fn(spec)
    ops = gen_single_ops(seed, n_ops, cap)
    rhs_override = None      # a resolve() pins Z to a custom rhs until the
    # next extend/evict re-solves against G (the default-rhs semantics)
    for step, (op, sub) in enumerate(ops):
        r = np.random.RandomState(sub)
        if op == "extend":
            data = gpg_extend(spec, data, r.randn(d), r.randn(d),
                              noise=noise)
            rhs_override = None
        elif op == "evict":
            data = gpg_evict(spec, data, noise=noise)
            rhs_override = None
        elif op == "resolve":
            rhs_override = jnp.asarray(r.randn(cap, d))
            data = gpg_resolve(spec, data, rhs_override, noise=noise)
        n = int(data.count)
        if n == 0:
            continue
        ctx = (f"seed={seed} kernel={kname} d={d} cap={cap} step={step} "
               f"op={op} n={n}")
        X = data.X[:n]
        R = (data.G[:n] if rhs_override is None else rhs_override[:n])
        # jitter=0: the noise term already makes the dense system PD, and
        # the default 1e-10 ridge visibly perturbs near-singular draws
        # (kappa ~ 1/noise) — the oracle must solve the SAME system.
        # Tolerance is relative to the solution scale for the same reason:
        # |Z| ~ 1/noise on degenerate-gram draws.
        Z_oracle = dense_solve(spec, X, R, lam=lam, noise=noise, jitter=0.0)
        scale = max(1.0, float(jnp.max(jnp.abs(Z_oracle))))
        err = float(jnp.max(jnp.abs(data.Z[:n] - Z_oracle)))
        assert err <= TOL * scale, \
            f"Z vs dense oracle err={err:.3e} scale={scale:.1e} [{ctx}]"
        if op == "query":
            Xq = jnp.asarray(r.randn(3, d))
            got = qfn(_factors_of(data), data.Z, Xq)
            f0 = build_factors(spec, X, lam=lam, noise=noise)
            want = qfn(f0, woodbury_solve(spec, f0, R), Xq)
            verr = float(jnp.max(jnp.abs(got.value - want.value)))
            gerr = float(jnp.max(jnp.abs(got.grad - want.grad)))
            assert max(verr, gerr) <= TOL * scale, \
                f"posterior vs rebuilt oracle err={max(verr, gerr):.3e} [{ctx}]"


# ---------------------------------------------------------------------------
# Regime-crossover trajectory (host GPGState) vs dense from-scratch oracle
# ---------------------------------------------------------------------------


def gen_regime_ops(seed: int, n_ops: int) -> list:
    """Extend-biased op tape for the crossover fuzz (payload sub-seeds)."""
    rnd = np.random.RandomState(seed)
    return [(["extend", "extend", "extend", "query", "evict",
              "refit"][rnd.randint(6)], int(rnd.randint(2**31 - 1)))
            for _ in range(n_ops)]


def check_regime_trajectory(kname: str, d: int, seed: int, n_ops: int = 6,
                            noise: float = 1e-6, lam: float = 0.7,
                            policy: str = "auto") -> None:
    """Stream a policy-driven ``GPGState`` across the exact->iterative
    crossover — fill past BOTH the N >= D ceiling and the cost-model
    boundary, then a random extend/evict/refit/query tail — checking Z
    and posterior queries against dense from-scratch oracles after EVERY
    op, in BOTH regimes.  The window sits AT the crossover, so the
    capacity action ('iterate' under 'auto' for full-rank draws — the
    window lift) fires mid-trajectory too."""
    from repro.core.state import GPGState
    from repro.regime.policy import resolve_policy

    spec = get_kernel(kname)
    xover = resolve_policy(policy).crossover_n(d)
    window = max(d + 1, xover)
    st = GPGState(kname, d=d, window=window, lam=lam, noise=noise,
                  policy=policy)
    qfn = make_query_fn(spec)
    regimes_seen = set()
    rnd = np.random.RandomState(seed)
    fill = max(d + 2, window + 2)
    tape = [("extend", int(rnd.randint(2**31 - 1))) for _ in range(fill)]
    tape += gen_regime_ops(seed + 1, n_ops)

    def oracle_check(step: int, op: str, sub: int) -> None:
        n = st.n
        if n == 0:
            return
        regimes_seen.add(st.regime)
        ctx = (f"seed={seed} kernel={kname} d={d} step={step} op={op} "
               f"n={n} regime={st.regime}")
        lam_now = st.data.lam
        Z_oracle = dense_solve(spec, st.X, st.G, lam=lam_now,
                               noise=st._noise_eff, jitter=0.0)
        scale = max(1.0, float(jnp.max(jnp.abs(Z_oracle))))
        err = float(jnp.max(jnp.abs(st.Z - Z_oracle)))
        assert err <= TOL * scale, \
            f"Z vs dense oracle err={err:.3e} scale={scale:.1e} [{ctx}]"
        if op == "query":
            r = np.random.RandomState(sub)
            Xq = jnp.asarray(r.randn(3, d))
            got = st.posterior(Xq)
            # the query oracle contracts the DENSE-solve representers
            # (already certified above) through a from-scratch factor
            # rebuild — at n > d a woodbury re-solve would add its own
            # near-singular error on top of the quantity under test
            f0 = build_factors(spec, st.X, lam=lam_now,
                               noise=st._noise_eff)
            want = qfn(f0, Z_oracle, Xq)
            qerr = max(float(jnp.max(jnp.abs(got.value - want.value))),
                       float(jnp.max(jnp.abs(got.grad - want.grad))))
            assert qerr <= TOL * scale, \
                f"posterior vs rebuilt oracle err={qerr:.3e} [{ctx}]"

    for step, (op, sub) in enumerate(tape):
        r = np.random.RandomState(sub)
        if op == "extend":
            st.extend(r.randn(d), r.randn(d))
        elif op == "evict":
            if st.n > 1:
                st.evict()
        elif op == "refit":
            if st.n >= 2:
                # exact evidence keeps the oracle tight in both regimes;
                # the SLQ estimator path has its own gates
                # (tests/test_regime.py, BENCH_regime.json)
                st.refit(steps=2, method="exact")
        oracle_check(step, op, sub)

    assert regimes_seen == {"exact", "iterative"}, (
        f"trajectory never crossed: saw {regimes_seen} "
        f"(seed={seed} kernel={kname} d={d} crossover={xover})")


# ---------------------------------------------------------------------------
# Fleet (vmapped) trajectory vs per-tenant host loop
# ---------------------------------------------------------------------------


def gen_fleet_ops(seed: int, steps: int, batch: int) -> list:
    """Per step: (op, (B,) lane mask, payload sub-seed)."""
    rnd = np.random.RandomState(seed)
    out = []
    for _ in range(steps):
        op = ["extend", "extend", "evict", "refit", "query"][rnd.randint(5)]
        mask = rnd.rand(batch) < 0.7
        if not mask.any():
            mask[rnd.randint(batch)] = True
        out.append((op, mask, int(rnd.randint(2**31 - 1))))
    return out


def check_fleet_vs_loop(kname: str, d: int, window: int, seed: int,
                        steps: int = 6, batch: int = 3,
                        refit_steps: int = 4) -> None:
    """Lockstep-compare a masked fleet trajectory against the same ops
    driven per tenant through the plain functional primitives."""
    spec = get_kernel(kname)
    rnd = np.random.RandomState(seed)
    lams = np.exp(rnd.uniform(-0.7, 0.7, batch))
    noises = 10.0 ** rnd.uniform(-8.0, -5.0, batch)
    fleet = fleet_init(spec, d, window, batch, lam=jnp.asarray(lams),
                       noise=jnp.asarray(noises), active=True)
    singles = [gpg_init(spec, d, window, lam=lams[b]) for b in range(batch)]
    noise_h = list(noises)
    signal_h = [1.0] * batch
    qfn = make_query_fn(spec)

    def compare(where: str) -> None:
        for b in range(batch):
            lane = fleet_lane(fleet, b)
            s = singles[b]
            ctx = (f"seed={seed} kernel={kname} d={d} window={window} "
                   f"lane={b} at={where}")
            assert int(lane.count) == int(s.count), \
                f"count {int(lane.count)} != {int(s.count)} [{ctx}]"
            for fname in ("Z", "X", "G", "lam", "K1e", "L"):
                want = getattr(s, fname)
                # relative on the solution scale: |Z| ~ 1/noise on near-
                # singular draws, and vmap's batched matmuls legitimately
                # round differently from the single-lane kernels
                sc = max(1.0, float(jnp.max(jnp.abs(want))))
                e = float(jnp.max(jnp.abs(getattr(lane, fname) - want)))
                assert e <= TOL * sc, \
                    f"{fname} err={e:.3e} scale={sc:.1e} [{ctx}]"

    fj = _fleet_jit(kname, window, refit_steps)
    sj = _single_jit(kname, refit_steps)
    for step, (op, mask, sub) in enumerate(gen_fleet_ops(seed, steps, batch)):
        r = np.random.RandomState(sub)
        if op == "extend":
            X, G = r.randn(batch, d), r.randn(batch, d)
            fleet = fj["extend"](fleet, jnp.asarray(X), jnp.asarray(G),
                                 jnp.asarray(mask))
            for b in np.flatnonzero(mask):
                nz = jnp.asarray(noise_h[b] / signal_h[b])
                if int(singles[b].count) >= window:
                    singles[b] = sj["evict_nosolve"](singles[b], nz)
                singles[b] = sj["extend"](singles[b], jnp.asarray(X[b]),
                                          jnp.asarray(G[b]), nz)
        elif op == "evict":
            fleet = fj["evict"](fleet, jnp.asarray(mask))
            for b in np.flatnonzero(mask):
                if int(singles[b].count) > 0:
                    singles[b] = sj["evict"](
                        singles[b],
                        jnp.asarray(noise_h[b] / signal_h[b]))
        elif op == "refit":
            fleet, _ = fj["refit"](fleet, jnp.asarray(mask))
            for b in np.flatnonzero(mask):
                if int(singles[b].count) >= 2:
                    singles[b], nz_f, sg_f = sj["refit"](
                        singles[b], jnp.asarray(noise_h[b]),
                        jnp.asarray(signal_h[b]), 0.1)
                    noise_h[b], signal_h[b] = float(nz_f), float(sg_f)
        elif op == "query":
            Xq = r.randn(batch, 3, d)
            got = fj["query"](fleet, jnp.asarray(Xq))
            for b in np.flatnonzero(mask):
                want = qfn(_factors_of(singles[b]), singles[b].Z,
                           jnp.asarray(Xq[b]))
                sc = max(1.0, float(jnp.max(jnp.abs(want.value))),
                         float(jnp.max(jnp.abs(want.grad))))
                e = max(float(jnp.max(jnp.abs(got.value[b] - want.value))),
                        float(jnp.max(jnp.abs(got.grad[b] - want.grad))))
                assert e <= TOL * sc, (
                    f"posterior err={e:.3e} scale={sc:.1e} [seed={seed} "
                    f"kernel={kname} lane={b} step={step}]")
        compare(f"step{step}:{op}")


# ---------------------------------------------------------------------------
# Crash-consistent recovery trajectories (repro.resilience)
# ---------------------------------------------------------------------------
#
# The recovery invariant under fuzz: a trajectory that snapshots, crashes
# and restores (snapshot + journal replay) must land on EXACTLY the bits
# of the uninterrupted run — same host methods, same jitted executables,
# verbatim leaf restore, digest-checked journal payloads.  The dense
# oracle still certifies every post-op state, so recovery cannot "pass"
# by restoring into a subtly wrong posterior.

_RECOVERY_FIELDS = ("X", "G", "Xt", "K1e", "K2e", "L", "Z", "lam", "count")


def gen_recovery_ops(seed: int, n_ops: int, cap: int) -> list:
    """Mutating-op tape for the recovery fuzz (payload sub-seeds)."""
    rnd = np.random.RandomState(seed)
    ops, count = [], 0
    for _ in range(n_ops):
        cands = ["extend"] if count == 0 else ["extend", "extend", "evict",
                                               "resolve"]
        op = cands[rnd.randint(len(cands))]
        ops.append((op, int(rnd.randint(2**31 - 1))))
        count = min(cap, count + 1) if op == "extend" else \
            max(0, count - 1) if op == "evict" else count
    return ops


def _drive_single(st, ops, *, seed, kname, journal=None):
    """Apply an op tape to a ``GPGState`` (journaling mutations that
    actually executed), dense-oracle-checking Z after every op."""
    d = st.d
    rhs_override = None
    for step, (op, sub) in enumerate(ops):
        r = np.random.RandomState(sub)
        if op == "extend":
            x, g = r.randn(d), r.randn(d)
            st.extend(x, g)
            rhs_override = None
            if journal is not None:
                journal.record("extend", payload={"x": x, "g": g})
        elif op == "evict":
            if st.n <= 1:
                continue
            st.evict()
            rhs_override = None
            if journal is not None:
                journal.record("evict", args={"k": 1})
        elif op == "resolve":
            if st.n == 0:
                continue
            rhs_override = r.randn(st.n, d)
            st.resolve(jnp.asarray(rhs_override))
            if journal is not None:
                journal.record("resolve", payload={"rhs": rhs_override})
        n = st.n
        if n == 0:
            continue
        R = st.G if rhs_override is None else jnp.asarray(rhs_override)
        Z_oracle = dense_solve(st.spec, st.X, R, lam=st.data.lam,
                               noise=st._noise_eff, jitter=0.0)
        scale = max(1.0, float(jnp.max(jnp.abs(Z_oracle))))
        err = float(jnp.max(jnp.abs(st.Z - Z_oracle)))
        assert err <= TOL * scale, (
            f"Z vs dense oracle err={err:.3e} scale={scale:.1e} "
            f"[recovery seed={seed} kernel={kname} step={step} op={op}]")
    return st


def _assert_bitwise(a_data, b_data, *, ctx: str, fields=_RECOVERY_FIELDS):
    for f in fields:
        want = np.asarray(getattr(a_data, f))
        got = np.asarray(getattr(b_data, f))
        assert np.array_equal(got, want, equal_nan=True), (
            f"leaf {f!r} differs after recovery (max |diff|="
            f"{np.max(np.abs(got.astype(np.float64) - want.astype(np.float64))):.3e}) [{ctx}]")


def check_recovery_single(kname: str, d: int, cap: int, seed: int,
                          root: str, n_ops: int = 9,
                          noise: float = 1e-6, lam: float = 0.7) -> None:
    """Snapshot / crash / journal-replay a ``GPGState`` trajectory and
    assert the recovered state is BIT-IDENTICAL to the uninterrupted run
    (dense-oracle-checked along both paths)."""
    import os

    from repro.core.state import GPGState
    from repro.resilience import (Journal, replay_single, restore,
                                  take_snapshot)

    ops = gen_recovery_ops(seed, n_ops, cap)
    snap_at, crash_at = max(1, n_ops // 3), max(2, 2 * n_ops // 3)
    mk = lambda: GPGState(kname, d, window=cap, lam=lam, noise=noise)
    ctx = f"seed={seed} kernel={kname} d={d} cap={cap}"

    # uninterrupted reference
    ref = _drive_single(mk(), ops, seed=seed, kname=kname)

    # snapshot -> journal -> crash -> restore -> replay -> tail
    jpath = os.path.join(root, "ops.jsonl")
    journal = Journal(jpath)
    live = _drive_single(mk(), ops[:snap_at], seed=seed, kname=kname)
    take_snapshot(live, root, step=snap_at, journal=journal)
    live = _drive_single(live, ops[snap_at:crash_at], seed=seed,
                         kname=kname, journal=journal)
    crashed_data = live.data
    del live                                    # the crash
    recovered = restore(root)
    replay_single(recovered,
                  Journal.since_snapshot(Journal.read(jpath)))
    _assert_bitwise(crashed_data, recovered.data,
                    ctx=f"{ctx} at=crash-point")
    recovered = _drive_single(recovered, ops[crash_at:], seed=seed,
                              kname=kname)
    _assert_bitwise(ref.data, recovered.data, ctx=f"{ctx} at=end")


def gen_fleet_recovery_ops(seed: int, steps: int, batch: int) -> list:
    """Per step: (op, tenant index list, payload sub-seed)."""
    rnd = np.random.RandomState(seed)
    out = []
    for _ in range(steps):
        op = ["extend", "extend", "extend", "evict", "resolve"][
            rnd.randint(5)]
        mask = rnd.rand(batch) < 0.7
        if not mask.any():
            mask[rnd.randint(batch)] = True
        out.append((op, [int(b) for b in np.flatnonzero(mask)],
                    int(rnd.randint(2**31 - 1))))
    return out


def _drive_fleet(fl, ops, tenants, *, journal=None):
    """Apply a grouped-op tape to a ``GPFleet`` (journaling executed
    launches with their exact grouping)."""
    d = fl.d
    for op, lanes, sub in ops:
        r = np.random.RandomState(sub)
        group = [tenants[b] for b in lanes]
        if op == "extend":
            obs = {t: (r.randn(d), r.randn(d)) for t in group}
            fl.extend(obs)
            if journal is not None:
                journal.record_fleet("extend", per_tenant={
                    t: {"x": x, "g": g} for t, (x, g) in obs.items()})
        elif op == "evict":
            group = [t for t in group if fl.n(t) > 1]
            if not group:
                continue
            fl.evict(group)
            if journal is not None:
                journal.record("evict", tenants=group)
        elif op == "resolve":
            group = [t for t in group if fl.n(t) > 0]
            if not group:
                continue
            rhs = {t: r.randn(fl.n(t), d) for t in group}
            fl.resolve(rhs)
            if journal is not None:
                journal.record_fleet("resolve", per_tenant={
                    t: {"rhs": v} for t, v in rhs.items()})
    return fl


def check_recovery_fleet(kname: str, d: int, window: int, seed: int,
                         root: str, steps: int = 6, batch: int = 3,
                         restore_batch: int | None = None) -> None:
    """Snapshot / crash / journal-replay a ``GPFleet`` trajectory; the
    recovered fleet must match the uninterrupted run BIT-IDENTICALLY on
    every tenant lane.  ``restore_batch`` restores into a different lane
    packing (elastic) — per-lane bits must still match, because the
    vmapped ops are lane-independent and the journal replays the same
    grouped launches."""
    import os

    from repro.core.fleet import GPFleet
    from repro.resilience import (Journal, replay_fleet, restore,
                                  take_snapshot)

    tenants = [f"t{b}" for b in range(batch)]
    ops = gen_fleet_recovery_ops(seed, steps, batch)
    snap_at, crash_at = max(1, steps // 3), max(2, 2 * steps // 3)
    ctx = (f"seed={seed} kernel={kname} d={d} window={window} "
           f"batch={batch}->{restore_batch or batch}")

    def mk():
        fl = GPFleet(kname, d=d, batch=batch, window=window)
        for t in tenants:
            fl.join(t)
        return fl

    ref = _drive_fleet(mk(), ops, tenants)

    jpath = os.path.join(root, "fleet_ops.jsonl")
    journal = Journal(jpath)
    live = _drive_fleet(mk(), ops[:snap_at], tenants)
    take_snapshot(live, root, step=snap_at, journal=journal)
    live = _drive_fleet(live, ops[snap_at:crash_at], tenants,
                        journal=journal)
    del live                                    # the crash
    recovered = restore(root, batch=restore_batch)
    replay_fleet(recovered, Journal.since_snapshot(Journal.read(jpath)))
    recovered = _drive_fleet(recovered, ops[crash_at:], tenants)

    for t in tenants:
        _assert_bitwise(ref.state_view(t), recovered.state_view(t),
                        ctx=f"{ctx} tenant={t}")

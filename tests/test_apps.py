"""Application-level tests: probabilistic linear solvers (Fig. 2) and
HMC / GPG-HMC (Fig. 5) — reduced sizes so the suite stays fast."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.linalg import (cg_solve, hessian_probabilistic_solver,
                          make_test_matrix, solution_probabilistic_solver)
from repro.sampling import banana_energy, banana_energy_rotated, gpg_hmc, hmc, random_rotation


@pytest.fixture(scope="module")
def linalg_problem():
    D = 40
    A = make_test_matrix(D, seed=0)
    rng = np.random.RandomState(1)
    x0 = jnp.asarray(rng.randn(D) * 5)
    xstar = jnp.asarray(rng.randn(D) - 2)
    return A, A @ xstar, x0, xstar


def test_test_matrix_spectrum(linalg_problem):
    A, b, x0, xstar = linalg_problem
    ev = jnp.linalg.eigvalsh(A)
    assert abs(float(ev.min()) - 0.5) < 1e-6
    assert abs(float(ev.max()) - 100.0) < 1e-6
    assert int(jnp.sum(ev > 1.0)) < 20          # ~15 large eigenvalues


def test_cg_converges_fast(linalg_problem):
    A, b, x0, xstar = linalg_problem
    tr = cg_solve(A, b, x0, tol=1e-5, max_iters=60)
    assert tr.relres[-1] <= 1e-5
    assert tr.iters <= 25


def test_solution_solver_tracks_cg(linalg_problem):
    """Paper Fig. 2: the GP-X solution solver performs similarly to CG."""
    A, b, x0, xstar = linalg_problem
    cg = cg_solve(A, b, x0, tol=1e-5, max_iters=60)
    gpx = solution_probabilistic_solver(A, b, x0, tol=1e-5, max_iters=60)
    assert gpx.relres[-1] <= 1e-5
    assert gpx.iters <= cg.iters * 2 + 3
    # kappa = 200: relres 1e-5 bounds x-error by ~kappa*1e-5*|x0 - x*|
    assert jnp.max(jnp.abs(gpx.x - xstar)) < 0.05


def test_hessian_solver_converges_slower(linalg_problem):
    """Paper: fixed c=0 'compromises the performance' — it still descends
    but is distinctly slower than CG/GP-X."""
    A, b, x0, xstar = linalg_problem
    gph = hessian_probabilistic_solver(A, b, x0, tol=1e-5, max_iters=40)
    assert gph.relres[-1] < 0.9          # monotone-ish progress
    cg = cg_solve(A, b, x0, tol=1e-5, max_iters=40)
    assert gph.relres[-1] > cg.relres[-1]


def test_hmc_samples_gaussian_marginals():
    D = 16
    key = jax.random.PRNGKey(0)
    x0 = jax.random.normal(key, (D,))
    res = hmc(banana_energy, x0, key, n_samples=800, eps=0.05, steps=20)
    assert 0.5 < float(res.accept_rate) <= 1.0
    # dims >= 3 are N(0, 1/2): check sample std
    tail = res.samples[200:, 3:]
    std = jnp.std(tail)
    assert abs(float(std) - math.sqrt(0.5)) < 0.15


def test_gpg_hmc_budget_and_validity():
    """GPG-HMC trains on ~sqrt(D) true gradients and still produces valid
    samples with usable acceptance (paper Sec. 5.3 qualitative claim)."""
    D = 36
    key = jax.random.PRNGKey(0)
    x0 = jax.random.normal(key, (D,))
    budget = int(math.sqrt(D))
    res = gpg_hmc(banana_energy, x0, jax.random.PRNGKey(1), n_samples=300,
                  eps=0.002, steps=64, lengthscale2=0.4 * D, budget=budget,
                  max_train_iters=400)
    assert res.surrogate.X.shape[0] <= budget
    assert res.n_true_grad_calls <= 3 * budget
    assert res.accept_rate > 0.3
    tail = res.samples[100:, 3:]
    assert abs(float(jnp.std(tail)) - math.sqrt(0.5)) < 0.2


def test_rotated_target_energy_invariant():
    D = 10
    R = random_rotation(D, seed=4)
    e = banana_energy_rotated(R)
    x = jax.random.normal(jax.random.PRNGKey(2), (D,))
    assert jnp.allclose(e(x), banana_energy(R @ x))

"""Single-sweep fused factor build + precision policy (DESIGN.md sec. 12).

Three claim families:
  * kernel parity: ``fused_factor_build`` (Pallas, interpret mode) against
    the ref.py oracle across shapes/dtypes/scalings;
  * structural single-sweep: the lowered ``woodbury_solve`` and query
    microbatch consume the X data stream in exactly ONE factor-build
    (reduction) contraction plus the one unavoidable output-assembly
    stream — counted on the jaxpr by ``utils.hlo.count_data_streams``;
  * precision: bf16 storage / f32 accumulation tracks the f32 pipeline to
    <= 1e-3 normwise on every fused entry point, and the state/serve
    layers cache the bf16 stream copies per revision.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import (build_factor_bundle, build_factors, dense_solve,
                        get_kernel, use_backend, use_precision,
                        woodbury_solve)
from repro.core import backend
from repro.core.query import _query_chunk, posterior_batch
from repro.core.state import GPGState
from repro.kernels import fused_factor_build, fused_factor_build_ref
from repro.utils.hlo import count_data_streams

D_STREAM = 384  # > max(N, Q)^2 for every shape below: the taint axis is unambiguous


def _rel(a, b):
    a = jnp.asarray(a, jnp.float64)
    b = jnp.asarray(b, jnp.float64)
    return float(jnp.linalg.norm(a - b) / (jnp.linalg.norm(b) + 1e-30))


# ---------------------------------------------------------------------------
# Kernel parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("na,nb,d", [(3, 5, 64), (8, 8, 128), (5, 12, 1000),
                                     (1, 1, 257)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("lam_kind", ["scalar", "diag"])
def test_fused_factor_build_parity(na, nb, d, dtype, lam_kind, rng):
    A = jax.random.normal(jax.random.fold_in(rng, 1), (na, d),
                          jnp.float32).astype(dtype)
    B = jax.random.normal(jax.random.fold_in(rng, 2), (nb, d),
                          jnp.float32).astype(dtype)
    V = jax.random.normal(jax.random.fold_in(rng, 3), (nb, d),
                          jnp.float32).astype(dtype)
    lam = 0.4 if lam_kind == "scalar" else \
        jnp.abs(jax.random.normal(jax.random.fold_in(rng, 4), (d,))) + 0.1
    vs = lam if lam_kind == "diag" else 0.8
    got = fused_factor_build(A, B, V, lam, v_scale=vs, interpret=True)
    want = fused_factor_build_ref(A, B, V, lam, vs)
    for g, w in zip(got, want):
        assert g.dtype == jnp.float32  # f32 outputs regardless of storage
        assert _rel(g, w.reshape(g.shape)) < 1e-5


def test_fused_factor_build_v_none_reuses_b(rng):
    A = jax.random.normal(jax.random.fold_in(rng, 1), (4, 200), jnp.float32)
    B = jax.random.normal(jax.random.fold_in(rng, 2), (6, 200), jnp.float32)
    got = fused_factor_build(A, B, None, 0.5, interpret=True)
    want = fused_factor_build(A, B, B, 0.5, interpret=True)
    for g, w in zip(got, want):
        assert jnp.array_equal(g, w)


def test_fused_factor_build_padding_exact(rng):
    """Zero lam/vs pad lanes kill garbage pad columns exactly."""
    A = jax.random.normal(jax.random.fold_in(rng, 1), (4, 1000))
    B = jax.random.normal(jax.random.fold_in(rng, 2), (6, 1000))
    V = jax.random.normal(jax.random.fold_in(rng, 3), (6, 1000))
    got = fused_factor_build(A, B, V, 1.0, v_scale=1.0, interpret=True)
    junk = 1e6 * jnp.ones((16, 24))
    ext = lambda M: jnp.concatenate([M, junk[: M.shape[0]]], axis=1)
    lam2 = jnp.concatenate([jnp.ones(1000), jnp.zeros(24)])
    embedded = fused_factor_build(ext(A), ext(B), ext(V), lam2, v_scale=lam2,
                                  interpret=True)
    for g, e in zip(got, embedded):
        assert jnp.array_equal(g, e)


@pytest.mark.parametrize("name", ["rbf", "expdot"])
def test_backend_fused_factor_build_parity(name, rng):
    """pallas (interpret) and jnp backends agree through the dispatch."""
    d = 96
    A = jax.random.normal(jax.random.fold_in(rng, 1), (5, d))
    B = jax.random.normal(jax.random.fold_in(rng, 2), (7, d))
    V = jax.random.normal(jax.random.fold_in(rng, 3), (7, d))
    lam = jnp.abs(jax.random.normal(jax.random.fold_in(rng, 4), (d,))) + 0.1
    with use_backend("pallas"):
        p = backend.fused_factor_build(A, B, V, lam, v_scale=lam)
    with use_backend("jnp"):
        j = backend.fused_factor_build(A, B, V, lam, v_scale=lam)
    for gp, gj in zip(p, j):
        assert _rel(gp, gj) < 1e-5


# ---------------------------------------------------------------------------
# Bundle-consuming solves
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["rbf", "expdot"])
def test_bundle_solve_matches_dense(name, rng):
    n, d = 5, 24
    spec = get_kernel(name)
    c = None if spec.is_stationary else \
        0.05 * jax.random.normal(jax.random.fold_in(rng, 9), (d,))
    X = jax.random.normal(jax.random.fold_in(rng, 1), (n, d))
    G = jax.random.normal(jax.random.fold_in(rng, 2), (n, d))
    b = build_factor_bundle(spec, X, G, lam=0.5, c=c)
    Z = woodbury_solve(spec, b.factors, G, bundle=b)
    Zref = dense_solve(spec, X, G, lam=0.5, c=c)
    assert _rel(Z, Zref) < 1e-6


@pytest.mark.parametrize("name", ["rbf", "expdot", "poly2"])
def test_bundle_solve_identical_to_unbundled(name, rng):
    """Passing the prebuilt bundle must not change the solve AT ALL —
    same S/C contractions, just computed in the shared sweep."""
    n, d = 5, 24
    spec = get_kernel(name)
    c = None if spec.is_stationary else \
        0.05 * jax.random.normal(jax.random.fold_in(rng, 9), (d,))
    X = jax.random.normal(jax.random.fold_in(rng, 1), (n, d))
    G = jax.random.normal(jax.random.fold_in(rng, 2), (n, d))
    b = build_factor_bundle(spec, X, G, lam=0.5, c=c)
    f = build_factors(spec, X, lam=0.5, c=c)
    Z0 = woodbury_solve(spec, f, G)
    Zb = woodbury_solve(spec, b.factors, G, bundle=b)
    assert jnp.array_equal(Z0, Zb)


def test_bundle_matches_build_factors(rng):
    """build_factor_bundle == build_factors + the separate contractions."""
    n, d = 6, 40
    for name in ("rbf", "expdot"):
        spec = get_kernel(name)
        c = None if spec.is_stationary else jnp.full((d,), 0.02)
        X = jax.random.normal(jax.random.fold_in(rng, 1), (n, d))
        G = jax.random.normal(jax.random.fold_in(rng, 2), (n, d))
        b = build_factor_bundle(spec, X, G, lam=0.3, c=c)
        f = build_factors(spec, X, lam=0.3, c=c)
        assert _rel(b.factors.K1e, f.K1e) < 1e-12
        assert _rel(b.factors.K2e, f.K2e) < 1e-12
        assert _rel(b.S, (f.Xt * 0.3) @ f.Xt.T) < 1e-12
        assert _rel(b.C, G @ f.Xt.T) < 1e-12


# ---------------------------------------------------------------------------
# Structural single-sweep asserts (the acceptance-criteria jaxpr gate)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["rbf", "expdot"])
def test_woodbury_single_x_stream(name, rng):
    """The lowered exact solve consumes the X stream in exactly ONE
    factor-build contraction (plus the one output-assembly stream)."""
    n, d = 5, D_STREAM
    spec = get_kernel(name)
    c = None if spec.is_stationary else jnp.full((d,), 0.01, jnp.float32)
    X = jax.random.normal(jax.random.fold_in(rng, 1), (n, d), jnp.float32)
    G = jax.random.normal(jax.random.fold_in(rng, 2), (n, d), jnp.float32)
    with use_backend("pallas"):
        f = build_factors(spec, X, lam=0.5, c=c, noise=1e-3)
        closed = jax.make_jaxpr(
            lambda Xt, g: woodbury_solve(spec, f._replace(Xt=Xt), g))(f.Xt, G)
    streams = count_data_streams(closed, 0, d)
    assert streams == {"reduction": 1, "expansion": 1}, streams


@pytest.mark.parametrize("name", ["rbf", "expdot"])
def test_query_chunk_single_x_stream(name, rng):
    """Per query microbatch: ONE reduction stream of the stored data X
    (and of the query batch), plus only the (Q, D) grad output stream."""
    n, q, d = 5, 4, D_STREAM
    spec = get_kernel(name)
    c = None if spec.is_stationary else jnp.full((d,), 0.01, jnp.float32)
    X = jax.random.normal(jax.random.fold_in(rng, 1), (n, d), jnp.float32)
    Z = jax.random.normal(jax.random.fold_in(rng, 2), (n, d), jnp.float32)
    Xq = jax.random.normal(jax.random.fold_in(rng, 3), (q, d), jnp.float32)
    with use_backend("pallas"):
        f = build_factors(spec, X, lam=0.5, c=c)
        closed = jax.make_jaxpr(
            lambda Xt, z, xq: _query_chunk(spec, xq, f._replace(Xt=Xt), z,
                                           None))(f.Xt, Z, Xq)
    xt_streams = count_data_streams(closed, 0, d)
    xq_streams = count_data_streams(closed, 2, d)
    assert xt_streams == {"reduction": 1, "expansion": 1}, xt_streams
    assert xq_streams["reduction"] == 1, xq_streams


def test_query_chunk_matches_unfused_matvecs(rng):
    """The fused mean chunk == the original cross_*_matvec contractions."""
    from repro.core.mvm import cross_grad_matvec, cross_value_matvec

    n, q, d = 6, 5, 48
    for name in ("rbf", "expdot"):
        spec = get_kernel(name)
        c = None if spec.is_stationary else jnp.full((d,), 0.03)
        X = jax.random.normal(jax.random.fold_in(rng, 1), (n, d))
        Z = jax.random.normal(jax.random.fold_in(rng, 2), (n, d))
        Xq = jax.random.normal(jax.random.fold_in(rng, 3), (q, d))
        f = build_factors(spec, X, lam=0.4, c=c)
        pb = _query_chunk(spec, Xq, f, Z, None)
        assert _rel(pb.value, cross_value_matvec(spec, Xq, f, Z)) < 1e-10
        assert _rel(pb.grad, cross_grad_matvec(spec, Xq, f, Z)) < 1e-10


# ---------------------------------------------------------------------------
# Precision policy: bf16 storage / f32 accumulation
# ---------------------------------------------------------------------------

def test_precision_resolution():
    assert backend.resolve_precision() in ("f32", "bf16")
    with use_precision("bf16"):
        assert backend.resolve_precision() == "bf16"
        assert backend.stream_dtype() == jnp.bfloat16
    assert backend.stream_dtype("f32") == jnp.float32
    with pytest.raises(ValueError):
        backend.set_precision("fp8")
    with pytest.raises(ValueError):
        backend.stream_dtype("f16")


@pytest.mark.parametrize("name", ["rbf", "expdot"])
def test_posterior_batch_bf16_tracks_f32(name, rng):
    """bf16 streams track the f32 query pipeline to ~storage precision."""
    n, q, d = 6, 9, 512
    spec = get_kernel(name)
    c = None if spec.is_stationary else jnp.full((d,), 0.01, jnp.float32)
    X = jax.random.normal(jax.random.fold_in(rng, 1), (n, d), jnp.float32)
    Z = jax.random.normal(jax.random.fold_in(rng, 2), (n, d), jnp.float32)
    Xq = 0.3 * jax.random.normal(jax.random.fold_in(rng, 3), (q, d),
                                 jnp.float32)
    f = build_factors(spec, X, lam=1.0 / d, c=c)
    pb32 = posterior_batch(spec, Xq, f, Z, precision="f32")
    pb16 = posterior_batch(spec, Xq, f, Z, precision="bf16")
    assert pb16.grad.dtype == jnp.float32   # outputs never round to bf16
    # end-to-end error is storage quantization (~1e-3) amplified by the
    # kernel nonlinearity and value-sum cancellation — the KERNEL-level
    # <=1e-3 contract (same stored data) is gated in test_kernels_pallas
    assert _rel(pb16.value, pb32.value) < 3e-2
    assert _rel(pb16.grad, pb32.grad) < 1e-2


def test_state_caches_bf16_stream_copies(rng):
    d = 32
    X = jax.random.normal(jax.random.fold_in(rng, 1), (5, d))
    G = jax.random.normal(jax.random.fold_in(rng, 2), (5, d))
    st = GPGState.from_data("rbf", X, G, lam=1.0 / d, noise=1e-8,
                            precision="bf16")
    f1, z1 = st.stream_factors
    assert f1.Xt.dtype == jnp.bfloat16
    assert z1.dtype != jnp.bfloat16         # Z is a solve output: NEVER bf16
    assert f1.shift is not None             # stationary: spread-scale coords
    f2, z2 = st.stream_factors
    assert f2.Xt is f1.Xt and z2 is z1      # cached per revision
    st.extend(X[0] + 0.1, G[0])
    f3, _ = st.stream_factors
    assert f3.Xt is not f1.Xt               # revision bumped -> fresh copies
    # posterior means off the bf16 stream track the f32 state
    st32 = GPGState.from_data("rbf", st.X, st.G, lam=1.0 / d, noise=1e-8)
    pb16 = st.posterior(X[:3])
    pb32 = st32.posterior(X[:3])
    assert _rel(pb16.grad, pb32.grad) < 2e-2
    assert _rel(pb16.value, pb32.value) < 2e-2


def test_bf16_clustered_window_no_cancellation_blowup(rng):
    """The failure mode that forced both precision rules (DESIGN 12.2):
    an optimizer-style CLUSTERED window (spread 0.05 at |x| ~ sqrt(D))
    has |Z| >> |grad| and r/m assembled from near-equal norms.  Naive
    bf16 storage (absolute coords + quantized Z) measured ~12% grad
    error here; the shipped policy (shifted coords, f32 Z) must stay at
    storage precision."""
    from repro.configs.paper_gp import GPServeConfig
    from repro.train.serve import build_gp_serve_step

    d = 1024
    key = jax.random.fold_in(rng, 77)
    fobj = lambda x: jnp.sum(jnp.sin(x) * jnp.roll(x, 1)) / d
    gf = jax.grad(fobj)
    st = GPGState("rbf", d=d, window=6, lam=1.0 / d, noise=1e-8,
                  dtype=jnp.float32)
    x = jax.random.normal(key, (d,), jnp.float32)
    for s in range(7):
        st.extend(x, gf(x))
        x = x + 0.05 * jax.random.normal(jax.random.fold_in(key, s), (d,),
                                         jnp.float32)
    Xq = x[None] + 0.02 * jax.random.normal(jax.random.fold_in(key, 99),
                                            (9, d), jnp.float32)
    ref = st.posterior(Xq)
    srv16 = build_gp_serve_step(st, config=GPServeConfig(microbatch=4,
                                                         precision="bf16"))
    out = srv16.query(Xq)
    assert _rel(out.grad, ref.grad) < 1e-3, _rel(out.grad, ref.grad)
    assert _rel(out.value, ref.value) < 3e-2
    # the state's own posterior path (cached shifted stream) agrees too
    pb = st.posterior(Xq)
    assert _rel(pb.grad, ref.grad) < 1e-3


def test_bf16_dot_kernel_centers_before_cast(rng):
    """Dot-kernel twin of the clustered-window rule: with data near a
    large center c, queries must be centered BEFORE bf16 quantization on
    the pre-quantized (cached/serve) path too — cast-then-center loses
    |x|/|x-c| of the resolution the centered storage keeps."""
    from repro.core import build_factors

    n, q, d = 6, 5, 1024
    spec = get_kernel("expdot")
    c = 3.0 * jax.random.normal(jax.random.fold_in(rng, 9), (d,),
                                jnp.float32)
    X = c[None] + 0.05 * jax.random.normal(jax.random.fold_in(rng, 1),
                                           (n, d), jnp.float32)
    Z = jax.random.normal(jax.random.fold_in(rng, 2), (n, d), jnp.float32)
    Xq = c[None] + 0.05 * jax.random.normal(jax.random.fold_in(rng, 3),
                                            (q, d), jnp.float32)
    f = build_factors(spec, X, lam=1.0 / d, c=c)
    ref = _query_chunk(spec, Xq, f, Z, None)
    # the pre-quantized view the state/serve layers cache: centered bf16 Xt
    f16 = f._replace(Xt=f.Xt.astype(jnp.bfloat16))
    pb = _query_chunk(spec, Xq, f16, Z, None)
    assert _rel(pb.grad, ref.grad) < 5e-3, _rel(pb.grad, ref.grad)
    assert _rel(pb.value, ref.value) < 5e-3, _rel(pb.value, ref.value)
    # and the in-chunk quantization path agrees
    pb2 = _query_chunk(spec, Xq, f, Z, None, stream_dt=jnp.bfloat16)
    assert _rel(pb2.grad, ref.grad) < 5e-3


def test_serve_step_bf16_precision(rng):
    from repro.configs.paper_gp import GPServeConfig
    from repro.train.serve import build_gp_serve_step

    d = 24
    X = jax.random.normal(jax.random.fold_in(rng, 1), (4, d))
    G = jax.random.normal(jax.random.fold_in(rng, 2), (4, d))
    st = GPGState.from_data("rbf", X, G, lam=1.0 / d, noise=1e-8)
    ref = st.posterior(X)
    srv = build_gp_serve_step(st, config=GPServeConfig(microbatch=2,
                                                       precision="bf16"))
    assert st.precision == "bf16"
    out = srv.query(X)
    assert _rel(out.grad, ref.grad) < 5e-3


# ---------------------------------------------------------------------------
# Serving-layer LRU solver cache
# ---------------------------------------------------------------------------

def test_serve_solver_cache_is_bounded_lru(rng):
    from repro.train.serve import GPServeBundle, build_gp_serve_step

    d = 16
    X = jax.random.normal(jax.random.fold_in(rng, 1), (4, d))
    G = jax.random.normal(jax.random.fold_in(rng, 2), (4, d))
    st = GPGState.from_data("rbf", X, G, lam=1.0 / d, noise=1e-6)
    srv = build_gp_serve_step(st, microbatch=2, return_std=True)
    s0 = srv.refresh_solver()
    assert srv.refresh_solver() is s0          # hit on unchanged revision
    for i in range(2 + GPServeBundle._SOLVER_CACHE_MAX):
        st.extend(X[0] + 0.01 * (i + 1), G[0])  # new revision each time
        srv.refresh_solver()
        assert len(srv._solver_cache) <= GPServeBundle._SOLVER_CACHE_MAX
    # the original (evicted) revision would need a rebuild; current hits
    s_now = srv.refresh_solver()
    assert srv.refresh_solver() is s_now

"""Core Gram-matrix structure vs autodiff ground truth (paper Sec. 2.2).

Every kernel's dense gradient-Gram assembly is checked against the Hessian
of the scalar kernel obtained by jax.jacfwd(jax.grad(...)) — the ultimate
oracle for Eq. 2/3/4.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import (build_factors, dense_cross_gram, dense_gram,
                        get_kernel, gram_matvec, pairwise_r)

N, D = 5, 7
LAM = 0.7

KERNELS = ["rbf", "matern32", "matern52", "rq", "poly2", "poly3", "expdot"]


def kernel_fn(spec, c=None):
    def k(xa, xb):
        if spec.is_stationary:
            d = xa - xb
            r = jnp.sum(d * LAM * d)
        else:
            xat = xa if c is None else xa - c
            xbt = xb if c is None else xb - c
            r = jnp.sum(xat * LAM * xbt)
        return spec.k0(r)

    return k


def data(name, rng):
    spec = get_kernel(name)
    c = None
    if not spec.is_stationary:
        c = jax.random.normal(jax.random.fold_in(rng, 99), (D,)) * 0.1
    X = jax.random.normal(jax.random.fold_in(rng, 1), (N, D))
    return spec, X, c


@pytest.mark.parametrize("name", KERNELS)
def test_dense_gram_matches_autodiff(name, rng):
    spec, X, c = data(name, rng)
    k = kernel_fn(spec, c)
    hess = jax.jacfwd(jax.grad(k, argnums=0), argnums=1)
    blocks = jax.vmap(lambda xa: jax.vmap(lambda xb: hess(xa, xb))(X))(X)
    full_ad = blocks.transpose(0, 2, 1, 3).reshape(N * D, N * D)
    full = dense_gram(spec, X, lam=LAM, c=c)
    if spec.is_stationary:
        # autodiff of sqrt(r) at r=0 NaNs on diagonal blocks for Matern;
        # compare off-diagonal blocks there (the clamped closed form is the
        # exact limit — validated by the PSD test below)
        mask = ~jnp.isnan(full_ad)
        assert jnp.mean(mask) > 0.7
        err = jnp.max(jnp.abs(jnp.where(mask, full - full_ad, 0.0)))
    else:
        err = jnp.max(jnp.abs(full - full_ad))
    scale = jnp.max(jnp.abs(jnp.where(jnp.isnan(full_ad), 0.0, full_ad)))
    assert err / scale < 1e-10, f"{name}: {err/scale}"


@pytest.mark.parametrize("name", ["rbf", "rq", "poly2", "expdot"])
def test_gram_psd(name, rng):
    """Gradient Gram matrices are covariance matrices => PSD."""
    spec, X, c = data(name, rng)
    full = dense_gram(spec, X, lam=LAM, c=c)
    evals = jnp.linalg.eigvalsh((full + full.T) / 2)
    assert evals.min() > -1e-8 * max(float(evals.max()), 1.0)


@pytest.mark.parametrize("name", KERNELS)
def test_matvec_matches_dense(name, rng):
    spec, X, c = data(name, rng)
    V = jax.random.normal(jax.random.fold_in(rng, 3), (N, D))
    f = build_factors(spec, X, lam=LAM, c=c)
    w = gram_matvec(f, V, stationary=spec.is_stationary)
    full = dense_gram(spec, X, lam=LAM, c=c)
    w_dense = (full @ V.reshape(-1)).reshape(N, D)
    assert jnp.allclose(w, w_dense, rtol=1e-9, atol=1e-9)


@pytest.mark.parametrize("name", ["rbf", "poly2"])
def test_matvec_noise_and_diag_lam(name, rng):
    spec, X, c = data(name, rng)
    lam = jnp.abs(jax.random.normal(jax.random.fold_in(rng, 5), (D,))) + 0.1
    V = jax.random.normal(jax.random.fold_in(rng, 3), (N, D))
    f = build_factors(spec, X, lam=lam, c=c, noise=0.3)
    w = gram_matvec(f, V, stationary=spec.is_stationary)
    full = dense_gram(spec, X, lam=lam, c=c, noise=0.3)
    w_dense = (full @ V.reshape(-1)).reshape(N, D)
    assert jnp.allclose(w, w_dense, rtol=1e-9, atol=1e-9)


@pytest.mark.parametrize("name", ["rbf", "poly2", "expdot"])
def test_cross_gram_consistent_with_square(name, rng):
    spec, X, c = data(name, rng)
    cross = dense_cross_gram(spec, X, X, lam=LAM, c=c)
    full = dense_gram(spec, X, lam=LAM, c=c)
    assert jnp.allclose(cross, full, rtol=1e-10, atol=1e-12)


def test_pairwise_r_nonnegative_stationary(rng):
    spec = get_kernel("rbf")
    X = jax.random.normal(rng, (N, D))
    r = pairwise_r(spec, X, X, 0.5)
    assert (r >= 0).all()
    assert jnp.allclose(jnp.diagonal(r), 0.0, atol=1e-12)

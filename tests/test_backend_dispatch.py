"""Backend-dispatch layer: resolution rules, pallas/jnp parity through the
full solver stack, single-launch guarantee for the fused MVM, and the
no-raw-hot-path source contract for the exact/iterative solvers."""
import pathlib

import jax
import jax.numpy as jnp
import pytest

from repro.core import (build_factors, get_kernel, gram_cg_solve,
                        gram_cg_solve_multi, gram_matvec, gram_matvec_multi,
                        resolve_backend, set_backend, use_backend,
                        woodbury_solve)
from repro.core import backend

SRC = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro"


def _setup(name, rng, n=5, d=64, dtype=jnp.float64):
    spec = get_kernel(name)
    c = None if spec.is_stationary else \
        jax.random.normal(jax.random.fold_in(rng, 9), (d,), dtype) * 0.05
    X = jax.random.normal(jax.random.fold_in(rng, 1), (n, d), dtype)
    G = jax.random.normal(jax.random.fold_in(rng, 2), (n, d), dtype)
    return spec, X, G, c


# ---------------------------------------------------------------------------
# Resolution
# ---------------------------------------------------------------------------

def test_resolution_order(monkeypatch):
    monkeypatch.delenv("REPRO_BACKEND", raising=False)
    assert resolve_backend() in ("jnp", "pallas")
    if jax.default_backend() != "tpu":
        assert resolve_backend() == "jnp"
    monkeypatch.setenv("REPRO_BACKEND", "pallas")
    assert resolve_backend() == "pallas"
    with use_backend("jnp"):
        assert resolve_backend() == "jnp"  # explicit beats env
    assert resolve_backend() == "pallas"
    monkeypatch.delenv("REPRO_BACKEND")
    with pytest.raises(ValueError):
        set_backend("tpu-magic")


# ---------------------------------------------------------------------------
# Parity: the same solves through the pallas kernel path (interpret on CPU)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["rbf", "expdot"])
@pytest.mark.parametrize("lam_kind", ["scalar", "diag"])
def test_gram_matvec_parity(name, lam_kind, rng):
    d = 64
    spec, X, G, c = _setup(name, rng, d=d)
    lam = 0.5 if lam_kind == "scalar" else \
        jnp.abs(jax.random.normal(jax.random.fold_in(rng, 3), (d,))) + 0.2
    noise = 0.0 if lam_kind == "diag" else 1e-2
    with use_backend("jnp"):
        f = build_factors(spec, X, lam=lam, c=c, noise=noise)
        want = gram_matvec(f, G, stationary=spec.is_stationary)
    with use_backend("pallas"):
        got = gram_matvec(f, G, stationary=spec.is_stationary)
    assert jnp.max(jnp.abs(got - want)) / jnp.max(jnp.abs(want)) < 1e-5


@pytest.mark.parametrize("name", ["rbf", "expdot"])
def test_gram_cg_solve_parity(name, rng):
    spec, X, G, c = _setup(name, rng)
    with use_backend("jnp"):
        f = build_factors(spec, X, lam=0.5, c=c, noise=1e-6)
        want = gram_cg_solve(spec, f, G, tol=1e-6).x
    with use_backend("pallas"):
        got = gram_cg_solve(spec, f, G, tol=1e-6, maxiter=200).x
    # pallas path accumulates in f32; compare through the operator
    with use_backend("jnp"):
        rw = gram_matvec(f, got, stationary=spec.is_stationary) - G
    assert float(jnp.linalg.norm(rw) / jnp.linalg.norm(G)) < 1e-3
    assert jnp.max(jnp.abs(got - want)) / jnp.max(jnp.abs(want)) < 1e-2


@pytest.mark.parametrize("name", ["rbf", "expdot"])
def test_woodbury_solve_parity(name, rng):
    spec, X, G, c = _setup(name, rng)
    with use_backend("jnp"):
        f = build_factors(spec, X, lam=0.5, c=c)
        want = woodbury_solve(spec, f, G)
    with use_backend("pallas"):
        got = woodbury_solve(spec, f, G)
    assert jnp.max(jnp.abs(got - want)) / jnp.max(jnp.abs(want)) < 1e-3


def test_cg_multi_matches_single(rng):
    """Joint CG over stacked RHS == per-RHS solves (block-diag operator).

    x64-precision tolerances, so the jnp backend is pinned explicitly —
    the suite must also pass under an exported REPRO_BACKEND=pallas.
    """
    spec, X, G, c = _setup("rbf", rng)
    with use_backend("jnp"):
        f = build_factors(spec, X, lam=0.3, noise=1e-8)
        G2 = jax.random.normal(jax.random.fold_in(rng, 7), G.shape, G.dtype)
        Gs = jnp.stack([G, G2])
        zs = gram_cg_solve_multi(spec, f, Gs, tol=1e-10).x
        for i, g in enumerate([G, G2]):
            z = gram_cg_solve(spec, f, g, tol=1e-10).x
            assert jnp.max(jnp.abs(zs[i] - z)) / jnp.max(jnp.abs(z)) < 1e-6
        W = gram_matvec_multi(f, zs, stationary=spec.is_stationary)
        assert float(jnp.linalg.norm(W - Gs) / jnp.linalg.norm(Gs)) < 1e-8


# ---------------------------------------------------------------------------
# Single-launch guarantee
# ---------------------------------------------------------------------------

from repro.utils.hlo import count_primitive as _count_primitive


def test_single_pallas_call_per_mvm(rng):
    """One fused MVM == exactly one pallas_call in the compiled program."""
    spec, X, G, c = _setup("rbf", rng, d=256, dtype=jnp.float32)
    f = build_factors(spec, X, lam=0.5, noise=1e-3)
    with use_backend("pallas"):
        jaxpr = jax.make_jaxpr(
            lambda v: gram_matvec(f, v, stationary=True))(G)
    assert _count_primitive(jaxpr.jaxpr, "pallas_call") == 1

    with use_backend("pallas"):
        jaxpr = jax.make_jaxpr(
            lambda v: gram_matvec_multi(f, v, stationary=True))(
                jnp.stack([G, G]))
    assert _count_primitive(jaxpr.jaxpr, "pallas_call") == 1


# ---------------------------------------------------------------------------
# Source contract: no raw jnp O(ND) contraction left in the solver modules
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("module,forbidden", [
    ("core/solvers.py", ["K1i @ V", "(K1i @", "@ f.Xt", "f.Xt @", "/ f.lam"]),
    ("core/woodbury.py", ["W0 @", "@ f.Xt.T", "K1i @ G", "K1i @ (G",
                          "f.Xt @ Gt"]),
])
def test_no_raw_hot_path(module, forbidden):
    import re

    src = (SRC / module).read_text()
    # dense_solve is the documented O((ND)^3) test-only reference — exempt.
    src = src.split("def dense_solve", 1)[0]
    # the contract is about code, not the derivations in docstrings/comments
    src = re.sub(r'""".*?"""', "", src, flags=re.S)
    src = "\n".join(line.split("#", 1)[0] for line in src.splitlines())
    for pattern in forbidden:
        assert pattern not in src, (module, pattern)
    assert "backend." in src


def test_backend_vocabulary_parity(rng):
    """Every backend op agrees with its jnp form under the pallas backend."""
    d = 70
    A = jax.random.normal(jax.random.fold_in(rng, 1), (5, d))
    B = jax.random.normal(jax.random.fold_in(rng, 2), (7, d))
    lam = jnp.abs(jax.random.normal(jax.random.fold_in(rng, 3), (d,))) + 0.1
    spec = get_kernel("rbf")
    with use_backend("pallas"):
        p_gram = backend.scaled_gram(A, B, lam)
        p_r = backend.pairwise_r(spec, A, B, lam)
        p_norms = backend.gram_norms(A, B, lam)
    with use_backend("jnp"):
        j_gram = backend.scaled_gram(A, B, lam)
        j_r = backend.pairwise_r(spec, A, B, lam)
        j_norms = backend.gram_norms(A, B, lam)
    assert jnp.allclose(p_gram, j_gram, rtol=1e-5, atol=1e-5)
    assert jnp.allclose(p_r, j_r, rtol=1e-5, atol=1e-5)
    for p, j in zip(p_norms, j_norms):
        assert jnp.allclose(p, j, rtol=1e-5, atol=1e-5)

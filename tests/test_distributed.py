"""Distributed-equivalence tests: shard_map Gram ops == single-device math,
straggler masking, gradient compression. Multi-device cases run in a
subprocess with xla_force_host_platform_device_count=8 so the main test
process keeps the 1-device contract.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.compression import ef_int8_compress, ef_int8_decompress

_SUBPROCESS_SRC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
from functools import partial
from jax.sharding import PartitionSpec as P
try:
    shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map
from repro.core import build_factors, get_kernel, gram_matvec, woodbury_solve
from repro.core.distributed import sharded_gram_matvec, sharded_woodbury_solve
from repro.runtime import masked_gradient_mean
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)
N, D = 6, 64
failures = []
for name in ["rbf", "poly2", "expdot"]:
    spec = get_kernel(name)
    c = None if spec.is_stationary else \
        jax.random.normal(jax.random.fold_in(key, 9), (D,)) * 0.1
    X = jax.random.normal(jax.random.fold_in(key, 1), (N, D))
    G = jax.random.normal(jax.random.fold_in(key, 2), (N, D))
    V = jax.random.normal(jax.random.fold_in(key, 3), (N, D))
    # dot-kernel r grows with D: scale lam so exp/poly stay conditioned
    lam = 0.7 if spec.is_stationary else 0.7 / D
    f = build_factors(spec, X, lam=lam, c=c)
    w_ref = gram_matvec(f, V, stationary=spec.is_stationary)
    w_sh = sharded_gram_matvec(mesh, spec)(f, V)
    e1 = float(jnp.max(jnp.abs(w_sh - w_ref)) / jnp.max(jnp.abs(w_ref)))
    Z_sh = sharded_woodbury_solve(mesh, spec)(X, G, lam=lam, c=c)
    # equivalence with the single-device exact solver (the point of the
    # test): identical math modulo psum reduction order
    Z_ref = woodbury_solve(spec, f, G)
    e2 = float(jnp.max(jnp.abs(Z_sh - Z_ref)) /
               (jnp.max(jnp.abs(Z_ref)) + 1e-300))
    if e1 > 1e-12 or e2 > 1e-4:     # e2: psum ordering noise amplified by
        failures.append((name, e1, e2))  # the inner N^2 solve's conditioning

# straggler masked mean over the data axis
@partial(shard_map, mesh=mesh, in_specs=(P("data"), P("data")),
         out_specs=(P("data"), P()))
def masked(g, alive):
    out, n = masked_gradient_mean({"g": g}, alive[0], "data")
    return out["g"], n

g = jnp.arange(8, dtype=jnp.float64).reshape(2, 4)[:, :1] * jnp.ones((2, 4))
g = jnp.arange(2, dtype=jnp.float64)[:, None] * jnp.ones((2, 4))
alive = jnp.array([1.0, 0.0])
out, n = masked(g, alive)
# only replica 0 alive -> mean == replica 0's grads == zeros
if float(n) != 1.0 or float(jnp.max(jnp.abs(out[0]))) > 1e-12:
    failures.append(("straggler", float(n), float(jnp.max(jnp.abs(out)))))

assert not failures, failures
print("SUBPROCESS_OK")
"""


def test_sharded_ops_match_reference_8dev():
    r = subprocess.run([sys.executable, "-c", _SUBPROCESS_SRC],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")})
    assert "SUBPROCESS_OK" in r.stdout, r.stdout + r.stderr


def test_ef_int8_compression_roundtrip(rng):
    x = jax.random.normal(rng, (1000,)) * 5.0
    err0 = jnp.zeros_like(x)
    codes, scales, err = ef_int8_compress(x, err0)
    back = ef_int8_decompress(codes, scales, 1000)
    # error feedback carries exactly the quantization residual
    assert jnp.allclose(back + err, x, rtol=1e-6, atol=1e-6)
    # quantization error bounded by scale/2 per block
    assert float(jnp.max(jnp.abs(err))) <= float(jnp.max(scales)) * 0.51


def test_ef_compression_error_feedback_converges(rng):
    """Summing dequantized payloads + final error == sum of true grads
    (the EF invariant that keeps SGD unbiased over time)."""
    true = jax.random.normal(rng, (512,))
    err = jnp.zeros_like(true)
    acc = jnp.zeros_like(true)
    for i in range(20):
        codes, scales, err = ef_int8_compress(true, err)
        acc = acc + ef_int8_decompress(codes, scales, 512)
    total_sent = acc + err
    assert jnp.allclose(total_sent, 20.0 * true, rtol=1e-4, atol=1e-4)

"""Multi-tenant fleet (core/fleet.py + train/serve.py::GPFleetServer):
deterministic differential trajectories, packing-order bitwise stability,
the one-compile-per-signature tenant-churn contract, and the padded-
tenant no-taint invariant (NaN-poisoned inactive lanes)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from fuzz_machine import check_fleet_vs_loop, check_single_trajectory
from repro.core import get_kernel
from repro.core.fleet import (GPFleet, fleet_evict, fleet_extend, fleet_init,
                              fleet_lane, fleet_mll, fleet_posterior,
                              fleet_refit, fleet_total_mll)
from repro.obs import compile_watch
from repro.obs import trace as obs
from repro.train.serve import GPFleetServer


@pytest.fixture(autouse=True)
def _clean_registry():
    obs.reset()
    obs.configure(None)
    compile_watch._WATCHES.clear()
    yield
    obs.reset()
    obs.configure(None)
    obs.set_enabled(None)
    compile_watch._WATCHES.clear()


# ---------------------------------------------------------------------------
# Deterministic differential trajectories (the hypothesis front end in
# test_property_invariants.py draws hundreds more of these in CI;
# REPRO_TEST_SEED offsets the pinned seeds to replay a reported failure)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kname,seed", [("rbf", 0), ("poly2", 1),
                                        ("expdot", 2), ("rq", 3)])
def test_fleet_trajectory_matches_host_loop(kname, seed, base_seed):
    check_fleet_vs_loop(kname, d=2 + seed % 3, window=3 + seed % 2,
                        seed=base_seed + seed, steps=6)


@pytest.mark.parametrize("kname,seed", [("rbf", 10), ("poly2", 11)])
def test_state_machine_matches_dense_oracle(kname, seed, base_seed):
    check_single_trajectory(kname, d=3, cap=4, seed=base_seed + seed,
                            n_ops=7)


# ---------------------------------------------------------------------------
# Packing-order bitwise stability
# ---------------------------------------------------------------------------


def _drive(order, rng_seed=5):
    """Same tenant workload, request dicts built in ``order``; returns the
    fleet arrays + a posterior read."""
    r = np.random.RandomState(rng_seed)
    payload = {t: [(r.randn(3), r.randn(3)) for _ in range(4)]
               for t in "abc"}
    queries = {t: r.randn(2, 3) for t in "abc"}
    fl = GPFleet("rbf", d=3, window=4, batch=4)
    for t in "abc":
        fl.join(t, lam=0.5 + 0.25 * ord(t) % 3)
    for i in range(4):
        fl.extend({t: payload[t][i] for t in order})
    out = fl.posterior({t: queries[t] for t in order})
    return fl, out


def test_fleet_packing_order_bitwise_stable():
    """The packed launch is a pure function of (lane payload, lane mask):
    the order requests were packed in must not change a single bit."""
    fl1, out1 = _drive("abc")
    fl2, out2 = _drive("cba")
    for leaf1, leaf2 in zip(jax.tree_util.tree_leaves(fl1.fleet),
                            jax.tree_util.tree_leaves(fl2.fleet)):
        np.testing.assert_array_equal(np.asarray(leaf1), np.asarray(leaf2))
    for t in "abc":
        np.testing.assert_array_equal(np.asarray(out1[t].value),
                                      np.asarray(out2[t].value))
        np.testing.assert_array_equal(np.asarray(out1[t].grad),
                                      np.asarray(out2[t].grad))


# ---------------------------------------------------------------------------
# Compile stability across tenant churn (satellite: mirrors the
# single-state test in test_obs.py at fleet scope)
# ---------------------------------------------------------------------------


def test_fleet_compile_stable_across_tenant_churn():
    """join -> extend to capacity -> evict -> refit -> leave -> rejoin:
    exactly ONE compile per (op, signature), zero recompiles — per-tenant
    count/noise/lam ride as traced arrays, so heterogeneous tenants and
    full churn share one executable per op."""
    r = np.random.RandomState(0)
    with obs.use_obs(True):
        fl = GPFleet("rbf", d=3, window=3, batch=4)
        fl.join("a", lam=0.4, noise=1e-7)
        fl.join("b", lam=1.6)
        for _ in range(4):            # past the window: auto-evict path too
            fl.extend({"a": (r.randn(3), r.randn(3)),
                       "b": (r.randn(3), r.randn(3))})
        fl.posterior({"a": r.randn(2, 3)})
        fl.evict(["b"])
        fl.refit(["a", "b"], steps=4)
        fl.posterior({"a": r.randn(2, 3), "b": r.randn(2, 3)})
        fl.leave("b")
        fl.join("c", lam=0.9, noise=1e-5)   # reuses b's freed lane
        fl.extend({"c": (r.randn(3), r.randn(3)),
                   "a": (r.randn(3), r.randn(3))})
        fl.mll()
        by_name = {w.name: w for w in compile_watch.all_watches()}
        for name in ("fleet_join", "fleet_extend", "fleet_evict",
                     "fleet_refit4", "fleet_posterior", "fleet_leave",
                     "fleet_mll"):
            w = by_name[name]
            assert w.n_signatures() == 1, (name, w.compiles)
            assert w.n_compiles() == 1, (name, w.compiles)
        compile_watch.assert_all_stable()


def test_fleet_server_steps_are_compile_stable():
    """The continuous-batching loop on top: interleaved submit/step churn
    with heterogeneous tenants never recompiles a fleet op."""
    r = np.random.RandomState(1)
    with obs.use_obs(True):
        srv = GPFleetServer(kernel="rbf", d=3)
        srv.connect("a", lam=0.5, noise=1e-6)
        srv.connect("b", lam=2.0)
        for _ in range(3):
            srv.submit("a", "extend", (r.randn(3), r.randn(3)))
            srv.submit("b", "extend", (r.randn(3), r.randn(3)))
            srv.submit("a", "query", r.randn(2, 3))
        srv.submit("b", "refit")
        srv.drain()
        srv.disconnect("b")
        srv.connect("c")
        srv.submit("c", "extend", (r.randn(3), r.randn(3)))
        srv.submit("c", "query", r.randn(2, 3))
        srv.drain()
        compile_watch.assert_all_stable()
        snap = obs.REGISTRY.snapshot()["counters"]
        assert snap["fleet.serve.requests"] == 12.0
        assert snap["fleet.launches"] > 0


# ---------------------------------------------------------------------------
# Padded-tenant taint (satellite): inactive/padded lanes contribute
# EXACTLY zero — NaN poison is the strongest detector (any cross-lane
# contraction or unmasked reduction would propagate it)
# ---------------------------------------------------------------------------


def _poison_inactive(fleet):
    """NaN every float leaf of the INACTIVE lanes."""
    act = np.asarray(fleet.active)

    def poison(leaf):
        leaf = jnp.asarray(leaf)
        if not jnp.issubdtype(leaf.dtype, jnp.floating) or leaf.ndim == 0:
            return leaf
        sel = act.reshape((-1,) + (1,) * (leaf.ndim - 1))
        return jnp.where(sel, leaf, jnp.nan)
    return fleet._replace(
        data=jax.tree_util.tree_map(poison, fleet.data),
        noise=jnp.where(fleet.active, fleet.noise, jnp.nan),
        signal=jnp.where(fleet.active, fleet.signal, jnp.nan))


def test_padded_tenants_contribute_exactly_zero():
    """Uneven B (2 of 4 lanes active), uneven per-tenant N: NaN-poisoned
    inactive lanes must not perturb one bit of the active lanes' extend/
    evict/refit/posterior, and masked MLL sums must exclude them."""
    spec = get_kernel("rbf")
    r = np.random.RandomState(2)
    d, window, B = 3, 4, 4
    active = jnp.asarray([True, False, True, False])
    fleet = fleet_init(spec, d, window, B, lam=0.8, noise=1e-6,
                       active=True)._replace(active=active)
    # uneven N: lane 0 gets 3 observations, lane 2 gets 1
    for k in range(3):
        mask = jnp.asarray([True, False, k == 0, False])
        fleet = fleet_extend(spec, fleet, r.randn(B, d), r.randn(B, d),
                             mask, window=window)
    clean = fleet
    dirty = _poison_inactive(fleet)

    X, G = r.randn(B, d), r.randn(B, d)
    Xq = r.randn(B, 2, d)
    for name, op in [
        ("extend", lambda f: fleet_extend(spec, f, X, G, window=window)),
        ("evict", lambda f: fleet_evict(spec, f)),
        ("refit", lambda f: fleet_refit(spec, f, steps=3)[0]),
    ]:
        got = op(dirty)
        want = op(clean)
        for b in (0, 2):
            for l_got, l_want in zip(
                    jax.tree_util.tree_leaves(fleet_lane(got, b)),
                    jax.tree_util.tree_leaves(fleet_lane(want, b))):
                np.testing.assert_array_equal(
                    np.asarray(l_got), np.asarray(l_want),
                    err_msg=f"lane taint through {name}")
    post_d = fleet_posterior(spec, dirty, Xq)
    post_c = fleet_posterior(spec, clean, Xq)
    for b in (0, 2):
        np.testing.assert_array_equal(np.asarray(post_d.value[b]),
                                      np.asarray(post_c.value[b]))
        np.testing.assert_array_equal(np.asarray(post_d.grad[b]),
                                      np.asarray(post_c.grad[b]))

    # masked evidence: the fleet total is the sum over ACTIVE lanes only,
    # finite even with NaN lanes in the batch
    per = fleet_mll(spec, clean)
    total = fleet_total_mll(spec, dirty)
    assert bool(jnp.isfinite(total))
    np.testing.assert_allclose(float(total),
                               float(per[0] + per[2]), rtol=1e-12)


def test_fleet_leave_zeroes_the_lane():
    """A freed lane is a pristine empty state — no residual bits that a
    later join or a fleet reduction could read."""
    fl = GPFleet("rbf", d=2, window=3, batch=2)
    r = np.random.RandomState(3)
    fl.join("t", lam=0.3, noise=1e-5)
    fl.extend({"t": (r.randn(2), r.randn(2))})
    slot = fl.slot_of("t")
    fl.leave("t")
    lane = fleet_lane(fl.fleet, slot)
    assert int(lane.count) == 0
    assert not bool(fl.fleet.active[slot])
    assert float(jnp.abs(lane.X).sum()) == 0.0
    assert float(jnp.abs(lane.Z).sum()) == 0.0
    np.testing.assert_array_equal(np.asarray(lane.L),
                                  np.eye(fl.capacity))


# ---------------------------------------------------------------------------
# Server semantics
# ---------------------------------------------------------------------------


def test_server_head_of_line_order_and_results():
    """A tenant's ops run in submission order across steps; one step never
    co-batches two ops of the same tenant."""
    r = np.random.RandomState(4)
    srv = GPFleetServer(kernel="rbf", d=2)
    srv.connect("t", lam=0.6)
    r1 = srv.submit("t", "extend", (r.randn(2), r.randn(2)))
    r2 = srv.submit("t", "extend", (r.randn(2), r.randn(2)))
    r3 = srv.submit("t", "query", r.randn(1, 2))
    done = srv.step()
    assert [x.done for x in (r1, r2, r3)] == [True, False, False]
    assert len(done) == 1
    srv.drain()
    assert r2.done and r3.done
    assert srv.fleet.n("t") == 2
    assert r3.result.value.shape == (1,)
    # the query ran AFTER both extends: it must match a fresh query now
    again = srv.submit("t", "query", r3.payload)
    srv.drain()
    np.testing.assert_array_equal(np.asarray(r3.result.value),
                                  np.asarray(again.result.value))


def test_server_idle_ttl_evicts_and_std_query_cache():
    from repro.configs.paper_gp import GPFleetConfig

    r = np.random.RandomState(5)
    srv = GPFleetServer(kernel="rbf", d=2,
                        config=GPFleetConfig(idle_ttl=2))
    srv.connect("busy", noise=1e-6)
    srv.connect("idle", noise=1e-6)
    for _ in range(4):
        srv.submit("busy", "extend", (r.randn(2), r.randn(2)))
        srv.step()
    assert srv.tenants == ["busy"]          # 'idle' TTL-evicted
    # std query path: solver LRU keyed on factor revision
    q = srv.submit("busy", "query", (r.randn(2, 2), True))
    srv.drain()
    assert q.result.std is not None and q.result.std.shape == (2,)
    assert bool(jnp.all(q.result.std >= -1e-12))

"""Hypothesis property tests on the system's mathematical invariants.

The ``test_fuzz_*`` state-machine tests are the randomized differential
suite: hypothesis draws a kernel/shape/seed, the seed deterministically
generates an op interleaving (tests/fuzz_machine.py), and every op is
checked against a dense from-scratch oracle and against the vmapped fleet
path.  On failure hypothesis shrinks and prints the falsifying
(kname, d, ..., seed) example — that tuple alone replays the trajectory
(``print_blob`` is on in the CI ``fleet-ci`` profile).  Example counts
come from the profile registered in conftest.py (dev: 25, fleet-ci: 200).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed in this image")
from hypothesis import given, settings, strategies as st

from conftest import arr as _arr
from fuzz_machine import (FUZZ_KERNELS, check_fleet_vs_loop,
                          check_recovery_fleet, check_recovery_single,
                          check_regime_trajectory, check_single_trajectory)
from repro.core import (build_factors, dense_gram, get_kernel, gram_matvec,
                        l_op, lt_op, woodbury_solve)
from repro.utils.flat import flatten_pytree, make_flat_spec, unflatten_pytree
from repro.utils.hlo import collective_breakdown

KERNEL_NAMES = FUZZ_KERNELS


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 6), d=st.integers(2, 9), seed=st.integers(0, 10**6),
       kname=st.sampled_from(KERNEL_NAMES))
def test_gram_symmetry(n, d, seed, kname):
    """grad-K-grad' is symmetric for any data (it is a covariance)."""
    spec = get_kernel(kname)
    X = _arr(seed, (n, d))
    full = dense_gram(spec, X, lam=0.5)
    assert np.allclose(full, full.T, rtol=1e-8, atol=1e-10)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 6), d=st.integers(2, 9), seed=st.integers(0, 10**6),
       kname=st.sampled_from(KERNEL_NAMES))
def test_matvec_linearity(n, d, seed, kname):
    spec = get_kernel(kname)
    X = _arr(seed, (n, d))
    V = _arr(seed + 1, (n, d))
    W = _arr(seed + 2, (n, d))
    f = build_factors(spec, X, lam=0.5)
    mv = lambda v: gram_matvec(f, v, stationary=spec.is_stationary)
    lhs = mv(2.0 * V - 3.0 * W)
    rhs = 2.0 * mv(V) - 3.0 * mv(W)
    assert np.allclose(lhs, rhs, rtol=1e-8, atol=1e-8)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 5), d=st.integers(6, 12), seed=st.integers(0, 10**6),
       kname=st.sampled_from(["rbf", "rq", "expdot"]))
def test_woodbury_solve_then_matvec_roundtrip(n, d, seed, kname):
    """Low-data regime (N < D): matvec(solve(G)) == G."""
    spec = get_kernel(kname)
    X = _arr(seed, (n, d))
    G = _arr(seed + 1, (n, d))
    f = build_factors(spec, X, lam=0.5, noise=1e-8)
    Z = woodbury_solve(spec, f, G)
    G2 = gram_matvec(f, Z, stationary=spec.is_stationary)
    assert np.allclose(G2, G, rtol=1e-5, atol=1e-6)


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 6), seed=st.integers(0, 10**6))
def test_l_operator_adjointness(n, seed):
    """<L(Q), M> == <Q, L^T(M)> — the sparse stationary-kernel operator."""
    Q = _arr(seed, (n, n))
    M = _arr(seed + 1, (n, n))
    lhs = float(jnp.sum(l_op(Q) * M))
    rhs = float(jnp.sum(Q * lt_op(M)))
    assert abs(lhs - rhs) < 1e-8 * max(1.0, abs(lhs))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10**6),
       shapes=st.lists(st.tuples(st.integers(1, 5), st.integers(1, 5)),
                       min_size=1, max_size=4),
       pad_to=st.sampled_from([1, 4, 16]))
def test_flatten_roundtrip(seed, shapes, pad_to):
    tree = {f"w{i}": _arr(seed + i, s) for i, s in enumerate(shapes)}
    spec = make_flat_spec(tree, pad_to=pad_to)
    flat = flatten_pytree(tree, spec)
    assert flat.shape[0] % pad_to == 0
    back = unflatten_pytree(flat, spec)
    for k in tree:
        assert np.allclose(back[k], tree[k])


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 50), m=st.integers(1, 20), seed=st.integers(0, 99))
def test_collective_parser_counts_exact_bytes(n, m, seed):
    hlo = f"""
ENTRY %main (p: f32[{n},{m}]) -> f32[{n},{m}] {{
  %p = f32[{n},{m}] parameter(0)
  %ar = f32[{n},{m}] all-reduce(%p), replica_groups={{}}
  ROOT %ag = bf16[{n},{m * 2}] all-gather(%ar), dimensions={{1}}
}}
"""
    got = collective_breakdown(hlo)
    assert got["all-reduce"] == n * m * 4
    assert got["all-gather"] == n * m * 2 * 2


# ---------------------------------------------------------------------------
# State-machine fuzzers (no explicit @settings: the conftest profile
# governs the example count — CI's fleet job runs these at ~200 examples)
# ---------------------------------------------------------------------------


@given(kname=st.sampled_from(FUZZ_KERNELS), d=st.integers(2, 6),
       cap=st.integers(2, 4), seed=st.integers(0, 2**31 - 1))
def test_fuzz_state_machine_vs_dense_oracle(kname, d, cap, seed):
    """Random extend/evict/resolve/query interleavings on the incremental
    state, dense-oracle-checked after EVERY op (<= 1e-5 rel)."""
    check_single_trajectory(kname, d, cap, seed, n_ops=7)


@given(kname=st.sampled_from(FUZZ_KERNELS), d=st.integers(2, 5),
       window=st.integers(2, 4), seed=st.integers(0, 2**31 - 1))
def test_fuzz_fleet_matches_host_loop(kname, d, window, seed):
    """The vmapped fleet trajectory == the same random op interleaving
    driven per tenant through the plain primitives (<= 1e-5 rel)."""
    check_fleet_vs_loop(kname, d, window, seed, steps=5)


@settings(max_examples=15, deadline=None)
@given(kname=st.sampled_from(FUZZ_KERNELS), d=st.integers(3, 5),
       seed=st.integers(0, 2**31 - 1))
def test_fuzz_regime_crossover_vs_dense_oracle(kname, d, seed):
    """Policy-driven trajectories streamed across the exact->iterative
    crossover (fill past N >= D and the cost-model boundary, then random
    extend/evict/refit/query), dense-oracle-checked after EVERY op in
    BOTH regimes (<= 1e-5 rel; regime dispatch must be invisible to the
    posterior)."""
    check_regime_trajectory(kname, d, seed)


@settings(max_examples=10, deadline=None)
@given(kname=st.sampled_from(["rbf", "expdot"]), d=st.integers(2, 5),
       cap=st.integers(3, 5), seed=st.integers(0, 2**31 - 1))
def test_fuzz_crash_recovery_single_bitwise(kname, d, cap, seed):
    """Snapshot/crash/journal-replay interleaved into a random trajectory:
    the recovered ``GPGState`` must be BIT-IDENTICAL to the uninterrupted
    run at the crash point AND at the end of the tape (dense-oracle-
    checked along both paths)."""
    import tempfile

    with tempfile.TemporaryDirectory() as td:
        check_recovery_single(kname, d, cap, seed, td)


@settings(max_examples=10, deadline=None)
@given(kname=st.sampled_from(["rbf", "rq"]), d=st.integers(2, 4),
       window=st.integers(2, 4), seed=st.integers(0, 2**31 - 1),
       elastic=st.booleans())
def test_fuzz_crash_recovery_fleet_bitwise(kname, d, window, seed, elastic):
    """The fleet flavor of the same invariant — and with ``elastic`` the
    snapshot restores into a DIFFERENT lane packing (batch 3 -> 5), which
    must still be bitwise per tenant lane (vmapped ops are
    lane-independent; the journal replays the exact grouped launches)."""
    import tempfile

    with tempfile.TemporaryDirectory() as td:
        check_recovery_fleet(kname, d, window, seed, td,
                             restore_batch=5 if elastic else None)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10**6), n=st.integers(2, 5), d=st.integers(2, 8))
def test_quantized_adam_moments_bounded_error(seed, n, d):
    """int8 blockwise quantization: |deq(q(x)) - x| <= absmax/127 per block."""
    from repro.optim.optimizers import _dq8, _pad_to_block, _q8

    x = _pad_to_block(jnp.asarray(
        np.random.RandomState(seed).randn(n * d) * 10.0).astype(jnp.float32))
    codes, scales = _q8(x)
    back = _dq8(codes, scales)
    blocks = x.reshape(-1, 256)
    bound = jnp.max(jnp.abs(blocks), axis=1) / 127.0 * 0.5 + 1e-9
    err = jnp.max(jnp.abs((back - x).reshape(-1, 256)), axis=1)
    assert bool(jnp.all(err <= bound * 1.01))

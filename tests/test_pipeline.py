"""GPipe pipeline-parallel mapping: fwd/bwd equivalence vs the sequential
oracle, on an 8-device (4 stages x 2) mesh in a subprocess."""
import os
import subprocess
import sys

_SRC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.launch.mesh import make_test_mesh
from repro.train.pipeline import gpipe_forward, reference_forward

mesh = make_test_mesh((4, 2), ("pod", "model"))
S, M, mb, D = 4, 6, 3, 16
key = jax.random.PRNGKey(0)
params = {"w": jax.random.normal(key, (S, D, D)) * 0.3,
          "b": jax.random.normal(jax.random.fold_in(key, 1), (S, D)) * 0.1}
x = jax.random.normal(jax.random.fold_in(key, 2), (M, mb, D))

def stage_apply(p, x):
    return jnp.tanh(x @ p["w"] + p["b"])

want = reference_forward(stage_apply, params, x)
got = gpipe_forward(stage_apply, params, x, mesh=mesh, stage_axis="pod")
assert float(jnp.max(jnp.abs(got - want))) < 1e-5

def loss_pipe(p):
    return jnp.sum(gpipe_forward(stage_apply, p, x, mesh=mesh,
                                 stage_axis="pod") ** 2)
def loss_ref(p):
    return jnp.sum(reference_forward(stage_apply, p, x) ** 2)
g1 = jax.grad(loss_pipe)(params)
g2 = jax.grad(loss_ref)(params)
err = max(float(jnp.max(jnp.abs(a - b))) for a, b in
          zip(jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g2)))
assert err < 1e-4, err
print("PIPELINE_OK")
"""


def test_gpipe_matches_sequential_8dev():
    r = subprocess.run([sys.executable, "-c", _SRC], capture_output=True,
                       text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")})
    assert "PIPELINE_OK" in r.stdout, r.stdout + r.stderr

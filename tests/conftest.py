"""Shared test fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches
must see 1 device (assignment contract); multi-device tests spawn
subprocesses or are guarded by device-count skips."""
import jax
import pytest

jax.config.update("jax_enable_x64", True)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)

"""Shared test fixtures.

NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device
(assignment contract); multi-device tests spawn subprocesses or are
guarded by device-count skips.  ``JAX_PLATFORMS`` defaults to cpu so the
suite is deterministic on accelerator-carrying hosts (set the env var
explicitly to test another backend).

Seed discipline: every randomized test draws through :func:`arr` (or its
``arr`` fixture) from an explicit integer seed, so any failure reproduces
from the printed seed alone — no ambient RNG state.  The hypothesis
profiles are registered here and selected via ``HYPOTHESIS_PROFILE``
(CI's fleet fuzz job runs ``fleet-ci``: ~200 examples, no deadline,
``print_blob`` so the failing example is replayable from the log).
"""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", True)

try:  # profiles are harmless when hypothesis is absent (tests importorskip)
    from hypothesis import settings

    settings.register_profile("dev", max_examples=25, deadline=None)
    settings.register_profile("fleet-ci", max_examples=200, deadline=None,
                              print_blob=True)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
except ImportError:  # pragma: no cover - image without hypothesis
    pass


def arr(seed: int, shape, scale: float = 1.0):
    """Deterministic gaussian array: the one seeded entry point for test
    data (``np.random.RandomState`` is stable across numpy versions)."""
    import jax.numpy as jnp

    return jnp.asarray(np.random.RandomState(seed).randn(*shape) * scale)


@pytest.fixture(scope="session", name="arr")
def arr_fixture():
    return arr


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def base_seed():
    """The suite-wide fuzz seed — override with REPRO_TEST_SEED to replay
    a CI failure locally (the failing test prints the derived seed)."""
    return int(os.environ.get("REPRO_TEST_SEED", "0"))

"""Checkpoint store + recovery loop + data pipeline integration tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.configs import get_config
from repro.data import DataConfig, batch_for_step, batch_shard_for_step
from repro.launch.mesh import make_test_mesh
from repro.optim import get_optimizer
from repro.runtime import FailureInjector, RecoveryConfig, run_with_recovery
from repro.train import build_train_step


def tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (4, 5)),
        "nested": {"b": jnp.arange(7, dtype=jnp.int32),
                   "c": jax.random.normal(jax.random.fold_in(k, 1),
                                          (3,)).astype(jnp.bfloat16)},
    }


def test_save_restore_roundtrip(tmp_path):
    t = tree()
    save_checkpoint(str(tmp_path), 5, t, extras={"note": "hi"})
    abstract = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), t)
    back, extras = restore_checkpoint(str(tmp_path), 5, abstract)
    assert extras == {"note": "hi"}
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(back)):
        assert a.dtype == b.dtype
        assert jnp.allclose(a.astype(jnp.float32), b.astype(jnp.float32))


def test_rotation_keeps_newest(tmp_path):
    for s in range(6):
        save_checkpoint(str(tmp_path), s, tree(s), keep=3)
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [3, 4, 5]
    assert latest_step(str(tmp_path)) == 5


def test_uncommitted_checkpoint_ignored(tmp_path):
    save_checkpoint(str(tmp_path), 1, tree())
    # simulate a crash mid-write: tmp dir exists without rename
    os.makedirs(tmp_path / "tmp_step_000000002")
    (tmp_path / "tmp_step_000000002" / "leaf_00000.npy").write_bytes(b"junk")
    assert latest_step(str(tmp_path)) == 1


def test_truncated_leaf_detected_and_skipped(tmp_path):
    """A truncated leaf file inside a COMMITTED checkpoint is detected as
    typed corruption, and restore_latest falls back to the previous good
    step instead of dying (or restoring garbage)."""
    import json

    from repro.checkpoint import (CheckpointCorruptionError, manifest_index,
                                  restore_latest)

    save_checkpoint(str(tmp_path), 1, tree(1))
    save_checkpoint(str(tmp_path), 2, tree(2))
    # truncate one leaf of step 2 mid-byte (a torn write after commit —
    # e.g. disk corruption; the two-phase rename can't catch this one)
    idx = manifest_index(str(tmp_path), 2)
    fname = idx["a"]["file"]
    leaf = tmp_path / "step_000000002" / fname
    leaf.write_bytes(leaf.read_bytes()[:-40])
    abstract = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree())
    with pytest.raises(CheckpointCorruptionError):
        restore_checkpoint(str(tmp_path), 2, abstract)
    step, back, _ = restore_latest(str(tmp_path), abstract)
    assert step == 1
    assert jnp.allclose(back["a"], tree(1)["a"])
    # wrong-shape leaf is corruption too (not a numpy reshape surprise)
    save_checkpoint(str(tmp_path), 3, tree(3))
    d3 = tmp_path / "step_000000003"
    np.save(d3 / manifest_index(str(tmp_path), 3)["a"]["file"],
            np.zeros((2, 2), np.float32))
    with pytest.raises(CheckpointCorruptionError):
        restore_checkpoint(str(tmp_path), 3, abstract)
    step, _, _ = restore_latest(str(tmp_path), abstract)
    assert step == 1
    # every step corrupt -> typed failure, not a silent empty state
    for s in (1,):
        idx = manifest_index(str(tmp_path), s)
        f = tmp_path / f"step_{s:09d}" / idx["a"]["file"]
        f.write_bytes(b"not-an-npy")
    with pytest.raises(CheckpointCorruptionError):
        restore_latest(str(tmp_path), abstract)


def test_async_manager(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=True)
    t = tree()
    mgr.save(3, t)
    assert mgr.latest() == 3          # latest() waits for the writer
    abstract = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), t)
    back, _ = mgr.restore(3, abstract)
    assert jnp.allclose(back["a"], t["a"])


def test_pipeline_determinism_and_shard_invariance():
    dc = DataConfig(vocab_size=100, seq_len=16, global_batch=8, seed=3)
    b1 = batch_for_step(dc, 7)["tokens"]
    b2 = batch_for_step(dc, 7)["tokens"]
    assert (b1 == b2).all()
    # shards concatenate to the global batch, for ANY shard count
    for ns in (2, 4, 8):
        parts = [batch_shard_for_step(dc, 7, i, ns)["tokens"]
                 for i in range(ns)]
        assert (jnp.concatenate(parts) == b1).all()
    # different steps give different data
    assert not (batch_for_step(dc, 8)["tokens"] == b1).all()
    # copy pattern: second half repeats first half
    assert (b1[:, 8:16] == b1[:, :8]).all()


def test_recovery_bit_identical(tmp_path):
    mesh = make_test_mesh((1, len(jax.devices())), ("data", "model"))
    cfg = get_config("chatglm3-6b", smoke=True)
    opt = get_optimizer("adamw", lr=1e-3)
    bundle = build_train_step(cfg, opt, mesh, shape="smoke_train",
                              donate=False)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=2)
    batch_fn = lambda step: batch_for_step(dc, step)

    def fresh():
        p = bundle.model.init(jax.random.PRNGKey(0))
        return p, bundle.opt.init(p)

    p, o = fresh()
    pA, _, _ = run_with_recovery(
        bundle.step, batch_fn, p, o, n_steps=9,
        config=RecoveryConfig(ckpt_dir=str(tmp_path / "a"), ckpt_every=3))
    p, o = fresh()
    pB, _, stats = run_with_recovery(
        bundle.step, batch_fn, p, o, n_steps=9,
        config=RecoveryConfig(ckpt_dir=str(tmp_path / "b"), ckpt_every=3),
        injector=FailureInjector(fail_at=(2, 7)))
    assert stats["restarts"] == 2
    for a, b in zip(jax.tree_util.tree_leaves(pA),
                    jax.tree_util.tree_leaves(pB)):
        assert (jnp.asarray(a) == jnp.asarray(b)).all()


def test_elastic_restore_subprocess(tmp_path):
    """Save on an 8-device mesh, restore on 4 — full logical arrays make
    resharding a pure device_put."""
    import subprocess
    import sys

    src = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import save_checkpoint, restore_checkpoint
from repro.launch.mesh import make_test_mesh
mesh = make_test_mesh((%d,), ("model",))
sh = NamedSharding(mesh, P("model"))
t = {{"w": jax.device_put(jnp.arange(32, dtype=jnp.float32), sh)}}
if %d == 8:
    save_checkpoint({str(str(tmp_path))!r}, 1, t)
else:
    a = {{"w": jax.ShapeDtypeStruct((32,), jnp.float32)}}
    back, _ = restore_checkpoint({str(str(tmp_path))!r}, 1, a,
                                 shardings={{"w": sh}})
    assert (back["w"] == jnp.arange(32)).all()
    assert len(back["w"].sharding.device_set) == %d
print("OK")
"""
    for n in (8, 4):
        code = src % (n, n, n, n)
        r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                           text=True, timeout=300,
                           env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")})
        assert "OK" in r.stdout, r.stdout + r.stderr

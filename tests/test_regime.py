"""repro.regime: cost-model crossover policy, matrix-free Krylov posterior,
SLQ evidence + Hutchinson hyper-gradients, exact gradient reduction, and
the GPGState wiring (capacity actions, evidence dispatch, telemetry,
compile stability across the regime switch)."""
import json
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from repro.core import build_factors, dense_solve, get_kernel
from repro.core.gram import dense_gram
from repro.core.state import GPGState, _default_maxiter, gpg_init
from repro.hyper import HyperParams, mll, mll_dense
from repro.hyper.mll import StructureError
from repro.obs import compile_watch
from repro.obs import trace as obs
from repro.regime import (RegimePolicy, assert_streaming_structure,
                          lanczos_tridiag, lift_gradients, posterior_solve,
                          project_points, reduce_gradients, resolve_policy,
                          slq_mll, solve)
from repro.regime.slq import make_slq_mll_fn
from repro.train.serve import build_gp_serve_step


@pytest.fixture(autouse=True)
def _clean_obs():
    obs.reset()
    obs.configure(None)
    compile_watch._WATCHES.clear()
    yield
    obs.reset()
    obs.configure(None)
    obs.set_enabled(None)
    compile_watch._WATCHES.clear()


def _data(n, d, seed=0):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randn(n, d)), jnp.asarray(rng.randn(n, d))


# ---------------------------------------------------------------------------
# policy: the analytic crossover + capacity actions
# ---------------------------------------------------------------------------

def test_crossover_is_deterministic_and_bounded():
    pol = RegimePolicy()
    for d in (2, 8, 32, 128):
        x = pol.crossover_n(d)
        assert 1 < x < pol.n_max
        assert x == pol.crossover_n(d)          # pure function of (cost, d)
        # the boundary is exactly where the flop polynomials cross
        assert pol.cost.iterative_flops(x, d, pol.planned_iters) \
            < pol.cost.exact_flops(x, d)
        assert pol.cost.iterative_flops(x - 1, d, pol.planned_iters) \
            >= pol.cost.exact_flops(x - 1, d)


def test_regime_for_modes():
    pol = RegimePolicy()
    x = pol.crossover_n(16)
    assert pol.regime_for(x - 1, 16) == "exact"
    assert pol.regime_for(x, 16) == "iterative"
    assert RegimePolicy(mode="exact").regime_for(10**6, 2) == "exact"
    assert RegimePolicy(mode="iterative").regime_for(1, 10**6) == "iterative"


def test_capacity_action_semantics():
    pol = RegimePolicy(capacity="auto")
    x = pol.crossover_n(16)
    # compressible rank -> compress; unknown rank never auto-compresses
    assert pol.capacity_action(20, 16, rank=4) == "compress"
    assert pol.capacity_action(x, 16, rank=None) == "iterate"
    assert pol.capacity_action(2, 16, rank=None) == "evict"
    # explicit compress degrades to evict when the data is incompressible
    assert RegimePolicy(capacity="compress").capacity_action(
        20, 16, rank=16) == "evict"
    assert RegimePolicy(capacity="compress").capacity_action(
        20, 16, rank=3) == "compress"


def test_resolve_policy_knob():
    assert resolve_policy(None, window=8).capacity == "evict"
    assert resolve_policy(None, window=None).capacity == "iterate"
    assert resolve_policy("compress").capacity == "compress"
    assert resolve_policy("iterative").mode == "iterative"
    pol = RegimePolicy(planned_iters=64)
    assert resolve_policy(pol) is pol
    with pytest.raises(ValueError):
        resolve_policy("bogus")
    with pytest.raises(TypeError):
        resolve_policy(3.14)


# ---------------------------------------------------------------------------
# krylov: matrix-free posterior at N > D
# ---------------------------------------------------------------------------

def test_posterior_solve_matches_dense_oracle_past_ceiling():
    n, d = 24, 8          # N > D: past the paper's exact-regime ceiling
    X, G = _data(n, d)
    spec = get_kernel("rbf")
    f = build_factors(spec, X, lam=1.0 / d, noise=1e-6)
    res = posterior_solve(spec, f, G, tol=1e-10)
    Zo = dense_solve(spec, X, G, lam=1.0 / d, noise=1e-6, jitter=0.0)
    rel = float(jnp.linalg.norm(res.Z - Zo) / jnp.linalg.norm(Zo))
    assert rel <= 1e-4, rel
    assert int(res.iters) < 10 * n + 50


def test_posterior_solve_warm_start_and_precond_help():
    n, d = 24, 8
    X, G = _data(n, d, seed=1)
    spec = get_kernel("rbf")
    f = build_factors(spec, X, lam=1.0 / d, noise=1e-6)
    cold = posterior_solve(spec, f, G, tol=1e-10)
    warm = posterior_solve(spec, f, G, z0=cold.Z, tol=1e-10)
    assert int(warm.iters) <= int(cold.iters)
    # Cholesky preconditioning from cached exact factors
    K1n = f.K1e + (1e-6 / f.lam + 1e-10) * jnp.eye(n)
    L = jnp.linalg.cholesky(K1n)
    pre = posterior_solve(spec, f, G, L=L, tol=1e-10)
    Zo = dense_solve(spec, X, G, lam=1.0 / d, noise=1e-6, jitter=0.0)
    assert float(jnp.linalg.norm(pre.Z - Zo) / jnp.linalg.norm(Zo)) <= 1e-4


def test_lanczos_tridiag_reconstructs_spectrum():
    rng = np.random.RandomState(3)
    m = 12
    A = rng.randn(m, m)
    A = jnp.asarray(A @ A.T + m * np.eye(m))
    alpha, beta, nrm = lanczos_tridiag(lambda v: A @ v,
                                       jnp.asarray(rng.randn(m)), m)
    T = jnp.diag(alpha) + jnp.diag(beta, 1) + jnp.diag(beta, -1)
    want = np.sort(np.linalg.eigvalsh(np.asarray(A)))
    got = np.sort(np.linalg.eigvalsh(np.asarray(T)))
    # full-dimensional Lanczos with reorthogonalization: exact spectrum
    assert np.max(np.abs(got - want) / want) < 1e-8


def test_streaming_structure_gate_catches_dense_gram():
    n, d = 24, 8
    X, G = _data(n, d)
    spec = get_kernel("rbf")
    f = build_factors(spec, X, lam=1.0 / d, noise=1e-6)
    # the real path passes...
    assert_streaming_structure(
        lambda g: posterior_solve(spec, f, g, tol=1e-10).Z, G, n=n, d=d)
    # ...a dense (ND, ND) materialization is structurally rejected
    with pytest.raises(StructureError):
        assert_streaming_structure(
            lambda g: jnp.linalg.solve(
                dense_gram(spec, X, lam=1.0 / d, noise=1e-6),
                g.reshape(-1)).reshape(n, d),
            G, n=n, d=d)


def test_regime_dispatching_solve():
    spec = get_kernel("rbf")
    for n, d, want in ((4, 16, "exact"), (24, 8, "iterative")):
        X, G = _data(n, d)
        f = build_factors(spec, X, lam=0.1, noise=1e-6)
        Z, info = solve(spec, f, G)
        assert info["regime"] == want
        Zo = dense_solve(spec, X, G, lam=0.1, noise=1e-6, jitter=0.0)
        assert float(jnp.linalg.norm(Z - Zo) / jnp.linalg.norm(Zo)) <= 1e-4


# ---------------------------------------------------------------------------
# slq: evidence + hyper-gradients past the ceiling
# ---------------------------------------------------------------------------

def test_slq_mll_within_one_percent_of_slogdet_oracle():
    n, d = 24, 8
    X, G = _data(n, d, seed=5)
    spec = get_kernel("rbf")
    h = HyperParams.create(lengthscale2=float(d), signal=1.2, noise=1e-4)
    got = float(slq_mll(spec, X, G, h, probes=16))
    want = float(mll_dense(spec, X, G, h))
    assert abs(got - want) / abs(want) <= 0.01
    # deterministic given the key: the probe block is fixed
    assert float(slq_mll(spec, X, G, h, probes=16)) == got


def test_slq_hyper_gradients_track_dense_autodiff():
    n, d = 20, 6
    X, G = _data(n, d, seed=6)
    spec = get_kernel("rbf")
    h = HyperParams.create(lengthscale2=float(d), signal=1.1, noise=1e-3)
    fn = make_slq_mll_fn(spec, X, G, probes=16)
    g_slq = jax.grad(fn)(h)
    g_dense = jax.grad(lambda hh: mll_dense(spec, X, G, hh))(h)
    for field in ("log_lengthscale2", "log_signal", "log_noise"):
        a = float(getattr(g_slq, field))
        b = float(getattr(g_dense, field))
        # Hutchinson trace noise: direction + magnitude, not bit equality
        assert abs(a - b) <= 0.05 * max(abs(b), 1.0), (field, a, b)


# ---------------------------------------------------------------------------
# reduction: exact gradient compression
# ---------------------------------------------------------------------------

def test_reduction_exactness_for_in_span_queries():
    rng = np.random.RandomState(7)
    d, k, n = 16, 3, 10
    B = rng.randn(k, d)
    X = jnp.asarray(rng.randn(n, k) @ B)
    G = jnp.asarray(rng.randn(n, k) @ B)       # in-span gradients
    spec = get_kernel("rbf")
    red = reduce_gradients(spec, X, G)
    assert red.rank == k
    assert float(red.residual) < 1e-8          # nothing dropped: lossless
    Xq = jnp.asarray(rng.randn(4, k) @ B)
    Yq, out = project_points(red, Xq)
    assert float(jnp.max(out)) < 1e-8
    # reduced-model solve == full-model solve on the projected queries
    Zr = dense_solve(spec, red.Xr, red.Gr, lam=0.2, noise=1e-6)
    Zf = dense_solve(spec, X, G, lam=0.2, noise=1e-6)
    assert np.allclose(np.asarray(lift_gradients(red, Zr)), np.asarray(Zf),
                       atol=1e-6)


def test_state_compress_equals_uncompressed_posterior():
    rng = np.random.RandomState(8)
    d, k = 12, 2
    B = rng.randn(k, d)
    pts = [(rng.randn(k) @ B, rng.randn(k) @ B) for _ in range(9)]
    st_c = GPGState("rbf", d=d, window=5, lam=0.3, noise=1e-6,
                    policy="compress")
    st_e = GPGState("rbf", d=d, capacity=16, lam=0.3, noise=1e-6)
    for x, g in pts:
        st_c.extend(x, g)
        st_e.extend(x, g)
    assert st_c._reduction is not None and st_c._reduction.rank == k
    assert st_c.d == k                     # the D axis actually collapsed
    assert st_c.n == len(pts)              # ...and nothing was evicted
    Xq = jnp.asarray(rng.randn(5, k) @ B)
    pc, pe = st_c.posterior(Xq), st_e.posterior(Xq)
    assert np.allclose(np.asarray(pc.value), np.asarray(pe.value),
                       atol=1e-6)
    assert np.allclose(np.asarray(pc.grad), np.asarray(pe.grad), atol=1e-6)
    # an out-of-span arrival grows the basis instead of corrupting state
    st_c.extend(rng.randn(d), rng.randn(d))
    assert st_c._reduction.rank == k + 1
    assert st_c.n == len(pts) + 1


def test_state_iterate_policy_lifts_window():
    rng = np.random.RandomState(9)
    st = GPGState("rbf", d=4, window=3, lam=0.5, noise=1e-6,
                  policy="iterate")
    for _ in range(8):
        st.extend(rng.randn(4), rng.randn(4))
    assert st.window is None and st.n == 8     # grew past the old window
    Zo = dense_solve(st.spec, st.X, st.G, lam=0.5, noise=1e-6, jitter=0.0)
    sc = max(1.0, float(jnp.max(jnp.abs(Zo))))
    assert float(jnp.max(jnp.abs(st.Z - Zo))) <= 1e-5 * sc


def test_state_evidence_dispatch():
    rng = np.random.RandomState(10)
    st = GPGState("rbf", d=4, capacity=32, lam=0.5, noise=1e-4, signal=1.1)
    for _ in range(20):
        st.extend(rng.randn(4), rng.randn(4))
    assert st.regime == "iterative"
    exact = float(st.mll(method="exact"))
    auto = float(st.mll())                     # auto -> slq here
    oracle = float(mll_dense(st.spec, st.X, st.G, st.hypers))
    assert abs(exact - oracle) / abs(oracle) < 1e-6
    assert abs(auto - oracle) / abs(oracle) < 0.02
    with pytest.raises(ValueError):
        st.mll(method="cholesky")
    # SLQ refit runs and does not corrupt the solve
    st.refit(steps=3, method="slq", probes=4, lanczos_iters=16)
    Zo = dense_solve(st.spec, st.X, st.G, lam=st.data.lam,
                     noise=st._noise_eff, jitter=0.0)
    sc = max(1.0, float(jnp.max(jnp.abs(Zo))))
    assert float(jnp.max(jnp.abs(st.Z - Zo))) <= 1e-5 * sc


def test_condition_scaled_maxiter():
    data = gpg_init(get_kernel("rbf"), 4, 8)
    ceiling = 10 * 8 + 50
    assert _default_maxiter(data, None) == ceiling
    assert _default_maxiter(data, 7) == 7               # explicit wins
    assert _default_maxiter(data, None, cond=1.0) == ceiling
    assert _default_maxiter(data, None, cond=float("inf")) == ceiling
    mid = _default_maxiter(data, None, cond=16.0, tol=1e-10)
    assert 8 // 2 + 16 <= mid < ceiling
    # monotone in the condition proxy, clamped at the legacy ceiling
    assert _default_maxiter(data, None, cond=64.0) >= mid
    assert _default_maxiter(data, None, cond=1e30) == ceiling


def test_serve_config_applies_solver_knobs():
    from repro.configs.paper_gp import GPServeConfig

    st = GPGState("rbf", d=4, capacity=8)
    cfg = GPServeConfig(microbatch=4, tol=1e-8, maxiter=33)
    bundle = build_gp_serve_step(st, config=cfg)
    assert bundle.microbatch == 4
    assert st.tol == 1e-8 and st.maxiter == 33
    assert st._maxiter_eff() == 33


# ---------------------------------------------------------------------------
# telemetry: the switch event fires exactly at the modeled crossover,
# and crossing it never recompiles the serve step
# ---------------------------------------------------------------------------

def test_regime_switch_telemetry_and_compile_stability(tmp_path):
    from tools.check_telemetry import check

    log = tmp_path / "regime.jsonl"
    obs.configure(str(log))
    rng = np.random.RandomState(11)
    d = 6
    with obs.use_obs(True):
        st = GPGState("rbf", d=d, capacity=16, lam=0.5, noise=1e-8,
                      policy="iterate")
        xover = st.policy.crossover_n(d)
        bundle = build_gp_serve_step(st, microbatch=4)
        Xq = jnp.asarray(rng.randn(4, d))
        for _ in range(xover + 3):
            st.extend(rng.randn(d), rng.randn(d))
            bundle.query(Xq)
        snap = obs.snapshot()
        obs.flush()
    assert snap["gauges"]["regime.active"] == 1.0
    assert snap["gauges"]["regime.crossover_n"] == float(xover)
    assert snap["counters"]["regime.switches"] == 1
    # one serve signature across the switch: zero recompiles
    watch = next(w for w in compile_watch.all_watches()
                 if w.name == "gp_serve_step")
    assert len(watch.compiles) == 1
    assert all(c == 1 for c in watch.compiles.values())
    # the JSONL gate agrees with the model...
    assert check(str(log), expect_regime_switch_at=xover) == []
    # ...and flags an off-model switch claim
    bad = check(str(log), expect_regime_switch_at=xover + 1)
    assert any("off-model" in f for f in bad)
    events = [json.loads(l) for l in log.read_text().splitlines() if l]
    sw = [e for e in events if e.get("type") == "regime"
          and e.get("event") == "switch"]
    assert len(sw) == 1 and sw[0]["n"] == xover and sw[0]["to"] == "iterative"

"""repro.obs: registry/span/sink semantics, the zero-cost disabled-mode
guarantee (jaxpr/HLO), in-jit taps, the recompile sentinel, the serve
LRU revision keying, health/cost probes, the --check null handling, and
the check_telemetry gate."""
import json
import pathlib
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.core.kernels import get_kernel
from repro.core.state import GPGState, gpg_extend, gpg_init
from repro.obs import compile_watch, cost, health, injit
from repro.obs import trace as obs
from repro.train.serve import build_gp_serve_step
from repro.utils.hlo import count_primitive

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))


@pytest.fixture(autouse=True)
def _clean_registry():
    obs.reset()
    obs.configure(None)
    compile_watch._WATCHES.clear()
    cost.clear_model_cache()
    yield
    obs.reset()
    obs.configure(None)
    obs.set_enabled(None)
    compile_watch._WATCHES.clear()


# ---------------------------------------------------------------------------
# trace: registry + spans + sink
# ---------------------------------------------------------------------------

def test_registry_counters_gauges_hists():
    r = obs.Registry()
    r.inc("c")
    r.inc("c", 2.5)
    r.set_gauge("g", 7.0)
    r.observe("h", 1.0)
    r.observe("h", 3.0)
    snap = r.snapshot()
    assert snap["counters"]["c"] == 3.5
    assert snap["gauges"]["g"] == 7.0
    assert snap["hists"]["h"]["count"] == 2
    assert snap["hists"]["h"]["total"] == 4.0
    assert snap["hists"]["h"]["min"] == 1.0 and snap["hists"]["h"]["max"] == 3.0
    # delta vs an earlier snapshot drops zero-change counters
    r2_before = r.snapshot()
    r.inc("c")
    r.inc("untouched", 0)
    d = r.delta(r2_before)
    assert d["counters"] == {"c": 1.0}
    assert d["hists"] == {}


def test_span_nesting_and_jsonl_sink(tmp_path):
    log = tmp_path / "t.jsonl"
    obs.configure(str(log))
    with obs.use_obs(True):
        with obs.span("outer"):
            with obs.span("inner", tag="x"):
                pass
        obs.flush()
    events = [json.loads(ln) for ln in log.read_text().splitlines()]
    spans = [e for e in events if e["type"] == "span"]
    assert [s["path"] for s in spans] == ["outer.inner", "outer"]
    assert spans[0]["attrs"] == {"tag": "x"}
    assert all(s["dur_s"] >= 0 for s in spans)
    snap = [e for e in events if e["type"] == "snapshot"][-1]
    assert "span.outer.seconds" in snap["hists"]
    assert "span.outer.inner.seconds" in snap["hists"]


def test_disabled_span_is_noop_and_sink_silent(tmp_path):
    log = tmp_path / "t.jsonl"
    obs.configure(str(log))
    with obs.use_obs(False):
        with obs.span("never"):
            pass
        obs.emit({"type": "x"})
    assert not log.exists()
    assert obs.REGISTRY.hists == {}


def test_enabled_resolution_env(monkeypatch):
    obs.set_enabled(None)
    monkeypatch.delenv("REPRO_OBS", raising=False)
    assert not obs.enabled()
    monkeypatch.setenv("REPRO_OBS", "on")
    assert obs.enabled()
    monkeypatch.setenv("REPRO_OBS", "off")
    assert not obs.enabled()
    obs.set_enabled(True)
    assert obs.enabled()        # forced override beats the env
    obs.set_enabled(None)


# ---------------------------------------------------------------------------
# injit: taps enter the jaxpr ONLY when enabled (the zero-cost proof)
# ---------------------------------------------------------------------------

def _trace_extend(spec, data, x, g):
    # fresh closure per call: jax.make_jaxpr caches on function identity,
    # so reusing one callable across enabled-modes would alias the traces
    return jax.make_jaxpr(
        lambda d, x_, g_: gpg_extend(spec, d, x_, g_, noise=1e-8))(
            data, x, g)


def test_extend_jaxpr_clean_when_disabled_tapped_when_enabled():
    spec = get_kernel("rbf")
    data = gpg_init(spec, 4, 4)
    x = jnp.ones(4)
    g = jnp.ones(4)
    with obs.use_obs(False):
        j_off = _trace_extend(spec, data, x, g)
    with obs.use_obs(True):
        j_on = _trace_extend(spec, data, x, g)
    # REPRO_OBS=off: not a single callback primitive in the whole program
    # — the compiled extend is bit-identical to a build without repro.obs
    assert count_primitive(j_off.jaxpr, "debug_callback") == 0
    # enabled: pivot2 + degenerate flag + CG iters + CG resnorm all tapped
    assert count_primitive(j_on.jaxpr, "debug_callback") >= 4


def test_query_step_jaxpr_identical_on_and_off():
    from repro.core.query import make_query_fn

    spec = get_kernel("rbf")
    st = GPGState.from_data("rbf", jnp.eye(3, 4), jnp.ones((3, 4)),
                            noise=1e-8)
    f, Z = st.padded_factors, st.data.Z
    Xq = jnp.ones((2, 4))
    with obs.use_obs(False):
        j_off = jax.make_jaxpr(make_query_fn(spec))(f, Z, Xq)
    with obs.use_obs(True):
        j_on = jax.make_jaxpr(make_query_fn(spec))(f, Z, Xq)
    # the batched query path is pure math — no taps on either side, and
    # the serve step's program is untouched by observability entirely
    assert str(j_off) == str(j_on)
    assert count_primitive(j_on.jaxpr, "debug_callback") == 0


def test_tap_accumulates_under_jit_and_cond():
    with obs.use_obs(True):
        @jax.jit
        def f(x, flag):
            injit.tap("t.sum", jnp.sum(x), kind="counter")
            return jax.lax.cond(
                flag,
                lambda v: (injit.tap("t.branch", 1, kind="counter"), v * 2)[1],
                lambda v: v,
                x)

        f(jnp.ones(3), True).block_until_ready()
        f(jnp.ones(3), False).block_until_ready()
        assert obs.counter_value("t.sum") == 6.0
        assert obs.counter_value("t.branch") == 1.0   # only the taken branch


def test_fold_metrics_host_side():
    with obs.use_obs(True):
        injit.fold({"a.x": jnp.asarray(3.0)}, kind="counter")
        injit.fold({"a.g": 2.0})
        assert obs.counter_value("a.x") == 3.0
        assert obs.gauge_value("a.g") == 2.0


# ---------------------------------------------------------------------------
# compile_watch: the recompile sentinel
# ---------------------------------------------------------------------------

def test_compile_watch_counts_signatures():
    with obs.use_obs(True):
        w = compile_watch.wrap(lambda x: x * 2, name="cw_t")
        w(jnp.ones(3))
        w(jnp.ones(3))          # cache hit: no new trace
        w(jnp.ones(5))          # new shape: one new compile
        assert isinstance(w, compile_watch.CompileWatch)
        assert w.calls == 3
        assert w.n_signatures() == 2
        assert w.n_compiles() == 2
        assert w.violations() == []
        w.assert_stable()
        assert obs.counter_value("compile.cw_t.compiles") == 2
        assert obs.counter_value("compile.cw_t.recompiles") == 0


def test_compile_watch_detects_forced_recompile():
    with obs.use_obs(True):
        w = compile_watch.wrap(lambda x: x + 1, name="cw_v")
        w(jnp.ones(3))
        jax.clear_caches()      # force XLA to re-trace the same signature
        w(jnp.ones(3))
        assert w.n_compiles() == 2 and w.n_signatures() == 1
        assert len(w.violations()) == 1
        assert obs.counter_value("compile.cw_v.recompiles") == 1
        with pytest.raises(AssertionError, match="recompiled"):
            w.assert_stable()


def test_wrap_is_plain_jit_when_disabled():
    fn = lambda x: x * 3          # noqa: E731
    with obs.use_obs(False):
        w = compile_watch.wrap(fn, name="cw_off")
    assert not isinstance(w, compile_watch.CompileWatch)
    # bit-identical lowering to an undecorated jax.jit of the same fn
    x = jnp.ones(3)
    assert jax.jit(fn).lower(x).as_text() == w.lower(x).as_text()


# ---------------------------------------------------------------------------
# serve wiring: revision-keyed LRU + the recompile-sentinel regression test
# ---------------------------------------------------------------------------

def _mk_state(d=4, n=3, noise=1e-6):
    X = jnp.eye(n, d) * 2.0
    G = jnp.ones((n, d))
    return GPGState.from_data("rbf", X, G, noise=noise, capacity=4)


def test_solver_cache_revision_keyed_with_counters():
    with obs.use_obs(True):
        st = _mk_state()
        serve = build_gp_serve_step(st, microbatch=2, return_std=True)
        Xq = jnp.ones((2, 4))
        serve.query(Xq)
        assert obs.counter_value("serve.solver_cache.misses") == 1
        serve.query(Xq)                      # unchanged revision: HIT
        assert obs.counter_value("serve.solver_cache.hits") == 1
        # resolve() rebuilds the data pytree but NOT the factorization —
        # the revision key keeps the entry (the identity key this replaced
        # would have re-factorized and double-cached here)
        st.resolve(st.G)
        serve.query(Xq)
        assert obs.counter_value("serve.solver_cache.hits") == 2
        assert obs.counter_value("serve.solver_cache.misses") == 1
        st.extend(3.0 * jnp.ones(4), jnp.ones(4))   # factors changed: MISS
        serve.query(Xq)
        assert obs.counter_value("serve.solver_cache.misses") == 2


def test_solver_cache_eviction_counter():
    with obs.use_obs(True):
        st = _mk_state()
        serve = build_gp_serve_step(st, microbatch=2, return_std=True)
        Xq = jnp.ones((2, 4))
        for i in range(serve._SOLVER_CACHE_MAX + 1):
            serve.query(Xq)
            st.refactor()        # bump the factor revision every round
        assert obs.counter_value("serve.solver_cache.evictions") == 1


def test_serve_step_compile_stable_across_extend_evict_refit_precision():
    """The tentpole invariant as a regression test: extend -> evict ->
    refit -> precision toggle, exactly ONE compile per distinct shape
    signature, zero recompiles."""
    with obs.use_obs(True):
        st = _mk_state(d=4, n=3, noise=1e-6)
        serve = build_gp_serve_step(st, microbatch=2, return_std=True)
        Xq = jnp.ones((2, 4))
        serve.query(Xq)
        st.extend(3.0 * jnp.ones(4), jnp.ones(4))
        serve.query(Xq)
        st.evict()
        serve.query(Xq)
        st.refit(steps=5)        # noise/signal/lam change VALUES only
        serve.query(Xq)
        w = serve.step
        assert w.n_signatures() == 1
        assert w.n_compiles() == 1
        w.assert_stable()

        # mean-only endpoint: a precision toggle changes the stream dtype
        # — a genuinely NEW signature, one (and only one) extra compile
        mean = build_gp_serve_step(st, microbatch=2)
        mean.query(Xq)
        st.set_precision("bf16")
        mean.query(Xq)
        st.set_precision("f32")
        mean.query(Xq)           # back to sig 1: jit cache hit, no trace
        assert mean.step.n_signatures() == 2
        assert mean.step.n_compiles() == 2
        mean.step.assert_stable()
        compile_watch.assert_all_stable()


# ---------------------------------------------------------------------------
# health + cost
# ---------------------------------------------------------------------------

def test_health_probes_and_monitor():
    with obs.use_obs(True):
        st = _mk_state(d=4, n=3)
        assert health.condition_proxy(st.data) >= 1.0
        assert health.solve_residual(st.spec, st.data,
                                     noise=st._noise_eff) < 1e-6
        assert health.precision_drift(st) < 0.1
        mon = health.HealthMonitor(cadence=2, drift=False)
        st.attach_health(mon)
        st.extend(3.0 * jnp.ones(4), jnp.ones(4))   # tick 1: no sample
        assert obs.counter_value("health.samples") == 0
        st.extend(4.0 * jnp.ones(4), jnp.ones(4))   # tick 2: sample
        assert obs.counter_value("health.samples") == 1
        assert obs.gauge_value("health.cond_k1n") >= 1.0


def test_cost_modeled_and_roofline_fraction():
    with obs.use_obs(True):
        a = jnp.ones((8, 8), jnp.float32)
        c = cost.modeled("t_mm", lambda x, y: x @ y, a, a)
        assert c.flops > 0
        assert obs.gauge_value("cost.t_mm.hbm_bytes") > 0
        frac = cost.record_measured("t_mm", 1e-3, c)
        assert frac is not None and frac > 0
        assert obs.gauge_value("cost.t_mm.roofline_fraction") == frac
    with obs.use_obs(False):
        assert cost.modeled("t_mm2", lambda x: x, a) is None
        assert cost.record_measured("t_mm2", 1.0) is None


# ---------------------------------------------------------------------------
# benchmarks/run.py --check: null/absent metrics + telemetry skip
# ---------------------------------------------------------------------------

def test_check_skips_null_metrics_and_telemetry():
    import benchmarks.run as br

    failures = []
    base = {
        "pallas_seconds": None,          # interpret-mode baseline column
        "ratio": None,
        "claim_holds": True,
        "speed_err": 1.0,
        "telemetry": {"counters": {"hot_bytes": 1.0}},
    }
    fresh = {
        "pallas_seconds": 2.0,
        "ratio": 5.0,                    # None baseline: not gated
        "claim_holds": None,             # None fresh: not a flip
        "speed_err": None,               # measured -> absent: not gated
        "telemetry": {"counters": {"hot_bytes": 1e9}},  # never gated
    }
    br._walk_regressions(base, fresh, ("kernels",), failures)
    assert failures == []
    # real regressions are still caught
    failures = []
    br._walk_regressions({"ratio": 1.0, "claim_holds": True},
                         {"ratio": 2.0, "claim_holds": False},
                         ("kernels",), failures)
    assert {f[0] for f in failures} == {"kernels.ratio",
                                        "kernels.claim_holds"}


# ---------------------------------------------------------------------------
# tools/check_telemetry.py: the CI smoke gate
# ---------------------------------------------------------------------------

def test_check_telemetry_on_instrumented_run(tmp_path):
    from tools.check_telemetry import check

    log = tmp_path / "run.jsonl"
    obs.configure(str(log))
    with obs.use_obs(True):
        st = _mk_state(d=4, n=3)
        serve = build_gp_serve_step(st, microbatch=2)
        st.extend(3.0 * jnp.ones(4), jnp.ones(4))
        serve.query(jnp.ones((2, 4)))
        obs.flush()
    assert check(str(log)) == []


def test_check_telemetry_flags_violations(tmp_path):
    from tools.check_telemetry import check

    log = tmp_path / "bad.jsonl"
    lines = [
        {"type": "span", "name": "state.extend", "path": "state.extend",
         "dur_s": -1.0},
        {"type": "compile", "watch": "gp_serve_step", "sig": "s", "nth": 2},
        {"type": "snapshot", "counters": {"state.extend_calls": 5.0},
         "gauges": {}},
    ]
    log.write_text("\n".join(json.dumps(e) for e in lines) + "\nnot json\n")
    failures = check(str(log))
    text = "\n".join(failures)
    assert "serve.query" in text              # missing required span
    assert "bad duration" in text
    assert "recompile-sentinel violation" in text
    assert "malformed JSON" in text
    assert "state.refactor_fallback" in text  # missing counter
    assert "cost." in text                    # no modeled gauges
    assert "counter/span mismatch" in text    # 5 claimed vs 1 span event
    # --allow-recompile downgrades exactly the sentinel failure
    relaxed = check(str(log), allow_recompile=True)
    assert all("recompile-sentinel" not in f for f in relaxed)
    assert check(str(tmp_path / "missing.jsonl"))

"""GPG-HMC example (paper Sec. 5.3): sample a 100-D banana density with a
GP gradient surrogate trained on ~sqrt(D) true gradient evaluations.

Run:  PYTHONPATH=src python examples/gpg_hmc_sampling.py
"""
import math

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp

from repro.hyper import HyperParams
from repro.sampling import banana_energy, gpg_hmc, hmc

D = 100
fourth = math.ceil(D ** 0.25)
eps = 4e-3 / fourth
steps = 32 * fourth
n_samples = 300

key = jax.random.PRNGKey(0)
x0 = jax.random.normal(key, (D,))

print(f"target: 100-D banana; eps={eps:.4f}, T={steps} leapfrog steps")
res = hmc(banana_energy, x0, key, n_samples=n_samples, eps=eps, steps=steps)
print(f"HMC      accept={float(res.accept_rate):.2f} "
      f"(true-gradient calls: {n_samples * (steps + 1):,})")

hp = HyperParams.create(lengthscale2=0.4 * D, noise=1e-8)  # App. F.3 init
res2 = gpg_hmc(banana_energy, x0, jax.random.PRNGKey(1),
               n_samples=n_samples, eps=eps, steps=steps,
               hypers=hp, budget=int(math.sqrt(D)))
print(f"GPG-HMC  accept={res2.accept_rate:.2f} "
      f"(true-gradient calls: {res2.n_true_grad_calls} — "
      f"{n_samples * (steps + 1) / res2.n_true_grad_calls:,.0f}x fewer)")
print(f"surrogate hypers (shared container): {res2.surrogate.hypers}")
print("samples stay valid: the Metropolis test uses the TRUE energy;")
print("the surrogate only trades acceptance rate for gradient cost.")

m = res2.samples[:, :2].mean(axis=0)
print(f"banana-plane sample mean: ({float(m[0]):.2f}, {float(m[1]):.2f})")

"""Probabilistic linear-algebra example (paper Sec. 4.2 / Fig. 2):
solve Ax = b with the GP-X solution-based solver vs conjugate gradients.

Run:  PYTHONPATH=src python examples/probabilistic_solver.py
"""
import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np

from repro.linalg import (cg_solve, hessian_probabilistic_solver,
                          make_test_matrix, solution_probabilistic_solver)

D = 100
A = make_test_matrix(D)                    # App. F.1 spectrum, kappa = 200
rng = np.random.RandomState(0)
x0 = jnp.asarray(rng.randn(D) * 5.0)
xstar = jnp.asarray(rng.randn(D) - 2.0)
b = A @ xstar

print(f"solving a {D}x{D} system, kappa={100/0.5:.0f}")
for name, fn in [("conjugate gradients  ", cg_solve),
                 ("GP-X solution solver ", solution_probabilistic_solver),
                 ("GP-H Hessian solver  ", hessian_probabilistic_solver)]:
    tr = fn(A, b, x0, tol=1e-5, max_iters=100)
    bar = "#" * max(1, int(40 * min(tr.iters, 100) / 100))
    print(f"  {name} iters={tr.iters:3d} relres={tr.relres[-1]:.1e} {bar}")

print("\nGP-X matches CG (paper Fig. 2); GP-H's fixed c=0 'compromises")
print("the performance' — reproduced, not a bug.")

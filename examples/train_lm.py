"""End-to-end driver: train a ~100M-param LM for a few hundred steps on the
synthetic induction-pattern stream, with checkpoint/restart enabled.

The model is a scaled-down gemma3-style transformer (sliding-window
interleave); success criterion: loss on the copy region falls well below
the iid entropy floor log(vocab) — the model must learn induction, not
just unigram statistics.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--gp]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.data import DataConfig, batch_for_step
from repro.launch.mesh import make_test_mesh
from repro.models import ModelConfig
from repro.optim import get_optimizer
from repro.runtime import RecoveryConfig, run_with_recovery
from repro.train import build_train_step
from repro.models.registry import SHAPES, ShapeSpec


def main() -> None:
    ap = argparse.ArgumentParser()
    # defaults are CPU-container-sized (~1s/step); the "real" run is
    #   --dim 768 --layers 12 --seq 1024 --batch 32  (~100M params), which
    # needs accelerator hardware for a few hundred steps.
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--gp", action="store_true",
                    help="use the GP-H preconditioned optimizer")
    ap.add_argument("--ckpt", default="",
                    help="checkpoint dir (default: fresh temp dir)")
    args = ap.parse_args()

    cfg = ModelConfig(
        arch="example-lm", family="dense", n_layers=args.layers,
        d_model=args.dim, n_heads=8, n_kv_heads=4, d_ff=4 * args.dim,
        vocab_size=args.vocab, window=64, global_every=4,
        param_dtype=jnp.float32, compute_dtype=jnp.float32, remat=False)

    seq_len, batch = args.seq, args.batch
    SHAPES["example"] = ShapeSpec("example", seq_len, batch, "train")

    mesh = make_test_mesh((1, len(jax.devices())), ("data", "model"))
    opt = get_optimizer("gp", lr=1.0, history=4, fallback_lr=1e-3,
                        max_step_rms=2e-3) if args.gp else \
        get_optimizer("adamw", lr=args.lr)
    bundle = build_train_step(cfg, opt, mesh, shape="example", donate=False)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(
        bundle.abstract_params))
    print(f"model: {n_params/1e6:.1f}M params, optimizer: {opt.name}")

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                    global_batch=batch)
    params = bundle.model.init(jax.random.PRNGKey(0))
    opt_state = bundle.opt.init(params)

    entropy_floor = float(jnp.log(cfg.vocab_size))
    t0 = time.time()
    hist = []

    def on_metrics(step, metrics):
        hist.append(float(metrics["loss"]))
        if step % 20 == 0 or step == 1:
            print(f"step {step:4d}  loss {hist[-1]:.3f}  "
                  f"(iid floor ~{entropy_floor:.2f})  "
                  f"{time.time()-t0:.0f}s", flush=True)

    import tempfile

    ckpt_dir = args.ckpt or tempfile.mkdtemp(prefix="repro_lm_")
    params, opt_state, stats = run_with_recovery(
        bundle.step, lambda s: batch_for_step(dc, s), params, opt_state,
        n_steps=args.steps,
        config=RecoveryConfig(ckpt_dir=ckpt_dir, ckpt_every=100),
        on_metrics=on_metrics)

    # copy-region loss: the second half of every sequence is a repeat, so a
    # model with induction heads beats the entropy floor there by a lot
    final = sum(hist[-10:]) / 10
    # average loss mixes random half (floor) and copy half (low): the
    # mixture must drop clearly below the floor
    print(f"final loss {final:.3f} vs iid floor {entropy_floor:.3f} "
          f"-> {'LEARNED copy pattern' if final < 0.8 * entropy_floor else 'available headroom unexploited (train longer)'}")


if __name__ == "__main__":
    main()

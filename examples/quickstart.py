"""Quickstart: a streaming gradient-GP posterior in ~40 lines.

Condition on gradient evaluations of a 10,000-dimensional function ONE AT
A TIME (the operation the paper makes O(N^2 D) instead of O((ND)^3)) and
serve batched posterior queries off the single cached solve.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp

from repro.core import GPGState

D = 10_000                   # dimension — the axis the paper makes cheap
N = 8                        # gradient observations (low-data regime N < D)


def f(x):                    # some smooth high-dimensional function
    return jnp.sum(jnp.sin(x) * jnp.roll(x, 1)) / D


grad_f = jax.grad(f)

key = jax.random.PRNGKey(0)
X = jax.random.normal(key, (N, D))

# stream the observations in: each extend() is a bordered O(N^2 D) factor
# update + warm-started re-solve — never a from-scratch refactorization
st = GPGState("rbf", d=D, window=N, lam=1.0 / D, noise=1e-10)
t0 = time.time()
for i in range(N):
    st.extend(X[i], grad_f(X[i]))
print(f"streamed {N} gradients in R^{D} in {time.time()-t0:.2f}s — {st}")
assert st.stats["n_refactor"] == 0, "extends were incremental"

# with N << D the model is LOCAL (exactly how the paper uses it: optimizer
# steps, HMC trajectories) — query near the data, not across the void.
# One batched call serves values, gradients AND Hessian-probe products
# for all queries with ZERO re-solves (factor reuse).
Xq = X[:2] + 0.05 * jax.random.normal(jax.random.fold_in(key, 1), (2, D))
v = jax.random.normal(jax.random.fold_in(key, 2), (D,))
pb = st.posterior(Xq, probe=v)
true = jax.vmap(grad_f)(Xq)
print("pred/true cosine near data:",
      [round(float(jnp.vdot(p, t) /
                   (jnp.linalg.norm(p) * jnp.linalg.norm(t))), 3)
       for p, t in zip(pb.grad, true)])
print("Hessian probe applied:", float(jnp.linalg.norm(pb.hess_v[0])))
print("solves:", st.stats["n_solve"], "(queries added none)")
print("(never materialized the", f"{N*D}x{N*D}", "Gram matrix —",
      f"state holds {4*N*D + 3*N*N} numbers)")

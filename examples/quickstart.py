"""Quickstart: GP inference with gradient observations in 40 lines.

Condition a gradient-GP on a handful of gradient evaluations of a 10,000-
dimensional function and predict gradients at new points — the operation
the paper makes O(N^2 D) instead of O((ND)^3).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp

from repro.core import (build_factors, get_kernel, posterior_grad,
                        posterior_hessian, woodbury_solve)

D = 10_000                   # dimension — the axis the paper makes cheap
N = 8                        # gradient observations (low-data regime N < D)


def f(x):                    # some smooth high-dimensional function
    return jnp.sum(jnp.sin(x) * jnp.roll(x, 1)) / D


grad_f = jax.grad(f)

key = jax.random.PRNGKey(0)
X = jax.random.normal(key, (N, D))
G = jax.vmap(grad_f)(X)

spec = get_kernel("rbf")                       # or matern52, rq, poly2, ...
lam = 1.0 / D                                  # isotropic lengthscale^2 = D

t0 = time.time()
factors = build_factors(spec, X, lam=lam, noise=1e-10)   # O(N^2 D) storage
Z = woodbury_solve(spec, factors, G)                     # O(N^2 D + N^6)
print(f"conditioned on {N} gradients in R^{D} in {time.time()-t0:.2f}s")

# with N << D the model is LOCAL (exactly how the paper uses it: optimizer
# steps, HMC trajectories) — query near the data, not across the void
xq = X[:2] + 0.05 * jax.random.normal(jax.random.fold_in(key, 1), (2, D))
pred = posterior_grad(spec, xq, factors, Z)
true = jax.vmap(grad_f)(xq)
print("pred/true cosine near data:",
      [round(float(jnp.vdot(p, t) /
                   (jnp.linalg.norm(p) * jnp.linalg.norm(t))), 3)
       for p, t in zip(pred, true)])

# posterior-mean Hessian at a point: diag + rank-2N operator, O(ND) to apply
H = posterior_hessian(spec, xq[0], factors, Z)
v = jax.random.normal(jax.random.fold_in(key, 2), (D,))
print("Hessian operator applied:", float(jnp.linalg.norm(H.matvec(v))))
print("(never materialized the", f"{N*D}x{N*D}", "Gram matrix —",
      f"factors hold {3*N*D + 2*N*N} numbers)")

"""Streaming first-order Bayesian optimization on the incremental state.

The online loop the serving layer exists for (cf. Ament & Gomes 2022,
"Scalable First-Order Bayesian Optimization", and the paper's Sec. 4.1
optimizer workloads):

    observe gradient  ->  GPGState.extend()       (bordered O(N^2 D) update,
                                                   sliding window, NO
                                                   refactorization)
                      ->  batched candidate scoring over the compiled
                          serve step               (Q candidates along the
                                                   gradient ray, posterior-
                                                   value acquisition,
                                                   ZERO re-solves)
                      ->  pick the next point, evaluate, repeat.

Every iteration touches the inner system exactly once (the extend's
warm-started re-solve); all Q candidate evaluations ride the cached
factors through train/serve.py's fixed-shape jitted query step — the same
executable across all rounds, because extend() never changes array shapes.

Run:   PYTHONPATH=src python examples/streaming_bo.py [--smoke]
"""
import sys
import time

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp

from repro.core import GPGState
from repro.train.serve import build_gp_serve_step

SMOKE = "--smoke" in sys.argv
D = 64 if SMOKE else 500          # search-space dimension
ROUNDS = 6 if SMOKE else 30       # BO iterations
Q = 64                            # candidates scored per round (batched)
WINDOW = 8                        # bounded posterior window (evict oldest)


def f(x):                         # ill-conditioned quadratic + ripple
    w = 1.0 + 9.0 * jnp.arange(D) / D
    return 0.5 * jnp.sum(w * x * x) + 0.1 * jnp.sum(jnp.cos(3.0 * x)) / D


fg = jax.jit(jax.value_and_grad(f))

key = jax.random.PRNGKey(0)
x0 = 2.0 * jax.random.normal(key, (D,))
st = GPGState("rbf", d=D, window=WINDOW, lam=1.0 / D, noise=1e-9)
serve = build_gp_serve_step(st, microbatch=Q)

best_x = x0
best_f, best_g = fg(x0)
best_f = float(best_f)
f0 = best_f
alpha = 0.05                      # adaptive trust-region step scale
t0 = time.time()
for it in range(ROUNDS):
    # 1. stream the gradient at the incumbent into the posterior state
    st.extend(best_x, best_g)

    # 2. candidates along the (jittered) gradient ray at Q step sizes;
    #    ONE batched query against the cached solve scores them all —
    #    the posterior mean value is the acquisition (pure exploitation)
    key, k1 = jax.random.split(key)
    steps = alpha * jnp.logspace(-2.0, 1.0, Q)[:, None]
    jitterd = (0.05 * jnp.linalg.norm(best_g) / jnp.sqrt(D)
               * jax.random.normal(k1, (Q, D)))
    cands = best_x[None] - steps * (best_g[None] + jitterd)
    pb = serve.query(cands)
    pick = cands[int(jnp.argmin(pb.value))]

    # 3. the ONLY true function/gradient evaluation of the round
    fx, gx = fg(pick)
    if float(fx) < best_f:
        best_x, best_f, best_g = pick, float(fx), gx
        alpha = min(alpha * 1.5, 10.0)         # grow the trust region
    else:
        st.extend(pick, gx)                    # failed pick still informs
        alpha = max(alpha * 0.5, 1e-5)
    if it % 5 == 0 or SMOKE:
        s = st.stats
        print(f"round {it:3d}  f(pick)={float(fx):+.4f}  best={best_f:+.4f}"
              f"  n={s['n']}  solves={s['n_solve']}"
              f"  refactors={s['n_refactor']}  cg_iters={s['cg_iters']}")

print(f"\n{ROUNDS} rounds, {Q} candidates/round in {time.time()-t0:.1f}s: "
      f"f {f0:+.3f} -> {best_f:+.3f}  ({st})")
assert best_f < f0, "BO loop failed to improve on the start point"

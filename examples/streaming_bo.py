"""Streaming first-order Bayesian optimization on the incremental state.

The online loop the serving layer exists for (cf. Ament & Gomes 2022,
"Scalable First-Order Bayesian Optimization", and the paper's Sec. 4.1
optimizer workloads):

    observe gradient  ->  GPGState.extend()       (bordered O(N^2 D) update,
                                                   sliding window, NO
                                                   refactorization)
                      ->  batched candidate scoring over the compiled
                          serve step               (Q candidates along the
                                                   gradient ray, EXPECTED
                                                   IMPROVEMENT acquisition
                                                   from the posterior
                                                   mean + std,
                                                   ZERO re-solves)
                      ->  pick the next point, evaluate, repeat.

Every iteration touches the inner system exactly once (the extend's
warm-started re-solve); all Q candidate evaluations ride the cached
factors through train/serve.py's fixed-shape jitted query step — the same
executable across all rounds, because extend() never changes array shapes
(and hypers enter as dynamic solver arrays, so even a refit would not
recompile).

Acquisition: with ``return_std`` on (the default) candidates are ranked by
EI against the incumbent's *model* value — the gradient-only posterior
mean is defined up to an additive constant, so the incumbent is scored in
the SAME batch and the constant cancels.  ``--mean-only`` falls back to
pure posterior-mean exploitation (the pre-uncertainty behavior).

``--chaos`` runs the same loop under a seeded ``ChaosInjector``:
observation payloads are randomly NaN-corrupted (the admission guardrail
rejects them and the loop retries with the clean gradient) and the live
Cholesky is randomly poisoned (the post-extend watchdog heals it on the
jitter ladder).  The loop must still converge — and with ``REPRO_OBS=on``
the log passes ``tools/check_telemetry.py --expect-recovery``.

Run:   PYTHONPATH=src python examples/streaming_bo.py [--smoke] [--mean-only]
                                                      [--chaos]
"""
import sys
import time

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
from jax.scipy.special import erf

from repro.core import GPGState
from repro.train.serve import build_gp_serve_step

SMOKE = "--smoke" in sys.argv
CHAOS = "--chaos" in sys.argv
USE_STD = "--mean-only" not in sys.argv   # EI needs return_std on the step
D = 64 if SMOKE else 500          # search-space dimension
ROUNDS = 6 if SMOKE else 30       # BO iterations
Q = 64                            # candidates scored per round (batched)
WINDOW = 8                        # bounded posterior window (evict oldest)


def f(x):                         # ill-conditioned quadratic + ripple
    w = 1.0 + 9.0 * jnp.arange(D) / D
    return 0.5 * jnp.sum(w * x * x) + 0.1 * jnp.sum(jnp.cos(3.0 * x)) / D


fg = jax.jit(jax.value_and_grad(f))


def _phi(z):                      # standard normal pdf
    return jnp.exp(-0.5 * z * z) / jnp.sqrt(2.0 * jnp.pi)


def _Phi(z):                      # standard normal cdf
    return 0.5 * (1.0 + erf(z / jnp.sqrt(2.0)))


def expected_improvement(mu, sigma, mu_best):
    """EI for MINIMIZATION: E[max(mu_best - f, 0)] under N(mu, sigma^2)."""
    sigma = jnp.maximum(sigma, 1e-12)
    imp = mu_best - mu
    z = imp / sigma
    return imp * _Phi(z) + sigma * _phi(z)


key = jax.random.PRNGKey(0)
x0 = 2.0 * jax.random.normal(key, (D,))
st = GPGState("rbf", d=D, window=WINDOW, lam=1.0 / D, noise=1e-9)
serve = build_gp_serve_step(st, microbatch=Q + 1, return_std=USE_STD)

if CHAOS:
    from repro.resilience import ChaosInjector, guardrails
    from repro.resilience.errors import NonFiniteObservationError

    chaos = ChaosInjector(seed=7, rates={"nan_payload": 0.3,
                                         "degenerate_factor": 0.2})


def observe(x, g):
    """Stream one gradient observation, optionally under chaos."""
    if CHAOS and chaos.draw("nan_payload"):
        try:                          # the admission guardrail rejects it
            st.extend(x, chaos.corrupt_payload(g))
        except NonFiniteObservationError:
            guardrails.record_recovery("nan_payload")
    if CHAOS and st.n >= 1 and chaos.poison_factor(st):
        pass                          # the extend below heals it in-line
    st.extend(x, g)

best_x = x0
best_f, best_g = fg(x0)
best_f = float(best_f)
f0 = best_f
alpha = 0.05                      # adaptive trust-region step scale
incumbent_fresh = True            # extend the incumbent only when it moved
t0 = time.time()
for it in range(ROUNDS):
    # 1. stream the gradient at the incumbent into the posterior state —
    #    but only a NEW incumbent: re-appending an unchanged best_x every
    #    stalled round would fill the sliding window with duplicates and
    #    degenerate the bordered factorization
    if incumbent_fresh:
        observe(best_x, best_g)
        incumbent_fresh = False

    # 2. candidates along the (jittered) gradient ray at Q step sizes,
    #    plus the incumbent itself (the EI reference — the posterior mean
    #    from gradients is only defined up to a constant, which cancels
    #    inside one batch); ONE batched query scores them all
    key, k1 = jax.random.split(key)
    steps = alpha * jnp.logspace(-2.0, 1.0, Q)[:, None]
    jitterd = (0.05 * jnp.linalg.norm(best_g) / jnp.sqrt(D)
               * jax.random.normal(k1, (Q, D)))
    cands = best_x[None] - steps * (best_g[None] + jitterd)
    batch = jnp.concatenate([cands, best_x[None]], axis=0)
    pb = serve.query(batch)
    if pb.std is not None:        # EI acquisition (falls back to mean)
        mu, mu_best = pb.value[:Q], pb.value[Q]
        ei = expected_improvement(mu, pb.std[:Q], mu_best)
        pick = cands[int(jnp.argmax(ei))]
    else:
        pick = cands[int(jnp.argmin(pb.value[:Q]))]

    # 3. the ONLY true function/gradient evaluation of the round
    fx, gx = fg(pick)
    if float(fx) < best_f:
        best_x, best_f, best_g = pick, float(fx), gx
        incumbent_fresh = True
        alpha = min(alpha * 1.5, 10.0)         # grow the trust region
    else:
        observe(pick, gx)                      # failed pick still informs
        alpha = max(alpha * 0.5, 1e-5)
    if it % 5 == 0 or SMOKE:
        s = st.stats
        print(f"round {it:3d}  f(pick)={float(fx):+.4f}  best={best_f:+.4f}"
              f"  n={s['n']}  solves={s['n_solve']}"
              f"  refactors={s['n_refactor']}  cg_iters={s['cg_iters']}")

acq = "EI" if USE_STD else "mean"
print(f"\n{ROUNDS} rounds ({acq} acquisition), {Q} candidates/round in "
      f"{time.time()-t0:.1f}s: f {f0:+.3f} -> {best_f:+.3f}  ({st})")
assert best_f < f0, "BO loop failed to improve on the start point"

"""D-sharded (shard_map) variants of the structured Gram operations.

The paper's decomposition has one systems-defining property: every O(D)
object only appears inside tall-skinny contractions that reduce to (N, N).
Sharding the dimension axis over the WHOLE mesh therefore makes each Gram
op a purely local (N, D_loc) computation plus a psum of a few N x N
matrices — O(N^2) bytes of collective traffic per solve, independent of D
and of device count. That is the communication-avoiding scheme this module
implements (DESIGN.md sec. 2/6).

All functions here are written for use INSIDE shard_map (they take local
shards and issue explicit psums over `axis_names`). ``sharded_*`` wrappers
construct the shard_map for callers holding global arrays.

Layout: (N, D) rows=observations, D sharded on the last axis. Lambda must
be scalar, or a (D,) diagonal sharded like the data.
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.5 exposes shard_map at the top level
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map as _shard_map

from . import backend
from .gram import FactorBundle, GramFactors
from .kernels import KernelSpec
from .mvm import gram_matvec, l_op, lt_op

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# Collective-side primitives (called inside shard_map)
# ---------------------------------------------------------------------------

def ring_psum(x, axis_name: str, size: int):
    """All-reduce built from ``size - 1`` ppermute ring hops (pytree-safe).

    Numerically a psum (up to summation order), but each hop is an
    independent point-to-point ``ppermute`` whose result the caller only
    consumes at the END of its pipeline stage — so XLA's latency-hiding
    scheduler can overlap the hops with unrelated local compute (the
    Megatron-style collective/compute overlap; ``core.dist_state.
    sgpg_posterior_mean_pipelined`` carries the in-flight reduction across
    a scan step).  Requires a flat one-axis mesh; ``size`` must be the
    static axis size.
    """
    if size == 1:
        return x
    perm = [(i, (i + 1) % size) for i in range(size)]
    acc, cur = x, x
    for _ in range(size - 1):
        cur = jax.tree_util.tree_map(
            lambda t: jax.lax.ppermute(t, axis_name, perm), cur)
        acc = jax.tree_util.tree_map(jnp.add, acc, cur)
    return acc

def local_scaled_gram(A: Array, B: Array, lam, axis_names: Sequence[str]) -> Array:
    """psum_d (A*lam) @ B^T for D-sharded A, B: the N^2-byte collective.

    The local partial routes through the backend dispatch, so on TPU each
    device runs the Pallas skinny-gram kernel over its (N, D_loc) shard.
    """
    part = backend.scaled_gram(A, B, lam)
    return jax.lax.psum(part, axis_names)


def local_pairwise_r(
    spec: KernelSpec, A: Array, B: Array, lam, axis_names: Sequence[str],
    c: Array | None = None,
) -> Array:
    """Pairwise r for D-sharded inputs; one fused psum of (gram, norms)."""
    if spec.is_stationary:
        part, da, db = backend.gram_norms(A, B, lam)
        g, da, db = jax.lax.psum((part, da, db), axis_names)
        return jnp.maximum(da[:, None] + db[None, :] - 2.0 * g, 0.0)
    At = A if c is None else A - c
    Bt = B if c is None else B - c
    return local_scaled_gram(At, Bt, lam, axis_names)


def local_build_factors(
    spec: KernelSpec, X: Array, lam, axis_names: Sequence[str],
    c: Array | None = None, noise: float = 0.0,
) -> GramFactors:
    """GramFactors with local (N, D_loc) Xt but *global* (replicated) K1e/K2e."""
    r = local_pairwise_r(spec, X, X, lam, axis_names, c=c)
    Xt = X if (spec.is_stationary or c is None) else X - c
    return GramFactors(K1e=spec.k1e(r), K2e=spec.k2e(r), Xt=Xt, lam=lam,
                       noise=float(noise), c=None if spec.is_stationary else c)


def local_gram_matvec(
    f: GramFactors, V: Array, *, stationary: bool, axis_names: Sequence[str],
) -> Array:
    """(grad K grad') vec(V) with D-sharded V/Xt. One N^2 psum, rest local.

    Identical math to core.mvm.gram_matvec: the only cross-device term is
    M = (Xt*lam) @ V^T; the (N,N) algebra is replicated and the final
    (N,N) @ (N,D_loc) update runs locally as one backend.gram_update
    launch (via gram_matvec's precomputed-gram path).
    """
    M = local_scaled_gram(f.Xt, V, f.lam, axis_names)
    return gram_matvec(f, V, stationary=stationary, gram_xv=M)


def local_factor_bundle(
    spec: KernelSpec, X: Array, G: Array, lam, axis_names: Sequence[str],
    c: Array | None = None, noise: float = 0.0,
) -> FactorBundle:
    """D-sharded ``build_factor_bundle``: ONE fused psum for everything.

    The single ``backend.fused_factor_build`` sweep of the local (N, D_loc)
    shards emits the gram/norm partials AND the RHS contraction C = G X~^T,
    so one stacked psum replicates every (N, N) strip a solve needs —
    where ``local_build_factors`` + ``local_woodbury_solve`` used to issue
    three separate collectives per solve.  The bundle's ``factors.Xt``
    stays LOCAL (it only ever feeds local output-assembly streams).
    """
    Xt = X if (spec.is_stationary or c is None) else X - c
    P_, na, nb, C, _ = backend.fused_factor_build(Xt, Xt, G, lam)
    P_, na, C = jax.lax.psum((P_, na, C), axis_names)
    if spec.is_stationary:
        r = jnp.maximum(na[:, None] + na[None, :] - 2.0 * P_, 0.0)
    else:
        r = P_
    f = GramFactors(K1e=spec.k1e(r), K2e=spec.k2e(r), Xt=Xt, lam=lam,
                    noise=float(noise), c=None if spec.is_stationary else c)
    return FactorBundle(factors=f, S=P_, C=C)


def local_woodbury_solve(
    spec: KernelSpec, f: GramFactors, G: Array, axis_names: Sequence[str],
    jitter: float = 1e-10, S: Array | None = None, C: Array | None = None,
) -> Array:
    """Exact Woodbury solve with D-sharded Xt/G (paper Eq. 6-8, distributed).

    Cross-device traffic: two (N,N) psums (S and the RHS skinny
    contraction) — or ZERO when a prebuilt bundle supplies them: pass
    ``S``/``C`` from :func:`local_factor_bundle` and the solve reuses the
    replicated strips (T0 = (K1i G) X~^T re-associates to K1i @ C), so
    repeated solves against cached factors issue no collectives at all.
    The N^2 x N^2 inner system is replicated on every device and solved
    redundantly (cheaper than sharding an N<=64 solve).
    """
    n = f.n
    dtype = G.dtype
    K1 = f.K1e
    if f.noise:
        lam_s = jnp.asarray(f.lam)
        K1 = K1 + (f.noise / lam_s) * jnp.eye(n, dtype=dtype)
    K1i = jnp.linalg.inv(K1 + jitter * jnp.eye(n, dtype=dtype))
    if S is None:
        S = local_scaled_gram(f.Xt, f.Xt, f.lam, axis_names)
    if C is not None:
        T = K1i @ C
    else:
        W0 = backend.kron_precond(K1i, G, 1.0)            # local (N, D_loc)
        T = local_scaled_gram(W0, f.Xt, 1.0, axis_names)  # skinny + psum

    if spec.is_stationary:
        T = lt_op(T)

        def inner(Q):
            return -Q.T / f.K2e + lt_op(K1i @ l_op(Q) @ S)

    else:

        def inner(Q):
            return Q.T / f.K2e + K1i @ Q @ S

    eye = jnp.eye(n * n, dtype=dtype).reshape(n * n, n, n)
    A = jax.vmap(inner)(eye).reshape(n * n, n * n).T
    q = jnp.linalg.solve(A + jitter * jnp.eye(n * n, dtype=dtype), T.reshape(-1))
    Q = q.reshape(n, n)

    QL = l_op(Q) if spec.is_stationary else Q
    return backend.gram_update(K1i, -(K1i @ QL), G, f.Xt, 1.0,
                               v_scale=1.0 / jnp.asarray(f.lam))


def local_cross_grad_matvec(
    spec: KernelSpec, Xq: Array, f: GramFactors, V: Array,
    axis_names: Sequence[str],
) -> Array:
    """Posterior-mean gradient at D-sharded query rows Xq: (Nq, D_loc)."""
    lam = f.lam
    if spec.is_stationary:
        r = local_pairwise_r(spec, Xq, f.Xt, lam, axis_names)
        K1e, K2e = spec.k1e(r), spec.k2e(r)
        m_part = backend.scaled_gram(Xq, V, lam) - \
            backend.row_dots(f.Xt, V, lam)[None, :]
        m = jax.lax.psum(m_part, axis_names)
        Mt = K2e * m
        W = backend.gram_update(K1e, -Mt, V, f.Xt, lam)
        return W + (Xq * jnp.sum(Mt, axis=1)[:, None]) * lam
    Xqt = Xq if f.c is None else Xq - f.c
    r = local_scaled_gram(Xqt, f.Xt, lam, axis_names)
    K1e, K2e = spec.k1e(r), spec.k2e(r)
    m = local_scaled_gram(Xqt, V, lam, axis_names)
    return backend.gram_update(K1e, K2e * m, V, f.Xt, lam)


# ---------------------------------------------------------------------------
# shard_map wrappers over a full mesh (callers hold global arrays)
# ---------------------------------------------------------------------------

def _d_sharding(mesh: Mesh):
    """Shard the last (D) axis over ALL mesh axes jointly."""
    return P(None, tuple(mesh.axis_names))


def sharded_gram_matvec(mesh: Mesh, spec: KernelSpec):
    """Returns fn(f: GramFactors[global], V[global]) -> W[global]."""
    names = tuple(mesh.axis_names)
    dspec = _d_sharding(mesh)
    lam_spec = P()  # scalar lam replicated; diagonal handled by caller

    @partial(
        _shard_map, mesh=mesh,
        in_specs=(P(None, None), P(None, None), dspec, lam_spec, dspec),
        out_specs=dspec,
    )
    def _run(K1e, K2e, Xt, lam, V):
        f = GramFactors(K1e=K1e, K2e=K2e, Xt=Xt, lam=lam, noise=0.0, c=None)
        return local_gram_matvec(f, V, stationary=spec.is_stationary,
                                 axis_names=names)

    def apply(f: GramFactors, V: Array) -> Array:
        return _run(f.K1e, f.K2e, f.Xt, jnp.asarray(f.lam), V)

    return apply


def sharded_factor_bundle(mesh: Mesh, spec: KernelSpec, noise: float = 0.0):
    """Returns fn(X[global], G[global], lam, c) -> FactorBundle.

    The bundle's ``factors.Xt`` comes back D-SHARDED (it only feeds local
    output streams); K1e/K2e/S/C are replicated.  Pass the result to
    :func:`sharded_woodbury_solve`'s ``bundle=`` to amortize the ONE
    build collective across repeated solves.
    """
    names = tuple(mesh.axis_names)
    dspec = _d_sharding(mesh)
    rep = P(None, None)
    out = (rep, rep, dspec, rep, rep)  # K1e, K2e, Xt(local), S, C

    def _arrays(b: FactorBundle):
        f = b.factors
        return f.K1e, f.K2e, f.Xt, b.S, b.C

    @partial(
        _shard_map, mesh=mesh,
        in_specs=(dspec, dspec, P()),
        out_specs=out,
    )
    def _run_stationary(X, G, lam):
        return _arrays(local_factor_bundle(spec, X, G, lam, names,
                                           noise=noise))

    @partial(
        _shard_map, mesh=mesh,
        in_specs=(dspec, dspec, P(), dspec),
        out_specs=out,
    )
    def _run_dot(X, G, lam, c):
        return _arrays(local_factor_bundle(spec, X, G, lam, names, c=c,
                                           noise=noise))

    def build(X: Array, G: Array, lam=1.0,
              c: Array | None = None) -> FactorBundle:
        lam = jnp.asarray(lam)
        if spec.is_stationary:
            K1e, K2e, Xt, S, C = _run_stationary(X, G, lam)
        else:
            if c is None:
                c = jnp.zeros((1, X.shape[1]), X.dtype)
            K1e, K2e, Xt, S, C = _run_dot(X, G, lam, jnp.atleast_2d(c))
        # Xt comes back pre-centered for dot kernels: c=None by design
        f = GramFactors(K1e=K1e, K2e=K2e, Xt=Xt, lam=lam,
                        noise=float(noise), c=None)
        return FactorBundle(factors=f, S=S, C=C)

    return build


def sharded_woodbury_solve(mesh: Mesh, spec: KernelSpec, noise: float = 0.0):
    """Returns fn(X[global], G[global], lam, c, bundle) -> Z[global].

    Without ``bundle``: builds factors and solves in one shard_map (one
    fused build psum + one RHS psum).  With a ``bundle`` from
    :func:`sharded_factor_bundle`: the prebuilt local factors and
    replicated S/C strips are REUSED — the solve issues ZERO collectives,
    matching the single-device ``woodbury_solve(bundle=...)`` fast path
    (which this wrapper used to ignore, re-streaming X per solve).
    """
    names = tuple(mesh.axis_names)
    dspec = _d_sharding(mesh)
    rep = P(None, None)

    @partial(
        _shard_map, mesh=mesh,
        in_specs=(dspec, dspec, P()),
        out_specs=dspec,
    )
    def _run_stationary(X, G, lam):
        b = local_factor_bundle(spec, X, G, lam, names, noise=noise)
        return local_woodbury_solve(spec, b.factors, G, names, S=b.S, C=b.C)

    @partial(
        _shard_map, mesh=mesh,
        in_specs=(dspec, dspec, P(), dspec),
        out_specs=dspec,
    )
    def _run_dot(X, G, lam, c):
        b = local_factor_bundle(spec, X, G, lam, names, c=c, noise=noise)
        return local_woodbury_solve(spec, b.factors, G, names, S=b.S, C=b.C)

    @partial(
        _shard_map, mesh=mesh,
        in_specs=(rep, rep, dspec, P(), rep, rep, dspec),
        out_specs=dspec,
    )
    def _run_bundle(K1e, K2e, Xt, lam, S, C, G):
        f = GramFactors(K1e=K1e, K2e=K2e, Xt=Xt, lam=lam,
                        noise=float(noise), c=None)
        return local_woodbury_solve(spec, f, G, names, S=S, C=C)

    def solve(X: Array, G: Array, lam=1.0, c: Array | None = None,
              bundle: FactorBundle | None = None) -> Array:
        if bundle is not None:
            f = bundle.factors
            Xt = f.Xt if f.c is None else f.Xt - f.c  # fold dot centering
            return _run_bundle(f.K1e, f.K2e, Xt, jnp.asarray(f.lam),
                               bundle.S, bundle.C, G)
        lam = jnp.asarray(lam)
        if spec.is_stationary:
            return _run_stationary(X, G, lam)
        if c is None:
            c = jnp.zeros((1, X.shape[1]), X.dtype)
        return _run_dot(X, G, lam, jnp.atleast_2d(c))

    return solve

"""D-sharded (shard_map) variants of the structured Gram operations.

The paper's decomposition has one systems-defining property: every O(D)
object only appears inside tall-skinny contractions that reduce to (N, N).
Sharding the dimension axis over the WHOLE mesh therefore makes each Gram
op a purely local (N, D_loc) computation plus a psum of a few N x N
matrices — O(N^2) bytes of collective traffic per solve, independent of D
and of device count. That is the communication-avoiding scheme this module
implements (DESIGN.md sec. 2/6).

All functions here are written for use INSIDE shard_map (they take local
shards and issue explicit psums over `axis_names`). ``sharded_*`` wrappers
construct the shard_map for callers holding global arrays.

Layout: (N, D) rows=observations, D sharded on the last axis. Lambda must
be scalar, or a (D,) diagonal sharded like the data.
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.5 exposes shard_map at the top level
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map as _shard_map

from . import backend
from .gram import GramFactors
from .kernels import KernelSpec
from .mvm import gram_matvec, l_op, lt_op

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# Collective-side primitives (called inside shard_map)
# ---------------------------------------------------------------------------

def local_scaled_gram(A: Array, B: Array, lam, axis_names: Sequence[str]) -> Array:
    """psum_d (A*lam) @ B^T for D-sharded A, B: the N^2-byte collective.

    The local partial routes through the backend dispatch, so on TPU each
    device runs the Pallas skinny-gram kernel over its (N, D_loc) shard.
    """
    part = backend.scaled_gram(A, B, lam)
    return jax.lax.psum(part, axis_names)


def local_pairwise_r(
    spec: KernelSpec, A: Array, B: Array, lam, axis_names: Sequence[str],
    c: Array | None = None,
) -> Array:
    """Pairwise r for D-sharded inputs; one fused psum of (gram, norms)."""
    if spec.is_stationary:
        part, da, db = backend.gram_norms(A, B, lam)
        g, da, db = jax.lax.psum((part, da, db), axis_names)
        return jnp.maximum(da[:, None] + db[None, :] - 2.0 * g, 0.0)
    At = A if c is None else A - c
    Bt = B if c is None else B - c
    return local_scaled_gram(At, Bt, lam, axis_names)


def local_build_factors(
    spec: KernelSpec, X: Array, lam, axis_names: Sequence[str],
    c: Array | None = None, noise: float = 0.0,
) -> GramFactors:
    """GramFactors with local (N, D_loc) Xt but *global* (replicated) K1e/K2e."""
    r = local_pairwise_r(spec, X, X, lam, axis_names, c=c)
    Xt = X if (spec.is_stationary or c is None) else X - c
    return GramFactors(K1e=spec.k1e(r), K2e=spec.k2e(r), Xt=Xt, lam=lam,
                       noise=float(noise), c=None if spec.is_stationary else c)


def local_gram_matvec(
    f: GramFactors, V: Array, *, stationary: bool, axis_names: Sequence[str],
) -> Array:
    """(grad K grad') vec(V) with D-sharded V/Xt. One N^2 psum, rest local.

    Identical math to core.mvm.gram_matvec: the only cross-device term is
    M = (Xt*lam) @ V^T; the (N,N) algebra is replicated and the final
    (N,N) @ (N,D_loc) update runs locally as one backend.gram_update
    launch (via gram_matvec's precomputed-gram path).
    """
    M = local_scaled_gram(f.Xt, V, f.lam, axis_names)
    return gram_matvec(f, V, stationary=stationary, gram_xv=M)


def local_woodbury_solve(
    spec: KernelSpec, f: GramFactors, G: Array, axis_names: Sequence[str],
    jitter: float = 1e-10,
) -> Array:
    """Exact Woodbury solve with D-sharded Xt/G (paper Eq. 6-8, distributed).

    Cross-device traffic: exactly two (N,N) psums (S and the RHS skinny
    contraction) — the N^2 x N^2 inner system is replicated on every device
    and solved redundantly (cheaper than sharding an N<=64 solve).
    """
    n = f.n
    dtype = G.dtype
    K1 = f.K1e
    if f.noise:
        lam_s = jnp.asarray(f.lam)
        K1 = K1 + (f.noise / lam_s) * jnp.eye(n, dtype=dtype)
    K1i = jnp.linalg.inv(K1 + jitter * jnp.eye(n, dtype=dtype))
    S = local_scaled_gram(f.Xt, f.Xt, f.lam, axis_names)
    W0 = backend.kron_precond(K1i, G, 1.0)            # local (N, D_loc)
    T = local_scaled_gram(W0, f.Xt, 1.0, axis_names)  # skinny + psum

    if spec.is_stationary:
        T = lt_op(T)

        def inner(Q):
            return -Q.T / f.K2e + lt_op(K1i @ l_op(Q) @ S)

    else:

        def inner(Q):
            return Q.T / f.K2e + K1i @ Q @ S

    eye = jnp.eye(n * n, dtype=dtype).reshape(n * n, n, n)
    A = jax.vmap(inner)(eye).reshape(n * n, n * n).T
    q = jnp.linalg.solve(A + jitter * jnp.eye(n * n, dtype=dtype), T.reshape(-1))
    Q = q.reshape(n, n)

    QL = l_op(Q) if spec.is_stationary else Q
    return backend.gram_update(K1i, -(K1i @ QL), G, f.Xt, 1.0,
                               v_scale=1.0 / jnp.asarray(f.lam))


def local_cross_grad_matvec(
    spec: KernelSpec, Xq: Array, f: GramFactors, V: Array,
    axis_names: Sequence[str],
) -> Array:
    """Posterior-mean gradient at D-sharded query rows Xq: (Nq, D_loc)."""
    lam = f.lam
    if spec.is_stationary:
        r = local_pairwise_r(spec, Xq, f.Xt, lam, axis_names)
        K1e, K2e = spec.k1e(r), spec.k2e(r)
        m_part = backend.scaled_gram(Xq, V, lam) - \
            backend.row_dots(f.Xt, V, lam)[None, :]
        m = jax.lax.psum(m_part, axis_names)
        Mt = K2e * m
        W = backend.gram_update(K1e, -Mt, V, f.Xt, lam)
        return W + (Xq * jnp.sum(Mt, axis=1)[:, None]) * lam
    Xqt = Xq if f.c is None else Xq - f.c
    r = local_scaled_gram(Xqt, f.Xt, lam, axis_names)
    K1e, K2e = spec.k1e(r), spec.k2e(r)
    m = local_scaled_gram(Xqt, V, lam, axis_names)
    return backend.gram_update(K1e, K2e * m, V, f.Xt, lam)


# ---------------------------------------------------------------------------
# shard_map wrappers over a full mesh (callers hold global arrays)
# ---------------------------------------------------------------------------

def _d_sharding(mesh: Mesh):
    """Shard the last (D) axis over ALL mesh axes jointly."""
    return P(None, tuple(mesh.axis_names))


def sharded_gram_matvec(mesh: Mesh, spec: KernelSpec):
    """Returns fn(f: GramFactors[global], V[global]) -> W[global]."""
    names = tuple(mesh.axis_names)
    dspec = _d_sharding(mesh)
    lam_spec = P()  # scalar lam replicated; diagonal handled by caller

    @partial(
        _shard_map, mesh=mesh,
        in_specs=(P(None, None), P(None, None), dspec, lam_spec, dspec),
        out_specs=dspec,
    )
    def _run(K1e, K2e, Xt, lam, V):
        f = GramFactors(K1e=K1e, K2e=K2e, Xt=Xt, lam=lam, noise=0.0, c=None)
        return local_gram_matvec(f, V, stationary=spec.is_stationary,
                                 axis_names=names)

    def apply(f: GramFactors, V: Array) -> Array:
        return _run(f.K1e, f.K2e, f.Xt, jnp.asarray(f.lam), V)

    return apply


def sharded_woodbury_solve(mesh: Mesh, spec: KernelSpec, noise: float = 0.0):
    """Returns fn(X[global], G[global], lam, c) -> Z[global] (exact solve)."""
    names = tuple(mesh.axis_names)
    dspec = _d_sharding(mesh)

    @partial(
        _shard_map, mesh=mesh,
        in_specs=(dspec, dspec, P()),
        out_specs=dspec,
    )
    def _run_stationary(X, G, lam):
        f = local_build_factors(spec, X, lam, names, noise=noise)
        return local_woodbury_solve(spec, f, G, names)

    @partial(
        _shard_map, mesh=mesh,
        in_specs=(dspec, dspec, P(), dspec),
        out_specs=dspec,
    )
    def _run_dot(X, G, lam, c):
        f = local_build_factors(spec, X, lam, names, c=c, noise=noise)
        return local_woodbury_solve(spec, f, G, names)

    def solve(X: Array, G: Array, lam=1.0, c: Array | None = None) -> Array:
        lam = jnp.asarray(lam)
        if spec.is_stationary:
            return _run_stationary(X, G, lam)
        if c is None:
            c = jnp.zeros((1, X.shape[1]), X.dtype)
        return _run_dot(X, G, lam, jnp.atleast_2d(c))

    return solve

"""Multi-tenant GP fleet: a (B,)-stacked ``GPGData`` stepped by ONE program.

The ROADMAP north star is "millions of users" — i.e. millions of
*independent* gradient-GP posteriors, not one big one.  ``GPGData`` is
already a fixed-capacity, jit-compatible pytree, so the whole incremental
lifecycle (``core/state.py``) batches with ``jax.vmap``:

  ``FleetGPGData``   — every ``GPGData`` leaf stacked to ``(B, ...)``, plus
                       per-tenant ``noise``/``signal`` hyper vectors and an
                       ``active`` lane mask.  Per-tenant count, Lambda,
                       noise, and signal all ride as TRACED arrays, so one
                       compiled program serves a heterogeneous tenant mix
                       (different N, different hypers) without retracing.
  ``fleet_extend``   — vmapped bordered-Cholesky append (auto-evict at the
                       window), masked per lane: unselected/inactive lanes
                       pass through bit-untouched.
  ``fleet_evict``    — vmapped sliding-window evict.
  ``fleet_resolve``  — vmapped re-solve against new per-tenant RHS.
  ``fleet_posterior``— vmapped batched posterior queries (B, Q, D).
  ``fleet_refit``    — vmapped MLL ascent (``hyper.fit.fit_scan_fn`` on the
                       per-tenant (N, N) evidence strips) + refactor.

Masking convention (DESIGN.md sec. 15): ops take a ``(B,)`` boolean lane
mask; the vmapped update is computed for every lane and the result is
selected leaf-wise against the old pytree, so masked lanes are EXACTLY the
old bits — a lane full of garbage (or NaNs) can never taint its neighbours
(there is no cross-lane contraction anywhere in the vmapped program) and
fleet-level reductions (``fleet_total_mll``) zero inactive lanes before
summing.  Per-tenant correctness is the single-tenant state machine's:
lane b of a fleet trajectory equals the same op sequence driven through
``GPGState`` (fuzz-asserted in tests/test_property_invariants.py).

``GPFleet`` is the host-facing wrapper (slot allocation, compile-watched
launches, revision bookkeeping); the continuous-batching request front end
lives in ``train/serve.py::GPFleetServer``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.obs import compile_watch as _cw
from repro.obs import trace as _obs

from .kernels import KernelSpec, get_kernel
from .gram import GramFactors
from .query import PosteriorBatch, make_query_fn
from .state import (GPGData, gpg_evict, gpg_extend, gpg_init, gpg_refactor,
                    gpg_resolve)

Array = jnp.ndarray


class FleetGPGData(NamedTuple):
    """B independent posterior states as one jit-compatible pytree.

    data:   ``GPGData`` with every leaf stacked to (B, ...) — per-lane
            X/G/Xt/Z (B, cap, D), factor strips + L (B, cap, cap), lam /
            count / solver stats (B,).
    noise:  (B,) raw per-tenant noise variance sigma^2.
    signal: (B,) per-tenant signal variance s^2.
    active: (B,) bool — live tenant lanes; inactive lanes are zeroed-out
            empty states and every fleet op masks them.
    """

    data: GPGData
    noise: Array
    signal: Array
    active: Array

    @property
    def batch(self) -> int:
        return self.data.count.shape[0]

    @property
    def capacity(self) -> int:
        return self.data.X.shape[1]

    @property
    def d(self) -> int:
        return self.data.X.shape[2]


def _lane_select(op: Array, new, old):
    """Leaf-wise ``where`` on the leading lane axis: masked lanes keep the
    OLD bits exactly (the no-taint contract)."""
    def pick(a, b):
        o = op.reshape(op.shape + (1,) * (a.ndim - 1))
        return jnp.where(o, a, b)

    return jax.tree_util.tree_map(pick, new, old)


def _noise_eff(fleet: FleetGPGData) -> Array:
    """(B,) effective noise sigma^2/s^2 — what the unscaled solves see."""
    return fleet.noise / fleet.signal


def fleet_lane(fleet: FleetGPGData, b: int) -> GPGData:
    """Lane ``b`` as a plain single-tenant ``GPGData`` view."""
    return jax.tree_util.tree_map(lambda leaf: leaf[b], fleet.data)


def fleet_init(
    spec: KernelSpec,
    d: int,
    capacity: int,
    batch: int,
    *,
    lam=1.0,
    noise=0.0,
    signal=1.0,
    active: bool = False,
    dtype=None,
) -> FleetGPGData:
    """An empty B-lane fleet (every lane an empty ``gpg_init`` state)."""
    single = gpg_init(spec, int(d), int(capacity), lam=1.0, dtype=dtype)
    data = jax.tree_util.tree_map(
        lambda leaf: jnp.broadcast_to(leaf, (int(batch),) + leaf.shape),
        single)
    dt = data.X.dtype
    ones = jnp.ones((int(batch),), dt)
    data = data._replace(lam=jnp.asarray(lam, dt) * ones)
    return FleetGPGData(
        data=data,
        noise=jnp.asarray(noise, dt) * ones,
        signal=jnp.asarray(signal, dt) * ones,
        active=jnp.full((int(batch),), bool(active)),
    )


def _resolve_op(fleet: FleetGPGData, op: Optional[Array]) -> Array:
    op = fleet.active if op is None else jnp.asarray(op) & fleet.active
    return op


# ---------------------------------------------------------------------------
# Lifecycle ops: vmapped + lane-masked (all pure and jit/vmap-safe)
# ---------------------------------------------------------------------------


def fleet_extend(
    spec: KernelSpec,
    fleet: FleetGPGData,
    X: Array,
    G: Array,
    op: Optional[Array] = None,
    *,
    window: Optional[int] = None,
    jitter: float = 1e-10,
    deg_thresh: float = 1e-8,
    tol: float = 1e-10,
    maxiter: Optional[int] = None,
    solve: bool = True,
) -> FleetGPGData:
    """Append one (x, grad) observation per selected lane — one launch.

    X/G: (B, D) payload rows (ignored on unselected lanes).  With a static
    ``window``, selected lanes already at the window auto-evict their
    oldest observation first (solve deferred to the post-extend re-solve),
    mirroring ``GPGState.extend``.  Lanes must satisfy count < capacity
    (window lanes do by construction; the host wrapper enforces the rest).
    """
    op = _resolve_op(fleet, op)
    data = fleet.data
    noise = _noise_eff(fleet)
    mi = int(maxiter) if maxiter is not None else 10 * fleet.capacity + 50
    if window is not None:
        evict_mask = op & (data.count >= int(window))
        evicted = jax.vmap(
            lambda d, nz: gpg_evict(spec, d, noise=nz, solve=False)
        )(data, noise)
        data = _lane_select(evict_mask, evicted, data)
    # full lanes never extend (count would drift past capacity and corrupt
    # the row mask); window lanes just evicted, so this only trims no-window
    # misuse — the host wrapper raises instead of silently dropping
    op = op & (data.count < fleet.capacity)
    new = jax.vmap(
        lambda d, x, g, nz: gpg_extend(
            spec, d, x, g, noise=nz, jitter=jitter, deg_thresh=deg_thresh,
            tol=tol, maxiter=mi, solve=solve)
    )(data, jnp.asarray(X, data.X.dtype), jnp.asarray(G, data.X.dtype),
      noise)
    return fleet._replace(data=_lane_select(op, new, data))


def fleet_evict(
    spec: KernelSpec,
    fleet: FleetGPGData,
    op: Optional[Array] = None,
    *,
    tol: float = 1e-10,
    maxiter: Optional[int] = None,
    solve: bool = True,
) -> FleetGPGData:
    """Drop the oldest observation on each selected lane — one launch."""
    op = _resolve_op(fleet, op) & (fleet.data.count > 0)
    mi = int(maxiter) if maxiter is not None else 10 * fleet.capacity + 50
    new = jax.vmap(
        lambda d, nz: gpg_evict(spec, d, noise=nz, tol=tol, maxiter=mi,
                                solve=solve)
    )(fleet.data, _noise_eff(fleet))
    return fleet._replace(data=_lane_select(op, new, fleet.data))


def fleet_resolve(
    spec: KernelSpec,
    fleet: FleetGPGData,
    rhs: Array,
    op: Optional[Array] = None,
    *,
    tol: float = 1e-10,
    maxiter: Optional[int] = None,
) -> FleetGPGData:
    """Re-solve selected lanes against new (B, cap, D) right-hand sides.

    Zero refactorization (the GP-X path): factors and Cholesky untouched,
    so per-tenant variance-solver caches keyed on the factor revision stay
    valid across this op (``train/serve.py``).
    """
    op = _resolve_op(fleet, op)
    mi = int(maxiter) if maxiter is not None else 10 * fleet.capacity + 50
    new = jax.vmap(
        lambda d, r, nz: gpg_resolve(spec, d, r, noise=nz, tol=tol,
                                     maxiter=mi)
    )(fleet.data, jnp.asarray(rhs, fleet.data.X.dtype), _noise_eff(fleet))
    return fleet._replace(data=_lane_select(op, new, fleet.data))


def fleet_refactor(
    spec: KernelSpec,
    fleet: FleetGPGData,
    lam: Optional[Array] = None,
    op: Optional[Array] = None,
    *,
    jitter: float = 1e-10,
    tol: float = 1e-10,
    maxiter: Optional[int] = None,
) -> FleetGPGData:
    """Full per-lane factor rebuild (e.g. after a Lambda refresh)."""
    op = _resolve_op(fleet, op)
    mi = int(maxiter) if maxiter is not None else 10 * fleet.capacity + 50
    lam_b = fleet.data.lam if lam is None else jnp.asarray(
        lam, fleet.data.X.dtype)
    new = jax.vmap(
        lambda d, lm, nz: gpg_refactor(spec, d, lm, noise=nz, jitter=jitter,
                                       tol=tol, maxiter=mi)
    )(fleet.data, lam_b, _noise_eff(fleet))
    return fleet._replace(data=_lane_select(op, new, fleet.data))


def fleet_posterior(
    spec: KernelSpec,
    fleet: FleetGPGData,
    Xq: Array,
) -> PosteriorBatch:
    """Batched posterior means for every lane: Xq (B, Q, D) -> (B, Q[, D]).

    Pure cross-covariance contractions against each lane's cached solve —
    zero re-solves, exactly the single-tenant query path vmapped over the
    lane axis (padded rows are inert, so fixed-capacity views keep the
    compiled shapes stable across per-tenant count changes).  Lanes with
    count == 0 (including inactive lanes) return exact zeros.
    """
    qfn = make_query_fn(spec)

    def one(d: GPGData, xq: Array) -> PosteriorBatch:
        f = GramFactors(K1e=d.K1e, K2e=d.K2e, Xt=d.Xt, lam=d.lam,
                        noise=0.0, c=None)
        return qfn(f, d.Z, xq)

    return jax.vmap(one)(fleet.data, jnp.asarray(Xq, fleet.data.X.dtype))


# ---------------------------------------------------------------------------
# Model selection: vmapped evidence + refit
# ---------------------------------------------------------------------------


def _lane_hypers(d: GPGData, noise: Array, signal: Array):
    """Per-lane ``HyperParams`` from the traced lam/noise/signal scalars."""
    from repro.hyper import HyperParams

    return HyperParams(
        log_lengthscale2=-jnp.log(d.lam),
        log_signal=jnp.log(signal),
        log_noise=jnp.log(jnp.maximum(noise, 1e-30)),
    )


def _lane_strips(d: GPGData):
    """The lane's (cap, cap) evidence strips; zero-padded rows are inert."""
    from repro.hyper.mll import strips_for_mll

    return strips_for_mll(d.X, d.G)


def fleet_mll(spec: KernelSpec, fleet: FleetGPGData) -> Array:
    """(B,) exact per-lane log marginal likelihood at the current hypers.

    Evidence is computed from the per-lane (N, N) strips with the count
    mask (``hyper.mll.mll_from_strips``), so uneven per-tenant N shares
    one compiled program.  Empty lanes evaluate to exactly 0.
    """
    from repro.hyper.mll import mll_from_strips

    d_dim = fleet.d

    def one(d: GPGData, nz: Array, sg: Array) -> Array:
        S0, C, GG = _lane_strips(d)
        h = _lane_hypers(d, nz, sg)
        return mll_from_strips(spec, S0, C, GG, d_dim, h, count=d.count)

    return jax.vmap(one)(fleet.data, fleet.noise, fleet.signal)


def fleet_total_mll(spec: KernelSpec, fleet: FleetGPGData) -> Array:
    """Masked fleet evidence: sum of per-lane MLL over ACTIVE, non-empty
    lanes only — padded/inactive tenants contribute exactly zero (the
    invariant tests/test_fleet.py taints for)."""
    per = fleet_mll(spec, fleet)
    keep = fleet.active & (fleet.data.count > 0)
    return jnp.sum(jnp.where(keep, per, 0.0))


def fleet_refit(
    spec: KernelSpec,
    fleet: FleetGPGData,
    op: Optional[Array] = None,
    *,
    steps: int = 16,
    lr: float = 0.1,
    mask=None,
    jitter: float = 1e-10,
    tol: float = 1e-10,
    maxiter: Optional[int] = None,
) -> tuple[FleetGPGData, Array]:
    """Refit every selected lane's hypers by MLL ascent, then refactor.

    The vmapped analogue of ``GPGState.refit``: per lane, a fixed-step
    traceable Adam ascent (``hyper.fit.fit_scan_fn``) on the strips-form
    evidence closure seeded from the lane's current hypers, followed by
    the one legitimate full refactorization + re-solve at the fitted
    Lambda/noise.  Selected lanes need count >= 2 (others are masked out).
    Returns ``(fleet', (B,) fitted mll)`` — masked lanes keep their old
    state bit-exactly and report mll 0.
    """
    from repro.hyper.fit import fit_scan_fn
    from repro.hyper.mll import make_mll_strips_fn

    op = _resolve_op(fleet, op) & (fleet.data.count >= 2)
    d_dim = fleet.d
    mi = int(maxiter) if maxiter is not None else 10 * fleet.capacity + 50

    def one(d: GPGData, nz: Array, sg: Array):
        S0, C, GG = _lane_strips(d)
        fn = make_mll_strips_fn(spec, S0, C, GG, d_dim, count=d.count)
        h, m = fit_scan_fn(fn, _lane_hypers(d, nz, sg), steps=steps, lr=lr,
                           mask=mask)
        new = gpg_refactor(spec, d, h.lam, noise=h.noise_eff, jitter=jitter,
                           tol=tol, maxiter=mi)
        return new, h.noise, h.signal, m

    news, nzs, sgs, mlls = jax.vmap(one)(fleet.data, fleet.noise,
                                         fleet.signal)
    return fleet._replace(
        data=_lane_select(op, news, fleet.data),
        noise=jnp.where(op, nzs, fleet.noise),
        signal=jnp.where(op, sgs, fleet.signal),
    ), jnp.where(op, mlls, 0.0)


# ---------------------------------------------------------------------------
# Tenant lifecycle: join / leave (lane reset keeps inactive lanes taint-free)
# ---------------------------------------------------------------------------


def _reset_lane(fleet: FleetGPGData, slot: Array, *, lam, noise, signal,
                active: bool) -> FleetGPGData:
    d0 = fleet.data
    cap, dim = fleet.capacity, fleet.d
    dt = d0.X.dtype
    zrow = jnp.zeros((cap, dim), dt)
    znn = jnp.zeros((cap, cap), dt)
    zero = jnp.zeros((), dt)
    data = d0._replace(
        X=d0.X.at[slot].set(zrow), G=d0.G.at[slot].set(zrow),
        Xt=d0.Xt.at[slot].set(zrow), Z=d0.Z.at[slot].set(zrow),
        K1e=d0.K1e.at[slot].set(znn), K2e=d0.K2e.at[slot].set(znn),
        L=d0.L.at[slot].set(jnp.eye(cap, dtype=dt)),
        lam=d0.lam.at[slot].set(jnp.asarray(lam, dt)),
        count=d0.count.at[slot].set(0),
        n_refactor=d0.n_refactor.at[slot].set(0),
        n_solve=d0.n_solve.at[slot].set(0),
        cg_iters=d0.cg_iters.at[slot].set(0),
        resnorm=d0.resnorm.at[slot].set(zero),
    )
    return fleet._replace(
        data=data,
        noise=fleet.noise.at[slot].set(jnp.asarray(noise, dt)),
        signal=fleet.signal.at[slot].set(jnp.asarray(signal, dt)),
        active=fleet.active.at[slot].set(bool(active)),
    )


def fleet_join(fleet: FleetGPGData, slot: Array, *, lam=1.0, noise=0.0,
               signal=1.0) -> FleetGPGData:
    """Claim lane ``slot`` for a new tenant: a fresh empty state with the
    tenant's hypers, active.  ``slot`` may be traced (one compile serves
    every join)."""
    return _reset_lane(fleet, slot, lam=lam, noise=noise, signal=signal,
                       active=True)


def fleet_leave(fleet: FleetGPGData, slot: Array) -> FleetGPGData:
    """Release lane ``slot``: zero the lane AND deactivate it, so a freed
    slot can never taint fleet-level reductions or future joins."""
    return _reset_lane(fleet, slot, lam=1.0, noise=0.0, signal=1.0,
                       active=False)


# ---------------------------------------------------------------------------
# Host-facing wrapper: slot allocation + compile-watched launches
# ---------------------------------------------------------------------------


class GPFleet:
    """A fleet of independent streaming GP posteriors behind ONE program
    per op.

    >>> fl = GPFleet("rbf", d=8, window=4, batch=16)
    >>> fl.join("alice", lam=0.1, noise=1e-8)
    >>> fl.extend({"alice": (x, g)})          # one vmapped launch
    >>> out = fl.posterior({"alice": Xq})     # one vmapped launch
    >>> fl.refit(["alice"])                   # vmapped MLL ascent

    Every lifecycle op is a single compile-watched jitted launch over the
    whole fleet; per-tenant count/noise/signal/Lambda are traced arrays,
    so tenant churn (join, extend to capacity, evict, refit, leave) reuses
    one executable per op — asserted in tests/test_fleet.py.  Capacity and
    batch are static; the batch grows by doubling (each doubling is one
    new signature, so signatures stay O(log tenants)).
    """

    def __init__(
        self,
        kernel: str | KernelSpec = "rbf",
        d: int | None = None,
        *,
        capacity: int = 8,
        batch: int = 8,
        window: int | None = None,
        lam=1.0,
        noise: float = 0.0,
        signal: float = 1.0,
        jitter: float = 1e-10,
        deg_thresh: float = 1e-8,
        tol: float = 1e-10,
        maxiter: int | None = None,
        dtype=None,
    ):
        if d is None:
            raise TypeError("GPFleet needs the input dimension d")
        self.spec = get_kernel(kernel) if isinstance(kernel, str) else kernel
        self.window = int(window) if window else None
        cap = self.window if self.window else int(capacity)
        self.defaults = {"lam": lam, "noise": float(noise),
                         "signal": float(signal)}
        self.jitter = float(jitter)
        self.deg_thresh = float(deg_thresh)
        self.tol = float(tol)
        self.maxiter = maxiter
        self.fleet = fleet_init(self.spec, int(d), cap, int(batch),
                                lam=lam, noise=noise, signal=signal,
                                active=False, dtype=dtype)
        self._slots: dict = {}                  # tenant id -> lane index
        self._free = list(range(int(batch)))[::-1]
        # per-lane monotonic revision counters (same contract as GPGState:
        # factor_revision keys the serve layer's variance-solver LRU)
        self.revision = [0] * int(batch)
        self.factor_revision = [0] * int(batch)
        self._ops: dict = {}
        if _obs.enabled():
            for name in ("fleet.launches", "fleet.extend_calls",
                         "fleet.evict_calls", "fleet.refit_calls",
                         "fleet.query_calls", "fleet.joins", "fleet.leaves"):
                _obs.REGISTRY.inc(name, 0)

    # -- slot management ---------------------------------------------------

    @property
    def batch(self) -> int:
        return self.fleet.batch

    @property
    def capacity(self) -> int:
        return self.fleet.capacity

    @property
    def d(self) -> int:
        return self.fleet.d

    @property
    def tenants(self) -> list:
        return list(self._slots)

    def slot_of(self, tenant) -> int:
        return self._slots[tenant]

    def n(self, tenant) -> int:
        return int(self.fleet.data.count[self._slots[tenant]])

    def state_view(self, tenant) -> GPGData:
        """The tenant's lane as a plain single-tenant ``GPGData``."""
        return fleet_lane(self.fleet, self._slots[tenant])

    def hypers_of(self, tenant) -> dict:
        b = self._slots[tenant]
        return {"lam": float(self.fleet.data.lam[b]),
                "noise": float(self.fleet.noise[b]),
                "signal": float(self.fleet.signal[b])}

    def _grow(self) -> None:
        """Double the lane count by zero-padding every leaf (exact; a new
        compile signature per doubling)."""
        b0 = self.batch
        fl = self.fleet

        def pad(leaf):
            return jnp.concatenate(
                [leaf, jnp.zeros((b0,) + leaf.shape[1:], leaf.dtype)])

        data = jax.tree_util.tree_map(pad, fl.data)
        # padded lanes must be valid EMPTY states, not all-zero garbage
        eye = jnp.broadcast_to(jnp.eye(self.capacity, dtype=data.L.dtype),
                               (b0, self.capacity, self.capacity))
        data = data._replace(
            L=data.L.at[b0:].set(eye),
            lam=data.lam.at[b0:].set(1.0))
        self.fleet = FleetGPGData(
            data=data, noise=pad(fl.noise),
            signal=jnp.concatenate(
                [fl.signal, jnp.ones((b0,), fl.signal.dtype)]),
            active=jnp.concatenate(
                [fl.active, jnp.zeros((b0,), bool)]))
        self._free = list(range(b0, 2 * b0))[::-1] + self._free
        self.revision += [0] * b0
        self.factor_revision += [0] * b0

    def join(self, tenant, *, lam=None, noise=None, signal=None) -> int:
        """Admit a tenant (grows the fleet when full); returns its lane."""
        if tenant in self._slots:
            raise ValueError(f"tenant {tenant!r} already joined")
        if not self._free:
            self._grow()
        slot = self._free.pop()
        dd = self.defaults
        self.fleet = self._launch(
            "join", lambda fl, s, lm, nz, sg: fleet_join(
                fl, s, lam=lm, noise=nz, signal=sg),
            self.fleet, jnp.asarray(slot, jnp.int32),
            jnp.asarray(dd["lam"] if lam is None else lam),
            jnp.asarray(dd["noise"] if noise is None else noise),
            jnp.asarray(dd["signal"] if signal is None else signal))
        self._slots[tenant] = slot
        self._bump(slot)
        if _obs.enabled():
            _obs.REGISTRY.inc("fleet.joins")
            _obs.REGISTRY.set_gauge("fleet.active_tenants", len(self._slots))
        return slot

    def leave(self, tenant) -> None:
        """Evict a tenant and free its lane (zeroed: no residual taint)."""
        slot = self._slots.pop(tenant)
        self.fleet = self._launch(
            "leave", lambda fl, s: fleet_leave(fl, s),
            self.fleet, jnp.asarray(slot, jnp.int32))
        self._free.append(slot)
        self._bump(slot)
        if _obs.enabled():
            _obs.REGISTRY.inc("fleet.leaves")
            _obs.REGISTRY.set_gauge("fleet.active_tenants", len(self._slots))

    def quarantine(self, tenant) -> None:
        """Isolate a poisoned tenant: flip its active mask off and free
        the lane (a ``leave``, NOT a repack — the other lanes' bits and
        the compile signature are untouched)."""
        self.leave(tenant)
        if _obs.enabled():
            _obs.REGISTRY.inc("fleet.quarantines")
            _obs.REGISTRY.inc("resilience.quarantined")
        _obs.emit({"type": "quarantine", "tenant": str(tenant)})

    # -- compile-watched launches ------------------------------------------

    def _launch(self, name: str, make_fn, *args):
        """Run op ``name`` through its cached compile-watched jit (ONE
        executable per op x signature — the fleet compile-stability
        contract)."""
        step = self._ops.get(name)
        if step is None:
            step = self._ops[name] = _cw.wrap(make_fn, name=f"fleet_{name}")
        if _obs.enabled():
            _obs.REGISTRY.inc("fleet.launches")
        return step(*args)

    def _bump(self, slot: int, factors: bool = True) -> None:
        self.revision[slot] += 1
        if factors:
            self.factor_revision[slot] += 1

    def _mask_of(self, tenants) -> Array:
        import numpy as np

        m = np.zeros((self.batch,), bool)
        for t in tenants:
            m[self._slots[t]] = True
        return jnp.asarray(m)

    # -- batched lifecycle -------------------------------------------------

    def extend(self, obs: dict) -> "GPFleet":
        """Append one (x, g) observation per tenant: ``{tenant: (x, g)}``
        — ONE vmapped launch for the whole group (auto-evict at the
        window)."""
        import numpy as np

        from repro.resilience import guardrails as _guard

        if not obs:
            return self
        for t, (x, g) in obs.items():
            _guard.check_finite(x, g, what="observation", tenant=t)
        if not self.window:
            for t in obs:
                if self.n(t) >= self.capacity:
                    raise ValueError(
                        f"tenant {t!r} is at capacity={self.capacity} "
                        "(no window configured)")
        X = np.zeros((self.batch, self.d), dtype=np.asarray(
            self.fleet.data.X).dtype)
        G = np.zeros_like(X)
        for t, (x, g) in obs.items():
            b = self._slots[t]
            X[b], G[b] = np.asarray(x), np.asarray(g)
        with _obs.span("fleet.extend", tenants=len(obs)):
            self.fleet = self._launch(
                "extend", lambda fl, X_, G_, op: fleet_extend(
                    self.spec, fl, X_, G_, op, window=self.window,
                    jitter=self.jitter, deg_thresh=self.deg_thresh,
                    tol=self.tol, maxiter=self.maxiter),
                self.fleet, jnp.asarray(X), jnp.asarray(G),
                self._mask_of(obs))
            if _obs.enabled():
                _obs.REGISTRY.inc("fleet.extend_calls", len(obs))
        for t in obs:
            self._bump(self._slots[t])
        return self

    def evict(self, tenants) -> "GPFleet":
        """Drop the oldest observation of each listed tenant — one launch."""
        tenants = list(tenants)
        if not tenants:
            return self
        with _obs.span("fleet.evict", tenants=len(tenants)):
            self.fleet = self._launch(
                "evict", lambda fl, op: fleet_evict(
                    self.spec, fl, op, tol=self.tol, maxiter=self.maxiter),
                self.fleet, self._mask_of(tenants))
            if _obs.enabled():
                _obs.REGISTRY.inc("fleet.evict_calls", len(tenants))
        for t in tenants:
            self._bump(self._slots[t])
        return self

    def resolve(self, rhs: dict) -> "GPFleet":
        """Re-solve listed tenants against new RHS: ``{tenant: (n, D)}``.
        Factors untouched — per-tenant ``factor_revision`` keys stay
        valid."""
        import numpy as np

        if not rhs:
            return self
        R = np.zeros((self.batch, self.capacity, self.d), dtype=np.asarray(
            self.fleet.data.X).dtype)
        for t, r in rhs.items():
            r = np.atleast_2d(np.asarray(r))
            R[self._slots[t], : r.shape[0]] = r
        with _obs.span("fleet.resolve", tenants=len(rhs)):
            self.fleet = self._launch(
                "resolve", lambda fl, R_, op: fleet_resolve(
                    self.spec, fl, R_, op, tol=self.tol,
                    maxiter=self.maxiter),
                self.fleet, jnp.asarray(R), self._mask_of(rhs))
        for t in rhs:
            self._bump(self._slots[t], factors=False)
        return self

    def refit(self, tenants, *, steps: int = 16, lr: float = 0.1,
              mask=None) -> dict:
        """MLL-refit the listed tenants (vmapped fit + refactor — one
        launch); returns ``{tenant: fitted mll}``."""
        tenants = [t for t in tenants if self.n(t) >= 2]
        if not tenants:
            return {}
        with _obs.span("fleet.refit", tenants=len(tenants)):
            self.fleet, mlls = self._launch(
                f"refit{steps}", lambda fl, op: fleet_refit(
                    self.spec, fl, op, steps=steps, lr=lr, mask=mask,
                    jitter=self.jitter, tol=self.tol, maxiter=self.maxiter),
                self.fleet, self._mask_of(tenants))
            if _obs.enabled():
                _obs.REGISTRY.inc("fleet.refit_calls", len(tenants))
        for t in tenants:
            self._bump(self._slots[t])
        return {t: float(mlls[self._slots[t]]) for t in tenants}

    def posterior(self, queries: dict, *, q_pad: int | None = None) -> dict:
        """Batched posterior means: ``{tenant: (q, D)}`` -> ``{tenant:
        PosteriorBatch}`` — ONE vmapped launch, requests padded to a
        shared Q bucket (``q_pad`` or the next power of two)."""
        import numpy as np

        if not queries:
            return {}
        qs = {t: np.atleast_2d(np.asarray(x)) for t, x in queries.items()}
        qmax = max(x.shape[0] for x in qs.values())
        Q = int(q_pad) if q_pad else 1 << (qmax - 1).bit_length()
        if qmax > Q:
            raise ValueError(f"request of {qmax} queries exceeds "
                             f"q_pad={Q}")
        Xq = np.zeros((self.batch, Q, self.d), dtype=np.asarray(
            self.fleet.data.X).dtype)
        for t, x in qs.items():
            Xq[self._slots[t], : x.shape[0]] = x
        with _obs.span("fleet.query", tenants=len(qs), q=Q):
            out = self._launch(
                "posterior", lambda fl, Xq_: fleet_posterior(
                    self.spec, fl, Xq_),
                self.fleet, jnp.asarray(Xq))
            if _obs.enabled():
                _obs.REGISTRY.inc("fleet.query_calls", len(qs))
                _obs.REGISTRY.inc("fleet.query_points",
                                  sum(x.shape[0] for x in qs.values()))
        return {
            t: PosteriorBatch(value=out.value[self._slots[t], : x.shape[0]],
                              grad=out.grad[self._slots[t], : x.shape[0]])
            for t, x in qs.items()
        }

    def mll(self, tenants=None) -> dict:
        """Per-tenant exact MLL at current hypers (one vmapped launch)."""
        tenants = self.tenants if tenants is None else list(tenants)
        per = self._launch(
            "mll", lambda fl: fleet_mll(self.spec, fl), self.fleet)
        return {t: float(per[self._slots[t]]) for t in tenants
                if self.n(t) > 0}

    def __repr__(self):
        return (f"GPFleet(kernel={self.spec.name!r}, tenants="
                f"{len(self._slots)}/{self.batch}, cap={self.capacity}, "
                f"d={self.d}, window={self.window})")

"""Core: structured GP inference with derivative observations (the paper)."""
from . import backend
from .backend import (resolve_backend, resolve_precision, set_backend,
                      set_precision, stream_dtype, use_backend, use_precision)
from .gram import (FactorBundle, GramFactors, build_factor_bundle,
                   build_factors, dense_gram, dense_cross_gram, pairwise_r,
                   scaled_gram)
from .inference import (
    HessianOperator,
    infer_optimum,
    posterior_grad,
    posterior_hessian,
    posterior_value,
)
from .kernels import KernelSpec, get_kernel, kernel_names
from .mvm import (
    cross_grad_matvec,
    cross_value_matvec,
    gram_matvec,
    gram_matvec_multi,
    l_op,
    lt_op,
)
from .dist_state import (
    SGPGData,
    ShardedGPGState,
    psum_bytes,
    sgpg_direct_solve,
    sgpg_evict,
    sgpg_extend,
    sgpg_init,
    sgpg_posterior_mean,
    sgpg_rebuild,
    sgpg_refactor,
    sgpg_resolve,
)
from .fleet import (
    FleetGPGData,
    GPFleet,
    fleet_evict,
    fleet_extend,
    fleet_init,
    fleet_join,
    fleet_lane,
    fleet_leave,
    fleet_mll,
    fleet_posterior,
    fleet_refactor,
    fleet_refit,
    fleet_resolve,
    fleet_total_mll,
)
from .query import PosteriorBatch, make_query_fn, posterior_batch
from .solvers import CGResult, cg, gram_cg_solve, gram_cg_solve_multi
from .state import (
    GPGData,
    GPGState,
    gpg_evict,
    gpg_extend,
    gpg_init,
    gpg_refactor,
    gpg_resolve,
)
from .woodbury import dense_solve, poly2_quadratic_solve, woodbury_solve

__all__ = [
    "FactorBundle", "GramFactors", "backend", "build_factor_bundle",
    "build_factors", "dense_gram",
    "dense_cross_gram", "pairwise_r", "scaled_gram", "HessianOperator",
    "infer_optimum", "posterior_grad", "posterior_hessian", "posterior_value",
    "KernelSpec", "get_kernel", "kernel_names", "cross_grad_matvec",
    "cross_value_matvec", "gram_matvec", "gram_matvec_multi", "l_op", "lt_op",
    "CGResult", "cg", "gram_cg_solve", "gram_cg_solve_multi",
    "resolve_backend", "set_backend", "use_backend", "resolve_precision",
    "set_precision", "use_precision", "stream_dtype", "dense_solve",
    "poly2_quadratic_solve", "woodbury_solve",
    "GPGData", "GPGState", "gpg_evict", "gpg_extend", "gpg_init",
    "gpg_refactor", "gpg_resolve",
    "FleetGPGData", "GPFleet", "fleet_evict", "fleet_extend", "fleet_init",
    "fleet_join", "fleet_lane", "fleet_leave", "fleet_mll",
    "fleet_posterior", "fleet_refactor", "fleet_refit", "fleet_resolve",
    "fleet_total_mll",
    "PosteriorBatch", "make_query_fn", "posterior_batch",
    "SGPGData", "ShardedGPGState", "psum_bytes", "sgpg_direct_solve",
    "sgpg_evict", "sgpg_extend", "sgpg_init", "sgpg_posterior_mean",
    "sgpg_rebuild", "sgpg_refactor", "sgpg_resolve",
]

"""Structured gradient-Gram-matrix factors (paper Sec. 2.2).

Layout convention: data matrices are stored **(N, D)** — observations on the
first (sublane) axis, dimension on the last (lane) axis. This is the
TPU-friendly transpose of the paper's (D, N) notation; all formulas in this
package have been re-derived for this layout (see DESIGN.md sec. 3).

Lambda is restricted to scalar or diagonal (shape ``(D,)``) — the paper's own
experiments use scalar Lambda; dense SPD Lambda would reintroduce O(D^2) work
which is exactly what the method avoids at D ~ 1e9.

The full DN x DN Gram matrix is *never* materialized outside of tests: it is
fully described by ``GramFactors`` = (K1e, K2e, Xt, lam), i.e.
O(N^2 + ND) storage instead of O((ND)^2) (paper Sec. 2.3, General
Improvements).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp

from . import backend
from .kernels import KernelSpec

Array = jnp.ndarray


def _lam_mul(lam: Array | float, V: Array) -> Array:
    """Lambda @ v for scalar/diagonal Lambda, acting on the last axis."""
    return V * lam


def scaled_gram(A: Array, B: Array, lam: Array | float) -> Array:
    """(N_a, N_b) matrix  A Lambda B^T  for (N, D)-layout inputs.

    This is THE hot contraction of the whole method: every O(D) object only
    ever appears inside this product. Dispatches through
    ``core.backend`` — the ``repro.kernels.skinny_gram`` Pallas TPU kernel
    on the pallas backend, the jnp oracle form elsewhere.
    """
    return backend.scaled_gram(A, B, lam)


def pairwise_r(spec: KernelSpec, A: Array, B: Array, lam, c=None) -> Array:
    """r(x_a, x_b) for all pairs; A: (Na, D), B: (Nb, D) -> (Na, Nb).

    Stationary kernels go through ``backend.gram_norms`` so the gram and
    both row-norm strips come out of a single pass over A/B.
    """
    return backend.pairwise_r(spec, A, B, lam, c=c)


class GramFactors(NamedTuple):
    """Everything needed to act with the DN x DN gradient Gram matrix.

    K1e/K2e: (N, N) effective first/second kernel-derivative matrices.
    Xt:      (N, D) centered inputs  (X - c for dot kernels, X for stationary).
    lam:     scalar or (D,) diagonal of Lambda.
    noise:   sigma^2 added to the Gram diagonal (scalar; exact paths require
             scalar lam when noise > 0 so that it folds into K1e).
    """

    K1e: Array
    K2e: Array
    Xt: Array
    lam: Array | float
    noise: float = 0.0
    c: Optional[Array] = None  # dot-kernel center; queries are centered with it
    # Stationary stream-quantization shift (DESIGN.md sec. 12.2): when set,
    # the stored Xt rows are RELATIVE to this f32 vector — exact for
    # stationary kernels (translation invariance) and essential under bf16
    # storage: quantizing absolute coordinates of clustered data destroys
    # the |a|^2+|b|^2-2ab cancellation, while spread-scale coordinates keep
    # it at storage precision.  Only ``query._mean_chunk`` consumes it
    # (queries are shifted by the same vector before casting); every other
    # consumer must receive unshifted factors (shift=None).
    shift: Optional[Array] = None

    @property
    def n(self) -> int:
        return self.Xt.shape[0]

    @property
    def d(self) -> int:
        return self.Xt.shape[1]


def build_factors(
    spec: KernelSpec,
    X: Array,
    lam: Array | float = 1.0,
    c: Optional[Array] = None,
    noise: float = 0.0,
) -> GramFactors:
    """Compute the O(N^2 + ND) factor set for observations at rows of X."""
    r = pairwise_r(spec, X, X, lam, c=c)
    K1e = spec.k1e(r)
    K2e = spec.k2e(r)
    Xt = X if (spec.is_stationary or c is None) else X - c
    return GramFactors(K1e=K1e, K2e=K2e, Xt=Xt, lam=lam, noise=float(noise),
                       c=None if spec.is_stationary else c)


class FactorBundle(NamedTuple):
    """Single-sweep factor set for one exact solve (DESIGN.md sec. 12).

    factors: the usual ``GramFactors`` (K1e/K2e from the same sweep).
    S:       (N, N)  (Xt Lam) Xt^T — Woodbury's inner-system gram.
    C:       (N, N)  G Xt^T — the right-hand contraction; by associativity
             T0 = (K1i G) Xt^T = K1i @ C, so the exact solve never streams
             G through K1i nor materializes the (N, D) intermediate.
    """

    factors: GramFactors
    S: Array
    C: Array


def build_factor_bundle(
    spec: KernelSpec,
    X: Array,
    G: Array,
    lam: Array | float = 1.0,
    c: Optional[Array] = None,
    noise: float = 0.0,
) -> FactorBundle:
    """ONE pass over (X, G) -> every skinny factor of an exact solve.

    Where :func:`build_factors` + ``woodbury_solve`` used to make four
    separate O(N^2 D) passes (pairwise-r gram, S, K1i @ G, its @ Xt^T),
    this streams X and G once through ``backend.fused_factor_build`` and
    assembles r/K1e/K2e/S/C from the resulting (N, N) strips — the rest of
    the solve is D-free until the final output assembly.
    """
    Xt = X if (spec.is_stationary or c is None) else X - c
    P, na, nb, C, _ = backend.fused_factor_build(Xt, Xt, G, lam)
    if spec.is_stationary:
        r = jnp.maximum(na[:, None] + nb[None, :] - 2.0 * P, 0.0)
    else:
        r = P
    f = GramFactors(K1e=spec.k1e(r), K2e=spec.k2e(r), Xt=Xt, lam=lam,
                    noise=float(noise), c=None if spec.is_stationary else c)
    return FactorBundle(factors=f, S=P, C=C)


# --------------------------------------------------------------------------
# Dense reference assembly — tests/benchmarks only (O((ND)^2) memory!).
# --------------------------------------------------------------------------

def dense_gram(spec: KernelSpec, X: Array, lam=1.0, c=None, noise: float = 0.0) -> Array:
    """Explicit (N*D, N*D) gradient Gram matrix; index = a*D + i (Eq. 19)."""
    n, d = X.shape
    f = build_factors(spec, X, lam=lam, c=c)
    lam_vec = jnp.broadcast_to(jnp.asarray(lam, X.dtype), (d,))
    blocks = jnp.zeros((n, n, d, d), X.dtype)
    base = jnp.diag(lam_vec)
    if spec.is_stationary:
        delta = _lam_mul(X[:, None, :] - X[None, :, :], lam)  # (n, n, d)
        outer = delta[..., :, None] * delta[..., None, :]
    else:
        u = _lam_mul(f.Xt, lam)  # (n, d) = Lam x~
        # block(a,b) = K1e ab * Lam + K2e ab * outer(Lam x~_b, Lam x~_a)
        outer = u[None, :, :, None] * u[:, None, None, :]
    blocks = f.K1e[:, :, None, None] * base[None, None] + f.K2e[:, :, None, None] * outer
    full = blocks.transpose(0, 2, 1, 3).reshape(n * d, n * d)
    if noise:
        full = full + noise * jnp.eye(n * d, dtype=X.dtype)
    return full


def dense_cross_gram(spec: KernelSpec, Xq: Array, X: Array, lam=1.0, c=None) -> Array:
    """Cross covariance cov(grad f(Xq), grad f(X)): (Nq*D, N*D)."""
    nq, d = Xq.shape
    n, _ = X.shape
    r = pairwise_r(spec, Xq, X, lam, c=c)
    K1e, K2e = spec.k1e(r), spec.k2e(r)
    lam_vec = jnp.broadcast_to(jnp.asarray(lam, X.dtype), (d,))
    base = jnp.diag(lam_vec)
    if spec.is_stationary:
        delta = _lam_mul(Xq[:, None, :] - X[None, :, :], lam)
        outer = delta[..., :, None] * delta[..., None, :]
    else:
        uq = _lam_mul(Xq - (0.0 if c is None else c), lam)
        ub = _lam_mul(X - (0.0 if c is None else c), lam)
        outer = ub[None, :, :, None] * uq[:, None, None, :]
    blocks = K1e[:, :, None, None] * base[None, None] + K2e[:, :, None, None] * outer
    return blocks.transpose(0, 2, 1, 3).reshape(nq * d, n * d)

"""Persistent, incrementally-updatable posterior state (the serving core).

The paper's complexity story — O(N^2 D + N^3) in the low-data regime
N < D (Sec. 4) — only pays off in the workloads it motivates (optimizer
loops, GPG-HMC, online BO) if observations can be **appended one at a time
without refactoring from scratch** and many posterior queries can be
served against one cached solve.  This module is that state machine:

  ``GPGData``   — a fixed-capacity, jit-compatible pytree holding the
                  zero-padded ``GramFactors`` strips, the bordered Cholesky
                  ``L`` of the N x N fast-case matrix K1n = K1e + (s^2/lam) I,
                  and the solved representers ``Z``.
  ``gpg_extend``— appends one (x, grad) observation: O(ND) border of the
                  factor strips, an O(N^2) **bordered Cholesky update** of
                  L (DESIGN.md sec. 10), and a warm-started preconditioned
                  CG re-solve.  The O(N^6) dense inner refactorization of
                  ``woodbury_solve`` never runs: no intermediate with an
                  N^2-sized axis is ever created (asserted structurally in
                  tests/test_core_state.py).
  ``gpg_evict`` — drops the oldest observation for bounded-N sliding-window
                  serving: a rank-1 Cholesky update restores L in O(N^2)
                  (no downdate is ever needed — deleting the first row of a
                  Cholesky is a rank-1 *up*date of the trailing block).
  fallback      — when the bordered pivot degenerates (observations nearly
                  collinear in kernel space), the update falls back to a
                  full O(N^3) refactorization of L (``n_refactor`` counts
                  these; still never O(N^6)).

All pure functions are traceable: ``optim/gp_precond.py`` runs them inside
a jitted, sharded training step.  The host-facing :class:`GPGState` wraps
them with auto-evict / auto-grow policy and python-side bookkeeping; the
batched query layer on top lives in ``core/query.py``.

Masking convention: arrays are padded to ``capacity`` rows; rows >= count
are zero (L carries an identity tail) so every contraction below is exact
on the padded arrays — see DESIGN.md sec. 10.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.scipy.linalg import cho_solve, solve_triangular

from repro.obs import injit as _obs_tap
from repro.obs import trace as _obs
# host-side guardrails + typed errors (repro.resilience imports nothing
# from repro.core at module level, so this is cycle-free)
from repro.resilience import guardrails as _guard
from repro.resilience.errors import UnsupportedQueryError

from . import backend
from .gram import GramFactors
from .kernels import KernelSpec, get_kernel
from .mvm import gram_matvec
from .solvers import cg

Array = jnp.ndarray

_TINY = 1e-30


class GPGData(NamedTuple):
    """Jit-compatible posterior state (fixed capacity, zero-padded).

    X/G:    (cap, D) raw inputs / observed gradients (rows >= count are 0).
    Xt:     (cap, D) centered inputs (X - c for dot kernels, X stationary).
    K1e/K2e:(cap, cap) effective kernel-derivative strips, zero-padded.
    L:      (cap, cap) lower Cholesky of K1n = K1e + (noise/lam + jitter) I
            on the valid block, identity on the tail rows/cols.
    Z:      (cap, D) representers solving (grad K grad') vec(Z) = vec(rhs);
            rhs is G unless overridden (flipped GP-X inference).
    lam:    scalar or (D,) Lambda diagonal.
    count:  valid row count; n_refactor/n_solve: lifetime op counters;
    cg_iters/resnorm: stats of the most recent solve.
    """

    X: Array
    G: Array
    Xt: Array
    K1e: Array
    K2e: Array
    L: Array
    Z: Array
    lam: Array
    count: Array
    n_refactor: Array
    n_solve: Array
    cg_iters: Array
    resnorm: Array
    c: Optional[Array] = None

    @property
    def capacity(self) -> int:
        return self.X.shape[0]

    @property
    def d(self) -> int:
        return self.X.shape[1]


def _row_mask(data: GPGData) -> Array:
    return jnp.arange(data.capacity) < data.count


def _static_noise(noise) -> bool:
    """True when ``noise`` is a host python number (the single-state path).

    The fleet path (``core/fleet.py``) vmaps these functions with the
    per-tenant noise riding as a TRACED scalar so one compiled program
    serves heterogeneous tenants; every host-side branch on noise below
    is gated on this predicate (a tracer always takes the traced form).
    """
    return isinstance(noise, (int, float))


def _diag_shift(lam: Array, noise, jitter: float):
    """(noise/lam + jitter) — the scalar added to K1e's valid diagonal."""
    lam = jnp.asarray(lam)
    if _static_noise(noise):
        if noise and lam.ndim != 0:
            raise ValueError(
                "noise > 0 requires scalar Lambda (as in woodbury)")
        return (noise / lam if noise else 0.0) + jitter
    # traced per-tenant noise (fleet): scalar Lambda by construction
    return noise / lam + jitter


def gpg_init(
    spec: KernelSpec,
    d: int,
    capacity: int,
    *,
    lam=1.0,
    c: Optional[Array] = None,
    dtype=None,
) -> GPGData:
    """Empty state with room for ``capacity`` gradient observations."""
    if dtype is None:
        dtype = jnp.asarray(0.0).dtype
    cap = int(capacity)
    zmat = jnp.zeros((cap, d), dtype)
    znn = jnp.zeros((cap, cap), dtype)
    return GPGData(
        X=zmat, G=zmat, Xt=zmat, K1e=znn, K2e=znn,
        L=jnp.eye(cap, dtype=dtype), Z=zmat,
        lam=jnp.asarray(lam, dtype),
        count=jnp.zeros((), jnp.int32),
        n_refactor=jnp.zeros((), jnp.int32),
        n_solve=jnp.zeros((), jnp.int32),
        cg_iters=jnp.zeros((), jnp.int32),
        resnorm=jnp.zeros((), dtype),
        c=None if (spec.is_stationary or c is None) else jnp.asarray(c, dtype),
    )


# ---------------------------------------------------------------------------
# Internals: border rows, Cholesky surgery, the masked solve
# ---------------------------------------------------------------------------

def _border(spec: KernelSpec, data: GPGData, x: Array):
    """New factor border: (xt_new, k1_col, k2_col, r_self) — O(ND).

    ONE ``backend.fused_factor_build`` sweep of the stored (cap, D) strip
    emits the border gram column AND both norm strips (stationary r) AND
    the new point's self-dot (dot-kernel r_self) — the pre-fusion
    scaled_gram/gram_norms/row_dots launches are gone (DESIGN.md sec. 12).
    """
    mask = _row_mask(data)
    xt_new = x if (spec.is_stationary or data.c is None) else x - data.c
    P, na, nb, _, _ = backend.fused_factor_build(data.Xt, xt_new[None], None,
                                                 data.lam)
    if spec.is_stationary:
        r_col = jnp.maximum(na + nb[0] - 2.0 * P[:, 0], 0.0)
        r_self = jnp.zeros((), x.dtype)
    else:
        r_col = P[:, 0]
        r_self = nb[0]
    k1_col = jnp.where(mask, spec.k1e(r_col), 0.0)
    k2_col = jnp.where(mask, spec.k2e(r_col), 0.0)
    return xt_new, k1_col, k2_col, r_self


def _full_chol(data: GPGData, noise: float, jitter: float) -> Array:
    """O(N^3) Cholesky of the masked K1n (identity tail); the fallback."""
    mask = _row_mask(data)
    shift = _diag_shift(data.lam, noise, jitter)
    K1n = data.K1e + jnp.diag(jnp.where(mask, shift, 1.0))
    L = jnp.linalg.cholesky(K1n)
    # last-resort regularization if K1n lost positive-definiteness to
    # roundoff (near-duplicate observations): retry with a scaled jitter
    bad = ~jnp.all(jnp.isfinite(L))
    tr = jnp.trace(K1n) / jnp.maximum(data.count, 1)
    K1r = K1n + jnp.diag(jnp.where(mask, 1e-6 * tr, 0.0))
    return jnp.where(bad, jnp.linalg.cholesky(K1r), L)


def _chol_append(L: Array, k_col: Array, kappa, n: Array, deg_thresh: float):
    """Bordered Cholesky: O(N^2) append of row n.
    Returns (L', degraded, pivot2).

    k_col must be zero at rows >= n (and L identity there), so the
    triangular solve is exact on the padded arrays.  ``pivot2`` is the
    squared new pivot — the numerical-health signal the obs taps record
    (it collapsing toward ``deg_thresh * kappa`` is the early warning for
    the O(N^3) fallback).
    """
    l = solve_triangular(L, k_col, lower=True)
    pivot2 = kappa - jnp.vdot(l, l)
    degraded = pivot2 <= deg_thresh * jnp.maximum(kappa, _TINY)
    row = jnp.where(jnp.arange(L.shape[0]) < n, l, 0.0)
    row = row.at[n].set(jnp.sqrt(jnp.maximum(pivot2, _TINY)))
    return L.at[n].set(row), degraded, pivot2


def _chol_rank1_update(L: Array, v: Array) -> Array:
    """chol(L L^T + v v^T) in O(N^2); identity-tail/zero-v rows are no-ops."""
    cap = L.shape[0]
    idx = jnp.arange(cap)

    def body(k, carry):
        L, v = carry
        Lkk, vk = L[k, k], v[k]
        r = jnp.sqrt(Lkk * Lkk + vk * vk)
        cos = r / jnp.maximum(Lkk, _TINY)
        sin = vk / jnp.maximum(Lkk, _TINY)
        below = idx > k
        col = L[:, k]
        new_col = jnp.where(below, (col + sin * v) / cos, col).at[k].set(r)
        v = jnp.where(below, cos * v - sin * new_col, v)
        return L.at[:, k].set(new_col), v

    L, _ = jax.lax.fori_loop(0, cap, body, (L, v))
    return L


def _solve(spec: KernelSpec, data: GPGData, rhs: Array, z0: Array, *,
           noise: float, tol: float, maxiter: int) -> GPGData:
    """Warm-started preconditioned CG on the masked padded Gram system.

    The preconditioner is the free Kronecker factor B = K1n x Lam applied
    through the cached Cholesky — two O(N^2) triangular sweeps per
    iteration plus ONE fused Gram MVM (O(N^2 D)); nothing here ever has an
    N^2-sized axis.
    """
    mask = _row_mask(data)[:, None]
    f = GramFactors(K1e=data.K1e, K2e=data.K2e,
                    Xt=jnp.where(mask, data.Xt, 0.0), lam=data.lam,
                    noise=float(noise) if _static_noise(noise) else 0.0,
                    c=data.c)
    if _static_noise(noise):
        mv = lambda V: gram_matvec(f, V, stationary=spec.is_stationary)
    else:
        # traced noise rides OUTSIDE the factors (the backend kernels take
        # static noise); one extra fused axpy per MVM, identical math
        mv = lambda V: gram_matvec(
            f, V, stationary=spec.is_stationary) + noise * V
    M_inv = lambda V: cho_solve((data.L, True), V) / data.lam
    res = cg(mv, jnp.where(mask, rhs, 0.0), x0=jnp.where(mask, z0, 0.0),
             tol=tol, maxiter=maxiter, M_inv=M_inv)
    _obs_tap.tap("state.cg_iters", res.iters, kind="hist")
    _obs_tap.tap("state.cg_resnorm", res.resnorm)
    Z = jnp.where(mask & jnp.isfinite(res.x), res.x, 0.0)
    return data._replace(Z=Z, n_solve=data.n_solve + 1, cg_iters=res.iters,
                         resnorm=jnp.asarray(res.resnorm, data.resnorm.dtype))


def _default_maxiter(data: GPGData, maxiter: Optional[int], *,
                     cond: Optional[float] = None,
                     tol: float = 1e-10) -> int:
    """Iteration budget for the warm-started CG re-solve.

    An explicit ``maxiter`` always wins.  Otherwise the budget is the
    classic CG bound  iters ~ sqrt(kappa) * log(2/tol) / 2  evaluated at
    the health monitor's condition proxy (``obs.health.condition_proxy``
    — a free lower bound on cond(K1n), the operator the preconditioner
    has to equalize), clamped between a warm-start floor and the legacy
    ``10 * capacity + 50`` ceiling so a wild proxy can neither starve nor
    blow up the solve.  Without a condition sample (no monitor attached,
    or jitted consumers where ``maxiter`` must stay static) the ceiling
    is the budget — exactly the pre-regime behavior.
    """
    if maxiter is not None:
        return int(maxiter)
    cap = data.capacity
    ceiling = 10 * cap + 50
    if cond is None:
        return ceiling
    import math

    if not math.isfinite(cond) or cond <= 1.0:
        return ceiling
    need = 0.5 * math.sqrt(cond) * math.log(2.0 / max(float(tol), 1e-300))
    return int(min(ceiling, max(cap // 2 + 16, math.ceil(need))))


# ---------------------------------------------------------------------------
# The public pure-functional API (jit/shard_map-safe; spec & floats static)
# ---------------------------------------------------------------------------

def gpg_extend(
    spec: KernelSpec,
    data: GPGData,
    x: Array,
    g: Array,
    *,
    noise: float = 0.0,
    jitter: float = 1e-10,
    deg_thresh: float = 1e-8,
    tol: float = 1e-10,
    maxiter: Optional[int] = None,
    solve: bool = True,
    rhs: Optional[Array] = None,
) -> GPGData:
    """Append one (x, grad) observation with a bordered factor update.

    Requires count < capacity (the host wrapper evicts/grows first; the
    jitted consumers guarantee it by construction).  ``rhs`` overrides the
    right-hand side of the re-solve (flipped GP-X inference); default G.
    """
    x = jnp.asarray(x, data.X.dtype)
    g = jnp.asarray(g, data.X.dtype)
    n = data.count
    xt_new, k1_col, k2_col, r_self = _border(spec, data, x)
    k1_diag = spec.k1e(r_self)
    shift = _diag_shift(data.lam, noise, jitter)

    K1e = data.K1e.at[n, :].set(k1_col).at[:, n].set(k1_col)
    K1e = K1e.at[n, n].set(k1_diag)
    K2e = data.K2e.at[n, :].set(k2_col).at[:, n].set(k2_col)
    K2e = K2e.at[n, n].set(spec.k2e(r_self))
    data = data._replace(
        X=data.X.at[n].set(x), G=data.G.at[n].set(g),
        Xt=data.Xt.at[n].set(xt_new), K1e=K1e, K2e=K2e,
        count=n + 1,
    )

    L_new, degraded, pivot2 = _chol_append(data.L, k1_col, k1_diag + shift,
                                           n, deg_thresh)
    _obs_tap.tap("state.pivot2", pivot2)
    _obs_tap.tap("state.degenerate_fallback", degraded, kind="counter")
    data = jax.lax.cond(
        degraded,
        lambda d: d._replace(L=_full_chol(d, noise, jitter),
                             n_refactor=d.n_refactor + 1),
        lambda d: d._replace(L=L_new),
        data,
    )
    if solve:
        data = _solve(spec, data, data.G if rhs is None else rhs, data.Z,
                      noise=noise, tol=tol,
                      maxiter=_default_maxiter(data, maxiter))
    return data


def gpg_evict(
    spec: KernelSpec,
    data: GPGData,
    *,
    noise: float = 0.0,
    tol: float = 1e-10,
    maxiter: Optional[int] = None,
    solve: bool = True,
) -> GPGData:
    """Drop the OLDEST observation (sliding window) in O(N^2 + N D).

    Removing row 0 of K1n = L L^T leaves the trailing block
    L21 L21^T + L22 L22^T, whose Cholesky is a rank-1 *update* of L22 —
    no downdate (and hence no loss of positive definiteness) ever occurs.
    """
    n = data.count
    cap = data.capacity
    keep = jnp.arange(cap) < jnp.maximum(n - 1, 0)
    km = keep[:, None]
    kmm = keep[:, None] & keep[None, :]

    def up(A):  # shift rows up by one, zeroing the vacated tail
        return jnp.where(km, jnp.roll(A, -1, axis=0), 0.0)

    def upleft(A):
        return jnp.where(kmm, jnp.roll(jnp.roll(A, -1, 0), -1, 1), 0.0)

    Ls = upleft(data.L) + jnp.diag(jnp.where(keep, 0.0, 1.0))
    v = jnp.where(keep, jnp.roll(data.L[:, 0], -1), 0.0)
    data = data._replace(
        X=up(data.X), G=up(data.G), Xt=up(data.Xt), Z=up(data.Z),
        K1e=upleft(data.K1e), K2e=upleft(data.K2e),
        L=_chol_rank1_update(Ls, v),
        count=jnp.maximum(n - 1, 0),
    )
    if solve:
        data = _solve(spec, data, data.G, data.Z, noise=noise, tol=tol,
                      maxiter=_default_maxiter(data, maxiter))
    return data


def gpg_refactor(
    spec: KernelSpec,
    data: GPGData,
    lam: Optional[Array] = None,
    *,
    noise: float = 0.0,
    jitter: float = 1e-10,
    tol: float = 1e-10,
    maxiter: Optional[int] = None,
    solve: bool = True,
    rhs: Optional[Array] = None,
) -> GPGData:
    """Full O(N^2 D + N^3) rebuild of factors + Cholesky (+ solve).

    The explicit refactorization entry point: hyperparameter (Lambda)
    refresh, bulk conditioning (``GPGState.from_data``), and the
    degradation fallback all land here.  Still never O(N^6).
    """
    if lam is not None:
        data = data._replace(lam=jnp.asarray(lam, data.X.dtype))
    mask = _row_mask(data)
    mm = mask[:, None] & mask[None, :]
    if spec.is_stationary:
        Xt = jnp.where(mask[:, None], data.X, 0.0)
    else:
        Xt = data.X if data.c is None else data.X - data.c
        Xt = jnp.where(mask[:, None], Xt, 0.0)
    r = backend.pairwise_r(spec, Xt, Xt, data.lam)
    data = data._replace(
        Xt=Xt,
        K1e=jnp.where(mm, spec.k1e(r), 0.0),
        K2e=jnp.where(mm, spec.k2e(r), 0.0),
        n_refactor=data.n_refactor + 1,
    )
    data = data._replace(L=_full_chol(data, noise, jitter))
    if solve:
        data = _solve(spec, data, data.G if rhs is None else rhs, data.Z,
                      noise=noise, tol=tol,
                      maxiter=_default_maxiter(data, maxiter))
    return data


def gpg_resolve(
    spec: KernelSpec,
    data: GPGData,
    rhs: Array,
    *,
    noise: float = 0.0,
    tol: float = 1e-10,
    maxiter: Optional[int] = None,
) -> GPGData:
    """Re-solve against a NEW right-hand side, reusing factors + Cholesky.

    Zero refactorization — this is the GP-X path, where the observations
    (displacements X - x_t) change wholesale every step while the Gram
    factors (built on the gradient inputs) only grow by borders.
    """
    return _solve(spec, data, rhs, data.Z, noise=noise, tol=tol,
                  maxiter=_default_maxiter(data, maxiter))


# ---------------------------------------------------------------------------
# Host-facing wrapper: policy (auto-evict / auto-grow) + bookkeeping
# ---------------------------------------------------------------------------

class GPGState:
    """A conditioned gradient-GP posterior you can stream observations into.

    >>> st = GPGState("rbf", d=32, window=8, lam=1.0 / 32, noise=1e-8)
    >>> st.extend(x, g)          # O(N^2 D) bordered update, warm CG re-solve
    >>> pb = st.posterior(Xq)    # batched queries, zero re-solves

    ``window=m`` serves from a bounded sliding window (extend auto-evicts
    the oldest observation); ``window=None`` grows capacity geometrically
    (a pure zero-pad — padding is exact, so growth needs no refactor).
    """

    def __init__(
        self,
        kernel: str | KernelSpec = "rbf",
        d: int | None = None,
        *,
        capacity: int = 8,
        window: int | None = None,
        lam=1.0,
        noise: float = 0.0,
        signal: float = 1.0,
        c=None,
        jitter: float = 1e-10,
        deg_thresh: float = 1e-8,
        tol: float = 1e-10,
        maxiter: int | None = None,
        dtype=None,
        precision: str | None = None,
        policy=None,
    ):
        if d is None:
            raise TypeError("GPGState needs the input dimension d")
        self.spec = get_kernel(kernel) if isinstance(kernel, str) else kernel
        # Stream storage precision (DESIGN.md sec. 12): 'f32' | 'bf16'.
        # bf16 keeps the f32 masters in ``data`` for every solve/factor and
        # maintains bf16 COPIES of the (cap, D) stream operands for the
        # query path — cast once per state revision, not once per query.
        self.precision = (backend.resolve_precision() if precision is None
                          else precision)
        backend.stream_dtype(self.precision)  # validate early
        self._stream_cache = None
        self.noise = float(noise)
        self.signal = float(signal)
        self.jitter = float(jitter)
        self.deg_thresh = float(deg_thresh)
        self.tol = float(tol)
        self.maxiter = maxiter
        self.window = int(window) if window else None
        cap = self.window if self.window else int(capacity)
        self.data = gpg_init(self.spec, int(d), cap, lam=lam, c=c,
                             dtype=dtype)
        # Regime policy (repro.regime): which solve/evidence path the
        # state's size warrants, and what a full window does — 'evict'
        # (the PR-3 default), 'compress' (exact gradient reduction onto
        # the observed subspace), 'iterate' (grow past the window and let
        # the iterative regime absorb it), or 'auto'.  Deferred import:
        # repro.regime imports core submodules at load time.
        from repro.regime.policy import resolve_policy

        self.policy = resolve_policy(policy, window=self.window)
        self._last_regime: str | None = None
        self._reduction = None      # set when 'compress' has fired
        self._raw_X = None          # original-frame copies (compress only)
        self._raw_G = None
        # Monotonic revision counters (repro.obs): ``revision`` bumps on
        # EVERY data mutation, ``factor_revision`` only when the factor
        # strips / Cholesky / lam / count change — it is the exact cache
        # key the serve layer's variance-solver LRU needs (a resolve()
        # against a new RHS changes Z but not the factorization).
        self.revision = 0
        self.factor_revision = 0
        self._health = None
        if _obs.enabled():
            # pre-register so a run that never trips them still exports
            # the keys (tools/check_telemetry.py self-consistency gate)
            _obs.REGISTRY.inc("state.extend_calls", 0)
            _obs.REGISTRY.inc("state.refactor_fallback", 0)

    # -- construction ------------------------------------------------------

    @classmethod
    def from_data(cls, kernel, X: Array, G: Array, **kw) -> "GPGState":
        """Bulk-condition on (X, G) with ONE solve (then stream via extend)."""
        X = jnp.atleast_2d(X)
        n, d = X.shape
        kw.setdefault("capacity", max(n, 1))
        st = cls(kernel, d, **kw)
        if st.window and n > st.window:
            raise ValueError(f"{n} observations exceed window={st.window}")
        if n > st.data.capacity:
            raise ValueError(f"{n} observations exceed "
                             f"capacity={st.data.capacity}")
        cap = st.data.capacity
        pad = cap - n
        Xp = jnp.pad(jnp.asarray(X, st.data.X.dtype), ((0, pad), (0, 0)))
        Gp = jnp.pad(jnp.asarray(G, st.data.X.dtype), ((0, pad), (0, 0)))
        st.data = st.data._replace(X=Xp, G=Gp,
                                   count=jnp.asarray(n, jnp.int32))
        st.data = gpg_refactor(st.spec, st.data, noise=st._noise_eff,
                               jitter=st.jitter, tol=st.tol,
                               maxiter=st.maxiter)
        return st

    # -- streaming updates -------------------------------------------------

    def _bump(self, factors: bool = True) -> None:
        """Advance the revision counters after a data mutation."""
        self.revision += 1
        if factors:
            self.factor_revision += 1

    def attach_health(self, monitor=None) -> "GPGState":
        """Attach a ``repro.obs.HealthMonitor`` (ticked on every extend)."""
        from repro.obs import HealthMonitor

        self._health = HealthMonitor() if monitor is None else monitor
        return self

    # -- regime selection (repro.regime) ------------------------------------

    @property
    def regime(self) -> str:
        """'exact' | 'iterative' — the solve/evidence path the policy's
        cost model picks for the CURRENT (n, d)."""
        return self.policy.regime_for(self.n, self.d)

    def _publish_regime(self) -> None:
        """Export regime gauges; emit a switch event on a boundary cross."""
        cur = self.regime
        self.policy.publish(self.n, self.d, cur, prev=self._last_regime)
        self._last_regime = cur

    def _cond_hint(self) -> Optional[float]:
        """Condition proxy for the maxiter budget, from the attached
        health monitor's last sample, bucketed to powers of 4 so the
        derived static maxiter only takes a handful of distinct values."""
        if self._health is None:
            return None
        last = getattr(self._health, "last", None)
        if not last:
            return None
        cond = last.get("cond_k1n")
        if cond is None or cond <= 1.0:
            return None
        import math

        if not math.isfinite(cond):
            return cond
        return 4.0 ** math.ceil(math.log(cond, 4.0))

    def _maxiter_eff(self) -> Optional[int]:
        """The per-solve iteration budget (None = legacy ceiling) —
        condition-scaled when a health monitor has sampled the state."""
        if self.maxiter is not None:
            return int(self.maxiter)
        cond = self._cond_hint()
        if cond is None:
            return None
        return _default_maxiter(self.data, None, cond=cond, tol=self.tol)

    def _capacity_action(self) -> str:
        """Resolve what a full window does, feeding the policy the data's
        affine rank only when compression is actually on the table.
        Non-scalar Lambda never compresses: the exact-reduction theorem
        (regime/reduction.py) is an isotropic-metric statement."""
        rank = None
        if self.policy.capacity in ("compress", "auto") \
                and self._reduction is None \
                and jnp.asarray(self.data.lam).ndim == 0:
            from repro.regime.reduction import affine_rank

            base = None if self.spec.is_stationary else \
                (self.data.c if self.data.c is not None else 0.0 * self.X[0])
            rank = affine_rank(self.X, base=base)
        return self.policy.capacity_action(self.n, self.d, rank)

    def _rebuild_reduced(self, Xr: Array, Gr: Array) -> None:
        """Replace ``data`` with a freshly-conditioned state over the
        reduced observations (one O(N^2 k + N^3) refactor)."""
        n = Xr.shape[0]
        cap = max(self.data.capacity, n + 1)
        data = gpg_init(self.spec, Xr.shape[1], cap, lam=self.data.lam,
                        c=None, dtype=self.data.X.dtype)
        pad = cap - n
        data = data._replace(
            X=jnp.pad(jnp.asarray(Xr, data.X.dtype), ((0, pad), (0, 0))),
            G=jnp.pad(jnp.asarray(Gr, data.X.dtype), ((0, pad), (0, 0))),
            count=jnp.asarray(n, jnp.int32))
        self.data = gpg_refactor(self.spec, data, noise=self._noise_eff,
                                 jitter=self.jitter, tol=self.tol,
                                 maxiter=self.maxiter)
        self._stream_cache = None

    def _compress(self) -> None:
        """Exact gradient reduction of the stored window onto its affine
        span (``regime/reduction.py``): the D axis collapses to the data's
        rank k, and the window cap is re-expressed at the reduced per-row
        flops — the state gains O(D/k) rows of headroom instead of
        evicting.  In-span posterior queries are EXACTLY unchanged; the
        dropped orthogonal gradient mass is published as
        ``regime.compress_residual``."""
        from repro.regime.reduction import reduce_gradients

        X, G = self.X, self.G
        red = reduce_gradients(self.spec, X, G, c=self.data.c)
        d_full, k, n = self.d, red.rank, self.n
        if self.window:
            self.window = max(self.window + 1,
                              int(self.window * d_full / max(k, 1)))
        # raw copies in the original frame: what basis growth rebuilds from
        self._raw_X = [row for row in X]
        self._raw_G = [row for row in G]
        self._reduction = red
        self._rebuild_reduced(red.Xr, red.Gr)
        if _obs.enabled():
            _obs.REGISTRY.inc("regime.compressions")
            _obs.REGISTRY.set_gauge("regime.compress_rank", float(k))
            _obs.REGISTRY.set_gauge("regime.compress_residual",
                                    float(red.residual))
            _obs.emit({"type": "regime", "event": "compress", "n": n,
                       "d": d_full, "rank": k,
                       "residual": float(red.residual)})

    def _grow_basis(self, x: Array) -> None:
        """Append the out-of-span direction of ``x`` to the reduction
        basis and rebuild the reduced state from the raw copies — rare
        (once per genuinely new direction), after which the grown span
        covers the newcomer exactly."""
        from repro.regime.reduction import Reduction

        red = self._reduction
        xc = jnp.asarray(x, red.base.dtype) - red.base
        resid = xc - red.basis @ (red.basis.T @ xc)
        w = resid / jnp.maximum(jnp.linalg.norm(resid), _TINY)
        basis = jnp.concatenate([red.basis, w[:, None]], axis=1)
        Xraw = jnp.stack(self._raw_X)
        Graw = jnp.stack(self._raw_G)
        Xr = (Xraw - red.base) @ basis
        Gr = Graw @ basis
        residual = jnp.linalg.norm(Graw - Gr @ basis.T)
        self._reduction = Reduction(basis=basis, base=red.base, Xr=Xr,
                                    Gr=Gr, residual=residual)
        self._rebuild_reduced(Xr, Gr)
        if _obs.enabled():
            _obs.REGISTRY.inc("regime.basis_growths")
            _obs.REGISTRY.set_gauge("regime.compress_rank",
                                    float(basis.shape[1]))

    def _project_in(self, x: Array) -> Array:
        """Map an incoming D-dim input into the reduced frame, growing
        the basis first when x leaves the observed span."""
        from repro.regime.reduction import project_points

        x = jnp.asarray(x)
        y, out = project_points(self._reduction, x[None])
        rn = float(out[0])
        if _obs.enabled():
            _obs.REGISTRY.set_gauge("regime.out_of_span", rn)
        scale = max(float(jnp.linalg.norm(x - self._reduction.base)), 1.0)
        if rn > 1e-7 * scale:
            self._grow_basis(x)
            y, _ = project_points(self._reduction, x[None])
        return y[0]

    def extend(self, x: Array, g: Array, *, solve: bool = True) -> "GPGState":
        """Append one observation.  A full window applies the policy's
        capacity action ({evict, compress, iterate}); a full capacity
        without a window zero-pad-grows, as ever."""
        obs_on = _obs.enabled()
        # admission guardrail: a NaN/inf observation raises a typed error
        # HERE, before any factor strip sees it (host-side: the jitted
        # extend program is byte-identical with guardrails on or off)
        _guard.check_finite(x, g, what="observation")
        with _obs.span("state.extend"):
            # the in-jit tap counts degenerate pivots as they happen; the
            # host-side counter below is the device-synced ground truth
            # (the capacity actions above never border-refactor, so any
            # n_refactor delta across gpg_extend IS the degenerate-pivot
            # fallback)
            before = int(self.data.n_refactor) if obs_on else 0
            x = jnp.asarray(x)
            g = jnp.asarray(g)
            if self.window and self.n >= self.window:
                action = self._capacity_action()
                if action == "compress":
                    self._compress()
                elif action == "iterate":
                    # lift the window cap: growth is absorbed by the
                    # iterative regime from here on
                    self.window = None
                else:
                    self.data = gpg_evict(self.spec, self.data,
                                          noise=self._noise_eff, solve=False)
                    if self._raw_X is not None:
                        self._raw_X.pop(0)
                        self._raw_G.pop(0)
            if self.n >= self.data.capacity:
                self._grow()
            if self._reduction is not None:
                xr = self._project_in(x)      # may grow the basis
                gr = g @ self._reduction.basis
                self._raw_X.append(x)
                self._raw_G.append(g)
                x, g = xr, gr
            before_refac = int(self.data.n_refactor) if obs_on else before
            with _obs.span(f"state.solve.{self.regime}"):
                self.data = gpg_extend(
                    self.spec, self.data, x, g, noise=self._noise_eff,
                    jitter=self.jitter, deg_thresh=self.deg_thresh,
                    tol=self.tol, maxiter=self._maxiter_eff(), solve=solve)
            if obs_on:
                _obs.REGISTRY.inc("state.extend_calls")
                fallbacks = int(self.data.n_refactor) - before_refac
                if fallbacks:
                    _obs.REGISTRY.inc("state.refactor_fallback", fallbacks)
                _obs.REGISTRY.set_gauge("state.n", self.n)
                if self._health is not None:
                    self._health.tick(self)
            self._publish_regime()
        self._bump()
        # post-mutation watchdog: one scalar read of the fresh pivot +
        # solve residual; non-finite factors climb the jitter ladder
        # (repro.resilience.guardrails) — triggers on NON-finite only,
        # so healthy-trajectory bits are untouched
        if _guard.enabled():
            _guard.after_mutation(self)
        return self

    def evict(self, k: int = 1) -> "GPGState":
        """Drop the k oldest observations (one re-solve at the end)."""
        with _obs.span("state.evict", k=k):
            for i in range(k):
                self.data = gpg_evict(self.spec, self.data,
                                      noise=self._noise_eff, tol=self.tol,
                                      maxiter=self.maxiter,
                                      solve=(i == k - 1))
                if self._raw_X is not None and self._raw_X:
                    self._raw_X.pop(0)
                    self._raw_G.pop(0)
            if _obs.enabled():
                _obs.REGISTRY.inc("state.evict_calls")
                _obs.REGISTRY.set_gauge("state.n", self.n)
        self._bump()
        return self

    def refactor(self, lam=None) -> "GPGState":
        """Explicit full refactorization (e.g. after a Lambda refresh)."""
        with _obs.span("state.refactor"):
            self.data = gpg_refactor(self.spec, self.data, lam,
                                     noise=self._noise_eff,
                                     jitter=self.jitter, tol=self.tol,
                                     maxiter=self.maxiter)
            if _obs.enabled():
                _obs.REGISTRY.inc("state.refactor_calls")
        self._bump()
        return self

    def resolve(self, rhs: Array) -> Array:
        """Solve for a new RHS with cached factors; returns trimmed Z."""
        with _obs.span("state.resolve"):
            full = jnp.zeros_like(self.data.G).at[: rhs.shape[0]].set(
                jnp.asarray(rhs, self.data.G.dtype))
            self.data = gpg_resolve(self.spec, self.data, full,
                                    noise=self._noise_eff, tol=self.tol,
                                    maxiter=self.maxiter)
            if _obs.enabled():
                _obs.REGISTRY.inc("state.resolve_calls")
        # factors/Cholesky untouched: the variance-solver LRU stays valid
        self._bump(factors=False)
        return self.Z

    def _grow(self):
        """Double capacity by zero-padding — exact, no refactorization."""
        d0 = self.data
        cap = d0.capacity
        pr = ((0, cap), (0, 0))
        pnn = ((0, cap), (0, cap))
        L = jnp.pad(d0.L, pnn)
        L = L.at[jnp.arange(cap, 2 * cap), jnp.arange(cap, 2 * cap)].set(1.0)
        self.data = d0._replace(
            X=jnp.pad(d0.X, pr), G=jnp.pad(d0.G, pr), Xt=jnp.pad(d0.Xt, pr),
            Z=jnp.pad(d0.Z, pr), K1e=jnp.pad(d0.K1e, pnn),
            K2e=jnp.pad(d0.K2e, pnn), L=L)

    # -- model selection (repro.hyper) -------------------------------------

    @property
    def _noise_eff(self) -> float:
        """sigma^2 / s^2 — the noise the UNSCALED Gram solves see.

        Posterior means only depend on noise through this ratio
        (s^2 k_q (s^2 K + sigma^2 I)^{-1} = k_q (K + sigma^2/s^2 I)^{-1}),
        so the representer state is signal-invariant; the signal variance
        re-enters multiplicatively in the posterior variance paths.
        """
        return self.noise / self.signal

    @property
    def hypers(self):
        """Current hyperparameters as a ``repro.hyper.HyperParams``."""
        from repro.hyper import HyperParams

        lam = jnp.asarray(self.data.lam)
        if lam.ndim != 0:
            raise ValueError("HyperParams requires scalar (isotropic) Lambda")
        # floor a noise-free state at a float32-representable tiny so the
        # log-reparameterization stays finite even without x64
        return HyperParams.create(
            lengthscale2=1.0 / lam, signal=self.signal,
            noise=max(self.noise, 1e-30))

    def _evidence_method(self, method: str) -> str:
        """Normalize an evidence ``method`` knob: 'auto' follows the
        regime policy (SLQ past the crossover — the exact determinant-
        lemma inner matrix is (N^2, N^2))."""
        if method == "auto":
            return "slq" if self.regime == "iterative" else "exact"
        if method not in ("exact", "slq"):
            raise ValueError(
                f"method must be 'auto', 'exact' or 'slq': {method!r}")
        return method

    def mll(self, *, method: str = "auto", **slq_kw):
        """Log marginal likelihood of the CURRENT window at the current
        hypers.  ``method='exact'`` is the structured determinant-lemma
        path (never the (ND, ND) Gram); ``'slq'`` the stochastic Lanczos
        quadrature estimator (``regime/slq.py``) whose cost stays
        O(P m N^2 D) past the crossover; ``'auto'`` follows the regime.
        ``slq_kw`` (key/probes/lanczos_iters/cg_tol/cg_maxiter) pass
        through to :func:`repro.regime.slq.slq_mll`."""
        if self.n < 1:
            raise ValueError("mll() needs at least one observation")
        if self._evidence_method(method) == "slq":
            from repro.regime.slq import slq_mll

            return slq_mll(self.spec, self.X, self.G, self.hypers,
                           c=self.data.c, **slq_kw)
        from repro.hyper import mll as _mll

        return _mll(self.spec, self.X, self.G, self.hypers, c=self.data.c)

    def refit(self, *, mask=None, steps: int = 150, lr: float = 0.08,
              method: str = "auto", **fit_kw):
        """Refit the hypers by MLL ascent on the current window, then do the
        one legitimate full refactorization with the fitted lengthscale.

        ``method`` picks the evidence estimator the ascent runs on (see
        :meth:`mll`); 'auto' uses SLQ + Hutchinson hyper-gradients past
        the regime crossover, where the exact evidence is unaffordable.
        SLQ knobs (key/probes/lanczos_iters/cg_tol/cg_maxiter) ride in
        ``fit_kw``.  Updates ``noise``/``signal``/``lam`` in place and
        re-solves; returns the ``repro.hyper.FitResult`` (``.improvement``
        = MLL gain over the current hypers, which seed the fit).
        """
        if self.n < 2:
            raise ValueError("refit() needs at least two observations")
        method = self._evidence_method(method)
        with _obs.span("state.refit", method=method):
            if method == "slq":
                from repro.hyper import fit_fn as _fit_fn
                from repro.regime.slq import make_slq_mll_fn

                slq_kw = {k: fit_kw.pop(k)
                          for k in ("key", "probes", "lanczos_iters",
                                    "cg_tol", "cg_maxiter")
                          if k in fit_kw}
                fn = make_slq_mll_fn(self.spec, self.X, self.G,
                                     c=self.data.c, **slq_kw)
                res = _fit_fn(fn, self.hypers, steps=steps, lr=lr,
                              mask=mask, **fit_kw)
            else:
                from repro.hyper import fit as _fit

                res = _fit(self.spec, self.X, self.G, init=self.hypers,
                           c=self.data.c, mask=mask, steps=steps, lr=lr,
                           **fit_kw)
            self.noise = float(res.hypers.noise)
            self.signal = float(res.hypers.signal)
            self.refactor(lam=res.hypers.lam)
        return res

    # -- views -------------------------------------------------------------

    @property
    def n(self) -> int:
        return int(self.data.count)

    @property
    def d(self) -> int:
        return self.data.d

    @property
    def X(self) -> Array:
        return self.data.X[: self.n]

    @property
    def G(self) -> Array:
        return self.data.G[: self.n]

    @property
    def Z(self) -> Array:
        return self.data.Z[: self.n]

    @property
    def factors(self) -> GramFactors:
        """GramFactors trimmed to the valid rows (for core/ entry points)."""
        k = self.n
        return GramFactors(K1e=self.data.K1e[:k, :k],
                           K2e=self.data.K2e[:k, :k],
                           Xt=self.data.Xt[:k], lam=self.data.lam,
                           noise=self._noise_eff, c=self.data.c)

    @property
    def padded_factors(self) -> GramFactors:
        """Fixed-capacity GramFactors views (shape-stable across extend()).

        The zero rows are exact for the cross-covariance query paths and
        Hessian matvecs — every padded kernel coefficient multiplies a
        zero Z/Xt column — so a compiled query step keyed on these shapes
        survives count changes without recompiling (``train/serve.py``).
        NOT safe for ``HessianOperator.solve`` (its inner W inverse sees
        the padding); use ``factors`` for that.
        """
        d = self.data
        return GramFactors(K1e=d.K1e, K2e=d.K2e, Xt=d.Xt, lam=d.lam,
                           noise=self._noise_eff, c=d.c)

    def set_precision(self, precision: str) -> "GPGState":
        """Switch the stream storage precision ('f32' | 'bf16').

        Owns the cache invalidation that goes with it.  NOTE: precision is
        a property of the STATE, shared by every serve bundle and
        ``posterior()`` caller on it — switching here changes what all of
        them stream (the f32 masters and every solve are unaffected).
        """
        backend.stream_dtype(precision)  # validate
        if precision != self.precision:
            self.precision = precision
            self._stream_cache = None
        return self

    @property
    def stream_factors(self):
        """(padded factors, Z) views in the STREAM storage precision.

        With ``precision='bf16'`` the DATA stream the query path reads —
        Xt (and the query batches, cast at request time) — is a bf16 copy
        cached per state revision (every mutation replaces the ``GPGData``
        pytree, so identity is an exact revision key); the (cap, cap)
        factors, the representers Z (a solve output) and the f32 masters
        are untouched.  All downstream contractions accumulate in f32 and
        return f32 (``core.backend`` precision rules).

        Stationary coordinates are stored RELATIVE to the first
        observation (``GramFactors.shift``) before casting — exactly
        invariant, and what keeps clustered-data r/m cancellations at
        storage precision instead of |x|-amplified (DESIGN.md sec. 12.2).
        The shifted view serves the MEAN path only; probe/std queries run
        on the f32 masters.
        """
        if self.precision != "bf16":
            return self.padded_factors, self.data.Z
        c = self._stream_cache
        if c is None or c[0] is not self.data:
            d = self.data
            if self.spec.is_stationary:
                shift = d.Xt[0]
                mask = (jnp.arange(d.capacity) < d.count)[:, None]
                # padded rows stay exactly zero (the serving contract)
                xt = jnp.where(mask, d.Xt - shift, 0.0)
            else:
                shift = None
                xt = d.Xt
            f = self.padded_factors._replace(Xt=xt.astype(jnp.bfloat16),
                                             shift=shift)
            # Z stays f32: it is a SOLVE output (precision rule 3), and
            # representers cancel by orders of magnitude in the mean —
            # quantizing them is |Z|/|mean|-amplified.  Only the data
            # stream Xt (and queries) carry bf16 storage.
            self._stream_cache = (d, f, d.Z)
        return self._stream_cache[1], self._stream_cache[2]

    @property
    def stats(self) -> dict:
        return {
            "n": self.n,
            "n_refactor": int(self.data.n_refactor),
            "n_solve": int(self.data.n_solve),
            "cg_iters": int(self.data.cg_iters),
            "resnorm": float(self.data.resnorm),
        }

    def posterior(self, Xq: Array, *, probe: Array | None = None,
                  microbatch: int | None = None, return_std: bool = False,
                  return_grad_std: bool = False):
        """Batched posterior queries against the cached solve (zero re-solves).

        ``return_std``/``return_grad_std`` add posterior stds via ONE
        structured factorization of the noisy Gram (``repro.hyper.
        variance``).  See :func:`repro.core.query.posterior_batch`.

        On a compressed state (the 'compress' capacity action) queries are
        projected into the reduced frame and gradient outputs lifted back
        to R^D — exact for in-span queries (regime/reduction.py theorem);
        the out-of-span query mass is published as a gauge.
        """
        if self._reduction is not None:
            return self._posterior_reduced(
                Xq, probe=probe, microbatch=microbatch,
                return_std=return_std, return_grad_std=return_grad_std)
        return self._posterior_raw(Xq, probe=probe, microbatch=microbatch,
                                   return_std=return_std,
                                   return_grad_std=return_grad_std)

    def _posterior_reduced(self, Xq, *, probe, microbatch, return_std,
                           return_grad_std):
        from repro.regime.reduction import lift_gradients, project_points

        if return_grad_std:
            # typed (and a NotImplementedError subclass for legacy
            # callers): serve loops catch this and degrade to mean-only
            # instead of killing the request loop
            raise UnsupportedQueryError(
                "grad_std on a compressed state: per-coordinate gradient "
                "stds do not rotate through the reduction basis without "
                "the full gradient covariance")
        red = self._reduction
        Yq, out = project_points(red, jnp.atleast_2d(Xq))
        if _obs.enabled() and out.size:
            _obs.REGISTRY.set_gauge("regime.query_out_of_span",
                                    float(jnp.max(out)))
        probe_r = None if probe is None else jnp.asarray(probe) @ red.basis
        pb = self._posterior_raw(Yq, probe=probe_r, microbatch=microbatch,
                                 return_std=return_std,
                                 return_grad_std=False)
        return pb._replace(
            grad=lift_gradients(red, pb.grad),
            hess_v=(None if pb.hess_v is None
                    else lift_gradients(red, pb.hess_v)))

    def _posterior_raw(self, Xq: Array, *, probe, microbatch, return_std,
                       return_grad_std):
        from .query import posterior_batch

        solver = None
        if return_std or return_grad_std:
            from repro.hyper.variance import make_solver

            # the variance FACTORIZATION always runs on the f32 masters
            # (precision rule 3); only the streams may be bf16
            solver = make_solver(self.spec, self.factors, noise=self.noise,
                                 signal=self.signal)
        if probe is not None or solver is not None:
            # probe/std paths need the unshifted f32 masters; the mean
            # path still quantizes in-chunk under precision='bf16'
            f, Zq = self.factors, self.Z
        else:
            fp, Zp = self.stream_factors
            k = self.n
            f = fp._replace(K1e=fp.K1e[:k, :k], K2e=fp.K2e[:k, :k],
                            Xt=fp.Xt[:k])
            Zq = Zp[:k]
        return posterior_batch(self.spec, jnp.atleast_2d(Xq), f,
                               Zq, probe=probe, microbatch=microbatch,
                               return_std=return_std,
                               return_grad_std=return_grad_std,
                               solver=solver, precision=self.precision)

    def __repr__(self):
        s = self.stats
        return (f"GPGState(kernel={self.spec.name!r}, n={s['n']}, "
                f"d={self.d}, window={self.window}, "
                f"solves={s['n_solve']}, refactors={s['n_refactor']})")

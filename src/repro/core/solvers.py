"""Iterative solvers on the matrix-free Gram MVM (paper Sec. 2.3, Eq. 9).

For N > D (or when O(N^6) is too much) the Gram system is solved with
(preconditioned) conjugate gradients using only Alg.-2 products:
O(N^2 D) per iteration, O(ND + N^2) memory.

Preconditioner: the Kronecker term B = K1e x Lam is an excellent and *free*
preconditioner — B^{-1} vec(V) = (K1e^{-1} @ V) / lam costs O(N^2 D) with no
extra storage. The paper notes preconditioning "drastically reduces the
required number of iterations" (citing Eriksson et al. 2018); this is our
concrete instantiation, evaluated in benchmarks/bench_iterative.py.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from . import backend
from .gram import GramFactors
from .mvm import gram_matvec, gram_matvec_multi

Array = jnp.ndarray


class CGResult(NamedTuple):
    x: Array
    iters: Array
    resnorm: Array


def cg(
    matvec: Callable[[Array], Array],
    b: Array,
    x0: Array | None = None,
    *,
    tol: float = 1e-6,
    maxiter: int = 1000,
    M_inv: Callable[[Array], Array] | None = None,
) -> CGResult:
    """Preconditioned CG on an arbitrary (flattened-pytree-free) array space.

    Shapes are whatever ``matvec`` accepts; inner products are full-array.
    Runs a lax.while_loop => jittable, usable under shard_map (inner products
    of sharded arrays become psums automatically under jit).
    """
    if x0 is None:
        x0 = jnp.zeros_like(b)
    if M_inv is None:
        M_inv = lambda v: v

    def dot(a, b_):
        return jnp.vdot(a, b_)

    bnorm = jnp.sqrt(dot(b, b)).real
    atol2 = (tol * jnp.maximum(bnorm, 1e-30)) ** 2

    r0 = b - matvec(x0)
    z0 = M_inv(r0)
    state = (x0, r0, z0, z0, dot(r0, z0), jnp.array(0, jnp.int32))

    def cond(s):
        x, r, z, p, rz, it = s
        return (dot(r, r).real > atol2) & (it < maxiter)

    def body(s):
        x, r, z, p, rz, it = s
        Ap = matvec(p)
        alpha = rz / dot(p, Ap)
        x = x + alpha * p
        r = r - alpha * Ap
        z = M_inv(r)
        rz_new = dot(r, z)
        beta = rz_new / rz
        p = z + beta * p
        return (x, r, z, p, rz_new, it + 1)

    x, r, *_, it = jax.lax.while_loop(cond, body, state)
    return CGResult(x=x, iters=it, resnorm=jnp.sqrt(dot(r, r).real))


def gram_cg_solve(
    spec,
    f: GramFactors,
    G: Array,
    *,
    tol: float = 1e-6,
    maxiter: int | None = None,
    precondition: bool = True,
    jitter: float = 1e-10,
) -> CGResult:
    """Solve (grad K grad') vec(Z) = vec(G) iteratively (paper Sec. 5.2 mode).

    Per iteration: ONE backend Gram MVM (a single fused pallas_call on the
    pallas backend) plus, when preconditioning, one ``backend.kron_precond``
    launch — no raw jnp O(ND) work in the loop.

    G may also be a stacked (R, N, D) right-hand-side batch: the operator is
    block-diagonal over the RHS axis, so CG on the stacked array (inner
    products over the full stack) is plain CG on an SPD operator, and each
    iteration is ONE multi-RHS fused MVM that streams Xt once for all R
    systems (Hessian operator columns, HMC predictive gradients).
    Convergence is governed by the joint residual norm.
    """
    n, d = G.shape[-2:]
    maxiter = maxiter if maxiter is not None else n * d

    if G.ndim == 3:
        mv = lambda V: gram_matvec_multi(f, V, stationary=spec.is_stationary)
    else:
        mv = lambda V: gram_matvec(f, V, stationary=spec.is_stationary)

    M_inv = _kron_precond_fn(f, n, G.dtype, jitter) if precondition else None
    return cg(mv, G, tol=tol, maxiter=maxiter, M_inv=M_inv)


def _kron_precond_fn(f: GramFactors, n: int, dtype, jitter: float):
    """B^{-1} for the free Kronecker preconditioner B = K1e x Lam."""
    K1 = f.K1e + jitter * jnp.eye(n, dtype=dtype)
    if f.noise:
        K1 = K1 + (f.noise / jnp.asarray(f.lam)) * jnp.eye(n, dtype=dtype)
    K1i = jnp.linalg.inv(K1)
    return lambda V: backend.kron_precond(K1i, V, f.lam)


def gram_cg_solve_multi(spec, f: GramFactors, G: Array, **kw) -> CGResult:
    """Stacked-RHS CG: G (R, N, D). Alias for ``gram_cg_solve`` — the solve
    policy lives in one place; this name exists for call-site clarity."""
    assert G.ndim == 3, G.shape
    return gram_cg_solve(spec, f, G, **kw)

"""Posterior inference from gradient observations (paper Sec. 4, App. D/E).

Given Z solving (grad K grad') vec(Z) = vec(G - prior_grad):

  * posterior mean gradient at x_q      — cross_grad_matvec (Eq. 26)
  * posterior mean function at x_q      — cross_value_matvec (up to prior const)
  * posterior mean Hessian at x_q       — Eq. 12 closed form, diag + rank-2N
  * posterior optimum ("GP-X", Eq. 13)  — flipped inference x(g = 0)

The Hessian closed forms below were re-derived from scratch for this repo's
(N, D) layout and are validated against jax.hessian of the posterior mean
function in tests/test_core_inference.py (which pins down every sign the
paper is loose about).

  dot:        Hbar = Lam [ Xt^T M Xt + Z^T Mh Xt + Xt^T Mh Z ] Lam
              M  = diag(k3e(r_qb) * w_b),  w_b = x~_q^T Lam Z_b
              Mh = diag(k2e(r_qb))                     (no trace term)
  stationary: same structure with Xt -> (x_q - X), w -> m_b = (x_q-x_b)^T Lam Z_b,
              coefficients (-8 k''' m_b), (-4 k''), plus Lam * sum_b(-4 k'' m_b).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from .gram import GramFactors, scaled_gram, pairwise_r
from .kernels import KernelSpec
from .mvm import cross_grad_matvec, cross_value_matvec

Array = jnp.ndarray


def posterior_grad(spec: KernelSpec, xq: Array, f: GramFactors, Z: Array) -> Array:
    """Posterior mean of grad f at query points xq: (Nq, D)."""
    return cross_grad_matvec(spec, jnp.atleast_2d(xq), f, Z)


def posterior_value(spec: KernelSpec, xq: Array, f: GramFactors, Z: Array) -> Array:
    """Posterior mean of f at xq, up to the (unidentified) prior constant."""
    return cross_value_matvec(spec, jnp.atleast_2d(xq), f, Z)


class HessianOperator(NamedTuple):
    """Posterior mean Hessian  H = lam*(c0) I + F diag(w) F^T-style low rank.

    Materialized form:  H = diag(lam)*trace_coef + P W P^T  where
    P = [Lam Xt^T, Lam Z^T]  (D, 2N)  and  W = [[M, Mh], [Mh, 0]]  (2N, 2N).
    Stored factored so it can be applied or inverted in O(ND + N^3)
    (Woodbury again — paper Sec. 4.1.1 "cost similar to quasi-Newton").
    """

    P: Array          # (D, 2N)
    W: Array          # (2N, 2N)
    diag: Array       # (D,)  or scalar broadcast; the Lam*trace term

    @property
    def d(self) -> int:
        return self.P.shape[0]

    def matvec(self, v: Array) -> Array:
        return self.diag * v + self.P @ (self.W @ (self.P.T @ v))

    def dense(self) -> Array:
        return jnp.diag(jnp.broadcast_to(self.diag, (self.d,))) + self.P @ self.W @ self.P.T

    def solve(self, rhs: Array, jitter: float = 1e-8, diag_floor: float = 1e-8) -> Array:
        """(H)^{-1} rhs via Woodbury on the diag + low-rank structure."""
        d0 = jnp.broadcast_to(self.diag, (self.d,))
        # keep the base invertible; sign-indefinite W handled by dense inner solve
        d0 = jnp.where(jnp.abs(d0) < diag_floor, diag_floor, d0)
        Pd = self.P / d0[:, None]                      # D x 2N
        k = self.W.shape[0]
        inner = jnp.linalg.inv(self.W + jitter * jnp.eye(k, dtype=rhs.dtype)) + self.P.T @ Pd
        y = jnp.linalg.solve(inner + jitter * jnp.eye(k, dtype=rhs.dtype), Pd.T @ rhs)
        return rhs / d0 - Pd @ y


def posterior_hessian(spec: KernelSpec, xq: Array, f: GramFactors, Z: Array) -> HessianOperator:
    """Posterior mean Hessian at a single query point xq: (D,) (paper Eq. 12)."""
    xq = jnp.asarray(xq)
    lam = f.lam
    n, d = f.Xt.shape
    lam_vec = jnp.broadcast_to(jnp.asarray(lam, xq.dtype), (d,))

    if spec.is_stationary:
        Xt = xq[None, :] - f.Xt                       # (N, D), x_q - x_b
        r = jnp.maximum(jnp.sum((Xt * lam) * Xt, axis=-1), 0.0)
        m = jnp.sum((Xt * lam) * Z, axis=-1)          # (N,)
        k2, k3 = spec.k2(r), spec.k3(r)
        M = jnp.diag(-8.0 * k3 * m)
        Mh = jnp.diag(-4.0 * k2)
        diag = lam_vec * jnp.sum(-4.0 * k2 * m)
    else:
        xqt = xq if f.c is None else xq - f.c
        Xt = f.Xt                                     # x~_b (already centered)
        r = jnp.sum((Xt * lam) * xqt[None, :], axis=-1)       # r_qb
        w = jnp.sum(xqt[None, :] * lam * Z, axis=-1)          # x~_q^T Lam Z_b
        k2, k3 = spec.k2(r), spec.k3(r)
        M = jnp.diag(k3 * w)
        Mh = jnp.diag(k2)
        diag = jnp.zeros((d,), xq.dtype)

    P = jnp.concatenate([(Xt * lam).T, (Z * lam).T], axis=1)  # (D, 2N)
    W = jnp.block([[M, Mh], [Mh, jnp.zeros((n, n), M.dtype)]])
    return HessianOperator(P=P, W=W, diag=diag)


def infer_optimum(
    spec: KernelSpec,
    f_g: GramFactors,
    Z: Array,
    x_t: Array,
    g_query: Array | None = None,
) -> Array:
    """GP-X: flipped inference of the input where the gradient is g_query=0.

    Paper Sec. 4.1.2 / Eq. 13: condition a gradient-GP whose *inputs* are the
    observed gradients G (factors f_g built on G!) and whose *observations*
    are the displacements X - x_t; then read off the posterior mean at
    g = g_query (default 0). Z solves the flipped Gram system.
    """
    d = f_g.Xt.shape[1]
    gq = jnp.zeros((1, d), Z.dtype) if g_query is None else jnp.atleast_2d(g_query)
    step = cross_grad_matvec(spec, gq, f_g, Z)[0]
    return x_t + step

"""Exact gradient-Gram solves via Woodbury (paper Sec. 2.3, App. C.1).

Solves  (grad K grad') vec(Z) = vec(G)  in O(N^2 D + N^6) instead of
O((ND)^3).  The only O(D) work is two skinny contractions and one skinny
update; the N^2 x N^2 inner system is built and solved densely (N <= ~64).

Operator factorization of the low-rank term, re-derived for the (N, D)
layout via adjoint algebra (validated against the dense Gram in tests —
the paper's App. A vec/shuffle conventions do not transfer 1:1):

  dot:        T2(V) = U(K2e . U^T(V)^T)        U(M) = (M @ Xt) * lam
                                               U^T(V) = (V*lam) @ Xt^T
  stationary: T2(V) = U(-K2e . U^T(V)^T)       U(M) = (l_op(M) @ X) * lam
                                               U^T(V) = lt_op((V*lam) @ X^T)

Inner operator and solution (K1i = K1e^{-1}, S = (Xt*lam) @ Xt^T):

  dot:        F(Q) = Q^T / K2e + K1i @ Q @ S
              Z    = K1i @ (G / lam - Q @ Xt)
  stationary: F(Q) = -Q^T / K2e + lt_op(K1i @ l_op(Q) @ S)
              Z    = K1i @ (G / lam - l_op(Q) @ X)

Special case (paper Sec. 4.2): poly2 kernel + quadratic objective =>
Q has the closed form  Q = 1/2 S^{-1} (Xt (G - g_c)^T)^T  and the whole solve
is O(N^2 D + N^3).
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from . import backend
from .gram import FactorBundle, GramFactors
from .kernels import KernelSpec
from .mvm import l_op, lt_op

Array = jnp.ndarray


def _solve_spd(A: Array, B: Array, jitter: float = 0.0) -> Array:
    if jitter:
        A = A + jitter * jnp.eye(A.shape[0], dtype=A.dtype)
    return jnp.linalg.solve(A, B)


def _materialize(op: Callable[[Array], Array], n: int, dtype) -> Array:
    """Build the dense (n^2, n^2) matrix of a linear operator on (n, n) mats."""
    eye = jnp.eye(n * n, dtype=dtype).reshape(n * n, n, n)
    cols = jax.vmap(op)(eye)  # row-major vec convention, self-consistent
    return cols.reshape(n * n, n * n).T


def woodbury_solve(
    spec: KernelSpec,
    f: GramFactors,
    G: Array,
    jitter: float = 1e-10,
    bundle: FactorBundle | None = None,
) -> Array:
    """Z (N, D) with (grad K grad') vec(Z) = vec(G). Exact (paper Eq. 6-8).

    The O(N^2 D) work is ONE fused factor sweep (``backend.
    fused_factor_build``: S and C = G Xt^T in the same read of Xt/G) plus
    the single fused output assembly at the end — the old separate
    S-gram / K1i-stream / @Xt^T passes are gone (DESIGN.md sec. 12);
    T0 = (K1i G) Xt^T = K1i @ C never touches a D-axis.  Pass ``bundle``
    (from :func:`repro.core.gram.build_factor_bundle`, which shares the
    sweep with the K1e/K2e build) to skip even that one input sweep.
    """
    n = f.n
    dtype = G.dtype
    K1 = f.K1e
    if f.noise:
        # scalar-lam only: (K1e x Lam) + s I = (K1e + s/lam I) x Lam
        lam_s = jnp.asarray(f.lam)
        if lam_s.ndim != 0:
            raise ValueError("noise > 0 requires scalar Lambda on the exact path")
        K1 = K1 + (f.noise / lam_s) * jnp.eye(n, dtype=dtype)
    K1i = jnp.linalg.inv(K1 + jitter * jnp.eye(n, dtype=dtype))
    if bundle is None:
        S, _, _, C, _ = backend.fused_factor_build(f.Xt, f.Xt, G, f.lam)
    else:
        S, C = bundle.S, bundle.C
    S = S.astype(dtype)
    T0 = K1i @ C.astype(dtype)                # = (K1i G) Xt^T, now O(N^3)

    if spec.is_stationary:
        T = lt_op(T0)

        def inner(Q):
            return -Q.T / f.K2e + lt_op(K1i @ l_op(Q) @ S)

    else:
        T = T0

        def inner(Q):
            return Q.T / f.K2e + K1i @ Q @ S

    A = _materialize(inner, n, dtype)
    q = jnp.linalg.solve(A + jitter * jnp.eye(n * n, dtype=dtype), T.reshape(-1))
    Q = q.reshape(n, n)

    # Z = K1i @ (G/lam - QL @ Xt) as ONE fused D-stream: the K1i factor is
    # pushed through both terms so no (N, D) intermediate materializes.
    QL = l_op(Q) if spec.is_stationary else Q
    return backend.gram_update(K1i, -(K1i @ QL), G, f.Xt, 1.0,
                               v_scale=1.0 / jnp.asarray(f.lam))


def poly2_quadratic_solve(
    f: GramFactors,
    G: Array,
    g_c: Array | None = None,
    jitter: float = 1e-12,
) -> Array:
    """O(N^2 D + N^3) exact solve for the poly2 kernel on a quadratic target.

    Paper Sec. 4.2 / App. C.1 "Special Case": with k(r)=r^2/2 (so K2e == 1,
    K1e == S when the data really comes from f(x)=1/2 (x-x*)^T A (x-x*) and
    gradients G, prior gradient mean g_c = A(c - x*)):

        Q = 1/2 S^{-1} (Xt (G - g_c)^T)^T     -- one N x N solve
        Z = K1i @ ((G - g_c) / lam - Q^T @ Xt)

    Gt := G - g_c plays the role of the r.h.s. (inference on the residual).
    """
    Gt = G if g_c is None else G - g_c
    n = f.n
    dtype = G.dtype
    # ONE sweep of (Xt, Gt): S = (Xt L) Xt^T and C = Gt Xt^T together
    # (Sa = Xt Gt^T = C^T) — the two separate gram passes are fused.
    S, _, _, C, _ = backend.fused_factor_build(f.Xt, f.Xt, Gt, f.lam)
    S = S.astype(dtype)
    eye = jnp.eye(n, dtype=dtype)
    Sj = S + jitter * eye
    # Sa = Xt Gt^T  (= X~ A X~^T on a true quadratic, symmetric);
    # Q = 1/2 Sa S^{-1} solves F(Q) = T analytically (paper App. C.1).
    Sa = C.T.astype(dtype)
    Q = 0.5 * jnp.linalg.solve(Sj.T, Sa.T).T          # Sa @ S^{-1}
    K1i = jnp.linalg.inv(f.K1e + jitter * eye)
    # K1i @ (Gt/lam - Q @ Xt), fused into one D-stream as in woodbury_solve.
    return backend.gram_update(K1i, -(K1i @ Q), Gt, f.Xt, 1.0,
                               v_scale=1.0 / jnp.asarray(f.lam))


def dense_solve(spec: KernelSpec, X: Array, G: Array, lam=1.0, c=None,
                noise: float = 0.0, jitter: float = 1e-10) -> Array:
    """O((ND)^3) reference solve against the materialized Gram (tests only)."""
    from .gram import dense_gram

    n, d = X.shape
    full = dense_gram(spec, X, lam=lam, c=c, noise=noise)
    z = jnp.linalg.solve(full + jitter * jnp.eye(n * d, dtype=X.dtype), G.reshape(-1))
    return z.reshape(n, d)

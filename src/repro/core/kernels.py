"""Kernel zoo: scalar kernels k(r) with derivatives w.r.t. the scalar r.

Every kernel is expressed through a scalar intermediate r(x_a, x_b)
(paper Def. 2):

  dot-product kernels:  r = (x_a - c)^T Lambda (x_b - c)
  stationary kernels:   r = (x_a - x_b)^T Lambda (x_a - x_b)

The gradient Gram matrix blocks only need k'(r), k''(r) (paper Eq. 2);
Hessian inference additionally needs k'''(r) (paper Eq. 11).

``effective'' coefficients absorb the chain-rule factors of r so that for
BOTH families the (a,b) block of the gradient Gram matrix reads

    block_ab = K1e[a,b] * Lambda + K2e[a,b] * outer(u_ab, w_ab)

  dot:        K1e = k'(r),    K2e = k''(r),    u_ab = Lam x~_b, w_ab = Lam x~_a
  stationary: K1e = -2 k'(r), K2e = -4 k''(r), u_ab = w_ab = Lam (x_a - x_b)

(derivation: paper Eq. 3/4, App. B.2/B.3).  Third-derivative effective
coefficient K3e is k''' (dot) and -8 k''' (stationary); see
``core/inference.py`` for where the signs enter Hessian inference.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

Array = jnp.ndarray

# Guard for kernels whose r-derivatives are singular at r=0 (Matern family).
# The singular factors are always multiplied by powers of ||x_a-x_b|| that
# vanish at least as fast, so clamping r is exact in the limit and keeps the
# decomposition finite (see DESIGN.md section 9).
_R_EPS = 1e-12


def _safe_sqrt(r: Array) -> Array:
    return jnp.sqrt(jnp.maximum(r, _R_EPS))


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """A scalar kernel k(r) and its first three derivatives in r."""

    name: str
    family: str  # 'dot' | 'stationary'
    k0: Callable[[Array], Array]
    k1: Callable[[Array], Array]
    k2: Callable[[Array], Array]
    k3: Callable[[Array], Array]
    # True if gradient GP is mathematically well defined (k once
    # differentiable as a covariance, i.e. k' finite at r=0 for stationary).
    grad_ok: bool = True

    @property
    def is_stationary(self) -> bool:
        return self.family == "stationary"

    # -- effective coefficients used by gram/mvm/woodbury/inference --------
    def k1e(self, r: Array) -> Array:
        v = self.k1(r)
        return -2.0 * v if self.is_stationary else v

    def k2e(self, r: Array) -> Array:
        v = self.k2(r)
        return -4.0 * v if self.is_stationary else v

    def k3e(self, r: Array) -> Array:
        v = self.k3(r)
        return -8.0 * v if self.is_stationary else v


# --------------------------------------------------------------------------
# Stationary kernels (paper Table 2).  r is the SQUARED scaled distance.
# --------------------------------------------------------------------------

def _rbf() -> KernelSpec:
    k0 = lambda r: jnp.exp(-0.5 * r)
    return KernelSpec(
        "rbf", "stationary",
        k0=k0,
        k1=lambda r: -0.5 * k0(r),
        k2=lambda r: 0.25 * k0(r),
        k3=lambda r: -0.125 * k0(r),
    )


def _matern12() -> KernelSpec:
    # k = exp(-sqrt(r)); k' singular at 0 -> gradient GP ill-defined.
    k0 = lambda r: jnp.exp(-_safe_sqrt(r))
    return KernelSpec(
        "matern12", "stationary",
        k0=k0,
        k1=lambda r: -k0(r) / (2.0 * _safe_sqrt(r)),
        k2=lambda r: (_safe_sqrt(r) + 1.0) / (4.0 * _safe_sqrt(r) ** 3) * k0(r),
        k3=lambda r: -(3.0 + 3.0 * _safe_sqrt(r) + r)
        / (8.0 * _safe_sqrt(r) ** 5) * k0(r),
        grad_ok=False,
    )


def _matern32() -> KernelSpec:
    # k = (1+s) e^{-s}, s = sqrt(3 r).  Stable closed forms:
    #   k'  = -(3/2) e^{-s}                      (finite at r=0)
    #   k'' = (3 sqrt(3) / (4 sqrt(r))) e^{-s}    (singular; clamped)
    def k0(r):
        s = jnp.sqrt(3.0 * jnp.maximum(r, 0.0))
        return (1.0 + s) * jnp.exp(-s)

    def k1(r):
        s = jnp.sqrt(3.0 * jnp.maximum(r, 0.0))
        return -1.5 * jnp.exp(-s)

    def k2(r):
        sr = _safe_sqrt(r)
        return (3.0 * jnp.sqrt(3.0) / (4.0 * sr)) * jnp.exp(-jnp.sqrt(3.0) * sr)

    def k3(r):
        sr = _safe_sqrt(r)
        s = jnp.sqrt(3.0) * sr
        # d/dr k2 = k2 * (-1/(2r) - sqrt(3)/(2 sqrt(r)))
        return k2(r) * (-0.5 / jnp.maximum(r, _R_EPS) - jnp.sqrt(3.0) / (2.0 * sr))

    return KernelSpec("matern32", "stationary", k0, k1, k2, k3)


def _matern52() -> KernelSpec:
    # k = (1 + s + s^2/3) e^{-s}, s = sqrt(5 r).  Stable closed forms:
    #   k'   = -(5/6)(1+s) e^{-s}
    #   k''  = (25/12) e^{-s}          (finite!  Matern-5/2 is C^2)
    #   k''' = -(125/24) e^{-s} / s    (singular; clamped)
    def k0(r):
        s = jnp.sqrt(5.0 * jnp.maximum(r, 0.0))
        return (1.0 + s + s * s / 3.0) * jnp.exp(-s)

    def k1(r):
        s = jnp.sqrt(5.0 * jnp.maximum(r, 0.0))
        return -(5.0 / 6.0) * (1.0 + s) * jnp.exp(-s)

    def k2(r):
        s = jnp.sqrt(5.0 * jnp.maximum(r, 0.0))
        return (25.0 / 12.0) * jnp.exp(-s)

    def k3(r):
        s = jnp.sqrt(5.0 * jnp.maximum(r, _R_EPS))
        return -(125.0 / 24.0) * jnp.exp(-s) / s

    return KernelSpec("matern52", "stationary", k0, k1, k2, k3)


def _rational_quadratic(alpha: float = 2.0) -> KernelSpec:
    a = float(alpha)

    def base(r, p):
        return (1.0 + r / (2.0 * a)) ** (-a - p)

    return KernelSpec(
        f"rq{a:g}", "stationary",
        k0=lambda r: base(r, 0.0),
        k1=lambda r: -0.5 * base(r, 1.0),
        k2=lambda r: (a + 1.0) / (4.0 * a) * base(r, 2.0),
        k3=lambda r: -(a + 1.0) * (a + 2.0) / (8.0 * a * a) * base(r, 3.0),
    )


# --------------------------------------------------------------------------
# Dot-product kernels (paper Table 1).  r is the centered scaled dot product.
# --------------------------------------------------------------------------

def _poly2() -> KernelSpec:
    return KernelSpec(
        "poly2", "dot",
        k0=lambda r: 0.5 * r * r,
        k1=lambda r: r,
        k2=lambda r: jnp.ones_like(r),
        k3=lambda r: jnp.zeros_like(r),
    )


def _poly(p: int) -> KernelSpec:
    p = int(p)
    if p < 2:
        raise ValueError("polynomial kernel needs p >= 2 for gradient GPs")

    return KernelSpec(
        f"poly{p}", "dot",
        k0=lambda r: r**p / (p * (p - 1)),
        k1=lambda r: r ** (p - 1) / (p - 1),
        k2=lambda r: r ** (p - 2),
        k3=lambda r: (p - 2) * r ** (p - 3) if p >= 3 else jnp.zeros_like(r),
    )


def _exp_dot() -> KernelSpec:
    e = lambda r: jnp.exp(r)
    return KernelSpec("expdot", "dot", e, e, e, e)


_REGISTRY: dict[str, Callable[[], KernelSpec]] = {
    "rbf": _rbf,
    "matern12": _matern12,
    "matern32": _matern32,
    "matern52": _matern52,
    "rq": _rational_quadratic,
    "poly2": _poly2,
    "poly3": lambda: _poly(3),
    "poly4": lambda: _poly(4),
    "expdot": _exp_dot,
}


def get_kernel(name: str, **kwargs) -> KernelSpec:
    """Look up a kernel by name. ``rq`` takes ``alpha``; ``poly<p>`` is fixed."""
    if name == "rq":
        return _rational_quadratic(**kwargs)
    try:
        return _REGISTRY[name]()
    except KeyError:
        raise KeyError(f"unknown kernel {name!r}; have {sorted(_REGISTRY)}")


def kernel_names() -> list[str]:
    return sorted(_REGISTRY)

"""Matrix-free products with the gradient Gram matrix (paper Alg. 2 / Eq. 9).

All D-sized objects are (N, D); the Gram matrix acts on vec(V) with
vec(V)[a*D + i] = V[a, i].  Cost per product: O(N^2 D); storage O(ND + N^2).

Derivations (this layout; see DESIGN.md):

  dot:         W = (K1e @ V + (K2e * M) @ Xt) * lam,      M = (Xt*lam) @ V^T
  stationary:  W = (K1e @ V + (diag(rowsum(Mt)) - Mt) @ X) * lam,
               Mt = K2e * (P - diag(P)[None, :]),         P = (X*lam) @ V^T

The stationary form is paper Alg. 2 with the sparse L operator folded in:
  L (Q)  = diag(rowsum(Q)) - Q
  L^T(M) = diag(M)[:, None] - M          (both O(N^2)).

Every O(ND) contraction routes through ``core.backend``: on the pallas
backend a full MVM is ONE ``fused_gram_mvm`` launch (no (N, D) or (N, N)
intermediate ever round-trips HBM); on the jnp backend the three-step
oracle below runs at native precision.
"""
from __future__ import annotations

import jax.numpy as jnp

from . import backend
from .gram import GramFactors, scaled_gram, pairwise_r
from .kernels import KernelSpec

Array = jnp.ndarray


def l_op(Q: Array) -> Array:
    """L(Q) = diag(rowsum(Q)) - Q  (paper App. A, stationary-kernel U = (I x Lam X) L)."""
    return jnp.diag(jnp.sum(Q, axis=1)) - Q


def lt_op(M: Array) -> Array:
    """L^T(M)[a,b] = M[a,a] - M[a,b]."""
    return jnp.diagonal(M)[:, None] - M


# The (N, N) Hadamard/L-operator algebra of Alg. 2 — O(N^2), never hot.
# Single jnp definition lives next to the kernel oracles.
from repro.kernels.ref import small_op as _small_op  # noqa: E402


def gram_matvec(f: GramFactors, V: Array, *, stationary: bool, gram_xv: Array | None = None) -> Array:
    """(grad K grad') vec(V) without materializing the Gram matrix.

    f.Xt is X-c for dot kernels and X for stationary ones.  ``gram_xv`` lets a
    caller (e.g. the distributed psum path) supply the precomputed (N, N)
    contraction (Xt*lam) @ V^T — in that case only the D-streaming update
    half runs (one ``backend.gram_update`` launch). Without it, the pallas
    backend runs the whole product as a single fused megakernel.
    """
    if gram_xv is None and backend.resolve_backend() == "pallas":
        return backend.fused_gram_mvm(f.K1e, f.K2e, f.Xt, V, f.lam,
                                      stationary=stationary, noise=f.noise)
    M = scaled_gram(f.Xt, V, f.lam) if gram_xv is None else gram_xv
    small = _small_op(f.K2e, M, stationary=stationary)
    return backend.gram_update(f.K1e, small, V, f.Xt, f.lam, noise=f.noise)


def gram_matvec_multi(f: GramFactors, V: Array, *, stationary: bool) -> Array:
    """Stacked-RHS Gram MVM: V (R, N, D) -> (R, N, D).

    On the pallas backend this is ONE multi-RHS megakernel launch that
    streams Xt once per phase for all R right-hand sides (CG over Hessian
    operator columns / HMC predictive gradients rides on this).
    """
    return backend.fused_gram_mvm(f.K1e, f.K2e, f.Xt, V, f.lam,
                                  stationary=stationary, noise=f.noise)


def cross_grad_matvec(
    spec: KernelSpec,
    Xq: Array,
    f: GramFactors,
    V: Array,
    lam=None,
) -> Array:
    """Posterior-mean style contraction: sum_b block(q, b) @ V[b].

    Xq: (Nq, D) query points; returns (Nq, D).  With V = Z (the Gram solve of
    the observed gradients) this IS the posterior mean of grad f at Xq
    (paper Eq. 26 / App. D).
    """
    lam = f.lam if lam is None else lam
    if spec.is_stationary:
        r = pairwise_r(spec, Xq, f.Xt, lam)
        K1e, K2e = spec.k1e(r), spec.k2e(r)
        # m[q, b] = (x_q - x_b)^T Lam V[b]
        m = scaled_gram(Xq, V, lam) - backend.row_dots(f.Xt, V, lam)[None, :]
        Mt = K2e * m
        W = backend.gram_update(K1e, -Mt, V, f.Xt, lam)
        return W + (Xq * jnp.sum(Mt, axis=1)[:, None]) * lam
    Xqt = Xq if f.c is None else Xq - f.c
    r = scaled_gram(Xqt, f.Xt, lam)
    K1e, K2e = spec.k1e(r), spec.k2e(r)
    # block(q,b)^{ij} = K1e Lam^{ij} + K2e [Lam x~_b]^i [Lam x~_q]^j
    # row q: sum_b K1e[q,b] Lam V[b] + sum_b K2e[q,b] (Lam x~_b) (x~_q . Lam V[b])
    m = scaled_gram(Xqt, V, lam)  # m[q,b] = x~_q^T Lam V[b]
    return backend.gram_update(K1e, K2e * m, V, f.Xt, lam)


def cross_value_matvec(
    spec: KernelSpec,
    Xq: Array,
    f: GramFactors,
    V: Array,
) -> Array:
    """cov(f(Xq), grad f(X)) contracted with V: (Nq,).

    cov(f(x_q), g_b)^j = d k(x_q, x_b) / d x_b^j = k'(r) * dr/dx_b.
      dot:        dr/dx_b = Lam x~_q
      stationary: dr/dx_b = -2 Lam (x_q - x_b)
    Used for posterior mean of the *function* from gradient observations
    (paper Fig. 4) — defined up to an additive constant (the prior mean).
    """
    lam = f.lam
    if spec.is_stationary:
        r = pairwise_r(spec, Xq, f.Xt, lam)
        k1 = spec.k1(r)
        # sum_b k1[q,b] * (-2) * (x_q - x_b)^T Lam V[b]
        m = scaled_gram(Xq, V, lam) - backend.row_dots(f.Xt, V, lam)[None, :]
        return jnp.sum(-2.0 * k1 * m, axis=1)
    Xqt = Xq if f.c is None else Xq - f.c
    r = scaled_gram(Xqt, f.Xt, lam)
    k1 = spec.k1(r)
    m = scaled_gram(Xqt, V, lam)
    return jnp.sum(k1 * m, axis=1)

"""Batched posterior query serving (factor reuse; zero re-solves).

Once a ``GPGState`` (or a plain ``GramFactors`` + solved ``Z``) exists, any
number of posterior queries are pure cross-covariance contractions against
the SAME cached solve — O(Q N D) total for Q query points, no inner system
ever touched again (paper Sec. 4: "the cost of inference is dominated by
the solve"; the serving layer amortizes that solve across every query).

Per microbatch of queries, everything routes through the fused backend
cross-covariance paths (``cross_value_matvec`` / ``cross_grad_matvec`` —
``backend.gram_update`` streams, one pallas launch each on TPU):

  value:    posterior mean of f       (Q,)    — up to the prior constant
  grad:     posterior mean of grad f  (Q, D)  — paper Eq. 26
  hess_v:   posterior mean Hessian-vector product H(x_q) @ v  (Q, D)
            — paper Eq. 12, applied through the diag + rank-2N factored
            form, vmapped over the microbatch.
  std:      posterior std of f        (Q,)    — ``return_std=True``
  grad_std: posterior std of grad f   (Q, D)  — ``return_grad_std=True``

The uncertainty paths (``repro.hyper.variance``) additionally need ONE
structured factorization of the noisy Gram per state revision (the
``GramSolver``); it is built on demand here, or passed in pre-factorized
by the serving layer.  Each value-std query is then one structured
Woodbury application (O(N^2 D + N^4)); gradient stds cost D applications
per query and are opt-in separately.

The microbatching loop bounds peak memory at O(B N D) for microbatch B and
keeps each chunk a single compiled computation — the shape served traffic
wants (``train/serve.py`` wraps this in a padded fixed-shape jitted step).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .gram import GramFactors
from .inference import posterior_hessian
from .kernels import KernelSpec
from .mvm import cross_grad_matvec, cross_value_matvec

Array = jnp.ndarray


class PosteriorBatch(NamedTuple):
    """Batched posterior means (and optional stds) at Q query points."""

    value: Array                      # (Q,)   mean of f (up to prior const)
    grad: Array                       # (Q, D) mean of grad f
    hess_v: Optional[Array] = None    # (Q, D) mean Hessian @ probe, if asked
    std: Optional[Array] = None       # (Q,)   std of f, if return_std
    grad_std: Optional[Array] = None  # (Q, D) std of grad f, if asked

    @property
    def q(self) -> int:
        return self.grad.shape[0]


def _query_chunk(spec: KernelSpec, Xq: Array, f: GramFactors, Z: Array,
                 probe: Optional[Array], solver=None,
                 want_grad_std: bool = False) -> PosteriorBatch:
    """One microbatch: fused cross-covariance contractions, no solves."""
    value = cross_value_matvec(spec, Xq, f, Z)
    grad = cross_grad_matvec(spec, Xq, f, Z)
    hess_v = None
    if probe is not None:
        hess_v = jax.vmap(
            lambda xq: posterior_hessian(spec, xq, f, Z).matvec(probe))(Xq)
    std = gstd = None
    if solver is not None:
        from repro.hyper.variance import grad_std as _gstd
        from repro.hyper.variance import value_std as _vstd

        std = _vstd(spec, Xq, f, solver)
        if want_grad_std:
            gstd = _gstd(spec, Xq, f, solver)
    return PosteriorBatch(value=value, grad=grad, hess_v=hess_v, std=std,
                          grad_std=gstd)


def _default_solver(spec: KernelSpec, f: GramFactors, signal):
    from repro.hyper.variance import make_solver

    # Core convention: GramFactors.noise is the noise on the UNSCALED Gram
    # (sigma^2/s^2 — what every solve in core/ adds).  make_solver expects
    # the raw sigma^2 and divides by the signal itself, so undo that here:
    # the effective noise must stay f.noise for any ``signal``.
    return make_solver(spec, f, noise=jnp.asarray(f.noise) * signal,
                       signal=signal)


def posterior_batch(
    spec: KernelSpec,
    Xq: Array,
    f: GramFactors,
    Z: Array,
    *,
    probe: Optional[Array] = None,
    microbatch: Optional[int] = None,
    return_std: bool = False,
    return_grad_std: bool = False,
    signal=1.0,
    solver=None,
) -> PosteriorBatch:
    """Evaluate posterior mean value/grad (and Hessian @ ``probe``) at Xq.

    Xq: (Q, D).  ``microbatch`` bounds the per-chunk query count (peak
    memory O(microbatch * N * D)); None evaluates in one chunk.  Q queries
    cost O(Q N D) and perform ZERO solves — the factors and Z are reused
    verbatim (asserted against the ``GPGData.n_solve`` counter in
    tests/test_core_state.py).

    ``return_std=True`` adds the posterior std of the value (``.std``);
    ``return_grad_std=True`` additionally the per-component gradient std
    (``.grad_std``).  Both are served through a ``repro.hyper.variance.
    GramSolver`` — pass one via ``solver`` to amortize its factorization
    across requests (the serve layer does), else it is built here with
    ``f.noise`` interpreted as the EFFECTIVE noise sigma^2/s^2 (the core
    convention for ``GramFactors``) and ``signal`` scaling the prior.
    The solver is a factorization of the noisy Gram, NOT a re-solve of
    the representer system: ``n_solve`` stays untouched.
    """
    Xq = jnp.atleast_2d(Xq)
    if (return_std or return_grad_std) and solver is None:
        solver = _default_solver(spec, f, signal)
    if not (return_std or return_grad_std):
        solver = None
    q = Xq.shape[0]
    if not microbatch or microbatch >= q:
        return _query_chunk(spec, Xq, f, Z, probe, solver, return_grad_std)
    chunks = [_query_chunk(spec, Xq[i:i + microbatch], f, Z, probe, solver,
                           return_grad_std)
              for i in range(0, q, microbatch)]
    cat = lambda xs: jnp.concatenate(xs)
    return PosteriorBatch(
        value=cat([c.value for c in chunks]),
        grad=cat([c.grad for c in chunks]),
        hess_v=None if probe is None else cat([c.hess_v for c in chunks]),
        std=None if solver is None else cat([c.std for c in chunks]),
        grad_std=(cat([c.grad_std for c in chunks])
                  if (solver is not None and return_grad_std) else None),
    )


def make_query_fn(spec: KernelSpec, *, with_probe: bool = False,
                  with_std: bool = False, with_grad_std: bool = False):
    """A jittable (f, Z[, solver], Xq[, probe]) -> PosteriorBatch evaluator.

    The factors/Z (and the variance ``GramSolver``, when ``with_std``) are
    *arguments*, not captures, so one compiled function serves every state
    revision of the same shape — extend() between batches never triggers
    recompilation, and because every hyperparameter lives inside the
    solver/factor arrays, neither does a refit (``train/serve.py`` relies
    on this for the streaming serve loop).
    """
    if with_std or with_grad_std:
        if with_probe:
            def fn(f: GramFactors, Z: Array, solver, Xq: Array, probe: Array):
                return _query_chunk(spec, Xq, f, Z, probe, solver,
                                    with_grad_std)
        else:
            def fn(f: GramFactors, Z: Array, solver, Xq: Array):
                return _query_chunk(spec, Xq, f, Z, None, solver,
                                    with_grad_std)
    elif with_probe:
        def fn(f: GramFactors, Z: Array, Xq: Array, probe: Array):
            return _query_chunk(spec, Xq, f, Z, probe)
    else:
        def fn(f: GramFactors, Z: Array, Xq: Array):
            return _query_chunk(spec, Xq, f, Z, None)
    return fn

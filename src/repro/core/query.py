"""Batched posterior query serving (factor reuse; zero re-solves).

Once a ``GPGState`` (or a plain ``GramFactors`` + solved ``Z``) exists, any
number of posterior queries are pure cross-covariance contractions against
the SAME cached solve — O(Q N D) total for Q query points, no inner system
ever touched again (paper Sec. 4: "the cost of inference is dominated by
the solve"; the serving layer amortizes that solve across every query).

Per microbatch of queries, everything routes through the fused backend
cross-covariance paths (``cross_value_matvec`` / ``cross_grad_matvec`` —
``backend.gram_update`` streams, one pallas launch each on TPU):

  value:   posterior mean of f       (Q,)    — up to the prior constant
  grad:    posterior mean of grad f  (Q, D)  — paper Eq. 26
  hess_v:  posterior mean Hessian-vector product H(x_q) @ v  (Q, D)
           — paper Eq. 12, applied through the diag + rank-2N factored
           form, vmapped over the microbatch.

The microbatching loop bounds peak memory at O(B N D) for microbatch B and
keeps each chunk a single compiled computation — the shape served traffic
wants (``train/serve.py`` wraps this in a padded fixed-shape jitted step).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .gram import GramFactors
from .inference import posterior_hessian
from .kernels import KernelSpec
from .mvm import cross_grad_matvec, cross_value_matvec

Array = jnp.ndarray


class PosteriorBatch(NamedTuple):
    """Batched posterior means at Q query points."""

    value: Array                    # (Q,)   mean of f (up to prior const)
    grad: Array                     # (Q, D) mean of grad f
    hess_v: Optional[Array] = None  # (Q, D) mean Hessian @ probe, if asked

    @property
    def q(self) -> int:
        return self.grad.shape[0]


def _query_chunk(spec: KernelSpec, Xq: Array, f: GramFactors, Z: Array,
                 probe: Optional[Array]) -> PosteriorBatch:
    """One microbatch: fused cross-covariance contractions, no solves."""
    value = cross_value_matvec(spec, Xq, f, Z)
    grad = cross_grad_matvec(spec, Xq, f, Z)
    hess_v = None
    if probe is not None:
        hess_v = jax.vmap(
            lambda xq: posterior_hessian(spec, xq, f, Z).matvec(probe))(Xq)
    return PosteriorBatch(value=value, grad=grad, hess_v=hess_v)


def posterior_batch(
    spec: KernelSpec,
    Xq: Array,
    f: GramFactors,
    Z: Array,
    *,
    probe: Optional[Array] = None,
    microbatch: Optional[int] = None,
) -> PosteriorBatch:
    """Evaluate posterior mean value/grad (and Hessian @ ``probe``) at Xq.

    Xq: (Q, D).  ``microbatch`` bounds the per-chunk query count (peak
    memory O(microbatch * N * D)); None evaluates in one chunk.  Q queries
    cost O(Q N D) and perform ZERO solves — the factors and Z are reused
    verbatim (asserted against the ``GPGData.n_solve`` counter in
    tests/test_core_state.py).
    """
    Xq = jnp.atleast_2d(Xq)
    q = Xq.shape[0]
    if not microbatch or microbatch >= q:
        return _query_chunk(spec, Xq, f, Z, probe)
    chunks = [_query_chunk(spec, Xq[i:i + microbatch], f, Z, probe)
              for i in range(0, q, microbatch)]
    return PosteriorBatch(
        value=jnp.concatenate([c.value for c in chunks]),
        grad=jnp.concatenate([c.grad for c in chunks]),
        hess_v=None if probe is None else
        jnp.concatenate([c.hess_v for c in chunks]),
    )


def make_query_fn(spec: KernelSpec, *, with_probe: bool = False):
    """A jittable (f, Z, Xq[, probe]) -> PosteriorBatch chunk evaluator.

    The factors/Z are *arguments*, not captures, so one compiled function
    serves every state revision of the same shape — extend() between
    batches never triggers recompilation (``train/serve.py`` relies on
    this for the streaming serve loop).
    """
    if with_probe:
        def fn(f: GramFactors, Z: Array, Xq: Array, probe: Array):
            return _query_chunk(spec, Xq, f, Z, probe)
    else:
        def fn(f: GramFactors, Z: Array, Xq: Array):
            return _query_chunk(spec, Xq, f, Z, None)
    return fn

"""Batched posterior query serving (factor reuse; zero re-solves).

Once a ``GPGState`` (or a plain ``GramFactors`` + solved ``Z``) exists, any
number of posterior queries are pure cross-covariance contractions against
the SAME cached solve — O(Q N D) total for Q query points, no inner system
ever touched again (paper Sec. 4: "the cost of inference is dominated by
the solve"; the serving layer amortizes that solve across every query).

Per microbatch of queries, the value and grad means come off ONE
single-sweep factor launch (``backend.fused_factor_build`` — cross gram,
norm strips, cross contraction and row-dot correction in the same read
of Xq/Xt/Z) plus one fused grad output stream (``backend.gram_update``);
see ``_mean_chunk`` and DESIGN.md sec. 12.  The chunk serves:

  value:    posterior mean of f       (Q,)    — up to the prior constant
  grad:     posterior mean of grad f  (Q, D)  — paper Eq. 26
  hess_v:   posterior mean Hessian-vector product H(x_q) @ v  (Q, D)
            — paper Eq. 12, applied through the diag + rank-2N factored
            form, vmapped over the microbatch.
  std:      posterior std of f        (Q,)    — ``return_std=True``
  grad_std: posterior std of grad f   (Q, D)  — ``return_grad_std=True``

The uncertainty paths (``repro.hyper.variance``) additionally need ONE
structured factorization of the noisy Gram per state revision (the
``GramSolver``); it is built on demand here, or passed in pre-factorized
by the serving layer.  Each value-std query is then one structured
Woodbury application (O(N^2 D + N^4)); gradient stds cost D applications
per query and are opt-in separately.

The microbatching loop bounds peak memory at O(B N D) for microbatch B and
keeps each chunk a single compiled computation — the shape served traffic
wants (``train/serve.py`` wraps this in a padded fixed-shape jitted step).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.obs import trace as _obs

from . import backend
from .gram import GramFactors
from .inference import posterior_hessian
from .kernels import KernelSpec

Array = jnp.ndarray


class PosteriorBatch(NamedTuple):
    """Batched posterior means (and optional stds) at Q query points."""

    value: Array                      # (Q,)   mean of f (up to prior const)
    grad: Array                       # (Q, D) mean of grad f
    hess_v: Optional[Array] = None    # (Q, D) mean Hessian @ probe, if asked
    std: Optional[Array] = None       # (Q,)   std of f, if return_std
    grad_std: Optional[Array] = None  # (Q, D) std of grad f, if asked

    @property
    def q(self) -> int:
        return self.grad.shape[0]


def _mean_chunk(spec: KernelSpec, Xq: Array, f: GramFactors, Z: Array,
                stream_dt=None):
    """Value + grad posterior means off ONE factor sweep (DESIGN.md sec. 12).

    A single ``backend.fused_factor_build`` launch streams (Xq, Xt, Z)
    once and emits every reduction both means need — the cross gram P,
    the norm strips for stationary r, the cross contraction
    C^T = (Xq L) Z^T, and the row-dot correction tz.  The only other
    D-touching op is the one fused output stream for grad
    (``backend.gram_update``).  Replaces the pre-fusion sequence of two
    ``pairwise_r`` + two ``scaled_gram`` + two ``row_dots`` launches
    (``cross_value_matvec`` / ``cross_grad_matvec`` kept for single-point
    callers).

    ``stream_dt`` quantizes the streams to storage precision here.  For
    stationary kernels the coordinates are shifted to the first data row
    BEFORE casting (every quantity below — r, m, and the two-term grad
    assembly — is exactly translation invariant, and quantizing absolute
    coordinates of clustered data would destroy their cancellations at
    ~|x|/spread amplification; DESIGN.md sec. 12.2).  A pre-quantized
    ``f`` carries the same shift in ``f.shift`` so queries join its
    frame.
    """
    lam = f.lam
    if stream_dt is not None and f.Xt.dtype != stream_dt:
        if spec.is_stationary:
            shift = f.Xt[0]
            f = f._replace(Xt=(f.Xt - shift).astype(stream_dt), shift=None)
            Xq = (Xq - shift).astype(stream_dt)
        else:
            Xqt0 = Xq if f.c is None else Xq - f.c
            f = f._replace(Xt=f.Xt.astype(stream_dt), c=None)
            Xq = Xqt0.astype(stream_dt)  # pre-centered in the dot frame
        # Z is NEVER quantized (precision rule 3: solve outputs stay f32).
        # Representers of a near-singular window are huge and cancel by
        # orders of magnitude in the posterior mean — storage quantization
        # of Z would be amplified by |Z|/|mean|, catastrophically for
        # clustered data.  X/G/query streams carry physical scales and
        # quantize safely.
    elif f.shift is not None:
        Xq = (Xq - f.shift).astype(f.Xt.dtype)
    elif f.Xt.dtype != Xq.dtype:
        # f arrived pre-quantized (cached stream copies): join its frame.
        # Dot-kernel Xt is stored centered, so queries must center THEN
        # cast — quantizing absolute coordinates first would lose
        # |x|/|x-c| of the precision the centered storage preserves.
        if not spec.is_stationary and f.c is not None:
            Xq = (Xq - f.c).astype(f.Xt.dtype)
            f = f._replace(c=None)
        else:
            Xq = Xq.astype(f.Xt.dtype)
    if not spec.is_stationary and f.c is not None:
        Xq = Xq - f.c
        f = f._replace(c=None)
    strips = _mean_strips(Xq, f, Z)
    return _mean_assemble(spec, strips, Xq, f, Z)


def _mean_strips(Xq: Array, f: GramFactors, Z: Array):
    """The ONE D-touching reduction of the mean path: a fused factor sweep.

    ``Xq`` must already be in ``f``'s frame (centered for dot kernels,
    shifted if ``f`` is).  Returns the 5-tuple of (Q, N)/(Q,)/(N,) strips
    — cross gram P, both norm strips, cross contraction C, row-dot tz.
    Every element is a plain sum over the D axis, so under D-sharding the
    local (Q, D_loc) launch's output is psummed ONCE as a stacked tuple
    and :func:`_mean_assemble` proceeds on the replicated strips
    (``core/dist_state.py``).
    """
    return backend.fused_factor_build(Xq, f.Xt, Z, f.lam, v_scale=f.lam)


def _mean_assemble(spec: KernelSpec, strips, Xq: Array, f: GramFactors,
                   Z: Array):
    """Strips -> (value, grad): replicated value + the one output stream.

    D-free except the fused grad output stream (``backend.gram_update``)
    and the stationary ``Xq``-proportional term — both act column-wise on
    the D axis, so under sharding they run unchanged on the local shard.
    """
    lam = f.lam
    if spec.is_stationary:
        P, naq, nbd, C, tz = strips
        r = jnp.maximum(naq[:, None] + nbd[None, :] - 2.0 * P, 0.0)
        m = C.T - tz[None, :]
        value = jnp.sum(-2.0 * spec.k1(r) * m, axis=1)
        Mt = spec.k2e(r) * m
        W = backend.gram_update(spec.k1e(r), -Mt, Z, f.Xt, lam)
        grad = W + (Xq * jnp.sum(Mt, axis=1)[:, None]) * lam
    else:
        P, _, _, C, _ = strips
        m = C.T
        value = jnp.sum(spec.k1(P) * m, axis=1)
        grad = backend.gram_update(spec.k1e(P), spec.k2e(P) * m, Z, f.Xt, lam)
    return value, grad


def _query_chunk(spec: KernelSpec, Xq: Array, f: GramFactors, Z: Array,
                 probe: Optional[Array], solver=None,
                 want_grad_std: bool = False,
                 stream_dt=None) -> PosteriorBatch:
    """One microbatch: fused cross-covariance contractions, no solves.

    The mean path may run on quantized (``stream_dt``) or pre-quantized
    shifted (``f.shift``) streams; the Hessian-probe and std paths always
    need UNSHIFTED factors, so callers requesting them must pass the f32
    masters (the state/serve layers do).
    """
    if f.shift is not None and (probe is not None or solver is not None):
        raise ValueError("probe/std queries need unshifted factors — pass "
                         "the f32 masters (the shifted bf16 view is a "
                         "mean-path stream, see GramFactors.shift)")
    value, grad = _mean_chunk(spec, Xq, f, Z, stream_dt)
    hess_v = None
    if probe is not None:
        hess_v = jax.vmap(
            lambda xq: posterior_hessian(spec, xq, f, Z).matvec(probe))(Xq)
    std = gstd = None
    if solver is not None:
        from repro.hyper.variance import grad_std as _gstd
        from repro.hyper.variance import value_std as _vstd

        std = _vstd(spec, Xq, f, solver)
        if want_grad_std:
            gstd = _gstd(spec, Xq, f, solver)
    return PosteriorBatch(value=value, grad=grad, hess_v=hess_v, std=std,
                          grad_std=gstd)


def _default_solver(spec: KernelSpec, f: GramFactors, signal):
    from repro.hyper.variance import make_solver

    # Core convention: GramFactors.noise is the noise on the UNSCALED Gram
    # (sigma^2/s^2 — what every solve in core/ adds).  make_solver expects
    # the raw sigma^2 and divides by the signal itself, so undo that here:
    # the effective noise must stay f.noise for any ``signal``.
    return make_solver(spec, f, noise=jnp.asarray(f.noise) * signal,
                       signal=signal)


def posterior_batch(
    spec: KernelSpec,
    Xq: Array,
    f: GramFactors,
    Z: Array,
    *,
    probe: Optional[Array] = None,
    microbatch: Optional[int] = None,
    return_std: bool = False,
    return_grad_std: bool = False,
    signal=1.0,
    solver=None,
    precision: Optional[str] = None,
) -> PosteriorBatch:
    """Evaluate posterior mean value/grad (and Hessian @ ``probe``) at Xq.

    Xq: (Q, D).  ``microbatch`` bounds the per-chunk query count (peak
    memory O(microbatch * N * D)); None evaluates in one chunk.  Q queries
    cost O(Q N D) and perform ZERO solves — the factors and Z are reused
    verbatim (asserted against the ``GPGData.n_solve`` counter in
    tests/test_core_state.py).

    ``return_std=True`` adds the posterior std of the value (``.std``);
    ``return_grad_std=True`` additionally the per-component gradient std
    (``.grad_std``).  Both are served through a ``repro.hyper.variance.
    GramSolver`` — pass one via ``solver`` to amortize its factorization
    across requests (the serve layer does), else it is built here with
    ``f.noise`` interpreted as the EFFECTIVE noise sigma^2/s^2 (the core
    convention for ``GramFactors``) and ``signal`` scaling the prior.
    The solver is a factorization of the noisy Gram, NOT a re-solve of
    the representer system: ``n_solve`` stays untouched.

    ``precision='bf16'`` streams Xq/Xt/Z in bf16 storage (f32 accumulation
    and outputs — the repo precision policy, DESIGN.md sec. 12); the
    default defers to ``backend.resolve_precision()``.
    """
    Xq = jnp.atleast_2d(Xq)
    sd = backend.stream_dtype(precision)
    stream_dt = sd if sd != jnp.float32 else None
    if (return_std or return_grad_std) and solver is None:
        solver = _default_solver(spec, f, signal)
    if not (return_std or return_grad_std):
        solver = None
    q = Xq.shape[0]
    # host-side telemetry only (never from inside a trace — a traced call
    # must not leak per-trace python effects into the registry)
    if _obs.enabled() and not isinstance(Xq, jax.core.Tracer):
        _obs.REGISTRY.inc("query.requests")
        _obs.REGISTRY.inc("query.points", q)
        _obs.REGISTRY.inc(
            "query.microbatches",
            1 if (not microbatch or microbatch >= q) else -(-q // microbatch))
    if not microbatch or microbatch >= q:
        return _query_chunk(spec, Xq, f, Z, probe, solver, return_grad_std,
                            stream_dt)
    chunks = [_query_chunk(spec, Xq[i:i + microbatch], f, Z, probe, solver,
                           return_grad_std, stream_dt)
              for i in range(0, q, microbatch)]
    cat = lambda xs: jnp.concatenate(xs)
    return PosteriorBatch(
        value=cat([c.value for c in chunks]),
        grad=cat([c.grad for c in chunks]),
        hess_v=None if probe is None else cat([c.hess_v for c in chunks]),
        std=None if solver is None else cat([c.std for c in chunks]),
        grad_std=(cat([c.grad_std for c in chunks])
                  if (solver is not None and return_grad_std) else None),
    )


def make_query_fn(spec: KernelSpec, *, with_probe: bool = False,
                  with_std: bool = False, with_grad_std: bool = False):
    """A jittable (f, Z[, solver], Xq[, probe]) -> PosteriorBatch evaluator.

    The factors/Z (and the variance ``GramSolver``, when ``with_std``) are
    *arguments*, not captures, so one compiled function serves every state
    revision of the same shape — extend() between batches never triggers
    recompilation, and because every hyperparameter lives inside the
    solver/factor arrays, neither does a refit (``train/serve.py`` relies
    on this for the streaming serve loop).
    """
    if with_std or with_grad_std:
        if with_probe:
            def fn(f: GramFactors, Z: Array, solver, Xq: Array, probe: Array):
                return _query_chunk(spec, Xq, f, Z, probe, solver,
                                    with_grad_std)
        else:
            def fn(f: GramFactors, Z: Array, solver, Xq: Array):
                return _query_chunk(spec, Xq, f, Z, None, solver,
                                    with_grad_std)
    elif with_probe:
        def fn(f: GramFactors, Z: Array, Xq: Array, probe: Array):
            return _query_chunk(spec, Xq, f, Z, probe)
    else:
        def fn(f: GramFactors, Z: Array, Xq: Array):
            return _query_chunk(spec, Xq, f, Z, None)
    return fn

"""Backend dispatch for the O(ND) hot contractions (DESIGN.md §4).

Every pass over an (N, D) array at D ~ 1e6..1e9 is an HBM roofline event,
so the core inference engine never spells out those contractions in raw
``jnp`` — it routes them through this module, which picks between

  * ``"pallas"``  — the fused TPU kernels in ``repro.kernels`` (interpret
    mode on CPU, so the same code path is CI-testable), and
  * ``"jnp"``     — the plain-jnp oracle forms, bit-identical to the
    pre-dispatch implementation (full precision under x64; used as the
    correctness reference everywhere).

Resolution order: ``set_backend()``/``use_backend()`` > the
``REPRO_BACKEND`` env var > auto (pallas on TPU, jnp elsewhere). The jnp
path accumulates in the input dtype; the pallas path accumulates in f32
(the TPU-native contract) — callers that need x64 semantics must be on the
jnp backend, which is the auto default everywhere x64 exists.

The functions here are the complete vocabulary of O(ND) work in the solve
path: if a core module multiplies something (N, D)-shaped outside this
module, that is a bug (grep-enforced in tests/test_backend_dispatch.py).
"""
from __future__ import annotations

import contextlib
import os
from typing import Iterator

import jax
import jax.numpy as jnp

from repro import kernels as _k
from repro.kernels import ref as _kref

Array = jnp.ndarray

_VALID = ("jnp", "pallas")
_FORCED: str | None = None


def resolve_backend() -> str:
    """The backend the next hot contraction will use: 'jnp' | 'pallas'."""
    if _FORCED is not None:
        return _FORCED
    env = os.environ.get("REPRO_BACKEND", "").strip().lower()
    if env in _VALID:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def set_backend(name: str | None) -> None:
    """Force the backend ('jnp' | 'pallas'); None restores auto-resolution."""
    global _FORCED
    if name is not None and name not in _VALID:
        raise ValueError(f"backend must be one of {_VALID} or None, got {name!r}")
    _FORCED = name


@contextlib.contextmanager
def use_backend(name: str) -> Iterator[None]:
    """Scoped ``set_backend`` — the test suite's parity harness."""
    prev = _FORCED
    set_backend(name)
    try:
        yield
    finally:
        set_backend(prev)


def _pallas() -> bool:
    return resolve_backend() == "pallas"


# ---------------------------------------------------------------------------
# The O(ND) contraction vocabulary
# ---------------------------------------------------------------------------

def scaled_gram(A: Array, B: Array, lam) -> Array:
    """(N_a, N_b) matrix  A Lambda B^T — THE hot contraction of the method."""
    if _pallas():
        return _k.skinny_gram(A, B, lam)
    return (A * lam) @ B.T


def gram_norms(A: Array, B: Array, lam):
    """(P, |A|^2_lam rowwise, |B|^2_lam rowwise) in one logical pass."""
    if _pallas():
        return _k.fused_gram_norms(A, B, lam)
    P = (A * lam) @ B.T
    na = jnp.sum((A * lam) * A, axis=-1)
    nb = jnp.sum((B * lam) * B, axis=-1)
    return P, na, nb


def pairwise_r(spec, A: Array, B: Array, lam, c=None) -> Array:
    """r(x_a, x_b) for all pairs; A: (Na, D), B: (Nb, D) -> (Na, Nb)."""
    if spec.is_stationary:
        g, da, db = gram_norms(A, B, lam)
        return jnp.maximum(da[:, None] + db[None, :] - 2.0 * g, 0.0)
    At = A if c is None else A - c
    Bt = B if c is None else B - c
    return scaled_gram(At, Bt, lam)


def row_dots(A: Array, B: Array, lam) -> Array:
    """sum_d A[:, d] * lam[d] * B[:, d] — one (N,) strip, pure VPU traffic.

    Bandwidth-identical on both backends (a single elementwise pass with an
    axis reduction), so there is no pallas kernel for it.
    """
    return jnp.sum((A * lam) * B, axis=-1)


def gram_update(K1: Array, small: Array, V: Array, X: Array, lam, *,
                v_scale=None, noise: float = 0.0) -> Array:
    """W = (K1 @ (V * v_scale) + small @ X) * lam + noise * V.

    The D-streaming half of Alg. 2 and the workhorse of every exact solve:
    Woodbury's final assembly runs it with v_scale = 1/lam, lam = 1.
    """
    if _pallas():
        return _k.gram_update(K1, small, V, X, lam, v_scale=v_scale,
                              noise=noise)
    Vs = V if v_scale is None else V * v_scale
    W = (K1 @ Vs + small @ X) * lam
    if noise:
        W = W + noise * V
    return W


def kron_precond(K1i: Array, V: Array, lam) -> Array:
    """B^{-1} vec(V) for the free Kronecker preconditioner B = K1e x Lam.

    V may be (N, D) or stacked (R, N, D); K1i is the (N, N) inverse factor.
    """
    if _pallas() and V.ndim == 2:
        return _k.small_matmul(K1i, V, 1.0 / jnp.asarray(lam))
    return (K1i @ V) / lam


def fused_gram_mvm(K1e: Array, K2e: Array, Xt: Array, V: Array, lam, *,
                   stationary: bool, noise: float = 0.0) -> Array:
    """The full Alg.-2 Gram MVM as one fused op (paper Eq. 9).

    Pallas: a single two-phase pallas_call (``kernels.fused_gram_mvm``) —
    two HBM reads of Xt/V, one write of W, zero materialized intermediates.
    jnp: the einsum oracle in f32 accumulation. V (N, D) or stacked
    (R, N, D); the stacked form amortizes the Xt stream across RHS.
    """
    if _pallas():
        return _k.fused_gram_mvm(K1e, K2e, Xt, V, lam, stationary=stationary,
                                 noise=noise)
    # Native-dtype oracle (keeps x64 precision; broadcast over stacked RHS).
    return _kref.gram_mvm_oracle(K1e, K2e, Xt, V, lam, stationary=stationary,
                                 noise=noise)

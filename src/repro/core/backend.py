"""Backend dispatch for the O(ND) hot contractions (DESIGN.md §4).

Every pass over an (N, D) array at D ~ 1e6..1e9 is an HBM roofline event,
so the core inference engine never spells out those contractions in raw
``jnp`` — it routes them through this module, which picks between

  * ``"pallas"``  — the fused TPU kernels in ``repro.kernels`` (interpret
    mode on CPU, so the same code path is CI-testable), and
  * ``"jnp"``     — the plain-jnp oracle forms, bit-identical to the
    pre-dispatch implementation (full precision under x64; used as the
    correctness reference everywhere).

Resolution order: ``set_backend()``/``use_backend()`` > the
``REPRO_BACKEND`` env var > auto (pallas on TPU, jnp elsewhere). The jnp
path accumulates in the input dtype; the pallas path accumulates in f32
(the TPU-native contract) — callers that need x64 semantics must be on the
jnp backend, which is the auto default everywhere x64 exists.

The functions here are the complete vocabulary of O(ND) work in the solve
path: if a core module multiplies something (N, D)-shaped outside this
module, that is a bug (grep-enforced in tests/test_backend_dispatch.py).
"""
from __future__ import annotations

import contextlib
import os
from typing import Iterator

import jax
import jax.numpy as jnp

from repro import kernels as _k
from repro.kernels import ref as _kref

Array = jnp.ndarray

_VALID = ("jnp", "pallas")
_FORCED: str | None = None

_VALID_PRECISION = ("f32", "bf16")
_FORCED_PRECISION: str | None = None


def resolve_backend() -> str:
    """The backend the next hot contraction will use: 'jnp' | 'pallas'."""
    if _FORCED is not None:
        return _FORCED
    env = os.environ.get("REPRO_BACKEND", "").strip().lower()
    if env in _VALID:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def set_backend(name: str | None) -> None:
    """Force the backend ('jnp' | 'pallas'); None restores auto-resolution."""
    global _FORCED
    if name is not None and name not in _VALID:
        raise ValueError(f"backend must be one of {_VALID} or None, got {name!r}")
    _FORCED = name


@contextlib.contextmanager
def use_backend(name: str) -> Iterator[None]:
    """Scoped ``set_backend`` — the test suite's parity harness."""
    prev = _FORCED
    set_backend(name)
    try:
        yield
    finally:
        set_backend(prev)


def _pallas() -> bool:
    return resolve_backend() == "pallas"


# ---------------------------------------------------------------------------
# Precision policy (DESIGN.md sec. 12): bf16 STORAGE, f32 ACCUMULATION.
#
# The method's hot paths are memory-bound streams over (N, D) data, so the
# input dtype — not the math — sets the wall clock.  The policy has exactly
# three rules:
#   1. (N, D) stream operands (X, G, Z, queries) MAY be stored/streamed
#      bf16; halving their bytes halves the HBM roofline of every sweep.
#   2. every contraction accumulates in f32 (``preferred_element_type`` in
#      the Pallas kernels; an explicit upcast on the jnp fallback so the
#      oracle path never silently accumulates in bf16).
#   3. all factor outputs (grams, norms, K1e/K2e, solves Z) stay f32 —
#      results are never rounded back to storage precision.
# ``resolve_precision`` is a session knob consumed by the state/serve
# layers when casting their stream copies; the backend ops themselves are
# polymorphic (they accept whatever storage dtype the caller holds).
# ---------------------------------------------------------------------------

def resolve_precision() -> str:
    """The storage precision streams default to: 'f32' | 'bf16'."""
    if _FORCED_PRECISION is not None:
        return _FORCED_PRECISION
    env = os.environ.get("REPRO_PRECISION", "").strip().lower()
    if env in _VALID_PRECISION:
        return env
    return "f32"


def set_precision(name: str | None) -> None:
    """Force the stream storage precision; None restores auto-resolution."""
    global _FORCED_PRECISION
    if name is not None and name not in _VALID_PRECISION:
        raise ValueError(
            f"precision must be one of {_VALID_PRECISION} or None, got {name!r}")
    _FORCED_PRECISION = name


@contextlib.contextmanager
def use_precision(name: str) -> Iterator[None]:
    """Scoped ``set_precision``."""
    prev = _FORCED_PRECISION
    set_precision(name)
    try:
        yield
    finally:
        set_precision(prev)


def stream_dtype(precision: str | None = None):
    """The jnp dtype of (N, D) stream storage under ``precision``."""
    p = resolve_precision() if precision is None else precision
    if p not in _VALID_PRECISION:
        raise ValueError(f"precision must be one of {_VALID_PRECISION}, got {p!r}")
    return jnp.bfloat16 if p == "bf16" else jnp.float32


def _acc(x: Array) -> Array:
    """Accumulation-dtype view: upcast sub-f32 storage so the jnp fallback
    matches the kernels' bf16-in/f32-accum contract (rule 2 above)."""
    x = jnp.asarray(x)
    return x.astype(jnp.float32) if x.dtype in (jnp.bfloat16, jnp.float16) else x


# ---------------------------------------------------------------------------
# The O(ND) contraction vocabulary
# ---------------------------------------------------------------------------

def scaled_gram(A: Array, B: Array, lam) -> Array:
    """(N_a, N_b) matrix  A Lambda B^T — THE hot contraction of the method."""
    if _pallas():
        return _k.skinny_gram(A, B, lam)
    A, B = _acc(A), _acc(B)
    return (A * lam) @ B.T


def gram_norms(A: Array, B: Array, lam):
    """(P, |A|^2_lam rowwise, |B|^2_lam rowwise) in one logical pass."""
    if _pallas():
        return _k.fused_gram_norms(A, B, lam)
    A, B = _acc(A), _acc(B)
    P = (A * lam) @ B.T
    na = jnp.sum((A * lam) * A, axis=-1)
    nb = jnp.sum((B * lam) * B, axis=-1)
    return P, na, nb


def fused_factor_build(A: Array, B: Array, V: Array | None, lam, *,
                       v_scale=1.0):
    """The single-sweep factor bundle (P, na, nb, C, tv) — DESIGN.md sec. 12.

    ONE pass over A/B/V emits every skinny factor of a solve or query
    microbatch: P = (A*lam) @ B^T, lam-weighted row norms na/nb,
    C = (V*v_scale) @ A^T, tv = rowdots(B, V, lam).  On the pallas backend
    this is a single ``kernels.fused_factor_build`` launch; the jnp form
    spells out the same contractions (XLA is free to fuse them, and the
    x64 oracle semantics are preserved for f32/f64 inputs).
    """
    if _pallas():
        return _k.fused_factor_build(A, B, V, lam, v_scale=v_scale)
    A, B = _acc(A), _acc(B)
    V = B if V is None else _acc(V)
    P = (A * lam) @ B.T
    na = jnp.sum((A * lam) * A, axis=-1)
    nb = jnp.sum((B * lam) * B, axis=-1)
    C = (V * v_scale) @ A.T
    tv = jnp.sum((B * lam) * V, axis=-1)
    return P, na, nb, C, tv


def pairwise_r(spec, A: Array, B: Array, lam, c=None) -> Array:
    """r(x_a, x_b) for all pairs; A: (Na, D), B: (Nb, D) -> (Na, Nb)."""
    if spec.is_stationary:
        g, da, db = gram_norms(A, B, lam)
        return jnp.maximum(da[:, None] + db[None, :] - 2.0 * g, 0.0)
    At = A if c is None else A - c
    Bt = B if c is None else B - c
    return scaled_gram(At, Bt, lam)


def row_dots(A: Array, B: Array, lam) -> Array:
    """sum_d A[:, d] * lam[d] * B[:, d] — one (N,) strip, pure VPU traffic.

    Bandwidth-identical on both backends (a single elementwise pass with an
    axis reduction), so there is no pallas kernel for it.
    """
    A, B = _acc(A), _acc(B)
    return jnp.sum((A * lam) * B, axis=-1)


def gram_update(K1: Array, small: Array, V: Array, X: Array, lam, *,
                v_scale=None, noise: float = 0.0) -> Array:
    """W = (K1 @ (V * v_scale) + small @ X) * lam + noise * V.

    The D-streaming half of Alg. 2 and the workhorse of every exact solve:
    Woodbury's final assembly runs it with v_scale = 1/lam, lam = 1.
    """
    if _pallas():
        return _k.gram_update(K1, small, V, X, lam, v_scale=v_scale,
                              noise=noise)
    V, X = _acc(V), _acc(X)
    Vs = V if v_scale is None else V * v_scale
    W = (_acc(K1) @ Vs + _acc(small) @ X) * lam
    if noise:
        W = W + noise * V
    return W


def kron_precond(K1i: Array, V: Array, lam) -> Array:
    """B^{-1} vec(V) for the free Kronecker preconditioner B = K1e x Lam.

    V may be (N, D) or stacked (R, N, D); K1i is the (N, N) inverse factor.
    """
    if _pallas() and V.ndim == 2:
        return _k.small_matmul(K1i, V, 1.0 / jnp.asarray(lam))
    return (_acc(K1i) @ _acc(V)) / lam


def fused_gram_mvm(K1e: Array, K2e: Array, Xt: Array, V: Array, lam, *,
                   stationary: bool, noise: float = 0.0) -> Array:
    """The full Alg.-2 Gram MVM as one fused op (paper Eq. 9).

    Pallas: a single two-phase pallas_call (``kernels.fused_gram_mvm``) —
    two HBM reads of Xt/V, one write of W, zero materialized intermediates.
    jnp: the einsum oracle in f32 accumulation. V (N, D) or stacked
    (R, N, D); the stacked form amortizes the Xt stream across RHS.
    """
    if _pallas():
        return _k.fused_gram_mvm(K1e, K2e, Xt, V, lam, stationary=stationary,
                                 noise=noise)
    # Native-dtype oracle (keeps x64 precision; broadcast over stacked RHS);
    # bf16 storage upcasts first so accumulation stays f32 (precision rule 2).
    return _kref.gram_mvm_oracle(_acc(K1e), _acc(K2e), _acc(Xt), _acc(V),
                                 lam, stationary=stationary, noise=noise)

"""D-sharded incremental posterior state: the mesh-parallel state machine.

``core/distributed.py`` proved the communication story for ONE-SHOT solves:
every O(D) object of the paper's decomposition only ever appears inside
tall-skinny contractions that reduce to (N, N), so sharding the D axis
over the whole mesh costs O(N^2) collective bytes per solve — independent
of D and of device count.  This module extends that scheme to the ENTIRE
incremental pipeline (extend / evict / resolve / refit / query) with a
stronger invariant: **at most ONE fused psum per phase**, and several
phases with none at all.

The trick is what the state carries.  Alongside the local (cap, D_loc)
shards of X/G/Xt/Z, :class:`SGPGData` maintains three replicated UNSCALED
(cap, cap) strips

    S0 = X~ X~^T        (lambda-free!)
    C  = G  X~^T
    GG = G  G^T

which are exactly the reductions every downstream phase needs:

  extend    — the border of all three strips against the new (x, g) row is
              four cap-vectors of local partials, psummed ONCE as a fused
              tuple (O(N) bytes!).  The kernel border columns, the bordered
              Cholesky append and the degraded-pivot O(N^3) fallback are
              replicated (N, N) algebra — no further collective.
  solve     — the exact Woodbury solve re-associates its two historical
              psums away: S = lam * S0 and T0 = K1i @ (rhs X~^T) = K1i @ C
              come straight off the strips, the (N^2, N^2) inner system is
              replicated, and the output assembly is one purely local
              ``backend.gram_update`` launch.  ZERO psums (the per-extend
              warm CG of the single-device path would cost one psum PER
              ITERATION — the direct solve is the communication-optimal
              choice here).
  evict     — row surgery on local shards + replicated strips.  ZERO psums.
  refactor  — a lengthscale change re-derives r from S0 (stationary
              r = lam*(d0_a + d0_b - 2 S0); dot r = lam*S0).  ZERO psums.
  resolve   — a NEW right-hand side needs C_rhs = psum(rhs_loc X~_loc^T):
              ONE psum of one (N, N) matrix.
  refit     — the entire MLL hyper-fit runs off the maintained strips
              (``hyper.mll.mll_from_strips``), replicated: ZERO psums for
              any number of fit steps.
  query     — one fused psum of the 5-tuple of cross strips per microbatch
              (``core.query._mean_strips``), then the replicated value and
              the local (Q, D_loc) grad assembly.  A ring (ppermute)
              variant overlaps the reduction of chunk i with the local
              compute of chunk i+1 (Megatron-style pipelining).

Scalar Lambda only: the unscaled-S0 maintenance is what buys the zero-psum
refactor/refit, and it requires lam to fold out of the strips (the paper's
own experiments are isotropic; ``core/woodbury.py`` has the same exact-path
restriction).

All ``sgpg_*`` functions are pure and written for use INSIDE shard_map
(local shards in, explicit psums over ``axis_names``).  The host-facing
:class:`ShardedGPGState` mirrors the ``GPGState`` API: it builds the mesh
program once per shape (``obs/compile_watch.wrap`` — compile-stable across
extend/evict/refit because count/noise are traced arguments), pads D to a
multiple of the device count (zero columns are exactly inert in every
strip), and serves posterior mean value/grad batches.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.scipy.linalg import cho_solve
from jax.sharding import PartitionSpec as P

from repro.obs import compile_watch as _cw
from repro.obs import trace as _obs

from . import backend
from .distributed import _shard_map, ring_psum
from .gram import GramFactors
from .kernels import KernelSpec, get_kernel
from .mvm import l_op, lt_op
from .query import _mean_assemble, _mean_strips
from .state import (GPGData, _chol_append, _row_mask, gpg_evict as
                    _base_evict, gpg_init)

Array = jnp.ndarray


class SGPGData(NamedTuple):
    """Sharded incremental state: local (cap, D_loc) shards + replicated
    (cap, cap) strips.

    base: a ``GPGData`` whose X/G/Xt/Z are LOCAL shards (inside shard_map)
          or D-sharded global arrays (outside); K1e/K2e/L and the counters
          are replicated.  ``base.c``, when present, is sharded like X.
    S0/C/GG: the replicated UNSCALED strips (see module docstring); rows
          and columns >= count are zero.
    """

    base: GPGData
    S0: Array
    C: Array
    GG: Array

    @property
    def capacity(self) -> int:
        return self.base.capacity

    @property
    def count(self) -> Array:
        return self.base.count


def sgpg_init(spec: KernelSpec, d: int, capacity: int, *, lam=1.0,
              c: Optional[Array] = None, dtype=None) -> SGPGData:
    """Empty sharded state (``d`` is the PADDED global dimension)."""
    base = gpg_init(spec, d, capacity, lam=lam, c=c, dtype=dtype)
    if jnp.asarray(base.lam).ndim != 0:
        raise ValueError("the D-sharded state requires scalar (isotropic) "
                         "Lambda — the unscaled-strip maintenance that buys "
                         "the zero-psum refactor/refit folds lam out of S0")
    znn = jnp.zeros((capacity, capacity), base.X.dtype)
    return SGPGData(base=base, S0=znn, C=znn, GG=znn)


# ---------------------------------------------------------------------------
# Internals (replicated algebra; no collectives)
# ---------------------------------------------------------------------------


def _full_chol_t(base: GPGData, noise, jitter: float) -> Array:
    """``state._full_chol`` with a TRACED noise scalar (no recompile when
    the host refit changes the noise)."""
    mask = _row_mask(base)
    shift = jnp.asarray(noise) / jnp.asarray(base.lam) + jitter
    K1n = base.K1e + jnp.diag(jnp.where(mask, shift, 1.0))
    L = jnp.linalg.cholesky(K1n)
    bad = ~jnp.all(jnp.isfinite(L))
    tr = jnp.trace(K1n) / jnp.maximum(base.count, 1)
    K1r = K1n + jnp.diag(jnp.where(mask, 1e-6 * tr, 0.0))
    return jnp.where(bad, jnp.linalg.cholesky(K1r), L)


def _r_from_strips(spec: KernelSpec, S0: Array, lam) -> Array:
    """Pairwise r of the whole window from the UNSCALED S0 strip."""
    if spec.is_stationary:
        d0 = jnp.diagonal(S0)
        return lam * jnp.maximum(d0[:, None] + d0[None, :] - 2.0 * S0, 0.0)
    return lam * S0


def sgpg_direct_solve(
    spec: KernelSpec,
    data: SGPGData,
    *,
    noise=0.0,
    jitter: float = 1e-10,
    rhs: Optional[Array] = None,
    C_rhs: Optional[Array] = None,
) -> SGPGData:
    """Exact Woodbury solve off the maintained strips — ZERO collectives.

    The two (N, N) psums of ``distributed.local_woodbury_solve`` are
    re-associated away: S = lam * S0, and the inner right-hand side
    T0 = (K1i rhs) X~^T = K1i @ C_rhs with C_rhs = rhs X~^T = the
    maintained C when rhs is the stored G (default).  The padded algebra
    is made exact by MASKING the inner operator (not just its inputs):
    with ``inner(Q) = where(mm, F(where(mm, Q, 0)), Q)`` the (N^2, N^2)
    system is block-diagonal [[A_vv, 0], [0, I]], so the embedded
    valid-block solution IS the unpadded solution.  (The naive unmasked
    padded system is NOT equivalent: ``lt_op`` writes M[a, a] into padded
    columns, which the unmasked A would constrain against garbage.)

    ``rhs``: local (cap, D_loc) right-hand side, default ``base.G``; rows
    >= count must be zero.  ``C_rhs``: its replicated (cap, cap) strip
    rhs @ X~^T — REQUIRED whenever rhs is not the stored G (the resolve
    phase psums it; extend fuses it into the border psum).
    """
    b = data.base
    cap = b.capacity
    dtype = b.K1e.dtype
    lam = jnp.asarray(b.lam)
    mask = _row_mask(b)
    mm = mask[:, None] & mask[None, :]

    # L factorizes K1n = K1e + (noise/lam + jitter) I with an identity
    # tail, so K1i is block-diagonal: exact inverse on the valid block.
    K1i = cho_solve((b.L, True), jnp.eye(cap, dtype=dtype))
    S = lam * jnp.where(mm, data.S0, 0.0)
    K2m = jnp.where(mm, b.K2e, 1.0)  # padded entries divide by 1, not 0

    if rhs is None:
        rhs = b.G
    if C_rhs is None:
        C_rhs = data.C
    T0 = K1i @ jnp.where(mm, C_rhs, 0.0)
    T = jnp.where(mm, lt_op(T0) if spec.is_stationary else T0, 0.0)

    if spec.is_stationary:
        def F(Q):
            return -Q.T / K2m + lt_op(K1i @ l_op(Q) @ S)
    else:
        def F(Q):
            return Q.T / K2m + K1i @ Q @ S

    def inner(Q):
        return jnp.where(mm, F(jnp.where(mm, Q, 0.0)), Q)

    eye = jnp.eye(cap * cap, dtype=dtype).reshape(cap * cap, cap, cap)
    A = jax.vmap(inner)(eye).reshape(cap * cap, cap * cap).T
    q = jnp.linalg.solve(A + jitter * jnp.eye(cap * cap, dtype=dtype),
                         T.reshape(-1))
    Q = q.reshape(cap, cap)

    QL = l_op(Q) if spec.is_stationary else Q
    Z = backend.gram_update(K1i, -(K1i @ QL), rhs, b.Xt, 1.0,
                            v_scale=1.0 / lam)
    Z = jnp.where(mask[:, None] & jnp.isfinite(Z), Z, 0.0)
    b = b._replace(Z=Z, n_solve=b.n_solve + 1,
                   cg_iters=jnp.zeros((), jnp.int32),
                   resnorm=jnp.zeros((), b.resnorm.dtype))
    return data._replace(base=b)


# ---------------------------------------------------------------------------
# The phase functions (called INSIDE shard_map)
# ---------------------------------------------------------------------------


def sgpg_extend(
    spec: KernelSpec,
    data: SGPGData,
    x: Array,
    g: Array,
    *,
    axis_names,
    noise=0.0,
    jitter: float = 1e-10,
    deg_thresh: float = 1e-8,
    solve: bool = True,
    rhs: Optional[Array] = None,
    extra_partials=None,
):
    """Append one observation: ONE fused psum of O(N)-byte border partials.

    ``x``/``g`` (and the optional ``rhs`` override) are LOCAL (D_loc,) /
    (cap, D_loc) shards.  The psum carries the four border cap-vectors of
    the strips (s0_col, c_col, c_row, gg_col), the rhs strip when ``rhs``
    is given, and any caller ``extra_partials`` pytree (the optimizer step
    fuses its direction reductions here) — still one collective.

    Returns ``(data, extras)`` where ``extras`` is the psummed
    ``extra_partials`` (None if not given).
    """
    b = data.base
    cap = b.capacity
    n = b.count
    x = jnp.asarray(x, b.X.dtype)
    g = jnp.asarray(g, b.X.dtype)
    xt_new = x if (spec.is_stationary or b.c is None) else x - b.c

    Xt_p = b.Xt.at[n].set(xt_new)
    G_p = b.G.at[n].set(g)
    # Local border partials: [x~_new; g] against the appended strips.
    pair = jnp.stack([xt_new, g])
    S2 = backend.scaled_gram(pair, Xt_p, 1.0)   # rows: x~_new.x~_b, g.x~_b
    G2 = backend.scaled_gram(pair, G_p, 1.0)    # rows: x~_new.g_a, g.g_a
    parts = (S2, G2)
    if rhs is not None:
        parts = parts + (backend.scaled_gram(rhs, Xt_p, 1.0),)
    if extra_partials is not None:
        parts = parts + (extra_partials,)
    parts = jax.lax.psum(parts, axis_names)     # the ONE extend collective
    S2, G2 = parts[0], parts[1]
    C_rhs = parts[2] if rhs is not None else None
    extras = parts[-1] if extra_partials is not None else None

    s0_col, c_col = S2[0], S2[1]                # S0[:, n] and C[n, :]
    c_row, gg_col = G2[0], G2[1]                # C[:, n] and GG[:, n]
    S0 = data.S0.at[n, :].set(s0_col).at[:, n].set(s0_col)
    C = data.C.at[n, :].set(c_col).at[:, n].set(c_row)
    GG = data.GG.at[n, :].set(gg_col).at[:, n].set(gg_col)

    # Border kernel columns from the replicated strip border (state._border
    # math, minus its D-streaming sweep — the strips already paid it).
    lam = jnp.asarray(b.lam)
    mask_pre = jnp.arange(cap) < n
    if spec.is_stationary:
        d0 = jnp.diagonal(S0)
        r_col = lam * jnp.maximum(d0 + s0_col[n] - 2.0 * s0_col, 0.0)
        r_self = jnp.zeros((), x.dtype)
    else:
        r_col = lam * s0_col
        r_self = lam * s0_col[n]
    k1_col = jnp.where(mask_pre, spec.k1e(r_col), 0.0)
    k2_col = jnp.where(mask_pre, spec.k2e(r_col), 0.0)
    k1_diag = spec.k1e(r_self)
    shift = jnp.asarray(noise) / lam + jitter

    K1e = b.K1e.at[n, :].set(k1_col).at[:, n].set(k1_col)
    K1e = K1e.at[n, n].set(k1_diag)
    K2e = b.K2e.at[n, :].set(k2_col).at[:, n].set(k2_col)
    K2e = K2e.at[n, n].set(spec.k2e(r_self))
    b = b._replace(X=b.X.at[n].set(x), G=G_p, Xt=Xt_p, K1e=K1e, K2e=K2e,
                   count=n + 1)

    L_new, degraded, _ = _chol_append(b.L, k1_col, k1_diag + shift, n,
                                      deg_thresh)
    b = jax.lax.cond(
        degraded,
        lambda d: d._replace(L=_full_chol_t(d, noise, jitter),
                             n_refactor=d.n_refactor + 1),
        lambda d: d._replace(L=L_new),
        b,
    )
    data = data._replace(base=b, S0=S0, C=C, GG=GG)
    if solve:
        data = sgpg_direct_solve(spec, data, noise=noise, jitter=jitter,
                                 rhs=rhs, C_rhs=C_rhs)
    return data, extras


def sgpg_evict(
    spec: KernelSpec,
    data: SGPGData,
    *,
    noise=0.0,
    jitter: float = 1e-10,
    solve: bool = True,
) -> SGPGData:
    """Drop the oldest observation: pure row surgery, ZERO collectives."""
    n = data.base.count
    cap = data.base.capacity
    keep = jnp.arange(cap) < jnp.maximum(n - 1, 0)
    kmm = keep[:, None] & keep[None, :]

    def upleft(A):
        return jnp.where(kmm, jnp.roll(jnp.roll(A, -1, 0), -1, 1), 0.0)

    base = _base_evict(spec, data.base, solve=False)
    data = data._replace(base=base, S0=upleft(data.S0), C=upleft(data.C),
                         GG=upleft(data.GG))
    if solve:
        data = sgpg_direct_solve(spec, data, noise=noise, jitter=jitter)
    return data


def sgpg_refactor(
    spec: KernelSpec,
    data: SGPGData,
    lam=None,
    *,
    noise=0.0,
    jitter: float = 1e-10,
    solve: bool = True,
) -> SGPGData:
    """Lengthscale refresh: r re-derived from the UNSCALED S0 strip.

    ZERO collectives — this is the payoff of storing S0 lambda-free: a
    refit's refactorization is replicated (N, N) algebra, where the
    single-device path re-streams the whole (N, D) window.
    """
    b = data.base
    if lam is not None:
        b = b._replace(lam=jnp.asarray(lam, b.X.dtype))
    mask = _row_mask(b)
    mm = mask[:, None] & mask[None, :]
    r = _r_from_strips(spec, data.S0, jnp.asarray(b.lam))
    b = b._replace(K1e=jnp.where(mm, spec.k1e(r), 0.0),
                   K2e=jnp.where(mm, spec.k2e(r), 0.0),
                   n_refactor=b.n_refactor + 1)
    b = b._replace(L=_full_chol_t(b, noise, jitter))
    data = data._replace(base=b)
    if solve:
        data = sgpg_direct_solve(spec, data, noise=noise, jitter=jitter)
    return data


def sgpg_resolve(
    spec: KernelSpec,
    data: SGPGData,
    rhs: Array,
    *,
    axis_names,
    noise=0.0,
    jitter: float = 1e-10,
) -> SGPGData:
    """Solve against a NEW local rhs shard: ONE psum of its (N, N) strip."""
    b = data.base
    mask = _row_mask(b)
    rhs = jnp.where(mask[:, None], jnp.asarray(rhs, b.X.dtype), 0.0)
    C_rhs = jax.lax.psum(backend.scaled_gram(rhs, b.Xt, 1.0), axis_names)
    return sgpg_direct_solve(spec, data, noise=noise, jitter=jitter,
                             rhs=rhs, C_rhs=C_rhs)


def sgpg_rebuild(
    spec: KernelSpec,
    data: SGPGData,
    *,
    axis_names,
    noise=0.0,
    jitter: float = 1e-10,
    solve: bool = True,
) -> SGPGData:
    """Bulk (re)build of all three strips from the local shards: ONE fused
    psum (bulk conditioning / ``from_data``), then the zero-psum refactor
    path rebuilds factors, Cholesky and the solve."""
    b = data.base
    mask = _row_mask(b)
    Xt = jnp.where(mask[:, None], b.Xt, 0.0)
    G = jnp.where(mask[:, None], b.G, 0.0)
    P_, _, _, C, _ = backend.fused_factor_build(Xt, Xt, G, 1.0)
    GGp = backend.scaled_gram(G, G, 1.0)
    S0, C, GG = jax.lax.psum((P_, C, GGp), axis_names)
    data = data._replace(base=b._replace(Xt=Xt, G=G), S0=S0, C=C, GG=GG)
    return sgpg_refactor(spec, data, noise=noise, jitter=jitter, solve=solve)


def sgpg_posterior_mean(
    spec: KernelSpec,
    data: SGPGData,
    Xq: Array,
    *,
    axis_names,
):
    """Posterior mean value/grad at local (Q, D_loc) query rows.

    ONE fused psum of the 5-tuple of cross strips (``query._mean_strips``
    run on the local shard), then the replicated value and the local
    (Q, D_loc) grad assembly — exactly the single-device ``_mean_chunk``
    split at its reduction boundary.
    """
    b = data.base
    Xq = jnp.asarray(Xq, b.X.dtype)
    if not spec.is_stationary and b.c is not None:
        Xq = Xq - b.c
    f = GramFactors(K1e=b.K1e, K2e=b.K2e, Xt=b.Xt, lam=b.lam, c=None)
    strips = jax.lax.psum(_mean_strips(Xq, f, b.Z), axis_names)
    return _mean_assemble(spec, strips, Xq, f, b.Z)


def sgpg_posterior_mean_pipelined(
    spec: KernelSpec,
    data: SGPGData,
    Xq: Array,
    *,
    axis_name: str,
    axis_size: int,
    chunks: int,
):
    """Chunked query with ring-reduced strips (Megatron-style overlap).

    The psum of chunk i's strips is replaced by a ``ppermute`` ring
    reduction carried OUT of chunk i's scan step: chunk i+1's local factor
    sweep has no data dependence on the in-flight ring hops, so XLA's
    latency-hiding scheduler overlaps collective and compute.  Requires a
    flat one-axis mesh (``launch.mesh.make_d_mesh``) and Q divisible by
    ``chunks``; numerics are identical to :func:`sgpg_posterior_mean` up
    to summation order.
    """
    b = data.base
    Xq = jnp.asarray(Xq, b.X.dtype)
    if not spec.is_stationary and b.c is not None:
        Xq = Xq - b.c
    f = GramFactors(K1e=b.K1e, K2e=b.K2e, Xt=b.Xt, lam=b.lam, c=None)
    q = Xq.shape[0]
    if q % chunks:
        raise ValueError(f"Q={q} not divisible by chunks={chunks}")
    Xqc = Xq.reshape(chunks, q // chunks, Xq.shape[1])

    def assemble(strips_local, xq):
        strips = ring_psum(strips_local, axis_name, axis_size)
        return _mean_assemble(spec, strips, xq, f, b.Z)

    if chunks == 1:
        return assemble(_mean_strips(Xqc[0], f, b.Z), Xqc[0])

    def body(carry, xq):
        prev_strips, prev_xq = carry
        out = assemble(prev_strips, prev_xq)     # ring hops for chunk i
        cur = _mean_strips(xq, f, b.Z)           # local sweep of chunk i+1
        return (cur, xq), out

    first = (_mean_strips(Xqc[0], f, b.Z), Xqc[0])
    (last_strips, last_xq), outs = jax.lax.scan(body, first, Xqc[1:])
    v_last, g_last = assemble(last_strips, last_xq)
    value = jnp.concatenate([outs[0].reshape(-1), v_last])
    grad = jnp.concatenate([outs[1].reshape(-1, Xq.shape[1]), g_last])
    return value, grad


# ---------------------------------------------------------------------------
# Communication-volume model (the claim BENCH_distributed.json checks)
# ---------------------------------------------------------------------------

#: psum launches per phase — the jaxpr gate contract (utils.hlo.count_psums)
PHASE_PSUMS = {
    "extend": 1, "evict": 0, "refactor": 0, "resolve": 1, "rebuild": 1,
    "query": 1, "solve": 0, "refit": 0,
}


def psum_bytes(phase: str, *, cap: int, q: int = 0, itemsize: int = 4,
               with_rhs: bool = False) -> int:
    """Analytic per-device collective bytes of one phase.

    All-reduce result bytes (what ``utils.hlo.collective_bytes`` counts):
    O(N^2) at worst, O(N) for extend — NEVER a function of D or of the
    device count.  This model feeds the ``collective.psum_bytes`` gauge
    and the BENCH_distributed claim gate.
    """
    if phase == "extend":
        n = 2 * 2 * cap + (cap * cap if with_rhs else 0)  # S2 + G2 (+ rhs)
        return n * itemsize
    if phase == "resolve":
        return cap * cap * itemsize
    if phase == "rebuild":
        return 3 * cap * cap * itemsize
    if phase == "query":
        # fused 5-tuple: P (q, cap), na (q,), nb (cap,), C (cap, q), tz (cap,)
        return (2 * q * cap + q + 2 * cap) * itemsize
    if phase in ("evict", "refactor", "solve", "refit"):
        return 0
    raise ValueError(f"unknown phase {phase!r}")


# ---------------------------------------------------------------------------
# Host-facing wrapper (mirrors GPGState; one compiled program per phase)
# ---------------------------------------------------------------------------


def _base_specs(names: tuple, has_c: bool) -> GPGData:
    dn = P(None, names)
    r = P()
    return GPGData(X=dn, G=dn, Xt=dn, K1e=r, K2e=r, L=r, Z=dn, lam=r,
                   count=r, n_refactor=r, n_solve=r, cg_iters=r, resnorm=r,
                   c=(P(names) if has_c else None))


class ShardedGPGState:
    """A D-sharded ``GPGState``: stream observations on a device mesh.

    >>> mesh = make_d_mesh()                      # all local devices
    >>> st = ShardedGPGState("rbf", d=2**16, window=8, mesh=mesh,
    ...                      lam=1e-4, noise=1e-8)
    >>> st.extend(x, g)        # ONE O(N)-byte fused psum + replicated algebra
    >>> pb = st.posterior(Xq)  # ONE O(QN)-byte fused psum per microbatch

    D is padded to a multiple of the mesh size (zero columns are exactly
    inert: they contribute zero to every strip and carry zero gradients);
    queries/outputs are transparently padded/trimmed.  Posterior serves the
    MEAN value/grad paths; probe/std queries require the (N, D)-resident
    variance solver and stay on the single-device state.

    Compile stability: every phase is ONE ``compile_watch``-wrapped jitted
    shard_map program, with count and noise as traced arguments — extends,
    evicts and refits never retrace (asserted in tests/test_dist_state.py).
    """

    def __init__(
        self,
        kernel: str | KernelSpec = "rbf",
        d: int | None = None,
        *,
        mesh=None,
        capacity: int = 8,
        window: int | None = None,
        lam=1.0,
        noise: float = 0.0,
        signal: float = 1.0,
        c=None,
        jitter: float = 1e-10,
        deg_thresh: float = 1e-8,
        dtype=None,
    ):
        if d is None:
            raise TypeError("ShardedGPGState needs the input dimension d")
        if mesh is None:
            from repro.launch.mesh import make_d_mesh

            mesh = make_d_mesh()
        self.spec = get_kernel(kernel) if isinstance(kernel, str) else kernel
        self.mesh = mesh
        self._names = tuple(mesh.axis_names)
        self.ndev = int(mesh.size)
        self.d_orig = int(d)
        self.d_pad = -(-self.d_orig // self.ndev) * self.ndev
        self.noise = float(noise)
        self.signal = float(signal)
        self.jitter = float(jitter)
        self.deg_thresh = float(deg_thresh)
        self.window = int(window) if window else None
        cap = self.window if self.window else int(capacity)
        if c is not None:
            c = jnp.pad(jnp.asarray(c, dtype), (0, self.d_pad - self.d_orig))
        self.data = sgpg_init(self.spec, self.d_pad, cap, lam=lam, c=c,
                              dtype=dtype)
        self.revision = 0
        self._fns: dict = {}
        self._query_fns: dict = {}
        self._query_raws: dict = {}
        if _obs.enabled():
            _obs.REGISTRY.inc("distributed.extend_calls", 0)

    # -- compiled phase programs (built once per shape) --------------------

    def _data_spec(self) -> SGPGData:
        has_c = self.data.base.c is not None
        r = P()
        return SGPGData(base=_base_specs(self._names, has_c), S0=r, C=r,
                        GG=r)

    def _phase(self, name: str):
        """The compiled shard_map program for one phase (cached)."""
        fn = self._fns.get(name)
        if fn is not None:
            return fn
        spec = self.spec
        names = self._names
        dspec = self._data_spec()
        vec = P(names)
        dn = P(None, names)
        jitter, deg = self.jitter, self.deg_thresh

        if name == "extend":
            def raw(data, x, g, noise):
                out, _ = sgpg_extend(spec, data, x, g, axis_names=names,
                                     noise=noise, jitter=jitter,
                                     deg_thresh=deg)
                return out
            in_specs = (dspec, vec, vec, P())
        elif name == "evict":
            def raw(data, noise):
                return sgpg_evict(spec, data, noise=noise, jitter=jitter)
            in_specs = (dspec, P())
        elif name == "refactor":
            def raw(data, lam, noise):
                return sgpg_refactor(spec, data, lam, noise=noise,
                                     jitter=jitter)
            in_specs = (dspec, P(), P())
        elif name == "resolve":
            def raw(data, rhs, noise):
                return sgpg_resolve(spec, data, rhs, axis_names=names,
                                    noise=noise, jitter=jitter)
            in_specs = (dspec, dn, P())
        elif name == "rebuild":
            def raw(data, noise):
                return sgpg_rebuild(spec, data, axis_names=names,
                                    noise=noise, jitter=jitter)
            in_specs = (dspec, P())
        else:
            raise KeyError(name)

        sm = _shard_map(raw, mesh=self.mesh, in_specs=in_specs,
                        out_specs=dspec, check_rep=False)
        fn = _cw.wrap(sm, name=f"distributed.{name}")
        self._fns[name] = fn
        return fn

    def _query_fn(self, q: int, chunks: Optional[int]):
        key = (q, chunks)
        fn = self._query_fns.get(key)
        if fn is not None:
            return fn
        spec = self.spec
        names = self._names
        dspec = self._data_spec()
        dn = P(None, names)
        if chunks is None:
            def raw(data, Xq):
                return sgpg_posterior_mean(spec, data, Xq, axis_names=names)
        else:
            if len(names) != 1:
                raise ValueError("pipelined queries need a flat one-axis "
                                 "mesh (launch.mesh.make_d_mesh)")
            axis, size = names[0], self.ndev

            def raw(data, Xq):
                return sgpg_posterior_mean_pipelined(
                    spec, data, Xq, axis_name=axis, axis_size=size,
                    chunks=chunks)
        sm = _shard_map(raw, mesh=self.mesh, in_specs=(dspec, dn),
                        out_specs=(P(), dn), check_rep=False)
        fn = _cw.wrap(sm, name=f"distributed.query.q{q}"
                      + (f".pipe{chunks}" if chunks else ""))
        self._query_fns[key] = fn
        self._query_raws[key] = sm
        return fn

    def _query_raw(self, q: int, chunks: Optional[int] = None):
        """The UNWRAPPED shard_map query program (for ``obs.cost.modeled``
        — a model lowering must never hit the compile-watched entry)."""
        self._query_fn(q, chunks)
        return self._query_raws[(q, chunks)]

    # -- padding helpers ---------------------------------------------------

    def _pad_cols(self, A: Array) -> Array:
        A = jnp.asarray(A, self.data.base.X.dtype)
        pad = self.d_pad - A.shape[-1]
        if pad == 0:
            return A
        width = [(0, 0)] * (A.ndim - 1) + [(0, pad)]
        return jnp.pad(A, width)

    def _gauge(self, phase: str, q: int = 0):
        if _obs.enabled():
            itemsize = jnp.dtype(self.data.base.X.dtype).itemsize
            _obs.REGISTRY.set_gauge(
                "collective.psum_bytes",
                psum_bytes(phase, cap=self.data.capacity, q=q,
                           itemsize=itemsize))

    # -- streaming updates (GPGState API) ----------------------------------

    @property
    def _noise_eff(self) -> float:
        return self.noise / self.signal

    def extend(self, x: Array, g: Array) -> "ShardedGPGState":
        """Append one observation (auto-evicts at the window bound)."""
        from repro.resilience import guardrails as _guard

        _guard.check_finite(x, g, what="observation")
        with _obs.span("distributed.extend", d=self.d_orig,
                       shards=self.ndev):
            if self.window and self.n >= self.window:
                self.data = self._phase("evict")(
                    self.data, jnp.asarray(0.0))  # solve follows the extend
            elif self.n >= self.data.capacity:
                raise ValueError("capacity exhausted (no window set)")
            self.data = self._phase("extend")(
                self.data, self._pad_cols(jnp.asarray(x)),
                self._pad_cols(jnp.asarray(g)),
                jnp.asarray(self._noise_eff))
            self._gauge("extend")
            if _obs.enabled():
                _obs.REGISTRY.inc("distributed.extend_calls")
                _obs.REGISTRY.set_gauge("state.n", self.n)
        self.revision += 1
        return self

    def evict(self, k: int = 1) -> "ShardedGPGState":
        with _obs.span("distributed.evict", k=k):
            for _ in range(k):
                self.data = self._phase("evict")(
                    self.data, jnp.asarray(self._noise_eff))
            self._gauge("evict")
        self.revision += 1
        return self

    def refactor(self, lam=None) -> "ShardedGPGState":
        with _obs.span("distributed.refactor"):
            lam = self.data.base.lam if lam is None else lam
            self.data = self._phase("refactor")(
                self.data, jnp.asarray(lam, self.data.base.X.dtype),
                jnp.asarray(self._noise_eff))
            self._gauge("refactor")
        self.revision += 1
        return self

    def resolve(self, rhs: Array) -> Array:
        """Solve against a new (n, d) RHS; returns the trimmed global Z."""
        with _obs.span("distributed.resolve"):
            full = jnp.zeros((self.data.capacity, self.d_orig),
                             self.data.base.X.dtype)
            full = full.at[: rhs.shape[0]].set(
                jnp.asarray(rhs, full.dtype))
            self.data = self._phase("resolve")(
                self.data, self._pad_cols(full),
                jnp.asarray(self._noise_eff))
            self._gauge("resolve")
        self.revision += 1
        return self.Z

    @classmethod
    def from_data(cls, kernel, X: Array, G: Array, **kw) -> "ShardedGPGState":
        """Bulk-condition on (X, G): ONE strip-building psum + one solve."""
        X = jnp.atleast_2d(X)
        n, d = X.shape
        kw.setdefault("capacity", max(n, 1))
        st = cls(kernel, d, **kw)
        cap = st.data.capacity
        if n > cap:
            raise ValueError(f"{n} observations exceed capacity={cap}")
        Xp = st._pad_cols(jnp.pad(jnp.asarray(X, st.data.base.X.dtype),
                                  ((0, cap - n), (0, 0))))
        Gp = st._pad_cols(jnp.pad(jnp.asarray(G, st.data.base.X.dtype),
                                  ((0, cap - n), (0, 0))))
        c = st.data.base.c
        Xt = Xp if (st.spec.is_stationary or c is None) else Xp - c[None, :]
        mask = (jnp.arange(cap) < n)[:, None]
        base = st.data.base._replace(X=Xp, G=Gp, Xt=jnp.where(mask, Xt, 0.0),
                                     count=jnp.asarray(n, jnp.int32))
        st.data = st.data._replace(base=base)
        st.data = st._phase("rebuild")(st.data,
                                       jnp.asarray(st._noise_eff))
        st._gauge("rebuild")
        return st

    # -- snapshot/restore (repro.resilience.snapshot) ----------------------

    _SNAP_D = ("X", "G", "Xt", "Z")             # leaves with a D axis
    _SNAP_R = ("K1e", "K2e", "L", "lam", "count", "n_refactor", "n_solve",
               "cg_iters", "resnorm")           # replicated leaves

    def snapshot_arrays(self) -> dict:
        """Host-gathered leaves, D-axes TRIMMED to ``d_orig`` — the
        mesh-independent logical state (pad columns are exactly zero by
        the module contract, so nothing is lost)."""
        import numpy as np

        b = self.data.base
        k = self.d_orig
        out = {f: np.asarray(jax.device_get(getattr(b, f)))[:, :k]
               for f in self._SNAP_D}
        out.update({f: np.asarray(jax.device_get(getattr(b, f)))
                    for f in self._SNAP_R})
        for f in ("S0", "C", "GG"):
            out[f] = np.asarray(jax.device_get(getattr(self.data, f)))
        if b.c is not None:
            out["c"] = np.asarray(jax.device_get(b.c))[:k]
        return out

    def load_snapshot_arrays(self, named: dict) -> "ShardedGPGState":
        """Install snapshot leaves VERBATIM, re-padded for THIS mesh and
        device_put with the phase programs' shardings.

        Restoring factors directly (instead of re-running ``rebuild``)
        is what preserves bit-identity: the live factors were built
        incrementally (bordered updates), and a from-scratch rebuild
        would round differently.  Same-mesh restores are bitwise; a
        different mesh re-pads with zero columns, which are exactly
        inert going forward.
        """
        import numpy as np
        from jax.sharding import NamedSharding

        dspec = self._data_spec()
        dt = self.data.base.X.dtype

        def putD(name, spec):
            a = np.asarray(named[name])
            a = np.pad(a, ((0, 0), (0, self.d_pad - a.shape[1])))
            return jax.device_put(jnp.asarray(a, dt),
                                  NamedSharding(self.mesh, spec))

        def putR(name, leaf, spec):
            a = jnp.asarray(np.asarray(named[name]), leaf.dtype)
            return jax.device_put(a, NamedSharding(self.mesh, spec))

        b = self.data.base
        kw = {f: putD(f, getattr(dspec.base, f)) for f in self._SNAP_D}
        kw.update({f: putR(f, getattr(b, f), getattr(dspec.base, f))
                   for f in self._SNAP_R})
        if b.c is not None and "c" in named:
            c = np.asarray(named["c"])
            c = np.pad(c, (0, self.d_pad - c.shape[0]))
            kw["c"] = jax.device_put(jnp.asarray(c, dt),
                                     NamedSharding(self.mesh, dspec.base.c))
        base = b._replace(**kw)
        self.data = self.data._replace(
            base=base,
            S0=putR("S0", self.data.S0, dspec.S0),
            C=putR("C", self.data.C, dspec.C),
            GG=putR("GG", self.data.GG, dspec.GG))
        self.revision += 1
        return self

    # -- model selection off the maintained strips -------------------------

    @property
    def hypers(self):
        from repro.hyper import HyperParams

        return HyperParams.create(
            lengthscale2=1.0 / float(jnp.asarray(self.data.base.lam)),
            signal=self.signal, noise=max(self.noise, 1e-30))

    def mll(self):
        """Exact MLL of the current window off the strips — ZERO psums."""
        from repro.hyper import mll_from_strips

        if self.n < 1:
            raise ValueError("mll() needs at least one observation")
        return mll_from_strips(self.spec, self.data.S0, self.data.C,
                               self.data.GG, self.d_orig, self.hypers,
                               count=self.data.base.count)

    def refit(self, *, mask=None, steps: int = 150, lr: float = 0.08,
              **fit_kw):
        """MLL-fit the hypers from the maintained strips, then the
        zero-psum refactor.  The whole fit is replicated host compute —
        no collective is issued for ANY number of fit steps."""
        from repro.hyper import fit_fn, make_mll_strips_fn

        if self.n < 2:
            raise ValueError("refit() needs at least two observations")
        with _obs.span("distributed.refit", steps=steps):
            fn = make_mll_strips_fn(
                self.spec, self.data.S0, self.data.C, self.data.GG,
                self.d_orig, count=self.data.base.count)
            res = fit_fn(fn, self.hypers, mask=mask, steps=steps, lr=lr,
                         **fit_kw)
            self.noise = float(res.hypers.noise)
            self.signal = float(res.hypers.signal)
            self.refactor(lam=res.hypers.lam)
        return res

    # -- queries -----------------------------------------------------------

    def posterior(self, Xq: Array, *, chunks: Optional[int] = None,
                  probe=None, return_std: bool = False,
                  return_grad_std: bool = False):
        """Posterior mean value/grad at Xq; ``chunks`` enables the ring-
        pipelined path (flat meshes).  Probe/std paths are not served
        sharded — use the single-device state for those."""
        from .query import PosteriorBatch

        if probe is not None or return_std or return_grad_std:
            raise NotImplementedError(
                "sharded posterior serves mean value/grad only; probe/std "
                "need the (N, D)-resident variance solver (single-device)")
        Xq = jnp.atleast_2d(Xq)
        q = Xq.shape[0]
        with _obs.span("distributed.query", q=q):
            value, grad = self._query_fn(q, chunks)(
                self.data, self._pad_cols(Xq))
            self._gauge("query", q=q)
        return PosteriorBatch(value=value, grad=grad[:, : self.d_orig])

    # -- views -------------------------------------------------------------

    @property
    def n(self) -> int:
        return int(self.data.base.count)

    @property
    def d(self) -> int:
        return self.d_orig

    @property
    def X(self) -> Array:
        return jnp.asarray(self.data.base.X)[: self.n, : self.d_orig]

    @property
    def G(self) -> Array:
        return jnp.asarray(self.data.base.G)[: self.n, : self.d_orig]

    @property
    def Z(self) -> Array:
        return jnp.asarray(self.data.base.Z)[: self.n, : self.d_orig]

    @property
    def stats(self) -> dict:
        b = self.data.base
        return {"n": self.n, "n_refactor": int(b.n_refactor),
                "n_solve": int(b.n_solve), "d_pad": self.d_pad,
                "shards": self.ndev}

    def __repr__(self):
        return (f"ShardedGPGState(kernel={self.spec.name!r}, n={self.n}, "
                f"d={self.d_orig} (pad {self.d_pad}), "
                f"shards={self.ndev}, window={self.window})")

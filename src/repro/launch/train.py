"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

Runs real steps on the available devices (CPU smoke / TPU pod alike):
builds the mesh that fits the device count, shards params/opt-state/batch
per the production rules, wraps the loop in run_with_recovery
(checkpoint/restart + optional failure injection drill), and logs loss.

On this CPU container use --smoke for the reduced configs.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config, get_optimizer_name
from repro.data import DataConfig, batch_for_step
from repro.launch.mesh import make_test_mesh
from repro.models import SHAPES
from repro.optim import get_optimizer
from repro.runtime import FailureInjector, RecoveryConfig, run_with_recovery
from repro.train import build_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--shape", default="smoke_train")
    ap.add_argument("--optimizer", default="")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--fail-at", default="",
                    help="comma-separated steps for a failure drill")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    n_dev = len(jax.devices())
    if n_dev >= 8:
        mesh = make_test_mesh((2, n_dev // 2), ("data", "model"))
    else:
        mesh = make_test_mesh((1, n_dev), ("data", "model"))
    opt_name = args.optimizer or get_optimizer_name(args.arch)
    opt = get_optimizer(opt_name, lr=args.lr)
    bundle = build_train_step(cfg, opt, mesh, shape=args.shape,
                              microbatches=args.microbatches, donate=False)

    ss = SHAPES[args.shape]
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=ss.seq_len,
                    global_batch=ss.global_batch, seed=args.seed)
    params = jax.device_put(bundle.model.init(jax.random.PRNGKey(args.seed)),
                            bundle.in_shardings[0])
    opt_state = jax.device_put(bundle.opt.init(params),
                               bundle.in_shardings[1])

    t0 = time.time()

    def on_metrics(step, metrics):
        if step % 5 == 0 or step == 1:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({time.time() - t0:.1f}s)", flush=True)

    injector = None
    if args.fail_at:
        injector = FailureInjector(
            fail_at=tuple(int(s) for s in args.fail_at.split(",")))

    params, opt_state, stats = run_with_recovery(
        bundle.step, lambda step: batch_for_step(dc, step), params, opt_state,
        n_steps=args.steps,
        config=RecoveryConfig(ckpt_dir=args.ckpt_dir,
                              ckpt_every=args.ckpt_every),
        injector=injector,
        shardings=(bundle.in_shardings[0], bundle.in_shardings[1]),
        on_metrics=on_metrics)
    print(f"done: {args.steps} steps, stats={stats}")


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import/init: jax locks device count on first use.
# (No `from __future__` here — the env var lines above must stay first.)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. builds train_step (train_*), prefill (prefill_*) or serve/decode step
     (decode_* / long_*) with full sharding annotations,
  3. .lower(<ShapeDtypeStructs>).compile()  — no arrays are ever allocated,
  4. records memory_analysis(), cost_analysis(), per-collective byte counts
     parsed from the partitioned HLO, and the three roofline terms
     (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  python -m repro.launch.dryrun --arch gemma3-4b --shape train_4k --mesh single
  python -m repro.launch.dryrun --arch all --shape all --mesh both --out r.json
"""
import argparse
import json
import time
import traceback

import jax
import numpy as np

from repro.configs import ARCHS, get_config, get_optimizer_name
from repro.launch.mesh import make_production_mesh
from repro.models import SHAPES, batch_specs, shape_applicable
from repro.optim import get_optimizer
from repro.train import build_decode_step, build_prefill_step, build_train_step
from repro.utils import roofline_terms
from repro.utils.hlo_cost import analyze_hlo
from repro.utils.roofline import TPUv5e

ASSIGNED_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
HBM_BYTES = 16e9            # v5e per-chip HBM
TRAIN_MICROBATCHES = 8


def _active_params(pa) -> tuple[float, float]:
    """(total, active) param counts from the abstract tree; routed-expert
    weights count as active * top_k / n_experts (handled by caller)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(pa)
    total = routed = 0.0
    for path, leaf in flat:
        n = float(np.prod(leaf.shape))
        total += n
        keys = [str(getattr(p, "key", "")) for p in path]
        if any(k in ("w_gate", "w_up", "w_down") for k in keys):
            routed += n
    return total, routed


def model_flops_of(cfg, pa, shape_name: str) -> float:
    ss = SHAPES[shape_name]
    total, routed = _active_params(pa)
    if cfg.n_experts:
        active = total - routed + routed * cfg.top_k / cfg.n_experts
    else:
        active = total
    if ss.kind == "train":
        tokens = ss.global_batch * ss.seq_len
        per_tok = 6.0
    elif ss.kind == "prefill":
        tokens = ss.global_batch * ss.seq_len
        per_tok = 2.0
    else:                       # decode: one token per sequence
        tokens = ss.global_batch
        per_tok = 2.0
    return per_tok * active * tokens


def build_cell(arch: str, shape_name: str, mesh):
    cfg = get_config(arch)
    ss = SHAPES[shape_name]
    if ss.kind == "train":
        opt = get_optimizer(get_optimizer_name(arch))
        b = build_train_step(cfg, opt, mesh, shape=shape_name,
                             microbatches=TRAIN_MICROBATCHES)
        args = (b.abstract_params, b.abstract_opt_state, b.abstract_batch)
        return b.step, args, b.abstract_params
    if ss.kind == "prefill":
        b = build_prefill_step(cfg, mesh, shape=shape_name)
        return b.step, (b.abstract_params,) + b.abstract_inputs, \
            b.abstract_params
    b = build_decode_step(cfg, mesh, shape=shape_name)
    return b.step, (b.abstract_params,) + b.abstract_inputs, b.abstract_params


def run_cell(arch: str, shape_name: str, mesh_kind: str) -> dict:
    cfg = get_config(arch)
    ok, reason = shape_applicable(cfg, shape_name)
    row = {"arch": arch, "shape": shape_name, "mesh": mesh_kind}
    if not ok:
        row.update(status="skipped", reason=reason)
        return row
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        chips = int(np.prod(mesh.devices.shape))
        with mesh:
            step, args, pa = build_cell(arch, shape_name, mesh)
            lowered = step.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
        # trip-count-aware structural cost model (utils/hlo_cost.py) —
        # compiled.cost_analysis() counts while bodies once, which under-
        # reports scanned-layer models by ~n_layers x.
        costs = analyze_hlo(hlo)
        coll = {k: float(v) for k, v in costs.coll_by_kind.items()}
        coll_bytes = float(costs.coll_bytes)
        flops = float(costs.flops)
        hbm_bytes = float(costs.bytes_hbm)      # pessimistic (CPU-fusion)
        hbm_bytes_opt = float(costs.bytes_out)  # optimistic (perfect fusion)
        xla_flops = float(cost.get("flops", 0.0))
        mf = model_flops_of(cfg, pa, shape_name)
        rt = roofline_terms(
            flops_per_device=flops, hbm_bytes_per_device=hbm_bytes,
            collective_bytes_per_device=coll_bytes, chips=chips,
            model_flops=mf)
        arg_b = float(mem.argument_size_in_bytes)
        tmp_b = float(mem.temp_size_in_bytes)
        out_b = float(mem.output_size_in_bytes)
        # arguments and outputs alias for donated params/opt-state
        peak = arg_b + tmp_b
        row.update(
            status="ok",
            chips=chips,
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            arg_bytes=arg_b, temp_bytes=tmp_b, out_bytes=out_b,
            peak_bytes=peak, fits_hbm=bool(peak <= HBM_BYTES),
            flops_per_dev=flops, hbm_bytes_per_dev=hbm_bytes,
            hbm_bytes_opt_per_dev=hbm_bytes_opt,
            memory_s_opt=hbm_bytes_opt / TPUv5e.hbm_bw,
            collective_bytes_per_dev=coll_bytes,
            collectives=coll, xla_flops_per_dev=xla_flops,
            model_flops=mf,
            compute_s=rt.compute_s, memory_s=rt.memory_s,
            collective_s=rt.collective_s, dominant=rt.dominant,
            useful_ratio=rt.useful_ratio, mfu_bound=rt.mfu_bound,
        )
    except Exception as e:  # noqa: BLE001 — a failed cell is a bug report
        row.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    return row


def fmt_row(r: dict) -> str:
    if r["status"] == "skipped":
        return f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:6s} SKIP ({r['reason'][:40]})"
    if r["status"] == "error":
        return f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:6s} ERROR {r['error'][:70]}"
    return (f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:6s} "
            f"peak={r['peak_bytes']/1e9:7.2f}GB fits={int(r['fits_hbm'])} "
            f"C={r['compute_s']*1e3:8.3f}ms M={r['memory_s']*1e3:8.3f}ms "
            f"K={r['collective_s']*1e3:8.3f}ms dom={r['dominant'][:4]} "
            f"useful={r['useful_ratio']:.2f} mfu_bound={r['mfu_bound']:.3f} "
            f"[compile {r['compile_s']:.0f}s]")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    archs = ARCHS if args.arch == "all" else tuple(args.arch.split(","))
    shapes = ASSIGNED_SHAPES if args.shape == "all" \
        else tuple(args.shape.split(","))
    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)

    rows = []
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                r = run_cell(arch, shape, mk)
                rows.append(r)
                print(fmt_row(r), flush=True)
    if args.out:
        existing = []
        if os.path.exists(args.out):
            with open(args.out) as f:
                existing = json.load(f)
        # replace rows with same (arch, shape, mesh)
        keyset = {(r["arch"], r["shape"], r["mesh"]) for r in rows}
        existing = [r for r in existing
                    if (r["arch"], r["shape"], r["mesh"]) not in keyset]
        with open(args.out, "w") as f:
            json.dump(existing + rows, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in rows)
    n_err = sum(r["status"] == "error" for r in rows)
    print(f"\n{n_ok} ok / {n_err} error / "
          f"{sum(r['status'] == 'skipped' for r in rows)} skipped")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

Prefills a batch of prompts (half the shape's seq_len) and greedily
decodes into the remaining cache space with the KV-cache / SSM-state
serve step. On this CPU container use --smoke.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.launch.mesh import make_test_mesh
from repro.models import SHAPES, build_model, make_concrete_batch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--tokens", type=int, default=12)
    ap.add_argument("--shape", default="smoke_prefill")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    ss = SHAPES[args.shape]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    full = make_concrete_batch(cfg, args.shape)
    prompt_len = ss.seq_len // 2
    max_len = ss.seq_len

    def crop(k, v):
        if k == "tokens":
            return v[:, :prompt_len]
        if k == "positions":
            return v[..., :prompt_len]
        return v

    prompt = {k: crop(k, v) for k, v in full.items()}

    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len))
    decode = jax.jit(model.decode)

    t0 = time.time()
    logits, cache = prefill(params, prompt)
    print(f"prefill: {prompt['tokens'].shape} in {time.time() - t0:.2f}s")

    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    n_tok = min(args.tokens, max_len - prompt_len - 1)
    for i in range(n_tok):
        pos = jnp.full((ss.global_batch,), prompt_len + i, jnp.int32)
        logits, cache = decode(params, cache, tok, pos)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    toks = jnp.stack(out, axis=1)
    print(f"decoded {n_tok} tokens/seq in {dt:.2f}s "
          f"({n_tok * ss.global_batch / max(dt, 1e-9):.1f} tok/s)")
    print("sample:", toks[0][:16].tolist())


if __name__ == "__main__":
    main()

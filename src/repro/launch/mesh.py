"""Production mesh construction (assignment contract).

Defined as FUNCTIONS so importing this module never touches jax device
state — the dry-run sets XLA_FLAGS before the first jax call and only then
asks for the mesh.
"""
from __future__ import annotations

import inspect

import jax

try:  # jax >= 0.5: explicit/auto axis types
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - older jax has implicit Auto axes
    AxisType = None

_MAKE_MESH_TAKES_AXIS_TYPES = "axis_types" in inspect.signature(jax.make_mesh).parameters


def _make_mesh(shape, axes):
    """jax.make_mesh across jax versions: pass axis_types only if supported."""
    if AxisType is not None and _MAKE_MESH_TAKES_AXIS_TYPES:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for CPU multi-device tests (8 fake host devices)."""
    return _make_mesh(shape, axes)


def make_d_mesh(ndev: int | None = None, axis: str = "d"):
    """Flat one-axis mesh over ``ndev`` (default: all) devices.

    The layout the D-sharded incremental state machine wants
    (``core/dist_state.py``): every (N, D) data strip splits its LAST axis
    over this single axis, all (N, N) strips are replicated, and ring
    (ppermute) pipelining has one well-defined ring to run on.  Multi-axis
    meshes also work everywhere psum-based (the D axis is sharded over all
    axes jointly); only the ring-overlap path requires this flat form.
    """
    n = len(jax.devices()) if ndev is None else int(ndev)
    return _make_mesh((n,), (axis,))

"""Production mesh construction (assignment contract).

Defined as FUNCTIONS so importing this module never touches jax device
state — the dry-run sets XLA_FLAGS before the first jax call and only then
asks for the mesh.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for CPU multi-device tests (8 fake host devices)."""
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))

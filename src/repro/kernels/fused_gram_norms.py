"""Pallas TPU kernel: one-pass gram + row norms for stationary pairwise r.

Stationary kernels need  r_ab = |x_a|^2_L + |x_b|^2_L - 2 x_a^T L x_b  for
*cross* sets (queries vs. data). A naive implementation streams A and B
three times (gram, norm_A, norm_B); this kernel produces all three partials
in a single pass — the r assembly itself is an O(Na*Nb) epilogue outside.

Outputs: P (Na, Nb) f32, na (Na, 1) f32, nb (Nb, 1) f32.
Padding contract as in skinny_gram (zero-padded lam kills padding exactly).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jnp.ndarray


def _kernel(a_ref, b_ref, lam_ref, p_ref, na_ref, nb_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        p_ref[...] = jnp.zeros_like(p_ref)
        na_ref[...] = jnp.zeros_like(na_ref)
        nb_ref[...] = jnp.zeros_like(nb_ref)

    lam = lam_ref[...].astype(jnp.float32)
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    al = a * lam
    p_ref[...] += jax.lax.dot_general(
        al, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    na_ref[...] += jnp.sum(al * a, axis=1, keepdims=True)
    nb_ref[...] += jnp.sum((b * lam) * b, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def fused_gram_norms_padded(
    A: Array, B: Array, lam: Array, *, block_d: int = 1024, interpret: bool = False
):
    na_, d = A.shape
    nb_, _ = B.shape
    assert d % block_d == 0, (d, block_d)
    lam2 = jnp.broadcast_to(lam, (d,)).reshape(1, d)
    grid = (d // block_d,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((na_, block_d), lambda i: (0, i)),
            pl.BlockSpec((nb_, block_d), lambda i: (0, i)),
            pl.BlockSpec((1, block_d), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((na_, nb_), lambda i: (0, 0)),
            pl.BlockSpec((na_, 1), lambda i: (0, 0)),
            pl.BlockSpec((nb_, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((na_, nb_), jnp.float32),
            jax.ShapeDtypeStruct((na_, 1), jnp.float32),
            jax.ShapeDtypeStruct((nb_, 1), jnp.float32),
        ],
        interpret=interpret,
    )(A, B, lam2)

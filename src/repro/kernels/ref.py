"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax.numpy as jnp

Array = jnp.ndarray


def skinny_gram_ref(A: Array, B: Array, lam) -> Array:
    """P = (A * lam) @ B^T in f32 accumulation."""
    a = A.astype(jnp.float32) * jnp.asarray(lam, jnp.float32)
    return a @ B.astype(jnp.float32).T


def gram_update_ref(K1: Array, M: Array, V: Array, X: Array, lam) -> Array:
    """W = (K1 @ V + M @ X) * lam, result in V.dtype."""
    acc = K1.astype(jnp.float32) @ V.astype(jnp.float32)
    acc = acc + M.astype(jnp.float32) @ X.astype(jnp.float32)
    return (acc * jnp.asarray(lam, jnp.float32)).astype(V.dtype)


def fused_gram_norms_ref(A: Array, B: Array, lam):
    lamv = jnp.asarray(lam, jnp.float32)
    a = A.astype(jnp.float32)
    b = B.astype(jnp.float32)
    P = (a * lamv) @ b.T
    na = jnp.sum(a * lamv * a, axis=1, keepdims=True)
    nb = jnp.sum(b * lamv * b, axis=1, keepdims=True)
    return P, na, nb

"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax.numpy as jnp

Array = jnp.ndarray


def _out_dtype(dt):
    """bf16 storage in, f32 out — mirror of the kernels' output contract."""
    return jnp.float32 if dt == jnp.bfloat16 else dt


def skinny_gram_ref(A: Array, B: Array, lam) -> Array:
    """P = (A * lam) @ B^T in f32 accumulation."""
    a = A.astype(jnp.float32) * jnp.asarray(lam, jnp.float32)
    return a @ B.astype(jnp.float32).T


def gram_update_ref(K1: Array, M: Array, V: Array, X: Array, lam,
                    v_scale=None, noise: float = 0.0) -> Array:
    """W = (K1 @ (V*v_scale) + M @ X) * lam + noise*V (f32 out for bf16 V)."""
    v = V.astype(jnp.float32)
    vs = v if v_scale is None else v * jnp.asarray(v_scale, jnp.float32)
    acc = K1.astype(jnp.float32) @ vs
    acc = acc + M.astype(jnp.float32) @ X.astype(jnp.float32)
    out = acc * jnp.asarray(lam, jnp.float32)
    if noise:
        out = out + jnp.float32(noise) * v
    return out.astype(_out_dtype(V.dtype))


def fused_gram_norms_ref(A: Array, B: Array, lam):
    lamv = jnp.asarray(lam, jnp.float32)
    a = A.astype(jnp.float32)
    b = B.astype(jnp.float32)
    P = (a * lamv) @ b.T
    na = jnp.sum(a * lamv * a, axis=1, keepdims=True)
    nb = jnp.sum(b * lamv * b, axis=1, keepdims=True)
    return P, na, nb


def fused_factor_build_ref(A: Array, B: Array, V: Array, lam, vs=1.0):
    """(P, na, nb, C, tv) — the single-sweep factor bundle, f32 accumulation.

    P = (A*lam) @ B^T, na/nb the lam-weighted row norms, C = (V*vs) @ A^T,
    tv = rowdots(B, V, lam).  V must share B's row count.
    """
    lamv = jnp.asarray(lam, jnp.float32)
    vsv = jnp.asarray(vs, jnp.float32)
    a = A.astype(jnp.float32)
    b = B.astype(jnp.float32)
    v = V.astype(jnp.float32)
    P = (a * lamv) @ b.T
    na = jnp.sum(a * lamv * a, axis=1, keepdims=True)
    nb = jnp.sum(b * lamv * b, axis=1, keepdims=True)
    C = (v * vsv) @ a.T
    tv = jnp.sum(b * lamv * v, axis=1, keepdims=True)
    return P, na, nb, C, tv


def small_op(K2e: Array, M: Array, *, stationary: bool) -> Array:
    """The (N, N) Hadamard/L-operator algebra of Alg. 2 (M may be stacked).

    THE single jnp definition of this fold — core/mvm.py and the backend
    dispatch reuse it; only the Mosaic kernel (fused_gram_mvm._small_from_m,
    gather-free) re-states it.
    """
    if not stationary:
        return K2e * M
    diag_m = jnp.diagonal(M, axis1=-2, axis2=-1)
    mt = K2e * (M - diag_m[..., None, :])
    eye = jnp.eye(M.shape[-1], dtype=M.dtype)
    return eye * jnp.sum(mt, axis=-1)[..., :, None] - mt


def gram_mvm_oracle(K1e: Array, K2e: Array, Xt: Array, V: Array, lam,
                    *, stationary: bool, noise: float = 0.0) -> Array:
    """Full Alg.-2 Gram MVM in the inputs' native dtype (V 2D or stacked 3D)."""
    m = jnp.einsum("ad,...bd->...ab", Xt * lam, V)
    small = small_op(K2e, m, stationary=stationary)
    w = jnp.einsum("ab,...bd->...ad", K1e, V)
    w = (w + jnp.einsum("...ab,bd->...ad", small, Xt)) * lam
    if noise:
        w = w + noise * V
    return w


def fused_gram_mvm_ref(K1e: Array, K2e: Array, Xt: Array, V: Array, lam,
                       *, stationary: bool, noise: float = 0.0) -> Array:
    """Full Alg.-2 Gram MVM oracle (f32 accumulation, V 2D or stacked 3D)."""
    out = gram_mvm_oracle(
        K1e.astype(jnp.float32), K2e.astype(jnp.float32),
        Xt.astype(jnp.float32), V.astype(jnp.float32),
        jnp.asarray(lam, jnp.float32), stationary=stationary,
        noise=float(noise))
    return out.astype(_out_dtype(V.dtype))

"""Jit'd public wrappers around the Pallas kernels.

Handles the padding contract (N to sublane multiples, D to block multiples,
zero-padded lam so padding cancels exactly), backend dispatch (interpret
mode on CPU — executes the kernel bodies in Python for validation), and
fallback to the jnp reference for tiny shapes where kernel launch overhead
dominates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .fused_gram_norms import fused_gram_norms_padded
from .gram_update import gram_update_padded
from .skinny_gram import skinny_gram_padded

Array = jnp.ndarray

_SUBLANE = 8


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_rows(A: Array, to: int) -> Array:
    n = A.shape[0]
    return A if n == to else jnp.pad(A, ((0, to - n), (0, 0)))


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pick_block_d(d: int, block_d: int) -> int:
    # shrink the block for small D so padding stays bounded
    while block_d > 128 and d <= block_d // 2:
        block_d //= 2
    return block_d


def skinny_gram(A: Array, B: Array, lam, *, block_d: int = 1024,
                interpret: bool | None = None) -> Array:
    """P = (A * lam) @ B^T, f32 accumulation; A: (Na, D), B: (Nb, D)."""
    interpret = _interpret_default() if interpret is None else interpret
    na, d = A.shape
    nb = B.shape[0]
    block_d = _pick_block_d(d, block_d)
    dp = _round_up(d, block_d)
    nap, nbp = _round_up(na, _SUBLANE), _round_up(nb, _SUBLANE)
    lam_f = jnp.broadcast_to(jnp.asarray(lam, jnp.float32), (d,))
    lam_p = jnp.pad(lam_f, (0, dp - d))
    Ap = _pad_rows(jnp.pad(A, ((0, 0), (0, dp - d))), nap)
    Bp = _pad_rows(jnp.pad(B, ((0, 0), (0, dp - d))), nbp)
    P = skinny_gram_padded(Ap, Bp, lam_p, block_d=block_d, interpret=interpret)
    return P[:na, :nb]


def gram_update(K1: Array, M: Array, V: Array, X: Array, lam, *,
                block_d: int = 1024, interpret: bool | None = None) -> Array:
    """W = (K1 @ V + M @ X) * lam; V, X: (N, D) streamed."""
    interpret = _interpret_default() if interpret is None else interpret
    n, d = V.shape
    block_d = _pick_block_d(d, block_d)
    dp = _round_up(d, block_d)
    np_ = _round_up(n, _SUBLANE)
    lam_f = jnp.broadcast_to(jnp.asarray(lam, jnp.float32), (d,))
    lam_p = jnp.pad(lam_f, (0, dp - d))
    Vp = _pad_rows(jnp.pad(V, ((0, 0), (0, dp - d))), np_)
    Xp = _pad_rows(jnp.pad(X, ((0, 0), (0, dp - d))), np_)
    K1p = jnp.pad(K1, ((0, np_ - n), (0, np_ - n)))
    Mp = jnp.pad(M, ((0, np_ - n), (0, np_ - n)))
    W = gram_update_padded(K1p, Mp, Vp, Xp, lam_p, block_d=block_d,
                           interpret=interpret)
    return W[:n, :d]


def fused_gram_norms(A: Array, B: Array, lam, *, block_d: int = 1024,
                     interpret: bool | None = None):
    """(P, norms_A, norms_B) in one pass; used for stationary pairwise r."""
    interpret = _interpret_default() if interpret is None else interpret
    na, d = A.shape
    nb = B.shape[0]
    block_d = _pick_block_d(d, block_d)
    dp = _round_up(d, block_d)
    nap, nbp = _round_up(na, _SUBLANE), _round_up(nb, _SUBLANE)
    lam_f = jnp.broadcast_to(jnp.asarray(lam, jnp.float32), (d,))
    lam_p = jnp.pad(lam_f, (0, dp - d))
    Ap = _pad_rows(jnp.pad(A, ((0, 0), (0, dp - d))), nap)
    Bp = _pad_rows(jnp.pad(B, ((0, 0), (0, dp - d))), nbp)
    P, na_o, nb_o = fused_gram_norms_padded(Ap, Bp, lam_p, block_d=block_d,
                                            interpret=interpret)
    return P[:na, :nb], na_o[:na, 0], nb_o[:nb, 0]


# jnp references re-exported for benchmarking parity
skinny_gram_ref = ref.skinny_gram_ref
gram_update_ref = ref.gram_update_ref
fused_gram_norms_ref = ref.fused_gram_norms_ref

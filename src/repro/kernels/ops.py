"""Jit'd public wrappers around the Pallas kernels.

Handles the padding contract (N to sublane multiples, D to block multiples,
zero-padded lam so padding cancels exactly), backend dispatch (interpret
mode on CPU — executes the kernel bodies in Python for validation), and
block-size selection under an explicit VMEM budget.

Block-size policy (``_pick_block_d``, DESIGN.md §4.4): the D-block is a lane
multiple chosen so that (a) the streamed VMEM footprint — double-buffered
input blocks plus the output block, minus the resident (N, N) operands and
scratch — fits ``vmem_budget_bytes``, and (b) padding waste
(round_up(D, block) - D) / D stays under ~12.5% whenever a lane-multiple
block can achieve it. For D just above a power-of-two boundary (e.g.
D = 1025) a fixed 1024-block would nearly double the streamed bytes; the
scan from the VMEM cap downward picks the largest block that keeps the pad
bounded instead.
"""
from __future__ import annotations

import contextlib
import functools

import jax
import jax.numpy as jnp

from . import ref
from .fused_factor_build import fused_factor_build_padded
from .fused_gram_mvm import fused_gram_mvm_multi_padded, fused_gram_mvm_padded
from .fused_gram_norms import fused_gram_norms_padded
from .gram_update import gram_update_padded, small_matmul_padded
from .skinny_gram import skinny_gram_padded

Array = jnp.ndarray

_SUBLANE = 8
_LANE = 128
# Half of a TPU v5e core's ~16 MB VMEM: leaves headroom for Mosaic's own
# buffers and the semaphore/control state of the streaming pipeline.
DEFAULT_VMEM_BUDGET = 8 * 1024 * 1024
_MAX_PAD_WASTE = 0.125

# How many ways the D axis is split across devices.  Under shard_map the
# kernels see local (N, D_loc) shapes and this stays 1; under GSPMD (jit +
# sharding constraints) they see the GLOBAL D, and block sizing must bound
# pad waste against the per-device slice D/shards — a one-grid-step block
# equal to global D would be 'shards'-times oversized on every device.
_DATA_SHARDS = 1


def set_data_shards(n: int) -> None:
    """Declare the D-axis device count for block sizing (GSPMD callers)."""
    global _DATA_SHARDS
    _DATA_SHARDS = max(1, int(n))


@contextlib.contextmanager
def use_data_shards(n: int):
    """Scoped :func:`set_data_shards` (restores the previous value)."""
    global _DATA_SHARDS
    prev = _DATA_SHARDS
    _DATA_SHARDS = max(1, int(n))
    try:
        yield
    finally:
        _DATA_SHARDS = prev


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_rows(A: Array, to: int) -> Array:
    n = A.shape[-2]
    if n == to:
        return A
    pad = [(0, 0)] * (A.ndim - 2) + [(0, to - n), (0, 0)]
    return jnp.pad(A, pad)


def _pad_cols(A: Array, to: int) -> Array:
    d = A.shape[-1]
    if d == to:
        return A
    pad = [(0, 0)] * (A.ndim - 1) + [(0, to - d)]
    return jnp.pad(A, pad)


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pick_block_d(
    d: int,
    block_d: int = 1024,
    *,
    stream_rows: int = 0,
    resident_bytes: int = 0,
    vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET,
    max_waste: float = _MAX_PAD_WASTE,
    shards: int | None = None,
) -> int:
    """Choose the D-block size for a lane-streaming kernel.

    ``stream_rows`` counts the f32 rows that move per lane of the block
    (inputs and outputs together); each is double-buffered. ``resident_bytes``
    is the VMEM taken by whole-array operands (K1e/K2e/scratch) that do not
    scale with the block.

    ``shards`` (default: the :func:`set_data_shards` context) is the D-axis
    device count under GSPMD: the axis each device actually streams is
    ceil(d / shards), so both the one-grid-step branch and the pad-waste
    bound are evaluated against that local slice.  Sizing against the
    global axis would e.g. hand a D=4096-on-8-devices problem a single
    4096-wide block — an 8x padded launch on every (N, 512) shard.
    """
    shards = _DATA_SHARDS if shards is None else max(1, int(shards))
    d_eff = -(-d // shards)      # per-device slice of the streamed axis
    cap = block_d
    if stream_rows:
        min_stream = _LANE * 8 * stream_rows  # one 128-lane double-buffered block
        if resident_bytes + min_stream > vmem_budget_bytes:
            raise ValueError(
                f"VMEM budget exhausted before streaming: resident operands "
                f"take {resident_bytes} B + minimum stream {min_stream} B > "
                f"budget {vmem_budget_bytes} B (N too large for this kernel "
                f"family — the (N, N) operands must fit on-chip)")
        cap = min(cap, (vmem_budget_bytes - resident_bytes) // (8 * stream_rows))
    cap = max(_LANE, cap // _LANE * _LANE)
    if d_eff <= cap:
        # One grid step per shard; round_up(d_eff, LANE) is the minimum
        # possible per-shard padding.
        return max(_LANE, _round_up(d_eff, _LANE))
    b = cap
    while b >= _LANE:
        if (_round_up(d_eff, b) - d_eff) / d_eff <= max_waste:
            return b
        b -= _LANE
    return _LANE


def _pad_d_inputs(arrays, lam, d: int, dp: int):
    """Zero-pad the D (lane) axis of each array and of lam to dp."""
    lam_f = jnp.broadcast_to(jnp.asarray(lam, jnp.float32), (d,))
    return [_pad_cols(a, dp) for a in arrays], jnp.pad(lam_f, (0, dp - d))


def skinny_gram(A: Array, B: Array, lam, *, block_d: int = 1024,
                interpret: bool | None = None,
                vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET) -> Array:
    """P = (A * lam) @ B^T, f32 accumulation; A: (Na, D), B: (Nb, D)."""
    interpret = _interpret_default() if interpret is None else interpret
    na, d = A.shape
    nb = B.shape[0]
    nap, nbp = _round_up(na, _SUBLANE), _round_up(nb, _SUBLANE)
    block_d = _pick_block_d(d, block_d, stream_rows=nap + nbp + 1,
                            resident_bytes=4 * nap * nbp,
                            vmem_budget_bytes=vmem_budget_bytes)
    dp = _round_up(d, block_d)
    (Ap, Bp), lam_p = _pad_d_inputs([A, B], lam, d, dp)
    Ap, Bp = _pad_rows(Ap, nap), _pad_rows(Bp, nbp)
    P = skinny_gram_padded(Ap, Bp, lam_p, block_d=block_d, interpret=interpret)
    return P[:na, :nb]


def gram_update(K1: Array, M: Array, V: Array, X: Array, lam, *,
                v_scale=None, noise: float = 0.0, block_d: int = 1024,
                interpret: bool | None = None,
                vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET) -> Array:
    """W = (K1 @ (V*v_scale) + M @ X) * lam + noise*V; V, X: (N, D) streamed.

    K1/M may be rectangular (Nq, N) (cross-covariance query path).
    """
    interpret = _interpret_default() if interpret is None else interpret
    n, d = V.shape
    nq = K1.shape[0]
    np_ = _round_up(n, _SUBLANE)
    nqp = _round_up(nq, _SUBLANE)
    block_d = _pick_block_d(d, block_d, stream_rows=2 * np_ + nqp + 2,
                            resident_bytes=8 * nqp * np_,
                            vmem_budget_bytes=vmem_budget_bytes)
    dp = _round_up(d, block_d)
    vs = jnp.ones((d,), jnp.float32) if v_scale is None else \
        jnp.broadcast_to(jnp.asarray(v_scale, jnp.float32), (d,))
    (Vp, Xp, vs_p), lam_p = _pad_d_inputs([V, X, vs], lam, d, dp)
    Vp, Xp = _pad_rows(Vp, np_), _pad_rows(Xp, np_)
    K1p = jnp.pad(K1, ((0, nqp - nq), (0, np_ - n)))
    Mp = jnp.pad(M, ((0, nqp - nq), (0, np_ - n)))
    W = gram_update_padded(K1p, Mp, Vp, Xp, lam_p, vs_p, block_d=block_d,
                           interpret=interpret, noise=float(noise))
    return W[:nq, :d]


def small_matmul(K: Array, V: Array, scale=1.0, *, block_d: int = 1024,
                 interpret: bool | None = None,
                 vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET) -> Array:
    """W = (K @ V) * scale; K: (Nq, N), V: (N, D) streamed, scale per-lane.

    The Kronecker-preconditioner application (scale = 1/lam): one read of
    V, one write of W — no dead operands (cf. gram_update)."""
    interpret = _interpret_default() if interpret is None else interpret
    n, d = V.shape
    nq = K.shape[0]
    np_ = _round_up(n, _SUBLANE)
    nqp = _round_up(nq, _SUBLANE)
    block_d = _pick_block_d(d, block_d, stream_rows=np_ + nqp + 1,
                            resident_bytes=4 * nqp * np_,
                            vmem_budget_bytes=vmem_budget_bytes)
    dp = _round_up(d, block_d)
    s = jnp.broadcast_to(jnp.asarray(scale, jnp.float32), (d,))
    (Vp, sp), _ = _pad_d_inputs([V, s], 0.0, d, dp)
    Vp = _pad_rows(Vp, np_)
    Kp = jnp.pad(K, ((0, nqp - nq), (0, np_ - n)))
    W = small_matmul_padded(Kp, Vp, sp, block_d=block_d, interpret=interpret)
    return W[:nq, :d]


def fused_factor_build(A: Array, B: Array, V: Array | None, lam, *,
                       v_scale=1.0, block_d: int = 1024,
                       interpret: bool | None = None,
                       vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET):
    """Single-sweep factor bundle (P, na, nb, C, tv) — ONE launch.

    A: (Na, D), B: (Nb, D), V: (Nb, D) (or None to reuse B).  Returns
    P = (A*lam) @ B^T (Na, Nb), row norms na (Na,) / nb (Nb,),
    C = (V*v_scale) @ A^T (Nb, Na), tv = rowdots(B, V, lam) (Nb,).
    Accepts bf16 storage for A/B/V; all outputs f32.
    """
    interpret = _interpret_default() if interpret is None else interpret
    if V is None:
        V = B
    if V.shape != B.shape:
        raise ValueError(f"V must share B's shape (tv/C row contract): "
                         f"V {V.shape} vs B {B.shape}")
    na, d = A.shape
    nb = B.shape[0]
    nap, nbp = _round_up(na, _SUBLANE), _round_up(nb, _SUBLANE)
    block_d = _pick_block_d(
        d, block_d, stream_rows=nap + 2 * nbp + 2,
        resident_bytes=4 * (2 * nap * nbp + nap + 2 * nbp),
        vmem_budget_bytes=vmem_budget_bytes)
    dp = _round_up(d, block_d)
    vs = jnp.broadcast_to(jnp.asarray(v_scale, jnp.float32), (d,))
    (Ap, Bp, Vp, vs_p), lam_p = _pad_d_inputs([A, B, V, vs], lam, d, dp)
    Ap = _pad_rows(Ap, nap)
    Bp, Vp = _pad_rows(Bp, nbp), _pad_rows(Vp, nbp)
    P, na_o, nb_o, C, tv = fused_factor_build_padded(
        Ap, Bp, Vp, lam_p, vs_p, block_d=block_d, interpret=interpret)
    return (P[:na, :nb], na_o[:na, 0], nb_o[:nb, 0], C[:nb, :na], tv[:nb, 0])


def fused_gram_norms(A: Array, B: Array, lam, *, block_d: int = 1024,
                     interpret: bool | None = None,
                     vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET):
    """(P, norms_A, norms_B) in one pass; used for stationary pairwise r."""
    interpret = _interpret_default() if interpret is None else interpret
    na, d = A.shape
    nb = B.shape[0]
    nap, nbp = _round_up(na, _SUBLANE), _round_up(nb, _SUBLANE)
    block_d = _pick_block_d(d, block_d, stream_rows=nap + nbp + 1,
                            resident_bytes=4 * (nap * nbp + nap + nbp),
                            vmem_budget_bytes=vmem_budget_bytes)
    dp = _round_up(d, block_d)
    (Ap, Bp), lam_p = _pad_d_inputs([A, B], lam, d, dp)
    Ap, Bp = _pad_rows(Ap, nap), _pad_rows(Bp, nbp)
    P, na_o, nb_o = fused_gram_norms_padded(Ap, Bp, lam_p, block_d=block_d,
                                            interpret=interpret)
    return P[:na, :nb], na_o[:na, 0], nb_o[:nb, 0]


def fused_gram_mvm(K1e: Array, K2e: Array, Xt: Array, V: Array, lam, *,
                   stationary: bool, noise: float = 0.0, block_d: int = 1024,
                   interpret: bool | None = None,
                   vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET) -> Array:
    """Full Alg.-2 Gram MVM, single pallas_call (see fused_gram_mvm.py).

    V of shape (N, D) -> W (N, D); stacked (R, N, D) RHS dispatch to the
    multi-RHS kernel which amortizes the Xt stream across R.
    """
    if V.ndim == 3:
        return fused_gram_mvm_multi(K1e, K2e, Xt, V, lam,
                                    stationary=stationary, noise=noise,
                                    block_d=block_d, interpret=interpret,
                                    vmem_budget_bytes=vmem_budget_bytes)
    interpret = _interpret_default() if interpret is None else interpret
    n, d = V.shape
    np_ = _round_up(n, _SUBLANE)
    block_d = _pick_block_d(d, block_d, stream_rows=3 * np_ + 1,
                            resident_bytes=12 * np_ * np_,
                            vmem_budget_bytes=vmem_budget_bytes)
    dp = _round_up(d, block_d)
    (Xp, Vp), lam_p = _pad_d_inputs([Xt, V], lam, d, dp)
    Xp, Vp = _pad_rows(Xp, np_), _pad_rows(Vp, np_)
    K1p = jnp.pad(K1e, ((0, np_ - n), (0, np_ - n)))
    K2p = jnp.pad(K2e, ((0, np_ - n), (0, np_ - n)))
    W = fused_gram_mvm_padded(K1p, K2p, Xp, Vp, lam_p, stationary=stationary,
                              noise=float(noise), block_d=block_d,
                              interpret=interpret)
    return W[:n, :d]


def fused_gram_mvm_multi(K1e: Array, K2e: Array, Xt: Array, V: Array, lam, *,
                         stationary: bool, noise: float = 0.0,
                         block_d: int = 1024, interpret: bool | None = None,
                         vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET) -> Array:
    """Stacked-RHS Alg.-2 MVM: V (R, N, D) -> W (R, N, D), one launch."""
    interpret = _interpret_default() if interpret is None else interpret
    r, n, d = V.shape
    np_ = _round_up(n, _SUBLANE)
    block_d = _pick_block_d(d, block_d, stream_rows=(2 * r + 1) * np_ + 1,
                            resident_bytes=4 * (2 + r) * np_ * np_,
                            vmem_budget_bytes=vmem_budget_bytes)
    dp = _round_up(d, block_d)
    (Xp, Vp), lam_p = _pad_d_inputs([Xt, V], lam, d, dp)
    Xp, Vp = _pad_rows(Xp, np_), _pad_rows(Vp, np_)
    K1p = jnp.pad(K1e, ((0, np_ - n), (0, np_ - n)))
    K2p = jnp.pad(K2e, ((0, np_ - n), (0, np_ - n)))
    W = fused_gram_mvm_multi_padded(K1p, K2p, Xp, Vp, lam_p,
                                    stationary=stationary, noise=float(noise),
                                    block_d=block_d, interpret=interpret)
    return W[:, :n, :d]


# jnp references re-exported for benchmarking parity
skinny_gram_ref = ref.skinny_gram_ref
gram_update_ref = ref.gram_update_ref
fused_gram_norms_ref = ref.fused_gram_norms_ref
fused_gram_mvm_ref = ref.fused_gram_mvm_ref
fused_factor_build_ref = ref.fused_factor_build_ref

"""Pallas TPU megakernel: single-sweep factor build (DESIGN.md sec. 12).

Every structured factor of the method is a reduction of the same (N, D)
data stream, yet the pre-fusion solve path made three-to-four separate
passes over X (and G) per solve: ``scaled_gram`` for the pairwise-r gram,
``fused_gram_norms`` for the stationary row norms, a Woodbury
``K1i @ G`` D-stream plus its ``@ Xt^T`` contraction, and the query-side
cross-gram. This kernel emits ALL of those skinny factors in one launch —
one read of each operand over the D grid, f32 VMEM accumulators:

  P  (Na, Nb) = (A * lam) @ B^T     the scaled (cross-)gram
  na (Na, 1)  = sum_d A*lam*A       row norms of A   (stationary r assembly)
  nb (Nb, 1)  = sum_d B*lam*B       row norms of B
  C  (Nb, Na) = (V * vs) @ A^T      the right-hand contraction
  tv (Nb, 1)  = sum_d B*lam*V       row dots of B against V

``V`` must share B's row count. The two hot instantiations:

  solve (Woodbury/poly2):  A = B = Xt, V = G,  vs = 1
      P = S = (Xt L) Xt^T;  C = G Xt^T, so T0 = (K1i G) Xt^T = K1i @ C
      by associativity — the Woodbury right-hand side needs NO extra
      stream of G and never materializes the (N, D) intermediate K1i G.
  query (posterior mean):  A = Xq, B = Xt, V = Z, vs = lam
      P/na/nb assemble pairwise r;  C^T = (Xq L) Z^T is the cross
      contraction of BOTH the value and grad posterior means;  tv is the
      stationary row-dot correction.

Inputs may be bf16 (storage precision): every accumulation runs in f32
via ``preferred_element_type`` and all five outputs are f32.

Grid runs over D-blocks only; the five outputs use constant index maps so
their f32 accumulators stay resident in VMEM across the whole sweep
(revisiting pattern) while the pallas pipeline double-buffers the streamed
A/B/V blocks. Padding contract as in skinny_gram: rows to sublane
multiples with zero rows (annihilated in every product), D to block_d
multiples with lam/vs zero-padded (kills padded lanes exactly).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jnp.ndarray


def _kernel(a_ref, b_ref, v_ref, lam_ref, vs_ref,
            p_ref, na_ref, nb_ref, c_ref, tv_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        p_ref[...] = jnp.zeros_like(p_ref)
        na_ref[...] = jnp.zeros_like(na_ref)
        nb_ref[...] = jnp.zeros_like(nb_ref)
        c_ref[...] = jnp.zeros_like(c_ref)
        tv_ref[...] = jnp.zeros_like(tv_ref)

    lam = lam_ref[...].astype(jnp.float32)
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    al = a * lam
    bl = b * lam
    p_ref[...] += jax.lax.dot_general(
        al, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    na_ref[...] += jnp.sum(al * a, axis=1, keepdims=True)
    nb_ref[...] += jnp.sum(bl * b, axis=1, keepdims=True)
    c_ref[...] += jax.lax.dot_general(
        v * vs_ref[...].astype(jnp.float32), a,
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    tv_ref[...] += jnp.sum(bl * v, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def fused_factor_build_padded(
    A: Array, B: Array, V: Array, lam: Array, vs: Array,
    *, block_d: int = 1024, interpret: bool = False,
):
    """(P, na, nb, C, tv) in ONE launch; pre-padded inputs only."""
    na_, d = A.shape
    nb_, _ = B.shape
    assert B.shape == (nb_, d) and V.shape == (nb_, d), (A.shape, B.shape,
                                                        V.shape)
    assert d % block_d == 0, (d, block_d)
    lam2 = jnp.broadcast_to(lam, (d,)).reshape(1, d)
    vs2 = jnp.broadcast_to(vs, (d,)).reshape(1, d)
    grid = (d // block_d,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((na_, block_d), lambda i: (0, i)),
            pl.BlockSpec((nb_, block_d), lambda i: (0, i)),
            pl.BlockSpec((nb_, block_d), lambda i: (0, i)),
            pl.BlockSpec((1, block_d), lambda i: (0, i)),
            pl.BlockSpec((1, block_d), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((na_, nb_), lambda i: (0, 0)),
            pl.BlockSpec((na_, 1), lambda i: (0, 0)),
            pl.BlockSpec((nb_, 1), lambda i: (0, 0)),
            pl.BlockSpec((nb_, na_), lambda i: (0, 0)),
            pl.BlockSpec((nb_, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((na_, nb_), jnp.float32),
            jax.ShapeDtypeStruct((na_, 1), jnp.float32),
            jax.ShapeDtypeStruct((nb_, 1), jnp.float32),
            jax.ShapeDtypeStruct((nb_, na_), jnp.float32),
            jax.ShapeDtypeStruct((nb_, 1), jnp.float32),
        ],
        interpret=interpret,
    )(A, B, V, lam2, vs2)

"""Pallas TPU megakernel: the full Alg.-2 Gram MVM in ONE pallas_call.

W = (K1e @ V + small @ Xt) * lam  (+ noise * V), where ``small`` is the
(N, N) Hadamard/L-operator algebra of paper Alg. 2:

  dot:         small = K2e * M,                      M = (Xt*lam) @ V^T
  stationary:  small = diag(rowsum(Mt)) - Mt,        Mt = K2e * (M - diag(M)[None, :])

Two-phase grid (phase, d_block), phase-major so the whole D-stream of
phase 0 completes before phase 1 starts:

  phase 0: accumulate M into an (N, N) f32 VMEM scratch (one read of
           Xt and V blocks per step);
  epilogue (first phase-1 step): form ``small`` from K1e/K2e/M entirely
           on-chip — including the stationary l_op/lt_op fold — and
           overwrite the scratch in place;
  phase 1: stream the output update (second read of Xt/V, one write of W).

HBM traffic per MVM: 2 reads of Xt, 2 reads of V, 1 write of W, plus the
(N, N) operands — zero HBM round-trips of any (N, D) or (N, N)
intermediate, and one kernel launch instead of three (see DESIGN.md §4.3
for the byte accounting vs. the unfused sequence).

The multi-RHS variant stacks V as (R, N, D) and amortizes the two Xt
streams across all R right-hand sides: (2 + 3R) N*D-sized transfers
instead of 5R — this is what CG over R RHS (Hessian operator columns,
HMC predictive gradients) rides on.

The output index map is (0, j * phase): during phase 0 every step parks on
output block 0, so no block transition occurs and nothing is flushed to HBM
until phase 1 writes real values.

Padding contract (enforced by ops.py): N to sublane multiples with K1e/K2e
zero-padded (zero rows/cols are annihilated in every term), D to block_d
multiples with lam zero-padded (kills padded lanes exactly). ``stationary``
and ``noise`` are compile-time constants baked into the kernel body.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jnp.ndarray

from .gram_update import _out_dtype  # bf16 storage in -> f32 out


def _eye(n: int) -> Array:
    # 2D iota (TPU cannot lower 1D iota); used for on-chip diag extraction.
    rows = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    return (rows == cols).astype(jnp.float32)


def _small_from_m(m: Array, k2: Array, stationary: bool) -> Array:
    """The O(N^2) epilogue: Alg.-2 ``small`` matrix from M and K2e."""
    if not stationary:
        return k2 * m
    n = m.shape[-1]
    eye = _eye(n)
    # diag(M)[b] = M[b, b] as a row vector, via a masked reduction (no
    # jnp.diagonal inside the kernel — gather-free, Mosaic-friendly).
    diag_m = jnp.sum(m * eye, axis=-2, keepdims=True)
    mt = k2 * (m - diag_m)
    rowsum = jnp.sum(mt, axis=-1, keepdims=True)
    return eye * rowsum - mt


def _kernel(k1_ref, k2_ref, x_ref, v_ref, lam_ref, o_ref, m_ref,
            *, stationary: bool, noise: float):
    p = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((p == 0) & (j == 0))
    def _init():
        m_ref[...] = jnp.zeros_like(m_ref)

    @pl.when(p == 0)
    def _accumulate():
        xl = x_ref[...].astype(jnp.float32) * lam_ref[...].astype(jnp.float32)
        v = v_ref[...].astype(jnp.float32)
        m_ref[...] += jax.lax.dot_general(
            xl, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when((p == 1) & (j == 0))
    def _epilogue():
        m_ref[...] = _small_from_m(m_ref[...], k2_ref[...].astype(jnp.float32),
                                   stationary)

    @pl.when(p == 1)
    def _update():
        k1 = k1_ref[...].astype(jnp.float32)
        v = v_ref[...].astype(jnp.float32)
        x = x_ref[...].astype(jnp.float32)
        acc = jnp.dot(k1, v, preferred_element_type=jnp.float32)
        acc += jnp.dot(m_ref[...], x, preferred_element_type=jnp.float32)
        out = acc * lam_ref[...].astype(jnp.float32)
        if noise:
            out = out + jnp.float32(noise) * v
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("stationary", "noise", "block_d",
                                             "interpret"))
def fused_gram_mvm_padded(
    K1e: Array, K2e: Array, Xt: Array, V: Array, lam: Array,
    *, stationary: bool, noise: float = 0.0, block_d: int = 1024,
    interpret: bool = False,
) -> Array:
    """Single-launch Alg.-2 MVM; pre-padded inputs only (see module doc)."""
    n, d = V.shape
    assert Xt.shape == (n, d) and K1e.shape == (n, n) and K2e.shape == (n, n)
    assert d % block_d == 0, (d, block_d)
    lam2 = jnp.broadcast_to(lam, (d,)).reshape(1, d)
    grid = (2, d // block_d)
    return pl.pallas_call(
        functools.partial(_kernel, stationary=stationary, noise=float(noise)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, n), lambda p, j: (0, 0)),
            pl.BlockSpec((n, n), lambda p, j: (0, 0)),
            pl.BlockSpec((n, block_d), lambda p, j: (0, j)),
            pl.BlockSpec((n, block_d), lambda p, j: (0, j)),
            pl.BlockSpec((1, block_d), lambda p, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((n, block_d), lambda p, j: (0, j * p)),
        out_shape=jax.ShapeDtypeStruct((n, d), _out_dtype(V.dtype)),
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(K1e, K2e, Xt, V, lam2)


def _kernel_multi(k1_ref, k2_ref, x_ref, v_ref, lam_ref, o_ref, m_ref,
                  *, stationary: bool, noise: float):
    p = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((p == 0) & (j == 0))
    def _init():
        m_ref[...] = jnp.zeros_like(m_ref)

    @pl.when(p == 0)
    def _accumulate():
        xl = x_ref[...].astype(jnp.float32) * lam_ref[...].astype(jnp.float32)
        v = v_ref[...].astype(jnp.float32)
        # M[r, a, b] = sum_d (Xt*lam)[a, d] V[r, b, d]
        m_ref[...] += jax.lax.dot_general(
            v, xl, (((2,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ).transpose(0, 2, 1)

    @pl.when((p == 1) & (j == 0))
    def _epilogue():
        m_ref[...] = _small_from_m(m_ref[...], k2_ref[...].astype(jnp.float32),
                                   stationary)

    @pl.when(p == 1)
    def _update():
        k1 = k1_ref[...].astype(jnp.float32)
        v = v_ref[...].astype(jnp.float32)
        x = x_ref[...].astype(jnp.float32)
        # (R, N, bd): K1e @ V_r batches over r; small_r @ Xt batches over r.
        acc = jax.lax.dot_general(
            v, k1, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ).transpose(0, 2, 1)
        acc += jax.lax.dot_general(
            m_ref[...], x, (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        out = acc * lam_ref[...].astype(jnp.float32)
        if noise:
            out = out + jnp.float32(noise) * v
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("stationary", "noise", "block_d",
                                             "interpret"))
def fused_gram_mvm_multi_padded(
    K1e: Array, K2e: Array, Xt: Array, V: Array, lam: Array,
    *, stationary: bool, noise: float = 0.0, block_d: int = 1024,
    interpret: bool = False,
) -> Array:
    """Stacked-RHS Alg.-2 MVM: V (R, N, D) -> W (R, N, D), one launch."""
    r, n, d = V.shape
    assert Xt.shape == (n, d) and K1e.shape == (n, n) and K2e.shape == (n, n)
    assert d % block_d == 0, (d, block_d)
    lam2 = jnp.broadcast_to(lam, (d,)).reshape(1, d)
    grid = (2, d // block_d)
    return pl.pallas_call(
        functools.partial(_kernel_multi, stationary=stationary,
                          noise=float(noise)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, n), lambda p, j: (0, 0)),
            pl.BlockSpec((n, n), lambda p, j: (0, 0)),
            pl.BlockSpec((n, block_d), lambda p, j: (0, j)),
            pl.BlockSpec((r, n, block_d), lambda p, j: (0, 0, j)),
            pl.BlockSpec((1, block_d), lambda p, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((r, n, block_d), lambda p, j: (0, 0, j * p)),
        out_shape=jax.ShapeDtypeStruct((r, n, d), _out_dtype(V.dtype)),
        scratch_shapes=[pltpu.VMEM((r, n, n), jnp.float32)],
        interpret=interpret,
    )(K1e, K2e, Xt, V, lam2)

"""Pallas TPU kernel: fused Gram-MVM second sweep  W = (K1 @ (V*vs) + M @ X) * lam + noise*V.

This is the D-streaming half of paper Alg. 2 (the (N,N) Hadamard/L-operator
algebra happens outside — it is O(N^2) and irrelevant). Fusing the two small
matmuls, the Lambda scaling, the optional per-lane V pre-scale ``vs`` and the
noise ridge into one pass keeps HBM traffic at the roofline (read V, read X,
write W — no intermediates), which is what matters for a memory-bound op.

``vs`` (v_scale) lets Woodbury's  Z = K1i @ (G/lam - corr @ Xt)  run as a
single launch with vs = 1/lam and lam = 1 (see core/woodbury.py); ``noise``
folds the sigma^2 * V ridge of the Gram MVM so no caller needs an extra
O(ND) elementwise pass.

Grid over D-blocks; every block does two (N,N)x(N,block_d) MXU matmuls.
Padding contract as in skinny_gram; K1/M are (N, N) and live in VMEM whole;
vs is zero-padded like lam (padded lanes of V are zero anyway). ``noise``
is a compile-time constant baked into the kernel body.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jnp.ndarray


def _out_dtype(dt):
    """bf16 storage in, f32 out: the accumulator is f32 and the precision
    policy (DESIGN.md sec. 12) never rounds results back to storage."""
    return jnp.float32 if dt == jnp.bfloat16 else dt


def _kernel(k1_ref, m_ref, v_ref, x_ref, lam_ref, vs_ref, o_ref, *, noise: float):
    k1 = k1_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    vs = v * vs_ref[...].astype(jnp.float32)
    acc = jnp.dot(k1, vs, preferred_element_type=jnp.float32)
    acc += jnp.dot(m, x, preferred_element_type=jnp.float32)
    out = acc * lam_ref[...].astype(jnp.float32)
    if noise:
        out = out + jnp.float32(noise) * v
    o_ref[...] = out.astype(o_ref.dtype)


def _small_matmul_kernel(k_ref, v_ref, s_ref, o_ref):
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    out = jnp.dot(k, v, preferred_element_type=jnp.float32)
    o_ref[...] = (out * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def small_matmul_padded(
    K: Array, V: Array, scale: Array,
    *, block_d: int = 1024, interpret: bool = False,
) -> Array:
    """W = (K @ V) * scale — the lean (N,N)x(N,D) stream with a fused
    per-lane epilogue (Kronecker-preconditioner application: scale = 1/lam).

    Exactly one read of V and one write of W; no M/X operands streamed.
    """
    n, d = V.shape
    nq = K.shape[0]
    assert K.shape == (nq, n) and d % block_d == 0, (K.shape, d, block_d)
    s2 = jnp.broadcast_to(scale, (d,)).reshape(1, d)
    grid = (d // block_d,)
    return pl.pallas_call(
        _small_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((nq, n), lambda i: (0, 0)),
            pl.BlockSpec((n, block_d), lambda i: (0, i)),
            pl.BlockSpec((1, block_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((nq, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((nq, d), _out_dtype(V.dtype)),
        interpret=interpret,
    )(K, V, s2)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret", "noise"))
def gram_update_padded(
    K1: Array, M: Array, V: Array, X: Array, lam: Array, vs: Array,
    *, block_d: int = 1024, interpret: bool = False, noise: float = 0.0,
) -> Array:
    """W = (K1 @ (V*vs) + M @ X) * lam + noise*V; V, X: (N, D) streamed.

    K1/M may be rectangular (Nq, N) — the cross-covariance query path —
    in which case W is (Nq, D) and the noise ridge requires Nq == N.
    """
    n, d = V.shape
    nq = K1.shape[0]
    assert X.shape == (n, d) and K1.shape == (nq, n) and M.shape == (nq, n)
    assert d % block_d == 0, (d, block_d)
    assert not noise or nq == n, "noise ridge needs a square update"
    lam2 = jnp.broadcast_to(lam, (d,)).reshape(1, d)
    vs2 = jnp.broadcast_to(vs, (d,)).reshape(1, d)
    grid = (d // block_d,)
    return pl.pallas_call(
        functools.partial(_kernel, noise=float(noise)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((nq, n), lambda i: (0, 0)),
            pl.BlockSpec((nq, n), lambda i: (0, 0)),
            pl.BlockSpec((n, block_d), lambda i: (0, i)),
            pl.BlockSpec((n, block_d), lambda i: (0, i)),
            pl.BlockSpec((1, block_d), lambda i: (0, i)),
            pl.BlockSpec((1, block_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((nq, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((nq, d), _out_dtype(V.dtype)),
        interpret=interpret,
    )(K1, M, V, X, lam2, vs2)

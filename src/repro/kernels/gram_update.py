"""Pallas TPU kernel: fused Gram-MVM second sweep  W = (K1 @ V + M @ X) * lam.

This is the D-streaming half of paper Alg. 2 (the (N,N) Hadamard/L-operator
algebra happens outside — it is O(N^2) and irrelevant). Fusing the two small
matmuls and the Lambda scaling into one pass halves HBM traffic vs. the
naive two-pass form (read V, read X, write W — no intermediates), which is
what matters for a memory-bound op.

Grid over D-blocks; every block does two (N,N)x(N,block_d) MXU matmuls.
Padding contract as in skinny_gram; K1/M are (N, N) and live in VMEM whole.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jnp.ndarray


def _kernel(k1_ref, m_ref, v_ref, x_ref, lam_ref, o_ref):
    k1 = k1_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    acc = jnp.dot(k1, v, preferred_element_type=jnp.float32)
    acc += jnp.dot(m, x, preferred_element_type=jnp.float32)
    o_ref[...] = (acc * lam_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def gram_update_padded(
    K1: Array, M: Array, V: Array, X: Array, lam: Array,
    *, block_d: int = 1024, interpret: bool = False,
) -> Array:
    """W = (K1 @ V + M @ X) * lam with V, X: (N, D) streamed over D-blocks."""
    n, d = V.shape
    assert X.shape == (n, d) and K1.shape == (n, n) and M.shape == (n, n)
    assert d % block_d == 0, (d, block_d)
    lam2 = jnp.broadcast_to(lam, (d,)).reshape(1, d)
    grid = (d // block_d,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, n), lambda i: (0, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
            pl.BlockSpec((n, block_d), lambda i: (0, i)),
            pl.BlockSpec((n, block_d), lambda i: (0, i)),
            pl.BlockSpec((1, block_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((n, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n, d), V.dtype),
        interpret=interpret,
    )(K1, M, V, X, lam2)

"""Pallas TPU kernels for the paper's compute hot spots (see DESIGN.md §4).

Each kernel ships with a pure-jnp oracle in ``ref.py``; ``ops.py`` holds the
padded, jit'd public entry points. Validated in interpret mode on CPU and
shaped for TPU v5e VMEM/MXU on the real target. The core inference engine
reaches these through ``repro.core.backend`` — never call them from core
modules directly, so the jnp oracle path stays a drop-in fallback.
"""
from .ops import (
    DEFAULT_VMEM_BUDGET,
    fused_factor_build,
    fused_factor_build_ref,
    fused_gram_mvm,
    fused_gram_mvm_multi,
    fused_gram_mvm_ref,
    fused_gram_norms,
    fused_gram_norms_ref,
    gram_update,
    gram_update_ref,
    skinny_gram,
    skinny_gram_ref,
    small_matmul,
)

__all__ = [
    "DEFAULT_VMEM_BUDGET",
    "fused_factor_build", "fused_factor_build_ref",
    "fused_gram_mvm", "fused_gram_mvm_multi", "fused_gram_mvm_ref",
    "fused_gram_norms", "fused_gram_norms_ref", "gram_update",
    "gram_update_ref", "skinny_gram", "skinny_gram_ref", "small_matmul",
]

"""Pallas TPU kernels for the paper's compute hot spots (see DESIGN.md §3).

Each kernel ships with a pure-jnp oracle in ``ref.py``; ``ops.py`` holds the
padded, jit'd public entry points. Validated in interpret mode on CPU and
shaped for TPU v5e VMEM/MXU on the real target.
"""
from .ops import (
    fused_gram_norms,
    fused_gram_norms_ref,
    gram_update,
    gram_update_ref,
    skinny_gram,
    skinny_gram_ref,
)

__all__ = [
    "fused_gram_norms", "fused_gram_norms_ref", "gram_update",
    "gram_update_ref", "skinny_gram", "skinny_gram_ref",
]

"""Pallas TPU kernel: tall-skinny scaled Gram  P = (A * lam) @ B^T.

THE hot contraction of the paper's method (DESIGN.md sec. 3): every O(D)
object only appears inside this product. A: (Na, D), B: (Nb, D) with
Na, Nb <= ~128 and D ~ 1e6..1e9 (per-device shard).

TPU adaptation: the MXU wants 128x128 tiles but Na/Nb are tiny, so the
kernel is *memory-bound by construction* (arithmetic intensity ~ Na flops
per byte of B-stream). The grid runs over D-blocks (lane-major streaming);
an (Na, Nb) f32 accumulator lives in the output VMEM block across grid
steps (revisiting pattern), so HBM sees exactly one read of A and B and a
single small write — the HBM roofline, which is the best achievable.

Padding contract (enforced by ops.py): Na, Nb multiples of 8, D a multiple
of block_d, lam zero-padded (zero lam rows exactly cancel padded columns).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jnp.ndarray


def _kernel(a_ref, b_ref, lam_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.float32) * lam_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    o_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def skinny_gram_padded(
    A: Array, B: Array, lam: Array, *, block_d: int = 1024, interpret: bool = False
) -> Array:
    """P[a, b] = sum_d A[a, d] * lam[d] * B[b, d]; pre-padded inputs only."""
    na, d = A.shape
    nb, _ = B.shape
    assert d % block_d == 0, (d, block_d)
    lam2 = jnp.broadcast_to(lam, (d,)).reshape(1, d)
    grid = (d // block_d,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((na, block_d), lambda i: (0, i)),
            pl.BlockSpec((nb, block_d), lambda i: (0, i)),
            pl.BlockSpec((1, block_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((na, nb), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((na, nb), jnp.float32),
        interpret=interpret,
    )(A, B, lam2)

"""Matrix-free Krylov solves on the gradient Gram — the iterative regime.

Past the crossover (``regime/policy.py``) the (N^2, N^2) inner matrix of
the exact Woodbury path is the bottleneck; this module is the replacement
solve layer.  Everything is driven through the existing fused Gram MVM
megakernel (``core/mvm.py::gram_matvec`` — ONE ``backend.fused_gram_mvm``
launch per operator application on the pallas backend):

  * :func:`posterior_solve`  — preconditioned CG for the representers
    ``(grad K grad' + noise I) vec(Z) = vec(G)``, warm-started from a
    cached solution and preconditioned by the last exact Cholesky factor
    of K1n when the caller has one (the incremental state always does),
    falling back to the free Kronecker preconditioner otherwise.  Block
    (stacked-RHS) right-hand sides ride the multi-RHS fused MVM.
  * :func:`lanczos_tridiag`  — fixed-step Lanczos with full two-pass
    reorthogonalization; the engine under ``regime/slq.py``'s stochastic
    quadrature.
  * :func:`assert_streaming_structure` — the N > D mirror image of
    ``hyper.mll.assert_no_dense_gram``: traces a solve and proves at the
    jaxpr level that no intermediate materializes the (ND, ND) Gram, the
    (N^2, N^2) inner matrix, or any other dense N^2-axis object.

Shapes never flatten to (ND,): vectors stay (N, D) arrays end to end
(inner products via per-element contractions), which is what makes the
structural bound below tight.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.scipy.linalg import cho_solve

from repro.core.gram import GramFactors
from repro.core.mvm import gram_matvec, gram_matvec_multi
from repro.core.solvers import CGResult, cg, _kron_precond_fn
from repro.obs import injit as _obs_tap

Array = jnp.ndarray

_TINY = 1e-30


class KrylovResult(NamedTuple):
    """A posterior solve from the iterative regime."""

    Z: Array          # representers, same shape as the RHS
    iters: Array      # CG iterations actually taken
    resnorm: Array    # final residual norm


def _gram_mv(spec, f: GramFactors, noise) -> Callable[[Array], Array]:
    """vec(V) -> (grad K grad' + noise I) vec(V), one fused launch.

    ``noise`` already folded into ``f.noise`` is the common case (the
    factors carry the effective noise); an explicit traced ``noise`` rides
    outside as one axpy, mirroring ``core.state._solve``.
    """
    if noise is None:
        return lambda V: gram_matvec(f, V, stationary=spec.is_stationary)
    return lambda V: (gram_matvec(f, V, stationary=spec.is_stationary)
                      + noise * V)


def posterior_solve(
    spec,
    f: GramFactors,
    rhs: Array,
    *,
    z0: Optional[Array] = None,
    L: Optional[Array] = None,
    noise=None,
    tol: float = 1e-8,
    maxiter: Optional[int] = None,
    jitter: float = 1e-10,
) -> KrylovResult:
    """Matrix-free preconditioned CG for the representers.

    ``L`` is the lower Cholesky of K1n = K1e + (noise_eff/lam) I — the
    incremental state maintains it in O(N^2) per extend, and here it is
    the preconditioner ``B^{-1} vec(V) = cho_solve(L, V)/lam`` (two
    triangular sweeps per iteration; the paper's free Kronecker factor
    applied through the cached factorization).  Without ``L`` the dense
    Kronecker preconditioner of ``core.solvers`` is built once (O(N^3)).

    ``rhs`` may be (N, D) or a stacked (R, N, D) block — the block solve
    runs ONE multi-RHS fused MVM per iteration for all R systems.
    Warm start ``z0`` defaults to zeros.  Per-iteration work is
    O(N^2 D + N^2); nothing here carries an axis larger than max(N, D)
    (proven by :func:`assert_streaming_structure`).
    """
    rhs = jnp.asarray(rhs)
    n, d = rhs.shape[-2:]
    if maxiter is None:
        maxiter = 10 * n + 50
    if rhs.ndim == 3:
        mv = (lambda V: gram_matvec_multi(f, V,
                                          stationary=spec.is_stationary))
        if noise is not None:
            base = mv
            mv = lambda V: base(V) + noise * V
    else:
        mv = _gram_mv(spec, f, noise)
    if L is not None:
        lam = jnp.asarray(f.lam)
        one = lambda V: cho_solve((L, True), V) / lam
        M_inv = one if rhs.ndim == 2 else (lambda V: jax.vmap(one)(V))
    else:
        M_inv = _kron_precond_fn(f, n, rhs.dtype, jitter)
    res: CGResult = cg(mv, rhs, x0=z0, tol=tol, maxiter=int(maxiter),
                       M_inv=M_inv)
    _obs_tap.tap("regime.cg_iters", res.iters, kind="hist")
    _obs_tap.tap("regime.cg_resnorm", res.resnorm)
    return KrylovResult(Z=res.x, iters=res.iters, resnorm=res.resnorm)


# ---------------------------------------------------------------------------
# Lanczos tridiagonalization (the SLQ engine)
# ---------------------------------------------------------------------------


def lanczos_tridiag(
    mv: Callable[[Array], Array],
    v0: Array,
    m: int,
) -> tuple[Array, Array, Array]:
    """m-step Lanczos on the SPD operator ``mv``; returns (alpha, beta, |v0|).

    ``alpha`` (m,) and ``beta`` (m-1,) are the tridiagonal coefficients of
    T_m = Q^T A Q for the Krylov basis grown from ``v0``.  Full two-pass
    reorthogonalization against the stored basis keeps the Ritz values
    honest at the f32/f64 precision the caller runs at — the basis is
    (m+1, N, D), so memory is m small multiples of the data itself and no
    axis ever exceeds max(m+1, N, D).  Iterates stay in the operand's
    natural (N, D) shape (never flattened to ND).
    """
    v0 = jnp.asarray(v0)
    nrm = jnp.sqrt(jnp.sum(v0 * v0))
    q0 = v0 / jnp.maximum(nrm, _TINY)
    Q = jnp.zeros((m + 1,) + v0.shape, v0.dtype).at[0].set(q0)

    def body(carry, i):
        Q, beta_prev = carry
        q = Q[i]
        w = mv(q) - beta_prev * Q[jnp.maximum(i - 1, 0)] * (i > 0)
        alpha = jnp.sum(q * w)
        w = w - alpha * q
        # two passes of classical Gram-Schmidt against the whole stored
        # basis (rows > i are zero, so the extra projections are no-ops)
        for _ in range(2):
            coef = jnp.sum(Q * w, axis=tuple(range(1, w.ndim + 1)))
            w = w - jnp.tensordot(coef, Q, axes=(0, 0))
        beta = jnp.sqrt(jnp.sum(w * w))
        q_next = w / jnp.maximum(beta, _TINY)
        Q = Q.at[i + 1].set(q_next)
        return (Q, beta), (alpha, beta)

    (_, _), (alphas, betas) = jax.lax.scan(body, (Q, jnp.zeros((), v0.dtype)),
                                           jnp.arange(m))
    return alphas, betas[:-1], nrm


# ---------------------------------------------------------------------------
# Structural gate: the iterative path is matrix-free, provably
# ---------------------------------------------------------------------------


def assert_streaming_structure(
    fn: Callable,
    *args,
    n: int,
    d: int,
    stack: int = 1,
) -> tuple[int, int]:
    """Trace ``fn(*args)`` and prove it never materializes a dense object.

    Two bounds over every jaxpr variable (recursing into scan/cond/jit
    sub-jaxprs):

      * no single axis exceeds N*D — the (N^2, N^2) inner matrix carries
        an N^2 axis, which is > ND exactly in the N > D regime this gate
        serves (mirror image of ``assert_no_dense_gram``'s N < D
        requirement);
      * no variable exceeds ``max(stack, ceil(N/D) + 1) * N * D`` total
        elements — the (ND, ND) Gram has (ND)^2 elements, astronomically
        past the bound, while every legitimate object is a small stack of
        (N, D) operands or an (N, N) strip: callers pass ``stack`` >=
        their deepest stack (m+2 for an m-step Lanczos basis, the probe
        count for SLQ; the default 1 fits a bare CG solve).

    Raises ``hyper.mll.StructureError`` on violation, ``ValueError`` when
    N <= D (the axis bound would not separate the inner matrix from the
    Gram).  Returns (max_axis, max_size) actually seen.
    """
    from repro.hyper.mll import StructureError
    from repro.utils.hlo import jaxpr_axis_sizes, jaxpr_var_sizes

    n, d = int(n), int(d)
    nd = n * d
    if n <= d:
        raise ValueError(
            f"streaming structural check needs N > D to be meaningful "
            f"(N={n}, D={d}: the forbidden N^2={n * n} inner axis must "
            f"exceed ND={nd})")
    closed = jax.make_jaxpr(fn)(*args)
    dims = jaxpr_axis_sizes(closed.jaxpr)
    sizes = jaxpr_var_sizes(closed.jaxpr)
    max_axis = max(dims) if dims else 0
    max_size = max(sizes) if sizes else 0
    if max_axis > nd:
        raise StructureError(
            f"iterative-regime trace materialized an axis of size "
            f"{max_axis} > N*D={nd} — the matrix-free path must never "
            f"build the (N^2, N^2) inner operator")
    budget = max(int(stack), (n + d - 1) // d + 1, 1) * nd
    if max_size > budget:
        raise StructureError(
            f"iterative-regime trace materialized a variable of "
            f"{max_size} elements > {budget} (stack={stack} x ND={nd}) — "
            f"a dense Gram-sized object slipped into the jaxpr")
    return max_axis, max_size

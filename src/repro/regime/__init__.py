"""repro.regime — regime-aware large-N solving (DESIGN.md sec. 16).

The paper's exact decomposition is an N < D (low-data) story: past that
ceiling the (N^2, N^2) inner matrix of the Woodbury/determinant-lemma
path dominates everything.  This package is the escape:

  policy.py     — analytic flop-model crossover between the exact and
                  iterative paths + the window-capacity action policy
                  ({evict, compress, iterate}); emits ``regime.*`` obs.
  krylov.py     — matrix-free block-CG/Lanczos solves through the fused
                  Gram MVM, warm-started and Cholesky-preconditioned;
                  jaxpr-level structural proof of matrix-freeness.
  slq.py        — stochastic Lanczos quadrature evidence + Hutchinson
                  hyper-gradients (the MLL fit past the ceiling).
  reduction.py  — exact gradient reduction onto the observed subspace
                  (compression instead of eviction when the data's
                  affine rank allows it).

``solve`` below is the one-call regime dispatcher for batch solves;
the incremental ``core.state.GPGState`` wires the same policy through
its streaming extend/evict/refit loop.
"""
from __future__ import annotations

from typing import Optional, Union

from .krylov import (KrylovResult, assert_streaming_structure,
                     lanczos_tridiag, posterior_solve)
from .policy import CostModel, RegimePolicy, resolve_policy
from .reduction import (Reduction, affine_rank, lift_gradients, lift_points,
                        project_points, reduce_gradients, subspace_basis)
from .slq import (DEFAULT_LANCZOS_ITERS, DEFAULT_PROBES, make_slq_mll_fn,
                  slq_logdet_mv, slq_mll)

__all__ = [
    "CostModel", "RegimePolicy", "resolve_policy", "solve",
    "KrylovResult", "posterior_solve", "lanczos_tridiag",
    "assert_streaming_structure",
    "slq_mll", "make_slq_mll_fn", "slq_logdet_mv",
    "DEFAULT_PROBES", "DEFAULT_LANCZOS_ITERS",
    "Reduction", "reduce_gradients", "affine_rank", "subspace_basis",
    "project_points", "lift_gradients", "lift_points",
]


def solve(
    spec,
    f,
    G,
    *,
    policy: Union[None, str, "RegimePolicy"] = None,
    z0=None,
    L=None,
    tol: float = 1e-8,
    maxiter: Optional[int] = None,
    jitter: float = 1e-10,
):
    """Solve (grad K grad') vec(Z) = vec(G) on whichever path the policy
    picks for this (N, D); returns (Z, info).

    ``info`` carries {"regime", "iters", "resnorm"} (iters/resnorm are
    None on the exact path — it is direct).  The factors' own ``noise``
    rides through both paths identically.
    """
    n, d = f.Xt.shape
    pol = resolve_policy(policy)
    regime = pol.regime_for(n, d)
    pol.publish(n, d, regime)
    if regime == "exact":
        from repro.core.woodbury import woodbury_solve

        Z = woodbury_solve(spec, f, G, jitter=jitter)
        return Z, {"regime": "exact", "iters": None, "resnorm": None}
    res = posterior_solve(spec, f, G, z0=z0, L=L, tol=tol, maxiter=maxiter,
                          jitter=jitter)
    info = {"regime": "iterative", "iters": res.iters,
            "resnorm": res.resnorm, "fallback": False}
    from repro.resilience import guardrails as _guard

    if _guard.enabled():
        # CG-divergence watchdog: a non-finite (or wildly regressed)
        # residual means the Krylov iteration has been poisoned (bad warm
        # start, degenerate preconditioner); the exact Woodbury path is
        # always available as a correct-if-slower fallback.
        import jax.numpy as jnp

        rhs_norm = float(jnp.linalg.norm(jnp.asarray(G, jnp.float64)))
        if _guard.cg_diverged(float(res.resnorm), rhs_norm):
            from repro.core.woodbury import woodbury_solve
            from repro.obs import trace as _trace

            _trace.REGISTRY.inc("resilience.cg_fallback")
            _guard.record_recovery("cg_divergence", n=n, d=d)
            Z = woodbury_solve(spec, f, G, jitter=jitter)
            return Z, {"regime": "exact", "iters": None, "resnorm": None,
                       "fallback": True}
    return res.Z, info

"""Analytic cost-model regime selection: exact vs. iterative solve paths.

The paper's exact decomposition (Sec. 3-4) routes every batch solve and
the evidence through the (N^2, N^2) determinant-lemma inner matrix:

    exact      O( c_sweep N^2 D  +  c_build N^4  +  c_factor N^6 )

(the fused strip sweeps, materializing the inner operator from the
strips, and its dense LU).  The matrix-free alternative iterates the
fused Gram MVM (``core/mvm.py``) with the free Kronecker preconditioner:

    iterative  O( iters * c_mvm N^2 D  +  c_chol N^3 )

Both are *deterministic flop polynomials in (N, D)* — no measurement
needed — so the crossover point N* where the iterative path becomes
cheaper is a pure function of D and the planned iteration count.  That is
the regime boundary: :class:`RegimePolicy` picks ``"exact"`` below it and
``"iterative"`` at/above it, per state revision, and
``tools/check_telemetry.py --expect-regime-switch-at N*`` asserts the
live ``regime.switch`` events agree with the model exactly.

The same policy object owns the *capacity action* — what a windowed
``GPGState`` does when the window is full.  Window eviction (PR 3) is
demoted from the only escape hatch to one policy among

    'evict'     drop the oldest observation (the PR-3 sliding window)
    'compress'  exact gradient reduction into the observed affine span
                (``regime/reduction.py``) — lossless for in-span queries
    'iterate'   stop enforcing the window; let N grow past the ceiling
                and let the regime crossover absorb the cost

with ``'auto'`` choosing: compress when the data's affine rank says the
D axis is collapsible, otherwise iterate when the iterative path can
absorb the growth, otherwise evict.

Everything here is host-side python over static ints — policies never
enter a jaxpr, so regime decisions can never cause a recompile by
themselves (the solve-path shapes are what matter, and those are
capacity-keyed, not regime-keyed; asserted in tests/test_regime.py).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import NamedTuple, Optional, Union

from repro.obs import trace as _obs


class CostModel(NamedTuple):
    """Flop-polynomial coefficients of the two solve paths.

    The defaults are operation counts read off the implementations, not
    tuned constants: one fused factor sweep touches each of the N^2 strip
    entries with O(D) work (``backend.fused_factor_build``); the inner
    operator is N^4 strip products (``hyper.mll.inner_matrix``); its LU
    is the classic 2/3 (N^2)^3; one fused Gram MVM is ~6 flops per
    (N, N, D) triple (two skinny matmuls + the Kronecker axpy); the
    preconditioner's two triangular sweeps cost ~2 N^2 D per iteration.
    """

    sweep: float = 2.0       # exact: strip build, per N^2 D
    build: float = 4.0       # exact: inner-operator materialize, per N^4
    factor: float = 2.0 / 3.0  # exact: dense LU of (N^2, N^2), per N^6
    mvm: float = 6.0         # iterative: fused Gram MVM, per N^2 D per iter
    precond: float = 2.0     # iterative: Kronecker precond, per N^2 D per iter
    chol: float = 1.0 / 3.0  # iterative: one N x N Cholesky, per N^3

    def exact_flops(self, n: int, d: int) -> float:
        n, d = float(n), float(d)
        return (self.sweep * n * n * d + self.build * n ** 4
                + self.factor * n ** 6)

    def iterative_flops(self, n: int, d: int, iters: int) -> float:
        n, d = float(n), float(d)
        return (float(iters) * (self.mvm + self.precond) * n * n * d
                + self.chol * n ** 3)

    def iterative_hbm_bytes(self, n: int, d: int, iters: int,
                            itemsize: int = 4) -> int:
        """Modeled HBM traffic of one iterative solve: per iteration the
        fused MVM streams 5 (N, D) operands plus the two (N, N) strips
        (DESIGN.md sec. 4.3), and the preconditioner reads L (N, N) and
        streams V in/out (2 ND)."""
        per_iter = (5 * n * d + 2 * n * n) + (n * n + 2 * n * d)
        return int(iters) * int(per_iter) * int(itemsize)


@lru_cache(maxsize=256)
def _crossover_n(cost: CostModel, d: int, iters: int, n_max: int) -> int:
    """Smallest N where the iterative path is modeled cheaper than exact.

    The difference exact - iterative is a polynomial whose N^6 term
    eventually dominates, so a single upward scan finds the first (and
    by monotonicity-at-scale, permanent) crossing; ``n_max`` bounds the
    scan and is returned when the exact path never loses (tiny D with
    huge planned iteration counts).
    """
    for n in range(1, int(n_max) + 1):
        if cost.iterative_flops(n, d, iters) < cost.exact_flops(n, d):
            return n
    return int(n_max)


_CAPACITY_ACTIONS = ("evict", "compress", "iterate", "auto")
_MODES = ("auto", "exact", "iterative")


@dataclasses.dataclass(frozen=True)
class RegimePolicy:
    """Which solve path, and what to do when a window fills.

    ``mode``            'auto' (cost-model crossover) or a forced regime.
    ``capacity``        'evict' | 'compress' | 'iterate' | 'auto'.
    ``planned_iters``   the iteration budget the cost model charges the
                        iterative path with (NOT a solver limit — solver
                        limits live on ``GPGState.tol/maxiter``).  Static
                        so the crossover is deterministic and auditable.
    ``compress_margin`` 'compress' fires only when the affine rank of the
                        stored data is <= margin * min(n, d) — compression
                        must actually shrink the problem to be worth a
                        refactor.
    ``n_max``           crossover-scan ceiling (the crossover for any
                        realistic (D, iters) is far below it).
    """

    mode: str = "auto"
    capacity: str = "evict"
    cost: CostModel = CostModel()
    planned_iters: int = 32
    compress_margin: float = 0.75
    n_max: int = 4096

    def __post_init__(self):
        if self.mode not in _MODES:
            raise ValueError(f"mode must be one of {_MODES}: {self.mode!r}")
        if self.capacity not in _CAPACITY_ACTIONS:
            raise ValueError(
                f"capacity must be one of {_CAPACITY_ACTIONS}: "
                f"{self.capacity!r}")

    # -- the crossover ------------------------------------------------------

    def crossover_n(self, d: int) -> int:
        """The modeled regime boundary N*(D): exact below, iterative at/
        above.  Deterministic (pure flop model) — this exact value is what
        telemetry asserts the live switch events fire at."""
        return _crossover_n(self.cost, int(d), int(self.planned_iters),
                            self.n_max)

    def regime_for(self, n: int, d: int) -> str:
        """'exact' | 'iterative' for a state holding n observations."""
        if self.mode != "auto":
            return self.mode
        return "iterative" if int(n) >= self.crossover_n(d) else "exact"

    # -- capacity action ----------------------------------------------------

    def capacity_action(self, n: int, d: int,
                        rank: Optional[int] = None) -> str:
        """Resolve what a full window should do ('evict' | 'compress' |
        'iterate').  ``rank`` is the affine rank of the stored data when
        the caller has it (``regime.reduction.affine_rank``); without it,
        'auto' never compresses (rather than guessing)."""
        act = self.capacity
        if act != "auto":
            if act == "compress" and not self._compressible(n, d, rank):
                return "evict"      # nothing to fold away: degrade safely
            return act
        if self._compressible(n, d, rank):
            return "compress"
        # growth is absorbable when the iterative path's marginal cost at
        # n+1 beats the exact path's (i.e. we are at/past the crossover,
        # where appending is cheaper than the information loss of evicting)
        if int(n) + 1 >= self.crossover_n(d):
            return "iterate"
        return "evict"

    def _compressible(self, n: int, d: int, rank: Optional[int]) -> bool:
        if rank is None:
            return False
        return int(rank) <= self.compress_margin * min(int(n), int(d))

    # -- observability ------------------------------------------------------

    def publish(self, n: int, d: int, regime: str, *,
                prev: Optional[str] = None) -> None:
        """Export ``regime.*`` gauges (and a switch event when ``prev``
        differs).  Host-side, obs-gated — free when observability is off."""
        if not _obs.enabled():
            return
        xover = self.crossover_n(d) if self.mode == "auto" else -1
        _obs.REGISTRY.set_gauge("regime.active",
                                1.0 if regime == "iterative" else 0.0)
        _obs.REGISTRY.set_gauge("regime.crossover_n", float(xover))
        if prev is not None and prev != regime:
            _obs.REGISTRY.inc("regime.switches")
            _obs.emit({"type": "regime", "event": "switch", "n": int(n),
                       "d": int(d), "from": prev, "to": regime,
                       "crossover_n": int(xover)})


def resolve_policy(
    policy: Union[None, str, RegimePolicy],
    *,
    window: Optional[int] = None,
) -> RegimePolicy:
    """Normalize the ``GPGState(policy=...)`` knob.

    ``None`` keeps the PR-3 behavior (windowed states evict; unwindowed
    states grow). A string names either a capacity action ('evict' /
    'compress' / 'iterate' / 'auto') or a forced regime ('exact' /
    'iterative'); a :class:`RegimePolicy` passes through untouched.
    """
    if isinstance(policy, RegimePolicy):
        return policy
    if policy is None:
        return RegimePolicy(capacity="evict" if window else "iterate")
    if isinstance(policy, str):
        if policy in _CAPACITY_ACTIONS:
            return RegimePolicy(capacity=policy)
        if policy in ("exact", "iterative"):
            return RegimePolicy(mode=policy,
                                capacity="evict" if window else "iterate")
        raise ValueError(
            f"unknown policy {policy!r}: expected one of "
            f"{_CAPACITY_ACTIONS + ('exact', 'iterative')} or a RegimePolicy")
    raise TypeError(f"policy must be None, str or RegimePolicy: {policy!r}")

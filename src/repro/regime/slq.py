"""Stochastic Lanczos quadrature evidence — MLL past the exact ceiling.

The exact structured MLL (``hyper/mll.py``) pays O((N^2)^3) for the
determinant-lemma inner matrix; past the regime crossover that is the
bottleneck.  This module replaces it with the classic SLQ estimator
(Ubaru, Chen & Saad 2017) driven entirely through the fused Gram MVM:

    logdet K'  ~  (1/P) sum_p  |v_p|^2  e_1^T log(T_p) e_1,

where K' = grad K grad' + noise_eff I is the UNSCALED noisy Gram,
v_p are Rademacher probes (shape (N, D) — never flattened), and T_p is
the m-step Lanczos tridiagonalization of K' started at v_p
(``regime/krylov.py::lanczos_tridiag``).  The signal variance re-enters
through the same scaling identity as the exact path:

    logdet K = ND log s^2 + logdet K',      quad = quad' / s^2,

with quad' = vec(G)^T K'^{-1} vec(G) from one preconditioned CG solve.
Cost: P Lanczos runs of m fused MVMs each + one CG solve — O(P m N^2 D)
versus the exact path's O(N^6), and O(m N D) memory.

Hyper-gradients do NOT differentiate through Lanczos (unstable and
pointless).  :func:`make_slq_mll_fn` wires a ``jax.custom_vjp`` whose
backward pass is the Hutchinson trace estimator sharing the forward
pass's probes and solves:

    d mll / d theta = -1/2 ( -alpha^T dK alpha + tr(K^{-1} dK) ),
    tr(K^{-1} dK)  ~  (1/P) sum_p u_p^T dK v_p,   u_p = K^{-1} v_p,

implemented as the exact gradient of the surrogate
``-1/2 (-alpha^T K(theta) alpha + (1/P) sum_p u_p^T K(theta) v_p)`` with
alpha and u_p held constant — the standard estimator of GPyTorch-style
iterative GP inference, here on the structured (never materialized) Gram.
Probes are FIXED by the caller's PRNG key, so the estimator is
deterministic given (key, P, m) and smooth across fit steps.

Everything runs under the jnp backend (reverse-mode differentiability;
the pallas kernels are forward-only), mirroring ``hyper.mll.mll``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import backend
from repro.core.gram import build_factors
from repro.core.kernels import KernelSpec, get_kernel
from repro.core.mvm import gram_matvec
from repro.core.solvers import cg
from repro.hyper.params import LOG2PI, HyperParams
from repro.obs import injit as _obs_tap

Array = jnp.ndarray

#: Defaults chosen so the N=96, D=32 bench lands well inside the 1%-of-
#: oracle gate (BENCH_regime.json); bump for smaller noise floors.
DEFAULT_PROBES = 8
DEFAULT_LANCZOS_ITERS = 48


def _as_spec(kernel) -> KernelSpec:
    return get_kernel(kernel) if isinstance(kernel, str) else kernel


def rademacher_probes(key, shape, dtype) -> Array:
    """+-1 probe block of ``shape`` — E[v v^T] = I, the Hutchinson choice
    with minimal variance among +-1 distributions."""
    return jnp.asarray(
        jax.random.rademacher(key, shape, dtype=jnp.int8), dtype)


def slq_logdet_mv(mv, probes: Array, lanczos_iters: int) -> Array:
    """SLQ logdet of the SPD operator ``mv`` from a (P, ...) probe stack.

    Per probe: m Lanczos steps (full reorthogonalization), an (m, m)
    symmetric tridiagonal eigendecomposition, and the Gauss-quadrature
    weights |W[0, :]|^2 — the first-row eigenvector mass — against
    log(eigenvalues).  Eigenvalues are clamped at a tiny floor: K' is
    SPD by construction (noise_eff > 0), so a nonpositive Ritz value is
    roundoff, not signal.
    """
    from .krylov import lanczos_tridiag

    m = int(lanczos_iters)

    def one(v):
        alpha, beta, nrm = lanczos_tridiag(mv, v, m)
        T = (jnp.diag(alpha) + jnp.diag(beta, 1) + jnp.diag(beta, -1))
        theta, W = jnp.linalg.eigh(T)
        weights = W[0, :] ** 2
        return nrm * nrm * jnp.sum(
            weights * jnp.log(jnp.maximum(theta, 1e-30)))

    ests = jax.vmap(one)(probes)
    _obs_tap.tap("slq.probes", probes.shape[0], kind="counter")
    _obs_tap.tap("slq.lanczos_iters", m, kind="hist")
    return jnp.mean(ests)


def _unscaled_mv(spec, X, lam, noise_eff, c):
    """W -> (grad K grad'(lam) + noise_eff I) W through the fused MVM."""
    f = build_factors(spec, X, lam=lam, c=c)
    return (lambda W: gram_matvec(f, W, stationary=spec.is_stationary)
            + noise_eff * W), f


def _kron_precond(f, noise_eff, n, dtype):
    """The free Kronecker preconditioner of the unscaled noisy system."""
    K1 = f.K1e + (noise_eff / jnp.asarray(f.lam) + 1e-12) * jnp.eye(
        n, dtype=dtype)
    K1i = jnp.linalg.inv(K1)
    return lambda V: backend.kron_precond(K1i, V, f.lam)


def make_slq_mll_fn(
    kernel,
    X: Array,
    G: Array,
    *,
    key=None,
    probes: int = DEFAULT_PROBES,
    lanczos_iters: int = DEFAULT_LANCZOS_ITERS,
    cg_tol: float = 1e-10,
    cg_maxiter: Optional[int] = None,
    c: Optional[Array] = None,
):
    """hypers -> SLQ mll closure with Hutchinson hyper-gradients.

    Drop-in for ``hyper.mll.make_mll_fn`` where the exact inner matrix is
    unaffordable: ``jax.grad`` of the returned closure is the Hutchinson
    gradient estimator described in the module docstring, safe under jit
    and inside ``hyper.fit.fit_fn`` / ``fit_scan_fn``.  The probe block is
    drawn ONCE from ``key`` (default: key 0) and reused by every call —
    deterministic, and what keeps the fit trajectory smooth.
    """
    spec = _as_spec(kernel)
    X = jnp.asarray(X)
    G = jnp.asarray(G)
    n, d = X.shape
    nd = n * d
    if key is None:
        key = jax.random.PRNGKey(0)
    V = rademacher_probes(key, (int(probes), n, d), X.dtype)
    maxiter = int(cg_maxiter) if cg_maxiter is not None else 10 * n + 50

    def _solves(h: HyperParams):
        """Forward-pass work: SLQ logdet + the CG solves both passes share.

        Runs on stop-gradient hypers — the value is exact in them, and the
        backward pass differentiates the surrogate instead.
        """
        lam = jax.lax.stop_gradient(h.lam)
        ne = jax.lax.stop_gradient(h.noise_eff)
        with backend.use_backend("jnp"):
            mv, f = _unscaled_mv(spec, X, lam, ne, c)
            M_inv = _kron_precond(f, ne, n, X.dtype)
            ld_u = slq_logdet_mv(mv, V, lanczos_iters)
            alpha_u = cg(mv, G, tol=cg_tol, maxiter=maxiter, M_inv=M_inv).x
            U_u = jax.vmap(
                lambda b: cg(mv, b, tol=cg_tol, maxiter=maxiter,
                             M_inv=M_inv).x)(V)
        return ld_u, alpha_u, U_u

    def _value(h: HyperParams, ld_u, alpha_u):
        quad = jnp.sum(G * alpha_u) / h.signal
        logdet = nd * h.log_signal + ld_u
        return -0.5 * (quad + logdet + nd * LOG2PI)

    @jax.custom_vjp
    def mll_slq(h: HyperParams):
        ld_u, alpha_u, _ = _solves(h)
        return _value(h, ld_u, alpha_u)

    def fwd(h):
        ld_u, alpha_u, U_u = _solves(h)
        return _value(h, ld_u, alpha_u), (h, alpha_u, U_u)

    def bwd(res, ct):
        h, alpha_u, U_u = res
        # constants of the surrogate: alpha = K^{-1} g and u_p = K^{-1} v_p
        # in the SCALED system K = s^2 K' (so /signal), gradients stopped
        sig = jax.lax.stop_gradient(h.signal)
        alpha = jax.lax.stop_gradient(alpha_u) / sig
        U = jax.lax.stop_gradient(U_u) / sig

        def surrogate(hh: HyperParams):
            with backend.use_backend("jnp"):
                f = build_factors(spec, X, lam=hh.lam, c=c)
                mv = lambda W: (
                    hh.signal
                    * gram_matvec(f, W, stationary=spec.is_stationary)
                    + hh.noise * W)
                t_quad = -jnp.sum(alpha * mv(alpha))
                t_tr = jnp.mean(jax.vmap(
                    lambda u, v: jnp.sum(u * mv(v)))(U, V))
            return -0.5 * (t_quad + t_tr)

        g = jax.grad(surrogate)(res[0])
        return (jax.tree_util.tree_map(lambda x: ct * x, g),)

    mll_slq.defvjp(fwd, bwd)
    return mll_slq


def slq_mll(
    kernel,
    X: Array,
    G: Array,
    hypers: HyperParams,
    *,
    key=None,
    probes: int = DEFAULT_PROBES,
    lanczos_iters: int = DEFAULT_LANCZOS_ITERS,
    cg_tol: float = 1e-10,
    cg_maxiter: Optional[int] = None,
    c: Optional[Array] = None,
) -> Array:
    """One-shot SLQ evidence value (see :func:`make_slq_mll_fn`)."""
    fn = make_slq_mll_fn(kernel, X, G, key=key, probes=probes,
                         lanczos_iters=lanczos_iters, cg_tol=cg_tol,
                         cg_maxiter=cg_maxiter, c=c)
    return fn(hypers)

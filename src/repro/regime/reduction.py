"""Exact gradient reduction: fold full gradients into directional ones.

The compression escape hatch of the capacity policy (Seung & Katzfuss,
"Scalable Derivative Gaussian Processes via Exact Gradient Reduction",
PAPERS.md): when the observed inputs occupy a low-dimensional affine
subspace of R^D — always true with N <= D+1 observations, and typical of
optimizer trajectories — the gradient GP factorizes exactly across that
subspace and its orthogonal complement, so full D-vector gradient
observations can be *folded into k directional derivatives each* without
changing any in-span prediction.

The theorem this module implements (isotropic Lambda = lam I; both kernel
families of ``core/kernels.py``):

  Let B be an orthonormal basis (D, k) of span{x_i - b} (stationary
  kernels; b any base point — differences are all that enter r) or
  span{x_i - c} (dot kernels; c the kernel center).  Rotate each gradient
  observation into B (+) B_perp.  Then

    * cov( d_u f(x_i), f(x_j) )        = 0      for u in B_perp
    * cov( d_u f(x_i), d_v f(x_j) )    = 0      for u in B_perp, v in B

  because every covariance term carries either u^T v or (x_i - x_j)^T u
  (stationary) / x~_j^T u (dot), all zero.  The orthogonal components
  {B_perp^T g_i} are therefore prior-independent of the in-span data AND
  of every in-span predictand, so dropping them leaves the posterior of
  f(q) and of B-span directional derivatives at any in-span query q
  EXACTLY unchanged.  (Out-of-span gradient components at q lose their
  posterior coupling to the dropped block — the one quantity compression
  forfeits; its magnitude is exactly the ``residual`` this module
  reports.)

  Moreover the reduced problem is *the same model in k dimensions*: with
  y_i = B^T (x_i - b), the projected pairwise geometry is preserved
  (differences/centered coordinates lie in span(B)), the projected
  iid noise stays iid, and the k-dimensional gradient-GP Gram of
  (y_i, B^T g_i) equals the in-span block of the original Gram.  So the
  compressed state is just a ``GPGState`` over (N, k) — every solver,
  kernel, bench and serving path applies unchanged at O(N^2 k) instead
  of O(N^2 D) per sweep.

Host-side linear algebra (one SVD of the (N, D) inputs per compression);
nothing here enters a jaxpr.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp

Array = jnp.ndarray


class Reduction(NamedTuple):
    """An exact-gradient-reduction of (X, G) onto the observed subspace.

    basis:    (D, k) orthonormal columns spanning the data subspace.
    base:     (D,) the subtraction point (first observation for stationary
              kernels, the kernel center for dot kernels).
    Xr, Gr:   (N, k) reduced inputs / directional-derivative observations.
    residual: Frobenius norm of the dropped orthogonal gradient mass
              |G - Gr B^T|_F — the exactly-quantified information loss
              for OUT-of-span gradient predictands (zero for everything
              the theorem covers).
    """

    basis: Array
    base: Array
    Xr: Array
    Gr: Array
    residual: Array

    @property
    def rank(self) -> int:
        return self.basis.shape[1]

    @property
    def d(self) -> int:
        return self.basis.shape[0]


def subspace_basis(X: Array, *, base: Optional[Array] = None,
                   tol: float = 1e-8) -> tuple[Array, Array]:
    """Orthonormal basis of span{x_i - base} via one SVD; returns (B, base).

    ``base=None`` uses the first row (the stationary-kernel choice — only
    differences matter, and x_0 keeps the span affine-correct).  Rank is
    cut at ``tol * s_max`` — directions the data only explores at
    roundoff level are noise, not geometry.
    """
    X = jnp.atleast_2d(X)
    if base is None:
        base = X[0]
    base = jnp.asarray(base, X.dtype)
    Xc = X - base
    _, s, vt = jnp.linalg.svd(Xc, full_matrices=False)
    smax = s[0] if s.shape[0] else jnp.asarray(0.0, X.dtype)
    k = int(jnp.sum(s > tol * jnp.maximum(smax, 1e-30)))
    k = max(k, 1)
    return vt[:k].T, base


def affine_rank(X: Array, *, base: Optional[Array] = None,
                tol: float = 1e-8) -> int:
    """Numerical rank of the observed subspace — what the capacity policy
    feeds ``RegimePolicy.capacity_action`` to decide compressibility."""
    B, _ = subspace_basis(X, base=base, tol=tol)
    return B.shape[1]


def reduce_gradients(
    spec,
    X: Array,
    G: Array,
    *,
    c: Optional[Array] = None,
    extra_points: Optional[Array] = None,
    tol: float = 1e-8,
) -> Reduction:
    """Build the exact reduction of (X, G) for kernel ``spec``.

    ``c`` is the dot-kernel center (the base point must be the center for
    dot kernels: their r depends on absolute centered coordinates, not
    differences).  ``extra_points`` fold expected query locations into the
    span so upcoming queries stay exactly covered (e.g. an optimizer's
    current iterate).
    """
    X = jnp.atleast_2d(X)
    G = jnp.asarray(G)
    if spec.is_stationary:
        base = None
    else:
        base = (jnp.zeros((X.shape[1],), X.dtype) if c is None
                else jnp.asarray(c, X.dtype))
    span_of = X if extra_points is None else jnp.concatenate(
        [X, jnp.atleast_2d(extra_points)], axis=0)
    B, base = subspace_basis(span_of, base=base, tol=tol)
    Xr = (X - base) @ B
    Gr = G @ B
    residual = jnp.linalg.norm(G - Gr @ B.T)
    return Reduction(basis=B, base=base, Xr=Xr, Gr=Gr, residual=residual)


def project_points(red: Reduction, Xq: Array) -> tuple[Array, Array]:
    """Project queries into the reduced frame; returns (Yq, out_of_span).

    ``out_of_span`` is the per-query norm of the component outside the
    basis — zero is the exactness condition; nonzero queries are served
    from the nearest in-span point (value error bounded by the kernel's
    smoothness over that distance, reported so callers/telemetry can see
    it rather than silently absorbing it).
    """
    Xq = jnp.atleast_2d(Xq)
    Yc = Xq - red.base
    Yq = Yc @ red.basis
    out = jnp.linalg.norm(Yc - Yq @ red.basis.T, axis=1)
    return Yq, out


def lift_gradients(red: Reduction, Gr: Array) -> Array:
    """Map reduced-frame gradients (Q, k) back to R^D as (Q, D).

    The orthogonal components are the prior mean (zero): exactly the
    posterior the compressed model defines.  In-span components are the
    full model's exact posterior (the theorem above).
    """
    return jnp.asarray(Gr) @ red.basis.T


def lift_points(red: Reduction, Yq: Array) -> Array:
    """Inverse of :func:`project_points` for in-span points."""
    return jnp.asarray(Yq) @ red.basis.T + red.base

"""Serving step builders: LM prefill/decode AND batched GP posterior query.

decode_* shapes lower `serve_step` — one new token against a KV cache of
seq_len — NOT train_step (assignment contract). The cache is donated so
steady-state decode is allocation-free.

``build_gp_serve_step`` is the posterior-inference analogue: a fixed-shape
jitted microbatch query step over a live ``GPGState`` (core/state.py).
The compiled step takes the state's factor arrays as *arguments*, so
interleaved ``extend()`` updates never recompile — the serve loop is
observe -> extend -> keep serving from the same compiled function.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import (SHAPES, ModelConfig, batch_specs, build_model,
                          set_activation_rules)

from .sharding import (batch_partition_specs, cache_partition_specs,
                       param_named_shardings, sanitize_spec_tree)


@dataclasses.dataclass
class ServeBundle:
    step: Callable
    abstract_params: Any
    abstract_inputs: tuple
    in_shardings: tuple
    model: Any


def build_prefill_step(cfg: ModelConfig, mesh: Mesh, *,
                       shape: str = "prefill_32k") -> ServeBundle:
    model = build_model(cfg)
    set_activation_rules(mesh, cfg.seq_shard_activations)
    ss = SHAPES[shape]
    pa, axes = model.abstract()
    p_shard = param_named_shardings(mesh, axes, pa)
    ba = batch_specs(cfg, shape)
    b_pspecs = sanitize_spec_tree(batch_partition_specs(cfg, ba, mesh), ba,
                                  mesh)
    b_shard = {k: NamedSharding(mesh, v) for k, v in b_pspecs.items()}

    def fn(params, batch):
        return model.prefill(params, batch, ss.seq_len)

    cache_abs = jax.eval_shape(lambda: model.init_cache(ss.global_batch,
                                                        ss.seq_len))
    c_specs = sanitize_spec_tree(
        cache_partition_specs(cache_abs, mesh, ss.global_batch), cache_abs,
        mesh)
    c_shard = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), c_specs,
        is_leaf=lambda x: isinstance(x, P))
    jitted = jax.jit(fn, in_shardings=(p_shard, b_shard),
                     out_shardings=(NamedSharding(mesh, P()), c_shard))
    return ServeBundle(step=jitted, abstract_params=pa,
                       abstract_inputs=(ba,), in_shardings=(p_shard, b_shard),
                       model=model)


def build_decode_step(cfg: ModelConfig, mesh: Mesh, *,
                      shape: str = "decode_32k",
                      donate: bool = True) -> ServeBundle:
    model = build_model(cfg)
    set_activation_rules(mesh, cfg.seq_shard_activations)
    ss = SHAPES[shape]
    pa, axes = model.abstract()
    p_shard = param_named_shardings(mesh, axes, pa)

    cache_abs = jax.eval_shape(lambda: model.init_cache(ss.global_batch,
                                                        ss.seq_len))
    c_specs = sanitize_spec_tree(
        cache_partition_specs(cache_abs, mesh, ss.global_batch), cache_abs,
        mesh)
    c_shard = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), c_specs,
        is_leaf=lambda x: isinstance(x, P))
    tok_abs = jax.ShapeDtypeStruct((ss.global_batch,), "int32")
    pos_abs = jax.ShapeDtypeStruct((ss.global_batch,), "int32")
    from repro.models import batch_axes_of
    b_ax = batch_axes_of(mesh)
    import numpy as np
    b_shards = int(np.prod([mesh.shape[a] for a in b_ax]))
    tok_spec = P(b_ax) if ss.global_batch % b_shards == 0 and \
        ss.global_batch >= b_shards else P()
    tok_shard = NamedSharding(mesh, tok_spec)

    def fn(params, cache, tokens, pos):
        return model.decode(params, cache, tokens, pos)

    jitted = jax.jit(
        fn,
        in_shardings=(p_shard, c_shard, tok_shard, tok_shard),
        out_shardings=(NamedSharding(mesh, P()), c_shard),
        donate_argnums=(1,) if donate else (),
    )
    return ServeBundle(step=jitted, abstract_params=pa,
                       abstract_inputs=(cache_abs, tok_abs, pos_abs),
                       in_shardings=(p_shard, c_shard, tok_shard, tok_shard),
                       model=model)


# ---------------------------------------------------------------------------
# GP posterior query serving (core/state.py + core/query.py)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GPServeBundle:
    """A compiled batched-query endpoint over a live posterior state.

    ``query(Xq)`` pads the request up to a multiple of ``microbatch``,
    runs the jitted fixed-shape chunk step per microbatch against the
    CURRENT state revision (factors/Z are read per call), and trims the
    padding off. Zero solves per request; extend() between requests reuses
    the same executable.

    With ``return_std`` the step additionally takes a ``GramSolver``
    argument (the structured factorization of the noisy Gram, built once
    per request by ``refresh_solver``).  Every hyperparameter — lam,
    signal, noise — reaches the compiled step as an ARRAY inside the
    factor/solver pytrees, so a ``refit()`` between requests changes the
    numbers but never the shapes: hypers are dynamic arguments and the
    executable survives them.
    """

    state: Any                       # GPGState
    microbatch: int
    step: Callable                   # jitted (factors, Z[, solver], chunk[, probe])
    probe: Optional[jnp.ndarray]
    return_std: bool = False
    return_grad_std: bool = False
    _solver_cache: Any = None        # (revision key, GramSolver)

    def refresh_solver(self):
        """The variance solver for the CURRENT state revision — factorized
        once per revision (O(N^2 D + (N^2)^3)) and cached: every state
        mutation replaces the ``GPGData`` pytree and bumps its op counters,
        so repeated requests against an unchanged state reuse the LU."""
        from repro.hyper.variance import make_solver

        st = self.state
        c = self._solver_cache
        if c is not None and c[0] is st.data and c[1] == (st.noise,
                                                          st.signal):
            return c[2]
        solver = make_solver(st.spec, st.padded_factors, noise=st.noise,
                             signal=st.signal, count=st.data.count)
        # hold the data pytree itself: identity can't be recycled while
        # cached, so `is` is an exact revision check
        self._solver_cache = (st.data, (st.noise, st.signal), solver)
        return solver

    def query(self, Xq):
        from repro.core.query import PosteriorBatch

        Xq = jnp.atleast_2d(Xq)
        q, d = Xq.shape
        b = self.microbatch
        pad = (-q) % b
        Xp = jnp.pad(Xq, ((0, pad), (0, 0)))
        # fixed-capacity padded views: shapes are stable across extend(),
        # so the compiled step is reused (padding is exact for queries)
        f, Z = self.state.padded_factors, self.state.data.Z
        want_std = self.return_std or self.return_grad_std
        solver = self.refresh_solver() if want_std else None
        chunks = []
        for i in range(0, q + pad, b):
            args = (f, Z) + ((solver,) if want_std else ()) + (Xp[i:i + b],)
            if self.probe is not None:
                args = args + (self.probe,)
            chunks.append(self.step(*args))
        cat = lambda xs: jnp.concatenate(xs)[:q]
        out = PosteriorBatch(
            value=cat([c.value for c in chunks]),
            grad=cat([c.grad for c in chunks]),
            hess_v=None if self.probe is None else
            cat([c.hess_v for c in chunks]),
            std=cat([c.std for c in chunks]) if self.return_std or
            self.return_grad_std else None,
            grad_std=cat([c.grad_std for c in chunks])
            if self.return_grad_std else None,
        )
        return out


def build_gp_serve_step(state, *, microbatch: int = 64, probe=None,
                        return_std: bool = False,
                        return_grad_std: bool = False) -> GPServeBundle:
    """Compile a batched posterior query step for a ``GPGState``.

    One compilation per (microbatch, capacity, D) shape — the step is fed
    the state's fixed-capacity padded factor views, so extend()/evict()
    never change the compiled shapes (only an unbounded-growth capacity
    doubling does).  Q-query requests cost O(Q N D) with exactly zero
    inner solves (the solve happened at ``extend()`` time — factor reuse
    is the whole point of the state).

    ``return_std=True`` serves posterior value stds (``return_grad_std``
    gradient stds too) through one structured Gram factorization per
    request; the hypers ride inside the solver pytree, so refits between
    requests never recompile (asserted in tests/test_hyper.py).
    """
    from repro.core.query import make_query_fn

    fn = make_query_fn(state.spec, with_probe=probe is not None,
                       with_std=return_std, with_grad_std=return_grad_std)
    return GPServeBundle(
        state=state, microbatch=int(microbatch), step=jax.jit(fn),
        probe=None if probe is None else jnp.asarray(probe),
        return_std=bool(return_std), return_grad_std=bool(return_grad_std),
    )

"""Serving step builders: prefill and one-token decode, sharding-annotated.

decode_* shapes lower `serve_step` — one new token against a KV cache of
seq_len — NOT train_step (assignment contract). The cache is donated so
steady-state decode is allocation-free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import (SHAPES, ModelConfig, batch_specs, build_model,
                          set_activation_rules)

from .sharding import (batch_partition_specs, cache_partition_specs,
                       param_named_shardings, sanitize_spec_tree)


@dataclasses.dataclass
class ServeBundle:
    step: Callable
    abstract_params: Any
    abstract_inputs: tuple
    in_shardings: tuple
    model: Any


def build_prefill_step(cfg: ModelConfig, mesh: Mesh, *,
                       shape: str = "prefill_32k") -> ServeBundle:
    model = build_model(cfg)
    set_activation_rules(mesh, cfg.seq_shard_activations)
    ss = SHAPES[shape]
    pa, axes = model.abstract()
    p_shard = param_named_shardings(mesh, axes, pa)
    ba = batch_specs(cfg, shape)
    b_pspecs = sanitize_spec_tree(batch_partition_specs(cfg, ba, mesh), ba,
                                  mesh)
    b_shard = {k: NamedSharding(mesh, v) for k, v in b_pspecs.items()}

    def fn(params, batch):
        return model.prefill(params, batch, ss.seq_len)

    cache_abs = jax.eval_shape(lambda: model.init_cache(ss.global_batch,
                                                        ss.seq_len))
    c_specs = sanitize_spec_tree(
        cache_partition_specs(cache_abs, mesh, ss.global_batch), cache_abs,
        mesh)
    c_shard = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), c_specs,
        is_leaf=lambda x: isinstance(x, P))
    jitted = jax.jit(fn, in_shardings=(p_shard, b_shard),
                     out_shardings=(NamedSharding(mesh, P()), c_shard))
    return ServeBundle(step=jitted, abstract_params=pa,
                       abstract_inputs=(ba,), in_shardings=(p_shard, b_shard),
                       model=model)


def build_decode_step(cfg: ModelConfig, mesh: Mesh, *,
                      shape: str = "decode_32k",
                      donate: bool = True) -> ServeBundle:
    model = build_model(cfg)
    set_activation_rules(mesh, cfg.seq_shard_activations)
    ss = SHAPES[shape]
    pa, axes = model.abstract()
    p_shard = param_named_shardings(mesh, axes, pa)

    cache_abs = jax.eval_shape(lambda: model.init_cache(ss.global_batch,
                                                        ss.seq_len))
    c_specs = sanitize_spec_tree(
        cache_partition_specs(cache_abs, mesh, ss.global_batch), cache_abs,
        mesh)
    c_shard = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), c_specs,
        is_leaf=lambda x: isinstance(x, P))
    tok_abs = jax.ShapeDtypeStruct((ss.global_batch,), "int32")
    pos_abs = jax.ShapeDtypeStruct((ss.global_batch,), "int32")
    from repro.models import batch_axes_of
    b_ax = batch_axes_of(mesh)
    import numpy as np
    b_shards = int(np.prod([mesh.shape[a] for a in b_ax]))
    tok_spec = P(b_ax) if ss.global_batch % b_shards == 0 and \
        ss.global_batch >= b_shards else P()
    tok_shard = NamedSharding(mesh, tok_spec)

    def fn(params, cache, tokens, pos):
        return model.decode(params, cache, tokens, pos)

    jitted = jax.jit(
        fn,
        in_shardings=(p_shard, c_shard, tok_shard, tok_shard),
        out_shardings=(NamedSharding(mesh, P()), c_shard),
        donate_argnums=(1,) if donate else (),
    )
    return ServeBundle(step=jitted, abstract_params=pa,
                       abstract_inputs=(cache_abs, tok_abs, pos_abs),
                       in_shardings=(p_shard, c_shard, tok_shard, tok_shard),
                       model=model)

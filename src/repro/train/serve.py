"""Serving step builders: LM prefill/decode AND batched GP posterior query.

decode_* shapes lower `serve_step` — one new token against a KV cache of
seq_len — NOT train_step (assignment contract). The cache is donated so
steady-state decode is allocation-free.

``build_gp_serve_step`` is the posterior-inference analogue: a fixed-shape
jitted microbatch query step over a live ``GPGState`` (core/state.py).
The compiled step takes the state's factor arrays as *arguments*, so
interleaved ``extend()`` updates never recompile — the serve loop is
observe -> extend -> keep serving from the same compiled function.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import (SHAPES, ModelConfig, batch_specs, build_model,
                          set_activation_rules)
from repro.obs import compile_watch as _cw
from repro.obs import cost as _cost
from repro.obs import trace as _obs
from repro.resilience import guardrails as _guard
from repro.resilience.errors import (DeadlineExceededError,
                                     NonFiniteObservationError,
                                     RetryExhaustedError, ShedResponse,
                                     TenantQuarantinedError)

from .sharding import (batch_partition_specs, cache_partition_specs,
                       param_named_shardings, sanitize_spec_tree)


@dataclasses.dataclass
class ServeBundle:
    step: Callable
    abstract_params: Any
    abstract_inputs: tuple
    in_shardings: tuple
    model: Any


def build_prefill_step(cfg: ModelConfig, mesh: Mesh, *,
                       shape: str = "prefill_32k") -> ServeBundle:
    model = build_model(cfg)
    set_activation_rules(mesh, cfg.seq_shard_activations)
    ss = SHAPES[shape]
    pa, axes = model.abstract()
    p_shard = param_named_shardings(mesh, axes, pa)
    ba = batch_specs(cfg, shape)
    b_pspecs = sanitize_spec_tree(batch_partition_specs(cfg, ba, mesh), ba,
                                  mesh)
    b_shard = {k: NamedSharding(mesh, v) for k, v in b_pspecs.items()}

    def fn(params, batch):
        return model.prefill(params, batch, ss.seq_len)

    cache_abs = jax.eval_shape(lambda: model.init_cache(ss.global_batch,
                                                        ss.seq_len))
    c_specs = sanitize_spec_tree(
        cache_partition_specs(cache_abs, mesh, ss.global_batch), cache_abs,
        mesh)
    c_shard = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), c_specs,
        is_leaf=lambda x: isinstance(x, P))
    jitted = jax.jit(fn, in_shardings=(p_shard, b_shard),
                     out_shardings=(NamedSharding(mesh, P()), c_shard))
    return ServeBundle(step=jitted, abstract_params=pa,
                       abstract_inputs=(ba,), in_shardings=(p_shard, b_shard),
                       model=model)


def build_decode_step(cfg: ModelConfig, mesh: Mesh, *,
                      shape: str = "decode_32k",
                      donate: bool = True) -> ServeBundle:
    model = build_model(cfg)
    set_activation_rules(mesh, cfg.seq_shard_activations)
    ss = SHAPES[shape]
    pa, axes = model.abstract()
    p_shard = param_named_shardings(mesh, axes, pa)

    cache_abs = jax.eval_shape(lambda: model.init_cache(ss.global_batch,
                                                        ss.seq_len))
    c_specs = sanitize_spec_tree(
        cache_partition_specs(cache_abs, mesh, ss.global_batch), cache_abs,
        mesh)
    c_shard = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), c_specs,
        is_leaf=lambda x: isinstance(x, P))
    tok_abs = jax.ShapeDtypeStruct((ss.global_batch,), "int32")
    pos_abs = jax.ShapeDtypeStruct((ss.global_batch,), "int32")
    from repro.models import batch_axes_of
    b_ax = batch_axes_of(mesh)
    import numpy as np
    b_shards = int(np.prod([mesh.shape[a] for a in b_ax]))
    tok_spec = P(b_ax) if ss.global_batch % b_shards == 0 and \
        ss.global_batch >= b_shards else P()
    tok_shard = NamedSharding(mesh, tok_spec)

    def fn(params, cache, tokens, pos):
        return model.decode(params, cache, tokens, pos)

    jitted = jax.jit(
        fn,
        in_shardings=(p_shard, c_shard, tok_shard, tok_shard),
        out_shardings=(NamedSharding(mesh, P()), c_shard),
        donate_argnums=(1,) if donate else (),
    )
    return ServeBundle(step=jitted, abstract_params=pa,
                       abstract_inputs=(cache_abs, tok_abs, pos_abs),
                       in_shardings=(p_shard, c_shard, tok_shard, tok_shard),
                       model=model)


# ---------------------------------------------------------------------------
# GP posterior query serving (core/state.py + core/query.py)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GPServeBundle:
    """A compiled batched-query endpoint over a live posterior state.

    ``query(Xq)`` pads the request up to a multiple of ``microbatch``,
    runs the jitted fixed-shape chunk step per microbatch against the
    CURRENT state revision (factors/Z are read per call), and trims the
    padding off. Zero solves per request; extend() between requests reuses
    the same executable.

    With ``return_std`` the step additionally takes a ``GramSolver``
    argument (the structured factorization of the noisy Gram, built once
    per request by ``refresh_solver``).  Every hyperparameter — lam,
    signal, noise — reaches the compiled step as an ARRAY inside the
    factor/solver pytrees, so a ``refit()`` between requests changes the
    numbers but never the shapes: hypers are dynamic arguments and the
    executable survives them.
    """

    state: Any                       # GPGState
    microbatch: int
    step: Callable                   # jitted (factors, Z[, solver], chunk[, probe])
    probe: Optional[jnp.ndarray]
    return_std: bool = False
    return_grad_std: bool = False
    step_fn: Optional[Callable] = None   # the raw (unjitted) step — the
    # cost model lowers THIS through a fresh jit, never through the
    # compile-watched entry point (a model lowering is not a serve compile)
    _solver_cache: Any = None        # OrderedDict: revision key -> GramSolver
    # LU factorizations per cached revision are O(cap^4) floats — a
    # long-running server interleaving refit()/extend() with queries would
    # otherwise accrete one per revision forever, so the cache is a small
    # LRU: the common alternating-revision pattern still hits, memory is
    # bounded at _SOLVER_CACHE_MAX factorizations.
    _SOLVER_CACHE_MAX = 4

    def refresh_solver(self):
        """The variance solver for the CURRENT factor revision — factorized
        once per revision (O(N^2 D + (N^2)^3)) and LRU-cached.  The key is
        the state's ``factor_revision`` counter (+ the noise/signal
        hypers), NOT the identity of the data pytree: a mutation that
        rebuilds ``GPGData`` without touching the factorization (e.g. a
        ``resolve()`` against a new RHS) keeps the key and HITS, instead
        of silently re-factorizing and double-caching an identical LU.
        ``id(st)`` rides along (with an `is` check; the cached reference
        pins it) so a swapped-in replacement state can never collide."""
        import collections

        from repro.hyper.variance import make_solver

        st = self.state
        if self._solver_cache is None:
            self._solver_cache = collections.OrderedDict()
        key = (id(st), st.factor_revision, st.noise, st.signal)
        hit = self._solver_cache.get(key)
        if hit is not None and hit[0] is st:
            self._solver_cache.move_to_end(key)
            if _obs.enabled():
                _obs.REGISTRY.inc("serve.solver_cache.hits")
            return hit[1]
        if _obs.enabled():
            _obs.REGISTRY.inc("serve.solver_cache.misses")
        solver = make_solver(st.spec, st.padded_factors, noise=st.noise,
                             signal=st.signal, count=st.data.count)
        self._solver_cache[key] = (st, solver)
        while len(self._solver_cache) > self._SOLVER_CACHE_MAX:
            self._solver_cache.popitem(last=False)
            if _obs.enabled():
                _obs.REGISTRY.inc("serve.solver_cache.evictions")
        return solver

    def query(self, Xq):
        from repro.core.query import PosteriorBatch

        with _obs.span("serve.query"):
            if getattr(self.state, "_reduction", None) is not None:
                return self._query_reduced(Xq)
            return self._query(Xq, PosteriorBatch)

    def _query_reduced(self, Xq):
        """Serve through the state's own reduced-frame path (the bundle's
        compiled step was shaped for the raw frame).  grad_std cannot
        rotate through the reduction basis — degrade to a grad_std=None
        answer instead of killing the request (typed, counted)."""
        from repro.resilience.errors import UnsupportedQueryError

        try:
            return self.state.posterior(
                Xq, probe=self.probe, microbatch=self.microbatch,
                return_std=self.return_std,
                return_grad_std=self.return_grad_std)
        except UnsupportedQueryError:
            if _obs.enabled():
                _obs.REGISTRY.inc("resilience.degraded_query")
            _obs.emit({"type": "degraded_query", "want": "grad_std"})
            return self.state.posterior(
                Xq, probe=self.probe, microbatch=self.microbatch,
                return_std=self.return_std, return_grad_std=False)

    def _query(self, Xq, PosteriorBatch):
        obs_on = _obs.enabled()
        Xq = jnp.atleast_2d(Xq)
        q, d = Xq.shape
        b = self.microbatch
        pad = (-q) % b
        # fixed-capacity padded views in the state's STREAM precision:
        # shapes are stable across extend(), so the compiled step is
        # reused (padding is exact for queries); with precision='bf16'
        # the bf16 copies are cached per revision by the state, so the
        # serve step streams half the bytes with no per-request cast.
        # probe/std endpoints serve from the unshifted f32 masters
        # (GramFactors.shift is a mean-path-only frame).
        want_std = self.return_std or self.return_grad_std
        if want_std or self.probe is not None:
            f, Z = self.state.padded_factors, self.state.data.Z
        else:
            f, Z = self.state.stream_factors
        if f.shift is not None:
            Xq = (Xq - f.shift).astype(f.Xt.dtype)
            f = f._replace(shift=None)
        elif f.c is not None and f.Xt.dtype == jnp.bfloat16:
            # dot-kernel bf16 stream: center-then-cast (the stored Xt is
            # centered; quantizing absolute coords first loses |x|/|x-c|)
            Xq = (Xq - f.c).astype(f.Xt.dtype)
            f = f._replace(c=None)
        Xp = jnp.pad(Xq.astype(f.Xt.dtype), ((0, pad), (0, 0)))
        solver = self.refresh_solver() if want_std else None
        n_chunks = (q + pad) // b
        costs = None
        if obs_on:
            _obs.REGISTRY.inc("serve.requests")
            _obs.REGISTRY.inc("serve.points", q)
            _obs.REGISTRY.set_gauge("serve.queue_depth", n_chunks)
            if self.step_fn is not None:
                # modeled bytes/flops of ONE chunk, scaled to the request;
                # cached per signature so steady-state requests pay nothing
                first = (f, Z) + ((solver,) if want_std else ()) \
                    + (Xp[0:b],)
                if self.probe is not None:
                    first = first + (self.probe,)
                costs = _cost.modeled("gp_serve_step", self.step_fn,
                                      *first, scale=float(n_chunks))
        import time as _time

        t0 = _time.monotonic()
        chunks = []
        for i in range(0, q + pad, b):
            args = (f, Z) + ((solver,) if want_std else ()) + (Xp[i:i + b],)
            if self.probe is not None:
                args = args + (self.probe,)
            chunks.append(self.step(*args))
        if obs_on:
            jax.block_until_ready(chunks)
            dt = _time.monotonic() - t0
            _obs.REGISTRY.observe("serve.request_seconds", dt)
            _cost.record_measured("gp_serve_step", dt, costs)
        cat = lambda xs: jnp.concatenate(xs)[:q]
        out = PosteriorBatch(
            value=cat([c.value for c in chunks]),
            grad=cat([c.grad for c in chunks]),
            hess_v=None if self.probe is None else
            cat([c.hess_v for c in chunks]),
            std=cat([c.std for c in chunks]) if self.return_std or
            self.return_grad_std else None,
            grad_std=cat([c.grad_std for c in chunks])
            if self.return_grad_std else None,
        )
        return out


def build_gp_serve_step(state, *, microbatch: int | None = None, probe=None,
                        return_std: bool = False,
                        return_grad_std: bool = False,
                        precision: str | None = None,
                        config=None) -> GPServeBundle:
    """Compile a batched posterior query step for a ``GPGState``.

    One compilation per (microbatch, capacity, D) shape — the step is fed
    the state's fixed-capacity padded factor views, so extend()/evict()
    never change the compiled shapes (only an unbounded-growth capacity
    doubling does).  Q-query requests cost O(Q N D) with exactly zero
    inner solves (the solve happened at ``extend()`` time — factor reuse
    is the whole point of the state).

    ``return_std=True`` serves posterior value stds (``return_grad_std``
    gradient stds too) through one structured Gram factorization per
    request; the hypers ride inside the solver pytree, so refits between
    requests never recompile (asserted in tests/test_hyper.py).

    ``config`` (a ``repro.configs.paper_gp.GPServeConfig``) supplies
    defaults for ``microbatch`` and ``precision``; an explicit
    ``precision`` (or config) of 'bf16' switches the STATE's stream
    storage to bf16 — the per-revision bf16 copies live on the state, so
    every consumer of ``state.stream_factors`` shares them.  When a
    config is passed, its ``tol``/``maxiter`` solve knobs are applied to
    the state too (they shape the extend-time CG re-solves this bundle's
    queries are served from).
    """
    from repro.configs.paper_gp import GP_SERVE
    from repro.core.query import make_query_fn

    if config is not None and precision is None:
        precision = config.precision
    if microbatch is None:
        microbatch = (config or GP_SERVE).microbatch
    if config is not None:
        state.tol = float(config.tol)
        state.maxiter = config.maxiter
    if precision is not None:
        # precision lives on the STATE (shared by every bundle/consumer);
        # an explicit request here re-points all of them — see
        # GPGState.set_precision
        state.set_precision(precision)
    fn = make_query_fn(state.spec, with_probe=probe is not None,
                       with_std=return_std, with_grad_std=return_grad_std)
    if _obs.enabled():
        # pre-register the serve counters at 0 so a run's final snapshot
        # exports them even when never tripped (check_telemetry contract)
        for name in ("serve.solver_cache.hits", "serve.solver_cache.misses",
                     "serve.solver_cache.evictions", "serve.requests"):
            _obs.REGISTRY.inc(name, 0)
    # compile_watch.wrap IS jax.jit when observability is off (bit-
    # identical serve step); on, every trace is counted per signature and
    # the "extend/refit never recompile" contract becomes a runtime gate
    return GPServeBundle(
        state=state, microbatch=int(microbatch),
        step=_cw.wrap(fn, name="gp_serve_step"), step_fn=fn,
        probe=None if probe is None else jnp.asarray(probe),
        return_std=bool(return_std), return_grad_std=bool(return_grad_std),
    )


# ---------------------------------------------------------------------------
# Multi-tenant continuous batching (core/fleet.py)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FleetRequest:
    """One pending tenant op.  ``result`` is set when the request has been
    packed into a launch (``done`` flips true); queries resolve to a
    ``PosteriorBatch``, refits to the fitted mll, lifecycle ops to None.

    Failure outcomes complete the request too: ``result`` is then a typed
    ``ResilienceError`` instance (deadline/retry/quarantine) or a
    ``ShedResponse`` — callers branch on type, they never block forever.
    ``deadline``/``not_before`` are server STEP counts (the serve clock),
    not wall time; ``chaos_kind`` tags injector-corrupted requests so
    recovery accounting stays exact."""

    tenant: Any
    op: str                 # 'extend' | 'evict' | 'resolve' | 'refit' | 'query'
    payload: Any = None
    done: bool = False
    result: Any = None
    attempts: int = 0
    deadline: Optional[int] = None
    not_before: int = 0
    chaos_kind: Optional[str] = None


class GPFleetServer:
    """Continuous-batching front end over a :class:`~repro.core.GPFleet`.

    The vLLM-style serving loop for GP posteriors: tenants ``connect`` and
    ``submit`` ops asynchronously; each ``step()`` packs the queue's
    head-of-line requests (at most ONE per tenant, preserving per-tenant
    submission order) into per-op groups and fires ONE vmapped launch per
    op type present — so a step serving 50 tenants costs the same number
    of launches as a step serving one.  Query payloads are padded into
    power-of-two Q buckets (>= ``config.q_bucket``), so the set of
    compiled signatures is bounded by O(log max_Q) x ops, not by traffic.

    Tenants idle for ``config.idle_ttl`` consecutive steps are evicted
    (lane zeroed and returned to the free list — ``fleet.idle_evictions``
    counts them); a later ``connect`` under the same id starts fresh.

    Posterior std queries (``op='query'`` with ``payload=(Xq, True)``)
    need the per-tenant variance ``GramSolver`` — an O(cap^4) LU that does
    not batch across tenants — so they are served per tenant through an
    LRU keyed on ``(slot, factor_revision, noise, signal)``: extend/refit
    bump the tenant's factor revision and miss; resolve() and pure queries
    keep it and hit (same contract as ``GPServeBundle.refresh_solver``).
    """

    def __init__(self, fleet=None, *, kernel="rbf", d=None, config=None,
                 injector=None, journal=None, **fleet_kwargs):
        import collections

        from repro.configs.paper_gp import GP_FLEET
        from repro.core.fleet import GPFleet

        self.config = config or GP_FLEET
        if fleet is None:
            fleet = GPFleet(kernel, d=d, batch=self.config.batch,
                            window=self.config.window, **fleet_kwargs)
        self.fleet = fleet
        self._queue: collections.deque = collections.deque()
        # adopt tenants already joined on a caller-supplied fleet
        self._idle: dict = {t: 0 for t in fleet.tenants}
        self._solvers: Any = collections.OrderedDict()
        self.steps = 0
        # -- resilience wiring (DESIGN.md sec. 17.3) ----------------------
        self.injector = injector          # ChaosInjector (drills/tests)
        self.journal = journal            # resilience.Journal (recovery)
        self._failures: dict = {}         # tenant -> consecutive faults
        self._quarantined: set = set()
        if _obs.enabled():
            for name in ("fleet.serve.requests", "fleet.serve.steps",
                         "fleet.idle_evictions",
                         "fleet.solver_cache.hits",
                         "fleet.solver_cache.misses",
                         "resilience.load_shed",
                         "resilience.deadline_expired",
                         "resilience.retries"):
                _obs.REGISTRY.inc(name, 0)

    # -- tenant lifecycle --------------------------------------------------

    def connect(self, tenant, **hypers) -> None:
        if tenant in self._quarantined:
            raise TenantQuarantinedError(
                f"tenant {tenant!r} is quarantined")
        self.fleet.join(tenant, **hypers)
        self._idle[tenant] = 0
        self._failures.pop(tenant, None)
        if self.journal is not None:
            self.journal.record("join", tenant=tenant,
                                args={k: float(v)
                                      for k, v in hypers.items()})

    def disconnect(self, tenant) -> None:
        self._queue = type(self._queue)(
            r for r in self._queue if r.tenant != tenant)
        self._idle.pop(tenant, None)
        slot = self.fleet.slot_of(tenant)
        self._solvers = type(self._solvers)(
            (k, v) for k, v in self._solvers.items() if k[0] != slot)
        self.fleet.leave(tenant)
        if self.journal is not None:
            self.journal.record("leave", tenant=tenant)

    @property
    def tenants(self):
        return self.fleet.tenants

    # -- request intake ----------------------------------------------------

    def submit(self, tenant, op: str, payload=None) -> FleetRequest:
        """Enqueue an op; returns the request (poll ``.done``/``.result``
        after ``step``/``drain``).

        Admission is where resilience bites first: quarantined tenants are
        refused, a full queue sheds with a typed ``ShedResponse`` result,
        and non-finite extend payloads are rejected BEFORE they can touch
        a factor strip (the request completes with the typed error as its
        result — repeated offenders get quarantined)."""
        if tenant in self._quarantined:
            raise TenantQuarantinedError(f"tenant {tenant!r} is quarantined")
        if tenant not in self._idle:
            raise KeyError(f"tenant {tenant!r} is not connected")
        if op not in ("extend", "evict", "resolve", "refit", "query"):
            raise ValueError(f"unknown fleet op {op!r}")
        req = FleetRequest(tenant=tenant, op=op, payload=payload,
                           deadline=self.steps + self.config.deadline_steps)
        if _obs.enabled():
            _obs.REGISTRY.inc("fleet.serve.requests")
        # load shedding: a bounded queue is the backpressure contract —
        # the caller gets a typed shed value immediately, never a stall
        if len(self._queue) >= self.config.max_queue:
            req.done = True
            req.result = ShedResponse(reason="queue_full",
                                      queue_depth=len(self._queue))
            if _obs.enabled():
                _obs.REGISTRY.inc("resilience.load_shed")
            _obs.emit({"type": "load_shed", "tenant": str(tenant)})
            return req
        # chaos: corrupt an extend payload on a nan_payload draw (the
        # admission guardrail below must catch it)
        if op == "extend" and payload is not None \
                and self._draw("nan_payload"):
            x, g = payload
            req.payload = payload = (self.injector.corrupt_payload(x), g)
            req.chaos_kind = "nan_payload"
        # chaos: stragglers park past their own deadline — the sweep in
        # step() must expire them without stalling anyone else
        if op == "query" and self._draw("straggler"):
            req.chaos_kind = "straggler"
            req.not_before = req.deadline + 1
        if op == "extend" and payload is not None:
            try:
                x, g = payload
                _guard.check_finite(x, g, what="observation", tenant=tenant)
            except NonFiniteObservationError as e:
                req.done = True
                req.result = e
                if req.chaos_kind == "nan_payload":
                    _guard.record_recovery("nan_payload",
                                           tenant=str(tenant))
                self._note_failure(tenant)
                return req
        self._queue.append(req)
        return req

    def _draw(self, kind: str) -> bool:
        """One injector Bernoulli draw (False without a ChaosInjector)."""
        draw = getattr(self.injector, "draw", None)
        return bool(draw is not None and draw(kind))

    def _note_failure(self, tenant) -> None:
        """Count a tenant-attributed fault; quarantine past the threshold
        (mask flip via ``GPFleet.quarantine`` — no repack, no recompile)."""
        self._failures[tenant] = self._failures.get(tenant, 0) + 1
        if self._failures[tenant] < self.config.quarantine_threshold:
            return
        self._quarantined.add(tenant)
        self._failures.pop(tenant, None)
        self._idle.pop(tenant, None)
        slot = self.fleet.slot_of(tenant)
        self._solvers = type(self._solvers)(
            (k, v) for k, v in self._solvers.items() if k[0] != slot)
        # pending requests fail typed — the queue never wedges on a
        # quarantined tenant
        kept = type(self._queue)()
        for r in self._queue:
            if r.tenant == tenant:
                r.done = True
                r.result = TenantQuarantinedError(
                    f"tenant {tenant!r} quarantined while queued")
            else:
                kept.append(r)
        self._queue = kept
        self.fleet.quarantine(tenant)
        if self.journal is not None:
            self.journal.record("leave", tenant=tenant)

    # -- the packing loop --------------------------------------------------

    def _take_head_of_line(self) -> list:
        """Pop at most one pending request per tenant, FIFO order — a
        tenant's ops are never reordered and never co-batched within one
        step (extend-then-query in one step would race)."""
        taken, skipped, busy = [], [], set()
        while self._queue:
            r = self._queue.popleft()
            if r.not_before > self.steps:
                # backoff/straggler parking: not eligible yet, but it
                # still holds its tenant's head-of-line slot (order!)
                busy.add(r.tenant)
                skipped.append(r)
            elif r.tenant in busy:
                skipped.append(r)
            else:
                busy.add(r.tenant)
                taken.append(r)
        self._queue.extend(skipped)
        return taken

    def step(self) -> list:
        """Pack + launch one round; returns the completed requests.

        Hardened path: expired requests are swept out first (typed
        ``DeadlineExceededError``), then each per-op group launches under
        the bounded-retry protocol — an injected kill requeues the group
        with exponential step backoff until ``config.max_retries`` is
        spent, after which requests complete with ``RetryExhaustedError``.
        A request never blocks forever and a fault in one op group never
        poisons the others."""
        from repro.runtime.recovery import SimulatedFailure

        cfg = self.config
        self.steps += 1
        completed = self._sweep_deadlines()
        batch = self._take_head_of_line()
        with _obs.span("fleet.serve.step", requests=len(batch),
                       queued=len(self._queue)):
            by_op: dict = {}
            for r in batch:
                by_op.setdefault(r.op, []).append(r)
            # lifecycle before queries: a step's queries see that step's
            # extends only for OTHER tenants (self ops are serialized by
            # head-of-line), so order here is launch-count, not semantics
            for op in ("extend", "evict", "resolve", "refit", "query"):
                reqs = by_op.get(op)
                if not reqs:
                    continue
                try:
                    kill = getattr(self.injector, "maybe_kill", None)
                    if kill is not None:
                        kill()
                    self._launch_group(op, reqs)
                except SimulatedFailure:
                    _guard.record_recovery("kill_step", op=op)
                    completed.extend(self._requeue(reqs))
                    continue
                for r in reqs:
                    r.done = True
                completed.extend(reqs)
            # idle bookkeeping + TTL eviction
            active = {r.tenant for r in completed}
            for t in list(self._idle):
                self._idle[t] = 0 if t in active else self._idle[t] + 1
                if self._idle[t] > cfg.idle_ttl:
                    self.disconnect(t)
                    if _obs.enabled():
                        _obs.REGISTRY.inc("fleet.idle_evictions")
            if _obs.enabled():
                _obs.REGISTRY.inc("fleet.serve.steps")
                _obs.REGISTRY.set_gauge("fleet.serve.queue_depth",
                                        len(self._queue))
        return completed

    def _launch_group(self, op: str, reqs: list) -> None:
        """One vmapped launch for an op group (+ journal on success)."""
        cfg, fl = self.config, self.fleet
        if op == "extend":
            fl.extend({r.tenant: r.payload for r in reqs})
            if self.journal is not None:
                self.journal.record_fleet("extend", per_tenant={
                    r.tenant: {"x": r.payload[0], "g": r.payload[1]}
                    for r in reqs})
        elif op == "evict":
            fl.evict([r.tenant for r in reqs])
            if self.journal is not None:
                self.journal.record("evict",
                                    tenants=[r.tenant for r in reqs])
        elif op == "resolve":
            fl.resolve({r.tenant: r.payload for r in reqs})
            if self.journal is not None:
                self.journal.record_fleet("resolve", per_tenant={
                    r.tenant: {"rhs": r.payload} for r in reqs})
        elif op == "refit":
            mlls = fl.refit([r.tenant for r in reqs],
                            steps=cfg.refit_steps, lr=cfg.refit_lr)
            for r in reqs:
                r.result = mlls.get(r.tenant)
            if self.journal is not None:
                self.journal.record("refit",
                                    tenants=[r.tenant for r in reqs],
                                    args={"steps": cfg.refit_steps,
                                          "lr": cfg.refit_lr})
        elif op == "query":
            self._serve_queries(reqs)

    def _requeue(self, reqs: list) -> list:
        """Bounded retry: requeue a killed group with exponential step
        backoff; past the budget, complete with RetryExhaustedError.
        Returns the requests that just failed terminally."""
        failed = []
        for r in reversed(reqs):            # appendleft: keep FIFO order
            r.attempts += 1
            if r.attempts > self.config.max_retries:
                r.done = True
                r.result = RetryExhaustedError(
                    f"{r.op!r} for tenant {r.tenant!r} failed "
                    f"{r.attempts} times")
                if _obs.enabled():
                    _obs.REGISTRY.inc("resilience.retry_exhausted")
                failed.append(r)
                continue
            r.not_before = self.steps + 2 ** r.attempts
            if _obs.enabled():
                _obs.REGISTRY.inc("resilience.retries")
            self._queue.appendleft(r)
        return failed

    def _sweep_deadlines(self) -> list:
        """Expire queued requests whose deadline has passed (typed result,
        never a silent drop); chaos-parked stragglers count as recovered
        the moment the sweep catches them."""
        expired, kept = [], type(self._queue)()
        for r in self._queue:
            if r.deadline is not None and self.steps > r.deadline:
                r.done = True
                r.result = DeadlineExceededError(
                    f"{r.op!r} for tenant {r.tenant!r} expired at "
                    f"step {self.steps} (deadline {r.deadline})")
                if _obs.enabled():
                    _obs.REGISTRY.inc("resilience.deadline_expired")
                if r.chaos_kind == "straggler":
                    _guard.record_recovery("straggler",
                                           tenant=str(r.tenant))
                expired.append(r)
            else:
                kept.append(r)
        self._queue = kept
        return expired

    def drain(self, max_steps: int = 10_000) -> int:
        """Step until the queue is empty; returns the number of steps."""
        n = 0
        while self._queue and n < max_steps:
            self.step()
            n += 1
        return n

    # -- queries -----------------------------------------------------------

    def _serve_queries(self, reqs: list) -> None:
        mean_reqs, std_reqs = [], []
        for r in reqs:
            xq, want_std = (r.payload if isinstance(r.payload, tuple)
                            else (r.payload, False))
            (std_reqs if want_std else mean_reqs).append((r, xq))
        if mean_reqs:
            qmax = max(jnp.atleast_2d(jnp.asarray(x)).shape[0]
                       for _, x in mean_reqs)
            bucket = max(self.config.q_bucket,
                         1 << (max(qmax, 1) - 1).bit_length())
            out = self.fleet.posterior(
                {r.tenant: x for r, x in mean_reqs}, q_pad=bucket)
            for r, _ in mean_reqs:
                r.result = out[r.tenant]
        for r, xq in std_reqs:
            r.result = self.query_std(r.tenant, xq)

    def query_std(self, tenant, Xq):
        """Per-tenant posterior mean + std (the non-batched slow path).

        Served from the tenant's lane view through the factor-revision
        solver LRU; like the PR 7 sharded path, variance queries are NOT
        fleet-batched (the GramSolver is a per-tenant O(cap^4) LU with no
        batched factorization yet — see DESIGN.md sec. 15).
        """
        from repro.core.gram import GramFactors
        from repro.core.query import make_query_fn
        from repro.hyper.variance import make_solver

        fl = self.fleet
        slot = fl.slot_of(tenant)
        lane = fl.state_view(tenant)
        hyp = fl.hypers_of(tenant)
        key = (slot, fl.factor_revision[slot], hyp["noise"], hyp["signal"])
        solver = self._solvers.get(key)
        if solver is None:
            if _obs.enabled():
                _obs.REGISTRY.inc("fleet.solver_cache.misses")
            f = GramFactors(K1e=lane.K1e, K2e=lane.K2e, Xt=lane.Xt,
                            lam=lane.lam, noise=0.0, c=None)
            solver = make_solver(fl.spec, f, noise=hyp["noise"],
                                 signal=hyp["signal"], count=lane.count)
            self._solvers[key] = solver
            while len(self._solvers) > self.config.solver_cache_max:
                self._solvers.popitem(last=False)
        else:
            self._solvers.move_to_end(key)
            if _obs.enabled():
                _obs.REGISTRY.inc("fleet.solver_cache.hits")
        f = GramFactors(K1e=lane.K1e, K2e=lane.K2e, Xt=lane.Xt,
                        lam=lane.lam, noise=0.0, c=None)
        qfn = _cw.wrap(make_query_fn(fl.spec, with_std=True),
                       name="fleet_query_std") if not hasattr(
                           self, "_std_step") else self._std_step
        self._std_step = qfn
        Xq = jnp.atleast_2d(jnp.asarray(Xq, lane.X.dtype))
        q = Xq.shape[0]
        bucket = max(self.config.q_bucket, 1 << (max(q, 1) - 1).bit_length())
        Xp = jnp.pad(Xq, ((0, bucket - q), (0, 0)))
        out = qfn(f, lane.Z, solver, Xp)
        from repro.core.query import PosteriorBatch

        return PosteriorBatch(value=out.value[:q], grad=out.grad[:q],
                              std=out.std[:q])


# ---------------------------------------------------------------------------
# D-sharded GP posterior serving (core/dist_state.py)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ShardedGPServeBundle:
    """Batched mean-query endpoint over a live ``ShardedGPGState``.

    Each microbatch is ONE fused psum of O(Q N) bytes (independent of D
    and of device count — DESIGN.md sec. 14); the compiled shard_map
    program is cached on the state per (microbatch, chunks) and survives
    extend/evict/refit (count and noise are traced arguments).  Mean-only:
    probe/std queries need the (N, D)-resident variance solver and stay on
    the single-device ``GPGState`` path.
    """

    state: Any                       # ShardedGPGState
    microbatch: int
    chunks: Optional[int] = None     # ring-pipelined query path when set

    def query(self, Xq):
        from repro.core.query import PosteriorBatch

        obs_on = _obs.enabled()
        st = self.state
        Xq = jnp.atleast_2d(Xq)
        q = Xq.shape[0]
        b = self.microbatch
        pad = (-q) % b
        Xp = jnp.pad(jnp.asarray(Xq, jnp.asarray(st.data.base.X).dtype),
                     ((0, pad), (0, 0)))
        n_chunks = (q + pad) // b
        with _obs.span("serve.query.sharded", q=q, shards=st.ndev):
            costs = None
            if obs_on:
                _obs.REGISTRY.inc("serve.requests")
                _obs.REGISTRY.inc("serve.points", q)
                _obs.REGISTRY.set_gauge("serve.queue_depth", n_chunks)
                # roofline entry for the sharded serve step: model ONE
                # microbatch through a fresh jit of the raw shard_map
                # program, scaled to the request
                costs = _cost.modeled(
                    "gp_serve_step_sharded", st._query_raw(b, self.chunks),
                    st.data, st._pad_cols(Xp[0:b]), scale=float(n_chunks))
            import time as _time

            t0 = _time.monotonic()
            outs = [st.posterior(Xp[i:i + b], chunks=self.chunks)
                    for i in range(0, q + pad, b)]
            if obs_on:
                jax.block_until_ready([o.value for o in outs])
                dt = _time.monotonic() - t0
                _obs.REGISTRY.observe("serve.request_seconds", dt)
                _cost.record_measured("gp_serve_step_sharded", dt, costs)
        return PosteriorBatch(
            value=jnp.concatenate([o.value for o in outs])[:q],
            grad=jnp.concatenate([o.grad for o in outs])[:q],
        )


def build_sharded_gp_serve_step(state, *, microbatch: int | None = None,
                                chunks: int | None = None,
                                config=None) -> ShardedGPServeBundle:
    """Compile a batched mean-query step for a ``ShardedGPGState``.

    The D-sharded analogue of :func:`build_gp_serve_step`: requests are
    padded to ``microbatch`` multiples and each chunk runs the state's
    cached shard_map query program (one fused psum of the (Q, N) cross
    strips per chunk).  ``chunks`` switches to the ring-pipelined
    (ppermute) variant, overlapping each sub-chunk's reduction with the
    next one's local factor sweep — flat one-axis meshes only.
    """
    from repro.configs.paper_gp import GP_SERVE
    from repro.core.dist_state import ShardedGPGState

    if not isinstance(state, ShardedGPGState):
        raise TypeError("build_sharded_gp_serve_step needs a "
                        "ShardedGPGState (build_gp_serve_step serves the "
                        "single-device GPGState)")
    if microbatch is None:
        microbatch = (config or GP_SERVE).microbatch
    if _obs.enabled():
        for name in ("serve.requests",):
            _obs.REGISTRY.inc(name, 0)
    return ShardedGPServeBundle(state=state, microbatch=int(microbatch),
                                chunks=chunks)

"""Sharding assignment for every train/serve input: params, optimizer
state, batches, and serving caches.

Rules (DESIGN.md sec. 6):
  * params — logical axes -> mesh axes (TP on 'model', FSDP on 'data').
  * optimizer state — mirrors param sharding; flat (history, D) GP/8-bit
    buffers shard D over ALL mesh axes; scalars replicated; adafactor
    factored stats inherit the surviving param axes.
  * batch — leading batch axis over ('pod','data') / ('data',).
  * caches — KV: batch over data axes when divisible, cache sequence over
    'model' (flash-decoding-style sharded-KV attention falls out of the
    GSPMD reduction); SSM states: heads over 'model'.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import ModelConfig, batch_axes_of, param_partition_specs
from repro.models.attention import KVCache
from repro.models.mamba2 import MambaState

Array = jnp.ndarray


def sanitize_spec(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Drop mesh axes that do not divide the corresponding dim.

    jit input shardings require exact divisibility; real configs have
    vocab sizes (50280, 256206) and head counts (24, 40) that do not
    divide 16. Dropping the offending axis replicates ONLY that dim — the
    other dims keep their sharding. The dry-run roofline notes where this
    costs memory (qwen2.5's 40 heads pad is the flagship example).
    """
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, parts):
        if entry is None:
            out.append(None)
            continue
        axes = tuple(entry) if isinstance(entry, tuple) else (entry,)
        while axes and dim % int(np.prod([mesh.shape[a] for a in axes])) != 0:
            axes = axes[:-1]
        out.append(axes if len(axes) > 1 else (axes[0] if axes else None))
    return P(*out)


def sanitize_spec_tree(specs: Any, abstract: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s, a: sanitize_spec(s, a.shape, mesh), specs, abstract,
        is_leaf=lambda x: isinstance(x, P))


def param_named_shardings(mesh: Mesh, axes_tree: Any,
                          params_abstract: Any = None) -> Any:
    specs = param_partition_specs(axes_tree)
    if params_abstract is not None:
        specs = sanitize_spec_tree(specs, params_abstract, mesh)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs,
                                  is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Optimizer state
# ---------------------------------------------------------------------------


def _all_axes(mesh: Mesh) -> tuple:
    return tuple(mesh.axis_names)


def opt_state_partition_specs(opt_name: str, params_abstract: Any,
                              param_specs: Any, state_abstract: Any,
                              mesh: Mesh) -> Any:
    """PartitionSpec tree matching an optimizer state's structure."""
    allax = _all_axes(mesh)
    n_dev = int(np.prod(mesh.devices.shape))

    def flat_spec(leaf):
        if leaf.ndim >= 1 and leaf.shape[-1] % n_dev == 0:
            return P(*([None] * (leaf.ndim - 1) + [allax]))
        return P()

    def mirror(sub_state, sub_params_spec):
        """m/v-style: same structure as params."""
        return jax.tree_util.tree_map(lambda _, s: s, sub_state,
                                      sub_params_spec,
                                      is_leaf=lambda x: isinstance(x, P))

    if opt_name in ("adamw", "momentum"):
        out = {"step": P()}
        for k in state_abstract:
            if k == "step":
                continue
            out[k] = mirror(state_abstract[k], param_specs)
        return out
    if opt_name == "sgd":
        return {"step": P()}
    if opt_name == "adafactor":
        p_leaves = jax.tree_util.tree_leaves(
            param_specs, is_leaf=lambda x: isinstance(x, P))
        pa_leaves = jax.tree_util.tree_leaves(params_abstract)

        def stats_spec(p_sds, spec):
            parts = list(spec) + [None] * (p_sds.ndim - len(spec))
            if p_sds.ndim >= 2:
                return {"r": P(*parts[:-1]), "c": P(*(parts[:-2] + parts[-1:]))}
            return {"v": P(*parts)}

        s_tree = state_abstract["s"]
        flat_s = jax.tree_util.tree_structure(
            jax.tree_util.tree_map(lambda x: 0, pa_leaves))
        stats = [stats_spec(p, s) for p, s in zip(pa_leaves, p_leaves)]
        s_specs = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(
                params_abstract), stats)
        return {"step": P(), "s": s_specs}
    if opt_name == "adamw8bit":
        def q_spec(q_sub):
            return {k: flat_spec(v) for k, v in q_sub.items()}

        q_specs = jax.tree_util.tree_map(
            q_spec, state_abstract["q"],
            is_leaf=lambda x: isinstance(x, dict) and "mq" in x)
        return {"step": P(), "q": q_specs}
    if opt_name == "gp_tree":
        def hist_spec(sub_params_spec):
            return jax.tree_util.tree_map(
                lambda s: P(*((None,) + tuple(s))), sub_params_spec,
                is_leaf=lambda x: isinstance(x, P))

        return {
            "step": P(), "count": P(),
            "xs": hist_spec(param_specs), "gs": hist_spec(param_specs),
            "m": jax.tree_util.tree_map(lambda s: s, param_specs,
                                        is_leaf=lambda x: isinstance(x, P)),
        }
    if opt_name.startswith("gp"):
        return {
            "step": P(), "count": P(),
            "xs": P(None, allax), "gs": P(None, allax), "m": P(allax),
        }
    raise ValueError(f"no sharding rule for optimizer {opt_name!r}")


# ---------------------------------------------------------------------------
# Batch / cache
# ---------------------------------------------------------------------------


def batch_partition_specs(cfg: ModelConfig, batch_specs: dict,
                          mesh: Mesh) -> dict:
    b_ax = batch_axes_of(mesh)
    out = {}
    for name, sds in batch_specs.items():
        out[name] = P(*((b_ax,) + (None,) * (len(sds.shape) - 1)))
    return out


def cache_partition_specs(cache_abstract: Any, mesh: Mesh,
                          batch_size: int) -> Any:
    """PartitionSpec tree for a (possibly stacked) cache pytree."""
    b_ax = batch_axes_of(mesh)
    b_shards = int(np.prod([mesh.shape[a] for a in b_ax]))
    shard_batch = batch_size % b_shards == 0 and batch_size >= b_shards

    def kv_spec(c: KVCache) -> KVCache:
        n_prefix = c.k.ndim - 4
        pre = (None,) * n_prefix
        b = b_ax if shard_batch else None
        seq = "model" if shard_batch else ("model",) + tuple(
            a for a in b_ax)      # B=1: spread cache seq over everything
        return KVCache(
            k=P(*(pre + (b, seq, None, None))),
            v=P(*(pre + (b, seq, None, None))),
            pos=P(*(pre + (b, seq))),
        )

    def mamba_spec(m: MambaState) -> MambaState:
        n_prefix = m.conv.ndim - 3
        pre = (None,) * n_prefix
        b = b_ax if shard_batch else None
        return MambaState(
            conv=P(*(pre + (b, None, "model"))),
            ssm=P(*(pre + (b, "model", None, None))),
        )

    def cross_spec(leaf) -> P:
        # enc-dec cross K/V: (L, B, S_src, Hk, hd)
        b = b_ax if shard_batch else None
        return P(None, b, "model", None, None)

    def walk(node):
        if isinstance(node, KVCache):
            return kv_spec(node)
        if isinstance(node, MambaState):
            return mamba_spec(node)
        if node is None:
            return None
        if hasattr(node, "_fields"):        # other NamedTuples (LMCache...)
            vals = {}
            for fld in node._fields:
                v = getattr(node, fld)
                if fld in ("cross_k", "cross_v") and v is not None:
                    vals[fld] = cross_spec(v)
                else:
                    vals[fld] = walk(v)
            return type(node)(**vals)
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        # bare array leaf
        return P()

    return walk(cache_abstract)

"""Next-token cross-entropy loss (all families; f32 logits).

MoE aux (load-balance) loss enters with a standard 0.01 coefficient.
The last position has no target and is masked.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jnp.ndarray

AUX_COEF = 0.01


def lm_loss(model, params, batch: dict):
    logits, aux = model.logits(params, batch)          # (B, S, V) f32
    tokens = batch["tokens"]
    targets = tokens[:, 1:]
    lg = logits[:, :-1, :]
    logz = jax.scipy.special.logsumexp(lg, axis=-1)
    tgt_logit = jnp.take_along_axis(lg, targets[..., None],
                                    axis=-1)[..., 0]
    ce = jnp.mean(logz - tgt_logit)
    loss = ce + AUX_COEF * aux
    return loss, {"loss": loss, "ce": ce, "aux": aux}

from .loss import lm_loss
from .sharding import (batch_partition_specs, cache_partition_specs,
                       opt_state_partition_specs, param_named_shardings)
from .step import TrainState, build_train_step, train_step_fn
from .serve import (build_decode_step, build_prefill_step,
                    build_gp_serve_step,
                    build_sharded_gp_serve_step)

__all__ = [
    "lm_loss", "batch_partition_specs", "cache_partition_specs",
    "opt_state_partition_specs", "param_named_shardings", "TrainState",
    "build_train_step", "train_step_fn", "build_decode_step",
    "build_prefill_step", "build_gp_serve_step",
    "build_sharded_gp_serve_step",
]

"""Train step builder: loss -> grads -> optimizer, with microbatch grad
accumulation, sharding-annotated for the production mesh.

The returned bundle carries everything the launcher and the dry-run need:
the jitted step, abstract input trees (params / opt state / batch) and
their NamedShardings — so `.lower(*abstract).compile()` is one call.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import (ModelConfig, batch_specs, build_model,
                          set_activation_rules)
from repro.optim import Optimizer

from .loss import lm_loss
from .sharding import (batch_partition_specs, opt_state_partition_specs,
                       param_named_shardings, sanitize_spec_tree)

Array = jnp.ndarray


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: int = 0


def train_step_fn(model, opt: Optimizer, microbatches: int = 1) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def loss_fn(params, mb):
        return lm_loss(model, params, mb)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def slice_mb(i):
                return jax.tree_util.tree_map(
                    lambda x: x.reshape((microbatches,
                                         x.shape[0] // microbatches)
                                        + x.shape[1:])[i], batch)

            def body(carry, i):
                acc, lsum = carry
                (l, m), g = grad_fn(params, slice_mb(i))
                acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g)
                return (acc, lsum + l), m

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), ms = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)),
                jnp.arange(microbatches))
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
            metrics = jax.tree_util.tree_map(lambda m: m[-1], ms)
            metrics["loss"] = loss
        new_params, new_opt_state = opt.update(grads, opt_state, params)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(grads)))
        metrics = dict(metrics, grad_norm=gnorm)
        return new_params, new_opt_state, metrics

    return step


@dataclasses.dataclass
class StepBundle:
    """Jitted step + everything needed to lower it abstractly."""

    step: Callable
    abstract_params: Any
    abstract_opt_state: Any
    abstract_batch: Any
    in_shardings: tuple
    model: Any
    opt: Optimizer


def build_train_step(cfg: ModelConfig, opt: Optimizer, mesh: Mesh, *,
                     shape: str = "train_4k", microbatches: int = 1,
                     donate: bool = True) -> StepBundle:
    model = build_model(cfg)
    set_activation_rules(mesh, cfg.seq_shard_activations)

    pa, axes = model.abstract()
    p_shard = param_named_shardings(mesh, axes, pa)
    oa = jax.eval_shape(opt.init, pa)
    o_specs = opt_state_partition_specs(opt.name, pa,
                                        jax.tree_util.tree_map(
                                            lambda s: s.spec, p_shard,
                                            is_leaf=lambda x: isinstance(
                                                x, NamedSharding)),
                                        oa, mesh)
    o_specs = sanitize_spec_tree(o_specs, oa, mesh)
    o_shard = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), o_specs,
        is_leaf=lambda x: isinstance(x, P))
    ba = batch_specs(cfg, shape)
    b_specs = sanitize_spec_tree(batch_partition_specs(cfg, ba, mesh), ba,
                                 mesh)
    b_shard = {k: NamedSharding(mesh, v) for k, v in b_specs.items()}

    fn = train_step_fn(model, opt, microbatches=microbatches)
    metrics_shard = NamedSharding(mesh, P())
    jitted = jax.jit(
        fn,
        in_shardings=(p_shard, o_shard, b_shard),
        out_shardings=(p_shard, o_shard, None),
        donate_argnums=(0, 1) if donate else (),
    )
    return StepBundle(step=jitted, abstract_params=pa, abstract_opt_state=oa,
                      abstract_batch=ba,
                      in_shardings=(p_shard, o_shard, b_shard),
                      model=model, opt=opt)

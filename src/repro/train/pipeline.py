"""GPipe-style pipeline parallelism over the 'pod' axis (optional mapping).

Default multi-pod mapping keeps pod=DP (gradient all-reduce is the most
latency-tolerant collective, so it belongs on the slow inter-pod links).
This module provides the alternative stage=pod mapping for models whose
weights cannot be FSDP'd effectively: layers split into `n_stages`
contiguous stages; microbatches stream through with the classic GPipe
schedule expressed as a shard_map over the stage axis + collective_permute
boundary transfers.

Schedule: for S stages and M microbatches, T = M + S - 1 ticks; at tick t
stage s processes microbatch (t - s) when 0 <= t - s < M. Implemented as a
lax.scan over ticks inside shard_map: every stage runs every tick (SPMD),
with masking for pipeline bubbles — the standard single-program GPipe
formulation. Backward runs through jax.grad of the whole pipelined
forward; XLA schedules the reverse permutes automatically.

Scope note: this is the structural/space-proof implementation (validated
for forward/backward equivalence against the sequential model on a
multi-device mesh in tests/test_pipeline.py); fusing it with the MoE/
attention layer stacks of models/ is future work — it operates on a
caller-supplied per-stage apply function.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.5 exposes shard_map at the top level
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map as _shard_map

# jax >= 0.6 tracks replicated-vs-varying types inside shard_map explicitly;
# older jax treats everything as varying, so pvary is the identity there.
_pvary = getattr(jax.lax, "pvary", lambda x, axes: x)

Array = jnp.ndarray


def gpipe_forward(
    stage_apply: Callable[[Any, Array], Array],
    stage_params: Any,              # pytree, leaves with leading (S,) axis
    x_mb: Array,                    # (M, mb, ...) microbatched input
    *,
    mesh: Mesh,
    stage_axis: str = "pod",
) -> Array:
    """Run x through S pipeline stages living on `stage_axis`.

    Returns the (M, mb, ...) outputs after the last stage. stage_params
    leaves are sharded P(stage_axis, ...); x_mb is replicated along the
    stage axis (each stage masks to its own schedule slot).
    """
    n_stages = mesh.shape[stage_axis]
    n_mb = x_mb.shape[0]
    ticks = n_mb + n_stages - 1

    param_specs = jax.tree_util.tree_map(
        lambda _: P(stage_axis), stage_params)

    @partial(
        _shard_map, mesh=mesh,
        in_specs=(param_specs, P()),
        out_specs=P(),
    )
    def run(params_local, x_all):
        # params_local leaves: (1, ...) — this device's stage
        p_stage = jax.tree_util.tree_map(lambda l: l[0], params_local)
        sidx = jax.lax.axis_index(stage_axis)

        def tick(carry, t):
            outputs, inflight = carry
            # stage s consumes microbatch (t - s); stage 0 reads fresh input
            mb_id = t - sidx
            fresh = x_all[jnp.clip(mb_id, 0, n_mb - 1)]
            x_in = jnp.where(sidx == 0, fresh, inflight)
            active = (mb_id >= 0) & (mb_id < n_mb)
            y = stage_apply(p_stage, x_in)
            y = jnp.where(active, y, inflight)
            # last stage writes its finished microbatch (mask-folded write —
            # lax.cond trips over varying manual axes under shard_map)
            idx = jnp.clip(mb_id, 0, n_mb - 1)
            upd = jnp.where(active & (sidx == n_stages - 1), y, outputs[idx])
            outputs = outputs.at[idx].set(upd)
            # boundary transfer: stage s -> s+1 (ring; wraparound ignored)
            nxt = jax.lax.ppermute(
                y, stage_axis,
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (outputs, nxt), None

        # initial carries must be marked device-varying along the stage axis
        out0 = _pvary(jnp.zeros_like(x_all), (stage_axis,))
        inflight0 = _pvary(jnp.zeros_like(x_all[0]), (stage_axis,))
        (outputs, _), _ = jax.lax.scan(tick, (out0, inflight0),
                                       jnp.arange(ticks))
        # outputs live on the last stage; broadcast to all members so the
        # out_specs=P() (replicated) contract holds
        outputs = jax.lax.psum(
            jnp.where(sidx == n_stages - 1, outputs, 0.0), stage_axis)
        return outputs

    return run(stage_params, x_mb)


def reference_forward(stage_apply, stage_params, x_mb):
    """Sequential oracle: apply all stages to every microbatch."""
    n_stages = jax.tree_util.tree_leaves(stage_params)[0].shape[0]

    def one(x):
        for s in range(n_stages):
            p = jax.tree_util.tree_map(lambda l: l[s], stage_params)
            x = stage_apply(p, x)
        return x

    return jax.vmap(one)(x_mb)

from .store import (CheckpointCorruptionError, CheckpointManager,
                    latest_step, manifest_index, restore_checkpoint,
                    restore_latest, save_checkpoint)

__all__ = ["CheckpointCorruptionError", "CheckpointManager", "latest_step",
           "manifest_index", "restore_checkpoint", "restore_latest",
           "save_checkpoint"]

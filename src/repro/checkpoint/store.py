"""Sharded NPZ checkpointing: manifest + per-leaf files, atomic commit,
rotation, async writer, elastic restore.

Layout:
  <root>/step_000123/          (committed atomically by dir rename)
    MANIFEST.json              step, leaf index (path -> file/shape/dtype),
                               mesh shape, data cursor, wall time
    <leaf_000>.npy ...         one file per pytree leaf

Fault-tolerance contract:
  * two-phase commit: everything is written under <root>/tmp_step_x/ and
    renamed to step_x last — a crash mid-write never yields a directory
    that restore() would pick up (restore only trusts dirs with MANIFEST
    whose "committed" flag is true).
  * rotation keeps the newest `keep` committed checkpoints.
  * elastic restore: leaves are stored as FULL logical arrays; restore
    device_puts them with the *target* sharding, so a run checkpointed on
    one mesh restores onto any other mesh/device count (tested 8->4->8).
  * async mode: save() copies to host then hands the write to a
    background thread — training never blocks on the filesystem.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

Array = Any

_SEP = "/"


def _flatten_with_names(tree: Any) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = _SEP.join(str(getattr(p, "key", getattr(p, "idx", getattr(
            p, "name", p)))) for p in path)
        out.append((name or "_root", leaf))
    return out


def _unflatten_like(tree: Any, named: dict[str, np.ndarray]) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, leaf in flat:
        name = _SEP.join(str(getattr(p, "key", getattr(p, "idx", getattr(
            p, "name", p)))) for p in path) or "_root"
        arr = named[name]
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(root: str, step: int, tree: Any, *,
                    extras: Optional[dict] = None, keep: int = 3) -> str:
    """Write a committed checkpoint; returns the final directory."""
    os.makedirs(root, exist_ok=True)
    tmp = os.path.join(root, f"tmp_step_{step:09d}")
    final = os.path.join(root, f"step_{step:09d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    named = _flatten_with_names(tree)
    index = {}
    for i, (name, leaf) in enumerate(named):
        fname = f"leaf_{i:05d}.npy"
        arr = np.asarray(jax.device_get(leaf))
        orig_dtype = str(arr.dtype)
        if arr.dtype.kind not in "biufc":       # ml_dtypes (bf16/f8): numpy
            arr = arr.astype(np.float32)        # can't reload them natively;
        #                                         f32 holds bf16 exactly
        np.save(os.path.join(tmp, fname), arr)
        index[name] = {"file": fname, "shape": list(arr.shape),
                       "dtype": orig_dtype}
    manifest = {
        "step": step,
        "committed": True,
        "time": time.time(),
        "index": index,
        "extras": extras or {},
    }
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic commit
    _rotate(root, keep)
    return final


def _committed_steps(root: str) -> list[int]:
    if not os.path.isdir(root):
        return []
    steps = []
    for d in os.listdir(root):
        if not d.startswith("step_"):
            continue
        mpath = os.path.join(root, d, "MANIFEST.json")
        try:
            with open(mpath) as f:
                m = json.load(f)
            if m.get("committed"):
                steps.append(int(m["step"]))
        except (OSError, ValueError, KeyError):
            continue
    return sorted(steps)


def _rotate(root: str, keep: int) -> None:
    steps = _committed_steps(root)
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(root, f"step_{s:09d}"), ignore_errors=True)


def latest_step(root: str) -> Optional[int]:
    steps = _committed_steps(root)
    return steps[-1] if steps else None


def restore_checkpoint(root: str, step: int, abstract_tree: Any, *,
                       shardings: Any = None) -> tuple[Any, dict]:
    """Load a checkpoint into the structure of `abstract_tree`.

    `shardings` (optional pytree of NamedSharding matching the tree)
    reshards onto the CURRENT mesh regardless of the mesh at save time.
    """
    d = os.path.join(root, f"step_{step:09d}")
    with open(os.path.join(d, "MANIFEST.json")) as f:
        manifest = json.load(f)
    named = {}
    for name, meta in manifest["index"].items():
        named[name] = np.load(os.path.join(d, meta["file"]))
    # shape guard: a checkpoint from a different model config must fail
    # loudly, not load garbage into mismatched leaves
    flat, _ = jax.tree_util.tree_flatten_with_path(abstract_tree)
    for path, sds in flat:
        name = _SEP.join(str(getattr(p, "key", getattr(p, "idx", getattr(
            p, "name", p)))) for p in path) or "_root"
        if name not in named:
            raise ValueError(f"checkpoint at step {step} missing leaf "
                             f"{name!r}")
        if tuple(named[name].shape) != tuple(sds.shape):
            raise ValueError(
                f"checkpoint leaf {name!r} has shape "
                f"{named[name].shape}, expected {tuple(sds.shape)} — "
                f"restoring a checkpoint from a different model config?")
    tree = _unflatten_like(abstract_tree, named)
    # cast dtypes to match the abstract tree (bf16 stored as f32 on disk);
    # route ml_dtypes casts through jnp (numpy can't cast to bfloat16)
    tree = jax.tree_util.tree_map(
        lambda a, sds: np.asarray(
            jax.numpy.asarray(a).astype(sds.dtype)), tree, abstract_tree)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    else:
        tree = jax.tree_util.tree_map(jax.numpy.asarray, tree)
    return tree, manifest["extras"]


class CheckpointManager:
    """Rotation + optional async writes around save/restore."""

    def __init__(self, root: str, *, keep: int = 3, async_write: bool = False):
        self.root = root
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree: Any, extras: Optional[dict] = None) -> None:
        if self.async_write:
            self.wait()
            # materialize on host BEFORE handing off so the trainer can
            # donate/overwrite device buffers immediately
            host_tree = jax.tree_util.tree_map(
                lambda l: np.asarray(jax.device_get(l)), tree)
            self._thread = threading.Thread(
                target=save_checkpoint, args=(self.root, step, host_tree),
                kwargs={"extras": extras, "keep": self.keep}, daemon=True)
            self._thread.start()
        else:
            save_checkpoint(self.root, step, tree, extras=extras,
                            keep=self.keep)

    def latest(self) -> Optional[int]:
        self.wait()
        return latest_step(self.root)

    def restore(self, step: int, abstract_tree: Any, shardings: Any = None):
        self.wait()
        return restore_checkpoint(self.root, step, abstract_tree,
                                  shardings=shardings)

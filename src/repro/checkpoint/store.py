"""Sharded NPZ checkpointing: manifest + per-leaf files, atomic commit,
rotation, async writer, elastic restore.

Layout:
  <root>/step_000123/          (committed atomically by dir rename)
    MANIFEST.json              step, leaf index (path -> file/shape/dtype),
                               mesh shape, data cursor, wall time
    <leaf_000>.npy ...         one file per pytree leaf

Fault-tolerance contract:
  * two-phase commit: everything is written under <root>/tmp_step_x/ and
    renamed to step_x last — a crash mid-write never yields a directory
    that restore() would pick up (restore only trusts dirs with MANIFEST
    whose "committed" flag is true).
  * rotation keeps the newest `keep` committed checkpoints.
  * elastic restore: leaves are stored as FULL logical arrays; restore
    device_puts them with the *target* sharding, so a run checkpointed on
    one mesh restores onto any other mesh/device count (tested 8->4->8).
  * async mode: save() copies to host then hands the write to a
    background thread — training never blocks on the filesystem.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

Array = Any

_SEP = "/"


class CheckpointCorruptionError(RuntimeError):
    """A committed checkpoint's leaf files do not match its manifest
    (truncated ``.npy``, size/dtype mismatch, missing file).

    Defined here rather than in ``repro.resilience.errors`` because this
    layer *detects* the corruption and the resilience package imports the
    checkpoint store (re-exported there for the one-stop taxonomy).
    """


def _flatten_with_names(tree: Any) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = _SEP.join(str(getattr(p, "key", getattr(p, "idx", getattr(
            p, "name", p)))) for p in path)
        out.append((name or "_root", leaf))
    return out


def _unflatten_like(tree: Any, named: dict[str, np.ndarray]) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, leaf in flat:
        name = _SEP.join(str(getattr(p, "key", getattr(p, "idx", getattr(
            p, "name", p)))) for p in path) or "_root"
        arr = named[name]
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(root: str, step: int, tree: Any, *,
                    extras: Optional[dict] = None, keep: int = 3) -> str:
    """Write a committed checkpoint; returns the final directory."""
    os.makedirs(root, exist_ok=True)
    tmp = os.path.join(root, f"tmp_step_{step:09d}")
    final = os.path.join(root, f"step_{step:09d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    named = _flatten_with_names(tree)
    index = {}
    for i, (name, leaf) in enumerate(named):
        fname = f"leaf_{i:05d}.npy"
        arr = np.asarray(jax.device_get(leaf))
        orig_dtype = str(arr.dtype)
        if arr.dtype.kind not in "biufc":       # ml_dtypes (bf16/f8): numpy
            arr = arr.astype(np.float32)        # can't reload them natively;
        #                                         f32 holds bf16 exactly
        np.save(os.path.join(tmp, fname), arr)
        index[name] = {"file": fname, "shape": list(arr.shape),
                       "dtype": orig_dtype}
    manifest = {
        "step": step,
        "committed": True,
        "time": time.time(),
        "index": index,
        "extras": extras or {},
    }
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic commit
    _rotate(root, keep)
    return final


def _committed_steps(root: str) -> list[int]:
    if not os.path.isdir(root):
        return []
    steps = []
    for d in os.listdir(root):
        if not d.startswith("step_"):
            continue
        mpath = os.path.join(root, d, "MANIFEST.json")
        try:
            with open(mpath) as f:
                m = json.load(f)
            if m.get("committed"):
                steps.append(int(m["step"]))
        except (OSError, ValueError, KeyError):
            continue
    return sorted(steps)


def _rotate(root: str, keep: int) -> None:
    steps = _committed_steps(root)
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(root, f"step_{s:09d}"), ignore_errors=True)


def latest_step(root: str) -> Optional[int]:
    steps = _committed_steps(root)
    return steps[-1] if steps else None


def restore_checkpoint(root: str, step: int, abstract_tree: Any, *,
                       shardings: Any = None) -> tuple[Any, dict]:
    """Load a checkpoint into the structure of `abstract_tree`.

    `shardings` (optional pytree of NamedSharding matching the tree)
    reshards onto the CURRENT mesh regardless of the mesh at save time.
    """
    d = os.path.join(root, f"step_{step:09d}")
    with open(os.path.join(d, "MANIFEST.json")) as f:
        manifest = json.load(f)
    named = {}
    for name, meta in manifest["index"].items():
        fpath = os.path.join(d, meta["file"])
        # corrupted-leaf detection: a committed manifest is necessary but
        # not sufficient — the leaf bytes can still rot (torn write after
        # rename on non-atomic filesystems, bit flips, truncation).  Any
        # mismatch against the manifest's own index is typed corruption so
        # callers (restore_latest) can skip to an older committed step.
        try:
            arr = np.load(fpath)
        except (OSError, ValueError, EOFError) as e:
            raise CheckpointCorruptionError(
                f"checkpoint step {step}: leaf {name!r} ({meta['file']}) "
                f"unreadable: {e}") from e
        if tuple(arr.shape) != tuple(meta["shape"]):
            raise CheckpointCorruptionError(
                f"checkpoint step {step}: leaf {name!r} has shape "
                f"{tuple(arr.shape)} but manifest says "
                f"{tuple(meta['shape'])}")
        # non-native dtypes (bf16/f8) are stored as f32 (see save); only
        # flag a file whose dtype matches NEITHER the manifest nor f32
        if (str(arr.dtype) != meta["dtype"]
                and arr.dtype != np.float32):
            raise CheckpointCorruptionError(
                f"checkpoint step {step}: leaf {name!r} has dtype "
                f"{arr.dtype} but manifest says {meta['dtype']}")
        named[name] = arr
    # shape guard: a checkpoint from a different model config must fail
    # loudly, not load garbage into mismatched leaves
    flat, _ = jax.tree_util.tree_flatten_with_path(abstract_tree)
    for path, sds in flat:
        name = _SEP.join(str(getattr(p, "key", getattr(p, "idx", getattr(
            p, "name", p)))) for p in path) or "_root"
        if name not in named:
            raise ValueError(f"checkpoint at step {step} missing leaf "
                             f"{name!r}")
        if tuple(named[name].shape) != tuple(sds.shape):
            raise ValueError(
                f"checkpoint leaf {name!r} has shape "
                f"{named[name].shape}, expected {tuple(sds.shape)} — "
                f"restoring a checkpoint from a different model config?")
    tree = _unflatten_like(abstract_tree, named)
    # cast dtypes to match the abstract tree (bf16 stored as f32 on disk);
    # route ml_dtypes casts through jnp (numpy can't cast to bfloat16)
    tree = jax.tree_util.tree_map(
        lambda a, sds: np.asarray(
            jax.numpy.asarray(a).astype(sds.dtype)), tree, abstract_tree)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    else:
        tree = jax.tree_util.tree_map(jax.numpy.asarray, tree)
    return tree, manifest["extras"]


def manifest_index(root: str, step: int) -> dict:
    """The manifest's leaf index {name: {file, shape, dtype}} for a step
    (lets callers build an abstract tree without knowing the pytree)."""
    d = os.path.join(root, f"step_{step:09d}")
    with open(os.path.join(d, "MANIFEST.json")) as f:
        return json.load(f)["index"]


def restore_latest(root: str, abstract_tree: Any, *,
                   shardings: Any = None) -> tuple[int, Any, dict]:
    """Restore the newest committed checkpoint that passes corruption
    checks, walking backwards past corrupted steps.

    Returns (step, tree, extras).  Each skipped step increments the
    ``resilience.checkpoint_fallbacks`` counter and emits a JSONL event
    so chaos runs can gate that corruption was detected AND survived.
    Raises CheckpointCorruptionError only when every committed step is
    corrupt; FileNotFoundError when there are none at all.
    """
    steps = _committed_steps(root)
    if not steps:
        raise FileNotFoundError(f"no committed checkpoints under {root!r}")
    last_err: Optional[Exception] = None
    for step in reversed(steps):
        try:
            tree, extras = restore_checkpoint(root, step, abstract_tree,
                                              shardings=shardings)
            return step, tree, extras
        except CheckpointCorruptionError as e:
            last_err = e
            from repro.obs import trace as _trace  # deferred: no cycles
            _trace.REGISTRY.inc("resilience.checkpoint_fallbacks")
            _trace.emit({"type": "resilience",
                         "action": "checkpoint_fallback",
                         "skipped_step": step, "error": str(e)})
    raise CheckpointCorruptionError(
        f"every committed checkpoint under {root!r} is corrupt "
        f"(steps {steps})") from last_err


class CheckpointManager:
    """Rotation + optional async writes around save/restore."""

    def __init__(self, root: str, *, keep: int = 3, async_write: bool = False):
        self.root = root
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree: Any, extras: Optional[dict] = None) -> None:
        if self.async_write:
            self.wait()
            # materialize on host BEFORE handing off so the trainer can
            # donate/overwrite device buffers immediately
            host_tree = jax.tree_util.tree_map(
                lambda l: np.asarray(jax.device_get(l)), tree)
            self._thread = threading.Thread(
                target=save_checkpoint, args=(self.root, step, host_tree),
                kwargs={"extras": extras, "keep": self.keep}, daemon=True)
            self._thread.start()
        else:
            save_checkpoint(self.root, step, tree, extras=extras,
                            keep=self.keep)

    def latest(self) -> Optional[int]:
        self.wait()
        return latest_step(self.root)

    def restore(self, step: int, abstract_tree: Any, shardings: Any = None):
        self.wait()
        return restore_checkpoint(self.root, step, abstract_tree,
                                  shardings=shardings)

    def restore_latest(self, abstract_tree: Any, shardings: Any = None):
        """Newest committed checkpoint that passes corruption checks;
        returns (step, tree, extras)."""
        self.wait()
        return restore_latest(self.root, abstract_tree, shardings=shardings)

"""Typed failure taxonomy for the resilience subsystem (DESIGN.md sec. 17).

Every failure the serving stack can recover from gets its own exception
class so serve loops can branch on *type*, not on string matching:

  ResilienceError                      — base of the whole taxonomy
    NonFiniteObservationError          — NaN/inf payload rejected at
                                         admission, BEFORE it touches a
                                         factor strip
    UnsupportedQueryError              — the query is well-posed but this
                                         state flavor cannot answer it
                                         (e.g. grad_std through a
                                         reduction frame); also subclasses
                                         NotImplementedError so legacy
                                         callers keep working
    DeadlineExceededError              — per-request deadline expired in
                                         the serve queue
    QueueOverloadError                 — request shed at admission
                                         (queue-depth limit)
    RetryExhaustedError                — a retryable failure survived the
                                         bounded-retry budget
    TenantQuarantinedError             — the tenant's lane was masked
                                         inert after repeated failures
    JournalCorruptionError             — op-journal digest mismatch or
                                         undecodable entry on replay

``CheckpointCorruptionError`` is defined in ``repro.checkpoint.store``
(the layer that detects it — importing this package from there would be
a cycle) and re-exported here so the taxonomy has one import surface.

``ShedResponse`` is the *typed shed value*: load-shedding is an expected
serving outcome, not an exception, so shed requests complete immediately
with a ``ShedResponse`` result instead of raising into the caller.
"""
from __future__ import annotations

from typing import NamedTuple

from repro.checkpoint.store import CheckpointCorruptionError


class ResilienceError(RuntimeError):
    """Base class for every typed failure the serving stack can handle."""


class NonFiniteObservationError(ResilienceError, ValueError):
    """A NaN/inf observation was rejected before touching any factor."""


class UnsupportedQueryError(ResilienceError, NotImplementedError):
    """This state flavor cannot answer the query (degrade, don't die)."""


class DeadlineExceededError(ResilienceError):
    """The request's deadline expired while it waited in the serve queue."""


class QueueOverloadError(ResilienceError):
    """The serve queue is at its depth limit; the request was shed."""


class RetryExhaustedError(ResilienceError):
    """A retryable failure persisted past the bounded-retry budget."""


class TenantQuarantinedError(ResilienceError):
    """The tenant was quarantined (lane masked inert) after repeated
    failures; pending and future requests fail with this type."""


class JournalCorruptionError(ResilienceError):
    """An op-journal entry failed its digest check (or cannot decode)."""


class ShedResponse(NamedTuple):
    """Typed result attached to a request shed at admission."""

    reason: str
    queue_depth: int


__all__ = [
    "ResilienceError",
    "NonFiniteObservationError",
    "UnsupportedQueryError",
    "DeadlineExceededError",
    "QueueOverloadError",
    "RetryExhaustedError",
    "TenantQuarantinedError",
    "JournalCorruptionError",
    "CheckpointCorruptionError",
    "ShedResponse",
]

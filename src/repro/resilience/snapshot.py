"""Snapshot/restore for all three inference-state flavors through the
existing two-phase ``CheckpointManager``.

What makes this cheap is the paper's decomposition itself: the complete
posterior is O(N^2 D + (N^2)^2) bytes of factor strips, streams and
representers — never an (ND, ND) Gram — so a full snapshot is a handful
of small ``.npy`` leaves plus a JSON extras blob of host scalars
(hypers, policy, revision counters).

Flavors and their elastic-restore contracts:

  GPGState        exact restore (same capacity); compressed states
                  persist their reduction frame + raw-stream copies.
  GPFleet         per-lane snapshot: restores at ANY lane packing / batch
                  size — tenants re-join in saved-slot order and their
                  lane leaves are written back verbatim, so every
                  per-tenant lane is bitwise-identical regardless of the
                  target batch (vmapped ops are lane-independent).
  ShardedGPGState D-axis leaves are stored TRIMMED to d_orig and
                  re-padded for the target mesh (zero pad columns are
                  exactly inert) — a state snapshotted on one mesh
                  restores onto any device count.  Same-mesh restore is
                  bitwise; replay after a cross-mesh restore matches to
                  accumulation-order rounding.

Restore walks committed checkpoints newest-first and skips corrupted
ones (typed ``CheckpointCorruptionError`` from the store layer), so a
torn leaf costs one checkpoint interval, never the state.
"""
from __future__ import annotations

from typing import Any, Optional

import numpy as np

from repro.checkpoint.store import (CheckpointCorruptionError,
                                    CheckpointManager, _committed_steps,
                                    manifest_index, restore_checkpoint)
from repro.obs import trace as _trace

_DATA_FIELDS = ("X", "G", "Xt", "K1e", "K2e", "L", "Z", "lam", "count",
                "n_refactor", "n_solve", "cg_iters", "resnorm")


def _np(leaf) -> np.ndarray:
    import jax

    return np.asarray(jax.device_get(leaf))


def _data_tree(data, prefix: str = "") -> dict:
    tree = {prefix + f: _np(getattr(data, f)) for f in _DATA_FIELDS}
    if data.c is not None:
        tree[prefix + "c"] = _np(data.c)
    return tree


def _data_from_tree(data, tree: dict, prefix: str = ""):
    """Rebuild a ``GPGData`` in the image of ``data`` from named leaves."""
    import jax.numpy as jnp

    kw = {f: jnp.asarray(tree[prefix + f]) for f in _DATA_FIELDS}
    if prefix + "c" in tree:
        kw["c"] = jnp.asarray(tree[prefix + "c"])
    return data._replace(**kw)


# ---------------------------------------------------------------------------
# Per-flavor snapshot trees
# ---------------------------------------------------------------------------


def _snap_single(st) -> tuple[dict, dict]:
    tree = _data_tree(st.data)
    extras = {
        "flavor": "single", "kernel": st.spec.name, "d": st.d,
        "capacity": st.data.capacity, "window": st.window,
        "noise": st.noise, "signal": st.signal, "jitter": st.jitter,
        "deg_thresh": st.deg_thresh, "tol": st.tol, "maxiter": st.maxiter,
        "precision": st.precision, "dtype": str(st.data.X.dtype),
        "policy_mode": st.policy.mode, "policy_capacity": st.policy.capacity,
        "last_regime": st._last_regime,
        "revision": st.revision, "factor_revision": st.factor_revision,
        "reduced": st._reduction is not None,
    }
    if st._reduction is not None:
        red = st._reduction
        tree["red_basis"] = _np(red.basis)
        tree["red_base"] = _np(red.base)
        tree["red_Xr"] = _np(red.Xr)
        tree["red_Gr"] = _np(red.Gr)
        tree["red_residual"] = _np(red.residual)
        tree["raw_X"] = np.stack([_np(r) for r in st._raw_X])
        tree["raw_G"] = np.stack([_np(r) for r in st._raw_G])
    return tree, extras


def _build_single(tree: dict, extras: dict):
    import jax.numpy as jnp

    from repro.core.state import GPGState
    from repro.regime.policy import RegimePolicy

    st = GPGState(
        extras["kernel"], int(extras["d"]),
        capacity=int(extras["capacity"]), window=extras["window"],
        noise=extras["noise"], signal=extras["signal"],
        jitter=extras["jitter"], deg_thresh=extras["deg_thresh"],
        tol=extras["tol"], maxiter=extras["maxiter"],
        dtype=np.dtype(extras["dtype"]), precision=extras["precision"],
        policy=RegimePolicy(mode=extras["policy_mode"],
                            capacity=extras["policy_capacity"]))
    st.data = _data_from_tree(st.data, tree)
    st._last_regime = extras.get("last_regime")
    st.revision = int(extras["revision"])
    st.factor_revision = int(extras["factor_revision"])
    if extras.get("reduced"):
        from repro.regime.reduction import Reduction

        st._reduction = Reduction(
            basis=jnp.asarray(tree["red_basis"]),
            base=jnp.asarray(tree["red_base"]),
            Xr=jnp.asarray(tree["red_Xr"]),
            Gr=jnp.asarray(tree["red_Gr"]),
            residual=jnp.asarray(tree["red_residual"]))
        st._raw_X = [jnp.asarray(r) for r in tree["raw_X"]]
        st._raw_G = [jnp.asarray(r) for r in tree["raw_G"]]
    return st


def _snap_fleet(fl) -> tuple[dict, dict]:
    tree = _data_tree(fl.fleet.data)
    tree["noise"] = _np(fl.fleet.noise)
    tree["signal"] = _np(fl.fleet.signal)
    tree["active"] = _np(fl.fleet.active)
    extras = {
        "flavor": "fleet", "kernel": fl.spec.name, "d": fl.d,
        "capacity": fl.capacity, "batch": fl.batch, "window": fl.window,
        "defaults": {k: float(v) for k, v in fl.defaults.items()},
        "jitter": fl.jitter, "deg_thresh": fl.deg_thresh, "tol": fl.tol,
        "maxiter": fl.maxiter, "dtype": str(fl.fleet.data.X.dtype),
        # JSON keys must be strings; the serve layer's tenants are
        "slots": {str(t): int(s) for t, s in fl._slots.items()},
        "revision": list(fl.revision),
        "factor_revision": list(fl.factor_revision),
    }
    return tree, extras


def _build_fleet(tree: dict, extras: dict, *, batch: Optional[int] = None):
    import jax.numpy as jnp

    from repro.core.fleet import FleetGPGData, GPFleet

    saved_batch = int(extras["batch"])
    target = saved_batch if batch is None else int(batch)
    dd = extras["defaults"]
    fl = GPFleet(extras["kernel"], int(extras["d"]),
                 capacity=int(extras["capacity"]), batch=target,
                 window=extras["window"], lam=dd["lam"], noise=dd["noise"],
                 signal=dd["signal"], jitter=extras["jitter"],
                 deg_thresh=extras["deg_thresh"], tol=extras["tol"],
                 maxiter=extras["maxiter"], dtype=np.dtype(extras["dtype"]))
    slots = {t: int(s) for t, s in extras["slots"].items()}
    if target == saved_batch:
        # same packing: verbatim stacked leaves (bitwise restore)
        data = _data_from_tree(fl.fleet.data, tree)
        fl.fleet = FleetGPGData(
            data=data, noise=jnp.asarray(tree["noise"]),
            signal=jnp.asarray(tree["signal"]),
            active=jnp.asarray(tree["active"]))
        fl._slots = dict(slots)
        fl._free = [s for s in range(target)
                    if s not in set(slots.values())][::-1]
        fl.revision = [int(r) for r in extras["revision"]]
        fl.factor_revision = [int(r) for r in extras["factor_revision"]]
        return fl
    # elastic repack: re-join tenants in saved-slot order, then write
    # each saved lane back verbatim — per-lane bits are packing-invariant
    if len(slots) > target:
        raise ValueError(
            f"cannot repack {len(slots)} tenants into batch={target}")
    order = sorted(slots, key=lambda t: slots[t])
    for t in order:
        fl.join(t)
    data, noise, signal = fl.fleet.data, fl.fleet.noise, fl.fleet.signal
    fields = _DATA_FIELDS + (("c",) if "c" in tree else ())
    for t in order:
        src, dst = slots[t], fl._slots[t]
        data = data._replace(**{
            f: getattr(data, f).at[dst].set(jnp.asarray(tree[f])[src])
            for f in fields})
        noise = noise.at[dst].set(jnp.asarray(tree["noise"])[src])
        signal = signal.at[dst].set(jnp.asarray(tree["signal"])[src])
        fl.revision[dst] = int(extras["revision"][src])
        fl.factor_revision[dst] = int(extras["factor_revision"][src])
    fl.fleet = FleetGPGData(data=data, noise=noise, signal=signal,
                            active=fl.fleet.active)
    return fl


def _snap_sharded(st) -> tuple[dict, dict]:
    tree = st.snapshot_arrays()
    extras = {
        "flavor": "sharded", "kernel": st.spec.name, "d": st.d_orig,
        "capacity": st.data.capacity, "window": st.window,
        "noise": st.noise, "signal": st.signal, "jitter": st.jitter,
        "deg_thresh": st.deg_thresh,
        "dtype": str(np.asarray(tree["X"]).dtype),
        "revision": st.revision,
    }
    return tree, extras


def _build_sharded(tree: dict, extras: dict, *, mesh=None):
    from repro.core.dist_state import ShardedGPGState

    st = ShardedGPGState(
        extras["kernel"], int(extras["d"]), mesh=mesh,
        capacity=int(extras["capacity"]), window=extras["window"],
        noise=extras["noise"], signal=extras["signal"],
        jitter=extras["jitter"], deg_thresh=extras["deg_thresh"],
        dtype=np.dtype(extras["dtype"]))
    st.load_snapshot_arrays(tree)
    st.revision = int(extras["revision"])
    return st


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


def snapshot(state, root: str, *, step: int, keep: int = 5,
             manager: Optional[CheckpointManager] = None,
             journal=None) -> str:
    """Write one committed snapshot of any state flavor; returns the
    checkpoint directory.  With ``journal``, a snapshot marker is
    appended so replay knows where the journal tail starts."""
    from repro.core.dist_state import ShardedGPGState
    from repro.core.fleet import GPFleet
    from repro.core.state import GPGState

    with _trace.span("resilience.snapshot", step=step):
        if isinstance(state, GPGState):
            tree, extras = _snap_single(state)
        elif isinstance(state, GPFleet):
            tree, extras = _snap_fleet(state)
        elif isinstance(state, ShardedGPGState):
            tree, extras = _snap_sharded(state)
        else:
            raise TypeError(f"cannot snapshot {type(state).__name__}")
        mgr = manager or CheckpointManager(root, keep=keep)
        mgr.save(step, tree, extras=extras)
        mgr.wait()
        if journal is not None:
            journal.mark_snapshot(step)
        _trace.REGISTRY.inc("resilience.snapshots")
        _trace.emit({"type": "resilience", "action": "snapshot",
                     "step": step, "flavor": extras["flavor"]})
    path = f"{root}/step_{step:09d}"
    return path


def _abstract_from_index(index: dict) -> dict:
    import jax

    return {name: jax.ShapeDtypeStruct(tuple(meta["shape"]),
                                       np.dtype(meta["dtype"]))
            for name, meta in index.items()}


def restore(root: str, *, step: Optional[int] = None, mesh=None,
            batch: Optional[int] = None) -> Any:
    """Rebuild a state from the newest good snapshot under ``root``.

    ``step`` pins a specific snapshot; otherwise committed steps are
    tried newest-first and corrupted ones skipped (counted as
    ``resilience.checkpoint_fallbacks``).  ``mesh`` retargets a sharded
    snapshot; ``batch`` repacks a fleet snapshot elastically.
    """
    with _trace.span("resilience.restore"):
        steps = [step] if step is not None else \
            list(reversed(_committed_steps(root)))
        if not steps:
            raise FileNotFoundError(f"no committed snapshots under {root!r}")
        last_err: Optional[Exception] = None
        for s in steps:
            try:
                abstract = _abstract_from_index(manifest_index(root, s))
                tree, extras = restore_checkpoint(root, s, abstract)
                break
            except CheckpointCorruptionError as e:
                last_err = e
                _trace.REGISTRY.inc("resilience.checkpoint_fallbacks")
                _trace.emit({"type": "resilience",
                             "action": "checkpoint_fallback",
                             "skipped_step": s, "error": str(e)})
        else:
            raise CheckpointCorruptionError(
                f"every committed snapshot under {root!r} is corrupt"
            ) from last_err
        tree = {k: np.asarray(v) for k, v in tree.items()}
        flavor = extras["flavor"]
        if flavor == "single":
            state = _build_single(tree, extras)
        elif flavor == "fleet":
            state = _build_fleet(tree, extras, batch=batch)
        elif flavor == "sharded":
            state = _build_sharded(tree, extras, mesh=mesh)
        else:
            raise ValueError(f"unknown snapshot flavor {flavor!r}")
        _trace.REGISTRY.inc("resilience.restores")
        _trace.emit({"type": "resilience", "action": "restore",
                     "step": s, "flavor": flavor})
    return state

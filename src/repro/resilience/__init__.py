"""repro.resilience — fault tolerance for the serving stack (DESIGN.md
sec. 17).

  errors.py      typed failure taxonomy (admission rejects, degraded
                 queries, shed/deadline/retry/quarantine, corruption)
  guardrails.py  host-side numerical guardrails: non-finite admission,
                 the jitter-escalation ladder, the CG-divergence
                 watchdog predicate, the bf16-drift trip-wire — zero
                 jaxpr cost by construction
  snapshot.py    snapshot/restore of all three state flavors through the
                 two-phase CheckpointManager (elastic fleet repack,
                 cross-mesh sharded restore, corruption fallback)
  journal.py     append-only op journal + bit-exact replay since the
                 last snapshot
  chaos.py       deterministic seed-replayable fault injector extending
                 runtime.recovery.FailureInjector to the serve path

The recovery invariant the tests enforce: snapshot + journal replay
reproduces an uninterrupted run BIT-IDENTICALLY (single and fleet
flavors; sharded on the same mesh), and every chaos-injected fault is
detected and recovered with matching ``resilience.*`` telemetry and
zero recompiles.
"""
from repro.resilience import chaos, errors, guardrails, journal, snapshot
from repro.resilience.chaos import FAULT_KINDS, ChaosInjector
from repro.resilience.errors import (CheckpointCorruptionError,
                                     DeadlineExceededError,
                                     JournalCorruptionError,
                                     NonFiniteObservationError,
                                     QueueOverloadError, ResilienceError,
                                     RetryExhaustedError, ShedResponse,
                                     TenantQuarantinedError,
                                     UnsupportedQueryError)
from repro.resilience.guardrails import (bf16_tripwire, check_finite,
                                         enabled, factor_ok,
                                         heal_factorization,
                                         record_recovery, set_enabled,
                                         use_guardrails)
from repro.resilience.journal import Journal, replay_fleet, replay_single
from repro.resilience.snapshot import restore, snapshot as take_snapshot

__all__ = [
    "chaos", "errors", "guardrails", "journal", "snapshot",
    "ChaosInjector", "FAULT_KINDS",
    "ResilienceError", "NonFiniteObservationError", "UnsupportedQueryError",
    "DeadlineExceededError", "QueueOverloadError", "RetryExhaustedError",
    "TenantQuarantinedError", "JournalCorruptionError",
    "CheckpointCorruptionError", "ShedResponse",
    "enabled", "set_enabled", "use_guardrails", "check_finite",
    "factor_ok", "heal_factorization", "bf16_tripwire", "record_recovery",
    "Journal", "replay_single", "replay_fleet",
    "take_snapshot", "restore",
]

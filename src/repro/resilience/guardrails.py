"""Numerical guardrails: admission checks, the jitter-escalation ladder,
the CG-divergence watchdog, and the bf16-drift trip-wire.

Everything in this module runs on the HOST, outside jit — the guarded
jitted programs are byte-for-byte the same jaxprs with guardrails on or
off (the zero-cost contract, asserted by ``bench_resilience`` with the
same primitive-count technique as ``obs/injit.py``).  The only cost the
happy path pays is a handful of scalar device reads per mutation, and
only while guardrails are enabled.

Master switch: ``REPRO_GUARDRAILS`` env var ("1"/"on"/"true"/"yes"; the
default is ON — resilience is the point), overridable in-process with
:func:`set_enabled` / the :func:`use_guardrails` context manager, same
shape as ``obs.trace``.

The guardrail ladder (DESIGN.md sec. 17.2), triggered when a factor goes
non-finite or a solve diverges:

  rung 0   exact refactor at the state's own jitter (corrupted-factor
           case: X/G masters are fine, the Cholesky is not);
  rung k   exact refactor at jitter * 10^k (genuinely degenerate stream:
           duplicated observations, collapsed pivots) — the escalated
           jitter STAYS on the state, because the stream that needed it
           still does;
  give up  restore the original jitter, leave telemetry, raise nothing —
           the caller decides (serving degrades, tests fail loudly).

Every action increments ``resilience.*`` counters and emits a JSONL
event through ``obs.trace`` so ``tools/check_telemetry.py`` can gate
recovery behavior.
"""
from __future__ import annotations

import os
from contextlib import contextmanager
from typing import Optional

import numpy as np

from repro.obs import trace as _trace
from repro.resilience.errors import NonFiniteObservationError

_FORCED: Optional[bool] = None


def enabled() -> bool:
    """Guardrails master switch (default ON)."""
    if _FORCED is not None:
        return _FORCED
    return os.environ.get("REPRO_GUARDRAILS", "on").lower() in (
        "1", "on", "true", "yes")


def set_enabled(flag: Optional[bool]) -> None:
    """Force guardrails on/off in-process (None = back to the env var)."""
    global _FORCED
    _FORCED = flag


@contextmanager
def use_guardrails(flag: bool = True):
    prev = _FORCED
    set_enabled(flag)
    try:
        yield
    finally:
        set_enabled(prev)


def record_recovery(kind: str, **attrs) -> None:
    """One handled fault: bump the recovery counters + emit an event.

    The chaos accounting contract: the injector bumps
    ``resilience.faults_injected`` once per injected fault, every handler
    calls this exactly once per fault it detects-and-handles, and
    ``check_telemetry --expect-recovery`` gates the two counters equal.
    """
    _trace.REGISTRY.inc("resilience.faults_recovered")
    _trace.REGISTRY.inc(f"resilience.recovered.{kind}")
    _trace.emit({"type": "resilience", "action": "recovered",
                 "kind": kind, **attrs})


# ---------------------------------------------------------------------------
# Admission: non-finite observations never touch a factor
# ---------------------------------------------------------------------------


def check_finite(*arrays, what: str = "observation",
                 tenant=None) -> None:
    """Reject non-finite payloads with a typed error BEFORE any factor op.

    Host-side by construction: the admission read happens on the request
    payload (usually already a numpy array), never inside a traced
    program, so the serve jaxprs are untouched.
    """
    if not enabled():
        return
    for a in arrays:
        if a is None:
            continue
        arr = np.asarray(a, dtype=np.float64)
        if not np.all(np.isfinite(arr)):
            _trace.REGISTRY.inc("resilience.rejected_nonfinite")
            _trace.emit({"type": "resilience", "action": "reject_nonfinite",
                         "what": what,
                         **({"tenant": str(tenant)} if tenant else {})})
            raise NonFiniteObservationError(
                f"non-finite {what} rejected at admission"
                + (f" (tenant {tenant!r})" if tenant is not None else ""))


# ---------------------------------------------------------------------------
# Jitter-escalation ladder on degenerate / corrupted factorizations
# ---------------------------------------------------------------------------


def factor_ok(state, *, cond_limit: Optional[float] = None) -> bool:
    """Is the cached factorization serviceable?  Finite L diagonal,
    finite representers/residual, and (optionally) a condition-proxy
    bound from ``obs.health``."""
    import jax.numpy as jnp

    data = state.data
    n = int(data.count)
    if n < 1:
        return True
    diag = jnp.diagonal(data.L)[:n]
    ok = bool(jnp.all(jnp.isfinite(diag))
              & jnp.all(jnp.isfinite(data.Z[:n]))
              & jnp.isfinite(data.resnorm))
    if not ok:
        return False
    if cond_limit is not None:
        from repro.obs.health import condition_proxy

        if condition_proxy(data) > cond_limit:
            return False
    return True


def heal_factorization(state, *, max_rungs: int = 3,
                       factor: float = 10.0,
                       cond_limit: Optional[float] = None) -> int:
    """Climb the jitter ladder until the factorization is serviceable.

    Returns the rung that healed (0 = plain exact refactor), or -1 when
    even jitter * factor**max_rungs could not produce finite factors (the
    original jitter is restored in that case).
    """
    base = state.jitter
    for rung in range(max_rungs + 1):
        state.jitter = base * (factor ** rung)
        state.refactor()
        _trace.REGISTRY.inc("resilience.jitter_escalations" if rung
                            else "resilience.refactor_heals")
        if factor_ok(state, cond_limit=cond_limit):
            _trace.emit({"type": "resilience", "action": "heal",
                         "rung": rung, "jitter": float(state.jitter),
                         "n": state.n})
            return rung
    state.jitter = base
    _trace.REGISTRY.inc("resilience.heal_failed")
    _trace.emit({"type": "resilience", "action": "heal_failed",
                 "max_jitter": base * factor ** max_rungs, "n": state.n})
    return -1


def after_mutation(state) -> bool:
    """Post-extend watchdog hook (called by ``GPGState.extend`` while
    guardrails are on): one fused scalar read of the fresh pivot +
    residual; on non-finite, climb the ladder and record the recovery.

    Returns True when a heal ran.  Triggers on NON-FINITE only — large
    residuals on a healthy stream are the iterative regime's business,
    and spurious jitter escalation would perturb exact-path answers.
    """
    import jax.numpy as jnp

    data = state.data
    n = int(data.count)
    if n < 1:
        return False
    pivot = jnp.diagonal(data.L)[n - 1]
    if bool(jnp.isfinite(pivot) & jnp.isfinite(data.resnorm)):
        return False
    _trace.REGISTRY.inc("resilience.factor_faults")
    rung = heal_factorization(state)
    if rung >= 0:
        record_recovery("degenerate_factor", rung=rung, n=n)
    return True


# ---------------------------------------------------------------------------
# CG-divergence watchdog (the regime/iterative path)
# ---------------------------------------------------------------------------


def cg_diverged(resnorm, rhs_norm: float) -> bool:
    """Divergence predicate for an iterative solve: a non-finite residual
    or one that GREW past the zero-iteration residual (||b||) means the
    Krylov recurrence broke (poisoned warm start, indefinite operator) —
    falling back to the exact solver is the only honest answer."""
    rn = float(resnorm)
    if not np.isfinite(rn):
        return True
    return rhs_norm > 0.0 and rn > 10.0 * rhs_norm


# ---------------------------------------------------------------------------
# bf16-drift trip-wire
# ---------------------------------------------------------------------------


def bf16_tripwire(state, *, limit: float = 0.05, n_points: int = 4) -> bool:
    """Validate the cached bf16 stream copies against the f32 masters;
    drop the cache (forcing a fresh cast from the masters on the next
    query) when they are non-finite or drifted past ``limit``.

    Cheap: the finiteness scan is over the cached (cap, D) bf16 copy, and
    the drift probe is ``obs.health.precision_drift`` at ``n_points``
    stored inputs.  Returns True when the wire tripped.
    """
    import jax.numpy as jnp

    if getattr(state, "precision", "f32") != "bf16" or state.n < 1:
        return False
    cache = getattr(state, "_stream_cache", None)
    tripped = False
    if cache is not None:
        f = cache[1]
        if not bool(jnp.all(jnp.isfinite(f.Xt.astype(jnp.float32)))):
            tripped = True
    if not tripped:
        from repro.obs.health import precision_drift

        drift = precision_drift(state, n_points=n_points)
        tripped = (not np.isfinite(drift)) or drift > limit
    if tripped:
        state._stream_cache = None
        _trace.REGISTRY.inc("resilience.bf16_recache")
        _trace.emit({"type": "resilience", "action": "bf16_recache",
                     "n": state.n})
        record_recovery("bf16_drift", n=state.n)
    return tripped

"""Deterministic, seed-replayable chaos injection for the serve path.

``ChaosInjector`` extends ``runtime.recovery.FailureInjector`` (fixed
``fail_at`` steps still work) with a seeded fault stream over the serve
fault classes:

  nan_payload        corrupt an observation payload with NaN (the
                     admission guardrail must reject it)
  kill_step          raise SimulatedFailure inside a serve step (the
                     bounded-retry machinery must absorb it)
  degenerate_factor  overwrite a live Cholesky with NaN (the jitter
                     ladder must refactor it back)
  cg_divergence      poison an iterative solve's warm start (the CG
                     watchdog must fall back to the exact solver)
  crash              kill the process state mid-trajectory (snapshot +
                     journal replay must restore it bit-identically)
  drop_device        declare a mesh device lost (the sharded state must
                     be rebuilt from its snapshot on a fresh mesh)
  straggler          mark a tenant slow (its requests must expire via
                     the deadline sweep, not stall the fleet)

Determinism contract: the fault stream is a pure function of ``seed``
and the sequence of ``draw()`` calls — replaying the same trajectory
with the same seed injects the same faults at the same points, which is
what makes chaos failures reproducible from a one-line seed, exactly
like the fuzz machine's op tapes.

Accounting contract: every injection bumps ``resilience.faults_injected``
(+ per-kind) here; every handler bumps ``resilience.faults_recovered``
(+ per-kind) via ``guardrails.record_recovery`` — the chaos CI gate
(``check_telemetry --expect-recovery``) asserts the totals match and
that recovery triggered zero recompiles.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.obs import trace as _trace
from repro.runtime.recovery import FailureInjector, SimulatedFailure

FAULT_KINDS = ("nan_payload", "kill_step", "degenerate_factor",
               "cg_divergence", "crash", "drop_device", "straggler")


@dataclasses.dataclass
class ChaosInjector(FailureInjector):
    """Seeded fault stream for chaos drills (see module docstring)."""

    seed: int = 0
    rates: dict = dataclasses.field(default_factory=dict)
    max_faults: Optional[int] = None

    def __post_init__(self):
        self._rng = np.random.RandomState(self.seed)
        self.injected: dict = {k: 0 for k in FAULT_KINDS}

    # -- bookkeeping -----------------------------------------------------

    @property
    def total_injected(self) -> int:
        return sum(self.injected.values())

    def record(self, kind: str, **attrs) -> None:
        """Count one injected fault (handlers pair this with
        ``guardrails.record_recovery``)."""
        self.injected[kind] = self.injected.get(kind, 0) + 1
        _trace.REGISTRY.inc("resilience.faults_injected")
        _trace.REGISTRY.inc(f"resilience.injected.{kind}")
        _trace.emit({"type": "chaos", "kind": kind, **attrs})

    def draw(self, kind: str) -> bool:
        """One deterministic Bernoulli draw for ``kind``; counts the
        fault when it fires.  Always advances the RNG (so enabling a
        fault class does not shift the others' streams)."""
        u = self._rng.rand()
        if self.max_faults is not None and \
                self.total_injected >= self.max_faults:
            return False
        if u < self.rates.get(kind, 0.0):
            self.record(kind)
            return True
        return False

    # -- fault actions ---------------------------------------------------

    def corrupt_payload(self, x):
        """Deterministically NaN one coordinate of a payload copy."""
        arr = np.array(x, dtype=np.float64, copy=True)
        idx = int(self._rng.randint(arr.size)) if arr.size else 0
        arr.reshape(-1)[idx] = np.nan
        return arr

    def maybe_kill(self) -> None:
        """Raise SimulatedFailure on a ``kill_step`` draw."""
        if self.draw("kill_step"):
            raise SimulatedFailure("chaos: killed serve step")

    def poison_factor(self, state) -> bool:
        """Overwrite the state's live Cholesky with NaN on a draw (the
        degenerate-factor fault class); returns True when it fired."""
        import jax.numpy as jnp

        if not self.draw("degenerate_factor"):
            return False
        bad = jnp.full_like(state.data.L, jnp.nan)
        state.data = state.data._replace(L=bad)
        return True

    def poison_warm_start(self, shape, dtype=None):
        """A NaN warm start for an iterative solve (cg_divergence)."""
        import jax.numpy as jnp

        self.record("cg_divergence")
        return jnp.full(shape, jnp.nan, dtype or jnp.float64)

"""Append-only op journal: bit-exact replay of mutations since a snapshot.

The recovery contract (DESIGN.md sec. 17.1): a serving process snapshots
its state every so often and journals every mutating op in between.  On
restore, the snapshot puts the state back bit-for-bit (f64/f32/int leaves
round-trip ``.npy`` exactly) and replaying the journaled ops through the
SAME jitted executables reproduces the uninterrupted bits — JSON floats
round-trip IEEE doubles exactly, and f32 payloads survive the f64 detour
unchanged.  ``tests/fuzz_machine.check_recovery_*`` asserts exactly this
against the dense differential oracle.

Entry format (one JSON object per line, fsync-free append — a torn tail
line is detected at read time and dropped, which is safe because the op
it described never committed a snapshot over it):

  {"op": "extend", "tenant": null, "seed": 123,          # optional seed
   "payload": {"x": [...], "g": [...]},                  # exact values
   "dtype": {"x": "float64", ...},
   "digest": {"x": "<sha256>", ...}}                     # replay check
  {"op": "snapshot", "step": 7}                          # snapshot marker

Fleet entries carry ``tenants`` + per-tenant payload dicts and replay as
one grouped launch — bitwise equivalent to any other grouping, because
the vmapped fleet ops compute every lane on every launch and masked
lanes keep their old bits exactly.
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Any, Optional

import numpy as np

from repro.obs import trace as _trace
from repro.resilience.errors import JournalCorruptionError


def _digest(arr: np.ndarray) -> str:
    a = np.ascontiguousarray(np.asarray(arr, dtype=np.float64))
    return hashlib.sha256(a.tobytes()).hexdigest()


def _encode(payload: dict) -> tuple[dict, dict, dict]:
    vals, dtypes, digests = {}, {}, {}
    for k, v in payload.items():
        if v is None:
            continue
        arr = np.asarray(v)
        dtypes[k] = str(arr.dtype)
        f64 = np.asarray(arr, dtype=np.float64)
        vals[k] = f64.tolist()
        digests[k] = _digest(f64)
    return vals, dtypes, digests


def decode_payload(entry: dict) -> dict:
    """Payload arrays of a journal entry, digest-verified, in their
    original dtypes (f64 -> f32/bf16 casts of values that were stored
    from those dtypes are exact)."""
    import jax.numpy as jnp

    out = {}
    for k, lst in (entry.get("payload") or {}).items():
        arr = np.asarray(lst, dtype=np.float64)
        want = entry.get("digest", {}).get(k)
        if want is not None and _digest(arr) != want:
            raise JournalCorruptionError(
                f"journal entry op={entry.get('op')!r} payload {k!r}: "
                f"digest mismatch")
        dt = entry.get("dtype", {}).get(k, "float64")
        if dt.startswith("bfloat"):
            out[k] = jnp.asarray(arr).astype(jnp.bfloat16)
        else:
            out[k] = arr.astype(np.dtype(dt))
    return out


class Journal:
    """Append-only JSONL op journal with snapshot markers."""

    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    def _append(self, entry: dict) -> None:
        with open(self.path, "a", encoding="utf-8") as f:
            f.write(json.dumps(entry) + "\n")
            f.flush()

    def record(self, op: str, *, tenant=None, tenants=None,
               seed: Optional[int] = None, args: Optional[dict] = None,
               payload: Optional[dict] = None) -> dict:
        """Journal one mutating op.  ``payload`` maps name -> array
        (stored exactly + digested); ``args`` holds plain-JSON scalars
        (k, steps, lr, lam...); ``seed`` tags seed-derived payloads so
        drills can regenerate instead of re-reading."""
        entry: dict[str, Any] = {"op": op}
        if tenant is not None:
            entry["tenant"] = tenant
        if tenants is not None:
            entry["tenants"] = list(tenants)
        if seed is not None:
            entry["seed"] = int(seed)
        if args:
            entry["args"] = args
        if payload:
            vals, dtypes, digests = _encode(payload)
            entry["payload"], entry["dtype"] = vals, dtypes
            entry["digest"] = digests
        self._append(entry)
        _trace.REGISTRY.inc("resilience.journal_appends")
        return entry

    def record_fleet(self, op: str, *, per_tenant: dict,
                     args: Optional[dict] = None) -> dict:
        """Journal one grouped fleet launch: {tenant: {name: array}}."""
        entry: dict[str, Any] = {"op": op, "tenants": list(per_tenant)}
        if args:
            entry["args"] = args
        pl, dt, dg = {}, {}, {}
        for t, p in per_tenant.items():
            vals, dtypes, digests = _encode(p or {})
            for k, v in vals.items():
                pl[f"{t}{chr(31)}{k}"] = v
                dt[f"{t}{chr(31)}{k}"] = dtypes[k]
                dg[f"{t}{chr(31)}{k}"] = digests[k]
        if pl:
            entry["payload"], entry["dtype"], entry["digest"] = pl, dt, dg
        self._append(entry)
        _trace.REGISTRY.inc("resilience.journal_appends")
        return entry

    def mark_snapshot(self, step: int) -> None:
        self._append({"op": "snapshot", "step": int(step)})

    # -- reading ---------------------------------------------------------

    @staticmethod
    def read(path: str) -> list[dict]:
        """All well-formed entries; a torn final line is dropped, a torn
        INTERIOR line is corruption (something after it committed)."""
        if not os.path.exists(path):
            return []
        entries, torn_at = [], None
        with open(path, encoding="utf-8") as f:
            for i, line in enumerate(f):
                if not line.strip():
                    continue
                try:
                    entries.append(json.loads(line))
                except ValueError:
                    torn_at = i
                    entries.append(None)
        if entries and entries[-1] is None:
            entries.pop()                       # torn tail: crash mid-append
        if any(e is None for e in entries):
            raise JournalCorruptionError(
                f"torn interior journal line at {path}:{torn_at}")
        return entries

    @staticmethod
    def since_snapshot(entries: list[dict],
                       step: Optional[int] = None) -> list[dict]:
        """Ops after the LAST snapshot marker (or the marker matching
        ``step``); the ops a restored process must replay."""
        idx = -1
        for i, e in enumerate(entries):
            if e.get("op") == "snapshot" and (step is None
                                              or e.get("step") == step):
                idx = i
        return [e for e in entries[idx + 1:] if e.get("op") != "snapshot"]


def _split_fleet_payload(entry: dict) -> dict:
    per = {t: {} for t in entry.get("tenants", [])}
    dec = decode_payload(entry)
    for key, arr in dec.items():
        t, k = key.split(chr(31), 1)
        per[t][k] = arr
    return per


def replay_single(state, entries: list[dict]):
    """Drive journaled ops through a restored ``GPGState`` — the same
    host methods, so the same jitted executables, so the same bits."""
    for e in entries:
        op = e["op"]
        p = decode_payload(e)
        a = e.get("args") or {}
        if op == "extend":
            state.extend(p["x"], p["g"], solve=a.get("solve", True))
        elif op == "evict":
            state.evict(int(a.get("k", 1)))
        elif op == "resolve":
            state.resolve(p["rhs"])
        elif op == "refactor":
            state.refactor(a.get("lam"))
        elif op == "refit":
            state.refit(steps=int(a.get("steps", 150)),
                        lr=float(a.get("lr", 0.08)))
        else:
            raise JournalCorruptionError(f"unknown single-state op {op!r}")
        _trace.REGISTRY.inc("resilience.journal_replayed")
    return state


def replay_fleet(fleet, entries: list[dict]):
    """Drive journaled grouped ops through a restored ``GPFleet``."""
    for e in entries:
        op = e["op"]
        a = e.get("args") or {}
        if op == "join":
            fleet.join(e["tenant"], **{k: float(v) for k, v in a.items()})
        elif op == "leave":
            fleet.leave(e["tenant"])
        elif op == "extend":
            per = _split_fleet_payload(e)
            fleet.extend({t: (p["x"], p["g"]) for t, p in per.items()})
        elif op == "evict":
            fleet.evict(list(e["tenants"]))
        elif op == "resolve":
            per = _split_fleet_payload(e)
            fleet.resolve({t: p["rhs"] for t, p in per.items()})
        elif op == "refit":
            fleet.refit(list(e["tenants"]),
                        steps=int(a.get("steps", 16)),
                        lr=float(a.get("lr", 0.1)))
        else:
            raise JournalCorruptionError(f"unknown fleet op {op!r}")
        _trace.REGISTRY.inc("resilience.journal_replayed")
    return fleet

"""Classic optimization drivers (paper Alg. 1) with line search — the
Fig. 2 / Fig. 3 reproduction machinery.

gp_optimize: GP-[H/X] optimization with bounded history m and a shared
line-search routine ("All algorithms shared the same line search routine",
Sec. 5.2). bfgs_optimize: our scipy-free BFGS baseline using the SAME line
search, for apples-to-apples comparison (scipy is not available offline).

These are host-side Python loops over jitted direction computations —
the paper's algorithms are inherently sequential; each iteration's heavy
work (Gram solve) is jitted and distributable.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import GPGState

from .gp_directions import gph_direction_state, gpx_direction_state

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# Strong-Wolfe line search (Nocedal & Wright Alg. 3.5/3.6, simplified)
# ---------------------------------------------------------------------------


def strong_wolfe(
    f: Callable[[Array], float],
    fg: Callable[[Array], tuple[float, Array]],
    x: Array, d: Array, f0: float, g0: Array,
    *, c1: float = 1e-4, c2: float = 0.9, alpha0: float = 1.0,
    max_iter: int = 20,
) -> tuple[float, int]:
    """Returns (alpha, n_evals). Falls back to backtracking on failure."""
    dg0 = float(jnp.vdot(g0, d))
    if dg0 >= 0:
        return 0.0, 0
    evals = 0

    def phi(a):
        nonlocal evals
        evals += 1
        fa, ga = fg(x + a * d)
        fa = float(fa)
        dga = float(jnp.vdot(ga, d))
        if not np.isfinite(fa):                 # overflow: treat as too far
            return np.inf, np.inf
        return fa, dga

    a_prev, f_prev = 0.0, float(f0)
    a = alpha0
    f_hi = None
    a_lo = a_hi = None
    f_lo = dg_lo = None
    for _ in range(max_iter):
        fa, dga = phi(a)
        if fa > f0 + c1 * a * dg0 or (f_hi is not None and fa >= f_prev):
            a_lo, f_lo, dg_lo, a_hi = a_prev, f_prev, dg0, a
            break
        if abs(dga) <= -c2 * dg0:
            return a, evals
        if dga >= 0:
            a_lo, f_lo, dg_lo, a_hi = a, fa, dga, a_prev
            break
        a_prev, f_prev = a, fa
        a *= 2.0
    else:
        return a, evals

    # zoom
    for _ in range(max_iter):
        am = 0.5 * (a_lo + a_hi)
        fm, dgm = phi(am)
        if fm > f0 + c1 * am * dg0 or fm >= f_lo:
            a_hi = am
        else:
            if abs(dgm) <= -c2 * dg0:
                return am, evals
            if dgm * (a_hi - a_lo) >= 0:
                a_hi = a_lo
            a_lo, f_lo = am, fm
    return a_lo if a_lo else 1e-8, evals


# ---------------------------------------------------------------------------
# Alg. 1 driver
# ---------------------------------------------------------------------------


class OptTrace(NamedTuple):
    x: Array
    fvals: np.ndarray
    gnorms: np.ndarray
    n_grad_evals: int


def gp_optimize(
    fg: Callable[[Array], tuple[float, Array]],
    x0: Array,
    *,
    mode: str = "gph",
    kernel: str = "rbf",
    lam=1.0,
    history: int = 0,            # 0 = keep everything (linalg mode)
    max_iters: int = 100,
    tol_grad: float = 1e-6,
    noise: float = 0.0,
    jitter: float = 1e-10,
    line_search: bool = True,
    step_fn: Callable | None = None,   # optional exact step (quadratics)
) -> OptTrace:
    """Paper Alg. 1: GP-[H/X] optimization with bounded history.

    The observation history lives in ONE incrementally maintained
    ``GPGState`` (the sliding window IS the bounded history m): each
    iteration appends the new (x, grad) pair with a bordered factor
    update + warm-started re-solve instead of refactoring from scratch.
    GP-X drives the FLIPPED state (inputs = gradients, observations = X),
    re-solving only the moving right-hand side X - x_t per step.
    """
    f = lambda x: fg(x)[0]
    x = jnp.asarray(x0)
    f0, g = fg(x)
    evals = 1
    st = GPGState(kernel, x.shape[0], window=history or None,
                  capacity=history or 8, lam=lam, noise=noise, jitter=jitter)

    def push(x_, g_):
        if mode == "gph":
            st.extend(x_, g_)
        else:
            # GP-X conditions on gradients as inputs (flipped inference);
            # the RHS moves with x_t, so the solve happens in resolve()
            st.extend(g_, x_, solve=False)

    push(x, g)
    fvals, gnorms = [float(f0)], [float(jnp.linalg.norm(g))]
    g0norm = gnorms[0]
    d = -g
    for it in range(max_iters):
        if gnorms[-1] <= tol_grad * max(g0norm, 1e-30):
            break
        # line search along d
        if step_fn is not None:
            alpha = float(step_fn(x, d, g))
            evals_ls = 0
        elif line_search:
            alpha, evals_ls = strong_wolfe(f, fg, x, d, fvals[-1], g)
            if alpha == 0.0:
                d = -g                       # restart on ascent direction
                alpha, evals_ls = strong_wolfe(f, fg, x, d, fvals[-1], g)
        else:
            alpha, evals_ls = 1.0, 0
        evals += evals_ls
        x = x + alpha * d
        f1, g = fg(x)
        evals += 1
        fvals.append(float(f1))
        gnorms.append(float(jnp.linalg.norm(g)))
        push(x, g)
        if mode == "gph":
            d = gph_direction_state(st, x, g, jitter=jitter)
        else:
            d = gpx_direction_state(st, x)
        if float(jnp.vdot(d, g)) > 0:
            d = -d                           # ensure descent (Alg. 1)
        if not bool(jnp.all(jnp.isfinite(d))):
            d = -g
        # norm guard: a wild Hessian posterior must not overflow the search
        dn = float(jnp.linalg.norm(d))
        cap = 1e3 * (float(jnp.linalg.norm(x)) + 1.0)
        if dn > cap:
            d = d * (cap / dn)
    return OptTrace(x=x, fvals=np.array(fvals), gnorms=np.array(gnorms),
                    n_grad_evals=evals)


def bfgs_optimize(
    fg: Callable[[Array], tuple[float, Array]],
    x0: Array,
    *,
    max_iters: int = 100,
    tol_grad: float = 1e-6,
) -> OptTrace:
    """Dense BFGS with the same strong-Wolfe search (scipy-free baseline)."""
    f = lambda x: fg(x)[0]
    x = jnp.asarray(x0, jnp.float64)
    d_dim = x.shape[0]
    H = jnp.eye(d_dim, dtype=x.dtype)
    f0, g = fg(x)
    evals = 1
    fvals, gnorms = [float(f0)], [float(jnp.linalg.norm(g))]
    g0norm = gnorms[0]
    for it in range(max_iters):
        if gnorms[-1] <= tol_grad * max(g0norm, 1e-30):
            break
        d = -(H @ g)
        if float(jnp.vdot(d, g)) > 0:
            d = -g
        alpha, evals_ls = strong_wolfe(f, fg, x, d, fvals[-1], g)
        if alpha == 0.0:
            break
        evals += evals_ls
        s = alpha * d
        x_new = x + s
        f1, g_new = fg(x_new)
        evals += 1
        y = g_new - g
        sy = float(jnp.vdot(s, y))
        if sy > 1e-12:
            rho = 1.0 / sy
            I = jnp.eye(d_dim, dtype=x.dtype)
            V = I - rho * jnp.outer(s, y)
            H = V @ H @ V.T + rho * jnp.outer(s, s)
        x, g = x_new, g_new
        fvals.append(float(f1))
        gnorms.append(float(jnp.linalg.norm(g)))
    return OptTrace(x=x, fvals=np.array(fvals), gnorms=np.array(gnorms),
                    n_grad_evals=evals)

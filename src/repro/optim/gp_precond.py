"""GP-preconditioned training optimizer (the paper's method as a first-class
distributed optimizer).

Maintains a bounded history of m flattened (params, grads) pairs — two
(m, D) matrices sharded over the WHOLE mesh like every D-vector — and
produces a quasi-Newton step from the nonparametric Hessian posterior
(GP-H) or the flipped optimum inference (GP-X). Until the history buffer
fills, it falls back to plain momentum.

Why this is cheap at scale (DESIGN.md sec. 2): all O(D) work in the GP
solve is the skinny contraction X̃ᵀΛV; under jit+GSPMD with D sharded, the
per-step collective cost on top of the gradient all-reduce is a handful of
m×m psums — O(m²) bytes, independent of D and of chip count.

State layout: ring buffers xs, gs of shape (m, D_pad) f32, a scalar count,
and the fallback momentum buffer.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.utils.flat import FlatSpec, flatten_pytree, make_flat_spec, unflatten_pytree

from .gp_directions import auto_lengthscale, gph_direction, gpx_direction
from .optimizers import Optimizer

Array = jnp.ndarray


def gp_precond(
    lr: float = 1.0,
    *,
    history: int = 6,
    mode: str = "gph",            # 'gph' | 'gpx'
    kernel: str = "rbf",
    lengthscale_factor: float = 10.0,
    noise: float = 1e-6,
    fallback_lr: float = 3e-4,
    fallback_beta: float = 0.9,
    max_step_rms: float = 1e-2,
    pad_to: int = 1,
) -> Optimizer:
    """GP-H/GP-X as a drop-in pytree optimizer (trust-region-clipped)."""

    def init(params):
        spec = make_flat_spec(params, pad_to=pad_to)
        d = spec.padded
        return {
            "step": jnp.zeros((), jnp.int32),
            "count": jnp.zeros((), jnp.int32),
            "xs": jnp.zeros((history, d), jnp.float32),
            "gs": jnp.zeros((history, d), jnp.float32),
            "m": jnp.zeros((d,), jnp.float32),
        }

    def update(grads, state, params):
        spec = make_flat_spec(params, pad_to=pad_to)
        x_t = flatten_pytree(params, spec)
        g_t = flatten_pytree(grads, spec)

        # ring-buffer append (shift up, write last)
        xs = jnp.concatenate([state["xs"][1:], x_t[None]], axis=0)
        gs = jnp.concatenate([state["gs"][1:], g_t[None]], axis=0)
        count = jnp.minimum(state["count"] + 1, history)
        m_buf = fallback_beta * state["m"] + g_t

        def gp_branch(_):
            lam = auto_lengthscale(xs, lengthscale_factor)
            if mode == "gph":
                d_ = gph_direction(xs, gs, x_t, g_t, kernel=kernel, lam=lam,
                                   noise=noise)
            else:
                d_ = gpx_direction(xs, gs, x_t, kernel=kernel, lam=lam,
                                   noise=noise)
                # descent safeguard (paper Alg. 1: flip if uphill)
                d_ = jnp.where(jnp.vdot(d_, g_t) > 0, -d_, d_)
            # trust region: clip update RMS; reject non-finite directions
            d_ = jnp.where(jnp.isfinite(d_), d_, 0.0)
            rms = jnp.sqrt(jnp.mean(d_ * d_) + 1e-30)
            d_ = d_ * jnp.minimum(1.0, max_step_rms / rms)
            return lr * d_

        def fallback_branch(_):
            return -fallback_lr * m_buf

        upd = jax.lax.cond(count >= history, gp_branch, fallback_branch,
                           operand=None)
        new_flat = x_t + upd
        new_params = jax.tree_util.tree_map(
            lambda n, o: n.astype(o.dtype), unflatten_pytree(new_flat, spec),
            params)
        return new_params, {
            "step": state["step"] + 1, "count": count,
            "xs": xs, "gs": gs, "m": m_buf,
        }

    return Optimizer(init, update, f"gp_{mode}")

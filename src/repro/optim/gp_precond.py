"""GP-preconditioned training optimizer (the paper's method as a first-class
distributed optimizer).

Maintains a bounded sliding window of m flattened (params, grads) pairs as
ONE incrementally updated posterior state (``repro.core.state.GPGData`` —
two (m, D) matrices sharded over the WHOLE mesh like every D-vector, plus
replicated (m, m) factor strips) and produces a quasi-Newton step from the
nonparametric Hessian posterior (GP-H) or the flipped optimum inference
(GP-X). Until the window fills, it falls back to plain momentum.

Update policy per training step (all inside the jitted, sharded step —
the state functions are pure and traceable):

  * window full  -> ``gpg_evict`` (rank-1 Cholesky update, O(m^2)), then
    ``gpg_extend`` (bordered factor update + warm-started CG re-solve);
  * every ``refresh_every`` steps (and on first fill) the lengthscale is
    re-estimated from the live window and the state does one full
    ``gpg_refactor`` — Lambda changes invalidate every Gram entry, so this
    is the one place a full O(m^2 D + m^3) rebuild is correct;
  * a degenerate bordered pivot triggers the same refactor fallback
    inside ``gpg_extend`` automatically.

Why this is cheap at scale (DESIGN.md sec. 2): all O(D) work in the GP
solve is the skinny contraction X̃ᵀΛV; under jit+GSPMD with D sharded, the
per-step collective cost on top of the gradient all-reduce is a handful of
m×m psums — O(m²) bytes, independent of D and of chip count.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import GramFactors, get_kernel, infer_optimum, posterior_hessian
from repro.core.dist_state import (SGPGData, _base_specs, sgpg_direct_solve,
                                   sgpg_evict, sgpg_extend, sgpg_init,
                                   sgpg_refactor)
from repro.core.distributed import _shard_map
from repro.core.state import gpg_evict, gpg_extend, gpg_init, gpg_refactor
from repro.hyper import (LENGTHSCALE_ONLY, HyperParams, fit_scan, fit_scan_fn,
                         make_mll_strips_fn)
from repro.obs import injit as _obs_tap
from repro.utils.flat import flatten_pytree, make_flat_spec, unflatten_pytree

from .gp_directions import auto_lengthscale
from .optimizers import Optimizer

Array = jnp.ndarray


def gp_precond(
    lr: float = 1.0,
    *,
    history: int = 6,
    mode: str = "gph",            # 'gph' | 'gpx'
    kernel: str = "rbf",
    lengthscale_factor: float = 10.0,
    noise: float = 1e-6,
    fallback_lr: float = 3e-4,
    fallback_beta: float = 0.9,
    max_step_rms: float = 1e-2,
    pad_to: int = 1,
    refresh_every: int = 8,
    refresh_mode: str = "heuristic",   # 'heuristic' | 'mll'
    mll_steps: int = 8,
    mll_lr: float = 0.15,
    cg_tol: float = 1e-6,
    cg_maxiter: int | None = None,
    jitter: float = 1e-6,
    mesh=None,
) -> Optimizer:
    """GP-H/GP-X as a drop-in pytree optimizer (trust-region-clipped).

    ``refresh_mode='mll'`` replaces the median-distance lengthscale
    heuristic of the periodic refresh with ``mll_steps`` traceable Adam
    steps on the exact structured log marginal likelihood
    (``repro.hyper.fit_scan``, lengthscale only — signal/noise stay at the
    configured values), still inside the jitted sharded training step.

    ``mesh`` switches the whole update to the D-sharded state machine
    (``repro.core.dist_state``): the flat parameter/gradient vectors and
    every (m, D) history matrix are sharded over all mesh axes, the state
    mutations run as ``sgpg_*`` phases inside ONE shard_map program, and
    the per-step collective traffic is at most THREE fused psums of O(m^2)
    bytes — extend border (+ the flipped-mode observation partials),
    direction reductions, and the trust-region scalars — independent of D
    and of device count.  The CG re-solve of the single-device path is
    replaced by the strips-based exact Woodbury solve (zero psums), so
    trajectories match the unsharded optimizer to solver tolerance.
    """
    if refresh_mode not in ("heuristic", "mll"):
        raise ValueError(f"refresh_mode must be 'heuristic' or 'mll', "
                         f"got {refresh_mode!r}")
    spec = get_kernel(kernel)
    flipped = mode != "gph"       # GP-X: inputs are gradients
    if mesh is not None:
        return _gp_precond_sharded(
            spec, mesh, flipped=flipped, lr=lr, history=history, mode=mode,
            lengthscale_factor=lengthscale_factor, noise=noise,
            fallback_lr=fallback_lr, fallback_beta=fallback_beta,
            max_step_rms=max_step_rms, pad_to=pad_to,
            refresh_every=refresh_every, refresh_mode=refresh_mode,
            mll_steps=mll_steps, mll_lr=mll_lr, jitter=jitter)
    solve_kw = dict(noise=noise, tol=cg_tol,
                    maxiter=cg_maxiter if cg_maxiter else 4 * history + 16)

    def init(params):
        fspec = make_flat_spec(params, pad_to=pad_to)
        d = fspec.padded
        return {
            "step": jnp.zeros((), jnp.int32),
            "count": jnp.zeros((), jnp.int32),
            "gpg": gpg_init(spec, d, history, lam=1.0, dtype=jnp.float32),
            "m": jnp.zeros((d,), jnp.float32),
        }

    def update(grads, state, params):
        fspec = make_flat_spec(params, pad_to=pad_to)
        x_t = flatten_pytree(params, fspec)
        g_t = flatten_pytree(grads, fspec)
        a_t, b_t = (g_t, x_t) if flipped else (x_t, g_t)

        data = state["gpg"]
        step = state["step"]
        prev = data.count
        count_after = jnp.minimum(prev + 1, history)
        gp_on = count_after >= history
        refresh_now = gp_on & ((prev < history)
                               | (step % refresh_every == 0))

        data = jax.lax.cond(
            prev >= history,
            lambda d: gpg_evict(spec, d, solve=False), lambda d: d, data)

        def _rhs(d):
            # GP-X observations are displacements X - x_t: they move with
            # x_t every step, so the RHS is rebuilt and re-solved against
            # the cached factors (never refactored for it).
            if not flipped:
                return None
            mask = (jnp.arange(history) < d.count)[:, None]
            return jnp.where(mask, d.G - x_t[None], 0.0)

        def br_fill(d):       # window not full yet: append, skip the solve
            return gpg_extend(spec, d, a_t, b_t, noise=noise, jitter=jitter,
                              solve=False)

        def br_refresh(d):    # lengthscale refresh: one full refactor
            d = gpg_extend(spec, d, a_t, b_t, noise=noise, jitter=jitter,
                           solve=False)
            lam_heur = auto_lengthscale(d.G if flipped else d.X,
                                        lengthscale_factor)
            if refresh_mode == "mll":
                # traceable MLL ascent on the window (lengthscale only) —
                # exact evidence gradient, heuristic kept as the seed AND
                # the non-finite fallback (bound guards live in fit_scan).
                # The evidence sees only the TRUE parameter columns: the
                # pad_to tail is identically-zero fake dimensions that
                # would bias the per-dimension logdet/quad terms (the
                # slice bound fspec.total is static, so this jits fine)
                obs = _rhs(d) if flipped else d.G
                init = HyperParams.from_lam(lam_heur, signal=1.0,
                                            noise=max(noise, 1e-12))
                fitted, _ = fit_scan(spec, d.X[:, :fspec.total],
                                     obs[:, :fspec.total], init,
                                     steps=mll_steps, lr=mll_lr,
                                     mask=LENGTHSCALE_ONLY)
                lam_new = jnp.where(jnp.isfinite(fitted.lam), fitted.lam,
                                    lam_heur)
            else:
                lam_new = lam_heur
            return gpg_refactor(spec, d, lam_new, jitter=jitter,
                                rhs=_rhs(d), **solve_kw)

        def br_incr(d):       # steady state: bordered update + warm CG
            return gpg_extend(spec, d, a_t, b_t, jitter=jitter,
                              rhs=_rhs(d), **solve_kw)

        idx = jnp.where(~gp_on, 0, jnp.where(refresh_now, 1, 2))
        data = jax.lax.switch(idx, [br_fill, br_refresh, br_incr], data)
        # in-jit taps: trace-time no-ops when observability is off, so the
        # training-step jaxpr is unchanged (tests/test_obs.py)
        _obs_tap.tap("gp_precond.steps", 1, kind="counter")
        _obs_tap.tap("gp_precond.refresh", refresh_now, kind="counter")
        _obs_tap.tap("gp_precond.cg_iters", data.cg_iters, kind="hist")
        _obs_tap.tap("gp_precond.resnorm", data.resnorm)
        m_buf = fallback_beta * state["m"] + g_t

        def gp_branch(_):
            # window is full here, so every padded row is valid
            f = GramFactors(K1e=data.K1e, K2e=data.K2e, Xt=data.Xt,
                            lam=data.lam, noise=float(noise), c=None)
            if mode == "gph":
                H = posterior_hessian(spec, x_t, f, data.Z)
                d_ = -H.solve(g_t, jitter=1e-8)
            else:
                d_ = infer_optimum(spec, f, data.Z, x_t) - x_t
                # descent safeguard (paper Alg. 1: flip if uphill)
                d_ = jnp.where(jnp.vdot(d_, g_t) > 0, -d_, d_)
            # trust region: clip update RMS; reject non-finite directions
            d_ = jnp.where(jnp.isfinite(d_), d_, 0.0)
            rms = jnp.sqrt(jnp.mean(d_ * d_) + 1e-30)
            d_ = d_ * jnp.minimum(1.0, max_step_rms / rms)
            return lr * d_

        def fallback_branch(_):
            return -fallback_lr * m_buf

        upd = jax.lax.cond(gp_on, gp_branch, fallback_branch, operand=None)
        new_flat = x_t + upd
        new_params = jax.tree_util.tree_map(
            lambda n, o: n.astype(o.dtype), unflatten_pytree(new_flat, fspec),
            params)
        return new_params, {
            "step": step + 1, "count": count_after,
            "gpg": data, "m": m_buf,
        }

    return Optimizer(init, update, f"gp_{mode}")


def _auto_lengthscale_strip(M: Array, n: int, factor: float) -> Array:
    """``auto_lengthscale(X, factor)`` re-derived from the replicated strip
    M = X X^T — same statistic, zero collectives (the strip already paid
    the D-reduction)."""
    sq = jnp.diagonal(M)
    r = sq[:, None] + sq[None, :] - 2.0 * M
    mean_r = jnp.sum(jnp.maximum(r, 0.0)) / jnp.maximum(n * (n - 1), 1)
    return 1.0 / jnp.maximum(factor * mean_r, 1e-20)


def _gp_precond_sharded(
    spec, mesh, *, flipped, lr, history, mode, lengthscale_factor, noise,
    fallback_lr, fallback_beta, max_step_rms, pad_to, refresh_every,
    refresh_mode, mll_steps, mll_lr, jitter,
) -> Optimizer:
    """The D-sharded update: one shard_map program, <= 3 fused psums/step.

    Collective schedule (DESIGN.md sec. 14):

      1. extend border  — the O(m)-byte strip border partials, with the
         flipped-mode observation reductions (v = X~ x_t, w = G x_t,
         |x_t|^2) fused in as ``extra_partials``; everything downstream of
         this psum (evict surgery, bordered Cholesky, refactor, the exact
         Woodbury solve, the whole MLL refresh) is replicated algebra.
      2. direction      — GP-H: the fused (r, m, P^T P, P^T g) tuple of the
         factored Hessian solve (the diag term is constant over D for
         scalar Lambda, so the inner (2m, 2m) system is replicated and the
         output assembly local).  GP-X stationary: the single m-vector
         x~_b^T Lambda Z_b (the query point g = 0 kills every other
         reduction); GP-X dot: none.
      3. scalars        — the trust-region RMS (and, for GP-X, the uphill
         flip inner product) as one fused scalar psum; the flip is applied
         AFTER the psum since the RMS is flip-invariant.
    """
    names = tuple(mesh.axis_names)
    ndev = int(mesh.size)
    pad_eff = math.lcm(max(int(pad_to), 1), ndev)
    h_jitter = 1e-8               # matches the unsharded H.solve call

    def init(params):
        fspec = make_flat_spec(params, pad_to=pad_eff)
        return {
            "step": jnp.zeros((), jnp.int32),
            "count": jnp.zeros((), jnp.int32),
            "gpg": sgpg_init(spec, fspec.padded, history, lam=1.0,
                             dtype=jnp.float32),
            "m": jnp.zeros((fspec.padded,), jnp.float32),
        }

    def update(grads, state, params):
        fspec = make_flat_spec(params, pad_to=pad_eff)
        x_t = flatten_pytree(params, fspec)
        g_t = flatten_pytree(grads, fspec)
        step = state["step"]
        d_pad = fspec.padded

        def body(data, x_t, g_t, m, step):
            a_t, b_t = (g_t, x_t) if flipped else (x_t, g_t)
            prev = data.base.count
            count_after = jnp.minimum(prev + 1, history)
            gp_on = count_after >= history
            refresh_now = gp_on & ((prev < history)
                                   | (step % refresh_every == 0))

            data = jax.lax.cond(
                prev >= history,
                lambda d: sgpg_evict(spec, d, solve=False), lambda d: d, data)

            if flipped:
                # Local partials of the flipped-mode observation strips
                # (rhs = G - x_t moves with x_t, so rhs X~^T = C - 1 v^T
                # and the MLL's GG_obs shift off three cheap reductions) —
                # fused into the extend psum below, not a 4th collective.
                n_row = data.base.count
                Xt_p = data.base.Xt.at[n_row].set(a_t)
                G_p = data.base.G.at[n_row].set(b_t)
                extra = (Xt_p @ x_t, G_p @ x_t, jnp.vdot(x_t, x_t))
            else:
                extra = None

            data, extras = sgpg_extend(
                spec, data, a_t, b_t, axis_names=names, noise=noise,
                jitter=jitter, solve=False, extra_partials=extra)

            def _rhs_pair(d):
                if not flipped:
                    return None, None
                mask = (jnp.arange(history) < d.base.count)[:, None]
                rhs = jnp.where(mask, d.base.G - x_t[None, :], 0.0)
                v = extras[0]
                C_rhs = jnp.where(mask & mask.T, d.C - v[None, :], 0.0)
                return rhs, C_rhs

            def br_fill(d):       # window not full yet: append only
                return d

            def br_refresh(d):    # lengthscale refresh off the strips
                rhs, C_rhs = _rhs_pair(d)
                lam_heur = _auto_lengthscale_strip(
                    d.GG if flipped else d.S0, history, lengthscale_factor)
                if refresh_mode == "mll":
                    if flipped:
                        v, w, s2 = extras
                        C_obs = C_rhs
                        GG_obs = d.GG - w[None, :] - w[:, None] + s2
                    else:
                        C_obs, GG_obs = d.C, d.GG
                    # the evidence sees only the TRUE parameter columns via
                    # d=fspec.total — the pad tail is zero in every strip
                    fn = make_mll_strips_fn(spec, d.S0, C_obs, GG_obs,
                                            fspec.total)
                    seed = HyperParams.from_lam(lam_heur, signal=1.0,
                                                noise=max(noise, 1e-12))
                    fitted, _ = fit_scan_fn(fn, seed, steps=mll_steps,
                                            lr=mll_lr, mask=LENGTHSCALE_ONLY)
                    lam_new = jnp.where(jnp.isfinite(fitted.lam), fitted.lam,
                                        lam_heur)
                else:
                    lam_new = lam_heur
                d = sgpg_refactor(spec, d, lam_new, noise=noise,
                                  jitter=jitter, solve=False)
                return sgpg_direct_solve(spec, d, noise=noise, jitter=jitter,
                                         rhs=rhs, C_rhs=C_rhs)

            def br_incr(d):       # steady state: exact strips solve
                rhs, C_rhs = _rhs_pair(d)
                return sgpg_direct_solve(spec, d, noise=noise, jitter=jitter,
                                         rhs=rhs, C_rhs=C_rhs)

            idx = jnp.where(~gp_on, 0, jnp.where(refresh_now, 1, 2))
            data = jax.lax.switch(idx, [br_fill, br_refresh, br_incr], data)
            m_new = fallback_beta * m + g_t

            def gp_branch(_):
                b = data.base
                lam = jnp.asarray(b.lam)
                if mode == "gph":
                    # posterior_hessian + H.solve with the D-reductions
                    # hoisted into one fused psum; W and the (2m, 2m) inner
                    # solve are replicated, P stays a local (D_loc, 2m).
                    if spec.is_stationary:
                        Xtq = x_t[None, :] - b.Xt
                        r_p = jnp.sum((Xtq * lam) * Xtq, axis=-1)
                        m_p = jnp.sum((Xtq * lam) * b.Z, axis=-1)
                    else:
                        Xtq = b.Xt
                        r_p = jnp.sum((Xtq * lam) * x_t[None, :], axis=-1)
                        m_p = jnp.sum(x_t[None, :] * lam * b.Z, axis=-1)
                    Pl = jnp.concatenate([(Xtq * lam).T, (b.Z * lam).T],
                                         axis=1)
                    r, mv, PtP, Ptg = jax.lax.psum(
                        (r_p, m_p, Pl.T @ Pl, Pl.T @ g_t), names)
                    if spec.is_stationary:
                        r = jnp.maximum(r, 0.0)
                        k2, k3 = spec.k2(r), spec.k3(r)
                        M = jnp.diag(-8.0 * k3 * mv)
                        Mh = jnp.diag(-4.0 * k2)
                        # constant over D for scalar Lambda -> replicated
                        d0 = lam * jnp.sum(-4.0 * k2 * mv)
                    else:
                        M = jnp.diag(spec.k3(r) * mv)
                        Mh = jnp.diag(spec.k2(r))
                        d0 = jnp.zeros((), x_t.dtype)
                    W = jnp.block([[M, Mh],
                                   [Mh, jnp.zeros((history, history),
                                                  M.dtype)]])
                    d0 = jnp.where(jnp.abs(d0) < h_jitter, h_jitter, d0)
                    eye = jnp.eye(2 * history, dtype=x_t.dtype)
                    inner = jnp.linalg.inv(W + h_jitter * eye) + PtP / d0
                    y = jnp.linalg.solve(inner + h_jitter * eye, Ptg / d0)
                    d_ = -(g_t / d0 - (Pl / d0) @ y)
                else:
                    # GP-X: cross_grad_matvec at the query g = 0 — the
                    # cross strips collapse to r = lam diag(S0) (free) and
                    # one m-vector psum (stationary) / nothing (dot).
                    if spec.is_stationary:
                        r_q = lam * jnp.maximum(jnp.diagonal(data.S0), 0.0)
                        mz = jax.lax.psum(
                            lam * jnp.sum(b.Xt * b.Z, axis=-1), names)
                        Mt = spec.k2e(r_q) * (-mz)
                        d_ = (spec.k1e(r_q) @ b.Z - Mt @ b.Xt) * lam
                    else:
                        r_q = jnp.zeros((history,), x_t.dtype)
                        d_ = (spec.k1e(r_q) @ b.Z) * lam
                d_f = jnp.where(jnp.isfinite(d_), d_, 0.0)
                if mode == "gph":
                    ss = jax.lax.psum(jnp.sum(d_f * d_f), names)
                else:
                    # fused: uphill-flip inner product + trust-region RMS
                    # (flip applied after the psum — RMS is flip-invariant)
                    dg, ss = jax.lax.psum(
                        (jnp.vdot(d_, g_t), jnp.sum(d_f * d_f)), names)
                    d_f = jnp.where(dg > 0, -d_f, d_f)
                rms = jnp.sqrt(ss / d_pad + 1e-30)
                return lr * d_f * jnp.minimum(1.0, max_step_rms / rms)

            upd = jax.lax.cond(gp_on, gp_branch,
                               lambda _: -fallback_lr * m_new, operand=None)
            return data, upd, m_new

        dspec = SGPGData(base=_base_specs(names, False), S0=P(), C=P(),
                         GG=P())
        vec = P(names)
        sm = _shard_map(body, mesh=mesh,
                        in_specs=(dspec, vec, vec, vec, P()),
                        out_specs=(dspec, vec, vec), check_rep=False)
        data, upd, m_buf = sm(state["gpg"], x_t, g_t, state["m"], step)

        prev = state["gpg"].base.count
        count_after = jnp.minimum(prev + 1, history)
        refresh_now = (count_after >= history) & (
            (prev < history) | (step % refresh_every == 0))
        _obs_tap.tap("gp_precond.steps", 1, kind="counter")
        _obs_tap.tap("gp_precond.refresh", refresh_now, kind="counter")
        _obs_tap.tap("gp_precond.cg_iters", data.base.cg_iters, kind="hist")
        _obs_tap.tap("gp_precond.resnorm", data.base.resnorm)

        new_flat = x_t + upd
        new_params = jax.tree_util.tree_map(
            lambda n, o: n.astype(o.dtype), unflatten_pytree(new_flat, fspec),
            params)
        return new_params, {
            "step": step + 1, "count": count_after,
            "gpg": data, "m": m_buf,
        }

    return Optimizer(init, update, f"gp_{mode}")

"""GP-preconditioned training optimizer (the paper's method as a first-class
distributed optimizer).

Maintains a bounded sliding window of m flattened (params, grads) pairs as
ONE incrementally updated posterior state (``repro.core.state.GPGData`` —
two (m, D) matrices sharded over the WHOLE mesh like every D-vector, plus
replicated (m, m) factor strips) and produces a quasi-Newton step from the
nonparametric Hessian posterior (GP-H) or the flipped optimum inference
(GP-X). Until the window fills, it falls back to plain momentum.

Update policy per training step (all inside the jitted, sharded step —
the state functions are pure and traceable):

  * window full  -> ``gpg_evict`` (rank-1 Cholesky update, O(m^2)), then
    ``gpg_extend`` (bordered factor update + warm-started CG re-solve);
  * every ``refresh_every`` steps (and on first fill) the lengthscale is
    re-estimated from the live window and the state does one full
    ``gpg_refactor`` — Lambda changes invalidate every Gram entry, so this
    is the one place a full O(m^2 D + m^3) rebuild is correct;
  * a degenerate bordered pivot triggers the same refactor fallback
    inside ``gpg_extend`` automatically.

Why this is cheap at scale (DESIGN.md sec. 2): all O(D) work in the GP
solve is the skinny contraction X̃ᵀΛV; under jit+GSPMD with D sharded, the
per-step collective cost on top of the gradient all-reduce is a handful of
m×m psums — O(m²) bytes, independent of D and of chip count.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core import GramFactors, get_kernel, infer_optimum, posterior_hessian
from repro.core.state import gpg_evict, gpg_extend, gpg_init, gpg_refactor
from repro.hyper import LENGTHSCALE_ONLY, HyperParams, fit_scan
from repro.obs import injit as _obs_tap
from repro.utils.flat import flatten_pytree, make_flat_spec, unflatten_pytree

from .gp_directions import auto_lengthscale
from .optimizers import Optimizer

Array = jnp.ndarray


def gp_precond(
    lr: float = 1.0,
    *,
    history: int = 6,
    mode: str = "gph",            # 'gph' | 'gpx'
    kernel: str = "rbf",
    lengthscale_factor: float = 10.0,
    noise: float = 1e-6,
    fallback_lr: float = 3e-4,
    fallback_beta: float = 0.9,
    max_step_rms: float = 1e-2,
    pad_to: int = 1,
    refresh_every: int = 8,
    refresh_mode: str = "heuristic",   # 'heuristic' | 'mll'
    mll_steps: int = 8,
    mll_lr: float = 0.15,
    cg_tol: float = 1e-6,
    cg_maxiter: int | None = None,
    jitter: float = 1e-6,
) -> Optimizer:
    """GP-H/GP-X as a drop-in pytree optimizer (trust-region-clipped).

    ``refresh_mode='mll'`` replaces the median-distance lengthscale
    heuristic of the periodic refresh with ``mll_steps`` traceable Adam
    steps on the exact structured log marginal likelihood
    (``repro.hyper.fit_scan``, lengthscale only — signal/noise stay at the
    configured values), still inside the jitted sharded training step.
    """
    if refresh_mode not in ("heuristic", "mll"):
        raise ValueError(f"refresh_mode must be 'heuristic' or 'mll', "
                         f"got {refresh_mode!r}")
    spec = get_kernel(kernel)
    flipped = mode != "gph"       # GP-X: inputs are gradients
    solve_kw = dict(noise=noise, tol=cg_tol,
                    maxiter=cg_maxiter if cg_maxiter else 4 * history + 16)

    def init(params):
        fspec = make_flat_spec(params, pad_to=pad_to)
        d = fspec.padded
        return {
            "step": jnp.zeros((), jnp.int32),
            "count": jnp.zeros((), jnp.int32),
            "gpg": gpg_init(spec, d, history, lam=1.0, dtype=jnp.float32),
            "m": jnp.zeros((d,), jnp.float32),
        }

    def update(grads, state, params):
        fspec = make_flat_spec(params, pad_to=pad_to)
        x_t = flatten_pytree(params, fspec)
        g_t = flatten_pytree(grads, fspec)
        a_t, b_t = (g_t, x_t) if flipped else (x_t, g_t)

        data = state["gpg"]
        step = state["step"]
        prev = data.count
        count_after = jnp.minimum(prev + 1, history)
        gp_on = count_after >= history
        refresh_now = gp_on & ((prev < history)
                               | (step % refresh_every == 0))

        data = jax.lax.cond(
            prev >= history,
            lambda d: gpg_evict(spec, d, solve=False), lambda d: d, data)

        def _rhs(d):
            # GP-X observations are displacements X - x_t: they move with
            # x_t every step, so the RHS is rebuilt and re-solved against
            # the cached factors (never refactored for it).
            if not flipped:
                return None
            mask = (jnp.arange(history) < d.count)[:, None]
            return jnp.where(mask, d.G - x_t[None], 0.0)

        def br_fill(d):       # window not full yet: append, skip the solve
            return gpg_extend(spec, d, a_t, b_t, noise=noise, jitter=jitter,
                              solve=False)

        def br_refresh(d):    # lengthscale refresh: one full refactor
            d = gpg_extend(spec, d, a_t, b_t, noise=noise, jitter=jitter,
                           solve=False)
            lam_heur = auto_lengthscale(d.G if flipped else d.X,
                                        lengthscale_factor)
            if refresh_mode == "mll":
                # traceable MLL ascent on the window (lengthscale only) —
                # exact evidence gradient, heuristic kept as the seed AND
                # the non-finite fallback (bound guards live in fit_scan).
                # The evidence sees only the TRUE parameter columns: the
                # pad_to tail is identically-zero fake dimensions that
                # would bias the per-dimension logdet/quad terms (the
                # slice bound fspec.total is static, so this jits fine)
                obs = _rhs(d) if flipped else d.G
                init = HyperParams.from_lam(lam_heur, signal=1.0,
                                            noise=max(noise, 1e-12))
                fitted, _ = fit_scan(spec, d.X[:, :fspec.total],
                                     obs[:, :fspec.total], init,
                                     steps=mll_steps, lr=mll_lr,
                                     mask=LENGTHSCALE_ONLY)
                lam_new = jnp.where(jnp.isfinite(fitted.lam), fitted.lam,
                                    lam_heur)
            else:
                lam_new = lam_heur
            return gpg_refactor(spec, d, lam_new, jitter=jitter,
                                rhs=_rhs(d), **solve_kw)

        def br_incr(d):       # steady state: bordered update + warm CG
            return gpg_extend(spec, d, a_t, b_t, jitter=jitter,
                              rhs=_rhs(d), **solve_kw)

        idx = jnp.where(~gp_on, 0, jnp.where(refresh_now, 1, 2))
        data = jax.lax.switch(idx, [br_fill, br_refresh, br_incr], data)
        # in-jit taps: trace-time no-ops when observability is off, so the
        # training-step jaxpr is unchanged (tests/test_obs.py)
        _obs_tap.tap("gp_precond.steps", 1, kind="counter")
        _obs_tap.tap("gp_precond.refresh", refresh_now, kind="counter")
        _obs_tap.tap("gp_precond.cg_iters", data.cg_iters, kind="hist")
        _obs_tap.tap("gp_precond.resnorm", data.resnorm)
        m_buf = fallback_beta * state["m"] + g_t

        def gp_branch(_):
            # window is full here, so every padded row is valid
            f = GramFactors(K1e=data.K1e, K2e=data.K2e, Xt=data.Xt,
                            lam=data.lam, noise=float(noise), c=None)
            if mode == "gph":
                H = posterior_hessian(spec, x_t, f, data.Z)
                d_ = -H.solve(g_t, jitter=1e-8)
            else:
                d_ = infer_optimum(spec, f, data.Z, x_t) - x_t
                # descent safeguard (paper Alg. 1: flip if uphill)
                d_ = jnp.where(jnp.vdot(d_, g_t) > 0, -d_, d_)
            # trust region: clip update RMS; reject non-finite directions
            d_ = jnp.where(jnp.isfinite(d_), d_, 0.0)
            rms = jnp.sqrt(jnp.mean(d_ * d_) + 1e-30)
            d_ = d_ * jnp.minimum(1.0, max_step_rms / rms)
            return lr * d_

        def fallback_branch(_):
            return -fallback_lr * m_buf

        upd = jax.lax.cond(gp_on, gp_branch, fallback_branch, operand=None)
        new_flat = x_t + upd
        new_params = jax.tree_util.tree_map(
            lambda n, o: n.astype(o.dtype), unflatten_pytree(new_flat, fspec),
            params)
        return new_params, {
            "step": step + 1, "count": count_after,
            "gpg": data, "m": m_buf,
        }

    return Optimizer(init, update, f"gp_{mode}")

"""Error-feedback int8 gradient compression (distributed-optimization trick).

At 1000+ nodes the cross-pod gradient all-reduce dominates the collective
roofline term. Standard mitigation: quantize the all-reduced payload to
int8 with per-block scales and carry the quantization error forward
(error feedback keeps SGD-style convergence guarantees).

This module provides the compress/decompress pair and a psum wrapper; the
train step applies it ONLY across the 'pod' axis (slow links) — intra-pod
reduction stays full precision.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jnp.ndarray

_BLOCK = 256


def _pad(x: Array) -> Array:
    pad = (-x.shape[0]) % _BLOCK
    return jnp.pad(x, (0, pad)) if pad else x


def ef_int8_compress(flat: Array, error: Array) -> tuple[Array, Array, Array]:
    """(grad + carried error) -> (int8 codes, f32 block scales, new error)."""
    n = flat.shape[0]
    x = _pad(flat + error)
    xb = x.reshape(-1, _BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(xb), axis=1, keepdims=True) / 127.0,
                        1e-12)
    codes = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    deq = (codes.astype(jnp.float32) * scale).reshape(-1)[:n]
    new_error = (x.reshape(-1)[:n] - deq)
    return codes.reshape(-1), scale[:, 0], new_error


def ef_int8_decompress(codes: Array, scales: Array, n: int) -> Array:
    xb = codes.reshape(-1, _BLOCK).astype(jnp.float32) * scales[:, None]
    return xb.reshape(-1)[:n]


def compressed_psum(flat: Array, error: Array, axis_name: str):
    """psum over `axis_name` with int8 payload + error feedback.

    Inside shard_map: each member quantizes its contribution, the int8
    codes are summed in int32 (psum), and the shared scale statistics are
    reduced alongside. Returns (reduced f32 gradient, new local error).
    """
    codes, scales, new_error = ef_int8_compress(flat, error)
    # sum of per-member dequantized payloads == dequantize(sum codes) only
    # for a shared scale; use the max scale across members so codes remain
    # comparable, then rescale local codes before the integer psum.
    scale_max = jax.lax.pmax(scales, axis_name)
    ratio = scales / scale_max
    codes_rescaled = jnp.round(
        codes.reshape(-1, _BLOCK).astype(jnp.float32) * ratio[:, None]
    ).astype(jnp.int32)
    summed = jax.lax.psum(codes_rescaled, axis_name)
    out = (summed.astype(jnp.float32) * scale_max[:, None]).reshape(-1)
    return out[: flat.shape[0]], new_error

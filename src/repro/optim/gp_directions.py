"""GP-H / GP-X step directions (paper Alg. 1) as pure jittable functions.

Shared by the classic optimizer loop (optim/classic.py, reproduces Fig. 2/3)
and the training-time preconditioner (optim/gp_precond.py). Both take the
observation history X, G as (N, D) matrices — N is the bounded history m.

GP-H (Sec. 4.1.1): condition a gradient-GP on (X, G), read off the
posterior-mean Hessian at x_t (Eq. 12, diag + rank-2N), return
-H^{-1} g_t via the factored Woodbury solve (HessianOperator.solve).

GP-X (Sec. 4.1.2 / Eq. 13): FLIP inputs and outputs — condition a GP whose
inputs are the observed gradients and whose observations are displacements
X - x_t, then query the posterior mean at g = 0. The returned step is
x̄* - x_t.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import (build_factors, get_kernel, infer_optimum,
                        posterior_hessian, woodbury_solve)

Array = jnp.ndarray


def gph_direction(
    X: Array, G: Array, x_t: Array, g_t: Array, *,
    kernel: str = "rbf", lam=1.0, noise: float = 0.0, jitter: float = 1e-8,
) -> Array:
    """Quasi-Newton step -H̄(x_t)^{-1} g_t from gradient history (X, G)."""
    spec = get_kernel(kernel)
    f = build_factors(spec, X, lam=lam, noise=noise)
    Z = woodbury_solve(spec, f, G, jitter=jitter)
    H = posterior_hessian(spec, x_t, f, Z)
    return -H.solve(g_t, jitter=jitter)


def gpx_direction(
    X: Array, G: Array, x_t: Array, *,
    kernel: str = "rbf", lam=1.0, noise: float = 0.0, jitter: float = 1e-8,
) -> Array:
    """Step towards the inferred optimum x̄*(g=0) (flipped inference)."""
    spec = get_kernel(kernel)
    f_g = build_factors(spec, G, lam=lam, noise=noise)
    Z = woodbury_solve(spec, f_g, X - x_t, jitter=jitter)
    x_star = infer_optimum(spec, f_g, Z, x_t)
    return x_star - x_t


def auto_lengthscale(X: Array, factor: float = 10.0) -> Array:
    """Isotropic Λ = 1 / (factor * mean pairwise squared distance).

    The paper fixes ℓ² = 10·D for the D-dim Rosenbrock (Λ = 1/(10D) · I,
    App. F.2); at training time the scale of parameter moves varies wildly,
    so we set ℓ² = factor * mean ||x_a - x_b||² from the live history —
    the same r statistics the Gram factors need anyway.
    """
    sq = jnp.sum(X * X, axis=1)
    r = sq[:, None] + sq[None, :] - 2.0 * (X @ X.T)
    n = X.shape[0]
    mean_r = jnp.sum(jnp.maximum(r, 0.0)) / jnp.maximum(n * (n - 1), 1)
    return 1.0 / jnp.maximum(factor * mean_r, 1e-20)

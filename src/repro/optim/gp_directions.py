"""GP-H / GP-X step directions (paper Alg. 1) as pure jittable functions.

Shared by the classic optimizer loop (optim/classic.py, reproduces Fig. 2/3)
and the training-time preconditioner (optim/gp_precond.py).

Two API levels:

* ``gph_direction`` / ``gpx_direction`` — stateless: take the history
  (X, G) as (N, D) matrices and refactor from scratch (exact Woodbury).
  Kept as the one-shot/reference path.
* ``gph_direction_state`` / ``gpx_direction_state`` — **incremental**:
  take a conditioned ``repro.core.GPGState`` whose factors and solve were
  maintained by ``extend()``/``evict()`` — no per-step refactorization.
  This is what the optimization loops drive (the state IS the bounded
  history m, as a sliding window).

GP-H (Sec. 4.1.1): condition a gradient-GP on (X, G), read off the
posterior-mean Hessian at x_t (Eq. 12, diag + rank-2N), return
-H^{-1} g_t via the factored Woodbury solve (HessianOperator.solve).

GP-X (Sec. 4.1.2 / Eq. 13): FLIP inputs and outputs — condition a GP whose
inputs are the observed gradients and whose observations are displacements
X - x_t, then query the posterior mean at g = 0. The returned step is
x̄* - x_t.  In state form the flipped state extends with (g, x) pairs and
only the right-hand side X - x_t is re-solved each step (factor reuse via
``GPGState.resolve``).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import (get_kernel, infer_optimum, posterior_hessian,
                        woodbury_solve)
from repro.core.gram import build_factor_bundle

Array = jnp.ndarray


def gph_direction(
    X: Array, G: Array, x_t: Array, g_t: Array, *,
    kernel: str = "rbf", lam=1.0, noise: float = 0.0, jitter: float = 1e-8,
) -> Array:
    """Quasi-Newton step -H̄(x_t)^{-1} g_t from gradient history (X, G).

    Factor build + Woodbury right-hand contractions come out of ONE fused
    sweep of (X, G) (``build_factor_bundle``, DESIGN.md sec. 12)."""
    spec = get_kernel(kernel)
    b = build_factor_bundle(spec, X, G, lam=lam, noise=noise)
    Z = woodbury_solve(spec, b.factors, G, jitter=jitter, bundle=b)
    H = posterior_hessian(spec, x_t, b.factors, Z)
    return -H.solve(g_t, jitter=jitter)


def gpx_direction(
    X: Array, G: Array, x_t: Array, *,
    kernel: str = "rbf", lam=1.0, noise: float = 0.0, jitter: float = 1e-8,
) -> Array:
    """Step towards the inferred optimum x̄*(g=0) (flipped inference)."""
    spec = get_kernel(kernel)
    b = build_factor_bundle(spec, G, X - x_t, lam=lam, noise=noise)
    Z = woodbury_solve(spec, b.factors, X - x_t, jitter=jitter, bundle=b)
    x_star = infer_optimum(spec, b.factors, Z, x_t)
    return x_star - x_t


def gph_direction_state(state, x_t: Array, g_t: Array, *,
                        jitter: float = 1e-8) -> Array:
    """GP-H step from an incrementally maintained ``GPGState`` on (X, G).

    Zero solves of the Gram system here — the state's cached Z is reused;
    only the O(ND + N^3) factored Hessian solve runs per step.
    """
    H = posterior_hessian(state.spec, x_t, state.factors, state.Z)
    return -H.solve(g_t, jitter=jitter)


def gpx_direction_state(state_g, x_t: Array) -> Array:
    """GP-X step from a FLIPPED ``GPGState`` (inputs = gradients).

    ``state_g`` must have been extended with (g, x) pairs: its factors live
    on gradient inputs (growing by borders), while the observations
    X - x_t move wholesale with x_t — so each step re-solves only the new
    right-hand side against the cached factors/preconditioner.
    """
    rhs = state_g.G - x_t
    Z = state_g.resolve(rhs)
    x_star = infer_optimum(state_g.spec, state_g.factors, Z, x_t)
    return x_star - x_t


def auto_lengthscale(X: Array, factor: float = 10.0) -> Array:
    """Isotropic Λ = 1 / (factor * mean pairwise squared distance).

    The paper fixes ℓ² = 10·D for the D-dim Rosenbrock (Λ = 1/(10D) · I,
    App. F.2); at training time the scale of parameter moves varies wildly,
    so we set ℓ² = factor * mean ||x_a - x_b||² from the live history —
    the same r statistics the Gram factors need anyway.
    """
    sq = jnp.sum(X * X, axis=1)
    r = sq[:, None] + sq[None, :] - 2.0 * (X @ X.T)
    n = X.shape[0]
    mean_r = jnp.sum(jnp.maximum(r, 0.0)) / jnp.maximum(n * (n - 1), 1)
    return 1.0 / jnp.maximum(factor * mean_r, 1e-20)

"""Pytree-native GP-H preconditioner: zero-marshalling distributed form.

The flat-vector gp_precond flattens params/grads into one (D,) vector each
step. Mathematically free — but on a mesh the flatten/unflatten is a
RESHARD of every parameter (measured: 2.8x the collective bytes of the
gradient all-reduce itself, EXPERIMENTS.md §Perf iteration 3). The paper's
own structure says none of that is necessary: every O(D) object appears
only inside inner products. So this module keeps the (m, D) histories as
PYTREES of stacked leaves ((m,) + leaf.shape, sharded exactly like the
leaf) and computes

    <A, B>_ab = sum_leaves tensordot(A_l[a], B_l[b])        (m x m, psum)
    (M @ H)_l = tensordot(M, H_l, axes=[[1],[0]])           (leaf-local)

— contractions over sharded axes lower to local partials + an m^2-float
all-reduce; linear combinations are embarrassingly local. The Woodbury /
Hessian algebra from core/ is re-expressed in those two primitives
(RBF/stationary kernels; scalar Lambda auto-scaled as in gp_precond).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core import get_kernel
from repro.core.mvm import l_op, lt_op

from .optimizers import Optimizer

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# Tree-of-stacked-leaves primitives
# ---------------------------------------------------------------------------


def tree_zeros_hist(params: Any, m: int) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros((m,) + p.shape, jnp.float32), params)


def tree_push(hist: Any, new: Any) -> Any:
    """Ring-buffer append along the leading axis."""
    return jax.tree_util.tree_map(
        lambda h, n: jnp.concatenate(
            [h[1:], n[None].astype(jnp.float32)], axis=0), hist, new)


def tree_inner(a: Any, b: Any) -> Array:
    """(m, n) Gram of two stacked-leaf trees: sum of per-leaf tensordots."""
    leaves_a = jax.tree_util.tree_leaves(a)
    leaves_b = jax.tree_util.tree_leaves(b)
    total = 0.0
    for la, lb in zip(leaves_a, leaves_b):
        axes = list(range(1, la.ndim))
        total = total + jnp.tensordot(la.astype(jnp.float32),
                                      lb.astype(jnp.float32),
                                      axes=(axes, axes))
    return total


def tree_lincomb(M: Array, hist: Any) -> Any:
    """(r, m) @ (m, D)-tree -> (r, D)-tree, leaf-local."""
    return jax.tree_util.tree_map(
        lambda h: jnp.tensordot(M, h.astype(jnp.float32), axes=[[1], [0]]),
        hist)


def tree_axpy(alpha: float, x: Any, y: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda a, b: alpha * a.astype(jnp.float32) + b.astype(jnp.float32),
        x, y)


def tree_row(hist: Any, i: int) -> Any:
    return jax.tree_util.tree_map(lambda h: h[i], hist)


# ---------------------------------------------------------------------------
# GP-H direction, leaf-wise (stationary kernels; scalar Lambda)
# ---------------------------------------------------------------------------


def gph_direction_tree(xs: Any, gs: Any, g_t: Any, *, kernel: str = "rbf",
                       lengthscale_factor: float = 10.0, noise: float = 1e-6,
                       jitter: float = 1e-8):
    """-H̄(x_t)^{-1} g_t with histories as stacked-leaf trees.

    Mirrors core.woodbury.woodbury_solve + core.inference.posterior_hessian
    for stationary kernels, with every O(D) contraction replaced by
    tree_inner / tree_lincomb. Returns the direction as a params-like tree.
    """
    spec = get_kernel(kernel)
    assert spec.is_stationary, "tree path implements stationary kernels"
    XX = tree_inner(xs, xs)                     # (m, m), unit-lam gram
    n = XX.shape[0]
    sq = jnp.diagonal(XX)
    r0 = jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * XX, 0.0)
    mean_r = jnp.sum(r0) / jnp.maximum(n * (n - 1), 1)
    lam = 1.0 / jnp.maximum(lengthscale_factor * mean_r, 1e-20)
    r = lam * r0

    K1e, K2e = spec.k1e(r), spec.k2e(r)
    dtype = K1e.dtype
    K1 = K1e + (noise / lam) * jnp.eye(n, dtype=dtype)
    K1i = jnp.linalg.inv(K1 + jitter * jnp.eye(n, dtype=dtype))
    S = lam * XX

    GX = tree_inner(gs, xs)                     # (m, m) = G Xᵀ (no Λ —
    T = lt_op(K1i @ GX)                         # matches core.woodbury)

    def inner(Q):
        return -Q.T / K2e + lt_op(K1i @ l_op(Q) @ S)

    eye = jnp.eye(n * n, dtype=dtype).reshape(n * n, n, n)
    A = jax.vmap(inner)(eye).reshape(n * n, n * n).T
    q = jnp.linalg.solve(A + jitter * jnp.eye(n * n, dtype=dtype),
                         T.reshape(-1))
    Q = q.reshape(n, n)

    # Z = K1i @ (G / lam - l_op(Q) @ X)   (m, D)-tree
    Zg = tree_lincomb(K1i / lam, gs)
    Zx = tree_lincomb(K1i @ l_op(Q), xs)
    Z = tree_axpy(-1.0, Zx, Zg)

    # ---- posterior Hessian at x_t = xs[-1] (Eq. 12, stationary) ----
    # Xt_h[b] = x_t - x_b  as an (m, D)-tree
    sel = (-jnp.ones((n, n), dtype)
           .at[jnp.arange(n), jnp.arange(n)].add(0.0))
    E_last = jnp.zeros((n, n), dtype).at[:, n - 1].set(1.0)
    Xt_h = tree_lincomb(E_last - jnp.eye(n, dtype=dtype), xs)
    r_q = lam * jnp.maximum(sq[n - 1] + sq - 2.0 * XX[n - 1], 0.0)  # (m,)
    mvec = lam * jnp.diagonal(tree_inner(Xt_h, Z))                  # (m,)
    k2, k3 = spec.k2(r_q), spec.k3(r_q)
    M = jnp.diag(-8.0 * k3 * mvec)
    Mh = jnp.diag(-4.0 * k2)
    diag0 = lam * jnp.sum(-4.0 * k2 * mvec)
    W = jnp.block([[M, Mh], [Mh, jnp.zeros((n, n), dtype)]])

    # H = diag0*I + P W Pᵀ, P = lam * [Xt_hᵀ, Zᵀ]  (D, 2m)
    d0 = jnp.where(jnp.abs(diag0) < 1e-8, 1e-8, diag0)
    # PᵀP (2m, 2m) via tree inners
    XX_h = tree_inner(Xt_h, Xt_h)
    XZ_h = tree_inner(Xt_h, Z)
    ZZ_h = tree_inner(Z, Z)
    PtP = lam * lam * jnp.block([[XX_h, XZ_h], [XZ_h.T, ZZ_h]])
    # Pᵀ g  (2m,)
    g1 = jax.tree_util.tree_map(lambda g: g[None], g_t)
    Pg = lam * jnp.concatenate([tree_inner(Xt_h, g1)[:, 0],
                                tree_inner(Z, g1)[:, 0]])
    k2n = W.shape[0]
    inner_m = jnp.linalg.inv(W + jitter * jnp.eye(k2n, dtype=dtype)) + \
        PtP / d0
    y = jnp.linalg.solve(inner_m + jitter * jnp.eye(k2n, dtype=dtype),
                         Pg / d0)
    # dir = -(g/d0 - P @ y / d0);  P @ y = lam*(Xt_hᵀ y1 + Zᵀ y2)
    Py_x = tree_lincomb((lam * y[:n])[None], Xt_h)      # (1, D)-tree
    Py_z = tree_lincomb((lam * y[n:])[None], Z)
    direction = jax.tree_util.tree_map(
        lambda g, a, b: -(g.astype(jnp.float32) - a[0] - b[0]) / d0,
        g_t, Py_x, Py_z)
    return direction


# ---------------------------------------------------------------------------
# Optimizer wrapper
# ---------------------------------------------------------------------------


def gp_precond_tree(
    lr: float = 1.0,
    *,
    history: int = 6,
    kernel: str = "rbf",
    lengthscale_factor: float = 10.0,
    noise: float = 1e-6,
    fallback_lr: float = 3e-4,
    fallback_beta: float = 0.9,
    max_step_rms: float = 1e-2,
) -> Optimizer:
    """GP-H preconditioner with pytree-native histories (no flatten)."""

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "count": jnp.zeros((), jnp.int32),
            "xs": tree_zeros_hist(params, history),
            "gs": tree_zeros_hist(params, history),
            "m": jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }

    def update(grads, state, params):
        xs = tree_push(state["xs"], params)
        gs = tree_push(state["gs"], grads)
        count = jnp.minimum(state["count"] + 1, history)
        m_buf = jax.tree_util.tree_map(
            lambda m_, g: fallback_beta * m_ + g.astype(jnp.float32),
            state["m"], grads)

        def gp_branch(_):
            d = gph_direction_tree(xs, gs, grads, kernel=kernel,
                                   lengthscale_factor=lengthscale_factor,
                                   noise=noise)
            sq = sum(jnp.sum(jnp.square(l))
                     for l in jax.tree_util.tree_leaves(d))
            cnt = sum(l.size for l in jax.tree_util.tree_leaves(d))
            rms = jnp.sqrt(sq / cnt + 1e-30)
            scale = jnp.where(jnp.isfinite(rms),
                              jnp.minimum(1.0, max_step_rms / rms), 0.0)
            return jax.tree_util.tree_map(
                lambda l: jnp.where(jnp.isfinite(l), l, 0.0) * scale * lr, d)

        def fb_branch(_):
            return jax.tree_util.tree_map(lambda m_: -fallback_lr * m_,
                                          m_buf)

        upd = jax.lax.cond(count >= history, gp_branch, fb_branch, None)
        new_params = jax.tree_util.tree_map(
            lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
            params, upd)
        return new_params, {"step": state["step"] + 1, "count": count,
                            "xs": xs, "gs": gs, "m": m_buf}

    return Optimizer(init, update, "gp_tree")

"""Pytree training optimizers: SGD / momentum / AdamW / 8-bit AdamW /
Adafactor.

All follow one tiny functional API:
  opt.init(params) -> state
  opt.update(grads, state, params) -> (new_params, new_state)

Numerics: moments are stored f32 (adamw), int8 blockwise-quantized
(adamw8bit — the memory story for the 1T-param kimi config), or factored
(adafactor — rank-1 second-moment statistics, the default for kimi).
Weight updates happen in f32 and are cast back to the param dtype.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]
    name: str = "opt"


def _cast_like(new, old):
    return jax.tree_util.tree_map(lambda n, o: n.astype(o.dtype), new, old)


# ---------------------------------------------------------------------------
# SGD / momentum
# ---------------------------------------------------------------------------


def sgd(lr: float = 1e-2) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        new = jax.tree_util.tree_map(
            lambda p, g: p.astype(jnp.float32) - lr * g.astype(jnp.float32),
            params, grads)
        return _cast_like(new, params), {"step": state["step"] + 1}

    return Optimizer(init, update, "sgd")


def momentum(lr: float = 1e-2, beta: float = 0.9) -> Optimizer:
    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }

    def update(grads, state, params):
        m = jax.tree_util.tree_map(
            lambda m_, g: beta * m_ + g.astype(jnp.float32), state["m"], grads)
        new = jax.tree_util.tree_map(
            lambda p, m_: p.astype(jnp.float32) - lr * m_, params, m)
        return _cast_like(new, params), {"step": state["step"] + 1, "m": m}

    return Optimizer(init, update, "momentum")


# ---------------------------------------------------------------------------
# AdamW (f32 moments)
# ---------------------------------------------------------------------------


def adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, wd: float = 0.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
        }

    def update(grads, state, params):
        t = state["step"] + 1
        tf = t.astype(jnp.float32)
        c1 = 1.0 - b1 ** tf
        c2 = 1.0 - b2 ** tf

        def upd(p, g, m_, v_):
            g = g.astype(jnp.float32)
            m_new = b1 * m_ + (1 - b1) * g
            v_new = b2 * v_ + (1 - b2) * g * g
            step_ = lr * (m_new / c1) / (jnp.sqrt(v_new / c2) + eps)
            p_new = p.astype(jnp.float32) * (1.0 - lr * wd) - step_
            return p_new, m_new, v_new

        out = jax.tree_util.tree_map(upd, params, grads, state["m"],
                                     state["v"])
        leaves, treedef = jax.tree_util.tree_flatten(
            out, is_leaf=lambda x: isinstance(x, tuple))
        new = jax.tree_util.tree_unflatten(treedef, [l[0] for l in leaves])
        m = jax.tree_util.tree_unflatten(treedef, [l[1] for l in leaves])
        v = jax.tree_util.tree_unflatten(treedef, [l[2] for l in leaves])
        return _cast_like(new, params), {"step": t, "m": m, "v": v}

    return Optimizer(init, update, "adamw")


# ---------------------------------------------------------------------------
# 8-bit AdamW: blockwise-quantized moments (block 256, per-block absmax)
# ---------------------------------------------------------------------------

_BLOCK = 256


def _q8(x: Array) -> tuple[Array, Array]:
    """f32 (n,) -> (int8 codes (n,), f32 scales (n/B,)). n padded by caller."""
    xb = x.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(xb), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    codes = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return codes.reshape(-1), scale[:, 0]


def _dq8(codes: Array, scale: Array) -> Array:
    xb = codes.reshape(-1, _BLOCK).astype(jnp.float32) * scale[:, None]
    return xb.reshape(-1)


def _pad_to_block(flat: Array) -> Array:
    n = flat.shape[0]
    pad = (-n) % _BLOCK
    return jnp.pad(flat, (0, pad)) if pad else flat


def adamw8bit(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
              eps: float = 1e-8, wd: float = 0.0) -> Optimizer:
    """AdamW with int8 moments: 2 bytes/param optimizer state instead of 8."""

    def init(params):
        def zq(p):
            n = _pad_to_block(jnp.zeros((p.size,), jnp.float32)).shape[0]
            return {
                "mq": jnp.zeros((n,), jnp.int8),
                "ms": jnp.zeros((n // _BLOCK,), jnp.float32),
                "vq": jnp.zeros((n,), jnp.int8),
                "vs": jnp.zeros((n // _BLOCK,), jnp.float32),
            }

        return {
            "step": jnp.zeros((), jnp.int32),
            "q": jax.tree_util.tree_map(zq, params),
        }

    def update(grads, state, params):
        t = state["step"] + 1
        tf = t.astype(jnp.float32)
        c1 = 1.0 - b1 ** tf
        c2 = 1.0 - b2 ** tf

        def upd(p, g, q):
            g = _pad_to_block(g.reshape(-1).astype(jnp.float32))
            m_ = _dq8(q["mq"], q["ms"])
            v_ = _dq8(q["vq"], q["vs"])
            m_new = b1 * m_ + (1 - b1) * g
            v_new = b2 * v_ + (1 - b2) * g * g
            step_ = lr * (m_new / c1) / (jnp.sqrt(jnp.maximum(v_new, 0) / c2)
                                         + eps)
            p_new = (p.astype(jnp.float32) * (1.0 - lr * wd)
                     - step_[:p.size].reshape(p.shape))
            mq, ms = _q8(m_new)
            vq, vs = _q8(v_new)
            return p_new, {"mq": mq, "ms": ms, "vq": vq, "vs": vs}

        out = jax.tree_util.tree_map(upd, params, grads, state["q"],
                                     is_leaf=lambda x: isinstance(x, dict)
                                     and "mq" in x)
        leaves, treedef = jax.tree_util.tree_flatten(
            out, is_leaf=lambda x: isinstance(x, tuple))
        new = jax.tree_util.tree_unflatten(treedef, [l[0] for l in leaves])
        q = jax.tree_util.tree_unflatten(treedef, [l[1] for l in leaves])
        return _cast_like(new, params), {"step": t, "q": q}

    return Optimizer(init, update, "adamw8bit")


# ---------------------------------------------------------------------------
# Adafactor (factored second moments; momentum-free) — the 1T-param default
# ---------------------------------------------------------------------------


def adafactor(lr: float = 3e-4, decay: float = 0.95, eps: float = 1e-30,
              clip: float = 1.0) -> Optimizer:
    def init(params):
        def stats(p):
            if p.ndim >= 2:
                return {
                    "r": jnp.zeros(p.shape[:-1], jnp.float32),
                    "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {
            "step": jnp.zeros((), jnp.int32),
            "s": jax.tree_util.tree_map(
                stats, params, is_leaf=lambda x: isinstance(x, jnp.ndarray)),
        }

    def update(grads, state, params):
        t = state["step"] + 1

        def upd(p, g, s):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if p.ndim >= 2:
                r = decay * s["r"] + (1 - decay) * jnp.mean(g2, axis=-1)
                c = decay * s["c"] + (1 - decay) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(r, axis=-1, keepdims=True),
                                    eps)[..., None]
                vhat = (r[..., None] * c[..., None, :]) / denom
                s_new = {"r": r, "c": c}
            else:
                vhat = decay * s["v"] + (1 - decay) * g2
                s_new = {"v": vhat}
            u = g / jnp.sqrt(vhat + eps)
            # update clipping (Adafactor's RMS rule)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip)
            return p.astype(jnp.float32) - lr * u, s_new

        out = jax.tree_util.tree_map(
            upd, params, grads, state["s"],
            is_leaf=lambda x: isinstance(x, dict) and ("r" in x or "v" in x))
        leaves, treedef = jax.tree_util.tree_flatten(
            out, is_leaf=lambda x: isinstance(x, tuple))
        new = jax.tree_util.tree_unflatten(treedef, [l[0] for l in leaves])
        s = jax.tree_util.tree_unflatten(treedef, [l[1] for l in leaves])
        return _cast_like(new, params), {"step": t, "s": s}

    return Optimizer(init, update, "adafactor")


def get_optimizer(name: str, lr: float = 3e-4, **kw) -> Optimizer:
    table = {
        "sgd": sgd, "momentum": momentum, "adamw": adamw,
        "adamw8bit": adamw8bit, "adafactor": adafactor,
    }
    if name == "gp":
        from .gp_precond import gp_precond
        return gp_precond(lr=lr, **kw)
    if name == "gp_tree":
        from .gp_tree import gp_precond_tree
        return gp_precond_tree(lr=lr, **kw)
    return table[name](lr=lr, **kw)

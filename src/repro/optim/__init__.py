"""Optimizers: classic pytree optimizers + the paper's GP-gradient methods."""
from .optimizers import (Optimizer, adafactor, adamw, adamw8bit, get_optimizer,
                         momentum, sgd)
from .gp_precond import gp_precond
from .gp_directions import (gph_direction, gph_direction_state,
                            gpx_direction, gpx_direction_state)
from .classic import gp_optimize, strong_wolfe
from .compression import ef_int8_compress, ef_int8_decompress

__all__ = [
    "Optimizer", "adafactor", "adamw", "adamw8bit", "get_optimizer",
    "momentum", "sgd", "gp_precond", "gph_direction", "gph_direction_state",
    "gpx_direction", "gpx_direction_state",
    "gp_optimize", "strong_wolfe", "ef_int8_compress",
    "ef_int8_decompress",
]

"""Exact log marginal likelihood of the gradient-GP, from structured factors.

The evidence of N gradient observations is governed by the (ND, ND) matrix

    K = s^2 * (grad K grad')(lam) + sigma^2 I,

whose log-determinant and quadratic form are exactly what the paper's
low-rank structure (Sec. 3-4) makes cheap: with B = K1n (x) Lambda the free
Kronecker factor and the derivative term written as the thin product A B^T
with N^2 columns (DESIGN.md sec. 11), the matrix determinant lemma
(Weinstein-Aronszajn) gives

    logdet K = ND log s^2  +  D logdet K1n + ND log lam
             + logdet( I_{N^2} + M ),
    M[(a,b),(a',b')] = K2e[a,b] * K1n^{-1}[b,a'] * s(a,b,a',b'),

      dot:        s = S[a,b']
      stationary: s = S[a,a'] - S[a,b'] - S[b,a'] + S[b,b']

where S = Xt Lambda Xt^T and K1n = K1e + (sigma^2/(s^2 lam)) I.  The
quadratic form comes from the matching Woodbury identity using the same
(N^2, N^2) inner matrix.  Total cost O(N^2 D + N^4 .. (N^2)^3), memory
O(ND + N^4) — the (ND, ND) Gram is NEVER materialized (enforceable at the
jaxpr level via :func:`assert_no_dense_gram`), exact where Padidar et al.
(2021) resort to variational approximation.

Everything here is a pure jnp computation of ``HyperParams`` pytrees, so
``jax.grad(mll)`` w.r.t. log-lengthscale / log-signal / log-noise is the
exact evidence gradient — that is what ``repro.hyper.fit`` descends.

Only scalar (isotropic) Lambda is supported, matching the paper's own
experiments and the exact-path restriction already present in
``core/woodbury.py``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.gram import GramFactors, build_factors
from repro.core.kernels import KernelSpec, get_kernel
from repro.core.mvm import l_op

from .params import LOG2PI, HyperParams

Array = jnp.ndarray


def _as_spec(kernel) -> KernelSpec:
    return get_kernel(kernel) if isinstance(kernel, str) else kernel


# ---------------------------------------------------------------------------
# The determinant-lemma inner matrix (N^2, N^2) and the structured pieces
# ---------------------------------------------------------------------------


def inner_matrix(spec: KernelSpec, f: GramFactors, K1i: Array,
                 S: Array) -> Array:
    """I + M — the (N^2, N^2) determinant-lemma / Woodbury inner matrix.

    Built from the (N, N) strips only (O(N^4) memory).  Zero-padded factor
    rows are inert: padded (a, b) rows of M vanish (K2e zero tail) and the
    matrix is block upper-triangular against the identity tail, so both
    ``slogdet`` and solves against zero-padded right-hand sides are exact.
    """
    n = f.K1e.shape[0]
    if spec.is_stationary:
        ss = (S[:, None, :, None] - S[:, None, None, :]
              - S[None, :, :, None] + S[None, :, None, :])
        M = f.K2e[:, :, None, None] * K1i[None, :, :, None] * ss
    else:
        M = (f.K2e[:, :, None, None] * K1i[None, :, :, None]
             * S[:, None, None, :])
    return jnp.eye(n * n, dtype=f.K1e.dtype) + M.reshape(n * n, n * n)


def _k1n(f: GramFactors, noise_eff) -> Array:
    """K1e + (sigma_eff^2 / lam) I on the valid block (identity-safe tail)."""
    n = f.K1e.shape[0]
    lam = jnp.asarray(f.lam)
    return f.K1e + (jnp.asarray(noise_eff) / lam) * jnp.eye(n, dtype=f.K1e.dtype)


def _rhs_inner(spec: KernelSpec, f: GramFactors, W: Array) -> Array:
    """B^T B0^{-1} vec(R) as an (N, N) matrix, given W = K1i R / lam."""
    lam = jnp.asarray(f.lam)
    sw = lam * (f.Xt @ W.T)                       # sw[a,b] = lam x~_a . W_b
    if spec.is_stationary:
        rd = lam * jnp.sum(f.Xt * W, axis=1)      # lam x_b . W_b
        return f.K2e * (sw - rd[None, :])
    return f.K2e * sw


def _correction(spec: KernelSpec, f: GramFactors, K1i: Array,
                y: Array) -> Array:
    """B0^{-1} A vec(y) as an (N, D) matrix (the Woodbury down-correction)."""
    if spec.is_stationary:
        return K1i @ (l_op(y) @ f.Xt)
    return K1i @ (y @ f.Xt)


def gram_logdet_quad(
    spec: KernelSpec,
    f: GramFactors,
    G: Array,
    noise_eff,
) -> tuple[Array, Array]:
    """(logdet, quad) of the UNSCALED system  K' = grad K grad' + noise_eff I.

    logdet K' = D logdet K1n + N D log lam + logdet(I + M); the quadratic
    form  vec(G)^T K'^{-1} vec(G)  reuses the same inner matrix through one
    LU solve.  O(N^2 D) skinny work + O((N^2)^3) inner dense work; no
    intermediate ever carries an ND-sized axis.
    """
    n, d = f.Xt.shape
    lam = jnp.asarray(f.lam)
    K1n = _k1n(f, noise_eff)
    K1i = jnp.linalg.inv(K1n)
    S = lam * (f.Xt @ f.Xt.T)
    A = inner_matrix(spec, f, K1i, S)

    _, ld_inner = jnp.linalg.slogdet(A)
    _, ld_k1n = jnp.linalg.slogdet(K1n)
    logdet = d * ld_k1n + n * d * jnp.log(lam) + ld_inner

    W = K1i @ G / lam                              # B0^{-1} vec(G)
    t = _rhs_inner(spec, f, W)
    y = jnp.linalg.solve(A, t.reshape(-1)).reshape(n, n)
    V = W - _correction(spec, f, K1i, y)           # K'^{-1} vec(G)
    quad = jnp.sum(G * V)
    return logdet, quad


# ---------------------------------------------------------------------------
# The log marginal likelihood and its dense oracle
# ---------------------------------------------------------------------------


def mll(
    kernel: str | KernelSpec,
    X: Array,
    G: Array,
    hypers: HyperParams,
    *,
    c: Optional[Array] = None,
) -> Array:
    """Exact log p(G | X, hypers) of the gradient GP — fully structured.

    Differentiable w.r.t. the ``HyperParams`` pytree (and X/G); jittable.
    The signal variance folds through the scaling identity
    s^2 K + sigma^2 I = s^2 (K + sigma^2/s^2 I), so the structured pieces
    run once on the unscaled Gram.
    """
    spec = _as_spec(kernel)
    n, d = X.shape
    # the evidence path pins the jnp oracle forms: it must be reverse-mode
    # differentiable w.r.t. the hypers (the pallas kernels are forward-only)
    # and is refresh-cadence work, never the per-step hot path
    from repro.core import backend

    with backend.use_backend("jnp"):
        f = build_factors(spec, X, lam=hypers.lam, c=c)
        logdet_u, quad_u = gram_logdet_quad(spec, f, G, hypers.noise_eff)
    nd = n * d
    logdet = nd * hypers.log_signal + logdet_u
    quad = quad_u / hypers.signal
    return -0.5 * (quad + logdet + nd * LOG2PI)


def make_mll_fn(kernel: str | KernelSpec, X: Array, G: Array, *,
                c: Optional[Array] = None):
    """hypers -> mll closure over fixed data (what fit/jax.grad consume)."""
    spec = _as_spec(kernel)
    X = jnp.asarray(X)
    G = jnp.asarray(G)

    def fn(hypers: HyperParams) -> Array:
        return mll(spec, X, G, hypers, c=c)

    return fn


# ---------------------------------------------------------------------------
# Strips form: the evidence from (S0, C, GG) alone — the D axis is gone
# ---------------------------------------------------------------------------


def strips_for_mll(X: Array, G: Array, *,
                   c: Optional[Array] = None) -> tuple[Array, Array, Array]:
    """The three UNSCALED (N, N) strips the evidence needs: S0, C, GG.

    S0 = X̃ X̃^T (lambda-free!), C = G X̃^T, GG = G G^T.  These are the only
    objects in the entire MLL + hyper-gradient computation that touch the
    D axis — under D-sharding they are one fused psum of local partials
    (``core.dist_state``), and because S0 is stored unscaled, every
    lambda (lengthscale) dependence re-enters *inside*
    :func:`mll_from_strips`, keeping ``jax.grad`` w.r.t. the hypers exact
    with ZERO additional collectives per fit step.
    """
    Xt = X if c is None else X - jnp.asarray(c)
    return Xt @ Xt.T, G @ Xt.T, G @ G.T


def mll_from_strips(
    kernel: str | KernelSpec,
    S0: Array,
    C: Array,
    GG: Array,
    d: int,
    hypers: HyperParams,
    *,
    count=None,
) -> Array:
    """Exact log p(G | X, hypers) from the (N, N) strips — no (N, D) input.

    Identical value (and hyper-gradient) to :func:`mll`: every quantity in
    ``gram_logdet_quad`` is re-expressed through the strips —

      sw   = (K1i C)^T                       (was lam Xt W^T)
      quad = sum(K1i * GG)/lam - sum(K1i * (C L(y)^T))   (L = l_op, station.)

    ``d`` is the TRUE input dimension (zero pad columns in X/G contribute
    zero to the strips, so padded-D callers pass the unpadded d for the
    per-dimension logdet terms).  ``count`` masks to the first ``count``
    rows (zero-padded fixed-capacity strips from the incremental state);
    the identity tail of K1n and the block structure of the inner matrix
    make the padded algebra exact, as in ``core/state.py``.
    """
    spec = _as_spec(kernel)
    n = S0.shape[0]
    if count is None:
        mask = jnp.ones((n,), bool)
        n_eff = n
    else:
        mask = jnp.arange(n) < count
        n_eff = count
    mm = mask[:, None] & mask[None, :]
    lam = jnp.asarray(hypers.lam)
    d0 = jnp.diagonal(S0)
    if spec.is_stationary:
        r = lam * jnp.maximum(d0[:, None] + d0[None, :] - 2.0 * S0, 0.0)
    else:
        r = lam * S0
    K1e = jnp.where(mm, spec.k1e(r), 0.0)
    K2e = jnp.where(mm, spec.k2e(r), 0.0)
    noise_eff = jnp.asarray(hypers.noise_eff)
    K1n = K1e + jnp.diag(jnp.where(mask, noise_eff / lam, 1.0))
    K1i = jnp.linalg.inv(K1n)
    S = lam * jnp.where(mm, S0, 0.0)
    Cm = jnp.where(mm, C, 0.0)
    GGm = jnp.where(mm, GG, 0.0)
    f_like = GramFactors(K1e=K1e, K2e=K2e, Xt=S0, lam=lam)
    A = inner_matrix(spec, f_like, K1i, S)

    _, ld_inner = jnp.linalg.slogdet(A)
    _, ld_k1n = jnp.linalg.slogdet(K1n)
    logdet_u = d * ld_k1n + n_eff * d * jnp.log(lam) + ld_inner

    sw = (K1i @ Cm).T                          # lam x~_a . W_b, via C
    if spec.is_stationary:
        t = K2e * (sw - jnp.diagonal(sw)[None, :])
    else:
        t = K2e * sw
    y = jnp.linalg.solve(A, t.reshape(-1)).reshape(n, n)
    yc = l_op(y) if spec.is_stationary else y
    quad_u = jnp.sum(K1i * GGm) / lam - jnp.sum(K1i * (Cm @ yc.T))

    nd = n_eff * d
    logdet = nd * hypers.log_signal + logdet_u
    quad = quad_u / hypers.signal
    return -0.5 * (quad + logdet + nd * LOG2PI)


def make_mll_strips_fn(kernel: str | KernelSpec, S0: Array, C: Array,
                       GG: Array, d: int, *, count=None):
    """hypers -> mll closure over fixed strips (replicated fit under
    sharding: the strips are psummed once, then every fit step is local)."""
    spec = _as_spec(kernel)
    S0, C, GG = jnp.asarray(S0), jnp.asarray(C), jnp.asarray(GG)

    def fn(hypers: HyperParams) -> Array:
        return mll_from_strips(spec, S0, C, GG, d, hypers, count=count)

    return fn


def mll_dense(
    kernel: str | KernelSpec,
    X: Array,
    G: Array,
    hypers: HyperParams,
    *,
    c: Optional[Array] = None,
) -> Array:
    """O((ND)^3 time, (ND)^2 memory) oracle via the materialized Gram +
    ``jnp.linalg.slogdet`` — the small-N*D reference ``mll`` is tested
    against (tests/test_hyper.py, benchmarks/bench_hyper.py)."""
    from repro.core.gram import dense_gram

    spec = _as_spec(kernel)
    n, d = X.shape
    K = (hypers.signal * dense_gram(spec, X, lam=hypers.lam, c=c)
         + hypers.noise * jnp.eye(n * d, dtype=X.dtype))
    _, logdet = jnp.linalg.slogdet(K)
    g = G.reshape(-1)
    quad = g @ jnp.linalg.solve(K, g)
    return -0.5 * (quad + logdet + n * d * LOG2PI)


# ---------------------------------------------------------------------------
# Jaxpr-level structural guarantee: no (ND, ND) Gram, ever
# ---------------------------------------------------------------------------


class StructureError(AssertionError):
    """Raised when a traced computation materializes a forbidden axis."""


def _jaxpr_axis_sizes(jaxpr) -> list[int]:
    # shared census with the iterative-regime gate (regime/krylov.py)
    from repro.utils.hlo import jaxpr_axis_sizes

    return jaxpr_axis_sizes(jaxpr)


def assert_no_dense_gram(
    kernel: str | KernelSpec,
    X: Array,
    G: Array,
    hypers: HyperParams,
    *,
    c: Optional[Array] = None,
    grad: bool = False,
) -> int:
    """Trace ``mll`` (or its hyper-gradient) and assert that no intermediate
    carries an axis of size >= N*D — i.e. the (ND, ND) Gram (or even a
    vec(G)-shaped flattening of it) is structurally absent.

    Requires N*D > N^2 (N < D) so the legitimate (N^2, N^2) inner matrix is
    distinguishable from the forbidden object; raises ``ValueError``
    otherwise (the check would be vacuous).  Returns the largest axis seen.
    """
    spec = _as_spec(kernel)
    n, d = X.shape
    nd = n * d
    if nd <= n * n:
        raise ValueError(
            f"structural check needs N < D to be meaningful (N={n}, D={d}: "
            f"the legitimate N^2={n * n} inner axis is >= ND={nd})")
    fn = make_mll_fn(spec, X, G, c=c)
    if grad:
        fn = jax.grad(fn)
    closed = jax.make_jaxpr(fn)(hypers)
    dims = _jaxpr_axis_sizes(closed.jaxpr)
    worst = max(dims) if dims else 0
    if worst >= nd:
        raise StructureError(
            f"mll trace materialized an axis of size {worst} >= N*D={nd} — "
            "the structured path must never build the dense gradient Gram")
    return worst

"""Hyperparameter fitting: maximize the structured exact MLL.

Two entry points over the unconstrained log-reparameterized ``HyperParams``
pytree (``jax.grad`` through ``mll.mll`` is the exact evidence gradient —
no ELBOs, no sampling):

  * :func:`fit`      — host-facing: one jit-compiled Adam step, a python
                       loop with patience-based early stopping, bound
                       guards, and non-finite-step rejection.  Returns a
                       :class:`FitResult` scorecard.
  * :func:`fit_scan` — pure/traceable fixed-step ``lax.scan`` variant for
                       use INSIDE a jitted consumer (the periodic MLL
                       refresh of ``optim/gp_precond.py`` runs this in the
                       sharded training step).

Bound guards: after every Adam step the log-hypers are clamped into
``BOUNDS`` (wide but finite boxes) so a bad gradient can never drive the
lengthscale or noise to 0/inf and poison downstream Cholesky/CG.  A
``mask`` pytree (1.0 = trainable) freezes individual hypers — the
in-training refresh fits the lengthscale only, holding the configured
noise fixed.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

# aliased import: fit_scan's scan outputs are locally named ``trace``
from repro.obs import injit as _obs_tap
from repro.obs import trace as _obs

from .mll import make_mll_fn
from .params import HyperParams

Array = jnp.ndarray

#: Hard boxes on the log-hypers (natural values: ell^2 in [1e-6, 1e12],
#: s^2 in [1e-8, 1e8], sigma^2 in [1e-14, 1e2]).
BOUNDS = HyperParams(
    log_lengthscale2=(math.log(1e-6), math.log(1e12)),
    log_signal=(math.log(1e-8), math.log(1e8)),
    log_noise=(math.log(1e-14), math.log(1e2)),
)

FULL_MASK = HyperParams(1.0, 1.0, 1.0)
LENGTHSCALE_ONLY = HyperParams(1.0, 0.0, 0.0)


def _clip(h: HyperParams) -> HyperParams:
    return HyperParams(*[
        jnp.clip(v, lo, hi) for v, (lo, hi) in zip(h, BOUNDS)])


def _mask_grad(g: HyperParams, mask: HyperParams) -> HyperParams:
    """Zero non-finite gradient entries and frozen (mask=0) fields,
    preserving each leaf's dtype (the f32 in-jit path must stay f32)."""
    return jax.tree_util.tree_map(
        lambda g_, msk: jnp.where(jnp.isfinite(g_), g_, 0.0)
        * jnp.asarray(msk, g_.dtype), g, mask)


class FitResult(NamedTuple):
    """What a fit did: fitted hypers + the evidence trajectory endpoints."""

    hypers: HyperParams
    mll: Array            # best (= final reported) log marginal likelihood
    mll0: Array           # MLL at the init — improvement = mll - mll0
    n_steps: int
    converged: bool       # early-stopped on the improvement tolerance
    history: Optional[Array] = None   # per-step MLL trace (host fit only)

    @property
    def improvement(self) -> float:
        return float(self.mll - self.mll0)


def _adam_update(g, m, v, step, lr, b1=0.9, b2=0.999, eps=1e-8):
    m = jax.tree_util.tree_map(lambda m_, g_: b1 * m_ + (1 - b1) * g_, m, g)
    v = jax.tree_util.tree_map(lambda v_, g_: b2 * v_ + (1 - b2) * g_ * g_,
                               v, g)
    t = step + 1
    mh = jax.tree_util.tree_map(lambda m_: m_ / (1 - b1 ** t), m)
    vh = jax.tree_util.tree_map(lambda v_: v_ / (1 - b2 ** t), v)
    upd = jax.tree_util.tree_map(
        lambda m_, v_: lr * m_ / (jnp.sqrt(v_) + eps), mh, vh)
    return upd, m, v


def fit_scan_fn(
    fn,
    init: HyperParams,
    *,
    steps: int = 16,
    lr: float = 0.1,
    mask: Optional[HyperParams] = None,
) -> tuple[HyperParams, Array]:
    """Traceable Adam ascent on an arbitrary hypers->mll closure.

    The engine under :func:`fit_scan`; also consumed directly with
    ``mll.make_mll_strips_fn`` closures, where the (N, N) strips were
    psummed once and every fit step is collective-free under sharding.
    Guards: non-finite gradients are zeroed (the step is a no-op instead
    of a poison), every iterate is clamped into ``BOUNDS``, and the
    returned hypers are the LAST iterate with a final non-finite fallback
    to the init.  Safe to call under jit / shard_map.
    """
    vg = jax.value_and_grad(fn)
    m0 = FULL_MASK if mask is None else mask

    zeros = jax.tree_util.tree_map(lambda v: jnp.zeros_like(jnp.asarray(v)),
                                   init)

    def body(carry, step):
        h, m, v = carry
        val, g = vg(h)
        g = _mask_grad(g, m0)
        upd, m, v = _adam_update(g, m, v, step, lr)
        h = _clip(jax.tree_util.tree_map(
            lambda p, u: (p + u).astype(jnp.asarray(p).dtype), h, upd))
        return (h, m, v), val

    (h, _, _), trace = jax.lax.scan(body, (init, zeros, zeros),
                                    jnp.arange(steps))
    final = fn(h)
    ok = jnp.isfinite(final) & jax.tree_util.tree_reduce(
        lambda a, b: a & b,
        jax.tree_util.tree_map(lambda v: jnp.all(jnp.isfinite(v)), h))
    _obs_tap.tap("hyper.fit_scan.final_mll", final)
    _obs_tap.tap("hyper.fit_scan.nonfinite_fallback", ~ok, kind="counter")
    h = jax.tree_util.tree_map(
        lambda a, b: jnp.where(ok, a, b), h, _clip(init))
    return h, jnp.where(ok, final, trace[0] if steps else final)


def fit_scan(
    kernel,
    X: Array,
    G: Array,
    init: HyperParams,
    *,
    steps: int = 16,
    lr: float = 0.1,
    c: Optional[Array] = None,
    mask: Optional[HyperParams] = None,
) -> tuple[HyperParams, Array]:
    """Fixed-step traceable Adam ascent on the MLL; returns (hypers, mll).

    Thin wrapper: builds the (X, G) evidence closure and runs
    :func:`fit_scan_fn` (see there for the in-scan guards).
    """
    fn = make_mll_fn(kernel, X, G, c=c)
    return fit_scan_fn(fn, init, steps=steps, lr=lr, mask=mask)


def fit(
    kernel,
    X: Array,
    G: Array,
    init: Optional[HyperParams] = None,
    *,
    c: Optional[Array] = None,
    steps: int = 200,
    lr: float = 0.08,
    tol: float = 1e-6,
    patience: int = 12,
    mask: Optional[HyperParams] = None,
) -> FitResult:
    """Maximize the exact structured MLL with early stopping.

    One Adam step is jit-compiled once; the python loop tracks the best
    iterate and stops after ``patience`` steps without a relative
    improvement > ``tol``.  ``init=None`` seeds the lengthscale from the
    mean-pairwise-distance heuristic (``optim.gp_directions.
    auto_lengthscale`` — exactly the init the MLL fit is meant to beat).
    """
    X = jnp.atleast_2d(X)
    G = jnp.asarray(G)
    if init is None:
        from repro.optim.gp_directions import auto_lengthscale  # deferred:
        # optim imports repro.hyper at module level; this import runs at
        # call time when both packages are complete.
        init = HyperParams.from_lam(auto_lengthscale(X), signal=1.0,
                                    noise=1e-8)
    fn = make_mll_fn(kernel, X, G, c=c)
    return fit_fn(fn, init, steps=steps, lr=lr, tol=tol,
                  patience=patience, mask=mask)


def fit_fn(
    fn,
    init: HyperParams,
    *,
    steps: int = 200,
    lr: float = 0.08,
    tol: float = 1e-6,
    patience: int = 12,
    mask: Optional[HyperParams] = None,
) -> FitResult:
    """Host fit loop over an arbitrary hypers->mll closure (engine of
    :func:`fit`; also consumed with ``mll.make_mll_strips_fn`` closures by
    the sharded state's ``refit`` — the strips are psummed once, then the
    whole fit is replicated host compute with zero collectives)."""
    init = _clip(jax.tree_util.tree_map(jnp.asarray, init))
    vg = jax.value_and_grad(fn)
    m0 = FULL_MASK if mask is None else mask

    @jax.jit
    def step_fn(h, m, v, step):
        val, g = vg(h)
        g = _mask_grad(g, m0)
        upd, m, v = _adam_update(g, m, v, step, lr)
        h_new = _clip(jax.tree_util.tree_map(
            lambda p, u: (p + u).astype(jnp.asarray(p).dtype), h, upd))
        return h_new, m, v, val

    zeros = jax.tree_util.tree_map(lambda v: jnp.zeros_like(v), init)
    h, m, v = init, zeros, zeros
    best_h, best_val = init, -jnp.inf
    mll0 = None
    history = []
    stall = 0
    converged = False
    k = 0
    with _obs.span("hyper.fit", steps=steps):
        for k in range(steps):
            h_new, m, v, val = step_fn(h, m, v, jnp.asarray(k))
            history.append(float(val))
            if mll0 is None and bool(jnp.isfinite(val)):
                mll0 = val        # the first FINITE evidence (at the init
                # on step 0; improvement stays NaN-free even if the very
                # first evaluation tripped the bound guards)
            if not bool(jnp.isfinite(val)):
                # bound guard tripped anyway — reject the step, keep going
                # from the best iterate with the optimizer state reset
                h, m, v = best_h, zeros, zeros
                stall += 1
            else:
                if float(val) > float(best_val) + tol * (1.0
                                                         + abs(float(val))):
                    best_h, best_val, stall = h, val, 0
                else:
                    stall += 1
                h = h_new
            if stall >= patience:
                converged = True
                break
    # the loop scores iterates BEFORE stepping, so the last Adam iterate is
    # still unevaluated here — score it and adopt it if it won (this is
    # also what makes fit(steps=1) perform a real step, not a no-op)
    final = fn(h)
    if bool(jnp.isfinite(final)) and float(final) > float(best_val):
        best_h, best_val = h, final
    if mll0 is None:
        mll0 = best_val           # never finite during the loop: report
        # zero improvement rather than a NaN baseline
    if _obs.enabled():
        _obs.REGISTRY.inc("hyper.fit.calls")
        _obs.REGISTRY.inc("hyper.fit.stop.early" if converged
                          else "hyper.fit.stop.max_steps")
        _obs.REGISTRY.set_gauge("hyper.fit.steps", k + 1)
        _obs.REGISTRY.set_gauge("hyper.fit.improvement",
                                float(best_val) - float(mll0))
    return FitResult(
        hypers=best_h,
        mll=jnp.asarray(best_val),
        mll0=jnp.asarray(mll0),
        n_steps=k + 1,
        converged=converged,
        history=jnp.asarray(history) if history else None,
    )

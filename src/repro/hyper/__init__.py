"""Model selection & uncertainty: structured exact MLL, hyperparameter
fitting, and posterior variance (paper Sec. 3-4 structure put to work for
the evidence; DESIGN.md sec. 11).

  params.py    — ``HyperParams``: the shared log-reparameterized container
                 (one source of truth across optim / sampling / serve).
  mll.py       — exact log marginal likelihood from the structured factors
                 (determinant-lemma logdet on the (N^2, N^2) inner matrix;
                 never the (ND, ND) Gram — jaxpr-assertable).
  fit.py       — jit-compiled Adam ascent on the MLL (host loop with early
                 stop + traceable ``fit_scan`` for in-jit refreshes).
  variance.py  — posterior value/gradient variance via the structured
                 Woodbury solver (``GramSolver``), clamped PSD.
"""
from .fit import (BOUNDS, FULL_MASK, LENGTHSCALE_ONLY, FitResult, fit,
                  fit_fn, fit_scan, fit_scan_fn)
from .mll import (StructureError, assert_no_dense_gram, gram_logdet_quad,
                  inner_matrix, make_mll_fn, make_mll_strips_fn, mll,
                  mll_dense, mll_from_strips, strips_for_mll)
from .params import HyperParams
from .variance import (GramSolver, grad_std, grad_var, make_solver,
                       solve_gram, value_std, value_var)

__all__ = [
    "HyperParams",
    "mll", "mll_dense", "make_mll_fn", "gram_logdet_quad", "inner_matrix",
    "assert_no_dense_gram", "StructureError",
    "mll_from_strips", "strips_for_mll", "make_mll_strips_fn",
    "fit", "fit_fn", "fit_scan", "fit_scan_fn", "FitResult", "BOUNDS",
    "FULL_MASK", "LENGTHSCALE_ONLY",
    "GramSolver", "make_solver", "solve_gram",
    "value_var", "value_std", "grad_var", "grad_std",
]

"""Posterior variance / standard deviation for value and gradient queries.

The missing half of the serving story: ``core/query.py`` serves posterior
*means* off one cached solve; acquisition functions (EI/UCB) and calibrated
model selection additionally need

    var[f(x_q)]        = s^2 [ k(x_q,x_q)      - c_q^T  K'^{-1} c_q  ]
    var[d_i f(x_q)]    = s^2 [ blk(q,q)_{ii}   - C_q,i^T K'^{-1} C_q,i ]

with K' = grad K grad' + (sigma^2/s^2) I the UNSCALED noisy Gram and
c_q / C_q the value/gradient cross-covariance columns — (N, D)-shaped
right-hand sides in this repo's layout.  Each quadratic form is one
structured Woodbury application through the SAME (N^2, N^2) inner matrix
the log-marginal-likelihood uses (``mll.inner_matrix``): the
:class:`GramSolver` factorizes it ONCE per state revision (O(N^2 D +
(N^2)^3)), after which every query costs O(N^2 D + N^4) — value queries
need one application, gradient queries D of them (vmapped).

Variances are clamped at zero (the subtraction of two PSD quadratic forms
can go negative by roundoff); zero-padded factor rows are masked out of
the cross-covariance so the solver works verbatim on the fixed-capacity
padded ``GPGData`` views (``train/serve.py`` passes those for
compile-stability).

All hyperparameters enter as ARRAYS inside the solver pytree, so a jitted
consumer taking a ``GramSolver`` argument stays compile-stable when the
hypers change (refit between requests never recompiles the serve step).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.scipy.linalg import lu_factor, lu_solve

from repro.core.gram import GramFactors
from repro.core.kernels import KernelSpec

from .mll import _correction, _rhs_inner, inner_matrix

Array = jnp.ndarray


class GramSolver(NamedTuple):
    """A reusable structured factorization of  K' = grad K grad' + noise I.

    All fields are arrays (a jit-stable pytree): K1i the inverse Kronecker
    factor, (A_lu, A_piv) the LU of the (N^2, N^2) inner matrix, ``mask``
    the valid-row indicator (handles zero-padded capacity tails), and the
    hyperparameters as dynamic scalars.
    """

    K1i: Array           # (N, N)  inverse of K1e + (noise/lam) I
    A_lu: Array          # (N^2, N^2) LU factors of I + M
    A_piv: Array         # (N^2,) pivots
    mask: Array          # (N,) 1.0 on valid rows, 0.0 on the padded tail
    lam: Array           # scalar Lambda
    signal: Array        # scalar s^2
    noise: Array         # scalar sigma^2 (the true, unscaled noise)

    @property
    def n(self) -> int:
        return self.K1i.shape[0]


def make_solver(
    spec: KernelSpec,
    f: GramFactors,
    *,
    noise=None,
    signal=1.0,
    count: Optional[Array] = None,
) -> GramSolver:
    """Factorize the structured system once (O(N^2 D + (N^2)^3)).

    ``noise`` defaults to ``f.noise``; ``count`` marks the number of valid
    rows when ``f`` is a zero-padded fixed-capacity view (padded rows of
    the inner matrix are inert by construction — block triangular against
    the identity tail — but the cross-covariances must be masked).
    Traceable: usable inside jit with dynamic hypers.
    """
    n = f.K1e.shape[0]
    lam = jnp.asarray(f.lam)
    if lam.ndim != 0:
        raise ValueError("posterior variance requires scalar Lambda "
                         "(isotropic lengthscale), as in the exact path")
    signal = jnp.asarray(signal, f.K1e.dtype)
    noise = jnp.asarray(f.noise if noise is None else noise, f.K1e.dtype)
    noise_eff = noise / signal
    mask = (jnp.ones((n,), f.K1e.dtype) if count is None
            else (jnp.arange(n) < count).astype(f.K1e.dtype))
    diag = jnp.where(mask > 0, noise_eff / lam, 1.0)
    K1n = f.K1e + jnp.diag(diag)
    K1i = jnp.linalg.inv(K1n)
    S = lam * (f.Xt @ f.Xt.T)
    A = inner_matrix(spec, f, K1i, S)
    A_lu, A_piv = lu_factor(A)
    return GramSolver(K1i=K1i, A_lu=A_lu, A_piv=A_piv, mask=mask, lam=lam,
                      signal=signal, noise=noise)


def solve_gram(spec: KernelSpec, f: GramFactors, solver: GramSolver,
               R: Array) -> Array:
    """K'^{-1} vec(R) for an (N, D) right-hand side — O(N^2 D + N^4).

    R must be zero on padded rows (mask it first); the result is again an
    (N, D) matrix with a zero tail.
    """
    n = solver.n
    W = solver.K1i @ R / solver.lam
    t = _rhs_inner(spec, f, W)
    y = lu_solve((solver.A_lu, solver.A_piv), t.reshape(-1)).reshape(n, n)
    return W - _correction(spec, f, solver.K1i, y)


# ---------------------------------------------------------------------------
# Cross-covariance right-hand sides (the query columns of the joint Gram)
# ---------------------------------------------------------------------------


def _value_cross(spec: KernelSpec, xq: Array, f: GramFactors,
                 solver: GramSolver):
    """(c_q as (N, D), prior k_qq) for ONE value query (unscaled kernel)."""
    lam = solver.lam
    if spec.is_stationary:
        dlt = xq[None, :] - f.Xt
        r = jnp.maximum(jnp.sum(dlt * lam * dlt, axis=1), 0.0)
        C = -2.0 * spec.k1(r)[:, None] * (lam * dlt)
        prior = spec.k0(jnp.zeros((), xq.dtype))
    else:
        xqt = xq if f.c is None else xq - f.c
        r = lam * (f.Xt @ xqt)
        C = spec.k1(r)[:, None] * (lam * xqt)[None, :]
        prior = spec.k0(lam * jnp.dot(xqt, xqt))
    return C * solver.mask[:, None], prior


def _grad_cross(spec: KernelSpec, xq: Array, f: GramFactors,
                solver: GramSolver):
    """(C_q as (D, N, D) RHS stack, prior blk(q,q) diagonal (D,))."""
    lam = solver.lam
    d = f.Xt.shape[1]
    eye = jnp.eye(d, dtype=xq.dtype)
    if spec.is_stationary:
        dlt = xq[None, :] - f.Xt
        r = jnp.maximum(jnp.sum(dlt * lam * dlt, axis=1), 0.0)
        k1e, k2e = spec.k1e(r), spec.k2e(r)
        u = lam * dlt                                       # (N, D)
        # R[i, b, j] = k1e[b] lam I[i,j] + k2e[b] u[b,i] u[b,j]
        R = (k1e[None, :, None] * lam * eye[:, None, :]
             + k2e[None, :, None] * u.T[:, :, None] * u[None, :, :])
        r0 = jnp.zeros((), xq.dtype)
        prior = spec.k1e(r0) * lam * jnp.ones((d,), xq.dtype)
    else:
        xqt = xq if f.c is None else xq - f.c
        r = lam * (f.Xt @ xqt)
        k1e, k2e = spec.k1e(r), spec.k2e(r)
        ub = lam * f.Xt                                     # Lam x~_b
        uq = lam * xqt                                      # Lam x~_q
        # R[i, b, j] = k1e[b] lam I[i,j] + k2e[b] ub[b,i] uq[j]
        R = (k1e[None, :, None] * lam * eye[:, None, :]
             + k2e[None, :, None] * ub.T[:, :, None] * uq[None, None, :])
        rqq = lam * jnp.dot(xqt, xqt)
        prior = spec.k1e(rqq) * lam + spec.k2e(rqq) * uq * uq
    return R * solver.mask[None, :, None], prior


# ---------------------------------------------------------------------------
# Public variance / std entry points (batched over queries)
# ---------------------------------------------------------------------------


def value_var(spec: KernelSpec, Xq: Array, f: GramFactors,
              solver: GramSolver) -> Array:
    """Posterior variance of f at each query row of Xq: (Q,), clamped >= 0."""

    def one(xq):
        C, prior = _value_cross(spec, xq, f, solver)
        V = solve_gram(spec, f, solver, C)
        return prior - jnp.sum(C * V)

    var = jax.vmap(one)(jnp.atleast_2d(Xq))
    return jnp.maximum(solver.signal * var, 0.0)


def grad_var(spec: KernelSpec, Xq: Array, f: GramFactors,
             solver: GramSolver) -> Array:
    """Posterior variance of each gradient component at Xq: (Q, D).

    The diagonal of the (D, D) posterior covariance block per query — D
    structured solves per query, vmapped; clamped at zero.
    """

    def one(xq):
        R, prior = _grad_cross(spec, xq, f, solver)
        V = jax.vmap(lambda Ri: solve_gram(spec, f, solver, Ri))(R)
        return prior - jnp.sum(R * V, axis=(1, 2))

    var = jax.vmap(one)(jnp.atleast_2d(Xq))
    return jnp.maximum(solver.signal * var, 0.0)


def value_std(spec: KernelSpec, Xq: Array, f: GramFactors,
              solver: GramSolver) -> Array:
    return jnp.sqrt(value_var(spec, Xq, f, solver))


def grad_std(spec: KernelSpec, Xq: Array, f: GramFactors,
             solver: GramSolver) -> Array:
    return jnp.sqrt(grad_var(spec, Xq, f, solver))

"""The shared hyperparameter container (one source of truth across
optim / sampling / serve).

Everything downstream of the Gram factors is parameterized by exactly
three scalars (the paper's own experiments use isotropic Lambda and fixed
unit signal variance; App. F):

  * the squared lengthscale  ell^2       (Lambda = ell^-2 I, i.e. lam = 1/ell^2)
  * the signal variance      s^2         (prior k <- s^2 k)
  * the noise variance       sigma^2     (observation noise on the gradients)

``HyperParams`` stores their *logs* so the container doubles as the
unconstrained optimization pytree for ``repro.hyper.fit``: a plain
jax.grad / Adam step on the NamedTuple is automatically a step in a
valid (positive) hyperparameter — no projection needed, only the loose
bound guards of ``fit.py``.

Scaling identities used throughout the package (DESIGN.md sec. 11):

  s^2 * K_G(lam) + sigma^2 I  =  s^2 * [ K_G(lam) + (sigma^2/s^2) I ]

so every structured computation runs on the *unscaled* Gram with the
effective noise ``sigma^2/s^2``, and the signal re-enters as additive
``ND log s^2`` (logdet) / multiplicative ``1/s^2`` (quadratic form) /
``s^2`` (posterior variance) corrections.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax.numpy as jnp

Array = jnp.ndarray


class HyperParams(NamedTuple):
    """Log-reparameterized GP hyperparameters (a jit/grad-friendly pytree).

    Fields are the logs of the positive quantities; use :meth:`create` to
    build one from natural values and the properties to read them back.
    """

    log_lengthscale2: Array      # log ell^2  (Lambda = exp(-log ell^2) I)
    log_signal: Array            # log s^2    (signal variance)
    log_noise: Array             # log sigma^2 (noise variance)

    # -- natural-space views ------------------------------------------------

    @property
    def lengthscale2(self) -> Array:
        return jnp.exp(self.log_lengthscale2)

    @property
    def lam(self) -> Array:
        """The isotropic Lambda scalar: lam = 1 / ell^2."""
        return jnp.exp(-self.log_lengthscale2)

    @property
    def signal(self) -> Array:
        return jnp.exp(self.log_signal)

    @property
    def noise(self) -> Array:
        return jnp.exp(self.log_noise)

    @property
    def noise_eff(self) -> Array:
        """sigma^2 / s^2 — the noise seen by the UNSCALED Gram system."""
        return jnp.exp(self.log_noise - self.log_signal)

    # -- constructors -------------------------------------------------------

    @classmethod
    def create(
        cls,
        lengthscale2: float | Array = 1.0,
        signal: float | Array = 1.0,
        noise: float | Array = 1e-8,
        dtype=None,
    ) -> "HyperParams":
        """Build from natural-space values (all must be > 0)."""
        def enc(v):
            a = jnp.log(jnp.asarray(v, dtype))
            if a.ndim != 0:
                raise ValueError("HyperParams fields must be scalars "
                                 f"(got shape {a.shape})")
            return a

        return cls(log_lengthscale2=enc(lengthscale2), log_signal=enc(signal),
                   log_noise=enc(noise))

    @classmethod
    def from_lam(cls, lam, signal=1.0, noise=1e-8, dtype=None) -> "HyperParams":
        """Build from the Lambda scalar used across core/ (lam = 1/ell^2)."""
        lam = jnp.asarray(lam, dtype)
        if lam.ndim != 0:
            raise ValueError("HyperParams requires scalar (isotropic) Lambda; "
                             f"got shape {lam.shape}")
        return cls.create(lengthscale2=1.0 / lam, signal=signal, noise=noise,
                          dtype=dtype)

    # -- misc ---------------------------------------------------------------

    def natural(self) -> dict:
        """Host-side summary {'lengthscale2', 'signal', 'noise'} as floats."""
        return {
            "lengthscale2": float(self.lengthscale2),
            "signal": float(self.signal),
            "noise": float(self.noise),
        }

    def __repr__(self):  # NamedTuple repr shows raw logs; natural is nicer
        try:
            n = self.natural()
            return (f"HyperParams(ell2={n['lengthscale2']:.4g}, "
                    f"s2={n['signal']:.4g}, noise={n['noise']:.4g})")
        except Exception:  # traced values have no float()
            return (f"HyperParams(log_ell2={self.log_lengthscale2}, "
                    f"log_s2={self.log_signal}, log_n2={self.log_noise})")


LOG2PI = math.log(2.0 * math.pi)

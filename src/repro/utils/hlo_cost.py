"""Trip-count-aware HLO cost model for the dry-run roofline.

XLA's ``compiled.cost_analysis()`` counts each while-loop BODY once —
for scan-stacked layer models that under-reports FLOPs/bytes by the layer
count (verified empirically: a 10-iteration scanned matmul reports 10x
fewer flops than its unrolled twin). Since every model here scans layers
(and microbatches), we walk the compiled HLO ourselves:

  * while ops carry ``backend_config={"known_trip_count":{"n":"N"}}`` —
    multiply the body totals by N;
  * FLOPs: dot ops (2 * prod(output dims) * prod(contracted dims)),
    recursing into fusion/call bodies;
  * HBM bytes: per top-level instruction, output bytes + operand bytes,
    skipping zero-cost views (tuple/gte/bitcast/parameter/constant) and
    NOT recursing into fusion bodies (fusion internals stay on-chip —
    that is the point of fusion);
  * collective bytes: output bytes of all-gather/all-reduce/
    reduce-scatter/all-to-all/collective-permute at their call site
    (so collectives inside scanned layers count per iteration).

This is a structural lower-bound-style model: elementwise FLOPs are not
counted (dot-dominated workloads; the mamba/moe gating undercount is noted
in EXPERIMENTS.md) and cache reuse is not modeled.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->")
# name = <shape-or-tuple> op( ...   — the shape group is non-greedy "anything
# up to the last word before the first '('"; tuple shapes may contain
# /*index=N*/ comments and layout braces, so no attempt to grammar them.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s([\w\-]+)\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_ATTR_RE = re.compile(r"(?:body|calls|condition|branch_computations)="
                           r"(\{[^}]*\}|%[\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")
_VIEW_OPS = {"tuple", "get-tuple-element", "bitcast", "parameter",
             "constant", "iota", "after-all", "add-dependency"}


def _shapes_bytes(shape_text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        nb = _DTYPE_BYTES.get(dt)
        if nb is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * nb
    return total


def _shape_dims(shape_text: str) -> list[int]:
    m = _SHAPE_RE.search(shape_text)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class _Instr:
    name: str
    shape: str
    op: str
    line: str


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes_hbm: float = 0.0        # pessimistic: output + operand bytes
    bytes_out: float = 0.0        # optimistic: output bytes only (perfect
    #                               producer->consumer fusion; TPU backends
    #                               fuse far more than the CPU backend the
    #                               dry-run compiles with)
    coll_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(default_factory=dict)

    def __iadd__(self, o: "Costs"):
        self.flops += o.flops
        self.bytes_hbm += o.bytes_hbm
        self.bytes_out += o.bytes_out
        self.coll_bytes += o.coll_bytes
        for k, v in o.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v
        return self

    def scaled(self, f: float) -> "Costs":
        return Costs(self.flops * f, self.bytes_hbm * f, self.bytes_out * f,
                     self.coll_bytes * f,
                     {k: v * f for k, v in self.coll_by_kind.items()})


def _parse_computations(hlo: str) -> dict[str, list[_Instr]]:
    comps: dict[str, list[_Instr]] = {}
    cur: list[_Instr] | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if not line.startswith(" ") and ("(" in line and ")" in line and
                                         "->" in line and "{" in line):
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                cur = []
                comps[m.group(1)] = cur
                continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            cur.append(_Instr(name=m.group(1), shape=m.group(2),
                              op=m.group(3), line=line))
    return comps


def _dot_flops(instr: _Instr, shapes: dict[str, str]) -> float:
    out_dims = _shape_dims(instr.shape)
    out_n = 1
    for d in out_dims:
        out_n *= d
    cm = _CONTRACT_RE.search(instr.line)
    contracted = 1
    if cm:
        idxs = [int(i) for i in cm.group(1).split(",") if i != ""]
        # operand list: first %name after '(' that is a known instruction
        args = instr.line.split("(", 1)[1]
        ops = [o for o in _OPERAND_RE.findall(args)]
        if ops:
            lhs_shape = shapes.get(ops[0], "")
            lhs_dims = _shape_dims(lhs_shape)
            for i in idxs:
                if i < len(lhs_dims):
                    contracted *= lhs_dims[i]
    return 2.0 * out_n * contracted


def analyze_hlo(hlo: str) -> Costs:
    comps = _parse_computations(hlo)
    shape_of: dict[str, dict[str, str]] = {
        cname: {i.name: i.shape for i in instrs}
        for cname, instrs in comps.items()
    }
    memo: dict[str, Costs] = {}

    def eval_comp(cname: str) -> Costs:
        if cname in memo:
            return memo[cname]
        memo[cname] = Costs()          # cycle guard
        total = Costs()
        instrs = comps.get(cname, [])
        local_shapes = shape_of.get(cname, {})
        for ins in instrs:
            if ins.op == "while":
                tm = _TRIP_RE.search(ins.line)
                trip = float(tm.group(1)) if tm else 1.0
                body = cond = None
                bm = re.search(r"body=%?([\w.\-]+)", ins.line)
                cm = re.search(r"condition=%?([\w.\-]+)", ins.line)
                if bm:
                    body = bm.group(1)
                if cm:
                    cond = cm.group(1)
                if body and body in comps:
                    total += eval_comp(body).scaled(trip)
                if cond and cond in comps:
                    total += eval_comp(cond).scaled(trip + 1.0)
                continue
            is_async_done = ins.op.endswith("-done")
            kind = next((c for c in _COLLECTIVES if ins.op.startswith(c)), None)
            if kind and not is_async_done:
                b = float(_shapes_bytes(ins.shape))
                total += Costs(0.0, 0.0, 0.0, b, {kind: b})
                continue
            if ins.op in ("fusion", "call", "conditional", "custom-call",
                          "reduce", "sort", "scatter", "map"):
                # bytes at the call site; flops from inside (dots in bodies)
                args = ins.line.split("(", 1)[1]
                opnds = _OPERAND_RE.findall(args)
                b = float(_shapes_bytes(ins.shape))
                for o in opnds:
                    if o in local_shapes:
                        b += float(_shapes_bytes(local_shapes[o]))
                inner = Costs()
                for callee in re.findall(
                        r"(?:calls|to_apply|branch_computations)=\{?%?"
                        r"([\w.\-]+(?:, ?%[\w.\-]+)*)\}?", ins.line):
                    for cn in _OPERAND_RE.findall("%" + callee.replace(
                            ", %", " %")):
                        if cn in comps:
                            c_in = eval_comp(cn)
                            inner += Costs(c_in.flops, 0.0, 0.0,
                                           c_in.coll_bytes,
                                           dict(c_in.coll_by_kind))
                total += Costs(inner.flops, b,
                               float(_shapes_bytes(ins.shape)),
                               inner.coll_bytes, dict(inner.coll_by_kind))
                continue
            if ins.op in _VIEW_OPS:
                continue
            if ins.op == "dot":
                total += Costs(_dot_flops(ins, local_shapes), 0.0, 0.0, 0.0,
                               {})
                # dot also reads/writes memory
            # generic data-moving op: output + operands
            args = ins.line.split("(", 1)[1]
            opnds = _OPERAND_RE.findall(args)
            out_b = float(_shapes_bytes(ins.shape))
            b = out_b
            for o in opnds:
                if o in local_shapes:
                    b += float(_shapes_bytes(local_shapes[o]))
            total += Costs(0.0, b, out_b, 0.0, {})
        memo[cname] = total
        return total

    # entry computation: the one marked ENTRY, else the last one
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                entry = m.group(1)
                break
    if entry is None and comps:
        entry = list(comps)[-1]
    return eval_comp(entry) if entry else Costs()

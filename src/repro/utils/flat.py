"""Pytree <-> flat-vector adapters for parameter-space GP inference.

The GP gradient machinery (core/) sees models as points in R^D. Training
code sees pytrees of weight matrices. The adapters here provide a fixed,
jit-stable mapping between the two, with optional zero-padding of D to a
multiple of the mesh size so the flat vector shards evenly over every
device ("every device holds D/num_devices of every state tensor",
DESIGN.md sec. 6). Padding is mathematically inert for the GP: padded
coordinates carry zero gradient and a zero row/column of Lambda, so they
never contribute to any X^T Lambda V contraction.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class FlatSpec:
    """Static description of a pytree's flat layout (hashable, jit-safe)."""

    treedef: Any
    shapes: tuple[tuple[int, ...], ...]
    dtypes: tuple[Any, ...]
    sizes: tuple[int, ...]
    offsets: tuple[int, ...]
    total: int          # un-padded logical dimension D
    padded: int         # D rounded up to a multiple of `pad_to`

    @property
    def pad(self) -> int:
        return self.padded - self.total


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def tree_size(tree: Any) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(tree))


def make_flat_spec(tree: Any, pad_to: int = 1) -> FlatSpec:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(l.dtype for l in leaves)
    sizes = tuple(int(np.prod(s)) for s in shapes)
    offsets = tuple(int(o) for o in np.cumsum((0,) + sizes)[:-1])
    total = int(sum(sizes))
    return FlatSpec(
        treedef=treedef, shapes=shapes, dtypes=dtypes, sizes=sizes,
        offsets=offsets, total=total, padded=_round_up(max(total, 1), pad_to),
    )


def flatten_pytree(tree: Any, spec: FlatSpec, dtype=jnp.float32) -> Array:
    """Concatenate all leaves into one (spec.padded,) vector."""
    leaves = jax.tree_util.tree_leaves(tree)
    parts = [l.reshape(-1).astype(dtype) for l in leaves]
    flat = jnp.concatenate(parts) if parts else jnp.zeros((0,), dtype)
    if spec.pad:
        flat = jnp.pad(flat, (0, spec.pad))
    return flat


def unflatten_pytree(flat: Array, spec: FlatSpec) -> Any:
    """Inverse of flatten_pytree; drops padding, restores shapes/dtypes."""
    leaves = []
    for off, size, shape, dt in zip(spec.offsets, spec.sizes, spec.shapes,
                                    spec.dtypes):
        leaves.append(
            jax.lax.dynamic_slice_in_dim(flat, off, size).reshape(shape).astype(dt)
        )
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)


def flat_axis_sharding(mesh, axes: Sequence[str] | None = None):
    """NamedSharding that shards a flat (padded,) vector over ALL mesh axes.

    The GP optimizer state (X history, G history, moments) is a set of
    D-vectors; sharding them over the flattened mesh gives D/num_devices
    per chip and makes every skinny contraction a fully local matmul + one
    O(N^2) psum.
    """
    from jax.sharding import NamedSharding, PartitionSpec

    names = tuple(mesh.axis_names) if axes is None else tuple(axes)
    return NamedSharding(mesh, PartitionSpec(names))

"""Roofline-term calculator for dry-run compiled artifacts (TPU v5e target).

Three terms per (arch x mesh), each an estimated lower-bound execution time
in seconds (system-prompt recipe):

  compute    = HLO_FLOPs        / (chips * peak_flops)
  memory     = HLO_bytes        / (chips * hbm_bw)
  collective = collective_bytes / (chips * link_bw)

cost_analysis() reports whole-program numbers for one logical program; on a
mesh the program is SPMD so flops/bytes are already per-partition when XLA
compiles with SPMD partitioning — we therefore DO NOT divide by chips again
for those, only for quantities that are genuinely global. To keep this
unambiguous the caller says whether the numbers are per-device already.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Chip:
    name: str
    peak_flops: float   # bf16 FLOP/s
    hbm_bw: float       # bytes/s
    link_bw: float      # bytes/s per ICI link


TPUv5e = Chip(name="tpu_v5e", peak_flops=197e12, hbm_bw=819e9, link_bw=50e9)


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes_hbm: float
    bytes_collective: float
    chips: int
    model_flops: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)  # type: ignore[arg-type]

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu_bound(self) -> float:
        """Upper bound on achievable MFU at the roofline: the fraction of
        peak the dominant term permits for the *useful* flops."""
        denom = self.bound_s * self.chips * TPUv5e.peak_flops
        return self.model_flops / denom if denom else 0.0

    def row(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_ratio": self.useful_ratio,
            "mfu_bound": self.mfu_bound,
        }


def roofline_terms(
    *,
    flops_per_device: float,
    hbm_bytes_per_device: float,
    collective_bytes_per_device: float,
    chips: int,
    chip: Chip = TPUv5e,
    model_flops: float = 0.0,
) -> RooflineTerms:
    """All inputs are per-device (SPMD-partitioned) quantities."""
    return RooflineTerms(
        compute_s=flops_per_device / chip.peak_flops,
        memory_s=hbm_bytes_per_device / chip.hbm_bw,
        collective_s=collective_bytes_per_device / chip.link_bw,
        flops=flops_per_device,
        bytes_hbm=hbm_bytes_per_device,
        bytes_collective=collective_bytes_per_device,
        chips=chips,
        model_flops=model_flops,
    )


def model_flops(
    *,
    n_params_active: float,
    tokens: float,
    training: bool,
) -> float:
    """MODEL_FLOPS = 6*N*D for training, 2*N*D for inference (per step)."""
    per_token = 6.0 if training else 2.0
    return per_token * n_params_active * tokens

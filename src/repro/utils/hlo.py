"""HLO-text analysis: collective-communication byte accounting.

``compiled.cost_analysis()`` reports FLOPs and HBM bytes but NOT the bytes
moved by collectives; the dry-run therefore parses the compiled HLO text
and sums operand sizes of every collective op (system-prompt roofline
recipe). Parsing is purely lexical — shapes in HLO are printed as e.g.
``bf16[2048,512]{1,0}`` right after the op name.
"""
from __future__ import annotations

import re
from collections import defaultdict

_COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "fp8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# e.g. "bf16[256,4096]{1,0}" or "f32[]" — dtype then dims.
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+(?:[a-z0-9]*)?|pred)\[([0-9,]*)\]")

# "%name = <shape or tuple> op-name(" ; tolerate leading spaces and "ROOT".
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s+("
    + "|".join(_COLLECTIVE_OPS)
    + r")(?:-start|-done)?\(",
)


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        nbytes = _DTYPE_BYTES.get(dtype)
        if nbytes is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * nbytes
    return total


def collective_breakdown(hlo_text: str) -> dict[str, int]:
    """Map collective op kind -> summed OUTPUT-shape bytes across the module.

    The output shape is what lands on each participating device and is the
    standard proxy for per-device link traffic (an all-gather of a shard to
    a full array writes the full array locally; an all-reduce's result is
    the tensor itself). ``-done`` variants are skipped so async pairs are
    not double counted.
    """
    out: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        if f"{m.group(2)}-done(" in line:
            continue
        out[m.group(2)] += _shape_bytes(m.group(1))
    return dict(out)


def collective_bytes(hlo_text: str) -> int:
    """Total collective bytes (sum over all kinds) in an HLO module."""
    return sum(collective_breakdown(hlo_text).values())


_STREAM_CONSUMERS = ("dot_general", "pallas_call")


def _axis_ge(aval, d: int) -> bool:
    shape = getattr(aval, "shape", ())
    return any(isinstance(s, int) and s >= d for s in shape)


def _is_var(v) -> bool:
    import jax

    return not isinstance(v, jax.core.Literal)


def _walk_streams(jaxpr, tainted: set, d: int, counts: dict) -> set:
    """Taint-propagate a D-axis data argument; classify its consumers.

    A *consumer* is a contraction primitive (``dot_general`` or a
    ``pallas_call`` launch — the only ops that stream an operand through
    the MXU/HBM pipeline); every other eqn just forwards taint to outputs
    that keep a >= d axis (pads/casts/masks/elementwise).  Consumers are
    classified by their outputs: all outputs D-free -> a *reduction*
    stream (factor build); any output keeping the D axis -> an
    *expansion* stream (output assembly).  Taint does NOT flow through a
    consumer: its result is derived data, and a further pass over it is a
    new stream of that object, not of the argument being tracked.
    """
    for eqn in jaxpr.eqns:
        tin = any(_is_var(v) and v in tainted for v in eqn.invars)
        name = eqn.primitive.name
        if name in _STREAM_CONSUMERS:
            if tin:
                kind = ("expansion" if any(_axis_ge(v.aval, d)
                                           for v in eqn.outvars)
                        else "reduction")
                counts[kind] = counts.get(kind, 0) + 1
            continue  # opaque: no taint through, no recursion into bodies
        sub = eqn.params.get("jaxpr", eqn.params.get("call_jaxpr"))
        inner = getattr(sub, "jaxpr", sub)
        if hasattr(inner, "eqns") and len(inner.invars) == len(eqn.invars):
            sub_taint = {iv for iv, ov in zip(inner.invars, eqn.invars)
                         if _is_var(ov) and ov in tainted}
            out_taint = _walk_streams(inner, sub_taint, d, counts)
            for outer_v, inner_v in zip(eqn.outvars, inner.outvars):
                if _is_var(inner_v) and inner_v in out_taint:
                    tainted.add(outer_v)
            continue
        if tin:
            for ov in eqn.outvars:
                if _axis_ge(ov.aval, d):
                    tainted.add(ov)
    return tainted


def count_data_streams(closed_jaxpr, argnum: int, d: int) -> dict:
    """{'reduction': r, 'expansion': e} streams of argument ``argnum``.

    The structural teeth behind the single-sweep claim (DESIGN.md sec. 12):
    tracing e.g. ``woodbury_solve`` as a function of X and counting the
    contractions that consume X (or anything elementwise-derived from it,
    pads and casts included) proves the lowered program reads the data
    stream exactly once to build factors (``reduction == 1``) plus the one
    unavoidable output-assembly stream (``expansion``) — a refactor that
    reintroduces a separate norms/S/RHS pass flips the count.  ``d`` is
    the data axis length; derived (N, N) objects must all be smaller, so
    pick shapes with max(N, Q)**2 < d when tracing.
    """
    jaxpr = closed_jaxpr.jaxpr
    counts: dict = {"reduction": 0, "expansion": 0}
    _walk_streams(jaxpr, {jaxpr.invars[argnum]}, d, counts)
    return counts


def count_primitive(jaxpr, name: str) -> int:
    """Recursively count occurrences of a jax primitive in a jaxpr.

    Walks into nested jaxprs (pjit/cond/scan/while bodies). Used to assert
    structural invariants — e.g. that the fused Gram MVM compiles to
    exactly ONE pallas_call (a Pallas kernel can only round-trip HBM
    through declared outputs, so the launch count pins the transfer model
    of DESIGN.md 4.3).
    """
    import jax

    count = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            count += 1
        for v in eqn.params.values():
            for leaf in jax.tree_util.tree_leaves(
                    v, is_leaf=lambda x: hasattr(x, "jaxpr") or hasattr(x, "eqns")):
                inner = getattr(leaf, "jaxpr", leaf)
                if hasattr(inner, "eqns"):
                    count += count_primitive(inner, name)
    return count


def count_psums(closed_jaxpr) -> int:
    """Number of ``psum`` equations in a traced shard_map program.

    The one-psum-per-phase gate of the D-sharded state machine
    (``core/dist_state.py``, DESIGN.md sec. 14): a multi-operand
    ``jax.lax.psum(tuple, ...)`` is ONE fused psum equation, so this count
    is exactly the number of collective launches a phase issues — extend
    <= 1, evict == 0, lengthscale refactor == 0, resolve/query == 1.
    Counts trace-level structure; lax.cond/switch bodies are all counted,
    so gate the per-phase functions, not a branchy step that traces
    every alternative.
    """
    return count_primitive(closed_jaxpr.jaxpr, "psum")


# ---------------------------------------------------------------------------
# Jaxpr shape census: axis/size bounds for structural never-dense gates
# ---------------------------------------------------------------------------


def jaxpr_axis_sizes(jaxpr) -> list:
    """Every integer axis size appearing on any var of ``jaxpr`` (recursing
    into sub-jaxprs).  The census behind the structural never-dense gates:
    ``hyper.mll.assert_no_dense_gram`` (exact regime, N < D) and
    ``regime.krylov.assert_streaming_structure`` (iterative regime, N > D).
    """
    dims: list = []
    for eqn in jaxpr.eqns:
        for v in list(eqn.invars) + list(eqn.outvars):
            shape = getattr(getattr(v, "aval", None), "shape", ())
            dims.extend(int(s) for s in shape if isinstance(s, int))
        for val in eqn.params.values():
            for sub in (val if isinstance(val, (tuple, list)) else (val,)):
                inner = getattr(sub, "jaxpr", sub)
                if hasattr(inner, "eqns"):
                    dims.extend(jaxpr_axis_sizes(inner))
    return dims


def jaxpr_var_sizes(jaxpr) -> list:
    """Total element count of every var of ``jaxpr`` (recursing into
    sub-jaxprs).  Catches square dense objects whose individual axes are
    individually legal — an (ND, ND) matrix has axis ND (same as a mere
    vec flattening) but ND^2 elements."""
    import math as _math

    sizes: list = []
    for eqn in jaxpr.eqns:
        for v in list(eqn.invars) + list(eqn.outvars):
            shape = getattr(getattr(v, "aval", None), "shape", ())
            if all(isinstance(s, int) for s in shape):
                sizes.append(int(_math.prod(shape)) if shape else 1)
        for val in eqn.params.values():
            for sub in (val if isinstance(val, (tuple, list)) else (val,)):
                inner = getattr(sub, "jaxpr", sub)
                if hasattr(inner, "eqns"):
                    sizes.extend(jaxpr_var_sizes(inner))
    return sizes

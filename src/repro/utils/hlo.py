"""HLO-text analysis: collective-communication byte accounting.

``compiled.cost_analysis()`` reports FLOPs and HBM bytes but NOT the bytes
moved by collectives; the dry-run therefore parses the compiled HLO text
and sums operand sizes of every collective op (system-prompt roofline
recipe). Parsing is purely lexical — shapes in HLO are printed as e.g.
``bf16[2048,512]{1,0}`` right after the op name.
"""
from __future__ import annotations

import re
from collections import defaultdict

_COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "fp8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# e.g. "bf16[256,4096]{1,0}" or "f32[]" — dtype then dims.
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+(?:[a-z0-9]*)?|pred)\[([0-9,]*)\]")

# "%name = <shape or tuple> op-name(" ; tolerate leading spaces and "ROOT".
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s+("
    + "|".join(_COLLECTIVE_OPS)
    + r")(?:-start|-done)?\(",
)


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        nbytes = _DTYPE_BYTES.get(dtype)
        if nbytes is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * nbytes
    return total


def collective_breakdown(hlo_text: str) -> dict[str, int]:
    """Map collective op kind -> summed OUTPUT-shape bytes across the module.

    The output shape is what lands on each participating device and is the
    standard proxy for per-device link traffic (an all-gather of a shard to
    a full array writes the full array locally; an all-reduce's result is
    the tensor itself). ``-done`` variants are skipped so async pairs are
    not double counted.
    """
    out: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        if f"{m.group(2)}-done(" in line:
            continue
        out[m.group(2)] += _shape_bytes(m.group(1))
    return dict(out)


def collective_bytes(hlo_text: str) -> int:
    """Total collective bytes (sum over all kinds) in an HLO module."""
    return sum(collective_breakdown(hlo_text).values())


def count_primitive(jaxpr, name: str) -> int:
    """Recursively count occurrences of a jax primitive in a jaxpr.

    Walks into nested jaxprs (pjit/cond/scan/while bodies). Used to assert
    structural invariants — e.g. that the fused Gram MVM compiles to
    exactly ONE pallas_call (a Pallas kernel can only round-trip HBM
    through declared outputs, so the launch count pins the transfer model
    of DESIGN.md 4.3).
    """
    import jax

    count = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            count += 1
        for v in eqn.params.values():
            for leaf in jax.tree_util.tree_leaves(
                    v, is_leaf=lambda x: hasattr(x, "jaxpr") or hasattr(x, "eqns")):
                inner = getattr(leaf, "jaxpr", leaf)
                if hasattr(inner, "eqns"):
                    count += count_primitive(inner, name)
    return count

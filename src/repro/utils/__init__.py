"""Shared utilities: flat-parameter adapters, HLO analysis, roofline math."""
from .flat import FlatSpec, flatten_pytree, unflatten_pytree, tree_size
from .hlo import collective_bytes, collective_breakdown
from .roofline import RooflineTerms, TPUv5e, roofline_terms, model_flops

__all__ = [
    "FlatSpec", "flatten_pytree", "unflatten_pytree", "tree_size",
    "collective_bytes", "collective_breakdown",
    "RooflineTerms", "TPUv5e", "roofline_terms", "model_flops",
]

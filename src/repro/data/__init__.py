from .pipeline import DataConfig, batch_for_step, batch_shard_for_step

__all__ = ["DataConfig", "batch_for_step", "batch_shard_for_step"]

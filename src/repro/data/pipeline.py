"""Deterministic synthetic token pipeline — stateless, resumable, sharded.

Every sequence is a pure function of (config, step, row) via
jax.random.fold_in chains, so:
  * restart-from-checkpoint reproduces the exact token stream from any step
    (no pipeline state to save beyond the step counter);
  * each data shard generates only ITS rows — no host ever materializes
    the global batch (the per-row keying makes shard output invariant to
    how rows are grouped into shards);
  * elasticity: resharding is renumbering row ranges, nothing moves.

Token structure: the second half of each sequence repeats the first half
(induction-head pattern). That makes the stream genuinely learnable —
train loss on the copy region falls well below the iid-token entropy floor,
which the end-to-end example uses as its success criterion.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    copy_pattern: bool = True


@partial(jax.jit, static_argnums=(0,))
def _rows(cfg: DataConfig, step: Array, row_ids: Array) -> Array:
    base = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)

    def one(row):
        key = jax.random.fold_in(base, row)
        toks = jax.random.randint(key, (cfg.seq_len,), 0, cfg.vocab_size,
                                  dtype=jnp.int32)
        if cfg.copy_pattern:
            half = cfg.seq_len // 2
            toks = toks.at[half:2 * half].set(toks[:half])
        return toks

    return jax.vmap(one)(row_ids)


def batch_for_step(cfg: DataConfig, step: int) -> dict:
    """Full global batch (tests / single-host examples)."""
    return {"tokens": _rows(cfg, jnp.asarray(step),
                            jnp.arange(cfg.global_batch))}


def batch_shard_for_step(cfg: DataConfig, step: int, shard: int,
                         num_shards: int) -> dict:
    """Shard `shard` of `num_shards` of the step's batch.

    Exactness contract: concatenating all shards == batch_for_step(step)
    row-split into num_shards (per-ROW keying makes the stream invariant
    to resharding — the elasticity property tests rely on this).
    """
    assert cfg.global_batch % num_shards == 0
    rows = cfg.global_batch // num_shards
    ids = jnp.arange(shard * rows, (shard + 1) * rows)
    return {"tokens": _rows(cfg, jnp.asarray(step), ids)}

"""chatglm3-6b — dense GQA with 2D (half/partial) RoPE (arXiv:2406.12793).

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024.
"""
from jax import numpy as jnp

from repro.models import ModelConfig

CONFIG = ModelConfig(
    arch="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    rope_style="half",
    qkv_bias=True,              # chatglm uses qkv bias
    param_dtype=jnp.bfloat16,
    compute_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    arch="chatglm3-6b-smoke",
    family="dense",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    rope_style="half",
    qkv_bias=True,
    param_dtype=jnp.float32,
    compute_dtype=jnp.float32,
    remat=False,
)

OPTIMIZER = "adamw"

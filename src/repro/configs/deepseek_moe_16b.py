"""deepseek-moe-16b — fine-grained MoE (arXiv:2401.06066).

28L d_model=2048 16H (MHA: kv=16) d_ff=1408/expert vocab=102400,
64 routed experts top-6 + 2 shared experts. ~16B params / ~2.8B active.
"""
from jax import numpy as jnp

from repro.models import ModelConfig

CONFIG = ModelConfig(
    arch="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    rope_style="full",
    param_dtype=jnp.bfloat16,
    compute_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    arch="deepseek-moe-16b-smoke",
    family="moe",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=32,
    vocab_size=512,
    n_experts=8,
    n_shared_experts=2,
    top_k=3,
    param_dtype=jnp.float32,
    compute_dtype=jnp.float32,
    remat=False,
)

OPTIMIZER = "adamw"

"""mamba2-130m — pure SSM, SSD/state-space duality (arXiv:2405.21060).

24L d_model=768 (attention-free) vocab=50280, ssm_state=128,
d_inner = 2*768 = 1536, head_dim 64 -> 24 SSM heads.
Decode state is O(1)/layer: long_500k is the showcase shape.
"""
from jax import numpy as jnp

from repro.models import ModelConfig

CONFIG = ModelConfig(
    arch="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    param_dtype=jnp.bfloat16,
    compute_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    arch="mamba2-130m-smoke",
    family="ssm",
    n_layers=3,
    d_model=64,
    vocab_size=512,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=16,
    ssm_chunk=16,
    param_dtype=jnp.float32,
    compute_dtype=jnp.float32,
    remat=False,
)

OPTIMIZER = "adamw"

"""Config registry: --arch <id> lookup for all assigned architectures."""
from __future__ import annotations

import importlib

from repro.models import ModelConfig

_MODULES = {
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "chatglm3-6b": "chatglm3_6b",
    "qwen2.5-32b": "qwen2_5_32b",
    "gemma3-4b": "gemma3_4b",
    "gemma3-1b": "gemma3_1b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "mamba2-130m": "mamba2_130m",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "zamba2-7b": "zamba2_7b",
}

ARCHS = tuple(_MODULES)


def _module(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; have {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = _module(arch)
    return mod.SMOKE if smoke else mod.CONFIG


def get_optimizer_name(arch: str) -> str:
    return getattr(_module(arch), "OPTIMIZER", "adamw")

"""Paper-native experiment configurations (App. F).

These drive the benchmarks that reproduce each figure/table:
  * LINALG  — Fig. 2: 100-D quadratic, poly2 kernel, prescribed spectrum
  * ROSEN   — Fig. 3/4: relaxed 100-D Rosenbrock, isotropic RBF
  * HMC     — Fig. 5: 100-D banana target, RBF surrogate
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class LinalgConfig:
    d: int = 100
    lam_min: float = 0.5
    lam_max: float = 100.0
    rho: float = 0.6
    tol: float = 1e-5          # relative gradient-norm termination
    max_iters: int = 120
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class RosenbrockConfig:
    d: int = 100
    history: int = 2           # paper: last 2 observations
    lam_gph: float = 9.0       # Lambda = 9*I for GP-H (App. F.2)
    lam_gpx: float = 0.05      # Lambda = 0.05*I for GP-X
    max_iters: int = 300
    tol_grad: float = 1e-6
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class GPServeConfig:
    """Knobs of the batched posterior-query serving path (train/serve.py).

    ``precision`` selects the STREAM storage dtype of the (N, D) operands
    the query path reads (X/Xt/Z and the query batch): 'bf16' halves their
    HBM bytes — which IS the wall clock of these memory-bound sweeps —
    while every contraction still accumulates in f32 and all factors/
    solves stay f32 (precision policy table, DESIGN.md sec. 12).

    ``tol``/``maxiter`` are the served state's CG solve knobs (the
    warm-started re-solve each ``extend`` runs): ``maxiter=None`` lets
    the state pick — condition-scaled via the attached health monitor's
    proxy when one is sampling, else the ``10*capacity + 50`` ceiling
    (``core.state._default_maxiter``).
    """

    microbatch: int = 64
    precision: str = "f32"       # 'f32' | 'bf16' stream storage
    tol: float = 1e-10           # CG residual tolerance of state solves
    maxiter: int | None = None   # CG budget; None = condition-scaled/auto


@dataclasses.dataclass(frozen=True)
class GPFleetConfig:
    """Knobs of the multi-tenant fleet serving path (core/fleet.py +
    train/serve.py::GPFleetServer).

    ``batch`` is the INITIAL lane count — the fleet doubles on demand, so
    signatures stay O(log tenants).  ``window`` is the per-tenant sliding
    window (= state capacity; the paper serves from the last few gradient
    observations).  ``q_bucket`` pads query requests up to power-of-two
    buckets starting here, bounding compile signatures of the batched
    query step.  ``idle_ttl`` server steps without any request evicts a
    tenant (its lane is zeroed and reusable); ``solver_cache_max`` bounds
    the per-tenant variance-solver LRU (each entry is an O(cap^4) LU).
    """

    batch: int = 8
    window: int = 4
    q_bucket: int = 16
    idle_ttl: int = 256
    solver_cache_max: int = 8
    refit_steps: int = 16
    refit_lr: float = 0.1
    # -- resilience knobs (DESIGN.md sec. 17) --------------------------
    # max_queue: submissions past this depth are load-shed with a typed
    # ShedResponse; deadline_steps: server steps a request may wait
    # before expiring; max_retries: bounded requeues after an injected
    # kill; quarantine_threshold: consecutive faults before a tenant's
    # lane is masked off.
    max_queue: int = 1024
    deadline_steps: int = 64
    max_retries: int = 2
    quarantine_threshold: int = 3


@dataclasses.dataclass(frozen=True)
class HMCConfig:
    d: int = 100
    n_samples: int = 2000
    # step size / leapfrog steps scale with D per Neal (App. F.3)
    eps_base: float = 4e-3
    t_base: int = 32
    # ell^2 = 0.4*D is the paper's HEURISTIC INIT for the axis-aligned
    # banana (App. F.3) — a hand-set guess, not a fitted value.  With
    # hyper_mode="mll" it only seeds ``repro.hyper.fit``: the surrogate
    # re-fits (lengthscale, signal, noise) by exact structured MLL ascent
    # on the phase-1 training set (GPGState.refit inside gpg_hmc).
    lengthscale2_factor: float = 0.4     # ell^2 = 0.4*D (aligned case)
    hyper_mode: str = "heuristic"        # 'heuristic' | 'mll'
    budget_factor: float = 1.0           # N = floor(sqrt(D))
    mass: float = 1.0
    seed: int = 0


LINALG = LinalgConfig()
ROSEN = RosenbrockConfig()
HMC = HMCConfig()
GP_SERVE = GPServeConfig()
GP_FLEET = GPFleetConfig()

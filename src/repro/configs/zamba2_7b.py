"""zamba2-7b — hybrid: Mamba2 backbone + weight-tied shared attention
block every 6 layers (arXiv:2411.15242).

81L d_model=3584 32H (MHA kv=32) d_ff=14336 (in the shared block)
vocab=32000, ssm_state=64. 81 = 13 groups of 6 + 3 trailing Mamba layers;
the shared attn+MLP block fires 13 times with ONE set of weights.
long_500k RUNS (SSM layers O(1); 13 full-length KV caches for the shared
block invocations).
"""
from jax import numpy as jnp

from repro.models import ModelConfig

CONFIG = ModelConfig(
    arch="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    attn_every=6,
    param_dtype=jnp.bfloat16,
    compute_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    arch="zamba2-7b-smoke",
    family="hybrid",
    n_layers=8,                 # 2 groups of 3 + 2 remainder
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=16,
    ssm_chunk=16,
    attn_every=3,
    param_dtype=jnp.float32,
    compute_dtype=jnp.float32,
    remat=False,
)

OPTIMIZER = "adamw"

"""qwen2-vl-7b — VLM backbone with M-RoPE (arXiv:2409.12191).

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064. The vision
frontend is a STUB per assignment: input_specs() provides precomputed
patch embeddings (B, n_patches, D) that replace the prompt prefix, plus
3-stream (t/h/w) M-RoPE position ids.
"""
from jax import numpy as jnp

from repro.models import ModelConfig

CONFIG = ModelConfig(
    arch="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    rope_style="mrope",
    rope_theta=1e6,
    qkv_bias=True,
    n_patches=256,
    param_dtype=jnp.bfloat16,
    compute_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    arch="qwen2-vl-7b-smoke",
    family="vlm",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    rope_style="mrope",
    qkv_bias=True,
    n_patches=8,
    param_dtype=jnp.float32,
    compute_dtype=jnp.float32,
    remat=False,
)

OPTIMIZER = "adamw"

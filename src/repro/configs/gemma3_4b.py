"""gemma3-4b — dense, 5:1 local:global sliding-window interleave, 128k ctx.

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144, head_dim=256,
window=1024, tied embeddings. long_500k RUNS (sub-quadratic: only the 1-in-6
global layers carry a full-length cache).
"""
from jax import numpy as jnp

from repro.models import ModelConfig

CONFIG = ModelConfig(
    arch="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    window=1024,
    global_every=6,
    tie_embeddings=True,
    rope_theta=1e6,
    param_dtype=jnp.bfloat16,
    compute_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    arch="gemma3-4b-smoke",
    family="dense",
    n_layers=8,                 # 1 group of 6 + 2 remainder: hits both stacks
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    window=16,
    global_every=6,
    tie_embeddings=True,
    param_dtype=jnp.float32,
    compute_dtype=jnp.float32,
    remat=False,
)

OPTIMIZER = "adamw"

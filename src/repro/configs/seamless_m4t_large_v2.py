"""seamless-m4t-large-v2 — enc-dec multimodal backbone (arXiv:2308.11596).

24 encoder + 24 decoder layers, d_model=1024 16H (MHA kv=16) d_ff=8192
vocab=256206. The audio frontend is a STUB per assignment: input_specs()
provides precomputed frame embeddings (B, S_src, D). Decode shapes run
(it has a decoder); long_500k is SKIPPED (full attention).
"""
from jax import numpy as jnp

from repro.models import ModelConfig

CONFIG = ModelConfig(
    arch="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,
    n_enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    param_dtype=jnp.bfloat16,
    compute_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    arch="seamless-m4t-large-v2-smoke",
    family="encdec",
    n_layers=3,
    n_enc_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    param_dtype=jnp.float32,
    compute_dtype=jnp.float32,
    remat=False,
)

OPTIMIZER = "adamw"

"""qwen2.5-32b — dense GQA with QKV bias (hf:Qwen/Qwen2.5).

64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064.
40 heads over a 16-way TP axis is uneven -> GSPMD pads (roofline notes).
"""
from jax import numpy as jnp

from repro.models import ModelConfig

CONFIG = ModelConfig(
    arch="qwen2.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=27648,
    vocab_size=152064,
    rope_style="full",
    rope_theta=1e6,
    qkv_bias=True,
    param_dtype=jnp.bfloat16,
    compute_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    arch="qwen2.5-32b-smoke",
    family="dense",
    n_layers=3,
    d_model=80,
    n_heads=5,                  # keep the uneven-heads property
    n_kv_heads=1,
    d_ff=192,
    vocab_size=512,
    qkv_bias=True,
    param_dtype=jnp.float32,
    compute_dtype=jnp.float32,
    remat=False,
)

OPTIMIZER = "adamw"

"""kimi-k2-1t-a32b — trillion-param MoE (Kimi K2, arXiv:2501.kimi2).

61L d_model=7168 64H (GQA kv=8) d_ff=2048/expert vocab=163840,
MoE 384 routed experts top-8 + 1 shared expert.
~1.03T total params / ~32B active. head_dim = 7168/64 = 112.

Memory note (EXPERIMENTS.md §Dry-run): params bf16 alone are 2 TB; with
gradients this saturates a single 256-chip v5e pod's 4 TB HBM, so train_4k
for this arch is multi-pod territory by physics — the optimizer therefore
defaults to factored second moments (adafactor-style) + no master copy.
"""
from jax import numpy as jnp

from repro.models import ModelConfig

CONFIG = ModelConfig(
    arch="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=112,
    d_ff=2048,
    vocab_size=163840,
    n_experts=384,
    n_shared_experts=1,
    top_k=8,
    rope_style="full",
    param_dtype=jnp.bfloat16,
    compute_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    arch="kimi-k2-1t-a32b-smoke",
    family="moe",
    n_layers=3,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    head_dim=8,
    d_ff=32,
    vocab_size=512,
    n_experts=16,
    n_shared_experts=1,
    top_k=4,
    param_dtype=jnp.float32,
    compute_dtype=jnp.float32,
    remat=False,
)

OPTIMIZER = "adafactor"        # 1T params: factored stats or bust (see above)

"""gemma3-1b — dense, 5:1 local:global interleave (small sibling).

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144, head_dim=256,
window=512, tied embeddings. long_500k RUNS.
"""
from jax import numpy as jnp

from repro.models import ModelConfig

CONFIG = ModelConfig(
    arch="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    window=512,
    global_every=6,
    tie_embeddings=True,
    rope_theta=1e6,
    param_dtype=jnp.bfloat16,
    compute_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    arch="gemma3-1b-smoke",
    family="dense",
    n_layers=7,                 # 1 group + 1 remainder
    d_model=48,
    n_heads=2,
    n_kv_heads=1,
    head_dim=16,
    d_ff=96,
    vocab_size=512,
    window=16,
    global_every=6,
    tie_embeddings=True,
    param_dtype=jnp.float32,
    compute_dtype=jnp.float32,
    remat=False,
)

OPTIMIZER = "adamw"

"""Cost accounting: modeled HBM bytes / flops as live per-call gauges.

``utils/hlo_cost.py`` and ``utils/roofline.py`` already model compiled
programs for the dry-run; this module turns them into *recorded telemetry*:

  * :func:`modeled`          — lower a callable once per (name, shape
    signature), run ``analyze_hlo`` on the compiled text, and publish
    ``cost.<name>.hbm_bytes`` / ``cost.<name>.out_bytes`` /
    ``cost.<name>.flops`` gauges + one ``{"type": "cost"}`` event.
    The analysis is cached, so steady-state serving pays nothing.
  * :func:`record_measured`  — put the measured seconds next to the model:
    ``cost.<name>.seconds`` and ``cost.<name>.roofline_fraction`` (the
    roofline-predicted time for the modeled bytes/flops divided by the
    measured time — achieved fraction of the chip's roofline bound,
    logged instead of folklore).

The serve layer calls both per request signature
(``train/serve.py::GPServeBundle.query``), scaling the one-chunk model by
the chunk count.  Lowering goes through a FRESH ``jax.jit`` of the raw
function — never through a CompileWatch-wrapped entry point, which would
record a phantom compile event.
"""
from __future__ import annotations

from typing import Callable, Optional

from repro.obs import compile_watch as _cw
from repro.obs import trace as _trace

_MODEL_CACHE: dict = {}


def modeled(name: str, fn: Callable, *args, scale: float = 1.0):
    """Model one call of ``fn(*args)``; publish ``cost.<name>.*`` gauges.

    Returns the (scaled) ``utils.hlo_cost.Costs`` — or None when
    observability is off (nothing is compiled or recorded).  Results are
    cached per (name, signature): the lower+compile+parse happens once
    per serve geometry, not per request.
    """
    if not _trace.enabled():
        return None
    import jax

    from repro.utils.hlo_cost import analyze_hlo

    key = (name, _cw.signature(args, {}))
    costs = _MODEL_CACHE.get(key)
    if costs is None:
        hlo = jax.jit(fn).lower(*args).compile().as_text()
        costs = analyze_hlo(hlo)
        _MODEL_CACHE[key] = costs
    out = costs.scaled(scale) if scale != 1.0 else costs
    _trace.REGISTRY.set_gauge(f"cost.{name}.hbm_bytes", out.bytes_hbm)
    _trace.REGISTRY.set_gauge(f"cost.{name}.out_bytes", out.bytes_out)
    _trace.REGISTRY.set_gauge(f"cost.{name}.flops", out.flops)
    _trace.emit({"type": "cost", "name": name, "flops": out.flops,
                 "hbm_bytes": out.bytes_hbm, "out_bytes": out.bytes_out,
                 "scale": scale})
    return out


def record_measured(name: str, seconds: float, costs=None,
                    chip=None) -> Optional[float]:
    """Record measured wall-clock next to the model for ``name``.

    ``costs`` is a ``Costs`` from :func:`modeled` (pass the same one the
    request was modeled with); with it, the achieved fraction of roofline
    — min-time-per-model / measured — is computed against ``chip``
    (default ``utils.roofline.TPUv5e``) and published as
    ``cost.<name>.roofline_fraction``.  Returns the fraction (or None).
    """
    if not _trace.enabled():
        return None
    _trace.REGISTRY.set_gauge(f"cost.{name}.seconds", float(seconds))
    _trace.REGISTRY.observe(f"cost.{name}.seconds_hist", float(seconds))
    frac = None
    if costs is not None and seconds > 0.0:
        if chip is None:
            from repro.utils.roofline import TPUv5e as chip
        bound = max(costs.bytes_hbm / chip.hbm_bw,
                    costs.flops / chip.peak_flops)
        frac = bound / float(seconds)
        _trace.REGISTRY.set_gauge(f"cost.{name}.roofline_fraction", frac)
    return frac


def clear_model_cache() -> None:
    _MODEL_CACHE.clear()
